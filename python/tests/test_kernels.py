"""L1 correctness: Pallas kernels vs the pure-jnp oracle, bit-exact.

Hypothesis sweeps shapes, strides, channel counts and exponents — the
kernel must agree with ref.py on every integer output.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import avgpool_global, conv2d, linear, maxpool2d, ref
from compile.kernels import quantize as qz

RNG = np.random.default_rng(0)


def rand_i(shape, lo=-128, hi=127):
    return jnp.asarray(RNG.integers(lo, hi + 1, shape), jnp.int32)


conv_cases = st.tuples(
    st.sampled_from([1, 2]),  # batch
    st.sampled_from([4, 6, 8]),  # H = W
    st.sampled_from([1, 3, 8]),  # cin
    st.sampled_from([4, 16]),  # cout
    st.sampled_from([(1, 0), (3, 1), (3, 0)]),  # (k, pad)
    st.sampled_from([1, 2]),  # stride
    st.booleans(),  # relu
    st.integers(min_value=-8, max_value=-4),  # out_exp - acc_exp control
)


@settings(max_examples=40, deadline=None)
@given(conv_cases)
def test_conv2d_matches_ref(case):
    n, h, cin, cout, (k, pad), stride, relu, shift = case
    if h + 2 * pad < k:
        return
    x = rand_i((n, h, h, cin))
    w = rand_i((k, k, cin, cout))
    b = rand_i((cout,), -(2**15), 2**15 - 1)
    acc_exp = -14
    out_exp = acc_exp - shift + 8  # a plausible positive shift
    got = conv2d(x, w, b, stride=stride, pad=pad, acc_exp=acc_exp, out_exp=out_exp, relu=relu)
    want = ref.conv2d_ref(x, w, b, stride, pad, acc_exp, out_exp, relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(
    st.sampled_from([4, 8]),
    st.sampled_from([4, 8, 16]),
    st.integers(min_value=-9, max_value=-3),
)
def test_conv2d_skip_init_matches_ref(h, cout, skip_exp):
    """The fused residual accumulator-init path (paper Fig. 13)."""
    x = rand_i((2, h, h, 4))
    w = rand_i((3, 3, 4, cout))
    b = rand_i((cout,), -(2**15), 2**15 - 1)
    skip = rand_i((2, h, h, cout))
    acc_exp = -14
    got = conv2d(x, w, b, stride=1, pad=1, acc_exp=acc_exp, out_exp=-6, relu=True,
                 skip=skip, skip_exp=skip_exp)
    want = ref.conv2d_ref(x, w, b, 1, 1, acc_exp, -6, True, skip=skip, skip_exp=skip_exp)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([2, 4]), st.sampled_from([4, 8, 16]), st.sampled_from([(2, 2), (3, 1)]))
def test_maxpool_matches_ref(n, c, ks):
    k, stride = ks
    x = rand_i((n, 8, 8, c))
    got = maxpool2d(x, k, stride)
    want = ref.maxpool2d_ref(x, k, stride)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([2, 4, 8]), st.integers(min_value=-8, max_value=-4))
def test_avgpool_matches_ref(hw, out_exp):
    x = rand_i((2, hw, hw, 16))
    got = avgpool_global(x, -6, out_exp)
    want = ref.avgpool_global_ref(x, -6, out_exp)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_linear_matches_ref():
    x = rand_i((4, 64))
    w = rand_i((64, 10))
    b = rand_i((10,), -(2**15), 2**15 - 1)
    np.testing.assert_array_equal(
        np.asarray(linear(x, w, b)), np.asarray(ref.linear_ref(x, w, b))
    )


# ------------------------------------------------------------ quant laws


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=-(2**30), max_value=2**30), st.integers(min_value=1, max_value=20))
def test_round_shift_is_floor_half_up(acc, shift):
    got = int(qz.round_shift(np.int32(acc), shift))
    want = (acc + (1 << (shift - 1))) >> shift
    assert got == want


@settings(max_examples=200, deadline=None)
@given(st.integers(min_value=-(2**30), max_value=2**30))
def test_relu_commutes_with_requantize(acc):
    a = jnp.asarray([acc], jnp.int32)
    fused = ref.qz.requantize(a, -14, -6, True) if False else qz.requantize(a, -14, -6, True)
    separate = jnp.maximum(qz.requantize(a, -14, -6, False), 0)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(separate))


def test_acc_width_paper_eq7():
    # Eq. 6/7: worst ResNet8/20 layer accumulates 9216 products -> 30 bits.
    n_acc = 32 * 32 * 3 * 3
    bits = int(np.ceil(np.log2(n_acc))) + 16
    assert bits == 30


def test_pow2_exponent_covers():
    assert qz.pow2_exponent(127.0, 8) == 0
    assert qz.pow2_exponent(1.0, 8) == -6
    for m in [0.3, 1.7, 12.0, 100.0]:
        e = qz.pow2_exponent(m, 8)
        assert 127.0 * 2.0**e >= m
        assert 127.0 * 2.0 ** (e - 1) < m
