"""AOT export path: HLO text sanity + weight blob layout."""

import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from compile import arch as A
from compile import params as P
from compile.aot import export_weights, lower_variant, vmem_report

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_lowered_hlo_contains_full_constants(tmp_path):
    """Regression for the elided-constants bug: `constant({...})` in the
    HLO text silently drops the baked weights (caught by probe-check)."""
    arch = A.resnet8()
    params, act_exps, w_exps, _ = P.get_params(arch)
    hlo = lower_variant(arch, params, act_exps, w_exps, 1)
    assert "ENTRY" in hlo
    assert "constant({...})" not in hlo, "large constants must be printed in full"
    assert "source_end_line" not in hlo, "metadata breaks the 0.5.1 parser"
    # The stem weight tensor (3,3,3,16) should appear as an s32 constant.
    assert "s32[3,3,3,16]" in hlo


def test_export_weights_blob_roundtrip(tmp_path):
    arch = A.resnet8()
    params, act_exps, w_exps, _ = P.get_params(arch)
    fname, records = export_weights(arch, params, w_exps, act_exps, str(tmp_path))
    blob = open(os.path.join(tmp_path, fname), "rb").read()
    for rec in records:
        raw = blob[rec["offset"] : rec["offset"] + rec["bytes"]]
        if rec["dtype"] == "i8":
            vals = np.frombuffer(raw, dtype=np.int8).astype(np.int64)
        else:
            vals = np.frombuffer(raw, dtype="<i2").astype(np.int64)
        want = np.asarray(params[rec["name"]][rec["kind"]]).reshape(-1)
        np.testing.assert_array_equal(vals, want, err_msg=f"{rec['name']}.{rec['kind']}")
    # Bias exponents are accumulator exponents (input exp + weight exp).
    prod = P._producer_map(arch)
    for rec in records:
        if rec["kind"] == "b":
            assert rec["exp"] == act_exps[prod[rec["name"]]] + w_exps[rec["name"]]


def test_vmem_report_within_tpu_budget():
    """L1 perf gate: per-grid-step VMEM footprint of the BlockSpec
    schedule stays under a TPU core's ~16 MiB VMEM for every layer (the
    rolling-window variant under 2 MiB)."""
    for name in ["resnet8", "resnet20"]:
        arch = A.ARCHS[name]()
        for row in vmem_report(arch):
            assert row["total"] < 16 * 2**20, row
            assert row["total_rolling"] < 2 * 2**20, row


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")), reason="artifacts not built")
def test_manifest_schema():
    m = json.load(open(os.path.join(ART, "manifest.json")))
    assert m["version"] == 1
    names = {v["name"] for v in m["models"]}
    assert "resnet8_b1" in names and "resnet20_b8" in names
    for arch_name, entry in m["archs"].items():
        assert os.path.exists(os.path.join(ART, entry["weights_file"]))
        assert "act_exps" in entry and "w_exps" in entry
        assert entry["act_exps"]["input"] == -7
    p = m["probe"]
    assert p["count"] >= 8
    for f in [p["input"], p["labels"], *p["logits"].values()]:
        assert os.path.exists(os.path.join(ART, f))


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")), reason="artifacts not built")
def test_probe_logits_match_current_weights():
    """The exported probe logits must be reproducible from the exported
    weights (guards against stale artifacts)."""
    from compile import data as D
    from compile import model as M

    m = json.load(open(os.path.join(ART, "manifest.json")))
    imgs = np.frombuffer(open(os.path.join(ART, m["probe"]["input"]), "rb").read(), dtype=np.int8)
    n = m["probe"]["count"]
    x = jnp.asarray(imgs.reshape(n, 32, 32, 3).astype(np.int32))
    for arch_name, logit_file in m["probe"]["logits"].items():
        arch = A.ARCHS[arch_name]()
        params, act_exps, w_exps, _ = P.get_params(arch)
        jp = {k: {"w": jnp.asarray(v["w"]), "b": jnp.asarray(v["b"])} for k, v in params.items()}
        want = np.frombuffer(open(os.path.join(ART, logit_file), "rb").read(), dtype="<i4")
        got = np.asarray(M.ref_forward(arch, jp, act_exps, w_exps, x)).reshape(-1)
        np.testing.assert_array_equal(got, want, err_msg=arch_name)
    _ = D
