"""L2 correctness: the quantized ResNet graphs.

* pallas forward == jnp ref forward (bit-exact) for both architectures;
* optimized (fused) dataflow == naive explicit-add dataflow;
* shape and exponent bookkeeping;
* dataset generator self-checks (the Rust side re-validates byte equality
  against the exported probe).
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import arch as A
from compile import data as D
from compile import model as M
from compile import params as P


def _setup(name):
    arch = A.ARCHS[name]()
    params, act_exps, w_exps, _ = P.get_params(arch)
    jp = {k: {"w": jnp.asarray(v["w"]), "b": jnp.asarray(v["b"])} for k, v in params.items()}
    return arch, jp, act_exps, w_exps


@pytest.mark.parametrize("name", ["resnet8", "resnet20"])
def test_pallas_forward_matches_ref(name):
    arch, jp, act_exps, w_exps = _setup(name)
    imgs, _ = D.eval_batch(0, 4)
    x = jnp.asarray(imgs)
    got = M.forward(arch, jp, act_exps, w_exps, x)
    want = M.ref_forward(arch, jp, act_exps, w_exps, x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.shape == (4, 10)


@pytest.mark.parametrize("name", ["resnet8", "resnet20"])
def test_fused_equals_explicit_add(name):
    """Paper Section III-G: the graph optimizations preserve numerics."""
    arch, jp, act_exps, w_exps = _setup(name)
    imgs, _ = D.eval_batch(8, 4)
    x = jnp.asarray(imgs)
    fused = M.ref_forward(arch, jp, act_exps, w_exps, x)
    naive = M.unoptimized_ref_forward(arch, jp, act_exps, w_exps, x)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(naive))


def test_arch_geometry():
    r8, r20 = A.resnet8(), A.resnet20()
    assert len(r8.blocks) == 3 and len(r20.blocks) == 9
    assert len(r8.conv_layers()) == 9 and len(r20.conv_layers()) == 21
    # MAC counts in the published ballpark.
    assert 11e6 < r8.total_macs() < 14e6
    assert 40e6 < r20.total_macs() < 42e6
    # Downsample blocks are exactly the stage transitions.
    assert sum(1 for b in r8.blocks if b.downsample) == 2
    assert sum(1 for b in r20.blocks if b.downsample) == 2


def test_param_shapes_follow_arch():
    arch = A.resnet8()
    params, _, _ = P.random_int_params(arch)
    for c in arch.conv_layers():
        assert params[c.name]["w"].shape == (c.k, c.k, c.cin, c.cout)
        assert params[c.name]["b"].shape == (c.cout,)
    assert params["fc"]["w"].shape == (64, 10)


def test_logits_depend_on_input_and_weights():
    arch, jp, act_exps, w_exps = _setup("resnet8")
    a, _ = D.eval_batch(0, 1)
    b, _ = D.eval_batch(1, 1)
    la = np.asarray(M.ref_forward(arch, jp, act_exps, w_exps, jnp.asarray(a)))
    lb = np.asarray(M.ref_forward(arch, jp, act_exps, w_exps, jnp.asarray(b)))
    assert not np.array_equal(la, lb)


# ------------------------------------------------------------- dataset


def test_dataset_deterministic_and_classful():
    x1, y1 = D.batch(0, 20, "test")
    x2, y2 = D.batch(0, 20, "test")
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, np.arange(20) % 10)
    assert x1.min() >= -128 and x1.max() <= 127
    # Different classes differ far beyond the noise floor.
    mad = np.abs(x1[0].astype(np.int64) - x1[1].astype(np.int64)).mean()
    assert mad > 24


def test_dataset_split_seeds_differ():
    a, _ = D.batch(0, 4, "train")
    b, _ = D.batch(0, 4, "test")
    assert not np.array_equal(a, b)


def test_lcg_matches_spec_constants():
    """Pin the LCG recurrence so the Rust mirror can never drift."""
    s = np.uint64(0)
    with np.errstate(over="ignore"):
        s = s * D.LCG_A + D.LCG_C
    assert int(s) == 1442695040888963407
    assert int(D.LCG_A) == 6364136223846793005


def test_quantize_checkpoint_bias_at_acc_exponent():
    arch = A.resnet8()
    rng = np.random.default_rng(0)
    fp = {}
    for c in arch.conv_layers():
        fp[c.name] = {
            "w": rng.normal(0, 0.1, (c.k, c.k, c.cin, c.cout)),
            "b": rng.normal(0, 0.1, (c.cout,)),
        }
    fp["fc"] = {"w": rng.normal(0, 0.1, (64, 10)), "b": np.zeros(10)}
    act_exps = A.default_act_exps(arch)
    int_params, w_exps = P.quantize_checkpoint(arch, fp, act_exps)
    producer = P._producer_map(arch)
    for name, p in int_params.items():
        assert p["w"].min() >= -127 and p["w"].max() <= 127
        assert p["b"].min() >= -(2**15) and p["b"].max() < 2**15
        # Weight exponent tight for max|w|.
        maxw = np.abs(fp[name]["w"]).max()
        assert 127 * 2.0 ** w_exps[name] >= maxw * 0.999
        _ = producer[name]
