"""Parameter containers: deterministic init, checkpoint save/load, and
conversion from float QAT checkpoints to the integer inference format.

Integer param dict layout (consumed by model.forward and exported by
aot.py):

    params[name] = {"w": int32 array (int8-valued), "b": int32 (int16-valued)}

with conv weights shaped (KH, KW, CIN, COUT) and fc weights (CIN, COUT).
Biases are stored *at the accumulator exponent* (in_exp + w_exp), which is
how the hardware consumes them (bias initializes the 32-bit accumulator,
paper Section III-C) — int16 range per the paper's bias quantization.
"""

from __future__ import annotations

import json
import os

import numpy as np

from . import arch as A
from .kernels import quantize as qz

CHECKPOINT_DIR = os.path.join(os.path.dirname(__file__), "..", "checkpoints")


def _conv_shape(arch: A.ArchSpec, name: str):
    for c in arch.conv_layers():
        if c.name == name:
            return (c.k, c.k, c.cin, c.cout)
    if name == "fc":
        return (arch.fc_in, arch.fc_out)
    raise KeyError(name)


def random_int_params(arch: A.ArchSpec, seed: int = 1234):
    """Deterministic random int8 weights (used when no checkpoint exists).

    He-style scale: std ~ sqrt(2 / fan_in) mapped into the int8 grid at the
    default weight exponent, so activations neither explode nor die and the
    artifact path is numerically representative even untrained.
    """
    w_exps = A.default_weight_exps(arch)
    act_exps = A.default_act_exps(arch)
    params = {}
    rng = np.random.default_rng(seed)
    for c in arch.conv_layers():
        fan_in = c.k * c.k * c.cin
        std_real = np.sqrt(2.0 / fan_in)
        std_q = std_real / 2.0 ** w_exps[c.name]
        w = np.clip(np.round(rng.normal(0.0, std_q, (c.k, c.k, c.cin, c.cout))), -127, 127)
        b = np.zeros((c.cout,), dtype=np.int64)
        params[c.name] = {"w": w.astype(np.int32), "b": b.astype(np.int32)}
    fan_in = arch.fc_in
    std_q = np.sqrt(2.0 / fan_in) / 2.0 ** w_exps["fc"]
    w = np.clip(np.round(rng.normal(0.0, std_q, (arch.fc_in, arch.fc_out))), -127, 127)
    params["fc"] = {
        "w": w.astype(np.int32),
        "b": np.zeros((arch.fc_out,), dtype=np.int32),
    }
    return params, act_exps, w_exps


def quantize_checkpoint(arch: A.ArchSpec, float_params: dict, act_exps: dict):
    """float QAT checkpoint -> integer params + weight exponents.

    Per-layer weight exponent = tightest power of two covering max|w|
    (Section III-A); bias is quantized to int16 at the accumulator
    exponent acc = in_exp + w_exp.
    """
    int_params, w_exps = {}, {}
    producer_of = _producer_map(arch)
    for name, p in float_params.items():
        w = np.asarray(p["w"], dtype=np.float64)
        e_w = qz.pow2_exponent(float(np.abs(w).max()), bits=8)
        q_w = np.clip(np.round(w / 2.0**e_w), -127, 127).astype(np.int32)
        in_exp = act_exps[producer_of[name]]
        acc_exp = in_exp + e_w
        b = np.asarray(p["b"], dtype=np.float64)
        q_b = np.clip(np.round(b / 2.0**acc_exp), qz.INT16_MIN, qz.INT16_MAX).astype(np.int32)
        int_params[name] = {"w": q_w, "b": q_b}
        w_exps[name] = e_w
    return int_params, w_exps


def _producer_map(arch: A.ArchSpec) -> dict:
    """conv/fc name -> name of the tensor it reads (for exponent lookup)."""
    producer = {"stem": "input"}
    prev = "stem"
    for blk in arch.blocks:
        if blk.downsample is not None:
            producer[blk.downsample.name] = prev
        producer[blk.conv0.name] = prev
        producer[blk.conv1.name] = blk.conv0.name
        prev = blk.conv1.name
    producer["fc"] = "pool"
    return producer


def checkpoint_path(arch_name: str) -> str:
    return os.path.join(CHECKPOINT_DIR, f"{arch_name}_qat.npz")


def save_checkpoint(arch_name: str, int_params: dict, act_exps: dict, w_exps: dict, meta: dict):
    os.makedirs(CHECKPOINT_DIR, exist_ok=True)
    arrays = {}
    for name, p in int_params.items():
        arrays[f"{name}.w"] = p["w"]
        arrays[f"{name}.b"] = p["b"]
    arrays["__meta__"] = np.frombuffer(
        json.dumps({"act_exps": act_exps, "w_exps": w_exps, **meta}).encode(), dtype=np.uint8
    )
    np.savez(checkpoint_path(arch_name), **arrays)


def load_checkpoint(arch: A.ArchSpec):
    """Returns (int_params, act_exps, w_exps, meta) or None if absent."""
    path = checkpoint_path(arch.name)
    if not os.path.exists(path):
        return None
    z = np.load(path)
    meta = json.loads(bytes(z["__meta__"]).decode())
    params = {}
    for name in arch.param_names():
        params[name] = {"w": z[f"{name}.w"].astype(np.int32), "b": z[f"{name}.b"].astype(np.int32)}
    act_exps = {k: int(v) for k, v in meta.pop("act_exps").items()}
    w_exps = {k: int(v) for k, v in meta.pop("w_exps").items()}
    return params, act_exps, w_exps, meta


def get_params(arch: A.ArchSpec, allow_random: bool = True):
    """Checkpoint if trained, deterministic random otherwise."""
    ckpt = load_checkpoint(arch)
    if ckpt is not None:
        p, a, w, _ = ckpt
        return p, a, w, "checkpoint"
    if not allow_random:
        raise FileNotFoundError(f"no checkpoint for {arch.name}")
    p, a, w = random_int_params(arch)
    return p, a, w, "random"
