"""Deterministic synthetic CIFAR-10 (the paper's dataset substitute).

The paper trains/evaluates on CIFAR-10.  That dataset is not available in
this environment, so we substitute a *bit-exactly reproducible* synthetic
set with the same geometry (32x32x3 int8 images, 10 classes) — see
DESIGN.md §Substitutions.  The generator is defined entirely over integer
arithmetic so that `rust/src/data/cifar.rs` can reproduce the exact same
bytes (asserted via the probe batch exported by aot.py):

* label(i) = i mod 10
* class pattern: a class-dependent integer lattice function (conv-learnable
  structure, not linearly trivial);
* noise: a 64-bit LCG (MMIX constants) seeded per sample, one step per
  element in (y, x, ch) depth-last order; amplitude +-24.

pixel(i, y, x, ch) = clip(pattern + noise, -128, 127), int8 @ 2**-7.

If a real CIFAR-10 binary batch (data_batch_*.bin) is placed under
python/cifar10/ the loaders pick it up instead — the substitution is a
fallback, not a fork of the code path.
"""

from __future__ import annotations

import os

import numpy as np

LCG_A = np.uint64(6364136223846793005)
LCG_C = np.uint64(1442695040888963407)
SEED_MIX = np.uint64(2654435761)
TRAIN_SEED = np.uint64(0x5EED_0001)
TEST_SEED = np.uint64(0x5EED_0002)
IMG_ELEMS = 32 * 32 * 3


def _pattern(label: int) -> np.ndarray:
    """Class-dependent base image, shape (32, 32, 3), range [-96, 96]."""
    c = label
    y = np.arange(32).reshape(32, 1, 1)
    x = np.arange(32).reshape(1, 32, 1)
    ch = np.arange(3).reshape(1, 1, 3)
    v = (x * (3 + 2 * c) + y * (5 + 3 * c) + ch * (7 + 5 * c) + 11 * c * c) % 97
    return (v * 2 - 96).astype(np.int32)


_JUMP_MULT = None
_JUMP_ADD = None


def _jump_tables():
    """Vectorized LCG: state_k = A^k * s0 + B_k for k in [0, IMG_ELEMS)."""
    global _JUMP_MULT, _JUMP_ADD
    if _JUMP_MULT is None:
        mult = np.empty(IMG_ELEMS, dtype=np.uint64)
        add = np.empty(IMG_ELEMS, dtype=np.uint64)
        m, a = np.uint64(1), np.uint64(0)
        with np.errstate(over="ignore"):
            for k in range(IMG_ELEMS):
                # state after k+1 steps from s0: m*s0 + a
                m = m * LCG_A
                a = a * LCG_A + LCG_C
                mult[k] = m
                add[k] = a
        _JUMP_MULT, _JUMP_ADD = mult, add
    return _JUMP_MULT, _JUMP_ADD


def sample(index: int, split_seed: np.uint64):
    """One synthetic sample: (image int8-valued int32 (32,32,3), label)."""
    label = index % 10
    s0 = (np.uint64(index) * SEED_MIX + split_seed).astype(np.uint64)
    mult, add = _jump_tables()
    with np.errstate(over="ignore"):
        states = mult * s0 + add
    noise = ((states >> np.uint64(33)) & np.uint64(0xFF)).astype(np.int32) % 49 - 24
    img = _pattern(label) + noise.reshape(32, 32, 3)
    return np.clip(img, -128, 127), label


def batch(start: int, n: int, split: str = "train"):
    """(images (n,32,32,3) int32, labels (n,) int32)."""
    seed = TRAIN_SEED if split == "train" else TEST_SEED
    imgs = np.empty((n, 32, 32, 3), dtype=np.int32)
    labels = np.empty((n,), dtype=np.int32)
    for j in range(n):
        imgs[j], labels[j] = sample(start + j, seed)
    return imgs, labels


def _real_cifar_dir() -> str | None:
    d = os.path.join(os.path.dirname(__file__), "..", "cifar10")
    return d if os.path.isdir(d) and any(
        f.endswith(".bin") for f in os.listdir(d)
    ) else None


def load_real_batch(path: str, n: int | None = None):
    """CIFAR-10 binary format: per record 1 label byte + 3072 RGB bytes
    (channel-planar).  Returns NHWC int8-valued int32 @ 2**-7 (x - 128)."""
    raw = np.fromfile(path, dtype=np.uint8)
    rec = 3073
    m = len(raw) // rec
    if n is not None:
        m = min(m, n)
    raw = raw[: m * rec].reshape(m, rec)
    labels = raw[:, 0].astype(np.int32)
    imgs = raw[:, 1:].reshape(m, 3, 32, 32).transpose(0, 2, 3, 1).astype(np.int32) - 128
    return imgs, labels


def eval_batch(start: int, n: int):
    """Test-split batch: real CIFAR-10 if provided, synthetic otherwise."""
    d = _real_cifar_dir()
    if d is not None:
        path = os.path.join(d, "test_batch.bin")
        if os.path.exists(path):
            imgs, labels = load_real_batch(path)
            return imgs[start : start + n], labels[start : start + n]
    return batch(start, n, split="test")
