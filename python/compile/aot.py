"""AOT export: lower the quantized ResNets to HLO text + weight blobs.

This is the single build-time Python entry point (`make artifacts`).  It
emits, under `artifacts/`:

* `<arch>_b<batch>.hlo.txt` — one HLO-text module per model variant with
  the integer weights baked in as constants.  HLO *text* (not
  `.serialize()`): the `xla` crate's xla_extension 0.5.1 rejects jax>=0.5
  serialized protos (64-bit instruction ids); the text parser reassigns
  ids and round-trips cleanly (see /opt/xla-example/README.md).
* `weights_<arch>.bin` — flat little-endian weight/bias blob for the Rust
  golden model (`sim::golden`), layout described in the manifest.
* `probe_input.bin` / `probe_labels.bin` / `probe_logits_<arch>.bin` — a
  16-image probe batch and its oracle logits: the cross-language
  correctness anchor (Rust asserts synthetic-dataset bit-equality, golden
  bit-equality, and PJRT-execution bit-equality against these).
* `manifest.json` — ties it all together (shapes, exponents, offsets).

Batch variants are compiled separately (batch baked into the HLO) so the
Rust dynamic batcher can pick an executable per batch bucket — one
compiled executable per model variant, as the runtime design requires.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import arch as A
from . import data as D
from . import model as M
from . import params as P
from .kernels import vmem_footprint_bytes

BATCHES = {"resnet8": (1, 8, 64), "resnet20": (1, 8)}
PROBE_N = 16


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format).

    `print_large_constants=True` is load-bearing: the default printer
    elides big literals as `constant({...})`, which silently drops the
    baked weights from the interchange — the Rust probe-check caught this
    as a PJRT-vs-oracle mismatch.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # The xla crate's 0.5.1-era parser rejects newer metadata attributes
    # (source_end_line etc.) — strip metadata entirely.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def export_weights(arch: A.ArchSpec, params: dict, w_exps: dict, act_exps: dict, out_dir: str):
    """Write weights_<arch>.bin and return the manifest tensor records."""
    producer = P._producer_map(arch)
    records, blob = [], bytearray()
    for name in arch.param_names():
        w = np.asarray(params[name]["w"], dtype=np.int64)
        b = np.asarray(params[name]["b"], dtype=np.int64)
        acc_exp = act_exps[producer[name]] + w_exps[name]
        for kind, arr, dtype in (("w", w, np.int8), ("b", b, np.int16)):
            data = arr.astype(dtype).tobytes()
            records.append(
                {
                    "name": name,
                    "kind": kind,
                    "shape": list(arr.shape),
                    "exp": w_exps[name] if kind == "w" else acc_exp,
                    "dtype": "i8" if dtype is np.int8 else "i16",
                    "offset": len(blob),
                    "bytes": len(data),
                }
            )
            blob.extend(data)
    fname = f"weights_{arch.name}.bin"
    with open(os.path.join(out_dir, fname), "wb") as f:
        f.write(bytes(blob))
    return fname, records


def export_probe(out_dir: str, archs: dict) -> dict:
    """Probe batch + oracle logits for every arch."""
    imgs, labels = D.eval_batch(0, PROBE_N)
    with open(os.path.join(out_dir, "probe_input.bin"), "wb") as f:
        f.write(imgs.astype(np.int8).tobytes())
    with open(os.path.join(out_dir, "probe_labels.bin"), "wb") as f:
        f.write(labels.astype(np.uint8).tobytes())
    entry = {
        "input": "probe_input.bin",
        "labels": "probe_labels.bin",
        "count": PROBE_N,
        "logits": {},
    }
    for arch_name, (arch, params, act_exps, w_exps) in archs.items():
        jp = {k: {"w": jnp.asarray(v["w"]), "b": jnp.asarray(v["b"])} for k, v in params.items()}
        logits = np.asarray(M.ref_forward(arch, jp, act_exps, w_exps, jnp.asarray(imgs)))
        fname = f"probe_logits_{arch_name}.bin"
        with open(os.path.join(out_dir, fname), "wb") as f:
            f.write(logits.astype("<i4").tobytes())
        entry["logits"][arch_name] = fname
    return entry


def lower_variant(arch, params, act_exps, w_exps, batch: int) -> str:
    jp = {k: {"w": jnp.asarray(v["w"]), "b": jnp.asarray(v["b"])} for k, v in params.items()}

    def fn(x):
        return (M.forward(arch, jp, act_exps, w_exps, x),)

    spec = jax.ShapeDtypeStruct((batch, arch.in_h, arch.in_w, arch.in_c), jnp.int32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def vmem_report(arch: A.ArchSpec) -> list:
    """L1 perf deliverable: per-conv VMEM footprint of the Pallas BlockSpec
    schedule (interpret mode gives no wallclock — structure is the metric)."""
    rows = []
    for c in arch.conv_layers():
        fp = vmem_footprint_bytes(c.in_h, c.in_w, c.cin, c.k, c.k, c.cout, pad=c.pad)
        rows.append({"layer": c.name, **fp})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts dir (or stamp path inside it)")
    ap.add_argument("--archs", nargs="*", default=["resnet8", "resnet20"])
    ap.add_argument("--report", action="store_true", help="print VMEM footprint report only")
    args = ap.parse_args()

    out_dir = args.out
    if out_dir.endswith((".txt", ".stamp")):
        out_dir = os.path.dirname(out_dir)
    os.makedirs(out_dir, exist_ok=True)

    if args.report:
        for name in args.archs:
            arch = A.ARCHS[name]()
            print(f"== {name} VMEM footprints (bytes/grid-step)")
            for r in vmem_report(arch):
                print(
                    f"  {r['layer']:8s} x_slab={r['x_slab']:8d} rolling_min={r['x_rolling_min']:7d} "
                    f"w={r['weights']:7d} acc={r['acc']:6d} total={r['total']:8d}"
                )
        return

    manifest = {"version": 1, "models": [], "archs": {}, "generated_unix": int(time.time())}
    loaded = {}
    for name in args.archs:
        arch = A.ARCHS[name]()
        params, act_exps, w_exps, source = P.get_params(arch)
        loaded[name] = (arch, params, act_exps, w_exps)
        wfile, records = export_weights(arch, params, w_exps, act_exps, out_dir)
        manifest["archs"][name] = {
            "act_exps": act_exps,
            "w_exps": w_exps,
            "weights_file": wfile,
            "weights": records,
            "source": source,
            "vmem_report": vmem_report(arch),
        }
        for batch in BATCHES[name]:
            t0 = time.time()
            hlo = lower_variant(arch, params, act_exps, w_exps, batch)
            fname = f"{name}_b{batch}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(hlo)
            manifest["models"].append(
                {
                    "name": f"{name}_b{batch}",
                    "arch": name,
                    "batch": batch,
                    "hlo": fname,
                    "input_shape": [batch, arch.in_h, arch.in_w, arch.in_c],
                    "input_exp": act_exps["input"],
                    "output_shape": [batch, arch.num_classes],
                }
            )
            print(f"lowered {fname}  ({len(hlo)/1e6:.1f} MB, {time.time()-t0:.1f}s)", flush=True)

    manifest["probe"] = export_probe(out_dir, loaded)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    # Stamp for make's freshness check.
    with open(os.path.join(out_dir, ".stamp"), "w") as f:
        f.write(str(manifest["generated_unix"]))
    print(f"artifacts written to {out_dir}")


if __name__ == "__main__":
    main()
