"""Quantization-aware training (the paper's Brevitas flow, in JAX).

Two-phase recipe, exactly as Section III-A describes:

1. **Float + BatchNorm** — train the residual network with per-channel
   BN (batch statistics + running averages), SGD momentum, cosine LR.
2. **Fold + QAT fine-tune** — fold the BN scale/shift into the conv
   weights/biases ("the batch normalization layers are merged with the
   quantized convolution layers"), calibrate power-of-two exponents from
   running maxima, then fine-tune with fake quantization (straight-through
   estimator) "to calibrate and tune the quantization parameters".

The final integer checkpoint feeds aot.py (HLO export), the Rust golden
model, and the dataflow simulator's accuracy claims.

Usage:  python -m compile.train --arch resnet8 --steps 400
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import arch as A
from . import data as D
from . import model as M
from . import params as P
from .kernels import quantize as qz

BN_EPS = 1e-5
BN_MOMENTUM = 0.9


# ----------------------------------------------------------- float model


def _conv_f(x, w, stride, pad):
    return jax.lax.conv_general_dilated(
        x, w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def init_params(arch: A.ArchSpec, seed: int = 0):
    """Float parameters: conv weights + BN (gamma, beta) per conv."""
    rng = np.random.default_rng(seed)
    fp = {}
    for c in arch.conv_layers():
        fan_in = c.k * c.k * c.cin
        fp[c.name] = {
            "w": jnp.asarray(rng.normal(0, np.sqrt(2.0 / fan_in), (c.k, c.k, c.cin, c.cout)), jnp.float32),
            "gamma": jnp.ones((c.cout,), jnp.float32),
            "beta": jnp.zeros((c.cout,), jnp.float32),
        }
    fp["fc"] = {
        "w": jnp.asarray(rng.normal(0, np.sqrt(1.0 / arch.fc_in), (arch.fc_in, arch.fc_out)), jnp.float32),
        "b": jnp.zeros((arch.fc_out,), jnp.float32),
    }
    return fp


def init_bn_state(arch: A.ArchSpec):
    return {
        c.name: {"mean": jnp.zeros((c.cout,), jnp.float32), "var": jnp.ones((c.cout,), jnp.float32)}
        for c in arch.conv_layers()
    }


def float_forward(arch, fp, bn_state, x, train: bool):
    """Float forward with BN; returns (logits, new_bn_state)."""
    new_state = {}

    def spec(name):
        return next(c for c in arch.conv_layers() if c.name == name)

    def conv_bn(name, t, relu, skip=None):
        s = spec(name)
        y = _conv_f(t, fp[name]["w"], s.stride, s.pad)
        if train:
            mean = jnp.mean(y, axis=(0, 1, 2))
            var = jnp.var(y, axis=(0, 1, 2))
            new_state[name] = {
                "mean": BN_MOMENTUM * bn_state[name]["mean"] + (1 - BN_MOMENTUM) * mean,
                "var": BN_MOMENTUM * bn_state[name]["var"] + (1 - BN_MOMENTUM) * var,
            }
        else:
            mean, var = bn_state[name]["mean"], bn_state[name]["var"]
            new_state[name] = bn_state[name]
        y = (y - mean) / jnp.sqrt(var + BN_EPS) * fp[name]["gamma"] + fp[name]["beta"]
        if skip is not None:
            y = y + skip
        return jax.nn.relu(y) if relu else y

    a = conv_bn("stem", x, relu=True)
    for blk in arch.blocks:
        xin = a
        skip = conv_bn(blk.downsample.name, xin, relu=False) if blk.downsample else xin
        h = conv_bn(blk.conv0.name, xin, relu=True)
        a = conv_bn(blk.conv1.name, h, relu=True, skip=skip)
    pooled = jnp.mean(a, axis=(1, 2))
    return pooled @ fp["fc"]["w"] + fp["fc"]["b"][None, :], new_state


# ----------------------------------------------------- fold + fake-quant


def fold_bn(arch, fp, bn_state):
    """BN fold (paper Sec. III-A / [35]): W' = W*g/std, b' = beta - mean*g/std."""
    folded = {}
    for c in arch.conv_layers():
        p = fp[c.name]
        std = jnp.sqrt(bn_state[c.name]["var"] + BN_EPS)
        scale = p["gamma"] / std
        folded[c.name] = {
            "w": p["w"] * scale[None, None, None, :],
            "b": p["beta"] - bn_state[c.name]["mean"] * scale,
        }
    folded["fc"] = {"w": fp["fc"]["w"], "b": fp["fc"]["b"]}
    return folded


def qat_forward(arch, fp, act_exps, w_exps, x):
    """Fake-quantized folded forward, mirroring the integer dataflow."""

    def spec(name):
        return next(c for c in arch.conv_layers() if c.name == name)

    def conv(name, t, relu, skip=None):
        s = spec(name)
        w = qz.fake_quant(fp[name]["w"], w_exps[name], bits=8)
        y = _conv_f(t, w, s.stride, s.pad) + fp[name]["b"][None, None, None, :]
        if skip is not None:
            y = y + skip
        if relu:
            y = jax.nn.relu(y)
        return qz.fake_quant(y, act_exps[name], bits=8)

    a = conv("stem", qz.fake_quant(x, act_exps["input"], bits=8), relu=True)
    for blk in arch.blocks:
        xin = a
        skip = conv(blk.downsample.name, xin, relu=False) if blk.downsample else xin
        h = conv(blk.conv0.name, xin, relu=True)
        a = conv(blk.conv1.name, h, relu=True, skip=skip)
    pooled = qz.fake_quant(jnp.mean(a, axis=(1, 2)), act_exps["pool"], bits=8)
    w = qz.fake_quant(fp["fc"]["w"], w_exps["fc"], bits=8)
    return pooled @ w + fp["fc"]["b"][None, :]


def calibrate(arch, folded, x):
    """Per-tensor maxima of the folded float graph -> pow2 exponents."""
    maxima = {"input": 1.0, "pool": 0.0}

    def spec(name):
        return next(c for c in arch.conv_layers() if c.name == name)

    def conv(name, t, relu, skip=None):
        s = spec(name)
        y = _conv_f(t, folded[name]["w"], s.stride, s.pad) + folded[name]["b"][None, None, None, :]
        if skip is not None:
            y = y + skip
        if relu:
            y = jax.nn.relu(y)
        maxima[name] = float(jnp.abs(y).max())
        return y

    a = conv("stem", x, relu=True)
    for blk in arch.blocks:
        xin = a
        skip = conv(blk.downsample.name, xin, relu=False) if blk.downsample else xin
        h = conv(blk.conv0.name, xin, relu=True)
        a = conv(blk.conv1.name, h, relu=True, skip=skip)
    maxima["pool"] = float(jnp.abs(jnp.mean(a, axis=(1, 2))).max())

    act_exps = {k: qz.pow2_exponent(v, bits=8) for k, v in maxima.items()}
    act_exps["input"] = A.INPUT_EXP
    w_exps = {
        n: qz.pow2_exponent(float(jnp.abs(folded[n]["w"]).max()), bits=8)
        for n in arch.param_names()
    }
    return act_exps, w_exps


# ----------------------------------------------------------------- train


def _sgd_step(loss_fn):
    @jax.jit
    def step(fp, mom, x, y, lr):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(fp, x, y)
        # Global-norm gradient clipping keeps the norm-free fine-tune stable.
        gnorm = jnp.sqrt(sum(jnp.sum(g**2) for g in jax.tree.leaves(grads)) + 1e-12)
        clip = jnp.minimum(1.0, 5.0 / gnorm)
        mom = jax.tree.map(lambda m, g: 0.9 * m + g * clip, mom, grads)
        fp = jax.tree.map(lambda p, m: p - lr * m, fp, mom)
        return fp, mom, loss, aux

    return step


def train(arch_name: str, steps: int, batch: int, lr0: float, qat_frac: float = 0.3):
    arch = A.ARCHS[arch_name]()
    t0 = time.time()
    fp = init_params(arch)
    bn_state = init_bn_state(arch)
    history = []

    # ---- Phase 1: float + BN -------------------------------------------
    phase1 = max(1, int(steps * (1.0 - qat_frac)))

    def loss1(params, x, y):
        logits, new_state = float_forward(arch, params, bn_state, x, train=True)
        logp = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
        acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
        return loss, (acc, new_state)

    step1 = _sgd_step(loss1)
    mom = jax.tree.map(jnp.zeros_like, fp)
    for i in range(phase1):
        imgs, labels = D.batch(i * batch, batch)
        x = jnp.asarray(imgs, jnp.float32) * np.float32(2.0**A.INPUT_EXP)
        y = jnp.asarray(labels)
        lr = lr0 * 0.5 * (1.0 + np.cos(np.pi * i / phase1))
        fp, mom, loss, (acc, new_state) = step1(fp, mom, x, y, jnp.float32(lr))
        bn_state = jax.tree.map(lambda v: v, new_state)
        if i % 25 == 0 or i == phase1 - 1:
            print(f"[float] step {i:4d}  loss {float(loss):.4f}  acc {float(acc):.3f}", flush=True)
            history.append({"phase": "float", "step": i, "loss": float(loss), "acc": float(acc)})

    # ---- Fold BN + calibrate -------------------------------------------
    folded = fold_bn(arch, fp, bn_state)
    imgs, _ = D.batch(0, batch)
    x0 = jnp.asarray(imgs, jnp.float32) * np.float32(2.0**A.INPUT_EXP)
    act_exps, w_exps = calibrate(arch, folded, x0)
    print(f"folded BN; act exps: {sorted(set(act_exps.values()))}")

    # ---- Phase 2: QAT fine-tune ----------------------------------------
    phase2 = steps - phase1

    def loss2(params, x, y):
        logits = qat_forward(arch, params, act_exps, w_exps, x)
        logp = jax.nn.log_softmax(logits)
        loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
        acc = jnp.mean((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
        return loss, acc

    step2 = _sgd_step(loss2)
    mom = jax.tree.map(jnp.zeros_like, folded)
    lr_q = lr0 * 0.05
    for i in range(phase2):
        imgs, labels = D.batch((phase1 + i) * batch, batch)
        x = jnp.asarray(imgs, jnp.float32) * np.float32(2.0**A.INPUT_EXP)
        y = jnp.asarray(labels)
        lr = lr_q * 0.5 * (1.0 + np.cos(np.pi * i / max(1, phase2)))
        folded, mom, loss, acc = step2(folded, mom, x, y, jnp.float32(lr))
        if i % 25 == 0 or i == phase2 - 1:
            print(f"[qat]   step {i:4d}  loss {float(loss):.4f}  acc {float(acc):.3f}", flush=True)
            history.append({"phase": "qat", "step": i, "loss": float(loss), "acc": float(acc)})

    # ---- Export integer checkpoint --------------------------------------
    float_np = {n: {"w": np.asarray(p["w"]), "b": np.asarray(p["b"])} for n, p in folded.items()}
    int_params, w_exps_final = P.quantize_checkpoint(arch, float_np, act_exps)
    int_acc = evaluate_int(arch, int_params, act_exps, w_exps_final, n=512)
    float_acc = evaluate_float(arch, folded, n=512)
    print(
        f"{arch_name}: float(folded) acc {float_acc:.3f} -> int8 acc {int_acc:.3f}"
        f"  ({time.time()-t0:.0f}s)"
    )
    P.save_checkpoint(
        arch.name, int_params, act_exps, w_exps_final,
        {"steps": steps, "batch": batch, "int8_accuracy": int_acc,
         "float_accuracy": float_acc, "history": history},
    )
    return int_acc


def evaluate_int(arch, int_params, act_exps, w_exps, n=512, bs=128):
    jp = {k: {"w": jnp.asarray(v["w"]), "b": jnp.asarray(v["b"])} for k, v in int_params.items()}
    correct = 0
    for s in range(0, n, bs):
        imgs, labels = D.eval_batch(s, min(bs, n - s))
        logits = M.ref_forward(arch, jp, act_exps, w_exps, jnp.asarray(imgs))
        correct += int(np.sum(np.argmax(np.asarray(logits), axis=1) == labels))
    return correct / n


def evaluate_float(arch, folded, n=512, bs=128):
    def fwd(x):
        def spec(name):
            return next(c for c in arch.conv_layers() if c.name == name)

        def conv(name, t, relu, skip=None):
            s = spec(name)
            y = _conv_f(t, folded[name]["w"], s.stride, s.pad) + folded[name]["b"][None, None, None, :]
            if skip is not None:
                y = y + skip
            return jax.nn.relu(y) if relu else y

        a = conv("stem", x, relu=True)
        for blk in arch.blocks:
            xin = a
            skip = conv(blk.downsample.name, xin, relu=False) if blk.downsample else xin
            h = conv(blk.conv0.name, xin, relu=True)
            a = conv(blk.conv1.name, h, relu=True, skip=skip)
        return jnp.mean(a, axis=(1, 2)) @ folded["fc"]["w"] + folded["fc"]["b"][None, :]

    correct = 0
    for s in range(0, n, bs):
        imgs, labels = D.eval_batch(s, min(bs, n - s))
        x = jnp.asarray(imgs, jnp.float32) * np.float32(2.0**A.INPUT_EXP)
        logits = np.asarray(fwd(x))
        correct += int(np.sum(np.argmax(logits, axis=1) == labels))
    return correct / n


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="resnet8", choices=sorted(A.ARCHS))
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--batch", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.02)
    args = ap.parse_args()
    train(args.arch, args.steps, args.batch, args.lr)


if __name__ == "__main__":
    main()
