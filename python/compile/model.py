"""L2: the quantized ResNet forward graph, built from the L1 kernels.

This is the *optimized* dataflow of the paper (Section III-G, Fig. 14)
expressed as a JAX function over integer tensors:

* the downsample 1x1 conv (when present) reads the same input tensor as
  conv0 — the paper's *loop merge* (both computations share one task and
  one input stream);
* the skip branch never materializes a second buffer of the input — the
  paper's *temporal reuse* (here: the same jnp value is passed to both
  consumers; in the Rust simulator the same is modeled as window-buffer
  forwarding);
* the residual add is gone — conv1's accumulator is initialized with the
  aligned skip value (paper Fig. 13), via the `skip=` argument of the
  Pallas conv kernel.

`forward` is what `aot.py` lowers to HLO text (weights baked as constants)
for the Rust runtime; it is also compared element-exactly against
`ref_forward` (pure jnp) in pytest, and against the Rust golden model via
the exported artifacts.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import arch as A
from .kernels import avgpool_global, conv2d, linear
from .kernels import ref as R
from .kernels import quantize as qz


def _conv_exps(name: str, producer: str, act_exps: dict, w_exps: dict):
    """(in_exp, acc_exp, out_exp) for conv `name` reading tensor `producer`."""
    in_exp = act_exps[producer]
    acc_exp = in_exp + w_exps[name]
    out_exp = act_exps[name]
    return in_exp, acc_exp, out_exp


def forward(arch: A.ArchSpec, params: dict, act_exps: dict, w_exps: dict, x: jnp.ndarray):
    """Int8 inference with Pallas kernels. x: (N,32,32,3) int8-valued int32.

    Returns int32 logits (N, 10).
    """

    def conv(name, producer, t, relu, skip=None, skip_exp=0):
        spec = _find(arch, name)
        in_exp, acc_exp, out_exp = _conv_exps(name, producer, act_exps, w_exps)
        return conv2d(
            t,
            params[name]["w"],
            params[name]["b"],
            stride=spec.stride,
            pad=spec.pad,
            acc_exp=acc_exp,
            out_exp=out_exp,
            relu=relu,
            skip=skip,
            skip_exp=skip_exp,
        )

    a = conv("stem", "input", x, relu=True)
    producer = "stem"
    for blk in arch.blocks:
        xin = a
        if blk.downsample is not None:
            # Loop merge: ds + conv0 consume the same input stream.
            skip = conv(blk.downsample.name, producer, xin, relu=False)
            skip_exp = act_exps[blk.downsample.name]
        else:
            # Temporal reuse: identity skip re-reads the window buffer.
            skip = xin
            skip_exp = act_exps[producer]
        h = conv(blk.conv0.name, producer, xin, relu=True)
        a = conv(blk.conv1.name, blk.conv0.name, h, relu=True, skip=skip, skip_exp=skip_exp)
        producer = blk.conv1.name
    pooled = avgpool_global(a, act_exps[producer], act_exps["pool"])
    return linear(pooled, params["fc"]["w"], params["fc"]["b"])


def ref_forward(arch: A.ArchSpec, params: dict, act_exps: dict, w_exps: dict, x):
    """Same graph through the pure-jnp oracle (no pallas)."""

    def conv(name, producer, t, relu, skip=None, skip_exp=0):
        spec = _find(arch, name)
        in_exp, acc_exp, out_exp = _conv_exps(name, producer, act_exps, w_exps)
        return R.conv2d_ref(
            t, params[name]["w"], params[name]["b"], spec.stride, spec.pad,
            acc_exp, out_exp, relu, skip=skip, skip_exp=skip_exp,
        )

    a = conv("stem", "input", x, relu=True)
    producer = "stem"
    for blk in arch.blocks:
        xin = a
        if blk.downsample is not None:
            skip = conv(blk.downsample.name, producer, xin, relu=False)
            skip_exp = act_exps[blk.downsample.name]
        else:
            skip = xin
            skip_exp = act_exps[producer]
        h = conv(blk.conv0.name, producer, xin, relu=True)
        a = conv(blk.conv1.name, blk.conv0.name, h, relu=True, skip=skip, skip_exp=skip_exp)
        producer = blk.conv1.name
    pooled = R.avgpool_global_ref(a, act_exps[producer], act_exps["pool"])
    return R.linear_ref(pooled, params["fc"]["w"], params["fc"]["b"])


def _find(arch: A.ArchSpec, name: str) -> A.ConvSpec:
    for c in arch.conv_layers():
        if c.name == name:
            return c
    raise KeyError(name)


def unoptimized_ref_forward(arch: A.ArchSpec, params: dict, act_exps: dict, w_exps: dict, x):
    """The *pre-optimization* residual dataflow: explicit add node.

    Used by tests to prove the paper's graph transformations are
    numerics-preserving: fusing the add into conv1's accumulator (Fig. 13)
    must give identical int8 outputs to adding the requantized branches —
    provided the add is performed at the accumulator exponent, which is
    exactly what the optimized form does and the naive form must emulate.
    Here we compute the naive form the way a generic dataflow tool would:
    conv1 (no skip) produces raw accumulators, the skip tensor is aligned
    and added, then ReLU + requantize.
    """

    def conv_raw(name, producer, t):
        spec = _find(arch, name)
        in_exp, acc_exp, out_exp = _conv_exps(name, producer, act_exps, w_exps)
        return R.conv2d_int_ref(t, params[name]["w"], params[name]["b"], spec.stride, spec.pad), acc_exp, out_exp

    def conv_q(name, producer, t, relu):
        acc, acc_exp, out_exp = conv_raw(name, producer, t)
        return qz.requantize(acc, acc_exp, out_exp, relu)

    a = conv_q("stem", "input", x, relu=True)
    producer = "stem"
    for blk in arch.blocks:
        xin = a
        if blk.downsample is not None:
            skip = conv_q(blk.downsample.name, producer, xin, relu=False)
            skip_exp = act_exps[blk.downsample.name]
        else:
            skip = xin
            skip_exp = act_exps[producer]
        h = conv_q(blk.conv0.name, producer, xin, relu=True)
        acc, acc_exp, out_exp = conv_raw(blk.conv1.name, blk.conv0.name, h)
        acc = acc + qz.align_skip(skip, skip_exp, acc_exp)  # explicit add node
        a = qz.requantize(acc, acc_exp, out_exp, relu=True)
        producer = blk.conv1.name
    pooled = R.avgpool_global_ref(a, act_exps[producer], act_exps["pool"])
    return R.linear_ref(pooled, params["fc"]["w"], params["fc"]["b"])
