"""Architecture specs for the CIFAR-10 residual networks of the paper.

ResNet20 is the classic CIFAR ResNet of He et al. [9] (3 stages x 3 basic
blocks, widths 16/32/64); ResNet8 is the MLPerf-Tiny-style variant used by
the paper's FINN / Vitis-AI comparison [30] (3 stages x 1 block).  Both end
in an 8x8 global average pool (64 = 2^6, so the divide is a shift) and a
64->10 classifier.

This module is the *single source of truth* for layer geometry and
quantization exponents on the Python side; `rust/src/models/resnet.rs`
builds the same graphs and the JSON manifest emitted by `aot.py` carries
the per-tensor exponents across, so the two sides can never drift.

Residual blocks are already in their *optimized* form (paper Section III-G,
Fig. 14): the downsample 1x1 convolution is loop-merged with conv0's input
(both read the same stream), and the add node is fused into conv1's
accumulator initialization.  The un-optimized graph only exists in the Rust
`graph/` IR, where the optimization passes transform it and must arrive at
exactly these dataflows.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

# Default power-of-two exponents (overridden by trained checkpoints).
INPUT_EXP = -7  # input pixels in [-1, 1): q = round(x * 128)
ACT_EXP = -5  # hidden activations
WEIGHT_EXP = -8  # weights in (-0.5, 0.5)


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One convolution layer (a paper 'computation task')."""

    name: str
    cin: int
    cout: int
    k: int  # filter size (fh = fw = k)
    stride: int
    pad: int
    relu: bool
    in_h: int
    in_w: int

    @property
    def out_h(self) -> int:
        return (self.in_h + 2 * self.pad - self.k) // self.stride + 1

    @property
    def out_w(self) -> int:
        return (self.in_w + 2 * self.pad - self.k) // self.stride + 1

    @property
    def macs(self) -> int:
        """Eq. 8: c_i = oh*ow*och*ich*fh*fw."""
        return self.out_h * self.out_w * self.cout * self.cin * self.k * self.k


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """A residual block: conv0 -> conv1, skip = identity | downsample."""

    name: str
    conv0: ConvSpec
    conv1: ConvSpec
    downsample: Optional[ConvSpec]  # 1x1 conv on the skip branch, or None


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    stem: ConvSpec
    blocks: tuple
    fc_in: int
    fc_out: int
    num_classes: int = 10
    in_h: int = 32
    in_w: int = 32
    in_c: int = 3

    def conv_layers(self):
        """All convolution layers in execution order (for the ILP, Eq. 13)."""
        out = [self.stem]
        for b in self.blocks:
            if b.downsample is not None:
                out.append(b.downsample)
            out.append(b.conv0)
            out.append(b.conv1)
        return out

    def total_macs(self) -> int:
        return sum(c.macs for c in self.conv_layers()) + self.fc_in * self.fc_out

    def param_names(self):
        return [c.name for c in self.conv_layers()] + ["fc"]


def _make_blocks(arch: str, stages, blocks_per_stage: int):
    """Build the residual block list for a CIFAR ResNet."""
    blocks = []
    h = w = 32
    cin = 16
    for si, cout in enumerate(stages):
        for bi in range(blocks_per_stage):
            first = bi == 0
            stride = 2 if (first and si > 0) else 1
            bname = f"s{si}b{bi}"
            conv0 = ConvSpec(
                name=f"{bname}c0", cin=cin, cout=cout, k=3, stride=stride,
                pad=1, relu=True, in_h=h, in_w=w,
            )
            oh, ow = conv0.out_h, conv0.out_w
            conv1 = ConvSpec(
                name=f"{bname}c1", cin=cout, cout=cout, k=3, stride=1,
                pad=1, relu=True, in_h=oh, in_w=ow,
            )
            ds = None
            if first and si > 0:
                ds = ConvSpec(
                    name=f"{bname}ds", cin=cin, cout=cout, k=1, stride=stride,
                    pad=0, relu=False, in_h=h, in_w=w,
                )
            blocks.append(BlockSpec(name=bname, conv0=conv0, conv1=conv1, downsample=ds))
            cin, h, w = cout, oh, ow
    return tuple(blocks)


def resnet20() -> ArchSpec:
    stem = ConvSpec("stem", 3, 16, 3, 1, 1, True, 32, 32)
    return ArchSpec("resnet20", stem, _make_blocks("resnet20", (16, 32, 64), 3), 64, 10)


def resnet8() -> ArchSpec:
    stem = ConvSpec("stem", 3, 16, 3, 1, 1, True, 32, 32)
    return ArchSpec("resnet8", stem, _make_blocks("resnet8", (16, 32, 64), 1), 64, 10)


ARCHS = {"resnet8": resnet8, "resnet20": resnet20}


def default_act_exps(arch: ArchSpec) -> dict:
    """Per-tensor activation exponents: tensor name -> exponent.

    Tensor names: 'input', '<conv name>' for each conv output, 'pool'.
    Trained checkpoints override this table via the manifest.
    """
    exps = {"input": INPUT_EXP, "pool": ACT_EXP}
    for c in arch.conv_layers():
        exps[c.name] = ACT_EXP
    return exps


def default_weight_exps(arch: ArchSpec) -> dict:
    return {n: WEIGHT_EXP for n in arch.param_names()}
