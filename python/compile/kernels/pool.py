"""Pallas pooling kernels (the paper's pooling *computation tasks*).

The CIFAR ResNets only need the global average pool before the classifier,
but the layer library (Section V lists max/average pooling as supported
operations) ships both, mirroring the templated C++ process library.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import quantize as qz


def _maxpool_kernel(x_ref, o_ref, *, k: int, stride: int, oh: int, ow: int):
    x = x_ref[0]  # (H, W, C)
    c = x.shape[-1]
    out = jnp.full((oh, ow, c), -(2**31), dtype=jnp.int32)
    for dy in range(k):
        for dx in range(k):
            slab = x[dy : dy + (oh - 1) * stride + 1 : stride,
                     dx : dx + (ow - 1) * stride + 1 : stride, :]
            out = jnp.maximum(out, slab.astype(jnp.int32))
    o_ref[0] = out


@functools.partial(jax.jit, static_argnames=("k", "stride"))
def maxpool2d(x: jnp.ndarray, k: int = 2, stride: int = 2) -> jnp.ndarray:
    """Max pool over int8-valued activations. Exponent passes through."""
    n, h, w, c = x.shape
    oh = (h - k) // stride + 1
    ow = (w - k) // stride + 1
    return pl.pallas_call(
        functools.partial(_maxpool_kernel, k=k, stride=stride, oh=oh, ow=ow),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda b: (b, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, oh, ow, c), lambda b: (b, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, c), jnp.int32),
        interpret=True,
    )(x)


def _avgpool_kernel(x_ref, o_ref, *, shift: int):
    x = x_ref[0]  # (H, W, C)
    acc = jnp.sum(x.astype(jnp.int32), axis=(0, 1))
    o_ref[0] = qz.clip_int8(qz.round_shift(acc, shift)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("in_exp", "out_exp"))
def avgpool_global(x: jnp.ndarray, in_exp: int, out_exp: int) -> jnp.ndarray:
    """Global average pool; power-of-two window so the divide is a shift."""
    n, h, w, c = x.shape
    hw = h * w
    assert hw & (hw - 1) == 0, "global pool window must be a power of two"
    shift = out_exp - in_exp + (hw.bit_length() - 1)
    return pl.pallas_call(
        functools.partial(_avgpool_kernel, shift=shift),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, h, w, c), lambda b: (b, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, c), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), jnp.int32),
        interpret=True,
    )(x)
