"""L1 kernel library: Pallas compute kernels + pure-jnp oracle.

Import surface used by the L2 model (`compile.model`):

    from compile.kernels import conv2d, linear, maxpool2d, avgpool_global
"""

from .conv2d import conv2d, vmem_footprint_bytes  # noqa: F401
from .linear import linear  # noqa: F401
from .pool import avgpool_global, maxpool2d  # noqa: F401
from . import quantize  # noqa: F401
from . import ref  # noqa: F401
