"""Power-of-two quantization primitives (paper Eqs. 1-3).

The paper quantizes weights/activations to int8, biases to int16, and
accumulates in int32, with *power-of-two scaling factors* so that every
scale alignment is a bit shift (Section III-A).  This module is the single
source of truth for that arithmetic on the Python side; `rust/src/quant/`
mirrors it bit-exactly (same floor/arithmetic-shift semantics), which is
what lets `cargo test` assert Rust-golden == PJRT-executed-HLO equality.

Conventions
-----------
A quantized tensor is an integer array ``q`` plus an integer exponent ``e``
such that the represented real value is ``q * 2**e`` (``e`` is usually
negative).  This matches the paper's ``a = clip(round(b * 2^{bw-s})) * 2^s``
with ``e = s - bw`` folded into a single signed exponent.

All rounding in the requantization path is *round-half-up in the shifted
domain*: ``floor((acc + 2^(k-1)) / 2^k)`` for a right shift by ``k > 0``.
Arithmetic (sign-preserving) shifts everywhere; int32 ``>>`` in numpy/jax
and Rust both implement floor division by a power of two, so the two
implementations agree on negative values too.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

INT8_MIN, INT8_MAX = -128, 127
INT16_MIN, INT16_MAX = -(2**15), 2**15 - 1


@dataclasses.dataclass(frozen=True)
class QTensor:
    """An integer tensor with a power-of-two scale: real = q * 2**exp."""

    q: jnp.ndarray  # int8 / int16 / int32 payload
    exp: int  # power-of-two exponent of the scale

    @property
    def shape(self):
        return self.q.shape

    def dequantize(self) -> jnp.ndarray:
        return self.q.astype(jnp.float32) * np.float32(2.0**self.exp)


def pow2_exponent(max_abs: float, bits: int = 8) -> int:
    """Smallest power-of-two exponent e with max_abs <= (2^(bits-1)-1) * 2^e.

    This is how both the QAT calibrator and the export path pick scales:
    the tightest power-of-two scale that covers the observed dynamic range
    (paper Section III-A: "scaling factors are set to powers of two").
    """
    limit = float(2 ** (bits - 1) - 1)
    if max_abs <= 0.0 or not np.isfinite(max_abs):
        return -(bits - 1)
    return int(np.ceil(np.log2(max_abs / limit)))


def quantize_pow2(x: jnp.ndarray, exp: int, bits: int = 8) -> jnp.ndarray:
    """Quantize float -> int with scale 2**exp (paper Eq. 1, zero-point 0).

    round-half-away-from-zero like torch.round? No: we use round-half-even
    via jnp.round for float->int conversion (training-time only); the
    *integer* requantization path (round_shift) is the one that must match
    Rust bit-exactly, and it does.
    """
    lo = -(2 ** (bits - 1))
    hi = 2 ** (bits - 1) - 1
    scaled = jnp.round(x * np.float32(2.0**-exp))
    return jnp.clip(scaled, lo, hi).astype(jnp.int32)


def fake_quant(x: jnp.ndarray, exp: int, bits: int = 8) -> jnp.ndarray:
    """Straight-through fake quantization for QAT (train.py)."""
    import jax

    q = quantize_pow2(x, exp, bits).astype(jnp.float32) * np.float32(2.0**exp)
    # STE: forward quantized value, gradient of identity.
    return x + jax.lax.stop_gradient(q - x)


def round_shift(acc, shift: int):
    """Requantize an int32 accumulator by an arithmetic shift.

    shift > 0: right shift with round-half-up  floor((acc + 2^(s-1)) / 2^s)
    shift <= 0: exact left shift.

    Must stay bit-identical to rust `quant::round_shift`.
    Works on jnp or np int32 arrays.
    """
    if shift <= 0:
        return acc << (-shift)
    half = 1 << (shift - 1)
    return (acc + half) >> shift


def clip_int8(x):
    return jnp.clip(x, INT8_MIN, INT8_MAX)


def requantize(acc, acc_exp: int, out_exp: int, relu: bool):
    """int32 accumulator @ 2**acc_exp  ->  int8 @ 2**out_exp.

    ReLU (when fused, Section III-A: ReLU merged into conv) is applied on
    the accumulator *before* the shift, exactly as the generated HLS code
    does it on the 32-bit register.
    """
    if relu:
        acc = jnp.maximum(acc, 0)
    shifted = round_shift(acc, out_exp - acc_exp)
    return clip_int8(shifted).astype(jnp.int32)


def align_skip(skip_q, skip_exp: int, acc_exp: int):
    """Align a skip-connection int8 tensor to the accumulator exponent.

    Paper Fig. 13: the residual add is optimized away by initializing the
    accumulation register of the long branch's second convolution with the
    skip value.  The skip exponent is >= the accumulator exponent (the
    accumulator sits at e_x + e_w, far below activation scales), so this is
    an exact left shift in int32.
    """
    shift = skip_exp - acc_exp
    assert shift >= 0, f"skip exp {skip_exp} below acc exp {acc_exp}"
    return skip_q.astype(jnp.int32) << shift
