"""Pallas int8 convolution kernel — the accelerator's compute hot-spot.

Maps the paper's convolution *computation task* (Section III-C, Fig. 4) to
a Pallas kernel:

* **Output stationary**: the grid iterates over (batch, output row); each
  kernel instance owns one full output row's accumulators (OW x COUT in
  registers/VMEM) and accumulates all ich*fh*fw contributions into them
  before writing once — exactly the paper's dataflow, where partial sums
  stay in the PE pipeline and data is written "after all input channels
  have been processed".
* **och-parallel**: the dot over (CIN) x (CIN, COUT) computes all output
  channels of a row position in parallel — the TPU/MXU analogue of the
  paper's och_par unroll (horizontal PE replication in Fig. 5).
* **ow-parallel**: one grid step produces a whole OW row, the analogue of
  ow_par weight reuse (each loaded filter tap multiplies every output
  column — the DSP-packing insight that one parameter feeds two MACs).
* **Fused skip initialization**: the optional `skip` operand initializes
  the accumulator (paper Fig. 13 — the residual add node is deleted and
  its value becomes the accumulation register's initial state).
* **Fused ReLU + power-of-two requantization** on the int32 accumulator.

TPU adaptation (DESIGN.md §Hardware-Adaptation): the BlockSpec streams one
padded input slab per (n, oh) grid step into VMEM; filter weights are
resident across the whole grid (weight-stationary in VMEM, like the
paper's on-chip parameter arrays).  `interpret=True` everywhere — the CPU
PJRT plugin cannot run Mosaic custom-calls; real-TPU viability is assessed
via the VMEM footprint model in `aot.py --report`.

All payloads are int32 arrays *holding* int8/int16-range values: the
quantization contract lives in the values, not the dtypes, which keeps the
HLO interface uniform for the Rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import quantize as qz


def _conv_kernel(
    x_ref,
    w_ref,
    b_ref,
    o_ref,
    *,
    kh: int,
    kw: int,
    stride: int,
    ow: int,
    acc_exp: int,
    out_exp: int,
    relu: bool,
):
    """One output row: acc[ow, cout] = bias + sum_{dy,dx} X[dy,dx] @ W[dy,dx]."""
    oh_idx = pl.program_id(1)
    cout = o_ref.shape[-1]
    acc = jnp.broadcast_to(b_ref[...][None, :], (ow, cout)).astype(jnp.int32)
    for dy in range(kh):
        # Input row feeding output row `oh_idx` for filter tap row dy.
        row = pl.load(
            x_ref,
            (pl.dslice(0, 1), pl.dslice(oh_idx * stride + dy, 1), slice(None), slice(None)),
        )[0, 0]  # (WP, CIN)
        for dx in range(kw):
            # ow_par analogue: every output column consumes this tap's
            # weights simultaneously (weight reuse across the row).
            slab = jax.lax.slice(row, (dx, 0), (dx + (ow - 1) * stride + 1, row.shape[1]))
            slab = slab[::stride] if stride > 1 else slab  # (OW, CIN)
            acc = acc + jax.lax.dot_general(
                slab,
                w_ref[dy, dx],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
    if relu:
        acc = jnp.maximum(acc, 0)
    shifted = qz.round_shift(acc, out_exp - acc_exp)
    o_ref[0, 0] = qz.clip_int8(shifted).astype(jnp.int32)


def _conv_kernel_skip(
    x_ref,
    w_ref,
    b_ref,
    s_ref,
    o_ref,
    *,
    kh: int,
    kw: int,
    stride: int,
    ow: int,
    acc_exp: int,
    out_exp: int,
    relu: bool,
    skip_shift: int,
):
    """Same as _conv_kernel but the accumulator is initialized with the
    aligned skip-connection row (paper Fig. 13: add node removed)."""
    oh_idx = pl.program_id(1)
    cout = o_ref.shape[-1]
    acc = jnp.broadcast_to(b_ref[...][None, :], (ow, cout)).astype(jnp.int32)
    acc = acc + (s_ref[0, 0].astype(jnp.int32) << skip_shift)
    for dy in range(kh):
        row = pl.load(
            x_ref,
            (pl.dslice(0, 1), pl.dslice(oh_idx * stride + dy, 1), slice(None), slice(None)),
        )[0, 0]
        for dx in range(kw):
            slab = jax.lax.slice(row, (dx, 0), (dx + (ow - 1) * stride + 1, row.shape[1]))
            slab = slab[::stride] if stride > 1 else slab
            acc = acc + jax.lax.dot_general(
                slab,
                w_ref[dy, dx],
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
    if relu:
        acc = jnp.maximum(acc, 0)
    shifted = qz.round_shift(acc, out_exp - acc_exp)
    o_ref[0, 0] = qz.clip_int8(shifted).astype(jnp.int32)


def _conv_kernel_slab(
    x_ref,
    w_ref,
    b_ref,
    *refs,  # [skip_ref,] o_ref — outputs follow all inputs in pallas
    kh: int,
    kw: int,
    stride: int,
    oh: int,
    ow: int,
    acc_exp: int,
    out_exp: int,
    relu: bool,
    skip_shift: int = 0,
):
    """Grid-free 'slab' schedule — the deployment-optimized variant
    (EXPERIMENTS.md §Perf L2).

    One straight-line program: per filter tap, a single big dot over
    (N*OH*OW, CIN) x (CIN, COUT).  The dots run in **f32, which is exact
    here**: |x*w| <= 128*127 and the contraction length is CIN <= 1024,
    so every partial sum stays below 2^24 and f32 represents it exactly;
    the int32 accumulation across taps (and the bias/skip initialization,
    ReLU, and power-of-two requantization) happen in integer arithmetic,
    preserving bit-exactness against ref.conv2d_ref while letting XLA CPU
    use its vectorized SGEMM path (~30x over int32 dots in a grid loop).
    """
    (skip_ref, o_ref) = refs if len(refs) == 2 else (None, refs[0])
    n = o_ref.shape[0]
    cout = o_ref.shape[-1]
    cin = x_ref.shape[-1]
    assert cin * 128 * 127 < (1 << 24), "f32 tap-dot exactness bound"
    acc = jnp.broadcast_to(b_ref[...][None, None, None, :], (n, oh, ow, cout)).astype(jnp.int32)
    if skip_ref is not None:
        acc = acc + (skip_ref[...].astype(jnp.int32) << skip_shift)
    xv = x_ref[...].astype(jnp.float32)
    wv = w_ref[...].astype(jnp.float32)
    for dy in range(kh):
        for dx in range(kw):
            slab = jax.lax.slice(
                xv,
                (0, dy, dx, 0),
                (n, dy + (oh - 1) * stride + 1, dx + (ow - 1) * stride + 1, cin),
            )
            slab = slab[:, ::stride, ::stride] if stride > 1 else slab
            part = jax.lax.dot_general(
                slab, wv[dy, dx], (((3,), (0,)), ((), ())), preferred_element_type=jnp.float32
            )
            acc = acc + part.astype(jnp.int32)
    if relu:
        acc = jnp.maximum(acc, 0)
    o_ref[...] = qz.clip_int8(qz.round_shift(acc, out_exp - acc_exp)).astype(jnp.int32)


@functools.partial(
    jax.jit,
    static_argnames=("stride", "pad", "acc_exp", "out_exp", "relu", "skip_exp", "schedule"),
)
def conv2d(
    x: jnp.ndarray,  # (N, H, W, CIN) int8-valued int32
    w: jnp.ndarray,  # (KH, KW, CIN, COUT)
    bias: jnp.ndarray,  # (COUT,) int16-valued int32, at acc exponent
    stride: int = 1,
    pad: int = 1,
    acc_exp: int = -14,
    out_exp: int = -7,
    relu: bool = True,
    skip: jnp.ndarray | None = None,  # (N, OH, OW, COUT) int8-valued
    skip_exp: int = 0,
    schedule: str = "slab",
):
    """Fused quantized convolution via pallas_call (interpret mode).

    Two schedules, both bit-exact against `ref.conv2d_ref` (asserted by
    pytest and, through the exported HLO, by the Rust golden-vs-PJRT
    integration test):

    * ``"slab"`` (default, deployed): grid-free straight-line program with
      exact f32 tap-dots — the CPU-PJRT-optimized form (§Perf L2);
    * ``"rows"``: grid over (batch, output row) with a BlockSpec-windowed
      input slab — the TPU-structured form whose VMEM footprint
      `vmem_footprint_bytes` models (one output row resident per step).
    """
    n, h, wd, cin = x.shape
    kh, kw, _, cout = w.shape
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (wd + 2 * pad - kw) // stride + 1
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    hp, wp = h + 2 * pad, wd + 2 * pad

    if schedule == "slab":
        out_shape = jax.ShapeDtypeStruct((n, oh, ow, cout), jnp.int32)
        kw_args = dict(
            kh=kh, kw=kw, stride=stride, oh=oh, ow=ow,
            acc_exp=acc_exp, out_exp=out_exp, relu=relu,
        )
        if skip is None:
            kernel = functools.partial(_conv_kernel_slab, **kw_args)
            return pl.pallas_call(kernel, out_shape=out_shape, interpret=True)(xp, w, bias)
        skip_shift = skip_exp - acc_exp
        assert skip_shift >= 0, "skip exponent must sit above the accumulator"
        kernel = functools.partial(_conv_kernel_slab, skip_shift=skip_shift, **kw_args)
        return pl.pallas_call(kernel, out_shape=out_shape, interpret=True)(xp, w, bias, skip)

    assert schedule == "rows", f"unknown schedule {schedule}"
    grid = (n, oh)
    x_spec = pl.BlockSpec((1, hp, wp, cin), lambda b, i: (b, 0, 0, 0))
    w_spec = pl.BlockSpec((kh, kw, cin, cout), lambda b, i: (0, 0, 0, 0))
    b_spec = pl.BlockSpec((cout,), lambda b, i: (0,))
    o_spec = pl.BlockSpec((1, 1, ow, cout), lambda b, i: (b, i, 0, 0))
    out_shape = jax.ShapeDtypeStruct((n, oh, ow, cout), jnp.int32)

    if skip is None:
        kernel = functools.partial(
            _conv_kernel,
            kh=kh,
            kw=kw,
            stride=stride,
            ow=ow,
            acc_exp=acc_exp,
            out_exp=out_exp,
            relu=relu,
        )
        return pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[x_spec, w_spec, b_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            interpret=True,
        )(xp, w, bias)

    skip_shift = skip_exp - acc_exp
    assert skip_shift >= 0, "skip exponent must sit above the accumulator"
    s_spec = pl.BlockSpec((1, 1, ow, cout), lambda b, i: (b, i, 0, 0))
    kernel = functools.partial(
        _conv_kernel_skip,
        kh=kh,
        kw=kw,
        stride=stride,
        ow=ow,
        acc_exp=acc_exp,
        out_exp=out_exp,
        relu=relu,
        skip_shift=skip_shift,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, w_spec, b_spec, s_spec],
        out_specs=o_spec,
        out_shape=out_shape,
        interpret=True,
    )(xp, w, bias, skip)


def vmem_footprint_bytes(h, w, cin, kh, kw, cout, pad=1, elem_bytes=4) -> dict:
    """Static VMEM footprint estimate for one grid step (DESIGN.md L1 perf).

    The paper sizes line buffers by Eq. 16; on TPU the analogous constraint
    is the per-step VMEM residency of the BlockSpec slabs.
    """
    hp, wp = h + 2 * pad, w + 2 * pad
    ow = wp - kw + 1
    x_bytes = hp * wp * cin * elem_bytes  # full padded slab (current spec)
    x_rows_bytes = kh * wp * cin * elem_bytes  # minimal rolling window
    w_bytes = kh * kw * cin * cout * elem_bytes
    acc_bytes = ow * cout * 4
    return {
        "x_slab": x_bytes,
        "x_rolling_min": x_rows_bytes,
        "weights": w_bytes,
        "acc": acc_bytes,
        "total": x_bytes + w_bytes + acc_bytes,
        "total_rolling": x_rows_bytes + w_bytes + acc_bytes,
    }
