"""Pure-jnp oracle for every L1 kernel.

This is the correctness contract: the Pallas kernels in this package and
the Rust golden model (`rust/src/sim/golden.rs`) must both reproduce these
functions *bit-exactly* on integer inputs.  Everything is plain jnp int32
arithmetic — no pallas, no lax.conv — so it doubles as readable
documentation of the accelerator's numerics.

Layout conventions (match the accelerator's depth-first streaming order,
paper Section III-F): activations are NHWC, weights are (KH, KW, CIN, COUT).
"""

from __future__ import annotations

import jax.numpy as jnp

from . import quantize as qz


def conv2d_int_ref(
    x: jnp.ndarray,  # (N, H, W, CIN) int32-valued int8 data
    w: jnp.ndarray,  # (KH, KW, CIN, COUT)
    bias: jnp.ndarray,  # (COUT,) int32-valued int16 data, at acc exponent
    stride: int,
    pad: int,
) -> jnp.ndarray:
    """Integer convolution, int32 accumulation. Returns raw accumulators.

    Output-stationary like the paper's compute pipeline (Fig. 4): each
    output element accumulates och*ich*fh*fw products (Eq. 4) plus the
    bias, which initializes the accumulator (first pipeline stage input).
    """
    n, h, wd, cin = x.shape
    kh, kw, cin_w, cout = w.shape
    assert cin == cin_w, (cin, cin_w)
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (wd + 2 * pad - kw) // stride + 1
    acc = jnp.broadcast_to(
        bias.astype(jnp.int32)[None, None, None, :], (n, oh, ow, cout)
    )
    x32 = xp.astype(jnp.int32)
    w32 = w.astype(jnp.int32)
    for dy in range(kh):
        for dx in range(kw):
            # Strided slab covering every output position for this tap.
            slab = x32[:, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride, :]
            acc = acc + jnp.einsum(
                "nhwc,co->nhwo", slab, w32[dy, dx], preferred_element_type=jnp.int32
            )
    return acc


def conv2d_ref(
    x,
    w,
    bias,
    stride: int,
    pad: int,
    acc_exp: int,
    out_exp: int,
    relu: bool,
    skip=None,
    skip_exp: int = 0,
):
    """Full fused conv: accumulate + optional skip-init + ReLU + requantize.

    `skip` is the paper's Fig. 13 optimization: instead of a separate add
    node, the skip tensor (int8 @ 2**skip_exp) initializes the accumulator.
    """
    acc = conv2d_int_ref(x, w, bias, stride, pad)
    if skip is not None:
        acc = acc + qz.align_skip(skip, skip_exp, acc_exp)
    return qz.requantize(acc, acc_exp, out_exp, relu)


def maxpool2d_ref(x: jnp.ndarray, k: int, stride: int) -> jnp.ndarray:
    """Max pooling on int8 data (returns same dtype/exponent)."""
    n, h, w, c = x.shape
    oh = (h - k) // stride + 1
    ow = (w - k) // stride + 1
    out = jnp.full((n, oh, ow, c), -(2**31), dtype=jnp.int32)
    for dy in range(k):
        for dx in range(k):
            slab = x[:, dy : dy + oh * stride : stride, dx : dx + ow * stride : stride, :]
            out = jnp.maximum(out, slab.astype(jnp.int32))
    return out


def avgpool_global_ref(x: jnp.ndarray, in_exp: int, out_exp: int) -> jnp.ndarray:
    """Global average pool with power-of-two divisor handling.

    CIFAR ResNets end with an 8x8 global average pool; 64 = 2^6 so the
    divide is folded into the requantization shift (exact, hardware
    friendly — the paper's pooling task does the same).
    """
    n, h, w, c = x.shape
    hw = h * w
    assert hw & (hw - 1) == 0, "global pool window must be a power of two"
    log_hw = hw.bit_length() - 1
    acc = jnp.sum(x.astype(jnp.int32), axis=(1, 2))
    # sum @ 2**in_exp ; real avg = sum * 2**(in_exp - log_hw)
    shifted = qz.round_shift(acc, out_exp - in_exp + log_hw)
    return qz.clip_int8(shifted).astype(jnp.int32)


def linear_ref(
    x: jnp.ndarray,  # (N, CIN)
    w: jnp.ndarray,  # (CIN, COUT)
    bias: jnp.ndarray,  # (COUT,) at acc exponent
) -> jnp.ndarray:
    """Fully connected layer; returns raw int32 accumulators (logits).

    The classifier head's outputs are consumed as int32 logits — argmax is
    scale-invariant so no requantization is needed (and the hardware skips
    it too).
    """
    acc = x.astype(jnp.int32) @ w.astype(jnp.int32) + bias.astype(jnp.int32)[None, :]
    return acc
