"""Pallas fully-connected (linear) kernel — the classifier head.

Returns raw int32 logits (argmax is scale invariant; the hardware also
skips the final requantization, see ref.linear_ref).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _linear_kernel(x_ref, w_ref, b_ref, o_ref):
    acc = jax.lax.dot_general(
        x_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    o_ref[...] = acc + b_ref[...][None, :]


@jax.jit
def linear(x: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    """(N, CIN) x (CIN, COUT) + bias -> int32 logits."""
    n, cin = x.shape
    cin_w, cout = w.shape
    assert cin == cin_w
    return pl.pallas_call(
        _linear_kernel,
        in_specs=[
            pl.BlockSpec((n, cin), lambda: (0, 0)),
            pl.BlockSpec((cin, cout), lambda: (0, 0)),
            pl.BlockSpec((cout,), lambda: (0,)),
        ],
        out_specs=pl.BlockSpec((n, cout), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, cout), jnp.int32),
        interpret=True,
    )(x, w, bias)
