//! The paper's core experiment, runnable: residual-block buffering
//! (Sections III-F/III-G, Eqs. 16–23, Figs. 12–14).
//!
//! For each network it prints the analytic skip-buffer sizes (naive
//! receptive-field bound vs optimized window-buffer bound), then proves
//! them *dynamically* in the dataflow simulator:
//!   - naive dataflow with Eq. 21 sizing runs; its skip FIFOs genuinely
//!     fill to the bound;
//!   - naive dataflow with the optimized (halved) sizing deadlocks;
//!   - optimized dataflow runs within the halved budget.
//!
//! ```bash
//! cargo run --release --example residual_buffers
//! ```

use anyhow::Result;
use resnet_hls::eval::figures::skip_buffering_series;
use resnet_hls::hls::config::configure;
use resnet_hls::hls::ULTRA96;
use resnet_hls::ilp::{loads_from_arch, solve};
use resnet_hls::models::{
    arch_by_name, build_optimized_graph, build_unoptimized_graph, default_exps,
};
use resnet_hls::sim::{build_network, SimOptions};

fn main() -> Result<()> {
    for model in ["resnet8", "resnet20"] {
        let arch = arch_by_name(model).unwrap();
        println!("== {model}: skip-connection buffering (Eqs. 21–23) ==");
        println!("{:<8} {:>12} {:>12} {:>8}", "block", "naive B_sc", "opt B_sc", "R_sc");
        let mut naive_total = 0usize;
        let mut opt_total = 0usize;
        for (name, naive, opt, r) in skip_buffering_series(&arch) {
            println!("{name:<8} {naive:>12} {opt:>12} {r:>8.3}");
            naive_total += naive;
            opt_total += opt;
        }
        println!(
            "total    {naive_total:>12} {opt_total:>12} {:>8.3}   (paper: 0.5)\n",
            opt_total as f64 / naive_total as f64
        );

        // Dynamic proof in the simulator.
        let (act, w) = default_exps(&arch);
        let loads = loads_from_arch(&arch, 2);
        let alloc = solve(&loads, ULTRA96.n_par() as u64).unwrap();

        let run = |naive: bool, factor: f64| -> Result<(bool, u64)> {
            let g = if naive {
                build_unoptimized_graph(&arch, &act, &w)
            } else {
                build_optimized_graph(&arch, &act, &w)
            };
            let cfg = configure(&arch.name, &g, &alloc, &ULTRA96, 2)?;
            let opts = SimOptions { frames: 2, skip_factor: factor, ..Default::default() };
            let mut net = build_network(&g, &cfg, &opts)?;
            let rep = net.run(2);
            Ok((rep.deadlocked, rep.ii_cycles))
        };

        for (label, naive, factor) in [
            ("naive dataflow, Eq.21 sizing  ", true, 1.0),
            ("naive dataflow, halved sizing ", true, 0.45),
            ("optimized dataflow, Eq.22     ", false, 1.0),
        ] {
            let (dead, ii) = run(naive, factor)?;
            println!(
                "  {label}: {}",
                if dead { "DEADLOCK (as the paper predicts)".into() } else { format!("runs, II = {ii} cycles") }
            );
        }
        println!();
    }
    Ok(())
}
