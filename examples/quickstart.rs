//! Quickstart: the whole flow on one page.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! 1. Build the ResNet8 graph the way the paper's flow does (unoptimized,
//!    explicit Add nodes), run the Section III-G optimization passes;
//! 2. Solve the Algorithm-1 ILP for the Kria KV260's DSP budget and close
//!    the design against the full resource model;
//! 3. Simulate the dataflow accelerator (cycle-approximate) and report
//!    FPS/latency at the board clock;
//! 4. Run *real* int8 inference through the AOT-compiled HLO on PJRT and
//!    check it against the in-process golden model — both behind the
//!    same `InferenceBackend` trait the serving router uses.

use anyhow::Result;
use resnet_hls::data::{synth_batch, TEST_SEED};
use resnet_hls::hls::{codegen, resources::fit_to_board, KV260};
use resnet_hls::ilp::loads_from_arch;
use resnet_hls::models::{arch_by_name, build_unoptimized_graph, default_exps};
use resnet_hls::passes;
use resnet_hls::paths::artifacts_dir;
use resnet_hls::runtime::{infer_tiled, GoldenBackend, InferenceBackend, PjrtBackend};
use resnet_hls::sim::{build_network, golden, SimOptions};

fn main() -> Result<()> {
    // -- 1. Graph + optimization passes ---------------------------------
    let arch = arch_by_name("resnet8").unwrap();
    let (act, w) = default_exps(&arch);
    let mut g = build_unoptimized_graph(&arch, &act, &w);
    let stats = passes::optimize(&mut g);
    println!(
        "passes: {} relu merged, {} loops merged, {} temporal reuses, {} adds fused",
        stats.relu_merged, stats.loops_merged, stats.reuses, stats.adds_fused
    );

    // -- 2. ILP + resource closure ---------------------------------------
    let loads = loads_from_arch(&arch, 2);
    let (alloc, cfg, report) = fit_to_board(&arch.name, &g, &loads, &KV260, 2)?;
    println!(
        "ILP: {} DSPs used (budget {}), bottleneck {} cycles/frame",
        alloc.dsps_used,
        KV260.n_par(),
        alloc.cycles_per_frame
    );
    println!("resources: {}", report.utilization(&KV260));

    // -- 3. Dataflow simulation ------------------------------------------
    let mut net = build_network(&g, &cfg, &SimOptions { frames: 4, ..Default::default() })?;
    let rep = net.run(4);
    println!(
        "sim: {:.0} FPS @ {:.0} MHz, latency {:.3} ms (paper: 30153 FPS, 0.046 ms)",
        rep.fps(KV260.clock_mhz),
        KV260.clock_mhz,
        rep.latency_ms(KV260.clock_mhz)
    );

    // -- 4. Real inference through the backend trait ----------------------
    // Two implementations of the same `InferenceBackend` API: the
    // in-process golden model and the AOT-compiled HLO on PJRT.  The
    // serving router runs on exactly this interface.
    let dir = artifacts_dir();
    let golden_b = GoldenBackend::from_artifacts(&dir, "resnet8", &[1, 8])?;
    let pjrt_b = PjrtBackend::load(&dir, "resnet8")?;
    let (input, labels) = synth_batch(0, 8, TEST_SEED);
    let gold = infer_tiled(&golden_b, &input)?;
    let hw = infer_tiled(&pjrt_b, &input)?;
    assert_eq!(gold.data, hw.data, "golden and PJRT disagree");
    let preds = golden::argmax_classes(&hw);
    println!(
        "PJRT ({} buckets {:?}) bit-exact vs golden; predictions {preds:?} labels {labels:?}",
        pjrt_b.arch(),
        pjrt_b.buckets()
    );

    // -- bonus: the generated HLS C++ ------------------------------------
    let cpp = codegen::emit_top(&cfg);
    println!("codegen: {} bytes of Vitis-HLS C++ (try `repro codegen`)", cpp.len());
    Ok(())
}
