//! Quickstart: the whole flow on one page.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! 1. Build the ResNet8 graph the way the paper's flow does (unoptimized,
//!    explicit Add nodes), run the Section III-G optimization passes;
//! 2. Solve the Algorithm-1 ILP for the Kria KV260's DSP budget and close
//!    the design against the full resource model;
//! 3. Simulate the dataflow accelerator (cycle-approximate) and report
//!    FPS/latency at the board clock;
//! 4. Run *real* int8 inference through the AOT-compiled HLO on PJRT and
//!    check it against the in-process golden model.

use anyhow::Result;
use resnet_hls::data::{synth_batch, TEST_SEED};
use resnet_hls::hls::{codegen, resources::fit_to_board, KV260};
use resnet_hls::ilp::loads_from_arch;
use resnet_hls::models::{arch_by_name, build_unoptimized_graph, default_exps, ModelWeights};
use resnet_hls::passes;
use resnet_hls::paths::artifacts_dir;
use resnet_hls::runtime::Engine;
use resnet_hls::sim::{build_network, golden, SimOptions};

fn main() -> Result<()> {
    // -- 1. Graph + optimization passes ---------------------------------
    let arch = arch_by_name("resnet8").unwrap();
    let (act, w) = default_exps(&arch);
    let mut g = build_unoptimized_graph(&arch, &act, &w);
    let stats = passes::optimize(&mut g);
    println!(
        "passes: {} relu merged, {} loops merged, {} temporal reuses, {} adds fused",
        stats.relu_merged, stats.loops_merged, stats.reuses, stats.adds_fused
    );

    // -- 2. ILP + resource closure ---------------------------------------
    let loads = loads_from_arch(&arch, 2);
    let (alloc, cfg, report) = fit_to_board(&arch.name, &g, &loads, &KV260, 2)?;
    println!(
        "ILP: {} DSPs used (budget {}), bottleneck {} cycles/frame",
        alloc.dsps_used,
        KV260.n_par(),
        alloc.cycles_per_frame
    );
    println!("resources: {}", report.utilization(&KV260));

    // -- 3. Dataflow simulation ------------------------------------------
    let mut net = build_network(&g, &cfg, &SimOptions { frames: 4, ..Default::default() })?;
    let rep = net.run(4);
    println!(
        "sim: {:.0} FPS @ {:.0} MHz, latency {:.3} ms (paper: 30153 FPS, 0.046 ms)",
        rep.fps(KV260.clock_mhz),
        KV260.clock_mhz,
        rep.latency_ms(KV260.clock_mhz)
    );

    // -- 4. Real inference through PJRT ----------------------------------
    let dir = artifacts_dir();
    let weights = ModelWeights::load(&dir, "resnet8")?;
    let engine = Engine::load(&dir)?;
    let (input, labels) = synth_batch(0, 8, TEST_SEED);
    let g_w = resnet_hls::models::build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let gold = golden::run(&g_w, &weights, &input)?;
    let hw = engine.infer_any("resnet8", &input)?;
    assert_eq!(gold.data, hw.data, "golden and PJRT disagree");
    let preds = golden::argmax_classes(&hw);
    println!("PJRT inference bit-exact vs golden; predictions {preds:?} labels {labels:?}");

    // -- bonus: the generated HLS C++ ------------------------------------
    let cpp = codegen::emit_top(&cfg);
    println!("codegen: {} bytes of Vitis-HLS C++ (try `repro codegen`)", cpp.len());
    Ok(())
}
