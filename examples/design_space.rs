//! Design-space exploration: the Algorithm-1 ILP swept over DSP budgets,
//! boards, and the ow_par packing ablation — the tooling a user would run
//! before committing to a board.
//!
//! ```bash
//! cargo run --release --example design_space [-- model]
//! ```

use anyhow::Result;
use resnet_hls::eval::figures::ilp_sweep;
use resnet_hls::hls::boards::BOARDS;
use resnet_hls::hls::resources::fit_to_board;
use resnet_hls::ilp::loads_from_arch;
use resnet_hls::models::{arch_by_name, build_optimized_graph, default_exps};

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "resnet20".into());
    let arch = arch_by_name(&model).expect("resnet8 | resnet20");

    println!("== {model}: throughput vs DSP budget (Alg. 1) ==");
    println!("{:>8} {:>14} {:>10} | {:>14} {:>10}", "budget", "fps/MHz(x2)", "DSPs", "fps/MHz(x1)", "DSPs");
    let budgets: Vec<u64> = (0..12).map(|i| 72 << i).take_while(|&b| b <= 4096).collect();
    let packed = ilp_sweep(&model, &budgets, 2);
    let unpacked = ilp_sweep(&model, &budgets, 1);
    for (p, u) in packed.iter().zip(&unpacked) {
        println!(
            "{:>8} {:>14.4} {:>10} | {:>14.4} {:>10}",
            p.0, p.1, p.2, u.1, u.2
        );
    }
    println!("(x2 = DSP-packed ow_par=2; x1 = unpacked baseline — Section III-C)");

    println!("\n== {model}: closed designs per board ==");
    let (act, w) = default_exps(&arch);
    let g = build_optimized_graph(&arch, &act, &w);
    let loads = loads_from_arch(&arch, 2);
    for board in BOARDS {
        let (alloc, cfg, report) = fit_to_board(&arch.name, &g, &loads, board, 2)?;
        println!(
            "{:<8} {:>8.0} FPS  {:>7.0} Gops/s  {:>5} DSP | {}",
            board.name,
            cfg.fps(),
            alloc.gops(board.clock_mhz, arch.total_macs()),
            alloc.dsps_used,
            report.utilization(board)
        );
    }

    println!("\n== {model}: per-layer allocation on KV260 ==");
    let (alloc, _, _) = fit_to_board(&arch.name, &g, &loads, &resnet_hls::hls::KV260, 2)?;
    println!("{:<10} {:>8} {:>8} {:>8} {:>10}", "layer", "och_par", "cp", "DSPs", "cycles");
    for l in &alloc.layers {
        println!("{:<10} {:>8} {:>8} {:>8} {:>10}", l.name, l.och_par, l.cp, l.dsps, l.cycles);
    }
    Ok(())
}
