//! TCP ingress client: stream synthetic CIFAR frames at a target FPS
//! and report client-observed p50/p95/p99 latency plus the shed rate.
//!
//! Point it at a running `repro listen` server:
//!
//! ```bash
//! cargo run --release -- listen --backend golden --port 7433 &
//! cargo run --release --example tcp_client -- 127.0.0.1:7433 512 2000
//! ```
//!
//! With no address argument the example is self-contained: it starts an
//! in-process ingress server on an ephemeral port (golden backend,
//! synthetic weights), measures the service rate closed-loop, then
//! drives ~2x that rate to demonstrate bounded-queue load-shedding with
//! retry-after hints — the ISSUE's soak scenario in miniature.
//!
//! Positional args: `[addr] [frames] [fps] [deadline_ms]`
//! (fps 0 = open loop).

use std::sync::Arc;

use anyhow::Result;
use resnet_hls::coordinator::{Router, RouterConfig};
use resnet_hls::net::{drive, DriveConfig, IngressServer, ServerConfig};
use resnet_hls::runtime::{BackendFactory, GoldenFactory};

fn main() -> Result<()> {
    let mut args = std::env::args().skip(1);
    let addr = args.next();
    let frames: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(512);
    let fps: f64 = args.next().and_then(|s| s.parse().ok()).unwrap_or(0.0);
    let deadline_ms: u32 = args.next().and_then(|s| s.parse().ok()).unwrap_or(0);

    match addr {
        Some(addr) => {
            let cfg = DriveConfig {
                addr,
                frames,
                fps,
                deadline_ms,
                ..Default::default()
            };
            println!(
                "driving {} frames at {} (deadline {} ms) -> {}",
                cfg.frames,
                if fps > 0.0 { format!("{fps:.0} FPS") } else { "open loop".into() },
                cfg.deadline_ms,
                cfg.addr
            );
            let report = drive(&cfg).map_err(|e| anyhow::anyhow!("drive failed: {e}"))?;
            println!("{report}");
            anyhow::ensure!(report.accounted(), "request accounting failed: {report}");
        }
        None => {
            println!("no address given — starting an in-process ingress server");
            let factory: Arc<dyn BackendFactory> = Arc::new(GoldenFactory::synthetic("resnet8", 7));
            let router = Arc::new(Router::start(vec![factory], RouterConfig::default())?);
            let server = IngressServer::start(
                router.clone(),
                ServerConfig { queue_capacity: 16, ..Default::default() },
            )?;
            let addr = format!("{}", server.local_addr());
            println!("listening on {addr}");

            // Closed-loop calibration: what rate does one connection
            // sustain with a small pipeline window?
            let cal = drive(&DriveConfig {
                addr: addr.clone(),
                frames: frames.min(128),
                window: 4,
                ..Default::default()
            })
            .map_err(|e| anyhow::anyhow!("calibration failed: {e}"))?;
            println!("calibration: {cal}");
            let base_fps = cal.ok_fps().max(50.0);

            // 2x sustained overload: the bounded queue must shed (with
            // retry-after hints) instead of buffering unboundedly.
            let overload = drive(&DriveConfig {
                addr: addr.clone(),
                frames,
                fps: 2.0 * base_fps,
                deadline_ms,
                window: 64,
                ..Default::default()
            })
            .map_err(|e| anyhow::anyhow!("overload drive failed: {e}"))?;
            println!("2x overload ({:.0} FPS): {overload}", 2.0 * base_fps);
            anyhow::ensure!(overload.accounted(), "request accounting failed: {overload}");

            let snap = server.shutdown();
            println!("ingress {snap}");
            let router = Arc::try_unwrap(router)
                .map_err(|_| anyhow::anyhow!("server still holds the router"))?;
            println!("router {}", router.shutdown());
        }
    }
    Ok(())
}
