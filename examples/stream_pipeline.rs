//! Streaming line-buffer backend demo: the paper's Section III dataflow
//! executed for real.
//!
//! Runs the same synthetic batch through the golden backend (whole-tensor
//! intermediates, single thread) and the streaming backend (one pipelined
//! task per layer, bounded FIFOs sized by `hls::streams`, skip paths
//! through Eq. 22-sized FIFOs), asserts bit-equality, and reports the
//! measured buffering saving plus wall-clock throughput of both.
//!
//! ```bash
//! cargo run --release --example stream_pipeline [-- frames]
//! ```

use std::time::Instant;

use anyhow::Result;
use resnet_hls::data::{synth_batch, TEST_SEED};
use resnet_hls::hls::streams::StreamKind;
use resnet_hls::runtime::{GoldenBackend, InferenceBackend, StreamBackend};

fn main() -> Result<()> {
    let frames: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(16);
    let (input, _) = synth_batch(0, frames, TEST_SEED);

    for arch in ["resnet8", "resnet20"] {
        println!("== {arch} ({frames} frames) ==");
        let golden = GoldenBackend::synthetic(arch, 7, &[frames])?;
        let stream = StreamBackend::synthetic(arch, 7, &[frames])?;

        let t0 = Instant::now();
        let g = golden.infer_batch(&input)?;
        let t_golden = t0.elapsed();

        let t0 = Instant::now();
        let s = stream.infer_batch(&input)?;
        let t_stream = t0.elapsed();

        assert_eq!(g.data, s.data, "stream backend must be bit-exact vs golden");
        println!("  bit-exact vs golden: OK");
        println!(
            "  golden {:>8.1} ms ({:.0} FPS)   stream {:>8.1} ms ({:.0} FPS, pipelined)",
            t_golden.as_secs_f64() * 1e3,
            frames as f64 / t_golden.as_secs_f64(),
            t_stream.as_secs_f64() * 1e3,
            frames as f64 / t_stream.as_secs_f64(),
        );

        let stats = stream.last_stats().expect("stats recorded");
        println!("  skip FIFOs (Eq. 22 capacity vs measured peak):");
        for b in stats.of_kind(StreamKind::Skip) {
            println!("    {:<14} cap {:>6}  peak {:>6}", b.name, b.capacity, b.peak);
        }
        println!(
            "  peak streamed buffering: {} elems vs {} whole-tensor intermediates ({:.4})",
            stats.peak_buffered_elems(),
            stats.whole_tensor_elems,
            stats.buffered_fraction()
        );
    }
    Ok(())
}
