//! Streaming line-buffer backend demo: the paper's Section III dataflow
//! executed for real.
//!
//! Part 1 runs the same synthetic batch through the golden backend
//! (whole-tensor intermediates, single thread) and the streaming backend
//! (pipelined tasks, bounded FIFOs sized by the board/ILP config, skip
//! paths through Eq. 22-sized FIFOs), asserts bit-equality, and reports
//! the measured buffering saving plus wall-clock throughput of both.
//!
//! Part 2 shows the *serving* engine: a persistent frame-pipelined
//! [`resnet_hls::stream::StreamPool`] with 1 vs 2 replicas against
//! repeated one-shot `run_streaming` calls — the pool keeps its stage
//! threads alive, so frame N+1 enters conv0 while frame N is in the
//! classifier, and replicas trade buffering for throughput.
//!
//! ```bash
//! cargo run --release --example stream_pipeline [-- frames]
//! ```

use std::time::Instant;

use anyhow::Result;
use resnet_hls::data::{synth_batch, TEST_SEED};
use resnet_hls::hls::streams::StreamKind;
use resnet_hls::models::{arch_by_name, build_optimized_graph, synthetic_weights};
use resnet_hls::runtime::{GoldenBackend, InferenceBackend, StreamBackend};
use resnet_hls::stream::{run_streaming, StreamConfig};

fn main() -> Result<()> {
    let frames: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(16);
    let (input, _) = synth_batch(0, frames, TEST_SEED);

    for arch in ["resnet8", "resnet20"] {
        println!("== {arch} ({frames} frames) ==");
        let golden = GoldenBackend::synthetic(arch, 7, &[frames])?;
        let stream = StreamBackend::synthetic(arch, 7, &[frames])?;

        let t0 = Instant::now();
        let g = golden.infer_batch(&input)?;
        let t_golden = t0.elapsed();

        let t0 = Instant::now();
        let s = stream.infer_batch(&input)?;
        let t_stream = t0.elapsed();

        assert_eq!(g.data, s.data, "stream backend must be bit-exact vs golden");
        println!("  bit-exact vs golden: OK");
        println!(
            "  golden {:>8.1} ms ({:.0} FPS)   stream {:>8.1} ms ({:.0} FPS, pipelined)",
            t_golden.as_secs_f64() * 1e3,
            frames as f64 / t_golden.as_secs_f64(),
            t_stream.as_secs_f64() * 1e3,
            frames as f64 / t_stream.as_secs_f64(),
        );

        let stats = stream.last_stats().expect("stats recorded");
        println!("  skip FIFOs (Eq. 22 capacity vs measured peak):");
        for b in stats.of_kind(StreamKind::Skip) {
            println!("    {:<14} cap {:>6}  peak {:>6}", b.name, b.capacity, b.peak);
        }
        println!(
            "  peak streamed buffering: {} elems vs {} whole-tensor intermediates ({:.4})",
            stats.peak_buffered_elems(),
            stats.whole_tensor_elems,
            stats.buffered_fraction()
        );
    }

    // ---- Part 2: persistent pool throughput (resnet8, 32 frames) ----
    let frames = frames.max(32);
    let (input, _) = synth_batch(0, frames, TEST_SEED);
    println!("\n== persistent stream pool, resnet8, {frames} frames ==");

    let arch = arch_by_name("resnet8").unwrap();
    let weights = synthetic_weights(&arch, 7);
    let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let golden = GoldenBackend::synthetic("resnet8", 7, &[frames])?;
    let want = golden.infer_batch(&input)?;

    let t0 = Instant::now();
    for i in 0..frames {
        let (one, _) = synth_batch(i as u64, 1, TEST_SEED);
        run_streaming(&g, &weights, &one, &StreamConfig::default())?;
    }
    let t_oneshot = t0.elapsed();
    println!(
        "  one-shot run_streaming x{frames}: {:>8.1} ms ({:.0} FPS) — plan + spawn + fill per frame",
        t_oneshot.as_secs_f64() * 1e3,
        frames as f64 / t_oneshot.as_secs_f64()
    );

    for replicas in [1usize, 2] {
        let backend = StreamBackend::synthetic_with(
            "resnet8",
            7,
            &[frames],
            StreamConfig { replicas, ..Default::default() },
        )?;
        let t0 = Instant::now();
        let out = backend.infer_batch(&input)?;
        let dt = t0.elapsed();
        assert_eq!(out.data, want.data, "pool must stay bit-exact vs golden");
        println!(
            "  pool x{replicas} replica(s) ({} frames in flight): {:>8.1} ms ({:.0} FPS, bit-exact)",
            backend.pool().capacity(),
            dt.as_secs_f64() * 1e3,
            frames as f64 / dt.as_secs_f64()
        );
    }
    Ok(())
}
