//! End-to-end serving driver (DESIGN.md experiment E7).
//!
//! Starts ONE multi-architecture inference router serving both the
//! AOT-compiled ResNet8 and ResNet20 (a worker pool per arch), streams a
//! synthetic CIFAR-10 test set through it at several request patterns,
//! and reports accuracy, throughput and latency percentiles.  Results
//! are recorded in EXPERIMENTS.md §E7.
//!
//! Without artifacts the example falls back to the artifact-free golden
//! backend (synthetic weights) so the serving path itself still runs.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_cifar [-- frames]
//! ```

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;
use resnet_hls::coordinator::{Router, RouterConfig};
use resnet_hls::data::{synth_batch, IMG_ELEMS, TEST_SEED};
use resnet_hls::paths::artifacts_dir;
use resnet_hls::runtime::{BackendFactory, GoldenFactory, PjrtFactory};

const ARCHS: [&str; 2] = ["resnet8", "resnet20"];

fn main() -> Result<()> {
    let frames: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(512);
    let (input, labels) = synth_batch(0, frames, TEST_SEED);

    let dir = artifacts_dir();
    let factories: Vec<Arc<dyn BackendFactory>> = if dir.join("manifest.json").exists() {
        ARCHS.iter().map(|a| {
            Arc::new(PjrtFactory::new(dir.clone(), a)) as Arc<dyn BackendFactory>
        }).collect()
    } else {
        println!("artifacts not built — serving on the golden backend (synthetic weights)");
        ARCHS.iter().map(|a| {
            Arc::new(GoldenFactory::synthetic(a, 7)) as Arc<dyn BackendFactory>
        }).collect()
    };
    let router = Router::start(factories, RouterConfig::default())?;

    for arch in ARCHS {
        println!("== serving {arch} ({frames} frames) ==");

        // Pattern A: open-loop burst (throughput-oriented).
        let t0 = Instant::now();
        let pending: Vec<_> = (0..frames)
            .map(|i| router.submit(arch, input.data[i * IMG_ELEMS..(i + 1) * IMG_ELEMS].to_vec()))
            .collect::<Result<_>>()?;
        let mut correct = 0usize;
        for (rx, &label) in pending.iter().zip(&labels) {
            let resp = rx.recv()??;
            if resp.class == label as usize {
                correct += 1;
            }
        }
        let dt = t0.elapsed();
        println!(
            "  burst:  {:.0} FPS ({} frames in {:.1} ms), accuracy {:.3}",
            frames as f64 / dt.as_secs_f64(),
            frames,
            dt.as_secs_f64() * 1e3,
            correct as f64 / frames as f64
        );
        println!("  burst metrics: {}", router.metrics(arch).unwrap().snapshot());

        // Pattern B: closed-loop single-stream (latency-oriented).
        let probe = frames.min(64);
        let t0 = Instant::now();
        let mut lat_us = Vec::with_capacity(probe);
        for i in 0..probe {
            let s = Instant::now();
            let _ = router.infer(arch, input.data[i * IMG_ELEMS..(i + 1) * IMG_ELEMS].to_vec())?;
            lat_us.push(s.elapsed().as_micros() as u64);
        }
        lat_us.sort_unstable();
        println!(
            "  single-stream: {:.0} FPS, latency p50 {} us  p90 {} us  max {} us",
            probe as f64 / t0.elapsed().as_secs_f64(),
            lat_us[probe / 2],
            lat_us[probe * 9 / 10],
            lat_us[probe - 1]
        );
    }

    println!("== final router snapshot ==\n{}", router.shutdown());
    Ok(())
}
