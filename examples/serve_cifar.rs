//! End-to-end serving driver (DESIGN.md experiment E7).
//!
//! Loads the AOT-compiled ResNet8/20, starts the inference coordinator
//! (dynamic batcher + executor thread), streams a synthetic CIFAR-10 test
//! set through it at several request patterns, and reports accuracy,
//! throughput and latency percentiles.  Results are recorded in
//! EXPERIMENTS.md §E7.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_cifar [-- frames]
//! ```

use std::time::Instant;

use anyhow::Result;
use resnet_hls::coordinator::{BatcherConfig, InferenceServer};
use resnet_hls::data::{synth_batch, IMG_ELEMS, TEST_SEED};
use resnet_hls::paths::artifacts_dir;

fn main() -> Result<()> {
    let frames: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(512);
    let (input, labels) = synth_batch(0, frames, TEST_SEED);

    for arch in ["resnet8", "resnet20"] {
        println!("== serving {arch} ({frames} frames) ==");
        let server = InferenceServer::start(artifacts_dir(), arch, BatcherConfig::default())?;

        // Pattern A: open-loop burst (throughput-oriented).
        let t0 = Instant::now();
        let pending: Vec<_> = (0..frames)
            .map(|i| server.submit(input.data[i * IMG_ELEMS..(i + 1) * IMG_ELEMS].to_vec()))
            .collect::<Result<_>>()?;
        let mut correct = 0usize;
        for (rx, &label) in pending.iter().zip(&labels) {
            let resp = rx.recv()??;
            if resp.class == label as usize {
                correct += 1;
            }
        }
        let dt = t0.elapsed();
        println!(
            "  burst:  {:.0} FPS ({} frames in {:.1} ms), accuracy {:.3}",
            frames as f64 / dt.as_secs_f64(),
            frames,
            dt.as_secs_f64() * 1e3,
            correct as f64 / frames as f64
        );
        println!("  burst metrics: {}", server.metrics.snapshot());

        // Pattern B: closed-loop single-stream (latency-oriented).
        let probe = frames.min(64);
        let t0 = Instant::now();
        let mut lat_us = Vec::with_capacity(probe);
        for i in 0..probe {
            let s = Instant::now();
            let _ = server.infer(input.data[i * IMG_ELEMS..(i + 1) * IMG_ELEMS].to_vec())?;
            lat_us.push(s.elapsed().as_micros() as u64);
        }
        lat_us.sort_unstable();
        println!(
            "  single-stream: {:.0} FPS, latency p50 {} us  p90 {} us  max {} us",
            probe as f64 / t0.elapsed().as_secs_f64(),
            lat_us[probe / 2],
            lat_us[probe * 9 / 10],
            lat_us[probe - 1]
        );
    }
    Ok(())
}
