//! Network ingress integration tests (the PR-6 tentpole, driven through
//! the public crate surface): the loopback round trip via the shared
//! [`resnet_hls::net::drive`] traffic generator, the bounded-queue
//! overload soak (sheds with retry hints, queue peak never above its
//! cap, every request answered exactly once and in order), and the
//! elastic acceptance criterion — socket backlog reported through
//! `Router::report_ingress` must grow a stream pool's replica band
//! above `min_replicas`, observable in the router's replica gauges.
//!
//! Deterministic failure-path coverage (expiry at dequeue, malformed
//! frames, shutdown draining) lives next to the server in
//! `src/net/server.rs`; these tests exercise the same binary protocol
//! end to end over real sockets with the same driver the example
//! client, the `client` subcommand and the soak bench use.

use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;
use resnet_hls::coordinator::{Router, RouterConfig};
use resnet_hls::net::{drive, DriveConfig, IngressServer, ServerConfig};
use resnet_hls::quant::{QTensor, Shape4};
use resnet_hls::runtime::{BackendFactory, GoldenFactory, InferenceBackend, StreamFactory};
use resnet_hls::stream::{ElasticConfig, StreamConfig};

/// Run `f` on a helper thread and fail LOUDLY if it exceeds `secs` — an
/// ingress-shutdown regression must hang this watchdog, not CI silently.
fn with_watchdog<F: FnOnce() + Send + 'static>(secs: u64, what: &str, f: F) {
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) => h.join().unwrap(),
        Err(RecvTimeoutError::Disconnected) => h.join().unwrap(), // propagate the panic
        Err(RecvTimeoutError::Timeout) => {
            panic!("{what}: exceeded the {secs}s watchdog (shutdown/drain regression)")
        }
    }
}

/// A backend that sleeps per batch and returns fixed logits — makes the
/// overload soak deterministic without golden compute cost.
struct SlowBackend {
    delay: Duration,
}

impl InferenceBackend for SlowBackend {
    fn arch(&self) -> &str {
        "resnet8"
    }

    fn buckets(&self) -> &[usize] {
        &[1, 8]
    }

    fn infer_batch(&self, input: &QTensor) -> Result<QTensor> {
        std::thread::sleep(self.delay);
        let n = input.shape.n;
        Ok(QTensor::from_vec(Shape4::new(n, 1, 1, 10), 0, vec![0i32; n * 10]))
    }
}

struct SlowFactory {
    delay: Duration,
}

impl BackendFactory for SlowFactory {
    fn arch(&self) -> &str {
        "resnet8"
    }

    fn create(&self) -> Result<Box<dyn InferenceBackend>> {
        Ok(Box::new(SlowBackend { delay: self.delay }))
    }
}

#[test]
fn drive_accounts_every_frame_against_a_golden_server() {
    with_watchdog(120, "golden loopback drive", || {
        let router = Arc::new(
            Router::start(
                vec![Arc::new(GoldenFactory::synthetic("resnet8", 7))],
                RouterConfig::default(),
            )
            .unwrap(),
        );
        let server = IngressServer::start(router.clone(), ServerConfig::default()).unwrap();

        let report = drive(&DriveConfig {
            addr: format!("{}", server.local_addr()),
            frames: 32,
            window: 8,
            ..Default::default()
        })
        .unwrap();
        assert!(report.accounted(), "accounting failed: {report}");
        assert_eq!(report.sent, 32);
        // An 8-deep pipeline window can never fill the 64-deep default
        // admission queue: nothing sheds, everything serves.
        assert_eq!(report.oks, 32, "unexpected non-OK responses: {report}");
        assert!(report.p50_us > 0 && report.p99_us >= report.p50_us);

        let snap = server.shutdown();
        assert_eq!(snap.accepted, 32);
        assert_eq!(snap.responses, 32);
        assert_eq!(snap.shed, 0);
        let rs = router.snapshot();
        assert_eq!(rs.total.requests, 32);
        assert_eq!(rs.total.shed, 0);
    });
}

#[test]
fn overload_soak_sheds_with_hints_and_never_exceeds_the_queue_cap() {
    with_watchdog(120, "overload soak", || {
        let deadline_ms = 60_000u32;
        let router = Arc::new(
            Router::start(
                vec![Arc::new(SlowFactory { delay: Duration::from_millis(2) })],
                RouterConfig::default(),
            )
            .unwrap(),
        );
        let server = IngressServer::start(
            router.clone(),
            ServerConfig { queue_capacity: 8, dispatchers: 1, ..Default::default() },
        )
        .unwrap();

        // Open loop with a 64-deep window against an 8-deep queue and a
        // ~2ms service time: a sustained (way past 2x) overload.  The
        // bounded queue must shed the excess with retry hints instead of
        // buffering it, and what it admits must still serve.
        let report = drive(&DriveConfig {
            addr: format!("{}", server.local_addr()),
            frames: 128,
            window: 64,
            deadline_ms,
            ..Default::default()
        })
        .unwrap();
        assert!(report.accounted(), "accounting failed: {report}");
        assert!(report.sheds > 0, "overload must shed: {report}");
        assert!(report.oks > 0, "admitted requests must still serve: {report}");
        assert!(
            report.p99_us < u64::from(deadline_ms) * 1000,
            "client-observed p99 {}us blew the {deadline_ms}ms deadline",
            report.p99_us
        );

        let snap = server.shutdown();
        assert!(
            snap.queue_peak_depth <= 8,
            "admission queue exceeded its cap: {}",
            snap.queue_peak_depth
        );
        assert_eq!(snap.shed as usize, report.sheds);
        let rs = router.snapshot();
        assert_eq!(rs.total.shed as usize, report.sheds);
        assert!(rs.total.shed_rate > 0.0, "shed rate must surface in the snapshot");
        assert!(
            format!("{}", rs.total).contains("shed"),
            "snapshot text must mention shedding: {}",
            rs.total
        );
    });
}

#[test]
fn ingress_backlog_grows_elastic_stream_replicas_above_min() {
    // The PR-6 acceptance criterion for the elastic loop: requests
    // buffered at the *socket tier* (the admission queue) must reach the
    // stream pool's scaling signal via `Router::report_ingress` +
    // `InferenceBackend::load_hint`, growing the pool above its
    // `min_replicas` floor even though the router's own queue stays
    // shallow (dispatchers submit one request at a time).
    with_watchdog(180, "elastic ingress growth", || {
        let elastic = ElasticConfig {
            min_replicas: 1,
            max_replicas: 2,
            high_water: Some(4),
            sample_interval: Duration::from_millis(2),
            scale_up_samples: 2,
            // Hold the grown pool so the post-drive snapshot can't race
            // an idle drain (peak gauges would survive one anyway).
            scale_down_samples: 10_000,
        };
        let factory = StreamFactory::synthetic("resnet8", 7)
            .with_config(StreamConfig { elastic: Some(elastic), ..Default::default() });
        let router =
            Arc::new(Router::start(vec![Arc::new(factory)], RouterConfig::default()).unwrap());
        let server = IngressServer::start(
            router.clone(),
            ServerConfig { queue_capacity: 32, dispatchers: 2, ..Default::default() },
        )
        .unwrap();

        // A 32-deep open-loop window keeps the admission queue tens of
        // frames deep for the whole burst — far above the high-water
        // mark of 4 for many 2ms controller samples.
        let report = drive(&DriveConfig {
            addr: format!("{}", server.local_addr()),
            frames: 64,
            window: 32,
            deadline_ms: 60_000,
            ..Default::default()
        })
        .unwrap();
        assert!(report.accounted(), "accounting failed: {report}");
        assert!(report.oks > 0, "the pool must serve under the burst: {report}");

        let rs = router.snapshot();
        let m = rs.per_arch.get("resnet8").expect("resnet8 metrics");
        assert!(
            m.stream_peak_replicas >= 2,
            "socket backlog never grew the pool above min_replicas=1 \
             (peak gauge {}, live {})",
            m.stream_peak_replicas,
            m.stream_replicas
        );

        let snap = server.shutdown();
        assert!(snap.accepted > 0);
    });
}
