//! Loom model checks for the two hand-rolled synchronisation protocols
//! in `stream/`: the bounded element-accounted FIFO (`Fifo::push` /
//! `pop` / `pop_idle`) and the elastic pool's retire handshake.
//!
//! The whole file is gated on `--cfg loom` (`RUSTFLAGS="--cfg loom"
//! cargo test --test loom_stream --release`) so the ordinary test run
//! never needs the `loom` crate.  The models are self-contained
//! re-statements of the protocols rather than imports of the real
//! types: the production code uses `std::sync` plus bounded
//! `wait_timeout` polling (a missed notify costs at most one `POLL`
//! interval before the waiter re-checks), which loom cannot express.
//! The models therefore replace every timeout with a plain `wait` and
//! hold the mutex across the notify — the stricter discipline under
//! which the protocol itself must be lost-wakeup free.  Loom then
//! exhaustively interleaves the threads: any execution where a waiter
//! sleeps forever, a token is lost or reordered, or the capacity
//! accounting goes negative fails the model.
#![cfg(loom)]

use loom::sync::atomic::{AtomicBool, Ordering};
use loom::sync::{Arc, Condvar, Mutex};
use loom::thread;
use std::collections::VecDeque;

/// Model of `stream::fifo::FifoState` + its condvar protocol.
struct ModelFifo {
    capacity: usize,
    abort: AtomicBool,
    state: Mutex<ModelState>,
    cv: Condvar,
}

struct ModelState {
    queue: VecDeque<Box<[i32]>>,
    occupancy: usize,
    peak: usize,
}

impl ModelFifo {
    fn new(capacity: usize) -> Arc<Self> {
        Arc::new(ModelFifo {
            capacity,
            abort: AtomicBool::new(false),
            state: Mutex::new(ModelState { queue: VecDeque::new(), occupancy: 0, peak: 0 }),
            cv: Condvar::new(),
        })
    }

    /// `Fifo::push` with the stall deadline replaced by an unbounded
    /// wait — the model proves the deadline is never needed for these
    /// schedules (it exists in production for *undersized* pipelines,
    /// which the static analyzer rejects up front).
    fn push(&self, token: Box<[i32]>) {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.occupancy + token.len() <= self.capacity {
                st.occupancy += token.len();
                st.peak = st.peak.max(st.occupancy);
                assert!(st.peak <= self.capacity, "capacity accounting overflowed");
                st.queue.push_back(token);
                self.cv.notify_all();
                return;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// `Fifo::pop` (bounded wait elided, as in `push`).
    fn pop(&self) -> Box<[i32]> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(tok) = st.queue.pop_front() {
                st.occupancy -= tok.len();
                self.cv.notify_all();
                return tok;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// `Fifo::pop_idle`: an unbounded frame-boundary wait that must
    /// still unblock promptly when the pool aborts.
    fn pop_idle(&self) -> Result<Box<[i32]>, ()> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(tok) = st.queue.pop_front() {
                st.occupancy -= tok.len();
                self.cv.notify_all();
                return Ok(tok);
            }
            if self.abort.load(Ordering::SeqCst) {
                return Err(());
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// The abort broadcast, with the notify ordered after a lock
    /// acquisition so it cannot slip between a waiter's flag check and
    /// its `wait` (production instead tolerates that window by polling
    /// with `wait_timeout`).
    fn abort(&self) {
        self.abort.store(true, Ordering::SeqCst);
        drop(self.state.lock().unwrap());
        self.cv.notify_all();
    }
}

/// Producer/consumer over a FIFO too small to hold the whole stream:
/// every interleaving must deliver all tokens, in order, without the
/// occupancy ever exceeding the declared capacity.
#[test]
fn fifo_push_pop_is_lossless_in_order_and_bounded() {
    loom::model(|| {
        let f = ModelFifo::new(2);
        let p = {
            let f = Arc::clone(&f);
            thread::spawn(move || {
                for v in [10i32, 20, 30] {
                    f.push(vec![v].into_boxed_slice());
                }
            })
        };
        let mut got = Vec::new();
        for _ in 0..3 {
            got.push(f.pop()[0]);
        }
        p.join().unwrap();
        assert_eq!(got, [10, 20, 30]);
        let st = f.state.lock().unwrap();
        assert_eq!(st.occupancy, 0);
        assert!(st.peak <= 2);
    });
}

/// The shutdown invariant `Fifo::push` documents: a zero-length
/// end-of-stream sentinel occupies no capacity, so it must be pushable
/// even while the FIFO is completely full — shutdown can never itself
/// deadlock behind a full queue.
#[test]
fn fifo_zero_len_sentinel_always_fits_when_full() {
    loom::model(|| {
        let f = ModelFifo::new(1);
        f.push(vec![7].into_boxed_slice()); // now full
        let s = {
            let f = Arc::clone(&f);
            // Must complete without any consumer making room.
            thread::spawn(move || f.push(Vec::new().into_boxed_slice()))
        };
        s.join().unwrap();
        assert_eq!(f.pop().len(), 1);
        assert_eq!(f.pop().len(), 0, "sentinel preserved behind the data token");
    });
}

/// `pop_idle` waits indefinitely for the next frame, so the abort
/// broadcast is its only exit: no interleaving may leave the idle
/// waiter asleep after `abort()` returns.
#[test]
fn pop_idle_always_unblocks_on_abort() {
    loom::model(|| {
        let f = ModelFifo::new(1);
        let c = {
            let f = Arc::clone(&f);
            thread::spawn(move || f.pop_idle())
        };
        f.abort();
        // Either the waiter saw the abort, or it raced ahead and there
        // was genuinely nothing to pop — both must return Err.
        assert!(c.join().unwrap().is_err());
    });
}

/// Model of the elastic retire handshake (`PoolInner::retire_one` vs
/// the feeder's claim loop in `pool.rs`): the controller raises the
/// per-replica `retire` flag and notifies the shared queue condvar; the
/// feeder re-checks the flag under the queue lock before every wait and
/// must exit between frames.  The model proves the feeder can neither
/// sleep through the retirement nor claim a job after observing it.
#[test]
fn retire_handshake_never_loses_the_wakeup() {
    loom::model(|| {
        struct Q {
            jobs: VecDeque<u32>,
            open: bool,
        }
        let q = Arc::new((Mutex::new(Q { jobs: VecDeque::new(), open: true }), Condvar::new()));
        let retire = Arc::new(AtomicBool::new(false));

        let feeder = {
            let q = Arc::clone(&q);
            let retire = Arc::clone(&retire);
            thread::spawn(move || {
                let mut served = 0u32;
                let (m, cv) = &*q;
                let mut st = m.lock().unwrap();
                loop {
                    if retire.load(Ordering::SeqCst) {
                        return served;
                    }
                    if let Some(_job) = st.jobs.pop_front() {
                        served += 1;
                        continue;
                    }
                    if !st.open {
                        return served;
                    }
                    st = cv.wait(st).unwrap();
                }
            })
        };

        // Submit one job, then retire the replica (lock ordered before
        // notify, as in the model FIFO above).
        let (m, cv) = &*q;
        {
            let mut st = m.lock().unwrap();
            st.jobs.push_back(1);
        }
        cv.notify_all();
        retire.store(true, Ordering::SeqCst);
        drop(m.lock().unwrap());
        cv.notify_all();

        let served = feeder.join().unwrap();
        assert!(served <= 1, "feeder claimed a job after retirement");
    });
}
