//! Multi-tenant serving under one shared worker budget (the PR-9
//! tentpole acceptance): two architectures lease stage workers from a
//! single process-wide [`WorkerBudget`].  A ResNet8 burst grows past the
//! replica count a fair static split would allow by *borrowing* the
//! headroom an idle ResNet20 pool is not using, the cap is never
//! exceeded (gauge-asserted on every poll), every frame stays bit-exact
//! against the golden model with in-order tickets, and when the burst
//! reverses the borrowed workers migrate back so ResNet20 can grow
//! instead.  Watchdogged: a budget deadlock must fail loudly, not hang
//! CI.

use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::{Duration, Instant};

use resnet_hls::data::{synth_batch, IMG_ELEMS, TEST_SEED};
use resnet_hls::models::{arch_by_name, build_optimized_graph, synthetic_weights};
use resnet_hls::sim::golden;
use resnet_hls::stream::{ElasticConfig, StreamConfig, StreamPool, WorkerBudget};

/// Run `f` on a helper thread and fail LOUDLY if it exceeds `secs` — a
/// budget deadlock must hang this watchdog, not CI silently.
fn with_watchdog<F: FnOnce() + Send + 'static>(secs: u64, what: &str, f: F) {
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) => h.join().unwrap(),
        Err(RecvTimeoutError::Disconnected) => h.join().unwrap(), // propagate the panic
        Err(RecvTimeoutError::Timeout) => {
            panic!("{what}: exceeded the {secs}s watchdog (budget deadlock regression)")
        }
    }
}

/// Poll `cond` until it holds or `deadline` passes; returns whether it
/// ever held.
fn wait_until(deadline: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    cond()
}

/// A fast-cadence elastic band (mirrors the stream_pool test tuning):
/// scale up after ~4ms of sustained burst, drain after ~50ms idle.
fn test_elastic(min: usize, max: usize) -> ElasticConfig {
    ElasticConfig {
        min_replicas: min,
        max_replicas: max,
        high_water: Some(4),
        sample_interval: Duration::from_millis(2),
        scale_up_samples: 2,
        scale_down_samples: 25,
    }
}

fn model(arch_name: &str, seed: u64) -> (resnet_hls::graph::Graph, resnet_hls::models::ModelWeights)
{
    let arch = arch_by_name(arch_name).unwrap();
    let weights = synthetic_weights(&arch, seed);
    let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    (g, weights)
}

/// Workers one replica of `arch_name` costs: probe with a throwaway
/// fixed single-replica pool (the stage count is a planning artifact the
/// test must not hardcode).
fn workers_per_replica(arch_name: &str) -> usize {
    let (g, weights) = model(arch_name, 7);
    let pool =
        StreamPool::new(arch_name, &g, Arc::new(weights), StreamConfig::default()).unwrap();
    let w = pool.workers_per_replica();
    drop(pool);
    w
}

/// Burst one pool and verify every ticket bit-exact, in submit order,
/// against precomputed golden logits.
fn burst_bit_exact(pool: &StreamPool, input: &resnet_hls::quant::QTensor, want: &[i32]) {
    let frames = input.shape.n;
    let tickets: Vec<_> = (0..frames)
        .map(|i| pool.submit(&input.data[i * IMG_ELEMS..(i + 1) * IMG_ELEMS]).unwrap())
        .collect();
    let mut got = Vec::new();
    for t in tickets {
        got.extend_from_slice(&t.wait().unwrap());
    }
    assert_eq!(got, want, "budgeted pool diverged from golden");
}

#[test]
fn shared_budget_migrates_workers_between_arch_bursts() {
    with_watchdog(900, "two-arch shared-budget burst", || {
        // Probe each arch's per-replica worker cost first; the budget is
        // sized off the real planning numbers, never hardcoded counts.
        let s8 = workers_per_replica("resnet8");
        let s20 = workers_per_replica("resnet20");
        assert!(s8 >= 1 && s20 >= 1);
        // The borrowing argument below needs the deeper model to cost at
        // least as much per replica (it has more stages by construction).
        assert!(s8 <= s20, "resnet8 replica ({s8}) outweighs resnet20 ({s20})?");

        // Cap = two replicas of each.  ResNet8's band max (3) only fits
        // while ResNet20 sits at its floor: 3*s8 + s20 <= total needs
        // s8 <= s20 (asserted above), while both bands maxed would need
        // 3*s8 + 2*s20 — strictly over the cap.  Reaching 3 replicas IS
        // the proof of borrowing.
        let total = 2 * (s8 + s20);
        assert!(3 * s8 + 2 * s20 > total, "bands must not both fit at max");
        let budget = Arc::new(WorkerBudget::new(total));

        let (g8, w8) = model("resnet8", 7);
        let (g20, w20) = model("resnet20", 7);
        let frames8 = 48usize;
        let frames20 = 12usize;
        let (in8, _) = synth_batch(0, frames8, TEST_SEED);
        let (in20, _) = synth_batch(1, frames20, TEST_SEED);
        let want8 = golden::run(&g8, &w8, &in8).unwrap();
        let want20 = golden::run(&g20, &w20, &in20).unwrap();

        let pool8 = StreamPool::new(
            "resnet8",
            &g8,
            Arc::new(w8),
            StreamConfig {
                elastic: Some(test_elastic(1, 3)),
                budget: Some(budget.clone()),
                ..Default::default()
            },
        )
        .unwrap();
        let pool20 = StreamPool::new(
            "resnet20",
            &g20,
            Arc::new(w20),
            StreamConfig {
                elastic: Some(test_elastic(1, 2)),
                budget: Some(budget.clone()),
                ..Default::default()
            },
        )
        .unwrap();

        // Registration reserved each pool's floor; the initial replicas
        // hold exactly those workers.
        let snap = budget.snapshot();
        assert_eq!(snap.total, total);
        assert_eq!(snap.held, s8 + s20);
        let arch_row = |name: &str| {
            snap.leases
                .iter()
                .find(|l| l.arch == name)
                .unwrap_or_else(|| panic!("no lease row for {name}"))
                .clone()
        };
        assert_eq!((arch_row("resnet8").reserved, arch_row("resnet8").held), (s8, s8));
        assert_eq!((arch_row("resnet20").reserved, arch_row("resnet20").held), (s20, s20));

        // Never-exceed is asserted on EVERY poll below, not just at the
        // end — a transient over-cap grant would slip past a final check.
        let assert_capped = |budget: &WorkerBudget| {
            let s = budget.snapshot();
            assert!(
                s.held <= s.total && s.committed <= s.total,
                "budget over cap: held {} committed {} total {}",
                s.held,
                s.committed,
                s.total
            );
        };

        // ---- Phase 1: burst ResNet8 while ResNet20 idles. ----
        let grew = wait_until(Duration::from_secs(180), || {
            assert_capped(&budget);
            assert_eq!(
                pool20.replicas(),
                1,
                "idle resnet20 must stay at min_replicas during the resnet8 burst"
            );
            // Keep the queue over the high-water mark long enough for the
            // controller to bid its way to the band max.
            burst_bit_exact(&pool8, &in8, &want8.data);
            pool8.peak_replicas() >= 3
        });
        assert!(
            grew,
            "resnet8 never borrowed to its band max (peak {}): budget refused headroom \
             resnet20 was not using",
            pool8.peak_replicas()
        );
        // Lease accounting at (or after) the peak stays within the cap.
        assert_capped(&budget);

        // ---- Phase 2: reverse the burst — the budget migrates back. ----
        // Idle resnet8 drains to its floor; its borrowed workers return.
        let drained = wait_until(Duration::from_secs(180), || {
            assert_capped(&budget);
            pool8.replicas() == 1
        });
        assert!(drained, "resnet8 did not drain to min when idle (at {})", pool8.replicas());
        let s = budget.snapshot();
        assert_eq!(s.held, s8 + s20, "drained replicas must return their leases");

        // Now burst ResNet20: the freed headroom lets it grow to ITS max.
        let grew20 = wait_until(Duration::from_secs(180), || {
            assert_capped(&budget);
            assert_eq!(
                pool8.replicas(),
                1,
                "idle resnet8 must stay at min_replicas during the resnet20 burst"
            );
            burst_bit_exact(&pool20, &in20, &want20.data);
            pool20.peak_replicas() >= 2
        });
        assert!(
            grew20,
            "resnet20 never grew after the burst reversed (peak {}): the budget did not \
             migrate back",
            pool20.peak_replicas()
        );
        assert_capped(&budget);

        // Shutdown returns every lease: nothing held, nothing queued.
        drop(pool8);
        drop(pool20);
        let s = budget.snapshot();
        assert_eq!((s.held, s.committed), (0, 0), "pool shutdown leaked leases");
    });
}
