//! Property-based tests over the coordinator and substrate invariants
//! (seeded deterministic cases via `util::prop::forall`).

use resnet_hls::analysis::{self, AnalysisError};
use resnet_hls::coordinator::{Batcher, BatcherConfig, Metrics, BOUNDS_US};
use resnet_hls::data::{synth_batch, TEST_SEED};
use resnet_hls::graph::{infer_shapes, ConvAttrs, Edge, Graph, InputRole, Op};
use resnet_hls::ilp::{brute_force, solve, LayerLoad};
use resnet_hls::models::{arch_by_name, build_optimized_graph, synthetic_weights};
use resnet_hls::passes;
use resnet_hls::quant::{clip_i8, requantize, round_shift};
use resnet_hls::sim::golden;
use resnet_hls::stream::{planned_config, run_streaming, StreamConfig};
use resnet_hls::util::prop::forall;
use resnet_hls::util::Json;
use resnet_hls::util::Lcg64;

// ------------------------------------------------------------- quant laws

#[test]
fn relu_commutes_with_requantization() {
    // The soundness condition of the relu-merge pass and the add-fusion
    // relu placement: relu(requant(x)) == requant_with_relu(x).
    forall("relu/requant commute", 5000, |rng| {
        let acc = rng.range_i64(-(1 << 30), 1 << 30) as i32;
        let acc_exp = rng.range_i64(-16, -8) as i32;
        let out_exp = rng.range_i64(-7, 0) as i32;
        let fused = requantize(acc, acc_exp, out_exp, true);
        let separate = clip_i8(round_shift(acc, out_exp - acc_exp)).max(0);
        assert_eq!(fused, separate, "acc={acc} shift={}", out_exp - acc_exp);
    });
}

#[test]
fn round_shift_monotone() {
    forall("round_shift monotone", 3000, |rng| {
        let a = rng.range_i64(-(1 << 30), 1 << 30) as i32;
        let b = rng.range_i64(-(1 << 30), 1 << 30) as i32;
        let s = rng.range_i64(0, 20) as i32;
        if a <= b {
            assert!(round_shift(a, s) <= round_shift(b, s));
        } else {
            assert!(round_shift(a, s) >= round_shift(b, s));
        }
    });
}

// ------------------------------------------------- random residual graphs

/// Build a random chain of residual blocks; returns (graph, arch-like
/// geometry used to build it).
fn random_residual_graph(rng: &mut Lcg64) -> Graph {
    let mut g = Graph::new();
    let mut c = [4usize, 8, 16][rng.below(3) as usize];
    let mut h = 16usize;
    let input = g.add_simple("input", Op::Input { h, w: h, c, exp: -7 }, &[]);
    let conv = |cin: usize, cout: usize, k: usize, stride: usize, relu: bool| {
        Op::Conv(ConvAttrs {
            cin, cout, k, stride, pad: if k == 3 { 1 } else { 0 }, relu,
            w_exp: -8, out_exp: -5, merged_downsample: None, forwards_input: false,
            raw_output: false,
        })
    };
    let mut prev = g.add_simple("stem", conv(c, c, 3, 1, true), &[Edge::new(input, 0)]);
    let blocks = 1 + rng.below(3) as usize;
    for b in 0..blocks {
        let down = rng.below(2) == 0 && h >= 8;
        let (cout, stride) = if down { (c * 2, 2) } else { (c, 1) };
        let xin = prev;
        let skip = if down {
            g.add_simple(format!("b{b}ds"), conv(c, cout, 1, 2, false), &[Edge::new(xin, 0)])
        } else {
            xin
        };
        let c0 = g.add_simple(format!("b{b}c0"), conv(c, cout, 3, stride, true), &[Edge::new(xin, 0)]);
        let mut c1_attrs = conv(cout, cout, 3, 1, false);
        if let Op::Conv(a) = &mut c1_attrs {
            a.raw_output = true;
        }
        let c1 = g.add_simple(format!("b{b}c1"), c1_attrs, &[Edge::new(c0, 0)]);
        let add = g.add(
            format!("b{b}_add"),
            Op::Add { out_exp: -5 },
            vec![(Edge::new(c1, 0), InputRole::Data), (Edge::new(skip, 0), InputRole::Data)],
        );
        prev = g.add_simple(format!("b{b}_relu"), Op::Relu, &[Edge::new(add, 0)]);
        c = cout;
        if down {
            h /= 2;
        }
    }
    let pool = g.add_simple("pool", Op::GlobalAvgPool { out_exp: -5 }, &[Edge::new(prev, 0)]);
    g.add_simple(
        "fc",
        Op::Linear { cin: c, cout: 10, w_exp: -8 },
        &[Edge::new(pool, 0)],
    );
    g
}

#[test]
fn passes_preserve_shapes_on_random_graphs() {
    forall("passes preserve output shape", 60, |rng| {
        let mut g = random_residual_graph(rng);
        g.validate().unwrap();
        let before = infer_shapes(&g).unwrap()[&Edge::new(g.output().unwrap(), 0)];
        let stats = passes::optimize(&mut g);
        assert!(stats.adds_fused > 0, "every block's add must fuse");
        g.validate().unwrap();
        let after = infer_shapes(&g).unwrap()[&Edge::new(g.output().unwrap(), 0)];
        assert_eq!(before, after);
        assert_eq!(g.count_kind("add"), 0);
        assert_eq!(g.count_kind("relu"), 0);
    });
}

#[test]
fn ilp_matches_brute_force_on_random_instances() {
    forall("ilp == brute force", 40, |rng| {
        let n = 1 + rng.below(3) as usize;
        let loads: Vec<LayerLoad> = (0..n)
            .map(|i| {
                let och = [4usize, 6, 8][rng.below(3) as usize];
                LayerLoad {
                    name: format!("l{i}"),
                    macs: (rng.range_i64(1, 200) as u64) * 4096 * och as u64 * 9,
                    taps: [1usize, 9][rng.below(2) as usize],
                    och,
                    ow_par: 2,
                }
            })
            .collect();
        let budget = rng.range_i64(9, 300) as u64;
        match (solve(&loads, budget), brute_force(&loads, budget)) {
            (None, None) => {}
            (Some(s), Some(b)) => {
                assert_eq!(s.cycles_per_frame, b.cycles_per_frame);
                assert!(s.dsps_used <= budget);
            }
            (s, b) => panic!("feasibility mismatch: {:?} vs {:?}", s.is_some(), b.is_some()),
        }
    });
}

// -------------------------------------------------------- numerics fuzzing

#[test]
fn optimization_pipeline_is_numerics_preserving_on_random_graphs() {
    // The headline invariant: running the Section III-G passes never
    // changes a single output bit, on arbitrary residual topologies and
    // random weights/inputs.
    forall("passes preserve numerics", 12, |rng| {
        let g_naive = random_residual_graph(rng);
        let mut g_opt = g_naive.clone();
        passes::optimize(&mut g_opt);

        // Build weights for the *named layers* of this graph via a mock
        // arch: reuse synthetic_weights by constructing per-layer specs.
        let weights = weights_for_graph(&g_naive, rng.next_u64());
        // Input geometry differs from CIFAR: generate random pixels.
        let in_node = g_naive.node(g_naive.find("input").unwrap());
        let (h, c) = match in_node.op {
            Op::Input { h, c, .. } => (h, c),
            _ => unreachable!(),
        };
        let mut data = Vec::with_capacity(2 * h * h * c);
        let mut r2 = Lcg64::new(rng.next_u64());
        for _ in 0..2 * h * h * c {
            data.push(r2.range_i64(-128, 127) as i32);
        }
        let input = resnet_hls::quant::QTensor::from_vec(
            resnet_hls::quant::Shape4::new(2, h, h, c),
            -7,
            data,
        );
        let _ = &input;

        let a = golden::run(&g_naive, &weights, &input).unwrap();
        let b = golden::run(&g_opt, &weights, &input).unwrap();
        assert_eq!(a.data, b.data, "optimization changed numerics");
    });
}

/// Synthesize weights keyed by the graph's conv/linear layer names.
fn weights_for_graph(g: &Graph, seed: u64) -> resnet_hls::models::ModelWeights {
    use resnet_hls::models::{ConvWeights, WeightTensor};
    use std::collections::BTreeMap;
    let mut rng = Lcg64::new(seed);
    let mut layers = BTreeMap::new();
    let mut act_exps = BTreeMap::new();
    let mut w_exps = BTreeMap::new();
    act_exps.insert("input".to_string(), -7);
    act_exps.insert("pool".to_string(), -5);
    for n in g.live() {
        match &n.op {
            Op::Conv(a) => {
                let wlen = a.k * a.k * a.cin * a.cout;
                layers.insert(
                    n.name.clone(),
                    ConvWeights {
                        w: WeightTensor {
                            name: n.name.clone(), kind: "w".into(),
                            shape: vec![a.k, a.k, a.cin, a.cout], exp: a.w_exp,
                            data: (0..wlen).map(|_| rng.range_i64(-32, 32) as i32).collect(),
                        },
                        b: WeightTensor {
                            name: n.name.clone(), kind: "b".into(),
                            shape: vec![a.cout], exp: -5 + a.w_exp,
                            data: (0..a.cout).map(|_| rng.range_i64(-256, 256) as i32).collect(),
                        },
                    },
                );
                act_exps.insert(n.name.clone(), a.out_exp);
                w_exps.insert(n.name.clone(), a.w_exp);
            }
            Op::Linear { cin, cout, w_exp } => {
                layers.insert(
                    n.name.clone(),
                    ConvWeights {
                        w: WeightTensor {
                            name: n.name.clone(), kind: "w".into(),
                            shape: vec![*cin, *cout], exp: *w_exp,
                            data: (0..cin * cout).map(|_| rng.range_i64(-32, 32) as i32).collect(),
                        },
                        b: WeightTensor {
                            name: n.name.clone(), kind: "b".into(),
                            shape: vec![*cout], exp: -5 + w_exp,
                            data: (0..*cout).map(|_| rng.range_i64(-256, 256) as i32).collect(),
                        },
                    },
                );
                w_exps.insert(n.name.clone(), *w_exp);
            }
            _ => {}
        }
    }
    resnet_hls::models::ModelWeights {
        arch: "random".into(),
        layers,
        aliases: BTreeMap::new(),
        act_exps,
        w_exps,
        source: "prop".into(),
    }
}

// ------------------------------------------------------ streaming backend

#[test]
fn stream_executor_bit_identical_to_golden_on_random_models() {
    // The tentpole invariant: the pipelined line-buffer executor produces
    // the exact golden bits for arbitrary synthetic weights and inputs on
    // both paper architectures' optimized graphs.
    for (arch_name, cases, frames) in
        [
            ("resnet8", 4u64, 2usize),
            ("resnet20", 1, 1),
            ("skipnet", 2, 1),
            ("longskipnet", 2, 1),
            ("tiednet", 2, 1),
        ]
    {
        forall(&format!("stream == golden ({arch_name})"), cases, |rng| {
            let arch = arch_by_name(arch_name).unwrap();
            let weights = synthetic_weights(&arch, rng.next_u64());
            let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
            let (input, _) = synth_batch(rng.below(1000), frames, TEST_SEED);
            let want = golden::run(&g, &weights, &input).unwrap();
            let (got, stats) =
                run_streaming(&g, &weights, &input, &StreamConfig::default()).unwrap();
            assert_eq!(want.shape, got.shape);
            assert_eq!(want.data, got.data, "{arch_name}: stream output diverged");
            assert!(
                stats.peak_buffered_elems() < stats.whole_tensor_elems,
                "{arch_name}: streamed buffering {} not below whole-tensor {}",
                stats.peak_buffered_elems(),
                stats.whole_tensor_elems
            );
        });
    }
}

#[test]
fn stream_executor_bounded_wait_instead_of_deadlock() {
    let arch = arch_by_name("resnet8").unwrap();
    let weights = synthetic_weights(&arch, 7);
    let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let (input, _) = synth_batch(0, 1, TEST_SEED);

    // At the exact minimum depths from hls::streams (the default
    // construction) the pipeline completes.
    let (out, _) = run_streaming(&g, &weights, &input, &StreamConfig::default()).unwrap();
    assert_eq!(out.shape.c, 10);

    // Forcing the skip FIFOs below one pixel token re-creates the paper's
    // Fig. 14 failure mode: the producer can never flush its skip row, so
    // the pipeline wedges.  The executor must surface a bounded-wait
    // stall error — progress detection, not a hang.
    let cfg = StreamConfig {
        progress_timeout: std::time::Duration::from_millis(250),
        skip_capacity_override: Some(4),
        // Reach past the static analyzer (which rejects this depth before
        // any thread spawns) to exercise the runtime watchdog.
        static_checks: false,
        ..StreamConfig::default()
    };
    let t0 = std::time::Instant::now();
    let err = run_streaming(&g, &weights, &input, &cfg).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("stalled"), "expected a stall error, got: {msg}");
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(30),
        "stall detection must be bounded, not a hang"
    );
}

// ------------------------------------------- general skip DAGs (naive mode)

/// Build a random skip-connection DAG in its naive dataflow form: a chain
/// of residual bodies whose merge nodes take 2 or 3 operands, the third
/// reaching back to a uniformly random earlier same-shape tensor (a long
/// skip).  Constant spatial size and channel count keep every earlier
/// tensor shape-compatible with every merge.  The long skip may land on
/// the immediately preceding segment — duplicating the identity operand's
/// edge — which `Graph::validate` must reject statically (the planner's
/// per-(edge, consumer) FIFO map cannot express it).
fn random_skip_dag(rng: &mut Lcg64) -> Graph {
    let mut g = Graph::new();
    let c = [4usize, 8][rng.below(2) as usize];
    let h = 16usize;
    let input = g.add_simple("input", Op::Input { h, w: h, c, exp: -7 }, &[]);
    let conv = |relu: bool, raw: bool| {
        Op::Conv(ConvAttrs {
            cin: c, cout: c, k: 3, stride: 1, pad: 1, relu,
            w_exp: -8, out_exp: -5, merged_downsample: None, forwards_input: false,
            raw_output: raw,
        })
    };
    let mut prev = g.add_simple("stem", conv(true, false), &[Edge::new(input, 0)]);
    // Same-shape tensors a later merge may legally reach back to.
    let mut history = vec![prev];
    let blocks = 1 + rng.below(3) as usize;
    for b in 0..blocks {
        let c0 = g.add_simple(format!("b{b}c0"), conv(true, false), &[Edge::new(prev, 0)]);
        let c1 = g.add_simple(format!("b{b}c1"), conv(false, true), &[Edge::new(c0, 0)]);
        let mut inputs =
            vec![(Edge::new(c1, 0), InputRole::Data), (Edge::new(prev, 0), InputRole::Data)];
        if rng.below(2) == 0 {
            let far = history[rng.below(history.len() as u64) as usize];
            inputs.push((Edge::new(far, 0), InputRole::Data));
        }
        let add = g.add(format!("b{b}_add"), Op::Add { out_exp: -5 }, inputs);
        prev = g.add_simple(format!("b{b}_relu"), Op::Relu, &[Edge::new(add, 0)]);
        history.push(prev);
    }
    let pool = g.add_simple("pool", Op::GlobalAvgPool { out_exp: -5 }, &[Edge::new(prev, 0)]);
    g.add_simple("fc", Op::Linear { cin: c, cout: 10, w_exp: -8 }, &[Edge::new(pool, 0)]);
    g
}

#[test]
fn random_skip_dags_plan_and_preflight_agree() {
    // The planner/verifier agreement property on arbitrary valid skip
    // DAGs: a config `preflight` approves really runs — stall-free and
    // bit-exact vs the golden model — and a config it rejects carries a
    // typed diagnostic naming a skip edge that actually exists in the
    // graph, with its minimum safe depth.
    forall("random skip DAGs: plan/preflight agreement", 10, |rng| {
        let g = random_skip_dag(rng);
        if let Err(e) = g.validate() {
            // The only invalid shape the generator produces: a long skip
            // that drew the identity operand's own edge.  Validation must
            // reject it by name instead of letting the planner stall.
            assert!(e.contains("duplicate input edge"), "unexpected invalid DAG: {e}\n{g}");
            return;
        }
        let weights = weights_for_graph(&g, rng.next_u64());
        let mut cfg = StreamConfig { naive_add: true, ..StreamConfig::default() };
        if rng.below(3) == 0 {
            // An always-undersized override (every Eq. 21 / full-frame
            // bound at h=16 exceeds it) to exercise the flag direction.
            cfg.skip_capacity_override = Some(8 + rng.below(64) as usize);
            cfg.progress_timeout = std::time::Duration::from_millis(300);
        }
        let acfg = planned_config("random-skip-dag", &g, &cfg).unwrap();
        match analysis::preflight(&g, &cfg, &acfg) {
            Ok(()) => {
                let in_node = g.node(g.find("input").unwrap());
                let (h, c) = match in_node.op {
                    Op::Input { h, c, .. } => (h, c),
                    _ => unreachable!(),
                };
                let mut r2 = Lcg64::new(rng.next_u64());
                let data: Vec<i32> =
                    (0..h * h * c).map(|_| r2.range_i64(-128, 127) as i32).collect();
                let input = resnet_hls::quant::QTensor::from_vec(
                    resnet_hls::quant::Shape4::new(1, h, h, c),
                    -7,
                    data,
                );
                let want = golden::run(&g, &weights, &input).unwrap();
                let (got, _) = run_streaming(&g, &weights, &input, &cfg).unwrap();
                assert_eq!(got.data, want.data, "approved DAG diverged:\n{g}");
            }
            Err(e) => {
                let ae = e
                    .downcast_ref::<AnalysisError>()
                    .unwrap_or_else(|| panic!("untyped rejection: {e:#}"));
                let fifo: Vec<_> =
                    ae.diagnostics.iter().filter(|d| d.code.starts_with("fifo.")).collect();
                assert!(!fifo.is_empty(), "rejection without a FIFO finding: {ae}");
                for d in fifo {
                    let (node, port) = d
                        .subject
                        .rsplit_once('.')
                        .unwrap_or_else(|| panic!("subject without edge: {}", d.subject));
                    assert!(
                        g.find(node).is_some(),
                        "diagnostic names a nonexistent node {node}:\n{g}"
                    );
                    assert!(port.starts_with("skip"), "not a skip edge: {}", d.subject);
                    if d.code == "fifo.undersized" {
                        assert!(d.min_safe_depth.is_some(), "{}: no safe depth", d.subject);
                    }
                }
            }
        }
    });
}

// --------------------------------------------------------------- batcher

#[test]
fn batcher_covers_all_queue_sizes_with_any_bucket_set() {
    forall("batcher coverage", 200, |rng| {
        let mut buckets = vec![1usize];
        let mut b = 1usize;
        for _ in 0..rng.below(4) {
            b *= [2usize, 4, 8][rng.below(3) as usize];
            buckets.push(b);
        }
        let batcher = Batcher::new(BatcherConfig { buckets, max_bucket: usize::MAX, ..Default::default() });
        let q = 1 + rng.below(300) as usize;
        let plans = batcher.plan(q);
        let total: usize = plans.iter().map(|p| p.take).sum();
        assert_eq!(total, q);
        for p in &plans {
            assert!(p.take <= p.bucket);
        }
        assert!(Batcher::efficiency(&plans) > 0.15);
    });
}

#[test]
fn batcher_plan_never_worse_than_pure_greedy() {
    // `plan()` may pad a remainder into a larger covering bucket, but only
    // when that is cheaper under the dispatch-overhead cost model — so its
    // total cost must never exceed the pure greedy largest-fit
    // decomposition (the policy `Engine::infer_any` used before the two
    // were unified).
    forall("plan cost <= greedy cost", 300, |rng| {
        let mut buckets = vec![1usize];
        let mut b = 1usize;
        for _ in 0..rng.below(4) {
            b *= [2usize, 3, 4, 8][rng.below(4) as usize];
            buckets.push(b);
        }
        let batcher =
            Batcher::new(BatcherConfig { buckets, max_bucket: usize::MAX, ..Default::default() });
        let q = 1 + rng.below(400) as usize;
        let plans = batcher.plan(q);
        assert_eq!(plans.iter().map(|p| p.take).sum::<usize>(), q);

        let cfg = batcher.config();
        let mut greedy_cost = 0usize;
        let mut left = q;
        while left > 0 {
            let b = cfg
                .buckets
                .iter()
                .rev()
                .find(|&&b| b <= left)
                .copied()
                .unwrap_or(cfg.buckets[0]);
            greedy_cost += b + cfg.dispatch_overhead;
            left -= b.min(left);
        }
        let cost = batcher.plan_cost(&plans);
        assert!(
            cost <= greedy_cost,
            "plan cost {cost} > greedy {greedy_cost} for q={q} buckets={:?}",
            cfg.buckets
        );
    });
}

// ------------------------------------------------- latency histogram laws

/// Upper bound of the histogram bucket a latency sample lands in.
fn bucket_bound(us: u64) -> u64 {
    BOUNDS_US[BOUNDS_US.iter().position(|&b| us <= b).unwrap_or(BOUNDS_US.len() - 1)]
}

#[test]
fn latency_percentiles_monotone_and_bucket_consistent() {
    // The snapshot's percentile readbacks are histogram-bucket upper
    // bounds, so for ANY sample set: p50 <= p95 <= p99 (monotone), each
    // is a real bucket bound from BOUNDS_US, the whole run is bracketed
    // by the min and max samples' buckets (p99 can legitimately exceed
    // the exact max — its bucket bound rounds up), and mean/max are
    // exact.  Degenerate shapes (empty, single sample) included.
    forall("latency percentile laws", 400, |rng| {
        let m = Metrics::new();
        let n = rng.below(48) as usize; // 0 = empty histogram
        let mut samples: Vec<u64> = Vec::with_capacity(n);
        for _ in 0..n {
            // Spread across the full bucket range, log-ish: a uniform
            // draw would almost never land in the sub-millisecond
            // buckets that serving latencies actually occupy.
            let exp = rng.below(7) as u32; // 10^0 .. 10^6 us
            let base = 10u64.pow(exp);
            let us = base + rng.range_i64(0, 9 * base as i64) as u64;
            m.record_latency(std::time::Duration::from_micros(us));
            samples.push(us);
        }
        let s = m.snapshot();
        if samples.is_empty() {
            assert_eq!((s.p50_le_us, s.p95_le_us, s.p99_le_us), (0, 0, 0));
            assert_eq!(s.max_latency_us, 0);
            assert_eq!(s.mean_latency_us, 0);
            return;
        }
        let min = *samples.iter().min().unwrap();
        let max = *samples.iter().max().unwrap();
        assert!(s.p50_le_us <= s.p95_le_us, "p50 {} > p95 {}", s.p50_le_us, s.p95_le_us);
        assert!(s.p95_le_us <= s.p99_le_us, "p95 {} > p99 {}", s.p95_le_us, s.p99_le_us);
        for p in [s.p50_le_us, s.p95_le_us, s.p99_le_us] {
            assert!(BOUNDS_US.contains(&p), "percentile {p} is not a bucket bound");
        }
        assert!(
            s.p50_le_us >= bucket_bound(min),
            "p50 {} below the smallest sample's bucket {}",
            s.p50_le_us,
            bucket_bound(min)
        );
        assert!(
            s.p99_le_us <= bucket_bound(max),
            "p99 {} beyond the largest sample's bucket {}",
            s.p99_le_us,
            bucket_bound(max)
        );
        assert_eq!(s.max_latency_us, max, "max must be exact, not bucketed");
        let mean = samples.iter().sum::<u64>() / samples.len() as u64;
        assert_eq!(s.mean_latency_us, mean, "integer mean must be exact");
        assert!(s.mean_latency_us <= max && s.mean_latency_us >= min / samples.len() as u64);
        if samples.len() == 1 {
            assert_eq!(s.p50_le_us, bucket_bound(max));
            assert_eq!(s.p99_le_us, bucket_bound(max));
            assert_eq!(s.mean_latency_us, max);
        }
    });
}

// ------------------------------------------------------------------ json

#[test]
fn json_roundtrip_fuzz() {
    forall("json roundtrip", 150, |rng| {
        let v = random_json(rng, 3);
        let text = v.to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(v, parsed, "text: {text}");
    });
}

fn random_json(rng: &mut Lcg64, depth: u32) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.below(2) == 0),
        2 => Json::Int(rng.range_i64(-1_000_000, 1_000_000)),
        3 => {
            let mut s = String::new();
            for _ in 0..rng.below(10) {
                s.push(match rng.below(5) {
                    0 => '"',
                    1 => '\\',
                    2 => '\n',
                    3 => 'é',
                    _ => (b'a' + (rng.below(26) as u8)) as char,
                });
            }
            Json::Str(s)
        }
        4 => Json::Array((0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect()),
        _ => {
            let mut m = std::collections::BTreeMap::new();
            for i in 0..rng.below(4) {
                m.insert(format!("k{i}"), random_json(rng, depth - 1));
            }
            Json::Object(m)
        }
    }
}

// ---------------------------------------------------------------- weights

#[test]
fn synthetic_weights_deterministic() {
    let arch = resnet_hls::models::arch_by_name("resnet20").unwrap();
    let a = synthetic_weights(&arch, 9);
    let b = synthetic_weights(&arch, 9);
    for name in arch.param_names() {
        assert_eq!(a.layer(&name).unwrap().w.data, b.layer(&name).unwrap().w.data);
    }
}
