//! Persistent stream-pool tests (the PR-3 tentpole): bit-exactness with
//! replicas and frames in flight, deterministic per-ticket delivery,
//! drain-on-drop shutdown under a loud watchdog, typed stall poisoning,
//! the naive-Add dataflow with Eq. 21 FIFOs (and its Fig. 14 deadlock as
//! a typed error), board/ILP-driven FIFO depths, the router's
//! stream-buffering gauges, and the elastic replica band (burst-driven
//! scale-up, idle drain to min, no-flap at the high-water mark, and
//! band-max bucket sizing; CI reruns the burst + drain coverage as the
//! STREAM_ELASTIC smoke), plus the PR-8 observability layer: per-stage
//! stall attribution naming the limiting conv on a deliberately
//! serialized pool, and bounded frame-span recording.

use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::{Duration, Instant};

use resnet_hls::coordinator::{Router, RouterConfig};
use resnet_hls::data::{synth_batch, IMG_ELEMS, TEST_SEED};
use resnet_hls::hls::streams::StreamKind;
use resnet_hls::hls::window::{skip_buffer_naive, skip_buffer_optimized};
use resnet_hls::models::{
    arch_by_name, build_optimized_graph, build_unoptimized_graph, synthetic_weights, ArchSpec,
    ConvSpec, ResidualSpec, Segment, SkipSpec,
};
use resnet_hls::quant::{QTensor, Shape4};
use resnet_hls::runtime::{
    BackendFactory, GoldenBackend, InferenceBackend, StreamBackend, StreamFactory,
};
use resnet_hls::sim::golden;
use resnet_hls::stream::{
    planned_config, run_streaming, ElasticConfig, ElasticPolicy, ScaleAction, StreamConfig,
    StreamPool, StreamStats, WindowStorage,
};

/// Run `f` on a helper thread and fail LOUDLY if it exceeds `secs` — a
/// pool-shutdown regression must hang this watchdog, not CI silently.
fn with_watchdog<F: FnOnce() + Send + 'static>(secs: u64, what: &str, f: F) {
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) => h.join().unwrap(),
        Err(RecvTimeoutError::Disconnected) => h.join().unwrap(), // propagate the panic
        Err(RecvTimeoutError::Timeout) => {
            panic!("{what}: exceeded the {secs}s watchdog (shutdown/drain regression)")
        }
    }
}

fn model(arch_name: &str, seed: u64) -> (resnet_hls::graph::Graph, resnet_hls::models::ModelWeights)
{
    let arch = arch_by_name(arch_name).unwrap();
    let weights = synthetic_weights(&arch, seed);
    let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    (g, weights)
}

#[test]
fn pool_bit_exact_with_replicas_and_frames_in_flight() {
    // Acceptance: >= 2 replicas, >= 3 frames in flight, both paper
    // architectures, bit-exact vs the golden model.
    for (arch_name, frames) in [("resnet8", 6usize), ("resnet20", 3)] {
        let (g, weights) = model(arch_name, 7);
        let (input, _) = synth_batch(0, frames, TEST_SEED);
        let want = golden::run(&g, &weights, &input).unwrap();

        let cfg = StreamConfig { replicas: 2, ..Default::default() };
        let pool = StreamPool::new(arch_name, &g, Arc::new(weights), cfg).unwrap();
        assert_eq!(pool.replicas(), 2);
        assert!(
            pool.capacity() >= frames,
            "{arch_name}: in-flight capacity {} below test batch {frames}",
            pool.capacity()
        );
        // Every frame enqueued before the first wait: the whole batch is
        // in flight across the two replicas simultaneously.
        let frame = input.shape.h * input.shape.w * input.shape.c;
        let tickets: Vec<_> = (0..frames)
            .map(|i| pool.submit(&input.data[i * frame..(i + 1) * frame]).unwrap())
            .collect();
        let mut got = Vec::new();
        for t in tickets {
            got.extend_from_slice(&t.wait().unwrap());
        }
        assert_eq!(got, want.data, "{arch_name}: pooled output diverged from golden");
        assert_eq!(pool.frames(), frames);
        let stats = pool.shutdown();
        assert_eq!(stats.frames, frames);
        assert!(
            stats.peak_buffered_elems() < stats.whole_tensor_elems,
            "{arch_name}: pooled peak {} must undercut replica-scaled whole-tensor {}",
            stats.peak_buffered_elems(),
            stats.whole_tensor_elems
        );
        // Replica 1's buffers are reported under the r1/ prefix.
        assert!(stats.buffers.iter().any(|b| b.name.starts_with("r1/")));
    }
}

#[test]
fn per_ticket_delivery_is_deterministic_under_cross_replica_completion() {
    // Results are bound to submission tickets, not to completion order:
    // waiting in *reverse* submit order across 3 replicas still yields
    // each frame's own golden logits.
    let (g, weights) = model("resnet8", 11);
    let frames = 8usize;
    let (input, _) = synth_batch(0, frames, TEST_SEED);
    let want = golden::run(&g, &weights, &input).unwrap();
    let classes = want.shape.c;

    let cfg = StreamConfig { replicas: 3, ..Default::default() };
    let pool = StreamPool::new("resnet8", &g, Arc::new(weights), cfg).unwrap();
    let frame = input.shape.h * input.shape.w * input.shape.c;
    let tickets: Vec<_> = (0..frames)
        .map(|i| pool.submit(&input.data[i * frame..(i + 1) * frame]).unwrap())
        .collect();
    let mut rows: Vec<Option<Vec<i32>>> = (0..frames).map(|_| None).collect();
    for (i, t) in tickets.into_iter().enumerate().rev() {
        rows[i] = Some(t.wait().unwrap());
    }
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(
            row.as_deref().unwrap(),
            &want.data[i * classes..(i + 1) * classes],
            "frame {i} got another frame's logits"
        );
    }
}

#[test]
fn dropped_pool_drains_frames_mid_pipeline_and_joins() {
    // Clean shutdown with frames mid-pipeline: dropping the pool must
    // finish every accepted frame (no lost responses) and join every
    // thread (the watchdog turns a leak/hang into a loud failure).
    with_watchdog(240, "pool drop with frames mid-pipeline", || {
        let (g, weights) = model("resnet8", 5);
        let frames = 4usize;
        let (input, _) = synth_batch(0, frames, TEST_SEED);
        let want = golden::run(&g, &weights, &input).unwrap();
        let classes = want.shape.c;

        let cfg = StreamConfig { replicas: 2, ..Default::default() };
        let pool = StreamPool::new("resnet8", &g, Arc::new(weights), cfg).unwrap();
        let frame = input.shape.h * input.shape.w * input.shape.c;
        let tickets: Vec<_> = (0..frames)
            .map(|i| pool.submit(&input.data[i * frame..(i + 1) * frame]).unwrap())
            .collect();
        // Drop immediately: the frames are still mid-pipeline.
        drop(pool);
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(
                t.wait().unwrap(),
                &want.data[i * classes..(i + 1) * classes],
                "frame {i} lost in shutdown"
            );
        }
    });
}

#[test]
fn stalled_pool_fails_typed_and_poisons_followups() {
    with_watchdog(120, "stalled pool unwind", || {
        let (g, weights) = model("resnet8", 7);
        let cfg = StreamConfig {
            progress_timeout: Duration::from_millis(250),
            skip_capacity_override: Some(4), // below one skip token
            // Reach past the static analyzer (which rejects this depth
            // outright) to exercise the runtime watchdog defense-in-depth.
            static_checks: false,
            ..Default::default()
        };
        let pool = StreamPool::new("resnet8", &g, Arc::new(weights), cfg).unwrap();
        let (input, _) = synth_batch(0, 1, TEST_SEED);
        let err = pool.infer(&input).unwrap_err();
        assert!(format!("{err:#}").contains("stalled"), "{err:#}");
        // The pool is poisoned: new submissions fail fast with the typed
        // error instead of queueing into a dead pipeline.
        let err2 = pool.submit(&input.data[..]).unwrap_err();
        assert!(format!("{err2:#}").contains("stalled"), "{err2:#}");
        assert!(pool.error().is_some());
    });
}

#[test]
fn naive_add_mode_matches_golden_with_eq21_fifos() {
    // ROADMAP item 5: the naive dataflow on the *executor* — explicit Add
    // stages, tee'd producers, raw accumulator streams — bit-exact at
    // Eq. 21 skip sizing.
    let arch = arch_by_name("resnet8").unwrap();
    let weights = synthetic_weights(&arch, 7);
    let g = build_unoptimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let (input, _) = synth_batch(0, 2, TEST_SEED);
    let want = golden::run(&g, &weights, &input).unwrap();

    // Without the flag, unoptimized graphs stay rejected.
    let err = run_streaming(&g, &weights, &input, &StreamConfig::default()).unwrap_err();
    assert!(format!("{err:#}").contains("optimized"), "{err:#}");

    let cfg = StreamConfig { naive_add: true, ..Default::default() };
    let (got, stats) = run_streaming(&g, &weights, &input, &cfg).unwrap();
    assert_eq!(want.data, got.data, "naive streaming diverged from golden");

    // One explicit Add skip FIFO per residual block, at exactly the
    // Eq. 21 receptive-field depth the configuration assigns.
    let acfg = planned_config("resnet8", &g, &cfg).unwrap();
    assert_eq!(acfg.adds.len(), arch.residuals().count());
    for a in acfg.adds.values() {
        let buf = stats
            .buffer(&format!("{}.skip", a.name))
            .unwrap_or_else(|| panic!("no stat for {}.skip", a.name));
        assert_eq!(buf.capacity, a.skip_fifo, "{}: capacity != Eq. 21 depth", a.name);
        assert!(buf.peak > 0, "{}: skip stream never used", a.name);
        assert!(buf.peak <= a.skip_fifo, "{}: peak beyond Eq. 21 depth", a.name);
    }
    let first = acfg.adds.values().find(|a| a.name == "s0b0_add").unwrap();
    assert_eq!(first.skip_fifo, skip_buffer_naive(3, 3, 32, 16, 3, 3));
}

#[test]
fn naive_add_undersized_skip_reproduces_fig14_deadlock_as_typed_stall() {
    // Halving the naive skip FIFOs toward the Eq. 22 optimized depth —
    // sound only after the graph transformations — wedges the tee'd
    // producer exactly as the paper's Fig. 14 describes.  On the
    // executor this must surface as a bounded-wait typed error.
    with_watchdog(120, "naive deadlock detection", || {
        let arch = arch_by_name("resnet8").unwrap();
        let weights = synthetic_weights(&arch, 7);
        let g = build_unoptimized_graph(&arch, &weights.act_exps, &weights.w_exps);
        let (input, _) = synth_batch(0, 1, TEST_SEED);
        let cfg = StreamConfig {
            naive_add: true,
            progress_timeout: Duration::from_millis(400),
            // Eq. 22-like sizing (~half of Eq. 21) on the naive dataflow.
            skip_capacity_override: Some(skip_buffer_optimized(3, 3, 32, 16)),
            // Reach past the static analyzer (tests/verify_analysis.rs
            // proves it flags exactly this config) to the runtime watchdog.
            static_checks: false,
            ..Default::default()
        };
        let t0 = Instant::now();
        let err = run_streaming(&g, &weights, &input, &cfg).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("stalled"), "expected a stall error, got: {msg}");
        assert!(
            t0.elapsed() < Duration::from_secs(60),
            "stall detection must be bounded, not a hang"
        );
    });
}

#[test]
fn fifo_depths_follow_board_ilp_config() {
    // ROADMAP item 3: the executor runs with exactly the depths codegen
    // emits — conv output FIFOs at their och_groups x och_par x ow_par
    // burst capacity, fused skips at configure's Eq. 22 spec.
    let (g, weights) = model("resnet8", 7);
    let cfg = StreamConfig::default();
    let (input, _) = synth_batch(0, 1, TEST_SEED);
    let (_, stats) = run_streaming(&g, &weights, &input, &cfg).unwrap();
    let acfg = planned_config("resnet8", &g, &cfg).unwrap();
    assert_eq!(acfg.ow_par, 2, "paper's packing default flows through");

    let mut conv_inputs = 0usize;
    for n in g.live() {
        let Some(lc) = acfg.convs.values().find(|l| l.name == n.name) else { continue };
        // Consumer of this conv's port-0 stream (single in the optimized
        // graph): its input FIFO must carry the configured burst.
        for m in g.live() {
            for (e, role) in &m.inputs {
                if e.node == n.id
                    && e.port == 0
                    && *role == resnet_hls::graph::InputRole::Data
                {
                    let buf = stats
                        .buffer(&format!("{}.in", m.name))
                        .unwrap_or_else(|| panic!("no stat for {}.in", m.name));
                    assert_eq!(
                        buf.capacity,
                        lc.out_stream.capacity(),
                        "{} -> {}: FIFO depth != configured output burst",
                        n.name,
                        m.name
                    );
                    conv_inputs += 1;
                }
            }
        }
        if let Some(skip) = &lc.skip_in {
            let buf = stats
                .buffer(&format!("{}.skip", lc.name))
                .unwrap_or_else(|| panic!("no stat for {}.skip", lc.name));
            assert_eq!(buf.capacity, skip.capacity(), "{}: skip != Eq. 22 spec", lc.name);
        }
    }
    assert!(conv_inputs >= 6, "expected the conv chain to be config-sized");
    // The ILP allocation actually shapes depths: at least one stream
    // holds more than a single och token (ow_par=2 bursts), which the
    // old fixed ow_par=1 policy never did.
    let widened = acfg
        .convs
        .values()
        .any(|l| l.out_stream.capacity() > l.och);
    assert!(widened, "config-driven depths should exceed the fixed one-burst policy");
}

#[test]
fn router_exports_stream_buffering_gauges() {
    // ROADMAP item 4: StreamStats reach the serving metrics as per-arch
    // snapshot gauges, aggregated across pool replicas.
    let factory: Arc<dyn BackendFactory> =
        Arc::new(StreamFactory::synthetic("resnet8", 7).with_replicas(2));
    let router = Router::start(vec![factory], RouterConfig::default()).unwrap();
    let (input, _) = synth_batch(0, 4, TEST_SEED);
    let pending: Vec<_> = (0..4)
        .map(|i| {
            router
                .submit("resnet8", input.data[i * IMG_ELEMS..(i + 1) * IMG_ELEMS].to_vec())
                .unwrap()
        })
        .collect();
    for rx in &pending {
        rx.recv().unwrap().unwrap();
    }
    let snap = router.shutdown();
    let m = &snap.per_arch["resnet8"];
    assert!(m.stream_peak_buffered_elems > 0, "gauge not exported");
    assert!(
        m.stream_buffered_fraction > 0.0 && m.stream_buffered_fraction < 1.0,
        "fraction {} out of range",
        m.stream_buffered_fraction
    );
    assert_eq!(snap.total.stream_peak_buffered_elems, m.stream_peak_buffered_elems);
}

#[test]
fn pool_throughput_smoke_32_frames() {
    // The bench's throughput scenario as a CI smoke: >= 32 frames through
    // a 2-replica pool, bit-exact, no timing assertions (the stream
    // backend bench measures; this guards the path).
    with_watchdog(300, "32-frame pooled throughput smoke", || {
        let cfg = StreamConfig { replicas: 2, ..Default::default() };
        let backend = StreamBackend::synthetic_with("resnet8", 7, &[32], cfg).unwrap();
        let golden_b = GoldenBackend::synthetic("resnet8", 7, &[32]).unwrap();
        let (input, _) = synth_batch(0, 32, TEST_SEED);
        let a = backend.infer_batch(&input).unwrap();
        let b = golden_b.infer_batch(&input).unwrap();
        assert_eq!(a.data, b.data, "pooled 32-frame batch must match golden");
        assert_eq!(backend.pool().frames(), 32);
        assert_eq!(backend.pool().replicas(), 2);
        let stats = backend.last_stats().expect("stats after serving");
        assert!(stats.peak_buffered_elems() < stats.whole_tensor_elems);
        // The cheap gauge pair agrees with the full named report.
        let (peak, whole) = backend.pool().buffered_gauges();
        assert_eq!(peak, stats.peak_buffered_elems());
        assert_eq!(whole, stats.whole_tensor_elems);
        assert_eq!(backend.stream_gauges(), Some((peak as u64, whole as u64)));
    });
}

// ------------------------------ slice-granular window buffers + ow_par

/// Summed peak occupancy of every window-buffer gauge in a report.
fn window_peak_total(stats: &StreamStats) -> usize {
    stats.of_kind(StreamKind::WindowSlice).map(|b| b.peak).sum()
}

#[test]
fn slice_granular_peaks_meet_eq16_17_and_beat_row_bound() {
    // The PR tentpole acceptance: in the (default) slice-granular mode,
    // every conv stage's measured peak window buffering is within the
    // exact Eq. 16/17 span (B_i plus the in-flight pixel) — strictly
    // below the old row-rounded bound — and skip peaks stay within the
    // Eq. 22 depths, on both paper architectures.
    for (arch_name, frames) in [("resnet8", 2usize), ("resnet20", 1)] {
        let (g, weights) = model(arch_name, 7);
        let (input, _) = synth_batch(0, frames, TEST_SEED);
        let cfg = StreamConfig::default();
        let (_, stats) = run_streaming(&g, &weights, &input, &cfg).unwrap();
        let acfg = planned_config(arch_name, &g, &cfg).unwrap();
        for lc in acfg.convs.values() {
            let buf = stats
                .buffer(&format!("{}.window", lc.name))
                .unwrap_or_else(|| panic!("{arch_name}: no stat for {}.window", lc.name));
            let span = lc.window_capacity + lc.ich;
            assert_eq!(buf.capacity, span, "{}: gauge bound != Eq. 16/17 span", lc.name);
            assert!(buf.peak > 0, "{}: window buffer never used", lc.name);
            assert!(
                buf.peak <= span,
                "{}: peak {} beyond the Eq. 16/17 span {span}",
                lc.name,
                buf.peak
            );
            let rows_bound =
                (if lc.merged_ds.is_some() { lc.k + 1 } else { lc.k }) * lc.iw * lc.ich;
            assert!(
                span < rows_bound,
                "{}: span {span} must undercut the row-rounded bound {rows_bound}",
                lc.name
            );
            if let Some(skip) = &lc.skip_in {
                let sbuf = stats
                    .buffer(&format!("{}.skip", lc.name))
                    .unwrap_or_else(|| panic!("{arch_name}: no stat for {}.skip", lc.name));
                assert!(
                    sbuf.peak <= skip.capacity(),
                    "{}: skip peak {} beyond Eq. 22 depth {}",
                    lc.name,
                    sbuf.peak,
                    skip.capacity()
                );
            }
        }
    }

    // Row-vs-slice measured delta (the relationship the stream_backend
    // bench reports): the legacy whole-row mode buffers strictly more.
    let (g, weights) = model("resnet8", 7);
    let (input, _) = synth_batch(0, 1, TEST_SEED);
    let want = golden::run(&g, &weights, &input).unwrap();
    let (slice_out, slice_stats) =
        run_streaming(&g, &weights, &input, &StreamConfig::default()).unwrap();
    let rows_cfg =
        StreamConfig { window_storage: WindowStorage::Rows, ..Default::default() };
    let (rows_out, rows_stats) = run_streaming(&g, &weights, &input, &rows_cfg).unwrap();
    assert_eq!(slice_out.data, want.data);
    assert_eq!(rows_out.data, want.data, "row storage mode must stay bit-exact too");
    assert!(
        window_peak_total(&slice_stats) < window_peak_total(&rows_stats),
        "slice-granular windows ({}) must buffer strictly less than rows ({})",
        window_peak_total(&slice_stats),
        window_peak_total(&rows_stats)
    );
}

#[test]
fn ow_par_sweep_bit_exact_with_slice_peaks() {
    // Acceptance: bit-exact vs golden for ow_par in {1, 2, 3} on both
    // architectures in slice-granular mode, window peaks within that
    // ow_par's exact Eq. 16/17 span.  ow_par = 3 on ResNet8 exercises
    // the 8-wide tail's remainder columns (8 % 3 = 2).  CI runs this
    // test once per value via STREAM_OW_PAR; unset, it sweeps all three.
    let sweep: Vec<usize> = match std::env::var("STREAM_OW_PAR") {
        Ok(v) => vec![v.parse().expect("STREAM_OW_PAR must be an integer")],
        Err(_) => vec![1, 2, 3],
    };
    for &ow_par in &sweep {
        for (arch_name, frames) in [("resnet8", 2usize), ("resnet20", 1)] {
            let (g, weights) = model(arch_name, 7);
            let (input, _) = synth_batch(0, frames, TEST_SEED);
            let want = golden::run(&g, &weights, &input).unwrap();
            let cfg = StreamConfig { ow_par, ..Default::default() };
            let (got, stats) = run_streaming(&g, &weights, &input, &cfg).unwrap();
            assert_eq!(
                want.data, got.data,
                "{arch_name} ow_par={ow_par}: diverged from golden"
            );
            let acfg = planned_config(arch_name, &g, &cfg).unwrap();
            assert_eq!(acfg.ow_par, ow_par);
            for lc in acfg.convs.values() {
                let buf = stats
                    .buffer(&format!("{}.window", lc.name))
                    .unwrap_or_else(|| panic!("no stat for {}.window", lc.name));
                assert_eq!(buf.capacity, lc.window_capacity + lc.ich);
                assert!(
                    buf.peak <= buf.capacity,
                    "{} ow_par={ow_par}: peak {} beyond span {}",
                    lc.name,
                    buf.peak,
                    buf.capacity
                );
            }
        }
    }
}

/// A deliberately odd-width net: 7-wide rows keep `ow % ow_par != 0` for
/// every swept ow_par, the strided stage lands on 4-wide rows (another
/// remainder for ow_par = 3), and the 4x4 tail satisfies the global
/// pool's power-of-two window.
fn odd_arch() -> ArchSpec {
    let conv = |name: &str, cin, cout, stride, relu, in_hw| ConvSpec {
        name: name.into(),
        cin,
        cout,
        k: 3,
        stride,
        pad: 1,
        relu,
        in_h: in_hw,
        in_w: in_hw,
    };
    ArchSpec {
        name: "odd7".into(),
        segments: vec![
            Segment::Conv(conv("stem", 3, 8, 1, true, 7)),
            Segment::Residual(ResidualSpec {
                name: "s0b0".into(),
                body: vec![conv("s0b0c0", 8, 8, 1, true, 7), conv("s0b0c1", 8, 8, 1, true, 7)],
                skips: vec![SkipSpec::identity()],
            }),
            Segment::Residual(ResidualSpec {
                name: "s1b0".into(),
                body: vec![conv("s1b0c0", 8, 16, 2, true, 7), conv("s1b0c1", 16, 16, 1, true, 4)],
                skips: vec![SkipSpec {
                    from: None,
                    proj: Some(ConvSpec {
                        name: "s1b0ds".into(),
                        cin: 8,
                        cout: 16,
                        k: 1,
                        stride: 2,
                        pad: 0,
                        relu: false,
                        in_h: 7,
                        in_w: 7,
                    }),
                }],
            }),
        ],
        fc_in: 16,
        fc_out: 10,
        in_h: 7,
        in_w: 7,
        in_c: 3,
        tied: std::collections::BTreeMap::new(),
    }
}

#[test]
fn odd_output_width_remainder_columns_bit_exact() {
    // Conv stages with ow % ow_par != 0 must neither drop nor duplicate
    // the tail window columns: a synthetic odd-output-width graph stays
    // bit-exact vs golden for every group width that leaves a remainder.
    let arch = odd_arch();
    let weights = synthetic_weights(&arch, 13);
    let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let frames = 2usize;
    let elems = frames * 7 * 7 * 3;
    let data: Vec<i32> = (0..elems).map(|i| ((i * 37 + 11) % 127) as i32 - 64).collect();
    let input = QTensor::from_vec(Shape4::new(frames, 7, 7, 3), -7, data);
    let want = golden::run(&g, &weights, &input).unwrap();
    for ow_par in [2usize, 3] {
        let cfg = StreamConfig { ow_par, ..Default::default() };
        let (got, _) = run_streaming(&g, &weights, &input, &cfg).unwrap();
        assert_eq!(
            want.data, got.data,
            "odd7 ow_par={ow_par}: remainder columns dropped or duplicated"
        );
    }
}

// ------------------------------------------------ elastic replica pool

/// Poll `cond` until it holds or `deadline` passes; returns whether it
/// ever held.
fn wait_until(deadline: Duration, mut cond: impl FnMut() -> bool) -> bool {
    let t0 = Instant::now();
    while t0.elapsed() < deadline {
        if cond() {
            return true;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    cond()
}

/// A fast-cadence elastic band for tests: scale up after ~4ms of
/// sustained burst, drain after ~50ms of full idleness.
fn test_elastic(min: usize, max: usize) -> ElasticConfig {
    ElasticConfig {
        min_replicas: min,
        max_replicas: max,
        high_water: Some(4),
        sample_interval: Duration::from_millis(2),
        scale_up_samples: 2,
        scale_down_samples: 25,
    }
}

#[test]
fn elastic_pool_grows_under_burst_and_drains_to_min_when_idle() {
    // The PR-5 tentpole acceptance: a burst deep enough to hold the
    // queue over the high-water mark grows the pool above min_replicas
    // (every frame still bit-exact vs golden, delivered per ticket in
    // submit order), and sustained idleness drains it back — with the
    // drained replicas' threads actually joined (replicas() only drops
    // after the join) under a loud watchdog.
    with_watchdog(600, "elastic burst + drain", || {
        let (g, weights) = model("resnet8", 7);
        // CI's STREAM_ELASTIC smoke runs the bigger burst.
        let frames: usize = if std::env::var("STREAM_ELASTIC").is_ok() { 64 } else { 40 };
        let (input, _) = synth_batch(0, frames, TEST_SEED);
        let want = golden::run(&g, &weights, &input).unwrap();

        let cfg = StreamConfig { elastic: Some(test_elastic(1, 3)), ..Default::default() };
        let pool = StreamPool::new("resnet8", &g, Arc::new(weights), cfg).unwrap();
        assert_eq!(pool.replicas(), 1, "elastic pool must start at min_replicas");
        assert_eq!((pool.min_replicas(), pool.max_replicas()), (1, 3));

        let tickets: Vec<_> = (0..frames)
            .map(|i| pool.submit(&input.data[i * IMG_ELEMS..(i + 1) * IMG_ELEMS]).unwrap())
            .collect();
        // The whole burst is queued, so the controller must grow the
        // pool while the frames drain through it.
        let grew = wait_until(Duration::from_secs(120), || pool.peak_replicas() >= 2);
        let mut got = Vec::new();
        for t in tickets {
            got.extend_from_slice(&t.wait().unwrap());
        }
        // Per-ticket delivery in submit order means bit-exact equality
        // on the concatenated rows.
        assert_eq!(got, want.data, "elastic pool diverged from golden");
        assert!(
            grew && pool.peak_replicas() >= 2,
            "pool never scaled above min under a {frames}-frame burst (peak {})",
            pool.peak_replicas()
        );

        // Fully idle now: the controller drains back to min_replicas,
        // joining each retired replica's threads (the replica gauge only
        // drops after the join completes).
        let drained = wait_until(Duration::from_secs(120), || pool.replicas() == 1);
        assert!(drained, "pool did not drain to min when idle (at {})", pool.replicas());
        assert_eq!(pool.frames(), frames);

        // The retired replicas' buffers stay in the final stats (r1/
        // prefix), and the whole-tensor base scales by the peak count.
        let peak_replicas = pool.peak_replicas();
        let stats = pool.shutdown();
        assert!(stats.buffers.iter().any(|b| b.name.starts_with("r1/")));
        assert_eq!(stats.frames, frames);
        assert!(stats.whole_tensor_elems > 0 && peak_replicas >= 2);
    });
}

#[test]
fn elastic_policy_holds_steady_at_the_high_water_mark() {
    // The no-flap acceptance: load sitting exactly AT the high-water
    // mark is steady state — no matter how long it sits there, the
    // policy neither grows nor drains, and it also resets any
    // in-progress streaks (so hovering around the mark cannot
    // accumulate into an action).
    let cfg = ElasticConfig {
        min_replicas: 1,
        max_replicas: 4,
        high_water: Some(8),
        scale_up_samples: 2,
        scale_down_samples: 3,
        ..Default::default()
    };
    let mut p = ElasticPolicy::new(&cfg, 99);
    assert_eq!(p.high_water(), 8);
    for _ in 0..1000 {
        assert_eq!(p.observe(8, 8, 2), None, "flapped at the high-water mark");
    }
    // One sample above the mark, then back at it: the up-streak resets.
    assert_eq!(p.observe(9, 9, 2), None);
    assert_eq!(p.observe(8, 8, 2), None);
    assert_eq!(p.observe(9, 9, 2), None, "streak must have reset at the mark");
    // Two idle samples, then the mark again: the idle streak resets too.
    assert_eq!(p.observe(0, 0, 2), None);
    assert_eq!(p.observe(0, 0, 2), None);
    assert_eq!(p.observe(8, 8, 2), None);
    assert_eq!(p.observe(0, 0, 2), None);
    assert_eq!(p.observe(0, 0, 2), None, "idle streak must have reset at the mark");
    // Sanity: sustained load strictly above the mark does scale up...
    assert_eq!(p.observe(9, 9, 2), None);
    assert_eq!(p.observe(9, 9, 2), Some(ScaleAction::Up));
    // ...and sustained full idleness does scale down.
    assert_eq!(p.observe(0, 0, 3), None);
    assert_eq!(p.observe(0, 0, 3), None);
    assert_eq!(p.observe(0, 0, 3), Some(ScaleAction::Down));
}

#[test]
fn elastic_router_exports_replica_gauge() {
    // The replica-count gauge reaches the serving metrics through
    // `InferenceBackend::replica_count`, and the router feeds its queue
    // depth back through `load_hint` (exercised here end to end; the
    // scaling transitions themselves are asserted pool-level above).
    let factory: Arc<dyn BackendFactory> =
        Arc::new(StreamFactory::synthetic("resnet8", 7).with_elastic(1, 2));
    let router = Router::start(vec![factory], RouterConfig::default()).unwrap();
    let (input, _) = synth_batch(0, 8, TEST_SEED);
    let pending: Vec<_> = (0..8)
        .map(|i| {
            router
                .submit("resnet8", input.data[i * IMG_ELEMS..(i + 1) * IMG_ELEMS].to_vec())
                .unwrap()
        })
        .collect();
    for rx in &pending {
        rx.recv().unwrap().unwrap();
    }
    let snap = router.shutdown();
    let m = &snap.per_arch["resnet8"];
    assert!(m.stream_replicas >= 1, "replica gauge not exported");
    assert!(m.stream_peak_replicas >= m.stream_replicas);
    assert_eq!(snap.total.stream_peak_replicas, m.stream_peak_replicas);
}

#[test]
fn elastic_buckets_size_to_band_max_capacity() {
    // Batcher buckets must be sized to the band *maximum* (not the live
    // replica count at construction), or the router would never hand an
    // elastic pool enough queued frames to justify growing.
    let ecfg = StreamConfig { elastic: Some(test_elastic(1, 2)), ..Default::default() };
    let e = StreamBackend::synthetic_with("resnet8", 7, &[], ecfg).unwrap();
    assert_eq!(e.pool().replicas(), 1);
    let fixed = StreamConfig { replicas: 2, ..Default::default() };
    let f = StreamBackend::synthetic_with("resnet8", 7, &[], fixed).unwrap();
    assert_eq!(e.pool().capacity(), f.pool().capacity());
    assert_eq!(e.buckets(), &[1, e.pool().capacity()]);
    assert_eq!(e.replica_count(), Some(1));
}

// ------------------------------------------ pipeline observability (PR 8)

#[test]
fn bottleneck_report_names_a_heavy_conv_on_a_serialized_pool() {
    // The tentpole acceptance: with every parallelism knob forced to 1
    // (inline channel/column workers, single window group, row-granular
    // buffers) the pipeline is compute-bound on a residual-block 3x3
    // conv — they carry >95% of the MACs, the stem/downsample/GAP/FC
    // are an order of magnitude lighter — so the stall attribution must
    // name one of them as the limiting stage, and any victim's starving
    // edge must be a real pipeline edge from the same report.
    with_watchdog(300, "bottleneck attribution", || {
        let (g, weights) = model("resnet8", 7);
        let frames = 48usize;
        let (input, _) = synth_batch(0, frames, TEST_SEED);
        let cfg = StreamConfig {
            replicas: 1,
            ow_par: 1,
            och_worker_cap: 1,
            ow_worker_cap: 1,
            window_storage: WindowStorage::Rows,
            ..Default::default()
        };
        let pool = StreamPool::new("resnet8", &g, Arc::new(weights), cfg).unwrap();
        let tickets: Vec<_> = (0..frames)
            .map(|i| pool.submit(&input.data[i * IMG_ELEMS..(i + 1) * IMG_ELEMS]).unwrap())
            .collect();
        for t in tickets {
            t.wait().unwrap();
        }
        let report = pool.stall_report();
        assert_eq!(report.frames, frames as u64);
        assert_eq!(report.replicas, 1);
        assert!(!report.edges.is_empty(), "no edge telemetry recorded");
        for s in &report.stages {
            assert!(s.elapsed_ns > 0, "{}: clock never ran", s.stage);
            assert!(
                s.busy_frac() + s.blocked_push_frac() + s.blocked_pop_frac() <= 1.01,
                "{}: time splits exceed wall time",
                s.stage
            );
        }
        let b = report.bottleneck();
        let lim = b.limiting.as_ref().expect("48 frames must yield a limiting stage");
        const CONVS: [&str; 6] = ["s0b0c0", "s0b0c1", "s1b0c0", "s1b0c1", "s2b0c0", "s2b0c1"];
        assert!(
            CONVS.contains(&lim.stage.as_str()),
            "limiting stage {:?} is not a residual-block conv\n{report}",
            lim.stage
        );
        // Limiting == busy-fraction argmax over the layer stages (the
        // feeder and sink never compete).
        for s in &report.stages {
            if s.role == resnet_hls::obs::StageRole::Stage {
                assert!(
                    s.busy_frac() <= lim.busy_frac() + 1e-9,
                    "{} busier than the named limiting stage {}",
                    s.stage,
                    lim.stage
                );
            }
        }
        if let Some(v) = &b.victim {
            if let Some(edge) = &v.edge {
                assert!(report.edge(edge).is_some(), "victim edge {edge} not in the report");
            }
        }
        // The human verdict names the limiting stage.
        assert!(b.to_string().contains(lim.stage.as_str()), "{b}");
    });
}

#[test]
fn frame_spans_are_recorded_with_ordered_marks() {
    // Span rings hold one bounded entry per recent frame: delivery never
    // precedes the feeder's claim, and the per-stage marks stamp in
    // pipeline order (nondecreasing microseconds on one shared epoch).
    let frames = 8usize;
    let cfg = StreamConfig { replicas: 1, ..Default::default() };
    let backend = StreamBackend::synthetic_with("resnet8", 7, &[frames], cfg).unwrap();
    let (input, _) = synth_batch(0, frames, TEST_SEED);
    backend.infer_batch(&input).unwrap();
    let mut spans = backend.pool().recent_spans();
    assert!(!spans.is_empty(), "span ring empty after {frames} frames");
    assert!(spans.len() <= frames, "more spans than frames served");
    spans.sort_by_key(|s| s.frame);
    for s in &spans {
        assert!(
            s.total_us >= s.queued_us,
            "frame {}: delivered ({} us) before it was claimed ({} us)",
            s.frame,
            s.total_us,
            s.queued_us
        );
        assert!(!s.marks_us.is_empty(), "frame {}: no boundary marks", s.frame);
        let mut prev = 0u64;
        for (thread, us) in &s.marks_us {
            assert!(
                *us >= prev,
                "frame {}: mark {thread} at {us} us precedes the previous boundary {prev}",
                s.frame
            );
            prev = *us;
        }
    }
}

#[test]
fn derived_buckets_track_inflight_capacity() {
    // An empty bucket list sizes the batcher to the pool: [1, capacity].
    let cfg = StreamConfig { replicas: 2, ..Default::default() };
    let backend = StreamBackend::synthetic_with("resnet8", 7, &[], cfg).unwrap();
    let cap = backend.pool().capacity();
    assert!(cap > 1);
    assert_eq!(backend.buckets(), &[1, cap]);
    // The capacity bucket exceeds the batcher policy's default
    // max_bucket cap (8, tuned for PJRT); the backend must tell the
    // router to lift the cap or the serve path would silently fall back
    // to single-frame dispatches (no frames in flight).
    assert!(cap > 8, "capacity bucket should exceed the default policy cap");
    assert_eq!(backend.preferred_max_bucket(), Some(cap));
}
