//! Persistent stream-pool tests (the PR-3 tentpole): bit-exactness with
//! replicas and frames in flight, deterministic per-ticket delivery,
//! drain-on-drop shutdown under a loud watchdog, typed stall poisoning,
//! the naive-Add dataflow with Eq. 21 FIFOs (and its Fig. 14 deadlock as
//! a typed error), board/ILP-driven FIFO depths, and the router's
//! stream-buffering gauges.

use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::{Duration, Instant};

use resnet_hls::coordinator::{Router, RouterConfig};
use resnet_hls::data::{synth_batch, IMG_ELEMS, TEST_SEED};
use resnet_hls::hls::window::{skip_buffer_naive, skip_buffer_optimized};
use resnet_hls::models::{
    arch_by_name, build_optimized_graph, build_unoptimized_graph, synthetic_weights,
};
use resnet_hls::runtime::{
    BackendFactory, GoldenBackend, InferenceBackend, StreamBackend, StreamFactory,
};
use resnet_hls::sim::golden;
use resnet_hls::stream::{planned_config, run_streaming, StreamConfig, StreamPool};

/// Run `f` on a helper thread and fail LOUDLY if it exceeds `secs` — a
/// pool-shutdown regression must hang this watchdog, not CI silently.
fn with_watchdog<F: FnOnce() + Send + 'static>(secs: u64, what: &str, f: F) {
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) => h.join().unwrap(),
        Err(RecvTimeoutError::Disconnected) => h.join().unwrap(), // propagate the panic
        Err(RecvTimeoutError::Timeout) => {
            panic!("{what}: exceeded the {secs}s watchdog (shutdown/drain regression)")
        }
    }
}

fn model(arch_name: &str, seed: u64) -> (resnet_hls::graph::Graph, resnet_hls::models::ModelWeights)
{
    let arch = arch_by_name(arch_name).unwrap();
    let weights = synthetic_weights(&arch, seed);
    let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    (g, weights)
}

#[test]
fn pool_bit_exact_with_replicas_and_frames_in_flight() {
    // Acceptance: >= 2 replicas, >= 3 frames in flight, both paper
    // architectures, bit-exact vs the golden model.
    for (arch_name, frames) in [("resnet8", 6usize), ("resnet20", 3)] {
        let (g, weights) = model(arch_name, 7);
        let (input, _) = synth_batch(0, frames, TEST_SEED);
        let want = golden::run(&g, &weights, &input).unwrap();

        let cfg = StreamConfig { replicas: 2, ..Default::default() };
        let pool = StreamPool::new(arch_name, &g, Arc::new(weights), cfg).unwrap();
        assert_eq!(pool.replicas(), 2);
        assert!(
            pool.capacity() >= frames,
            "{arch_name}: in-flight capacity {} below test batch {frames}",
            pool.capacity()
        );
        // Every frame enqueued before the first wait: the whole batch is
        // in flight across the two replicas simultaneously.
        let frame = input.shape.h * input.shape.w * input.shape.c;
        let tickets: Vec<_> = (0..frames)
            .map(|i| pool.submit(&input.data[i * frame..(i + 1) * frame]).unwrap())
            .collect();
        let mut got = Vec::new();
        for t in tickets {
            got.extend_from_slice(&t.wait().unwrap());
        }
        assert_eq!(got, want.data, "{arch_name}: pooled output diverged from golden");
        assert_eq!(pool.frames(), frames);
        let stats = pool.shutdown();
        assert_eq!(stats.frames, frames);
        assert!(
            stats.peak_buffered_elems() < stats.whole_tensor_elems,
            "{arch_name}: pooled peak {} must undercut replica-scaled whole-tensor {}",
            stats.peak_buffered_elems(),
            stats.whole_tensor_elems
        );
        // Replica 1's buffers are reported under the r1/ prefix.
        assert!(stats.buffers.iter().any(|b| b.name.starts_with("r1/")));
    }
}

#[test]
fn per_ticket_delivery_is_deterministic_under_cross_replica_completion() {
    // Results are bound to submission tickets, not to completion order:
    // waiting in *reverse* submit order across 3 replicas still yields
    // each frame's own golden logits.
    let (g, weights) = model("resnet8", 11);
    let frames = 8usize;
    let (input, _) = synth_batch(0, frames, TEST_SEED);
    let want = golden::run(&g, &weights, &input).unwrap();
    let classes = want.shape.c;

    let cfg = StreamConfig { replicas: 3, ..Default::default() };
    let pool = StreamPool::new("resnet8", &g, Arc::new(weights), cfg).unwrap();
    let frame = input.shape.h * input.shape.w * input.shape.c;
    let tickets: Vec<_> = (0..frames)
        .map(|i| pool.submit(&input.data[i * frame..(i + 1) * frame]).unwrap())
        .collect();
    let mut rows: Vec<Option<Vec<i32>>> = (0..frames).map(|_| None).collect();
    for (i, t) in tickets.into_iter().enumerate().rev() {
        rows[i] = Some(t.wait().unwrap());
    }
    for (i, row) in rows.iter().enumerate() {
        assert_eq!(
            row.as_deref().unwrap(),
            &want.data[i * classes..(i + 1) * classes],
            "frame {i} got another frame's logits"
        );
    }
}

#[test]
fn dropped_pool_drains_frames_mid_pipeline_and_joins() {
    // Clean shutdown with frames mid-pipeline: dropping the pool must
    // finish every accepted frame (no lost responses) and join every
    // thread (the watchdog turns a leak/hang into a loud failure).
    with_watchdog(240, "pool drop with frames mid-pipeline", || {
        let (g, weights) = model("resnet8", 5);
        let frames = 4usize;
        let (input, _) = synth_batch(0, frames, TEST_SEED);
        let want = golden::run(&g, &weights, &input).unwrap();
        let classes = want.shape.c;

        let cfg = StreamConfig { replicas: 2, ..Default::default() };
        let pool = StreamPool::new("resnet8", &g, Arc::new(weights), cfg).unwrap();
        let frame = input.shape.h * input.shape.w * input.shape.c;
        let tickets: Vec<_> = (0..frames)
            .map(|i| pool.submit(&input.data[i * frame..(i + 1) * frame]).unwrap())
            .collect();
        // Drop immediately: the frames are still mid-pipeline.
        drop(pool);
        for (i, t) in tickets.into_iter().enumerate() {
            assert_eq!(
                t.wait().unwrap(),
                &want.data[i * classes..(i + 1) * classes],
                "frame {i} lost in shutdown"
            );
        }
    });
}

#[test]
fn stalled_pool_fails_typed_and_poisons_followups() {
    with_watchdog(120, "stalled pool unwind", || {
        let (g, weights) = model("resnet8", 7);
        let cfg = StreamConfig {
            progress_timeout: Duration::from_millis(250),
            skip_capacity_override: Some(4), // below one skip token
            ..Default::default()
        };
        let pool = StreamPool::new("resnet8", &g, Arc::new(weights), cfg).unwrap();
        let (input, _) = synth_batch(0, 1, TEST_SEED);
        let err = pool.infer(&input).unwrap_err();
        assert!(format!("{err:#}").contains("stalled"), "{err:#}");
        // The pool is poisoned: new submissions fail fast with the typed
        // error instead of queueing into a dead pipeline.
        let err2 = pool.submit(&input.data[..]).unwrap_err();
        assert!(format!("{err2:#}").contains("stalled"), "{err2:#}");
        assert!(pool.error().is_some());
    });
}

#[test]
fn naive_add_mode_matches_golden_with_eq21_fifos() {
    // ROADMAP item 5: the naive dataflow on the *executor* — explicit Add
    // stages, tee'd producers, raw accumulator streams — bit-exact at
    // Eq. 21 skip sizing.
    let arch = arch_by_name("resnet8").unwrap();
    let weights = synthetic_weights(&arch, 7);
    let g = build_unoptimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let (input, _) = synth_batch(0, 2, TEST_SEED);
    let want = golden::run(&g, &weights, &input).unwrap();

    // Without the flag, unoptimized graphs stay rejected.
    let err = run_streaming(&g, &weights, &input, &StreamConfig::default()).unwrap_err();
    assert!(format!("{err:#}").contains("optimized"), "{err:#}");

    let cfg = StreamConfig { naive_add: true, ..Default::default() };
    let (got, stats) = run_streaming(&g, &weights, &input, &cfg).unwrap();
    assert_eq!(want.data, got.data, "naive streaming diverged from golden");

    // One explicit Add skip FIFO per residual block, at exactly the
    // Eq. 21 receptive-field depth the configuration assigns.
    let acfg = planned_config("resnet8", &g, &cfg).unwrap();
    assert_eq!(acfg.adds.len(), arch.blocks.len());
    for a in acfg.adds.values() {
        let buf = stats
            .buffer(&format!("{}.skip", a.name))
            .unwrap_or_else(|| panic!("no stat for {}.skip", a.name));
        assert_eq!(buf.capacity, a.skip_fifo, "{}: capacity != Eq. 21 depth", a.name);
        assert!(buf.peak > 0, "{}: skip stream never used", a.name);
        assert!(buf.peak <= a.skip_fifo, "{}: peak beyond Eq. 21 depth", a.name);
    }
    let first = acfg.adds.values().find(|a| a.name == "s0b0_add").unwrap();
    assert_eq!(first.skip_fifo, skip_buffer_naive(3, 3, 32, 16, 3, 3));
}

#[test]
fn naive_add_undersized_skip_reproduces_fig14_deadlock_as_typed_stall() {
    // Halving the naive skip FIFOs toward the Eq. 22 optimized depth —
    // sound only after the graph transformations — wedges the tee'd
    // producer exactly as the paper's Fig. 14 describes.  On the
    // executor this must surface as a bounded-wait typed error.
    with_watchdog(120, "naive deadlock detection", || {
        let arch = arch_by_name("resnet8").unwrap();
        let weights = synthetic_weights(&arch, 7);
        let g = build_unoptimized_graph(&arch, &weights.act_exps, &weights.w_exps);
        let (input, _) = synth_batch(0, 1, TEST_SEED);
        let cfg = StreamConfig {
            naive_add: true,
            progress_timeout: Duration::from_millis(400),
            // Eq. 22-like sizing (~half of Eq. 21) on the naive dataflow.
            skip_capacity_override: Some(skip_buffer_optimized(3, 3, 32, 16)),
            ..Default::default()
        };
        let t0 = Instant::now();
        let err = run_streaming(&g, &weights, &input, &cfg).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("stalled"), "expected a stall error, got: {msg}");
        assert!(
            t0.elapsed() < Duration::from_secs(60),
            "stall detection must be bounded, not a hang"
        );
    });
}

#[test]
fn fifo_depths_follow_board_ilp_config() {
    // ROADMAP item 3: the executor runs with exactly the depths codegen
    // emits — conv output FIFOs at their och_groups x och_par x ow_par
    // burst capacity, fused skips at configure's Eq. 22 spec.
    let (g, weights) = model("resnet8", 7);
    let cfg = StreamConfig::default();
    let (input, _) = synth_batch(0, 1, TEST_SEED);
    let (_, stats) = run_streaming(&g, &weights, &input, &cfg).unwrap();
    let acfg = planned_config("resnet8", &g, &cfg).unwrap();
    assert_eq!(acfg.ow_par, 2, "paper's packing default flows through");

    let mut conv_inputs = 0usize;
    for n in g.live() {
        let Some(lc) = acfg.convs.values().find(|l| l.name == n.name) else { continue };
        // Consumer of this conv's port-0 stream (single in the optimized
        // graph): its input FIFO must carry the configured burst.
        for m in g.live() {
            for (e, role) in &m.inputs {
                if e.node == n.id
                    && e.port == 0
                    && *role == resnet_hls::graph::InputRole::Data
                {
                    let buf = stats
                        .buffer(&format!("{}.in", m.name))
                        .unwrap_or_else(|| panic!("no stat for {}.in", m.name));
                    assert_eq!(
                        buf.capacity,
                        lc.out_stream.capacity(),
                        "{} -> {}: FIFO depth != configured output burst",
                        n.name,
                        m.name
                    );
                    conv_inputs += 1;
                }
            }
        }
        if let Some(skip) = &lc.skip_in {
            let buf = stats
                .buffer(&format!("{}.skip", lc.name))
                .unwrap_or_else(|| panic!("no stat for {}.skip", lc.name));
            assert_eq!(buf.capacity, skip.capacity(), "{}: skip != Eq. 22 spec", lc.name);
        }
    }
    assert!(conv_inputs >= 6, "expected the conv chain to be config-sized");
    // The ILP allocation actually shapes depths: at least one stream
    // holds more than a single och token (ow_par=2 bursts), which the
    // old fixed ow_par=1 policy never did.
    let widened = acfg
        .convs
        .values()
        .any(|l| l.out_stream.capacity() > l.och);
    assert!(widened, "config-driven depths should exceed the fixed one-burst policy");
}

#[test]
fn router_exports_stream_buffering_gauges() {
    // ROADMAP item 4: StreamStats reach the serving metrics as per-arch
    // snapshot gauges, aggregated across pool replicas.
    let factory: Arc<dyn BackendFactory> =
        Arc::new(StreamFactory::synthetic("resnet8", 7).with_replicas(2));
    let router = Router::start(vec![factory], RouterConfig::default()).unwrap();
    let (input, _) = synth_batch(0, 4, TEST_SEED);
    let pending: Vec<_> = (0..4)
        .map(|i| {
            router
                .submit("resnet8", input.data[i * IMG_ELEMS..(i + 1) * IMG_ELEMS].to_vec())
                .unwrap()
        })
        .collect();
    for rx in &pending {
        rx.recv().unwrap().unwrap();
    }
    let snap = router.shutdown();
    let m = &snap.per_arch["resnet8"];
    assert!(m.stream_peak_buffered_elems > 0, "gauge not exported");
    assert!(
        m.stream_buffered_fraction > 0.0 && m.stream_buffered_fraction < 1.0,
        "fraction {} out of range",
        m.stream_buffered_fraction
    );
    assert_eq!(snap.total.stream_peak_buffered_elems, m.stream_peak_buffered_elems);
}

#[test]
fn pool_throughput_smoke_32_frames() {
    // The bench's throughput scenario as a CI smoke: >= 32 frames through
    // a 2-replica pool, bit-exact, no timing assertions (the stream
    // backend bench measures; this guards the path).
    with_watchdog(300, "32-frame pooled throughput smoke", || {
        let cfg = StreamConfig { replicas: 2, ..Default::default() };
        let backend = StreamBackend::synthetic_with("resnet8", 7, &[32], cfg).unwrap();
        let golden_b = GoldenBackend::synthetic("resnet8", 7, &[32]).unwrap();
        let (input, _) = synth_batch(0, 32, TEST_SEED);
        let a = backend.infer_batch(&input).unwrap();
        let b = golden_b.infer_batch(&input).unwrap();
        assert_eq!(a.data, b.data, "pooled 32-frame batch must match golden");
        assert_eq!(backend.pool().frames(), 32);
        assert_eq!(backend.pool().replicas(), 2);
        let stats = backend.last_stats().expect("stats after serving");
        assert!(stats.peak_buffered_elems() < stats.whole_tensor_elems);
        // The cheap gauge pair agrees with the full named report.
        let (peak, whole) = backend.pool().buffered_gauges();
        assert_eq!(peak, stats.peak_buffered_elems());
        assert_eq!(whole, stats.whole_tensor_elems);
        assert_eq!(backend.stream_gauges(), Some((peak as u64, whole as u64)));
    });
}

#[test]
fn derived_buckets_track_inflight_capacity() {
    // An empty bucket list sizes the batcher to the pool: [1, capacity].
    let cfg = StreamConfig { replicas: 2, ..Default::default() };
    let backend = StreamBackend::synthetic_with("resnet8", 7, &[], cfg).unwrap();
    let cap = backend.pool().capacity();
    assert!(cap > 1);
    assert_eq!(backend.buckets(), &[1, cap]);
    // The capacity bucket exceeds the batcher policy's default
    // max_bucket cap (8, tuned for PJRT); the backend must tell the
    // router to lift the cap or the serve path would silently fall back
    // to single-frame dispatches (no frames in flight).
    assert!(cap > 8, "capacity bucket should exceed the default policy cap");
    assert_eq!(backend.preferred_max_bucket(), Some(cap));
}
