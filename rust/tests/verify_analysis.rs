//! Static-verifier acceptance tests (the ISSUE 7 tentpole): the
//! analyzer must approve every stock board/ILP configuration the
//! executor actually runs bit-exact, statically flag the paper's
//! Fig. 14 undersized-skip-FIFO configuration by edge name with its
//! minimum safe depth, and make `plan_pipeline` refuse provably
//! deadlocking configs with a typed [`AnalysisError`] before a single
//! stage thread spawns.  The agreement property ties the two worlds
//! together: configurations the verifier flags really do stall at
//! runtime (reached via `static_checks: false`), and configurations it
//! approves really do run.

use std::sync::mpsc::RecvTimeoutError;
use std::time::{Duration, Instant};

use resnet_hls::analysis::{self, AnalysisError, Severity};
use resnet_hls::data::{synth_batch, TEST_SEED};
use resnet_hls::graph::qonnx;
use resnet_hls::hls::window::{skip_buffer_naive, skip_buffer_optimized};
use resnet_hls::models::{
    arch_by_name, build_optimized_graph, build_unoptimized_graph, synthetic_weights,
};
use resnet_hls::sim::golden;
use resnet_hls::stream::{planned_config, run_streaming, StreamConfig};
use resnet_hls::util::Json;

/// Run `f` on a helper thread and fail LOUDLY if it exceeds `secs`.
fn with_watchdog<F: FnOnce() + Send + 'static>(secs: u64, what: &str, f: F) {
    let (tx, rx) = std::sync::mpsc::channel();
    let h = std::thread::spawn(move || {
        f();
        let _ = tx.send(());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(()) => h.join().unwrap(),
        Err(RecvTimeoutError::Disconnected) => h.join().unwrap(),
        Err(RecvTimeoutError::Timeout) => panic!("{what}: exceeded the {secs}s watchdog"),
    }
}

/// The Fig. 14 reproduction config: the naive dataflow with its Eq. 21
/// skip FIFOs forced down to the Eq. 22 optimized depth — sound only
/// after the graph transformations, provably deadlocking without them.
fn fig14_cfg() -> StreamConfig {
    StreamConfig {
        naive_add: true,
        skip_capacity_override: Some(skip_buffer_optimized(3, 3, 32, 16)),
        progress_timeout: Duration::from_millis(400),
        ..Default::default()
    }
}

#[test]
fn stock_configs_are_approved_and_run_bit_exact() {
    // Approve direction of the agreement property: everything the
    // verifier passes must actually execute, bit-exact vs golden.
    for arch_name in ["resnet8", "resnet20"] {
        let arch = arch_by_name(arch_name).unwrap();
        let weights = synthetic_weights(&arch, 7);
        let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
        let cfg = StreamConfig::default();
        let acfg = planned_config(arch_name, &g, &cfg).unwrap();

        let report = analysis::verify(&g, Some(&weights), &cfg, &acfg).unwrap();
        assert!(
            report.ok(),
            "{arch_name}: stock config rejected:\n{report}"
        );
        assert_eq!(report.count(Severity::Error), 0);
        // Every pass actually looked: fifo, window and range checks all
        // left passed-check evidence.
        for code in ["fifo.ok", "window.ok", "range.ok"] {
            assert!(
                report.diagnostics.iter().any(|d| d.code == code),
                "{arch_name}: no {code} diagnostic in report"
            );
        }

        let (input, _) = synth_batch(0, 1, TEST_SEED);
        let want = golden::run(&g, &weights, &input).unwrap();
        let (got, _) = run_streaming(&g, &weights, &input, &cfg).unwrap();
        assert_eq!(got.data, want.data, "{arch_name}: approved config diverged from golden");
    }
}

#[test]
fn general_topology_stock_configs_verify_and_run() {
    // ISSUE 10 acceptance: the long-skip/multi-add net and the
    // weight-tied net go through `repro verify`'s exact call sequence —
    // planned config, full report — and the approved configs execute
    // bit-exact, including skipnet's optimized form (which keeps its
    // 3-operand add as a naive Eq. 21 island) and longskipnet's
    // 2-operand long-skip merge (naive island at the full-frame bound —
    // the shape add fusion must refuse).
    for arch_name in ["skipnet", "longskipnet", "tiednet"] {
        let arch = arch_by_name(arch_name).unwrap();
        let weights = synthetic_weights(&arch, 7);
        let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
        let cfg = StreamConfig::default();
        let acfg = planned_config(arch_name, &g, &cfg).unwrap();

        let report = analysis::verify(&g, Some(&weights), &cfg, &acfg).unwrap();
        assert!(report.ok(), "{arch_name}: stock config rejected:\n{report}");
        assert_eq!(report.count(Severity::Error), 0);

        let (input, _) = synth_batch(0, 1, TEST_SEED);
        let want = golden::run(&g, &weights, &input).unwrap();
        let (got, _) = run_streaming(&g, &weights, &input, &cfg).unwrap();
        assert_eq!(got.data, want.data, "{arch_name}: approved config diverged from golden");
    }
}

#[test]
fn undersized_long_skip_is_rejected_with_the_edge_named() {
    // The long-skip acceptance criterion: skipnet's r1 merge takes a
    // skip reaching back to the stem, whose sound capacity is the full
    // 32x32x16 frame (Eq. 21 only bounds block-local skips).  Forcing
    // every skip FIFO to the block-local Eq. 21 depth starves exactly
    // that edge; the verifier must name it, with the full-frame bound
    // as the minimum safe depth, and `plan_pipeline` must refuse the
    // config with the same typed diagnostic before any thread spawns.
    let arch = arch_by_name("skipnet").unwrap();
    let weights = synthetic_weights(&arch, 7);
    let g = build_unoptimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let cfg = StreamConfig {
        naive_add: true,
        skip_capacity_override: Some(skip_buffer_naive(3, 3, 32, 16, 3, 3)),
        progress_timeout: Duration::from_millis(400),
        ..Default::default()
    };
    let acfg = planned_config("skipnet", &g, &cfg).unwrap();

    let report = analysis::verify(&g, Some(&weights), &cfg, &acfg).unwrap();
    assert!(!report.ok(), "undersized long skip must be rejected:\n{report}");
    let d = report
        .find("fifo.undersized", "r1_add.skip2")
        .expect("the starved long-skip edge must be named exactly");
    assert_eq!(d.severity, Severity::Error);
    assert_eq!(d.min_safe_depth, Some(32 * 32 * 16), "full-frame bound for a non-local skip");
    // The block-local operands at the same depth stay approved — the
    // rejection is per-edge, not per-node.
    assert!(report.find("fifo.undersized", "r1_add.skip").is_none());

    let (input, _) = synth_batch(0, 1, TEST_SEED);
    let t0 = Instant::now();
    let err = run_streaming(&g, &weights, &input, &cfg).unwrap_err();
    assert!(t0.elapsed() < Duration::from_secs(5), "rejection must be static, not a stall");
    let analysis_err = err
        .downcast_ref::<AnalysisError>()
        .unwrap_or_else(|| panic!("expected AnalysisError, got: {err:#}"));
    assert!(
        analysis_err.diagnostics.iter().any(|d| d.subject == "r1_add.skip2"),
        "rejection must carry the starved edge: {analysis_err}"
    );
}

#[test]
fn fig14_config_is_flagged_with_edge_name_and_min_safe_depth() {
    let arch = arch_by_name("resnet8").unwrap();
    let weights = synthetic_weights(&arch, 7);
    let g = build_unoptimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let cfg = fig14_cfg();
    let acfg = planned_config("resnet8", &g, &cfg).unwrap();

    let report = analysis::verify(&g, Some(&weights), &cfg, &acfg).unwrap();
    assert!(!report.ok(), "Fig. 14 config must be rejected:\n{report}");
    let d = report
        .find("fifo.undersized", "s0b0_add.skip")
        .expect("the undersized edge must be named exactly");
    assert_eq!(d.severity, Severity::Error);
    assert_eq!(d.measured, Some(skip_buffer_optimized(3, 3, 32, 16) as i64));
    assert_eq!(d.min_safe_depth, Some(skip_buffer_naive(3, 3, 32, 16, 3, 3)));
    // The JSON rendering carries the same machine-readable fields the
    // README documents.
    let j = report.to_json();
    assert_eq!(j.at("status").and_then(|s| s.as_str()), Some("rejected"));
}

#[test]
fn plan_rejects_deadlocking_config_before_any_thread_spawns() {
    // `static_checks` defaults on: the pool must refuse the Fig. 14
    // config with a typed, downcastable error — immediately, not after
    // burning a progress timeout on spinning stage threads.
    let arch = arch_by_name("resnet8").unwrap();
    let weights = synthetic_weights(&arch, 7);
    let g = build_unoptimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let (input, _) = synth_batch(0, 1, TEST_SEED);

    let t0 = Instant::now();
    let err = run_streaming(&g, &weights, &input, &fig14_cfg()).unwrap_err();
    assert!(
        t0.elapsed() < Duration::from_secs(5),
        "static rejection must not wait out a runtime stall"
    );
    let analysis_err = err
        .downcast_ref::<AnalysisError>()
        .unwrap_or_else(|| panic!("expected AnalysisError, got: {err:#}"));
    assert!(
        analysis_err.diagnostics.iter().any(|d| d.subject == "s0b0_add.skip"),
        "rejection must carry the undersized edge: {analysis_err}"
    );
    assert!(
        analysis_err
            .diagnostics
            .iter()
            .all(|d| d.min_safe_depth.is_some() || d.code != "fifo.undersized"),
        "undersized findings must carry the minimum safe depth"
    );
}

#[test]
fn flagged_configs_really_stall_at_runtime() {
    // Flag direction of the agreement property: a config the verifier
    // rejects, executed anyway via the `static_checks: false` escape
    // hatch, must produce the runtime `Stalled` watchdog error — the
    // static diagnostic and the dynamic behavior agree.
    with_watchdog(120, "agreement stall direction", || {
        let arch = arch_by_name("resnet8").unwrap();
        let weights = synthetic_weights(&arch, 7);
        let g = build_unoptimized_graph(&arch, &weights.act_exps, &weights.w_exps);
        let (input, _) = synth_batch(0, 1, TEST_SEED);
        let bound = skip_buffer_naive(3, 3, 32, 16, 3, 3);
        for cap in [bound / 2, bound / 4] {
            let mut cfg = fig14_cfg();
            cfg.skip_capacity_override = Some(cap);
            let acfg = planned_config("resnet8", &g, &cfg).unwrap();
            let report = analysis::verify(&g, Some(&weights), &cfg, &acfg).unwrap();
            assert!(!report.ok(), "cap {cap}: verifier must flag this config");

            cfg.static_checks = false;
            let err = run_streaming(&g, &weights, &input, &cfg).unwrap_err();
            let msg = format!("{err:#}");
            assert!(
                msg.contains("stalled"),
                "cap {cap}: flagged config must stall at runtime, got: {msg}"
            );
        }
    });
}

#[test]
fn imported_qonnx_graph_verifies_weightless() {
    // The `repro verify --qonnx` path: a round-tripped export carries
    // no weight blobs, so the range pass falls back to dtype worst
    // cases — and the stock architecture still verifies clean.
    let arch = arch_by_name("resnet8").unwrap();
    let weights = synthetic_weights(&arch, 7);
    let g0 = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let text = qonnx::export(&g0).to_string();
    let g = qonnx::import(&Json::parse(&text).unwrap()).unwrap();

    let cfg = StreamConfig::default();
    let acfg = planned_config("qonnx-import", &g, &cfg).unwrap();
    let report = analysis::verify(&g, None, &cfg, &acfg).unwrap();
    assert!(report.ok(), "imported stock graph rejected:\n{report}");
    assert!(
        report
            .diagnostics
            .iter()
            .any(|d| d.code == "range.ok" && d.message.contains("dtype worst case")),
        "weightless import must use the dtype fallback"
    );
}

#[test]
fn malformed_qonnx_documents_fail_typed_not_abort() {
    // Corpus mirror of the unit tests, at the exact call sequence the
    // CLI uses: parse -> import -> (never reached) verify.
    for text in [
        "",
        "{",
        r#"{"graph":{"nodes":[{"name":"c","op_type":"QConv","inputs":[],
            "attributes":{"cin":3,"cout":4,"kernel":3,"stride":0,"pad":1}}]}}"#,
        r#"{"graph":{"nodes":[{"name":"x","op_type":"Relu","inputs":[],"attributes":{}},
            {"name":"x","op_type":"Relu","inputs":[],"attributes":{}}]}}"#,
    ] {
        match Json::parse(text) {
            Err(_) => {} // typed parse failure is the expected path
            Ok(doc) => {
                assert!(qonnx::import(&doc).is_err(), "import must reject: {text}");
            }
        }
    }
}
