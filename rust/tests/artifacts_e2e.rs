//! End-to-end tests over the built artifacts (`make artifacts` first):
//! the cross-language bit-equality chain and the serving path.
//!
//! These are the strongest correctness signals in the repo:
//!   synthetic dataset:  Rust generator == Python generator  (bytes)
//!   golden model:       Rust integer inference == jnp oracle (logits)
//!   PJRT runtime:       AOT HLO executed via the xla crate == oracle
//!   passes:             optimized graph == naive graph       (logits)
//!   server:             batched serving returns the same classes

use resnet_hls::coordinator::{BatcherConfig, Router, RouterConfig};
#[allow(deprecated)]
use resnet_hls::coordinator::InferenceServer;
use resnet_hls::data::{synth_batch, TEST_SEED};
use resnet_hls::models::{arch_by_name, build_optimized_graph, build_unoptimized_graph, ModelWeights};
use resnet_hls::paths::artifacts_dir;
use resnet_hls::runtime::{Artifacts, BackendFactory, Engine, PjrtFactory};
use resnet_hls::sim::golden;
use std::sync::Arc;

/// These tests verify the built artifacts; without them they *skip*
/// (the artifact-free serving-path coverage lives in `integration.rs`).
fn require_artifacts() -> Option<Artifacts> {
    let dir = artifacts_dir();
    match Artifacts::load(&dir) {
        Ok(a) => Some(a),
        Err(e) => {
            eprintln!("skipping: artifacts not built ({e:#})");
            None
        }
    }
}

#[test]
fn dataset_bit_equality() {
    let Some(artifacts) = require_artifacts() else { return };
    let probe = artifacts.probe().unwrap();
    let (local, labels) = synth_batch(0, probe.input.shape.n, TEST_SEED);
    assert_eq!(local.data, probe.input.data, "synthetic CIFAR-10 generators disagree");
    assert_eq!(labels, probe.labels);
}

#[test]
fn golden_matches_jnp_oracle() {
    let Some(artifacts) = require_artifacts() else { return };
    let probe = artifacts.probe().unwrap();
    assert!(!probe.logits.is_empty());
    for (arch_name, oracle) in &probe.logits {
        let arch = arch_by_name(arch_name).unwrap();
        let weights = ModelWeights::load(&artifacts.dir, arch_name).unwrap();
        let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
        let logits = golden::run(&g, &weights, &probe.input).unwrap();
        assert_eq!(&logits.data, oracle, "golden != oracle for {arch_name}");
    }
}

#[test]
fn naive_graph_matches_oracle_too() {
    // The pre-optimization dataflow computes the same logits — the
    // Section III-G transformations are numerics-preserving end to end.
    let Some(artifacts) = require_artifacts() else { return };
    let probe = artifacts.probe().unwrap();
    for (arch_name, oracle) in &probe.logits {
        let arch = arch_by_name(arch_name).unwrap();
        let weights = ModelWeights::load(&artifacts.dir, arch_name).unwrap();
        let g = build_unoptimized_graph(&arch, &weights.act_exps, &weights.w_exps);
        let logits = golden::run(&g, &weights, &probe.input).unwrap();
        assert_eq!(&logits.data, oracle, "naive golden != oracle for {arch_name}");
    }
}

#[test]
fn pjrt_execution_matches_oracle() {
    let Some(artifacts) = require_artifacts() else { return };
    let probe = artifacts.probe().unwrap();
    let engine = Engine::from_artifacts(&artifacts).unwrap();
    for (arch_name, oracle) in &probe.logits {
        let logits = engine.infer_any(arch_name, &probe.input).unwrap();
        assert_eq!(&logits.data, oracle, "PJRT != oracle for {arch_name}");
    }
}

#[test]
fn pjrt_batch_variants_agree() {
    // b1 and b8 executables must produce identical logits per frame.
    let Some(artifacts) = require_artifacts() else { return };
    let engine = Engine::from_artifacts(&artifacts).unwrap();
    let (input, _) = synth_batch(100, 8, TEST_SEED);
    let via_b8 = engine.infer_any("resnet8", &input).unwrap();
    let b1 = engine.model("resnet8_b1").unwrap();
    for i in 0..8usize {
        let (one, _) = synth_batch(100 + i as u64, 1, TEST_SEED);
        let out = b1.infer(&one).unwrap();
        assert_eq!(&via_b8.data[i * 10..(i + 1) * 10], &out.data[..], "frame {i}");
    }
}

// The deprecated shim must keep working until its callers migrate.
#[allow(deprecated)]
#[test]
fn server_end_to_end_matches_golden_classes() {
    let Some(artifacts) = require_artifacts() else { return };
    let n = 32usize;
    let (input, _) = synth_batch(0, n, TEST_SEED);
    // Golden predictions.
    let weights = ModelWeights::load(&artifacts.dir, "resnet8").unwrap();
    let arch = arch_by_name("resnet8").unwrap();
    let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let logits = golden::run(&g, &weights, &input).unwrap();
    let expect = golden::argmax_classes(&logits);

    // Served predictions.
    let server =
        InferenceServer::start(artifacts.dir.clone(), "resnet8", BatcherConfig::default()).unwrap();
    let frame = 32 * 32 * 3;
    let pending: Vec<_> = (0..n)
        .map(|i| server.submit(input.data[i * frame..(i + 1) * frame].to_vec()).unwrap())
        .collect();
    for (i, rx) in pending.into_iter().enumerate() {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.class, expect[i], "frame {i}");
        assert_eq!(resp.logits, logits.data[i * 10..(i + 1) * 10].to_vec());
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap.frames, n as u64);
    assert_eq!(snap.errors, 0);
}

#[test]
fn router_pjrt_mixed_arch_matches_oracle() {
    // One router, two PJRT pools; routed classes must match the oracle
    // logits' argmax for both architectures.
    let Some(artifacts) = require_artifacts() else { return };
    let probe = artifacts.probe().unwrap();
    if probe.logits.is_empty() {
        return;
    }
    let factories: Vec<Arc<dyn BackendFactory>> = probe
        .logits
        .iter()
        .map(|(arch, _)| {
            Arc::new(PjrtFactory::new(artifacts.dir.clone(), arch)) as Arc<dyn BackendFactory>
        })
        .collect();
    let router = Router::start(factories, RouterConfig::default()).unwrap();
    let n = probe.input.shape.n;
    let frame = probe.input.shape.h * probe.input.shape.w * probe.input.shape.c;
    // Interleave submissions across architectures.
    let mut pending = Vec::new();
    for i in 0..n {
        for (arch, _) in &probe.logits {
            let pixels = probe.input.data[i * frame..(i + 1) * frame].to_vec();
            pending.push((arch.clone(), i, router.submit(arch, pixels).unwrap()));
        }
    }
    for (arch, i, rx) in pending {
        let resp = rx.recv().unwrap().unwrap();
        let oracle = &probe.logits.iter().find(|(a, _)| *a == arch).unwrap().1;
        let expect = golden::argmax_classes(&resnet_hls::quant::QTensor::from_vec(
            resnet_hls::quant::Shape4::new(1, 1, 1, 10),
            0,
            oracle[i * 10..(i + 1) * 10].to_vec(),
        ))[0];
        assert_eq!(resp.class, expect, "{arch} frame {i}");
    }
    let snap = router.shutdown();
    assert_eq!(snap.total.frames, (n * probe.logits.len()) as u64);
    assert_eq!(snap.total.errors, 0);
}

#[test]
fn weights_manifest_consistency() {
    let Some(artifacts) = require_artifacts() else { return };
    for arch_name in artifacts.arch_names() {
        let arch = arch_by_name(&arch_name).unwrap();
        let w = ModelWeights::load(&artifacts.dir, &arch_name).unwrap();
        for c in arch.conv_layers() {
            let lw = w.layer(&c.name).unwrap();
            assert_eq!(lw.w.shape, vec![c.k, c.k, c.cin, c.cout], "{arch_name}/{}", c.name);
            assert_eq!(lw.b.shape, vec![c.cout]);
            // int8 weights, int16 biases.
            assert!(lw.w.data.iter().all(|&v| (-128..=127).contains(&v)));
            assert!(lw.b.data.iter().all(|&v| (-(1 << 15)..(1 << 15)).contains(&v)));
            // Bias exponent is the accumulator exponent.
            let producer_exp = w
                .act_exps
                .get(if c.name == "stem" { "input" } else { "" })
                .copied();
            let _ = producer_exp; // exponent wiring validated by bit-equality above
        }
    }
}
