//! Cross-module integration tests that do not require built artifacts:
//! the full design flow (graph -> passes -> ILP -> config -> resources ->
//! simulation -> codegen) for every (model, board) the paper evaluates,
//! and the serving path (router + batcher + metrics) on the artifact-free
//! golden backend.

use std::sync::Arc;

use resnet_hls::coordinator::{BatcherConfig, Router, RouterConfig};
use resnet_hls::graph::{infer_shapes, Edge, InputRole, Op};
use resnet_hls::runtime::{
    BackendFactory, GoldenBackend, GoldenFactory, InferenceBackend, SimBackend, StreamBackend,
    StreamFactory,
};
use resnet_hls::stream::{run_streaming, StreamConfig};
use resnet_hls::hls::boards::{BOARDS, KV260, ULTRA96};
use resnet_hls::hls::streams::skip_stream;
use resnet_hls::hls::window::buffer_size;
use resnet_hls::hls::codegen::emit_top;
use resnet_hls::hls::config::configure;
use resnet_hls::hls::resources::{estimate, fit_to_board};
use resnet_hls::ilp::{loads_from_arch, solve};
use resnet_hls::models::{
    arch_by_name, build_optimized_graph, build_unoptimized_graph, default_exps, synthetic_weights,
};
use resnet_hls::passes;
use resnet_hls::sim::{build_network, golden, SimOptions};

#[test]
fn full_flow_all_models_all_boards() {
    for arch_name in ["resnet8", "resnet20"] {
        let arch = arch_by_name(arch_name).unwrap();
        let (act, w) = default_exps(&arch);
        for board in BOARDS {
            // The published flow: build unoptimized, run the passes.
            let mut g = build_unoptimized_graph(&arch, &act, &w);
            let stats = passes::optimize(&mut g);
            assert!(stats.adds_fused > 0);
            assert!(passes::equivalent(&g, &build_optimized_graph(&arch, &act, &w)));

            let loads = loads_from_arch(&arch, 2);
            let (alloc, cfg, report) =
                fit_to_board(&arch.name, &g, &loads, board, 2).expect("design fits");
            assert!(report.fits(board), "{arch_name}@{}", board.name);
            assert!(alloc.dsps_used <= board.n_par() as u64);

            let mut net =
                build_network(&g, &cfg, &SimOptions { frames: 3, ..Default::default() }).unwrap();
            let rep = net.run(3);
            assert!(!rep.deadlocked, "{arch_name}@{} deadlocked", board.name);
            // The simulator's steady state ought to be within 2.5x of the
            // ILP's idealized initiation interval.
            let ratio = rep.ii_cycles as f64 / alloc.cycles_per_frame as f64;
            assert!(
                (0.9..2.5).contains(&ratio),
                "{arch_name}@{}: sim II {} vs ILP {} (x{ratio:.2})",
                board.name,
                rep.ii_cycles,
                alloc.cycles_per_frame
            );

            let cpp = emit_top(&cfg);
            assert!(cpp.contains("#pragma HLS dataflow"));
        }
    }
}

#[test]
fn simulated_latency_exceeds_ii_but_not_wildly() {
    let arch = arch_by_name("resnet20").unwrap();
    let (act, w) = default_exps(&arch);
    let g = build_optimized_graph(&arch, &act, &w);
    let loads = loads_from_arch(&arch, 2);
    let alloc = solve(&loads, KV260.n_par() as u64).unwrap();
    let cfg = configure(&arch.name, &g, &alloc, &KV260, 2).unwrap();
    let mut net = build_network(&g, &cfg, &SimOptions { frames: 4, ..Default::default() }).unwrap();
    let rep = net.run(4);
    assert!(!rep.deadlocked);
    assert!(rep.latency_cycles >= rep.ii_cycles);
    assert!(
        rep.latency_cycles < 6 * rep.ii_cycles,
        "latency {} vs II {}",
        rep.latency_cycles,
        rep.ii_cycles
    );
}

#[test]
fn naive_dataflow_skip_occupancy_hits_receptive_field_bound() {
    // In the naive dataflow, the skip FIFO peak occupancy should approach
    // the Eq. 21 bound that the config assigned as its capacity.
    let arch = arch_by_name("resnet8").unwrap();
    let (act, w) = default_exps(&arch);
    let g = build_unoptimized_graph(&arch, &act, &w);
    let loads = loads_from_arch(&arch, 2);
    let alloc = solve(&loads, ULTRA96.n_par() as u64).unwrap();
    let cfg = configure(&arch.name, &g, &alloc, &ULTRA96, 2).unwrap();
    let mut net = build_network(&g, &cfg, &SimOptions { frames: 2, ..Default::default() }).unwrap();
    let rep = net.run(2);
    assert!(!rep.deadlocked);
    // The s0b0 identity-skip FIFO (tee -> add): Eq. 21 gives 2208 for the
    // 32x32x16 block (rh = rw = 5).
    let f = rep
        .fifo_stats
        .iter()
        .find(|f| f.name.contains("tee(stem) -> s0b0_add"))
        .expect("naive skip fifo");
    let bound = 2208.0;
    let frac = f.max_occupancy as f64 / bound;
    assert!(
        frac > 0.8,
        "peak skip occupancy {} should approach Eq.21 bound {bound}",
        f.max_occupancy
    );
}

#[test]
fn optimized_dataflow_skip_occupancy_within_half_naive_bound() {
    let arch = arch_by_name("resnet8").unwrap();
    let (act, w) = default_exps(&arch);
    let g = build_optimized_graph(&arch, &act, &w);
    let loads = loads_from_arch(&arch, 2);
    let alloc = solve(&loads, ULTRA96.n_par() as u64).unwrap();
    let cfg = configure(&arch.name, &g, &alloc, &ULTRA96, 2).unwrap();
    let mut net = build_network(&g, &cfg, &SimOptions { frames: 2, ..Default::default() }).unwrap();
    let rep = net.run(2);
    assert!(!rep.deadlocked);
    let f = rep
        .fifo_stats
        .iter()
        .find(|f| f.name.contains("s0b0c0.1 -> s0b0c1"))
        .expect("optimized skip fifo");
    // Eq. 22 for the same block is 1072; the naive bound is 2208 (R_sc).
    assert!(
        (f.max_occupancy as f64) < 0.75 * 2208.0,
        "optimized skip peak {} should be well below the naive bound",
        f.max_occupancy
    );
}

#[test]
fn golden_inference_consistent_across_batch_splits() {
    // Running 4 frames at once == running them one by one.
    let arch = arch_by_name("resnet8").unwrap();
    let weights = synthetic_weights(&arch, 3);
    let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let (batch, _) = resnet_hls::data::synth_batch(0, 4, resnet_hls::data::TEST_SEED);
    let all = golden::run(&g, &weights, &batch).unwrap();
    for i in 0..4usize {
        let (one, _) = resnet_hls::data::synth_batch(i as u64, 1, resnet_hls::data::TEST_SEED);
        let out = golden::run(&g, &weights, &one).unwrap();
        assert_eq!(&all.data[i * 10..(i + 1) * 10], &out.data[..], "frame {i}");
    }
}

#[test]
fn ow_par_ablation_packing_doubles_fps() {
    // Same DSP budget, ow_par 1 vs 2: packing should deliver ~2x FPS
    // until och caps bind.
    let arch = arch_by_name("resnet20").unwrap();
    let a1 = solve(&loads_from_arch(&arch, 1), 600).unwrap();
    let a2 = solve(&loads_from_arch(&arch, 2), 600).unwrap();
    let speedup = a1.cycles_per_frame as f64 / a2.cycles_per_frame as f64;
    assert!(
        (1.5..=2.2).contains(&speedup),
        "packing speedup {speedup} (cycles {} -> {})",
        a1.cycles_per_frame,
        a2.cycles_per_frame
    );
}

#[test]
fn shapes_preserved_through_pass_pipeline_on_both_archs() {
    for arch_name in ["resnet8", "resnet20"] {
        let arch = arch_by_name(arch_name).unwrap();
        let (act, w) = default_exps(&arch);
        let mut g = build_unoptimized_graph(&arch, &act, &w);
        let out_before = {
            let shapes = infer_shapes(&g).unwrap();
            shapes[&Edge::new(g.output().unwrap(), 0)]
        };
        passes::optimize(&mut g);
        let shapes = infer_shapes(&g).unwrap();
        let out_after = shapes[&Edge::new(g.output().unwrap(), 0)];
        assert_eq!(out_before, out_after);
        g.validate().unwrap();
    }
}

#[test]
fn resource_estimates_scale_with_parallelism() {
    let arch = arch_by_name("resnet8").unwrap();
    let (act, w) = default_exps(&arch);
    let g = build_optimized_graph(&arch, &act, &w);
    let loads = loads_from_arch(&arch, 2);
    let mut last_dsps = 0;
    // Minimum feasible budget: one PE per tap per layer = 7*9 + 2*1 = 65.
    for budget in [80u64, 128, 256, 512] {
        let alloc = solve(&loads, budget).unwrap();
        let cfg = configure(&arch.name, &g, &alloc, &KV260, 2).unwrap();
        let rep = estimate(&cfg);
        assert!(rep.dsps >= last_dsps, "DSPs must grow with budget");
        last_dsps = rep.dsps;
    }
}

#[test]
fn deadlock_experiment_matrix() {
    // The Fig. 14 claim as a truth table over (dataflow, skip sizing):
    //   naive + Eq.21 sizing        -> runs
    //   naive + halved (Eq.22-like) -> deadlock
    //   optimized + Eq.22 sizing    -> runs
    let arch = arch_by_name("resnet8").unwrap();
    let (act, w) = default_exps(&arch);
    let loads = loads_from_arch(&arch, 2);
    let alloc = solve(&loads, ULTRA96.n_par() as u64).unwrap();

    let run = |naive: bool, factor: f64| -> bool {
        let g = if naive {
            build_unoptimized_graph(&arch, &act, &w)
        } else {
            build_optimized_graph(&arch, &act, &w)
        };
        let cfg = configure(&arch.name, &g, &alloc, &ULTRA96, 2).unwrap();
        let opts = SimOptions { frames: 2, skip_factor: factor, ..Default::default() };
        let mut net = build_network(&g, &cfg, &opts).unwrap();
        net.run(2).deadlocked
    };
    assert!(!run(true, 1.0), "naive @ Eq.21 must run");
    assert!(run(true, 0.45), "naive @ half sizing must deadlock");
    assert!(!run(false, 1.0), "optimized @ Eq.22 must run");
}

// -------------------------------------------- streaming backend (tentpole)

#[test]
fn stream_backend_bit_exact_with_eq22_buffering() {
    // Acceptance: StreamBackend is bit-exact vs GoldenBackend on both
    // paper architectures, its reported peak intermediate buffering is
    // below the whole-tensor-intermediates total, and every skip FIFO is
    // sized exactly by hls::streams::skip_stream (Eq. 22) and ran within
    // that depth.
    for (arch_name, frames) in [("resnet8", 2usize), ("resnet20", 1)] {
        let stream = StreamBackend::synthetic(arch_name, 7, &[1, 2, 4]).unwrap();
        let golden = GoldenBackend::synthetic(arch_name, 7, &[1, 2, 4]).unwrap();
        let (input, _) = resnet_hls::data::synth_batch(0, frames, resnet_hls::data::TEST_SEED);
        let a = stream.infer_batch(&input).unwrap();
        let b = golden.infer_batch(&input).unwrap();
        assert_eq!(a.data, b.data, "{arch_name}: stream vs golden mismatch");

        let stats = stream.last_stats().expect("stream stats recorded");
        assert!(
            stats.peak_buffered_elems() < stats.whole_tensor_elems,
            "{arch_name}: streamed peak {} must undercut whole-tensor {}",
            stats.peak_buffered_elems(),
            stats.whole_tensor_elems
        );

        let arch = arch_by_name(arch_name).unwrap();
        let weights = synthetic_weights(&arch, 7);
        let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
        let shapes = infer_shapes(&g).unwrap();
        let mut skip_fifos = 0usize;
        for n in g.live() {
            if let Op::Conv(at) = &n.op {
                if n.inputs.iter().any(|(_, r)| *r == InputRole::SkipInit) {
                    let in_shape = shapes[&n.inputs[0].0];
                    let expect =
                        skip_stream(buffer_size(at.k, at.k, in_shape.w, at.cin, 1).unwrap())
                            .capacity();
                    let buf = stats
                        .buffer(&format!("{}.skip", n.name))
                        .unwrap_or_else(|| panic!("{arch_name}: no stat for {}.skip", n.name));
                    assert_eq!(buf.capacity, expect, "{}: capacity != Eq. 22 depth", n.name);
                    assert!(buf.peak > 0, "{}: skip stream never used", n.name);
                    assert!(buf.peak <= expect, "{}: peak beyond Eq. 22 depth", n.name);
                    skip_fifos += 1;
                }
            }
        }
        assert_eq!(
            skip_fifos,
            arch.residuals().count(),
            "{arch_name}: one skip FIFO per residual segment"
        );
    }
}

#[test]
fn router_serves_on_stream_backend() {
    // The fourth backend is selectable through the coordinator exactly
    // like the others, and serves golden-identical classes.
    let expect = golden_classes("resnet8", 7, 3);
    let factory: Arc<dyn BackendFactory> =
        Arc::new(StreamFactory::synthetic("resnet8", 7).with_buckets(&[1, 2]));
    let router = Router::start(
        vec![factory],
        RouterConfig { workers_per_arch: 1, batcher: BatcherConfig::default() },
    )
    .unwrap();
    let (input, _) = resnet_hls::data::synth_batch(0, 3, resnet_hls::data::TEST_SEED);
    let frame = 32 * 32 * 3;
    let pending: Vec<_> = (0..3)
        .map(|i| router.submit("resnet8", input.data[i * frame..(i + 1) * frame].to_vec()))
        .collect::<anyhow::Result<_>>()
        .unwrap();
    for (rx, want) in pending.iter().zip(expect) {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.class, want);
    }
    router.shutdown();
}

// ---------------------------- general topologies (skip graphs, weight tying)

#[test]
fn general_topologies_bit_exact_across_backends() {
    // The scenario-diversity acceptance: a long-skip/multi-add net and a
    // weight-tied ODE-style net run through every artifact-free backend
    // bit-identically — golden (reference), sim (golden numerics paced by
    // the cycle model, so its construction exercises ILP + resource
    // closure + the discrete-event network on the new shapes), the
    // pipelined stream pool, and the naive Eq. 21 dataflow.
    for arch_name in ["skipnet", "longskipnet", "tiednet"] {
        let golden_b = GoldenBackend::synthetic(arch_name, 7, &[1, 2]).unwrap();
        let stream_b = StreamBackend::synthetic(arch_name, 7, &[1, 2]).unwrap();
        let sim_b = SimBackend::synthetic(arch_name, 7, &[1, 2], &KV260).unwrap();
        let (input, _) = resnet_hls::data::synth_batch(0, 2, resnet_hls::data::TEST_SEED);
        let want = golden_b.infer_batch(&input).unwrap();
        assert_eq!(
            stream_b.infer_batch(&input).unwrap().data,
            want.data,
            "{arch_name}: stream vs golden"
        );
        assert_eq!(
            sim_b.infer_batch(&input).unwrap().data,
            want.data,
            "{arch_name}: sim vs golden"
        );

        // Fourth form: the unoptimized graph under the naive Eq. 21
        // dataflow — multi-input adds as explicit stream stages.
        let arch = arch_by_name(arch_name).unwrap();
        let weights = synthetic_weights(&arch, 7);
        let gn = build_unoptimized_graph(&arch, &weights.act_exps, &weights.w_exps);
        let naive = golden::run(&gn, &weights, &input).unwrap();
        assert_eq!(naive.data, want.data, "{arch_name}: naive graph numerics");
        let cfg = StreamConfig { naive_add: true, ..StreamConfig::default() };
        let (got, _) = run_streaming(&gn, &weights, &input, &cfg).unwrap();
        assert_eq!(got.data, naive.data, "{arch_name}: naive stream vs golden");
    }
}

#[test]
fn general_topologies_full_design_flow() {
    // The published flow end to end on the non-ResNet shapes: passes
    // reach the hand-optimized form, the design closes on a board, the
    // cycle simulator runs deadlock-free, and codegen emits the general
    // add tasks (one skip FIFO per extra operand).
    for arch_name in ["skipnet", "longskipnet", "tiednet"] {
        let arch = arch_by_name(arch_name).unwrap();
        let (act, w) = default_exps(&arch);
        let mut g = build_unoptimized_graph(&arch, &act, &w);
        let stats = passes::optimize(&mut g);
        assert!(stats.adds_fused > 0, "{arch_name}: fusable residuals must fuse");
        assert!(passes::equivalent(&g, &build_optimized_graph(&arch, &act, &w)));

        let loads = loads_from_arch(&arch, 2);
        let (alloc, cfg, report) =
            fit_to_board(&arch.name, &g, &loads, &KV260, 2).expect("design fits");
        assert!(report.fits(&KV260), "{arch_name}@KV260");
        assert!(alloc.dsps_used <= KV260.n_par() as u64);

        let mut net =
            build_network(&g, &cfg, &SimOptions { frames: 2, ..Default::default() }).unwrap();
        let rep = net.run(2);
        assert!(!rep.deadlocked, "{arch_name}@KV260 deadlocked");

        let cpp = emit_top(&cfg);
        assert!(cpp.contains("#pragma HLS dataflow"));
        if arch_name == "skipnet" {
            // The 3-operand naive island survives as an add task with a
            // second, independently sized skip FIFO.
            assert!(cpp.contains("skipfifo_r1_add_2"), "second skip FIFO declared:\n{cpp}");
        }
    }
}

#[test]
fn router_serves_mixed_classic_and_general_fleet_on_stream_backend() {
    // The ISSUE 10 integration scenario: one router serving the classic
    // ResNet preset alongside both new general-topology architectures,
    // every arch on the streaming pool, classes bit-equal to sim::golden,
    // and the per-arch accounting visible in the shutdown snapshot.
    let seed = 7u64;
    let counts = [("resnet8", 3usize), ("skipnet", 3), ("tiednet", 2)];
    let factories: Vec<Arc<dyn BackendFactory>> = counts
        .iter()
        .map(|(a, _)| {
            Arc::new(StreamFactory::synthetic(a, seed).with_buckets(&[1, 2]))
                as Arc<dyn BackendFactory>
        })
        .collect();
    let router = Router::start(
        factories,
        RouterConfig { workers_per_arch: 1, batcher: BatcherConfig::default() },
    )
    .unwrap();

    let frame = resnet_hls::data::IMG_ELEMS;
    let max_frames = counts.iter().map(|&(_, n)| n).max().unwrap();
    let (input, _) = resnet_hls::data::synth_batch(0, max_frames, resnet_hls::data::TEST_SEED);
    let mut pending = Vec::new();
    for i in 0..max_frames {
        for &(arch, n) in &counts {
            if i < n {
                let pixels = input.data[i * frame..(i + 1) * frame].to_vec();
                pending.push((arch, i, router.submit(arch, pixels).unwrap()));
            }
        }
    }

    let expected: Vec<(&str, Vec<usize>)> =
        counts.iter().map(|&(a, n)| (a, golden_classes(a, seed, n))).collect();
    for (arch, i, rx) in pending {
        let expect = expected.iter().find(|(a, _)| *a == arch).unwrap().1[i];
        let resp = rx.recv().expect("response channel alive").expect("inference ok");
        assert_eq!(resp.class, expect, "{arch} frame {i}");
    }

    let snap = router.shutdown();
    assert_eq!(snap.total.errors, 0);
    for &(arch, n) in &counts {
        assert_eq!(snap.per_arch[arch].frames, n as u64, "{arch} frame count");
    }
}

// ------------------------------------------------- serving path (golden)

/// Golden single-frame predictions for the first `frames` synthetic test
/// frames of `arch_name` under synthetic weights `seed`.
fn golden_classes(arch_name: &str, seed: u64, frames: usize) -> Vec<usize> {
    let arch = arch_by_name(arch_name).unwrap();
    let weights = synthetic_weights(&arch, seed);
    let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let (input, _) = resnet_hls::data::synth_batch(0, frames, resnet_hls::data::TEST_SEED);
    let logits = golden::run(&g, &weights, &input).unwrap();
    golden::argmax_classes(&logits)
}

#[test]
fn router_serves_mixed_arch_requests_on_golden_backend() {
    // The acceptance scenario: no artifacts, no PJRT — a multi-arch
    // router on golden backends, mixed-arch submissions, work-stealing
    // workers, graceful drain, and classes bit-equal to sim::golden.
    let seed = 7u64;
    let counts = [("resnet8", 5usize), ("resnet20", 3usize)];
    let factories: Vec<Arc<dyn BackendFactory>> = counts
        .iter()
        .map(|(a, _)| {
            Arc::new(GoldenFactory::synthetic(a, seed).with_buckets(&[1, 2, 4]))
                as Arc<dyn BackendFactory>
        })
        .collect();
    let router = Router::start(
        factories,
        RouterConfig { workers_per_arch: 2, batcher: BatcherConfig::default() },
    )
    .unwrap();
    assert_eq!(router.archs(), vec!["resnet20".to_string(), "resnet8".to_string()]);

    let max_frames = counts.iter().map(|&(_, n)| n).max().unwrap();
    let (input, _) = resnet_hls::data::synth_batch(0, max_frames, resnet_hls::data::TEST_SEED);
    let frame = resnet_hls::data::IMG_ELEMS;

    // Interleave submissions across the two architectures.
    let mut pending = Vec::new();
    for i in 0..max_frames {
        for &(arch, n) in &counts {
            if i < n {
                let pixels = input.data[i * frame..(i + 1) * frame].to_vec();
                pending.push((arch, i, router.submit(arch, pixels).unwrap()));
            }
        }
    }

    // Graceful shutdown *before* receiving: every accepted request must
    // still get a real response (drain semantics).
    let snap = router.shutdown();

    let expected: Vec<(&str, Vec<usize>)> = counts
        .iter()
        .map(|&(a, n)| (a, golden_classes(a, seed, n)))
        .collect();
    for (arch, i, rx) in pending {
        let expect = expected.iter().find(|(a, _)| *a == arch).unwrap().1[i];
        let resp = rx.recv().expect("response channel alive").expect("inference ok");
        assert_eq!(resp.class, expect, "{arch} frame {i}");
        assert_eq!(resp.logits.len(), 10);
    }

    let total: usize = counts.iter().map(|&(_, n)| n).sum();
    assert_eq!(snap.total.requests, total as u64);
    assert_eq!(snap.total.frames, total as u64);
    assert_eq!(snap.total.errors, 0);
    assert!(snap.total.padding_efficiency > 0.0 && snap.total.padding_efficiency <= 1.0);
    let r8 = &snap.per_arch["resnet8"];
    let r20 = &snap.per_arch["resnet20"];
    assert_eq!(r8.frames + r20.frames, snap.total.frames);
}

#[test]
fn router_rejects_bad_submissions() {
    let factory: Arc<dyn BackendFactory> =
        Arc::new(GoldenFactory::synthetic("resnet8", 3).with_buckets(&[1, 2]));
    let router = Router::start(vec![factory], RouterConfig::default()).unwrap();
    assert!(router.submit("resnet99", vec![0; resnet_hls::data::IMG_ELEMS]).is_err());
    assert!(router.submit("resnet8", vec![0; 3]).is_err(), "wrong frame size");
}

#[test]
fn router_drop_never_silently_discards_requests() {
    let factory: Arc<dyn BackendFactory> =
        Arc::new(GoldenFactory::synthetic("resnet8", 3).with_buckets(&[1, 2]));
    let router = Router::start(vec![factory], RouterConfig::default()).unwrap();
    let frame = resnet_hls::data::IMG_ELEMS;
    let (input, _) = resnet_hls::data::synth_batch(0, 8, resnet_hls::data::TEST_SEED);
    let pending: Vec<_> = (0..8)
        .map(|i| router.submit("resnet8", input.data[i * frame..(i + 1) * frame].to_vec()).unwrap())
        .collect();
    // Abort path: dropping the handle must never silently discard a
    // request — each channel yields either a real response or an
    // explicit "server stopped" error.
    drop(router);
    for rx in pending {
        let outcome = rx.recv().expect("no silently dropped channels");
        if let Err(e) = outcome {
            assert!(e.to_string().contains("server stopped"), "unexpected error: {e}");
        }
    }
}

#[test]
fn golden_backend_tiling_is_frame_exact() {
    // infer_tiled pads tails with zero frames; no real frame may change.
    let backend = GoldenBackend::synthetic("resnet8", 11, &[1, 2, 4]).unwrap();
    let (input, _) = resnet_hls::data::synth_batch(0, 5, resnet_hls::data::TEST_SEED);
    let tiled = resnet_hls::runtime::infer_tiled(&backend, &input).unwrap();
    let whole = backend.infer_batch(&input).unwrap();
    assert_eq!(tiled.data, whole.data);
    assert_eq!(backend.buckets(), &[1, 2, 4]);
}

#[test]
fn router_start_fails_cleanly_on_unknown_arch() {
    let factory: Arc<dyn BackendFactory> = Arc::new(GoldenFactory::synthetic("resnet99", 3));
    // Backend construction happens in the worker; the error must still
    // surface from start(), not on the first request.
    assert!(Router::start(vec![factory], RouterConfig::default()).is_err());
}
