//! Bench: the real request path — PJRT inference throughput per batch
//! bucket and end-to-end served throughput through the backend-generic
//! router (DESIGN.md E7).
//!
//! Requires `make artifacts`.  Run: `cargo bench --bench runtime_e2e`

use std::sync::Arc;

use resnet_hls::coordinator::{Router, RouterConfig};
use resnet_hls::data::{synth_batch, IMG_ELEMS, TEST_SEED};
use resnet_hls::paths::artifacts_dir;
use resnet_hls::runtime::{BackendFactory, Engine, GoldenFactory, PjrtFactory};
use resnet_hls::util::Bencher;

fn main() {
    let dir = artifacts_dir();
    let engine = match Engine::load(&dir) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping runtime_e2e: {e} (run `make artifacts`)");
            return;
        }
    };
    println!("pjrt platform: {}", engine.platform());

    let mut b = Bencher::new();
    for arch in ["resnet8", "resnet20"] {
        for bucket in engine.buckets(arch) {
            let (input, _) = synth_batch(0, bucket, TEST_SEED);
            let model = engine.model(&format!("{arch}_b{bucket}")).unwrap();
            b.bench_items(&format!("pjrt {arch} b{bucket}"), bucket as f64, &mut || {
                model.infer(&input).unwrap();
            });
        }
    }

    // Served throughput through the router (batcher + channels), for the
    // PJRT backend and — as the dispatch-overhead baseline — the golden
    // backend.
    let factories: [(&str, Arc<dyn BackendFactory>); 2] = [
        ("pjrt", Arc::new(PjrtFactory::new(dir.clone(), "resnet8"))),
        ("golden", Arc::new(GoldenFactory::from_artifacts(dir.clone(), "resnet8"))),
    ];
    for (label, factory) in factories {
        let router = Router::start(vec![factory], RouterConfig::default()).unwrap();
        let (input, _) = synth_batch(0, 64, TEST_SEED);
        b.bench_items(&format!("served {label} resnet8 64-frame burst"), 64.0, &mut || {
            let pending: Vec<_> = (0..64)
                .map(|i| {
                    router
                        .submit("resnet8", input.data[i * IMG_ELEMS..(i + 1) * IMG_ELEMS].to_vec())
                        .unwrap()
                })
                .collect();
            for rx in pending {
                rx.recv().unwrap().unwrap();
            }
        });
        println!("  metrics {}", router.shutdown());
    }
}
