//! Bench: Fig. 5 — the DSP packing pipelines.
//!
//! Prints the chain structure for the paper's filter sizes and
//! micro-benchmarks the bit-exact packing model against scalar MACs
//! (the model itself is software; the figure data is the chain plan).
//!
//! Run: `cargo bench --bench fig_packing`

use resnet_hls::eval::figures::packing_figure;
use resnet_hls::hls::packing::{decode_lanes, dsp_stage, packed_chain, MAX_CHAIN};
use resnet_hls::util::bench::black_box;
use resnet_hls::util::{Bencher, Lcg64};

fn main() {
    println!("== Fig. 5: packed compute pipelines ==");
    for (taps, och_par) in [(9usize, 1usize), (9, 8), (1, 8), (25, 4)] {
        let f = packing_figure(taps, och_par);
        println!(
            "filter {taps:>2} taps x och_par {och_par}: chains {:?} (+{} adders), \
             {:>3} DSPs, {:>3} MACs/cy packed vs {:>3} unpacked",
            f.chains, f.extra_adders, f.dsps, f.macs_per_cycle_packed, f.macs_per_cycle_unpacked
        );
        assert!(f.chains.iter().all(|&c| c <= MAX_CHAIN));
        assert_eq!(f.macs_per_cycle_packed, 2 * f.macs_per_cycle_unpacked);
    }

    // Verify once more at scale: random chains, bit-exact lanes.
    let mut rng = Lcg64::new(42);
    let mut checked = 0u64;
    for _ in 0..100_000 {
        let n = 1 + (rng.below(MAX_CHAIN as u64)) as usize;
        let taps: Vec<(i8, i8, i8)> = (0..n)
            .map(|_| {
                (
                    rng.range_i64(-128, 127) as i8,
                    rng.range_i64(-128, 127) as i8,
                    rng.range_i64(-128, 127) as i8,
                )
            })
            .collect();
        let (u, v) = packed_chain(&taps);
        let su: i32 = taps.iter().map(|&(_, d, b)| d as i32 * b as i32).sum();
        let sv: i32 = taps.iter().map(|&(a, _, b)| a as i32 * b as i32).sum();
        assert_eq!((u, v), (su, sv));
        checked += 1;
    }
    println!("packing model: {checked} random chains bit-exact");

    let mut b = Bencher::new();
    let taps: Vec<(i8, i8, i8)> = (0..7).map(|i| (i as i8, -(i as i8), 3)).collect();
    b.bench_items("packed_chain(7)", 14.0, &mut || {
        black_box(packed_chain(black_box(&taps)));
    });
    b.bench_items("dsp_stage", 2.0, &mut || {
        black_box(dsp_stage(black_box(12345), 7, -9, 55));
    });
    b.bench("decode_lanes", || {
        black_box(decode_lanes(black_box(123456789)));
    });
}
