//! Bench: the TCP ingress tier under load — closed-loop round-trip
//! latency through the full socket/admission/dispatch/router path, the
//! sustained 2x-overload soak (bounded queue, load-shedding with
//! retry-after hints, client-observed tail latency), and a machine-
//! readable `BENCH_ingress.json` summary for CI trend tracking.
//!
//! Artifact-free (golden backend, synthetic weights, ephemeral port).
//! Run: `cargo bench --bench ingress_soak`
//! (`REPRO_BENCH_QUICK=1` for a short CI-ish run.)

use std::collections::BTreeMap;
use std::sync::Arc;

use resnet_hls::coordinator::{Router, RouterConfig};
use resnet_hls::data::{synth_batch, IMG_ELEMS, TEST_SEED};
use resnet_hls::net::{drive, Client, DriveConfig, IngressServer, ServerConfig};
use resnet_hls::runtime::GoldenFactory;
use resnet_hls::util::{Bencher, Json};

fn main() {
    let quick = std::env::var("REPRO_BENCH_QUICK").ok().as_deref() == Some("1");
    let frames = if quick { 256 } else { 2048 };
    let mut b = Bencher::new();

    // The ISSUE's soak shape in miniature: a deliberately small queue so
    // a 64-deep client window overcommits it and sheds are observable.
    let cap = 16usize;
    let router = Arc::new(
        Router::start(
            vec![Arc::new(GoldenFactory::synthetic("resnet8", 7))],
            RouterConfig::default(),
        )
        .expect("router start"),
    );
    let server = IngressServer::start(
        router.clone(),
        ServerConfig { queue_capacity: cap, ..Default::default() },
    )
    .expect("ingress start");
    let addr = format!("{}", server.local_addr());
    println!("ingress soak bench on {addr} (queue cap {cap}, {frames} frames/drive)");

    // ---- closed-loop round trip: one request outstanding ----
    // The full wire + admission + dispatch + router + golden-compute
    // path, client-observed.  This is the latency floor the overload
    // percentiles are judged against.
    let (batch, _) = synth_batch(0, 1, TEST_SEED);
    let mut client = Client::connect(&addr).expect("connect");
    let rt = b.bench_items("ingress round trip (closed loop)", 1.0, &mut || {
        let resp = client
            .request("resnet8", 0, &batch.data[..IMG_ELEMS])
            .expect("request");
        assert!(
            matches!(resp, resnet_hls::net::ResponseFrame::Ok { .. }),
            "closed-loop request must serve, got {resp:?}"
        );
    });
    drop(client);

    // ---- calibration: what does one pipelined connection sustain? ----
    let cal = drive(&DriveConfig {
        addr: addr.clone(),
        frames,
        window: 4,
        ..Default::default()
    })
    .expect("calibration drive");
    println!("calibration (window 4): {cal}");
    assert!(cal.accounted(), "calibration accounting failed: {cal}");
    let base_fps = cal.ok_fps().max(50.0);

    // ---- the soak: 2x sustained overload ----
    // Paced at twice the measured service rate with a window four times
    // the queue cap: the bounded queue must shed the excess (every shed
    // carrying a retry-after hint), never exceed its cap, and keep
    // serving what it admits.
    let overload = drive(&DriveConfig {
        addr: addr.clone(),
        frames,
        fps: 2.0 * base_fps,
        window: 4 * cap,
        ..Default::default()
    })
    .expect("overload drive");
    println!("2x overload ({:.0} FPS target): {overload}", 2.0 * base_fps);
    assert!(overload.accounted(), "soak accounting failed: {overload}");
    assert!(overload.sheds > 0, "a 2x overload against cap {cap} must shed: {overload}");
    assert!(overload.oks > 0, "admitted requests must still serve: {overload}");

    let snap = server.shutdown();
    println!("ingress {snap}");
    assert!(
        snap.queue_peak_depth <= cap,
        "admission queue exceeded its cap: {} > {cap}",
        snap.queue_peak_depth
    );
    let rs = router.snapshot();
    println!("router {rs}");

    // ---- machine-readable summary ----
    let mut o: BTreeMap<String, Json> = BTreeMap::new();
    o.insert("bench".into(), Json::Str("ingress_soak".into()));
    o.insert("quick".into(), Json::Bool(quick));
    o.insert("frames_per_drive".into(), Json::Int(frames as i64));
    o.insert("queue_capacity".into(), Json::Int(cap as i64));
    o.insert("round_trip_median_ns".into(), Json::Float(rt.median_ns));
    o.insert("closed_loop_ok_fps".into(), Json::Float(cal.ok_fps()));
    o.insert("closed_loop_p99_us".into(), Json::Int(cal.p99_us as i64));
    o.insert("overload_fps_target".into(), Json::Float(2.0 * base_fps));
    o.insert("overload_ok_fps".into(), Json::Float(overload.ok_fps()));
    o.insert("overload_oks".into(), Json::Int(overload.oks as i64));
    o.insert("overload_sheds".into(), Json::Int(overload.sheds as i64));
    o.insert("overload_shed_rate".into(), Json::Float(overload.shed_rate()));
    o.insert("overload_p50_us".into(), Json::Int(overload.p50_us as i64));
    o.insert("overload_p95_us".into(), Json::Int(overload.p95_us as i64));
    o.insert("overload_p99_us".into(), Json::Int(overload.p99_us as i64));
    o.insert("queue_peak_depth".into(), Json::Int(snap.queue_peak_depth as i64));
    o.insert("accepted".into(), Json::Int(snap.accepted as i64));
    o.insert("shed".into(), Json::Int(snap.shed as i64));
    o.insert("deadline_expired".into(), Json::Int(snap.expired as i64));
    o.insert("router_shed_rate".into(), Json::Float(rs.total.shed_rate));
    let j = Json::Object(o);
    std::fs::write("BENCH_ingress.json", format!("{j}\n")).expect("write BENCH_ingress.json");
    println!("wrote BENCH_ingress.json: {j}");

    let router = Arc::try_unwrap(router).ok().expect("router still shared");
    let _ = router.shutdown();
}
