//! Bench: regenerate paper Table 4 (resource utilization, modeled vs
//! paper) and check the utilization *shape*.
//!
//! Run: `cargo bench --bench table4`

use resnet_hls::eval::tables::{print_table4, table4};
use resnet_hls::hls::boards::{KV260, ULTRA96};

fn main() {
    let rows = table4().expect("table4");
    print_table4(&rows);

    println!("\n== shape checks ==");
    let get = |label: &str, board: &str| {
        rows.iter()
            .find(|r| r.label.contains(label) && r.board == board)
            .unwrap_or_else(|| panic!("row {label}@{board}"))
    };
    let mut ok = true;
    let mut check = |name: &str, cond: bool| {
        ok &= cond;
        println!("  [{}] {name}", if cond { "ok" } else { "FAIL" });
    };

    // Every modeled design fits its board.
    for r in &rows {
        let board = if r.board == "KV260" { &KV260 } else { &ULTRA96 };
        check(&format!("{} fits {}", r.label, r.board), r.report.fits(board));
    }
    // KV260 designs park parameters in URAM, Ultra96 in BRAM (Sec. III-D).
    check("KV260 uses URAM", get("resnet20", "KV260").report.urams > 0);
    check("Ultra96 uses no URAM", get("resnet20", "Ultra96").report.urams == 0);
    // LUTs bind before DSPs on KV260/resnet20 (paper: 69.4% LUT @ 50% DSP).
    let r = get("resnet20", "KV260");
    check(
        "resnet20@KV260 is LUT-bound",
        (r.report.luts as f64 / KV260.luts as f64) > (r.report.dsps as f64 / KV260.dsps as f64),
    );
    // Within a loose band of the paper's absolute numbers where reported.
    for r in &rows {
        if let Some(p) = r.paper {
            let lut_ratio = (r.report.luts as f64 / 1e3) / p.kluts;
            check(
                &format!("{}@{} kLUT within band (x{:.2})", r.label, r.board, lut_ratio),
                (0.35..=2.5).contains(&lut_ratio),
            );
            if p.dsps > 0 {
                let dsp_ratio = r.report.dsps as f64 / p.dsps as f64;
                check(
                    &format!("{}@{} DSP within band (x{:.2})", r.label, r.board, dsp_ratio),
                    (0.2..=2.0).contains(&dsp_ratio),
                );
            }
        }
    }
    assert!(ok, "table 4 shape checks failed");
}
