//! Bench: the streaming executor vs the golden model, and the persistent
//! frame-pipelined pool vs repeated one-shot `run_streaming` calls.
//!
//! The second comparison is the PR-3 acceptance measurement: >= 32 frames
//! through a 2-replica [`StreamPool`]-backed backend (stage threads
//! spawned once, frames pipelined through the FIFO chain) against the
//! same 32 frames paying plan + thread spawn + pipeline fill per frame.
//!
//! Artifact-free.  Run: `cargo bench --bench stream_backend`
//! (`REPRO_BENCH_QUICK=1` for a short CI-ish run.)

use resnet_hls::data::{synth_batch, TEST_SEED};
use resnet_hls::models::{arch_by_name, build_optimized_graph, synthetic_weights};
use resnet_hls::runtime::{GoldenBackend, InferenceBackend, StreamBackend};
use resnet_hls::stream::{run_streaming, StreamConfig};
use resnet_hls::util::Bencher;

fn main() {
    let mut b = Bencher::new();

    // ---- single-batch: pipelined executor vs golden ----
    for (arch, frames) in [("resnet8", 8usize), ("resnet20", 2)] {
        let golden = GoldenBackend::synthetic(arch, 7, &[frames]).unwrap();
        let stream = StreamBackend::synthetic(arch, 7, &[frames]).unwrap();
        let (input, _) = synth_batch(0, frames, TEST_SEED);

        // Correctness gate before timing anything.
        let g = golden.infer_batch(&input).unwrap();
        let s = stream.infer_batch(&input).unwrap();
        assert_eq!(g.data, s.data, "{arch}: stream backend must match golden");

        b.bench_items(&format!("golden {arch} b{frames}"), frames as f64, &mut || {
            golden.infer_batch(&input).unwrap();
        });
        b.bench_items(&format!("stream {arch} b{frames}"), frames as f64, &mut || {
            stream.infer_batch(&input).unwrap();
        });

        let stats = stream.last_stats().unwrap();
        println!(
            "{arch}: peak streamed buffering {} elems vs whole-tensor {} ({:.4})",
            stats.peak_buffered_elems(),
            stats.whole_tensor_elems,
            stats.buffered_fraction()
        );
    }

    // ---- serving throughput: persistent pool vs per-call pipelines ----
    let frames = 32usize;
    let arch = arch_by_name("resnet8").unwrap();
    let weights = synthetic_weights(&arch, 7);
    let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let (input, _) = synth_batch(0, frames, TEST_SEED);

    let pooled = StreamBackend::synthetic_with(
        "resnet8",
        7,
        &[frames],
        StreamConfig { replicas: 2, ..Default::default() },
    )
    .unwrap();
    let want = GoldenBackend::synthetic("resnet8", 7, &[frames])
        .unwrap()
        .infer_batch(&input)
        .unwrap();
    assert_eq!(pooled.infer_batch(&input).unwrap().data, want.data);

    let singles: Vec<_> = (0..frames)
        .map(|i| synth_batch(i as u64, 1, TEST_SEED).0)
        .collect();

    let s_pool = b.bench_items(
        "pool resnet8 32 frames (2 replicas, persistent)",
        frames as f64,
        &mut || {
            pooled.infer_batch(&input).unwrap();
        },
    );
    let s_once = b.bench_items(
        "one-shot run_streaming resnet8 32 x 1 frame",
        frames as f64,
        &mut || {
            for f in &singles {
                run_streaming(&g, &weights, f, &StreamConfig::default()).unwrap();
            }
        },
    );
    let speedup = s_once.median_ns / s_pool.median_ns;
    println!(
        "persistent pool vs repeated one-shot executor: {speedup:.2}x \
         ({:.0} vs {:.0} frames/s)",
        s_pool.items_per_sec(),
        s_once.items_per_sec()
    );
    assert!(
        speedup > 1.0,
        "the persistent pool must beat per-call pipelines (got {speedup:.2}x)"
    );

    let stats = pooled.last_stats().unwrap();
    println!(
        "pool buffering: peak {} elems vs replica-scaled whole-tensor {} ({:.4}), \
         {} frames served",
        stats.peak_buffered_elems(),
        stats.whole_tensor_elems,
        stats.buffered_fraction(),
        stats.frames
    );
}
