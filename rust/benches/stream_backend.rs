//! Bench: the streaming line-buffer executor vs the golden model — does
//! cross-layer pipeline parallelism pay for the FIFO handshakes?
//!
//! Artifact-free.  Run: `cargo bench --bench stream_backend`

use resnet_hls::data::{synth_batch, TEST_SEED};
use resnet_hls::runtime::{GoldenBackend, InferenceBackend, StreamBackend};
use resnet_hls::util::Bencher;

fn main() {
    let mut b = Bencher::new();
    for (arch, frames) in [("resnet8", 8usize), ("resnet20", 2)] {
        let golden = GoldenBackend::synthetic(arch, 7, &[frames]).unwrap();
        let stream = StreamBackend::synthetic(arch, 7, &[frames]).unwrap();
        let (input, _) = synth_batch(0, frames, TEST_SEED);

        // Correctness gate before timing anything.
        let g = golden.infer_batch(&input).unwrap();
        let s = stream.infer_batch(&input).unwrap();
        assert_eq!(g.data, s.data, "{arch}: stream backend must match golden");

        b.bench_items(&format!("golden {arch} b{frames}"), frames as f64, &mut || {
            golden.infer_batch(&input).unwrap();
        });
        b.bench_items(&format!("stream {arch} b{frames}"), frames as f64, &mut || {
            stream.infer_batch(&input).unwrap();
        });

        let stats = stream.last_stats().unwrap();
        println!(
            "{arch}: peak streamed buffering {} elems vs whole-tensor {} ({:.4})",
            stats.peak_buffered_elems(),
            stats.whole_tensor_elems,
            stats.buffered_fraction()
        );
    }
}
