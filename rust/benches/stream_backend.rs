//! Bench: the streaming executor vs the golden model, the persistent
//! frame-pipelined pool vs repeated one-shot `run_streaming` calls, the
//! row-vs-slice window-storage peak-buffering delta, the `ow_par`
//! 1-vs-2 throughput delta of the column-parallel conv workers, and the
//! elastic replica band's burst throughput vs a fixed-size pool.
//!
//! The pool comparison is the PR-3 acceptance measurement: >= 32 frames
//! through a 2-replica [`StreamPool`]-backed backend (stage threads
//! spawned once, frames pipelined through the FIFO chain) against the
//! same 32 frames paying plan + thread spawn + pipeline fill per frame.
//!
//! The pool comparison doubles as the observability-overhead guard:
//! the pooled backend is timed with the `obs` stall/occupancy probes
//! disabled and then enabled (the shipping default), and the full run
//! asserts the regression stays under 3%.  A machine-readable
//! `BENCH_stream.json` summary — including the pool's per-stage stall
//! attribution and bottleneck verdict — is written for CI tracking.
//!
//! Artifact-free.  Run: `cargo bench --bench stream_backend`
//! (`REPRO_BENCH_QUICK=1` for a short CI-ish run.)

use std::collections::BTreeMap;

use resnet_hls::data::{synth_batch, TEST_SEED};
use resnet_hls::hls::streams::StreamKind;
use resnet_hls::models::{arch_by_name, build_optimized_graph, synthetic_weights, tiednet};
use resnet_hls::runtime::{GoldenBackend, InferenceBackend, StreamBackend};
use resnet_hls::sim::golden;
use resnet_hls::stream::{run_streaming, ElasticConfig, StreamConfig, WindowStorage};
use resnet_hls::util::{Bencher, Json};

fn main() {
    let quick = std::env::var("REPRO_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut b = Bencher::new();

    // ---- single-batch: pipelined executor vs golden ----
    for (arch, frames) in [("resnet8", 8usize), ("resnet20", 2)] {
        let golden = GoldenBackend::synthetic(arch, 7, &[frames]).unwrap();
        let stream = StreamBackend::synthetic(arch, 7, &[frames]).unwrap();
        let (input, _) = synth_batch(0, frames, TEST_SEED);

        // Correctness gate before timing anything.
        let g = golden.infer_batch(&input).unwrap();
        let s = stream.infer_batch(&input).unwrap();
        assert_eq!(g.data, s.data, "{arch}: stream backend must match golden");

        b.bench_items(&format!("golden {arch} b{frames}"), frames as f64, &mut || {
            golden.infer_batch(&input).unwrap();
        });
        b.bench_items(&format!("stream {arch} b{frames}"), frames as f64, &mut || {
            stream.infer_batch(&input).unwrap();
        });

        let stats = stream.last_stats().unwrap();
        println!(
            "{arch}: peak streamed buffering {} elems vs whole-tensor {} ({:.4})",
            stats.peak_buffered_elems(),
            stats.whole_tensor_elems,
            stats.buffered_fraction()
        );
    }

    // ---- row vs slice window storage: measured peak-buffering delta ----
    println!("\n== window storage: row-granular vs slice-granular (Eq. 16/17) ==");
    for arch in ["resnet8", "resnet20"] {
        let a = arch_by_name(arch).unwrap();
        let w = synthetic_weights(&a, 7);
        let g = build_optimized_graph(&a, &w.act_exps, &w.w_exps);
        let (input, _) = synth_batch(0, 1, TEST_SEED);
        let rows_cfg =
            StreamConfig { window_storage: WindowStorage::Rows, ..Default::default() };
        let (out_rows, st_rows) = run_streaming(&g, &w, &input, &rows_cfg).unwrap();
        let (out_slices, st_slices) =
            run_streaming(&g, &w, &input, &StreamConfig::default()).unwrap();
        assert_eq!(out_rows.data, out_slices.data, "{arch}: storage modes must agree");
        let peak =
            |st: &resnet_hls::stream::StreamStats| -> usize {
                st.of_kind(StreamKind::WindowSlice).map(|b| b.peak).sum()
            };
        let (pr, ps) = (peak(&st_rows), peak(&st_slices));
        assert!(ps < pr, "{arch}: slice windows must buffer less than rows");
        println!(
            "  {arch}: window peaks {pr} elems (rows) -> {ps} (slices), \
             {:.1}% saved; total streamed peak {} -> {}",
            100.0 * (pr - ps) as f64 / pr as f64,
            st_rows.peak_buffered_elems(),
            st_slices.peak_buffered_elems(),
        );
    }

    // ---- ow_par column workers: 1-vs-2 throughput delta ----
    println!("\n== ow_par column parallelism (slice-granular, resnet8) ==");
    {
        let a = arch_by_name("resnet8").unwrap();
        let w = synthetic_weights(&a, 7);
        let g = build_optimized_graph(&a, &w.act_exps, &w.w_exps);
        let frames = 4usize;
        let (input, _) = synth_batch(0, frames, TEST_SEED);
        let golden = GoldenBackend::synthetic("resnet8", 7, &[frames]).unwrap();
        let want = golden.infer_batch(&input).unwrap();
        let mut rates = Vec::new();
        for ow_par in [1usize, 2] {
            let cfg = StreamConfig { ow_par, ..Default::default() };
            assert_eq!(
                run_streaming(&g, &w, &input, &cfg).unwrap().0.data,
                want.data,
                "ow_par={ow_par} must stay bit-exact"
            );
            let stream =
                StreamBackend::synthetic_with("resnet8", 7, &[frames], cfg).unwrap();
            let s = b.bench_items(
                &format!("stream resnet8 b{frames} ow_par={ow_par}"),
                frames as f64,
                &mut || {
                    stream.infer_batch(&input).unwrap();
                },
            );
            rates.push(s.items_per_sec());
        }
        println!(
            "  ow_par 1 -> 2: {:.0} -> {:.0} frames/s ({:.2}x)",
            rates[0],
            rates[1],
            rates[1] / rates[0]
        );
    }

    // ---- serving throughput: persistent pool vs per-call pipelines ----
    let frames = 32usize;
    let arch = arch_by_name("resnet8").unwrap();
    let weights = synthetic_weights(&arch, 7);
    let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let (input, _) = synth_batch(0, frames, TEST_SEED);

    let pooled = StreamBackend::synthetic_with(
        "resnet8",
        7,
        &[frames],
        StreamConfig { replicas: 2, ..Default::default() },
    )
    .unwrap();
    let want = GoldenBackend::synthetic("resnet8", 7, &[frames])
        .unwrap()
        .infer_batch(&input)
        .unwrap();
    assert_eq!(pooled.infer_batch(&input).unwrap().data, want.data);

    let singles: Vec<_> = (0..frames)
        .map(|i| synth_batch(i as u64, 1, TEST_SEED).0)
        .collect();

    // Observability A/B on the same warm pool: probes disabled, then
    // enabled (the shipping default).  The instrumentation must be
    // cheap enough to leave on — the acceptance guard is < 3% — but a
    // quick CI run's sample budget is too noisy to judge, so the
    // assert is full-run only (the JSON records the ratio either way).
    resnet_hls::obs::set_enabled(false);
    let s_pool_off = b.bench_items(
        "pool resnet8 32 frames (2 replicas, obs off)",
        frames as f64,
        &mut || {
            pooled.infer_batch(&input).unwrap();
        },
    );
    resnet_hls::obs::set_enabled(true);
    let s_pool = b.bench_items(
        "pool resnet8 32 frames (2 replicas, obs on)",
        frames as f64,
        &mut || {
            pooled.infer_batch(&input).unwrap();
        },
    );
    let obs_ratio = s_pool.median_ns / s_pool_off.median_ns;
    println!(
        "obs overhead on the persistent pool: {:+.2}% ({:.0} -> {:.0} frames/s)",
        100.0 * (obs_ratio - 1.0),
        s_pool_off.items_per_sec(),
        s_pool.items_per_sec()
    );
    assert!(
        quick || obs_ratio < 1.03,
        "obs instrumentation costs {:.2}% pool throughput (must stay < 3%)",
        100.0 * (obs_ratio - 1.0)
    );
    let s_once = b.bench_items(
        "one-shot run_streaming resnet8 32 x 1 frame",
        frames as f64,
        &mut || {
            for f in &singles {
                run_streaming(&g, &weights, f, &StreamConfig::default()).unwrap();
            }
        },
    );
    let speedup = s_once.median_ns / s_pool.median_ns;
    println!(
        "persistent pool vs repeated one-shot executor: {speedup:.2}x \
         ({:.0} vs {:.0} frames/s)",
        s_pool.items_per_sec(),
        s_once.items_per_sec()
    );
    assert!(
        speedup > 1.0,
        "the persistent pool must beat per-call pipelines (got {speedup:.2}x)"
    );

    let stats = pooled.last_stats().unwrap();
    println!(
        "pool buffering: peak {} elems vs replica-scaled whole-tensor {} ({:.4}), \
         {} frames served",
        stats.peak_buffered_elems(),
        stats.whole_tensor_elems,
        stats.buffered_fraction(),
        stats.frames
    );

    // ---- elastic band: burst throughput once the pool has grown ----
    // A fast-cadence 1..=2 band under the same 32-frame burst: after the
    // controller grows the pool, sustained bursts run at (about) the
    // fixed-2-replica rate while idle periods pay only one replica's
    // threads.  Correctness gate first, as everywhere else.
    let elastic = StreamBackend::synthetic_with(
        "resnet8",
        7,
        &[frames],
        StreamConfig {
            elastic: Some(ElasticConfig {
                min_replicas: 1,
                max_replicas: 2,
                high_water: Some(4),
                sample_interval: std::time::Duration::from_millis(2),
                scale_up_samples: 2,
                scale_down_samples: 10_000, // hold the grown pool for the bench
            }),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(elastic.infer_batch(&input).unwrap().data, want.data);
    let s_elastic = b.bench_items(
        "elastic pool resnet8 32 frames (band 1..=2, queue-driven)",
        frames as f64,
        &mut || {
            elastic.infer_batch(&input).unwrap();
        },
    );
    println!(
        "elastic band 1..=2 vs fixed 2 replicas: {:.0} vs {:.0} frames/s \
         (live replicas {}, peak {})",
        s_elastic.items_per_sec(),
        s_pool.items_per_sec(),
        elastic.pool().replicas(),
        elastic.pool().peak_replicas()
    );

    // ---- shared worker budget: lease accounting must be free ----
    // The same fixed 2-replica pool, now leasing its stage workers from
    // a process-wide WorkerBudget (the multi-tenant substrate).  The
    // budget sits on the replica *scaling* path, not the frame path —
    // one mutex acquire per replica spawn/retire, nothing per frame —
    // so serving throughput must stay within 3% of the unbudgeted pool.
    // Quick CI runs are too noisy to judge; the assert is full-run only
    // (the JSON records the ratio either way).
    let budget = std::sync::Arc::new(resnet_hls::stream::WorkerBudget::new(1024));
    let budgeted = StreamBackend::synthetic_with(
        "resnet8",
        7,
        &[frames],
        StreamConfig {
            replicas: 2,
            budget: Some(budget.clone()),
            ..Default::default()
        },
    )
    .unwrap();
    assert_eq!(budgeted.infer_batch(&input).unwrap().data, want.data);
    let s_budgeted = b.bench_items(
        "budgeted pool resnet8 32 frames (2 replicas, shared WorkerBudget)",
        frames as f64,
        &mut || {
            budgeted.infer_batch(&input).unwrap();
        },
    );
    let budget_ratio = s_budgeted.median_ns / s_pool.median_ns;
    let bsnap = budget.snapshot();
    println!(
        "shared budget vs unbudgeted pool: {:+.2}% ({:.0} -> {:.0} frames/s); \
         {} of {} workers leased ({:.0}% util)",
        100.0 * (budget_ratio - 1.0),
        s_pool.items_per_sec(),
        s_budgeted.items_per_sec(),
        bsnap.held,
        bsnap.total,
        100.0 * bsnap.utilization()
    );
    assert!(
        quick || budget_ratio < 1.03,
        "worker-budget leasing costs {:.2}% pool throughput (must stay < 3%)",
        100.0 * (budget_ratio - 1.0)
    );
    {
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        o.insert("bench".into(), Json::Str("stream_backend_multitenant".into()));
        o.insert("quick".into(), Json::Bool(quick));
        o.insert("frames_per_batch".into(), Json::Int(frames as i64));
        o.insert("pool_fps_unbudgeted".into(), Json::Float(s_pool.items_per_sec()));
        o.insert("pool_fps_budgeted".into(), Json::Float(s_budgeted.items_per_sec()));
        o.insert("budget_overhead_ratio".into(), Json::Float(budget_ratio));
        o.insert("budget".into(), bsnap.to_json());
        let j = Json::Object(o);
        std::fs::write("BENCH_multitenant.json", format!("{j}\n"))
            .expect("write BENCH_multitenant.json");
        println!("wrote BENCH_multitenant.json");
    }

    // ---- weight-tied depth sweep: throughput vs N at constant params ----
    // The ODE-style trade the ROADMAP names: tiednet(N) repeats one
    // residual block N times around the same two parameter blobs, so
    // depth costs pipeline stages (throughput), never memory.  Each
    // depth is correctness-gated against golden before timing; the
    // parameter footprint is asserted byte-identical across the sweep.
    println!("\n== weight-tied repeated blocks (tiednet, shared blobs) ==");
    let tie_frames = 2usize;
    let (tie_input, _) = synth_batch(0, tie_frames, TEST_SEED);
    let mut tie_fps: BTreeMap<String, Json> = BTreeMap::new();
    let mut tie_bytes = None;
    for n in [1usize, 2, 4] {
        let arch = tiednet(n);
        let w = synthetic_weights(&arch, 7);
        match tie_bytes {
            None => tie_bytes = Some(w.param_bytes()),
            Some(b) => assert_eq!(
                w.param_bytes(),
                b,
                "tiednet({n}): weight tying must hold param bytes constant"
            ),
        }
        let g = build_optimized_graph(&arch, &w.act_exps, &w.w_exps);
        let want = golden::run(&g, &w, &tie_input).unwrap();
        let (out, _) = run_streaming(&g, &w, &tie_input, &StreamConfig::default()).unwrap();
        assert_eq!(out.data, want.data, "tiednet({n}): stream must match golden");
        let s = b.bench_items(
            &format!("stream tiednet N={n} b{tie_frames}"),
            tie_frames as f64,
            &mut || {
                run_streaming(&g, &w, &tie_input, &StreamConfig::default()).unwrap();
            },
        );
        tie_fps.insert(format!("n{n}"), Json::Float(s.items_per_sec()));
    }
    {
        let mut o: BTreeMap<String, Json> = BTreeMap::new();
        o.insert("bench".into(), Json::Str("stream_weighttied".into()));
        o.insert("quick".into(), Json::Bool(quick));
        o.insert("frames_per_batch".into(), Json::Int(tie_frames as i64));
        o.insert(
            "param_bytes".into(),
            Json::Int(tie_bytes.expect("sweep ran") as i64),
        );
        o.insert("fps".into(), Json::Object(tie_fps));
        let j = Json::Object(o);
        std::fs::write("BENCH_weighttied.json", format!("{j}\n"))
            .expect("write BENCH_weighttied.json");
        println!("wrote BENCH_weighttied.json");
    }

    // ---- machine-readable summary ----
    // The stall report rides along so CI trends don't just say "slower"
    // but *which stage* went slower: per-stage busy/blocked fractions,
    // per-FIFO blocked time and occupancy, and the bottleneck verdict.
    let report = pooled.pool().stall_report();
    let bottleneck = report.bottleneck();
    let mut o: BTreeMap<String, Json> = BTreeMap::new();
    o.insert("bench".into(), Json::Str("stream_backend".into()));
    o.insert("quick".into(), Json::Bool(quick));
    o.insert("frames_per_batch".into(), Json::Int(frames as i64));
    o.insert("pool_fps_obs_off".into(), Json::Float(s_pool_off.items_per_sec()));
    o.insert("pool_fps_obs_on".into(), Json::Float(s_pool.items_per_sec()));
    o.insert("obs_overhead_ratio".into(), Json::Float(obs_ratio));
    o.insert("pool_vs_oneshot_speedup".into(), Json::Float(speedup));
    o.insert("oneshot_fps".into(), Json::Float(s_once.items_per_sec()));
    o.insert("elastic_fps".into(), Json::Float(s_elastic.items_per_sec()));
    o.insert("bottleneck".into(), Json::Str(bottleneck.to_string()));
    o.insert("stalls".into(), report.to_json());
    let j = Json::Object(o);
    std::fs::write("BENCH_stream.json", format!("{j}\n")).expect("write BENCH_stream.json");
    println!("wrote BENCH_stream.json");
}
