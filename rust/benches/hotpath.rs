//! Bench: the L3 hot paths — the instrument for the performance pass
//! (EXPERIMENTS.md §Perf).  Each entry is one optimization target.
//!
//! Run: `cargo bench --bench hotpath`

use resnet_hls::coordinator::{Batcher, BatcherConfig};
use resnet_hls::data::{synth_batch, TEST_SEED};
use resnet_hls::hls::config::configure;
use resnet_hls::hls::ULTRA96;
use resnet_hls::ilp::{loads_from_arch, solve};
use resnet_hls::models::{arch_by_name, build_optimized_graph, default_exps, synthetic_weights};
use resnet_hls::sim::{build_network, golden, SimOptions};
use resnet_hls::util::bench::black_box;
use resnet_hls::util::{Bencher, Json};

fn main() {
    let mut b = Bencher::new();

    // 1. Golden int8 conv (the numerics hot loop).
    let arch = arch_by_name("resnet8").unwrap();
    let weights = synthetic_weights(&arch, 5);
    let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let (input1, _) = synth_batch(0, 1, TEST_SEED);
    let macs = arch.total_macs() as f64;
    b.bench_items("golden resnet8 1 frame (MACs/s)", macs, &mut || {
        black_box(golden::run(&g, &weights, &input1).unwrap());
    });

    // 2. Simulator engine (task-steps/s over a full resnet20 frame).
    let arch20 = arch_by_name("resnet20").unwrap();
    let (act, w) = default_exps(&arch20);
    let g20 = build_optimized_graph(&arch20, &act, &w);
    let loads = loads_from_arch(&arch20, 2);
    let alloc = solve(&loads, 1248).unwrap();
    let cfg = configure(&arch20.name, &g20, &alloc, &ULTRA96, 2).unwrap();
    b.bench("sim resnet20 3 frames", || {
        let mut net =
            build_network(&g20, &cfg, &SimOptions { frames: 3, ..Default::default() }).unwrap();
        let rep = net.run(3);
        assert!(!rep.deadlocked);
    });

    // 3. Batcher planning (request-path, must be ~ns).
    let batcher = Batcher::new(BatcherConfig::default());
    b.bench("batcher plan(70)", || {
        black_box(batcher.plan(black_box(70)));
    });

    // 4. Manifest JSON parse (startup path).
    let manifest = std::fs::read_to_string(resnet_hls::paths::artifacts_dir().join("manifest.json"))
        .unwrap_or_else(|_| "{\"models\":[]}".into());
    b.bench("manifest json parse", || {
        black_box(Json::parse(black_box(&manifest)).unwrap());
    });

    // 5. Full design flow (tooling path).
    b.bench("fit_to_board resnet20@Ultra96", || {
        resnet_hls::hls::resources::fit_to_board(&arch20.name, &g20, &loads, &ULTRA96, 2).unwrap();
    });

    // 6. ILP solve.
    b.bench("ilp solve resnet20@1248", || {
        black_box(solve(black_box(&loads), 1248));
    });
}
