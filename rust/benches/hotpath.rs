//! Bench: the L3 hot paths — the instrument for the performance pass
//! (EXPERIMENTS.md §Perf).  Each entry is one optimization target.
//!
//! Also the observability-overhead guard: the streaming executor's
//! per-token FIFO push/pop is timed with the `obs` instrumentation
//! disabled and enabled, and the full run (`REPRO_BENCH_QUICK` unset)
//! asserts the probe is cheap enough to leave on.  A machine-readable
//! `BENCH_hotpath.json` summary is written for CI trend tracking.
//!
//! Run: `cargo bench --bench hotpath`
//! (`REPRO_BENCH_QUICK=1` for a short CI-ish run.)

use std::collections::BTreeMap;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

use resnet_hls::coordinator::{Batcher, BatcherConfig};
use resnet_hls::data::{synth_batch, TEST_SEED};
use resnet_hls::hls::config::configure;
use resnet_hls::hls::streams::StreamKind;
use resnet_hls::hls::ULTRA96;
use resnet_hls::ilp::{loads_from_arch, solve};
use resnet_hls::models::{arch_by_name, build_optimized_graph, default_exps, synthetic_weights};
use resnet_hls::sim::{build_network, golden, SimOptions};
use resnet_hls::stream::Fifo;
use resnet_hls::util::bench::black_box;
use resnet_hls::util::{Bencher, Json};

fn main() {
    let quick = std::env::var("REPRO_BENCH_QUICK").ok().as_deref() == Some("1");
    let mut b = Bencher::new();

    // 1. Golden int8 conv (the numerics hot loop).
    let arch = arch_by_name("resnet8").unwrap();
    let weights = synthetic_weights(&arch, 5);
    let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let (input1, _) = synth_batch(0, 1, TEST_SEED);
    let macs = arch.total_macs() as f64;
    let s_golden = b.bench_items("golden resnet8 1 frame (MACs/s)", macs, &mut || {
        black_box(golden::run(&g, &weights, &input1).unwrap());
    });

    // 2. Simulator engine (task-steps/s over a full resnet20 frame).
    let arch20 = arch_by_name("resnet20").unwrap();
    let (act, w) = default_exps(&arch20);
    let g20 = build_optimized_graph(&arch20, &act, &w);
    let loads = loads_from_arch(&arch20, 2);
    let alloc = solve(&loads, 1248).unwrap();
    let cfg = configure(&arch20.name, &g20, &alloc, &ULTRA96, 2).unwrap();
    let s_sim = b.bench("sim resnet20 3 frames", || {
        let mut net =
            build_network(&g20, &cfg, &SimOptions { frames: 3, ..Default::default() }).unwrap();
        let rep = net.run(3);
        assert!(!rep.deadlocked);
    });

    // 3. Batcher planning (request-path, must be ~ns).
    let batcher = Batcher::new(BatcherConfig::default());
    let s_plan = b.bench("batcher plan(70)", || {
        black_box(batcher.plan(black_box(70)));
    });

    // 4. Manifest JSON parse (startup path).
    let manifest = std::fs::read_to_string(resnet_hls::paths::artifacts_dir().join("manifest.json"))
        .unwrap_or_else(|_| "{\"models\":[]}".into());
    let s_json = b.bench("manifest json parse", || {
        black_box(Json::parse(black_box(&manifest)).unwrap());
    });

    // 5. Full design flow (tooling path).
    let s_fit = b.bench("fit_to_board resnet20@Ultra96", || {
        resnet_hls::hls::resources::fit_to_board(&arch20.name, &g20, &loads, &ULTRA96, 2).unwrap();
    });

    // 6. ILP solve.
    let s_ilp = b.bench("ilp solve resnet20@1248", || {
        black_box(solve(black_box(&loads), 1248));
    });

    // 7. Instrumented FIFO push/pop — the streaming executor's per-token
    //    hot path — with stall/occupancy observability off vs on.  The
    //    uncontended path costs one relaxed histogram increment when the
    //    probe is enabled; the token is recycled so neither side pays an
    //    allocation.  The guard keeps the probe honest about "cheap
    //    enough to leave on" (quick CI runs are too noisy to judge).
    const OPS: usize = 4096;
    let abort = Arc::new(AtomicBool::new(false));
    let fifo = Fifo::new(
        "bench.edge".into(),
        StreamKind::Output,
        64,
        abort,
        Duration::from_secs(10),
    );
    let mut tok: Box<[i32]> = vec![0i32; 4].into_boxed_slice();
    let mut pingpong = || {
        for _ in 0..OPS {
            fifo.push(std::mem::replace(&mut tok, Box::new([]))).unwrap();
            tok = fifo.pop().unwrap();
        }
    };
    let was_enabled = resnet_hls::obs::enabled();
    resnet_hls::obs::set_enabled(false);
    let s_off = b.bench_items("fifo push+pop x4096 (obs off)", OPS as f64, &mut pingpong);
    resnet_hls::obs::set_enabled(true);
    let s_on = b.bench_items("fifo push+pop x4096 (obs on)", OPS as f64, &mut pingpong);
    resnet_hls::obs::set_enabled(was_enabled);
    let op_off = s_off.median_ns / OPS as f64;
    let op_on = s_on.median_ns / OPS as f64;
    let ratio = s_on.median_ns / s_off.median_ns;
    println!(
        "fifo op: {op_off:.1} ns (obs off) -> {op_on:.1} ns (obs on), {:.1}% overhead",
        100.0 * (ratio - 1.0)
    );
    assert!(
        quick || ratio < 1.5,
        "obs probe too expensive on the FIFO hot path: {ratio:.2}x (must stay < 1.5x)"
    );

    // ---- machine-readable summary ----
    let mut o: BTreeMap<String, Json> = BTreeMap::new();
    o.insert("bench".into(), Json::Str("hotpath".into()));
    o.insert("quick".into(), Json::Bool(quick));
    o.insert("golden_resnet8_macs_per_sec".into(), Json::Float(s_golden.items_per_sec()));
    o.insert("sim_resnet20_3f_median_ns".into(), Json::Float(s_sim.median_ns));
    o.insert("batcher_plan_median_ns".into(), Json::Float(s_plan.median_ns));
    o.insert("manifest_parse_median_ns".into(), Json::Float(s_json.median_ns));
    o.insert("fit_to_board_median_ns".into(), Json::Float(s_fit.median_ns));
    o.insert("ilp_solve_median_ns".into(), Json::Float(s_ilp.median_ns));
    o.insert("fifo_op_ns_obs_off".into(), Json::Float(op_off));
    o.insert("fifo_op_ns_obs_on".into(), Json::Float(op_on));
    o.insert("obs_overhead_ratio".into(), Json::Float(ratio));
    let j = Json::Object(o);
    std::fs::write("BENCH_hotpath.json", format!("{j}\n")).expect("write BENCH_hotpath.json");
    println!("wrote BENCH_hotpath.json: {j}");
}
