//! Bench: regenerate paper Table 3 (performance rows, modeled vs paper)
//! and time the full design flow per row.
//!
//! Run: `cargo bench --bench table3`

use resnet_hls::eval::tables::{print_table3, table3};
use resnet_hls::hls::boards::{KV260, ULTRA96};
use resnet_hls::util::Bencher;

fn main() {
    let rows = table3().expect("table3");
    print_table3(&rows);

    // Shape assertions (the reproduction criteria of DESIGN.md E1/E8).
    let get = |label: &str, board: &str| {
        rows.iter()
            .find(|r| r.label.contains(label) && r.board == board)
            .unwrap_or_else(|| panic!("row {label}@{board}"))
    };
    let our8kv = get("resnet8 CNN", "KV260");
    let our20kv = get("resnet20 CNN", "KV260");
    let our8u96 = get("resnet8 CNN", "Ultra96");
    let our20u96 = get("resnet20 CNN", "Ultra96");
    let overlay = get("overlay", "KV260");
    let finn = get("FINN", "KV260");
    let adder = get("AdderNet", "KV260");

    println!("\n== shape checks (who wins, by roughly what factor) ==");
    let checks: Vec<(String, f64, f64, f64)> = vec![
        ("resnet8 > resnet20 FPS (paper 3.97x)".into(), our8kv.fps / our20kv.fps, 2.0, 6.0),
        ("KV260 > Ultra96 resnet8 (paper 2.32x)".into(), our8kv.fps / our8u96.fps, 1.3, 4.0),
        ("KV260 > Ultra96 resnet20 (paper 2.34x)".into(), our20kv.fps / our20u96.fps, 1.3, 4.0),
        ("our latency << overlay (paper 28x)".into(), overlay.latency_ms / our8kv.latency_ms, 8.0, 100.0),
        ("our FPS > FINN 4-bit (paper 2.2x)".into(), our8kv.fps / finn.fps, 1.2, 6.0),
        ("our Gops > AdderNet (paper 1.9x)".into(), our20kv.gops / adder.gops, 1.2, 4.0),
    ];
    let mut ok = true;
    for (name, val, lo, hi) in checks {
        let pass = (lo..=hi).contains(&val);
        ok &= pass;
        println!("  [{}] {name}: {val:.2} (band {lo}-{hi})", if pass { "ok" } else { "FAIL" });
    }
    assert!(ok, "table 3 shape checks failed");

    // Timing: the full design flow per (model, board).
    let mut b = Bencher::new();
    b.bench("flow: resnet8@KV260 (passes+ILP+closure+sim)", || {
        resnet_hls::eval::tables::our_design("resnet8", &KV260).unwrap();
    });
    b.bench("flow: resnet20@Ultra96", || {
        resnet_hls::eval::tables::our_design("resnet20", &ULTRA96).unwrap();
    });
}
