//! Bench: Figs. 11/14 + Eq. 23 — residual buffering, analytic and
//! dynamic (simulator-measured FIFO occupancy), plus window-buffer
//! partitioning (Figs. 7/9).
//!
//! Run: `cargo bench --bench fig_buffering`

use resnet_hls::eval::figures::{skip_buffering_series, window_figure};
use resnet_hls::hls::config::configure;
use resnet_hls::hls::ULTRA96;
use resnet_hls::ilp::{loads_from_arch, solve};
use resnet_hls::models::{
    arch_by_name, build_optimized_graph, build_unoptimized_graph, default_exps,
};
use resnet_hls::sim::{build_network, SimOptions};
use resnet_hls::util::Bencher;

fn main() {
    for model in ["resnet8", "resnet20"] {
        let arch = arch_by_name(model).unwrap();
        println!("== {model}: Eq. 23 skip buffering (analytic) ==");
        let mut naive_t = 0usize;
        let mut opt_t = 0usize;
        for (name, naive, opt, r) in skip_buffering_series(&arch) {
            println!("  {name:<8} naive {naive:>6}  opt {opt:>6}  R_sc {r:.3}");
            naive_t += naive;
            opt_t += opt;
            assert!((0.45..=0.55).contains(&r));
        }
        println!("  total: {naive_t} -> {opt_t} ({:.3})", opt_t as f64 / naive_t as f64);
    }

    println!("\n== Figs. 7/9: window buffer slice sizes (stem, 32x32x3) ==");
    for ow_par in [1usize, 2] {
        let sizes = window_figure(3, 32, 3, ow_par).unwrap();
        println!("  ow_par={ow_par}: {} slices {:?}", sizes.len(), sizes);
    }

    // The executor's slice-chain view of the same buffer: fill a
    // SliceWindow to the full Eq. 17 span and show how the buffered
    // elements (beyond the in-flight pixel) occupy the configured chain.
    {
        let (k, iw, ich, ow_par) = (3usize, 32usize, 3usize, 2usize);
        let plan = resnet_hls::hls::window::slice_plan(k, k, iw, ich, ow_par).unwrap();
        let mut win = resnet_hls::stream::SliceWindow::new(ich, &plan);
        let span_pixels = plan.total() / ich + 1;
        for i in 0..span_pixels {
            win.push_pixel(std::sync::Arc::from(vec![i as i32; ich]));
        }
        let occ = win.slice_occupancy();
        assert_eq!(occ.iter().sum::<usize>(), plan.total());
        assert_eq!(win.held(), plan.total() + ich);
        println!(
            "  full span ({} elems + {ich} in flight): chain occupancy {:?}",
            plan.total(),
            occ
        );
    }

    // Row-granular (legacy executor) vs slice-granular (Eq. 16/17) window
    // storage: the per-layer and total peak-buffering delta the stream
    // executor now realizes at execution time (ow_par = 2 spans, plus the
    // in-flight pixel each).
    println!("\n== window storage bound: rows (fh*iw*ich) vs slice span (Eq. 16/17) ==");
    for model in ["resnet8", "resnet20"] {
        let arch = arch_by_name(model).unwrap();
        let mut rows_total = 0usize;
        let mut slice_total = 0usize;
        for c in arch.conv_layers() {
            let ow_par = 2;
            let rows = c.k * c.in_w * c.cin;
            let span = resnet_hls::hls::window::buffer_size(c.k, c.k, c.in_w, c.cin, ow_par)
                .unwrap()
                + c.cin;
            rows_total += rows;
            slice_total += span;
        }
        assert!(slice_total < rows_total);
        println!(
            "  {model}: {rows_total} elems (rows) -> {slice_total} (slices), {}% saved",
            100 * (rows_total - slice_total) / rows_total
        );
    }

    // Ablation: the paper's stated future work -- rate-aware partition
    // merging (Section III-F last paragraph).  Layers whose computation
    // consumes one window every ich*och_groups cycles can time-multiplex
    // FIFO reads; the split shrinks with zero throughput cost.
    println!("\n== ablation: rate-aware window partitioning (future work, implemented) ==");
    let arch20 = arch_by_name("resnet20").unwrap();
    let mut full_total = 0usize;
    let mut merged_total = 0usize;
    for c in arch20.conv_layers() {
        let interval = c.cin * 4; // och_groups >= 4 across the balanced allocs
        let full = resnet_hls::hls::window::slice_plan(c.k, c.k, c.in_w, c.cin, 2).unwrap();
        let merged = resnet_hls::hls::window::slice_plan_rate_aware(
            c.k, c.k, c.in_w, c.cin, 2, interval,
        )
        .unwrap();
        full_total += full.slices();
        merged_total += merged.slices();
    }
    println!(
        "  resnet20: {} FIFO slices -> {} ({}% fewer window-task FIFOs)",
        full_total,
        merged_total,
        100 * (full_total - merged_total) / full_total.max(1)
    );
    assert!(merged_total < full_total);

    // Dynamic measurement: simulator FIFO peak occupancy vs the bounds.
    println!("\n== dynamic: simulator-measured skip-FIFO peaks (resnet8 @ Ultra96) ==");
    let arch = arch_by_name("resnet8").unwrap();
    let (act, w) = default_exps(&arch);
    let loads = loads_from_arch(&arch, 2);
    let alloc = solve(&loads, ULTRA96.n_par() as u64).unwrap();

    let g = build_unoptimized_graph(&arch, &act, &w);
    let cfg = configure(&arch.name, &g, &alloc, &ULTRA96, 2).unwrap();
    let mut net = build_network(&g, &cfg, &SimOptions { frames: 2, ..Default::default() }).unwrap();
    let rep = net.run(2);
    assert!(!rep.deadlocked);
    for f in rep
        .fifo_stats
        .iter()
        .filter(|f| f.name.contains("_add") && f.name.contains("tee"))
    {
        println!(
            "  naive {:<34} cap {:>6} peak {:>6} ({:.0}%)",
            f.name,
            f.capacity,
            f.max_occupancy,
            100.0 * f.max_occupancy as f64 / f.capacity as f64
        );
    }
    let g = build_optimized_graph(&arch, &act, &w);
    let cfg = configure(&arch.name, &g, &alloc, &ULTRA96, 2).unwrap();
    let mut net = build_network(&g, &cfg, &SimOptions { frames: 2, ..Default::default() }).unwrap();
    let rep = net.run(2);
    assert!(!rep.deadlocked);
    for f in rep.fifo_stats.iter().filter(|f| f.name.contains(".1 ->")) {
        println!("  opt   {:<34} cap {:>6} peak {:>6}", f.name, f.capacity, f.max_occupancy);
    }

    // Timing: simulation speed for the buffering experiment.
    let mut b = Bencher::new();
    b.bench("sim: resnet8 naive 2 frames", || {
        let g = build_unoptimized_graph(&arch, &act, &w);
        let cfg = configure(&arch.name, &g, &alloc, &ULTRA96, 2).unwrap();
        let mut net =
            build_network(&g, &cfg, &SimOptions { frames: 2, ..Default::default() }).unwrap();
        let rep = net.run(2);
        assert!(!rep.deadlocked);
    });
}
