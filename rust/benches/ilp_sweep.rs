//! Bench: Algorithm 1 — throughput vs DSP budget sweep + solver timing.
//!
//! Run: `cargo bench --bench ilp_sweep`

use resnet_hls::eval::figures::ilp_sweep;
use resnet_hls::ilp::{loads_from_arch, solve};
use resnet_hls::models::arch_by_name;
use resnet_hls::util::bench::black_box;
use resnet_hls::util::Bencher;

fn main() {
    for model in ["resnet8", "resnet20"] {
        println!("== {model}: Alg. 1 throughput vs N_PAR ==");
        println!("{:>8} {:>14} {:>8} {:>12}", "N_PAR", "frames/Mcycle", "DSPs", "FPS@274MHz");
        let budgets: Vec<u64> = vec![72, 96, 128, 180, 256, 360, 512, 724, 1024, 1248, 2048];
        let pts = ilp_sweep(model, &budgets, 2);
        for (b, fpm, dsps) in &pts {
            println!("{b:>8} {fpm:>14.4} {dsps:>8} {:>12.0}", fpm * 274.0);
        }
        // Monotone in budget.
        for w in pts.windows(2) {
            assert!(w[1].1 >= w[0].1 - 1e-12);
        }
        // Diminishing returns: och caps flatten the top end.
        if pts.len() >= 3 {
            let (_, first, _) = pts[0];
            let (_, last, _) = pts[pts.len() - 1];
            assert!(last > first, "more budget must help somewhere");
        }
    }

    let mut b = Bencher::new();
    let arch = arch_by_name("resnet20").unwrap();
    let loads = loads_from_arch(&arch, 2);
    b.bench("ilp solve resnet20 @1248", || {
        black_box(solve(black_box(&loads), 1248));
    });
    b.bench("ilp solve resnet20 @360", || {
        black_box(solve(black_box(&loads), 360));
    });
}
