//! `repro` — CLI for the ResNet-HLS reproduction.
//!
//! Subcommands:
//!   info                         artifacts + design summary
//!   optimize   --model M --board B [--ow-par N]   run Algorithm 1 + closure
//!   simulate   --model M --board B [--naive] [--skip-factor F] [--frames N]
//!   codegen    --model M --board B [--out FILE]   emit the HLS C++ top
//!   eval-tables                  Table 3 + Table 4 (modeled vs paper)
//!   golden-eval [--model M] [--n N]               golden accuracy on synthetic test set
//!   probe-check                  cross-language bit-equality (golden vs oracle vs PJRT)
//!   serve      [--model M[,M2...]] [--frames N] [--backend pjrt|golden|sim|stream]
//!              [--workers N] [--replicas B | --min-replicas A --max-replicas B]
//!              [--ow-par N] [--window-storage rows|slices] [--worker-budget W]
//!                                route synthetic frames through the inference router
//!                                (stream: B persistent pipeline replicas per worker —
//!                                or an elastic A..=B band scaled under the router's
//!                                queue-depth signal — ow_par window groups + column
//!                                workers, slice-granular Eq. 16/17 window buffers by
//!                                default); a comma-separated --model serves several
//!                                arches behind one router, and --worker-budget caps
//!                                total stage workers across all their stream pools
//!                                (see README "Multi-tenant serving")
//!   buffers    [--model M]       Eq. 21/22/23 per residual block, plus the
//!                                streaming executor's measured peak occupancy
//!   listen     [--host H] [--port P] [--backend ...] [--workers N]
//!              [--queue-cap N] [--dispatchers N] [--deadline-ms D]
//!              [--duration-s S] [--metrics-port P] [serve's backend flags,
//!              including --model M[,M2...] and --worker-budget W]
//!                                TCP ingress front-end ahead of the router:
//!                                bounded admission, load-shedding with
//!                                retry-after, deadlines enforced at admission
//!                                and at dequeue (see README "Network ingress");
//!                                --metrics-port adds the HTTP exposition
//!                                endpoint (/metrics Prometheus, /stats.json)
//!   stats      [--addr H:P | --model M [--frames N] [--replicas B]
//!              [--ow-par N] [--window-storage rows|slices]] [--json]
//!                                pipeline observability: with --addr, scrape a
//!                                running `listen --metrics-port` endpoint
//!                                (Prometheus text, or /stats.json with --json);
//!                                otherwise profile a local stream pool on
//!                                synthetic frames and print per-stage stall
//!                                attribution, per-FIFO occupancy and the
//!                                bottleneck verdict (see README
//!                                "Observability")
//!   client     [--addr H:P] [--model M] [--frames N] [--fps F]
//!              [--deadline-ms D] [--window W]
//!                                stream synthetic CIFAR frames at a target FPS;
//!                                prints p50/p95/p99 latency + shed rate and
//!                                fails unless every request is accounted for
//!   verify     [--model M | --qonnx FILE] [--board B] [--ow-par N] [--naive]
//!              [--skip-capacity N] [--json]
//!                                static pipeline verification before any thread
//!                                spawns: FIFO deadlock-freedom (Eq. 21/22 depth
//!                                bounds, naming the undersized edge and its
//!                                minimum safe depth — Fig. 14 as a diagnostic),
//!                                i32 accumulator range analysis from the actual
//!                                weight blobs, and Eq. 16/17 window feasibility;
//!                                exits nonzero when rejected (see README
//!                                "Static verification")

use anyhow::Result;

use resnet_hls::coordinator::{Router, RouterConfig};
use resnet_hls::data::{synth_batch, TEST_SEED};
use resnet_hls::eval::figures::skip_buffering_series;
use resnet_hls::eval::tables::{print_table3, print_table4, table3, table4};
use resnet_hls::hls::{board_by_name, codegen, config::configure, resources::fit_to_board, ULTRA96};
use resnet_hls::ilp::loads_from_arch;
use resnet_hls::models::{
    arch_by_name, build_optimized_graph, default_exps, synthetic_weights, ModelWeights,
};
use resnet_hls::net::{drive, DriveConfig, IngressServer, ServerConfig};
use resnet_hls::paths::artifacts_dir;
use resnet_hls::runtime::{
    Artifacts, BackendFactory, Engine, GoldenFactory, InferenceBackend, PjrtFactory, SimFactory,
    StreamBackend, StreamFactory,
};
use resnet_hls::sim::{build_network, golden, SimOptions};
use resnet_hls::util::cli::Args;

fn main() {
    let args = Args::parse(
        std::env::args().skip(1),
        &[
            "model", "board", "frames", "n", "out", "skip-factor", "ow-par", "budget", "backend",
            "workers", "replicas", "min-replicas", "max-replicas", "window-storage", "host",
            "port", "queue-cap", "dispatchers", "deadline-ms", "duration-s", "addr", "fps",
            "window", "qonnx", "skip-capacity", "metrics-port", "worker-budget",
        ],
    );
    let result = match args.subcommand.as_deref() {
        Some("info") => cmd_info(),
        Some("optimize") => cmd_optimize(&args),
        Some("simulate") => cmd_simulate(&args),
        Some("codegen") => cmd_codegen(&args),
        Some("eval-tables") => cmd_eval_tables(),
        Some("golden-eval") => cmd_golden_eval(&args),
        Some("probe-check") => cmd_probe_check(),
        Some("serve") => cmd_serve(&args),
        Some("listen") => cmd_listen(&args),
        Some("client") => cmd_client(&args),
        Some("buffers") => cmd_buffers(&args),
        Some("verify") => cmd_verify(&args),
        Some("stats") => cmd_stats(&args),
        _ => {
            eprintln!(
                "usage: repro <info|optimize|simulate|codegen|eval-tables|golden-eval|probe-check|serve|listen|client|buffers|verify|stats> [options]"
            );
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn arch_of(args: &Args) -> Result<resnet_hls::models::ArchSpec> {
    let name = args.opt_or("model", "resnet8");
    arch_by_name(name).ok_or_else(|| anyhow::anyhow!("unknown model {name}"))
}

/// `serve`/`listen` accept a comma-separated `--model resnet8,resnet20`:
/// every listed architecture gets its own worker pool behind one router.
fn archs_of(args: &Args) -> Result<Vec<resnet_hls::models::ArchSpec>> {
    let names = args.opt_or("model", "resnet8");
    let mut archs = Vec::new();
    for name in names.split(',').map(str::trim).filter(|n| !n.is_empty()) {
        anyhow::ensure!(
            archs.iter().all(|a: &resnet_hls::models::ArchSpec| a.name != name),
            "--model lists {name} twice"
        );
        archs.push(arch_by_name(name).ok_or_else(|| anyhow::anyhow!("unknown model {name}"))?);
    }
    anyhow::ensure!(!archs.is_empty(), "--model lists no architecture");
    Ok(archs)
}

/// `--worker-budget N`: one process-wide [`WorkerBudget`] shared by every
/// stream pool behind the router (absent/0 = unbudgeted).  A budget that
/// cannot cover the sum of the pools' `min_replicas x stages` reservations
/// is rejected with the typed `BudgetError` when `Router::start` builds
/// the pools.
fn worker_budget_of(
    args: &Args,
) -> Result<Option<std::sync::Arc<resnet_hls::stream::WorkerBudget>>> {
    let n = args.opt_usize("worker-budget", 0);
    if n == 0 {
        return Ok(None);
    }
    anyhow::ensure!(
        args.opt_or("backend", "pjrt") == "stream",
        "--worker-budget leases stream-pool stage workers; it requires --backend stream"
    );
    Ok(Some(std::sync::Arc::new(resnet_hls::stream::WorkerBudget::new(n))))
}

fn board_of(args: &Args) -> &'static resnet_hls::hls::Board {
    board_by_name(args.opt_or("board", "kv260")).unwrap_or(&ULTRA96)
}

fn cmd_info() -> Result<()> {
    println!("resnet-hls repro — paper: Minnella et al., 2023 (FPGA ResNet HLS)");
    let dir = artifacts_dir();
    match Artifacts::load(&dir) {
        Ok(a) => {
            println!("artifacts: {} ({} model variants)", dir.display(), a.models.len());
            for m in &a.models {
                println!(
                    "  {} arch={} batch={} input={:?} ({})",
                    m.name,
                    m.arch,
                    m.batch,
                    m.input_shape,
                    m.hlo_path.file_name().unwrap_or_default().to_string_lossy()
                );
            }
            for arch in a.arch_names() {
                let w = ModelWeights::load(&dir, &arch)?;
                println!(
                    "  weights[{arch}]: {} layers, {} bytes, source={}",
                    w.layers.len(),
                    w.param_bytes(),
                    w.source
                );
            }
        }
        Err(e) => println!("artifacts: not available ({e}) — run `make artifacts`"),
    }
    Ok(())
}

fn cmd_optimize(args: &Args) -> Result<()> {
    let arch = arch_of(args)?;
    let board = board_of(args);
    let ow_par = args.opt_usize("ow-par", 2);
    let (act, w) = default_exps(&arch);
    let g = build_optimized_graph(&arch, &act, &w);
    let loads = loads_from_arch(&arch, ow_par);
    let (alloc, cfg, report) = fit_to_board(&arch.name, &g, &loads, board, ow_par)?;
    println!(
        "== {} on {} (ow_par={ow_par}, N_PAR={}) ==",
        arch.name,
        board.name,
        board.n_par()
    );
    println!(
        "{:<10} {:>8} {:>8} {:>6} {:>10} {:>10}",
        "layer", "och_par", "cp", "DSPs", "cycles", "macs"
    );
    for l in &alloc.layers {
        println!(
            "{:<10} {:>8} {:>8} {:>6} {:>10} {:>10}",
            l.name, l.och_par, l.cp, l.dsps, l.cycles,
            loads.iter().find(|x| x.name == l.name).map(|x| x.macs).unwrap_or(0)
        );
    }
    println!(
        "bottleneck {} cycles/frame -> {:.0} FPS @ {:.0} MHz ({:.0} Gops/s)",
        alloc.cycles_per_frame,
        alloc.fps(board.clock_mhz),
        board.clock_mhz,
        alloc.gops(board.clock_mhz, arch.total_macs())
    );
    println!("resources: {}", report.utilization(board));
    println!("skip buffering total: {} activations", cfg.skip_buffer_total());
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let arch = arch_of(args)?;
    let board = board_of(args);
    let naive = args.has_flag("naive");
    let frames = args.opt_usize("frames", 4) as u32;
    let skip_factor = args.opt_f64("skip-factor", 1.0);
    let (act, w) = default_exps(&arch);
    let g = if naive {
        resnet_hls::models::build_unoptimized_graph(&arch, &act, &w)
    } else {
        build_optimized_graph(&arch, &act, &w)
    };
    let loads = loads_from_arch(&arch, 2);
    let alloc = resnet_hls::ilp::solve(&loads, board.n_par() as u64)
        .ok_or_else(|| anyhow::anyhow!("infeasible"))?;
    let cfg = configure(&arch.name, &g, &alloc, board, 2)?;
    let opts = SimOptions { frames, skip_factor, ..Default::default() };
    let mut net = build_network(&g, &cfg, &opts)?;
    let rep = net.run(frames);
    println!(
        "== simulate {} on {} ({}, skip_factor={skip_factor}, {frames} frames) ==",
        arch.name,
        board.name,
        if naive { "naive dataflow" } else { "optimized dataflow" }
    );
    if rep.deadlocked {
        println!(
            "DEADLOCK after {} cycles (frames completed: {})",
            rep.total_cycles,
            rep.frame_done.len()
        );
    } else {
        println!(
            "latency {} cycles ({:.3} ms), steady-state II {} cycles -> {:.0} FPS",
            rep.latency_cycles,
            rep.latency_ms(board.clock_mhz),
            rep.ii_cycles,
            rep.fps(board.clock_mhz)
        );
    }
    if args.has_flag("verbose") {
        for f in &rep.fifo_stats {
            println!("  fifo {:<42} cap {:>7} peak {:>7}", f.name, f.capacity, f.max_occupancy);
        }
        for t in &rep.task_stats {
            println!("  task {:<12} busy {:>10} stall {:>10}", t.name, t.busy_cycles, t.stall_cycles);
        }
    }
    Ok(())
}

fn cmd_codegen(args: &Args) -> Result<()> {
    let arch = arch_of(args)?;
    let board = board_of(args);
    let (act, w) = default_exps(&arch);
    let g = build_optimized_graph(&arch, &act, &w);
    let loads = loads_from_arch(&arch, 2);
    let (_, cfg, _) = fit_to_board(&arch.name, &g, &loads, board, 2)?;
    let cpp = codegen::emit_top(&cfg);
    match args.opt("out") {
        Some(path) => {
            std::fs::write(path, &cpp)?;
            println!("wrote {} bytes to {path}", cpp.len());
        }
        None => print!("{cpp}"),
    }
    Ok(())
}

fn cmd_eval_tables() -> Result<()> {
    print_table3(&table3()?);
    println!();
    print_table4(&table4()?);
    Ok(())
}

fn cmd_golden_eval(args: &Args) -> Result<()> {
    let arch = arch_of(args)?;
    let n = args.opt_usize("n", 256);
    let dir = artifacts_dir();
    let weights = ModelWeights::load(&dir, &arch.name)?;
    let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let mut correct = 0usize;
    let bs = 64;
    for start in (0..n).step_by(bs) {
        let take = bs.min(n - start);
        let (input, labels) = synth_batch(start as u64, take, TEST_SEED);
        let logits = golden::run(&g, &weights, &input)?;
        for (pred, &label) in golden::argmax_classes(&logits).iter().zip(&labels) {
            if *pred == label as usize {
                correct += 1;
            }
        }
    }
    println!(
        "golden {}: accuracy {:.3} on {} synthetic test frames (weights: {})",
        arch.name,
        correct as f64 / n as f64,
        n,
        weights.source
    );
    Ok(())
}

fn cmd_probe_check() -> Result<()> {
    let dir = artifacts_dir();
    let artifacts = Artifacts::load(&dir)?;
    let probe = artifacts.probe()?;
    println!("probe: {} frames", probe.input.shape.n);

    // 1. Synthetic dataset generator bit-equality (Rust vs Python).
    let (local, _) = synth_batch(0, probe.input.shape.n as u64 as usize, TEST_SEED);
    anyhow::ensure!(local.data == probe.input.data, "synthetic dataset mismatch");
    println!("  dataset: rust == python  OK");

    // 2. Golden model vs jnp oracle.
    for (arch_name, oracle) in &probe.logits {
        let arch = arch_by_name(arch_name).unwrap();
        let weights = ModelWeights::load(&dir, arch_name)?;
        let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
        let logits = golden::run(&g, &weights, &probe.input)?;
        anyhow::ensure!(&logits.data == oracle, "golden mismatch for {arch_name}");
        println!("  golden[{arch_name}]: rust == jnp oracle  OK");
    }

    // 3. PJRT-executed HLO vs oracle.
    let engine = Engine::from_artifacts(&artifacts)?;
    println!("  pjrt platform: {}", engine.platform());
    for (arch_name, oracle) in &probe.logits {
        let logits = engine.infer_any(arch_name, &probe.input)?;
        anyhow::ensure!(&logits.data == oracle, "PJRT mismatch for {arch_name}");
        println!("  pjrt[{arch_name}]: HLO == jnp oracle  OK");
    }
    println!("probe-check: ALL BIT-EXACT");
    Ok(())
}

/// Build the backend factory from the shared `serve`/`listen` flags
/// (`--backend`, `--replicas` / elastic band, `--ow-par`,
/// `--window-storage`, plus the shared `--worker-budget` handle when the
/// fleet serves multi-tenant), plus a human description for the startup
/// line.
fn build_factory(
    args: &Args,
    arch_name: &str,
    workers: usize,
    budget: Option<std::sync::Arc<resnet_hls::stream::WorkerBudget>>,
) -> Result<(std::sync::Arc<dyn BackendFactory>, String)> {
    let replicas = args.opt_usize("replicas", 1);
    // Elastic band: either flag opts the stream pool into queue-driven
    // replica scaling (the other end of the band defaults sensibly);
    // a contradictory band is rejected here, not silently clamped.
    let min_replicas = args.opt_usize("min-replicas", 0);
    let max_replicas = args.opt_usize("max-replicas", 0);
    let elastic = if min_replicas > 0 || max_replicas > 0 {
        let min = min_replicas.max(1);
        anyhow::ensure!(
            max_replicas == 0 || max_replicas >= min,
            "--max-replicas {max_replicas} is below --min-replicas {min}"
        );
        Some((min, max_replicas.max(min)))
    } else {
        None
    };
    anyhow::ensure!(
        elastic.is_none() || args.opt("replicas").is_none(),
        "--replicas fixes the pool size; use either it or the elastic \
         --min-replicas/--max-replicas band, not both"
    );
    let ow_par = args.opt_usize("ow-par", 2);
    let storage = match args.opt_or("window-storage", "slices") {
        "rows" => resnet_hls::stream::WindowStorage::Rows,
        "slices" => resnet_hls::stream::WindowStorage::Slices,
        other => anyhow::bail!("unknown window storage {other} (expected rows|slices)"),
    };
    let backend = args.opt_or("backend", "pjrt");
    let dir = artifacts_dir();
    // `golden` prefers the trained artifact weights when present and
    // falls back to deterministic synthetic weights (fully artifact-free).
    let factory: std::sync::Arc<dyn BackendFactory> = match backend {
        "pjrt" => std::sync::Arc::new(PjrtFactory::new(dir.clone(), arch_name)),
        "golden" => std::sync::Arc::new(GoldenFactory::auto(dir.clone(), arch_name, 7)),
        "sim" => std::sync::Arc::new(SimFactory::synthetic(arch_name, 7)),
        "stream" => {
            let mut f = StreamFactory::auto(dir.clone(), arch_name, 7)
                .with_replicas(replicas)
                .with_ow_par(ow_par)
                .with_storage(storage);
            if let Some((min, max)) = elastic {
                f = f.with_elastic(min, max);
            }
            if let Some(b) = &budget {
                f = f.with_budget(b.clone());
            }
            std::sync::Arc::new(f)
        }
        other => anyhow::bail!("unknown backend {other} (expected pjrt|golden|sim|stream)"),
    };
    let desc = if backend == "stream" {
        let band = match elastic {
            Some((min, max)) => format!("elastic {min}..={max} replicas (queue-driven)"),
            None => format!("{replicas} pipeline replica(s)"),
        };
        let shared = match &budget {
            Some(b) => format!("; shared worker budget {}", b.total()),
            None => String::new(),
        };
        format!(
            "stream backend ({workers} worker(s), {band} each, persistent \
             frame-pipelined pool; ow_par={ow_par}, {storage:?} window storage; buckets sized \
             to in-flight capacity{shared})"
        )
    } else {
        format!("{backend} backend ({workers} worker(s))")
    };
    Ok((factory, desc))
}

fn cmd_serve(args: &Args) -> Result<()> {
    let archs = archs_of(args)?;
    let frames = args.opt_usize("frames", 256);
    let workers = args.opt_usize("workers", 1);
    let budget = worker_budget_of(args)?;
    let mut factories = Vec::new();
    for arch in &archs {
        let (factory, desc) = build_factory(args, &arch.name, workers, budget.clone())?;
        factories.push(factory);
        println!("serving {} on {desc}", arch.name);
    }
    let mut router =
        Router::start(factories, RouterConfig { workers_per_arch: workers, ..Default::default() })?;
    if let Some(b) = &budget {
        router.set_budget(b.clone());
    }
    let (input, labels) = synth_batch(0, frames, TEST_SEED);
    let frame_elems = 32 * 32 * 3;
    let t0 = std::time::Instant::now();
    // Interleave submissions across the arches so a multi-tenant fleet
    // loads every pool concurrently, not one after the other.
    let mut pending = Vec::new();
    for i in 0..frames {
        let pixels = input.data[i * frame_elems..(i + 1) * frame_elems].to_vec();
        for arch in &archs {
            pending.push(router.submit(&arch.name, pixels.clone())?);
        }
    }
    let mut correct = 0usize;
    for (chunk, &label) in pending.chunks(archs.len()).zip(&labels) {
        for rx in chunk {
            let resp = rx.recv()??;
            if resp.class == label as usize {
                correct += 1;
            }
        }
    }
    let dt = t0.elapsed();
    let total = frames * archs.len();
    println!(
        "served {total} frames in {:.1} ms -> {:.0} FPS; accuracy {:.3}",
        dt.as_secs_f64() * 1e3,
        total as f64 / dt.as_secs_f64(),
        correct as f64 / total as f64
    );
    println!("metrics {}", router.shutdown());
    Ok(())
}

fn cmd_listen(args: &Args) -> Result<()> {
    let archs = archs_of(args)?;
    let workers = args.opt_usize("workers", 1);
    let budget = worker_budget_of(args)?;
    let mut factories = Vec::new();
    let mut desc = String::new();
    for arch in &archs {
        let (factory, d) = build_factory(args, &arch.name, workers, budget.clone())?;
        factories.push(factory);
        desc = d; // identical flags -> identical description per arch
    }
    let mut router =
        Router::start(factories, RouterConfig { workers_per_arch: workers, ..Default::default() })?;
    if let Some(b) = &budget {
        router.set_budget(b.clone());
    }
    let router = std::sync::Arc::new(router);
    let host = args.opt_or("host", "127.0.0.1");
    let port = args.opt_usize("port", 7433);
    let cfg = ServerConfig {
        addr: format!("{host}:{port}"),
        queue_capacity: args.opt_usize("queue-cap", 64),
        dispatchers: args.opt_usize("dispatchers", 2),
        default_deadline: std::time::Duration::from_millis(
            args.opt_usize("deadline-ms", 500) as u64
        ),
        // `--metrics-port 0` works like `--port 0`: the OS picks.
        metrics_addr: args.opt("metrics-port").map(|p| format!("{host}:{p}")),
        ..Default::default()
    };
    let server = IngressServer::start(router.clone(), cfg)?;
    // The CI smoke job greps these exact lines for the ephemeral ports
    // (`--port 0` lets the OS pick one).
    let names: Vec<&str> = archs.iter().map(|a| a.name.as_str()).collect();
    println!("listening on {} — {} ({desc})", server.local_addr(), names.join(","));
    if let Some(m) = server.metrics_addr() {
        println!("metrics listening on {m}");
    }
    {
        use std::io::Write as _;
        let _ = std::io::stdout().flush();
    }
    let duration = args.opt_usize("duration-s", 0);
    let mut ticks = 0u64;
    loop {
        std::thread::sleep(std::time::Duration::from_secs(1));
        ticks += 1;
        if duration > 0 && ticks >= duration as u64 {
            break;
        }
        if ticks % 30 == 0 {
            println!("ingress {}", server.snapshot());
            println!("metrics {}", router.snapshot());
        }
    }
    // --duration-s elapsed: stop the ingress tier first (it drains and
    // answers everything admitted), then the router.
    let snap = server.shutdown();
    println!("ingress {snap}");
    let router = std::sync::Arc::try_unwrap(router)
        .map_err(|_| anyhow::anyhow!("ingress server still holds the router"))?;
    println!("metrics {}", router.shutdown());
    Ok(())
}

fn cmd_client(args: &Args) -> Result<()> {
    let arch = arch_of(args)?;
    let cfg = DriveConfig {
        addr: args.opt_or("addr", "127.0.0.1:7433").to_string(),
        arch: arch.name.clone(),
        frames: args.opt_usize("frames", 256),
        fps: args.opt_f64("fps", 0.0),
        deadline_ms: args.opt_usize("deadline-ms", 0) as u32,
        window: args.opt_usize("window", 8),
    };
    println!(
        "driving {} x {} to {} (fps {}, window {}, deadline {} ms)",
        cfg.frames,
        cfg.arch,
        cfg.addr,
        if cfg.fps > 0.0 { format!("{:.0}", cfg.fps) } else { "open-loop".to_string() },
        cfg.window,
        cfg.deadline_ms
    );
    let report = drive(&cfg).map_err(|e| anyhow::anyhow!("client failed: {e}"))?;
    println!("{report}");
    anyhow::ensure!(
        report.accounted(),
        "accounting failed: {} sent vs {} ok + {} shed + {} expired + {} err \
         (out-of-order {}, hintless sheds {})",
        report.sent,
        report.oks,
        report.sheds,
        report.expired,
        report.errors,
        report.out_of_order,
        report.sheds_without_hint
    );
    Ok(())
}

/// Static pipeline verification (the `repro verify` front-end over
/// `analysis::verify`): plan the accelerator configuration exactly as
/// the stream pool would, then prove FIFO deadlock-freedom, i32
/// accumulator headroom and Eq. 16/17 window feasibility *without
/// spawning a single thread*.  Rejection exits nonzero after printing
/// every diagnostic (human-readable by default, `--json` for tooling).
fn cmd_verify(args: &Args) -> Result<()> {
    let board = board_of(args);
    let ow_par = args.opt_usize("ow-par", 2);
    let naive = args.has_flag("naive");
    let as_json = args.has_flag("json");
    let skip_capacity_override = match args.opt("skip-capacity") {
        None => None,
        Some(s) => Some(
            s.parse::<usize>()
                .map_err(|e| anyhow::anyhow!("--skip-capacity {s}: {e}"))?,
        ),
    };
    let cfg = resnet_hls::stream::StreamConfig {
        board,
        ow_par,
        naive_add: naive,
        skip_capacity_override,
        ..Default::default()
    };
    // --qonnx verifies an untrusted import (typed parse errors, no
    // weight blobs: range analysis falls back to dtype worst cases);
    // otherwise the named architecture with its trained weights when
    // artifacts exist, deterministic synthetic weights when not.
    let (label, g, weights) = match args.opt("qonnx") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| anyhow::anyhow!("--qonnx {path}: {e}"))?;
            let doc = resnet_hls::util::Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("--qonnx {path}: {e}"))?;
            let g = resnet_hls::graph::qonnx::import(&doc)
                .map_err(|e| anyhow::anyhow!("--qonnx {path}: {e}"))?;
            (format!("qonnx:{path}"), g, None)
        }
        None => {
            let arch = arch_of(args)?;
            let weights = ModelWeights::load(&artifacts_dir(), &arch.name)
                .unwrap_or_else(|_| synthetic_weights(&arch, 7));
            let g = if naive {
                resnet_hls::models::build_unoptimized_graph(
                    &arch,
                    &weights.act_exps,
                    &weights.w_exps,
                )
            } else {
                build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps)
            };
            (arch.name.clone(), g, Some(weights))
        }
    };
    let acfg = resnet_hls::stream::planned_config(&label, &g, &cfg)?;
    let report = resnet_hls::analysis::verify(&g, weights.as_ref(), &cfg, &acfg)?;
    if as_json {
        println!("{}", report.to_json());
    } else {
        println!(
            "== static pipeline verification: {label} on {} (ow_par={ow_par}{}{}) ==",
            board.name,
            if naive { ", naive dataflow" } else { "" },
            match skip_capacity_override {
                Some(c) => format!(", skip capacity forced to {c}"),
                None => String::new(),
            }
        );
        println!("{report}");
    }
    anyhow::ensure!(
        report.ok(),
        "static verification rejected the configuration ({} error(s))",
        report.errors().count()
    );
    Ok(())
}

/// Pipeline observability front-end (`repro stats`).  Two modes:
///
/// * `--addr H:P` — scrape a running `repro listen --metrics-port`
///   endpoint and print the body verbatim (Prometheus text by default,
///   `/stats.json` with `--json`);
/// * otherwise — profile a local streaming pool: run `--frames`
///   synthetic frames through a [`StreamBackend`] and print the
///   per-stage stall attribution (busy / blocked-on-push /
///   blocked-on-pop), per-FIFO occupancy histograms and the bottleneck
///   verdict that the pool's `obs` instrumentation recorded.
fn cmd_stats(args: &Args) -> Result<()> {
    let as_json = args.has_flag("json");
    if let Some(addr) = args.opt("addr") {
        let path = if as_json { "/stats.json" } else { "/metrics" };
        let body = resnet_hls::net::metrics::fetch(addr, path)
            .map_err(|e| anyhow::anyhow!("fetch http://{addr}{path}: {e}"))?;
        print!("{body}");
        return Ok(());
    }
    let arch = arch_of(args)?;
    let frames = args.opt_usize("frames", 64);
    let cfg = resnet_hls::stream::StreamConfig {
        replicas: args.opt_usize("replicas", 1),
        ow_par: args.opt_usize("ow-par", 2),
        window_storage: match args.opt_or("window-storage", "slices") {
            "rows" => resnet_hls::stream::WindowStorage::Rows,
            "slices" => resnet_hls::stream::WindowStorage::Slices,
            other => anyhow::bail!("unknown window storage {other} (expected rows|slices)"),
        },
        ..Default::default()
    };
    let dir = artifacts_dir();
    let backend = if dir.join("manifest.json").exists() {
        StreamBackend::from_artifacts_with(&dir, &arch.name, &[], cfg)?
    } else {
        StreamBackend::synthetic_with(&arch.name, 7, &[], cfg)?
    };
    let (input, _) = synth_batch(0, frames, TEST_SEED);
    let t0 = std::time::Instant::now();
    backend.infer_batch(&input)?;
    let dt = t0.elapsed();
    let report = backend.pool().stall_report();
    if as_json {
        println!("{}", report.to_json());
        return Ok(());
    }
    println!(
        "== pipeline stall attribution: {} ({frames} frames in {:.1} ms -> {:.0} FPS) ==",
        arch.name,
        dt.as_secs_f64() * 1e3,
        frames as f64 / dt.as_secs_f64()
    );
    println!("{report}");
    let mut spans = backend.pool().recent_spans();
    spans.sort_by_key(|s| s.frame);
    if let Some(last) = spans.last() {
        println!(
            "spans retained: {} (latest frame {}: queued {} us, total {} us)",
            spans.len(),
            last.frame,
            last.queued_us,
            last.total_us
        );
    }
    Ok(())
}

fn cmd_buffers(args: &Args) -> Result<()> {
    let arch = arch_of(args)?;
    println!("== skip-connection buffering, {} (Eqs. 21-23) ==", arch.name);
    println!("{:<8} {:>10} {:>10} {:>8}", "block", "naive", "optimized", "R_sc");
    for (name, naive, opt, r) in skip_buffering_series(&arch) {
        println!("{name:<8} {naive:>10} {opt:>10} {r:>8.3}");
    }

    // Measured: run the streaming executor on one synthetic frame and
    // report the actual peak occupancy of every Eq. 22-sized skip FIFO,
    // plus the total buffering against whole-tensor intermediates.
    let weights = synthetic_weights(&arch, 7);
    let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
    let (input, _) = synth_batch(0, 1, TEST_SEED);
    let (_, stats) =
        resnet_hls::stream::run_streaming(&g, &weights, &input, &Default::default())?;
    println!("\n== streaming executor, measured (1 frame) ==");
    println!("{:<16} {:>10} {:>10}", "skip fifo", "capacity", "peak");
    for b in stats.of_kind(resnet_hls::hls::streams::StreamKind::Skip) {
        println!("{:<16} {:>10} {:>10}", b.name, b.capacity, b.peak);
    }
    // Slice-granular window buffers: the bound is the exact Eq. 16/17
    // span (B_i plus the in-flight pixel), not rounded up to rows.
    println!("{:<16} {:>10} {:>10}", "window buffer", "Eq.16/17", "peak");
    for b in stats.of_kind(resnet_hls::hls::streams::StreamKind::WindowSlice) {
        println!("{:<16} {:>10} {:>10}", b.name, b.capacity, b.peak);
    }
    println!(
        "peak streamed buffering {} elems vs whole-tensor intermediates {} ({:.4} of naive)",
        stats.peak_buffered_elems(),
        stats.whole_tensor_elems,
        stats.buffered_fraction()
    );
    Ok(())
}
