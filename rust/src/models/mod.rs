//! Model zoo: the paper's ResNet8 and ResNet20 (CIFAR-10 geometry), as
//! architecture specs, graph builders (pre- and post-optimization forms),
//! and the loader for the weights exported by `python/compile/aot.py`.

// Panic-freedom gate: model/weight construction runs inside
// serving-backend factories, so failures must be typed errors, never
// unwinds.  `clippy.toml` disallows Option/Result unwrap+expect; test
// modules opt out locally.
#![deny(clippy::disallowed_methods)]

pub mod resnet;
mod weights;

pub use resnet::{
    build_optimized_graph, build_unoptimized_graph, default_exps, longskipnet, resnet20, resnet8,
    skipnet, tiednet, ActExps, ArchSpec, ConvSpec, ResidualSpec, Segment, SkipSpec, WExps,
};
pub use weights::{synthetic_weights, ConvWeights, ModelWeights, WeightTensor};

/// Look up an architecture by name.
pub fn arch_by_name(name: &str) -> Option<ArchSpec> {
    match name {
        "resnet8" => Some(resnet8()),
        "resnet20" => Some(resnet20()),
        "skipnet" => Some(skipnet()),
        "longskipnet" => Some(longskipnet()),
        // Registry default for the weight-tied net; `tiednet(n)` is public
        // for other depths.
        "tiednet" => Some(tiednet(4)),
        _ => None,
    }
}
