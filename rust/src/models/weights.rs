//! Loader for the weight blobs exported by `python/compile/aot.py`.
//!
//! The manifest (`artifacts/manifest.json`) describes, per architecture, a
//! flat little-endian binary (`weights_<arch>.bin`) of int8 weight tensors
//! and int16 bias tensors plus their power-of-two exponents.  This feeds
//! the Rust golden model (`sim::golden`) — the same integer values the
//! AOT-lowered HLO has baked in as constants, which is what makes the
//! golden-vs-PJRT bit-equality test meaningful.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;

/// One tensor record from the manifest.
#[derive(Debug, Clone)]
pub struct WeightTensor {
    pub name: String,
    pub kind: String, // "w" | "b"
    pub shape: Vec<usize>,
    pub exp: i32,
    pub data: Vec<i32>,
}

/// A convolution's (or the fc layer's) parameters.
#[derive(Debug, Clone)]
pub struct ConvWeights {
    /// Weights: conv (KH, KW, CIN, COUT) or fc (CIN, COUT), int8-valued.
    pub w: WeightTensor,
    /// Bias at the accumulator exponent, int16-valued.
    pub b: WeightTensor,
}

impl ConvWeights {
    /// Weight exponent.
    pub fn w_exp(&self) -> i32 {
        self.w.exp
    }

    /// Accumulator exponent (= bias exponent by construction).
    pub fn acc_exp(&self) -> i32 {
        self.b.exp
    }
}

/// All parameters + exponent tables for one architecture.
#[derive(Debug, Clone)]
pub struct ModelWeights {
    pub arch: String,
    pub layers: BTreeMap<String, ConvWeights>,
    /// Weight tying: layer name -> key in `layers`.  Tied layers (the
    /// ODE-style repeated block) resolve through here to one shared blob,
    /// so `param_bytes` stays constant as the block is repeated.
    pub aliases: BTreeMap<String, String>,
    pub act_exps: BTreeMap<String, i32>,
    pub w_exps: BTreeMap<String, i32>,
    /// "checkpoint" (trained) or "random" (deterministic init).
    pub source: String,
}

impl ModelWeights {
    /// Load from an artifacts directory for the given arch name.
    pub fn load(artifacts: &Path, arch: &str) -> Result<ModelWeights> {
        let manifest_path = artifacts.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Json::parse(&text).context("parsing manifest.json")?;
        Self::from_manifest(&manifest, artifacts, arch)
    }

    pub fn from_manifest(manifest: &Json, artifacts: &Path, arch: &str) -> Result<ModelWeights> {
        let entry = manifest
            .at(&format!("archs/{arch}"))
            .ok_or_else(|| anyhow!("arch {arch} not in manifest"))?;
        let wfile = entry
            .get("weights_file")
            .and_then(|j| j.as_str())
            .ok_or_else(|| anyhow!("missing weights_file"))?;
        let blob = std::fs::read(artifacts.join(wfile))
            .with_context(|| format!("reading {wfile}"))?;

        let exps = |key: &str| -> Result<BTreeMap<String, i32>> {
            let obj = entry
                .get(key)
                .and_then(|j| j.as_object())
                .ok_or_else(|| anyhow!("missing {key}"))?;
            obj.iter()
                .map(|(k, v)| {
                    v.as_i64()
                        .map(|x| (k.clone(), x as i32))
                        .ok_or_else(|| anyhow!("bad exp for {k}"))
                })
                .collect()
        };
        let act_exps = exps("act_exps")?;
        let w_exps = exps("w_exps")?;

        let records = entry
            .get("weights")
            .and_then(|j| j.as_array())
            .ok_or_else(|| anyhow!("missing weights records"))?;
        let mut tensors: BTreeMap<(String, String), WeightTensor> = BTreeMap::new();
        for rec in records {
            let name = rec.get("name").and_then(|j| j.as_str()).unwrap_or_default().to_string();
            let kind = rec.get("kind").and_then(|j| j.as_str()).unwrap_or_default().to_string();
            let dtype = rec.get("dtype").and_then(|j| j.as_str()).unwrap_or_default();
            let offset = rec.get("offset").and_then(|j| j.as_i64()).unwrap_or(-1) as usize;
            let bytes = rec.get("bytes").and_then(|j| j.as_i64()).unwrap_or(-1) as usize;
            let shape: Vec<usize> = rec
                .get("shape")
                .and_then(|j| j.as_array())
                .map(|a| a.iter().filter_map(|v| v.as_i64()).map(|x| x as usize).collect())
                .unwrap_or_default();
            let exp = rec.get("exp").and_then(|j| j.as_i64()).unwrap_or(0) as i32;
            if offset + bytes > blob.len() {
                bail!("tensor {name}.{kind} overruns blob ({} + {} > {})", offset, bytes, blob.len());
            }
            let raw = &blob[offset..offset + bytes];
            let data: Vec<i32> = match dtype {
                "i8" => raw.iter().map(|&b| b as i8 as i32).collect(),
                "i16" => raw
                    .chunks_exact(2)
                    .map(|c| i16::from_le_bytes([c[0], c[1]]) as i32)
                    .collect(),
                other => bail!("unknown dtype {other} for {name}.{kind}"),
            };
            let elems: usize = shape.iter().product();
            if elems != data.len() {
                bail!("tensor {name}.{kind}: shape {:?} but {} elems", shape, data.len());
            }
            tensors.insert((name.clone(), kind.clone()), WeightTensor { name, kind, shape, exp, data });
        }

        let names: Vec<String> = tensors.keys().map(|(n, _)| n.clone()).collect();
        let mut layers = BTreeMap::new();
        for name in names {
            if layers.contains_key(&name) {
                continue;
            }
            let w = tensors
                .get(&(name.clone(), "w".into()))
                .cloned()
                .ok_or_else(|| anyhow!("missing weights for {name}"))?;
            let b = tensors
                .get(&(name.clone(), "b".into()))
                .cloned()
                .ok_or_else(|| anyhow!("missing bias for {name}"))?;
            layers.insert(name, ConvWeights { w, b });
        }

        let aliases = entry
            .get("aliases")
            .and_then(|j| j.as_object())
            .map(|obj| {
                obj.iter()
                    .filter_map(|(k, v)| v.as_str().map(|s| (k.clone(), s.to_string())))
                    .collect()
            })
            .unwrap_or_default();

        Ok(ModelWeights {
            arch: arch.to_string(),
            layers,
            aliases,
            act_exps,
            w_exps,
            source: entry.get("source").and_then(|j| j.as_str()).unwrap_or("?").to_string(),
        })
    }

    pub fn layer(&self, name: &str) -> Result<&ConvWeights> {
        if let Some(l) = self.layers.get(name) {
            return Ok(l);
        }
        // One level of alias resolution (weight-tied layers).
        if let Some(key) = self.aliases.get(name) {
            return self
                .layers
                .get(key)
                .ok_or_else(|| anyhow!("layer {name} aliases missing blob {key}"));
        }
        Err(anyhow!("no weights for layer {name}"))
    }

    /// Activation exponent for a named tensor.
    pub fn act_exp(&self, tensor: &str) -> Result<i32> {
        self.act_exps
            .get(tensor)
            .copied()
            .ok_or_else(|| anyhow!("no activation exponent for {tensor}"))
    }

    /// Total parameter bytes (int8 weights + int16 biases) — feeds the
    /// BRAM/URAM resource model.
    pub fn param_bytes(&self) -> usize {
        self.layers
            .values()
            .map(|c| c.w.data.len() + 2 * c.b.data.len())
            .sum()
    }
}

/// Synthesize deterministic weights for tests that must run without
/// artifacts (mirrors `params.random_int_params` loosely; NOT bit-identical
/// to the Python init — artifact-based tests use the real blobs).
pub fn synthetic_weights(
    arch: &crate::models::ArchSpec,
    seed: u64,
) -> ModelWeights {
    use crate::util::Lcg64;
    let (act_exps, w_exps) = crate::models::resnet::default_exps(arch);
    let mut rng = Lcg64::new(seed);
    let mut layers = BTreeMap::new();
    let mut aliases = BTreeMap::new();
    for c in arch.conv_layers() {
        let key = arch.weight_key(&c.name);
        if key != c.name {
            aliases.insert(c.name.clone(), key.to_string());
        }
        if layers.contains_key(key) {
            // Tied repeat: share the first instance's blob, drawing nothing
            // from the RNG so param bytes stay constant with depth.
            continue;
        }
        let n = c.k * c.k * c.cin * c.cout;
        let w_data: Vec<i32> = (0..n).map(|_| rng.range_i64(-64, 64) as i32).collect();
        let b_data: Vec<i32> = (0..c.cout).map(|_| rng.range_i64(-512, 512) as i32).collect();
        let in_exp = act_exps.get(&c.name).copied().unwrap_or(-5);
        layers.insert(
            key.to_string(),
            ConvWeights {
                w: WeightTensor {
                    name: key.to_string(), kind: "w".into(),
                    shape: vec![c.k, c.k, c.cin, c.cout], exp: w_exps[&c.name], data: w_data,
                },
                b: WeightTensor {
                    name: key.to_string(), kind: "b".into(),
                    shape: vec![c.cout], exp: in_exp + w_exps[&c.name] - 2, data: b_data,
                },
            },
        );
    }
    let n = arch.fc_in * arch.fc_out;
    layers.insert(
        "fc".into(),
        ConvWeights {
            w: WeightTensor {
                name: "fc".into(), kind: "w".into(),
                shape: vec![arch.fc_in, arch.fc_out], exp: w_exps["fc"],
                data: (0..n).map(|_| rng.range_i64(-64, 64) as i32).collect(),
            },
            b: WeightTensor {
                name: "fc".into(), kind: "b".into(), shape: vec![arch.fc_out],
                exp: act_exps["pool"] + w_exps["fc"],
                data: (0..arch.fc_out).map(|_| rng.range_i64(-512, 512) as i32).collect(),
            },
        },
    );
    ModelWeights {
        arch: arch.name.clone(),
        layers,
        aliases,
        act_exps,
        w_exps,
        source: "synthetic".into(),
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::models::resnet8;

    #[test]
    fn synthetic_weights_cover_all_layers() {
        let arch = resnet8();
        let w = synthetic_weights(&arch, 1);
        for name in arch.param_names() {
            let l = w.layer(&name).unwrap();
            assert!(!l.w.data.is_empty());
            assert_eq!(l.b.data.len(), *l.b.shape.last().unwrap());
        }
        assert!(w.param_bytes() > 70_000, "resnet8 ~78k params");
    }

    #[test]
    fn tied_weights_share_one_blob_at_constant_param_bytes() {
        use crate::models::resnet::tiednet;
        let w1 = synthetic_weights(&tiednet(1), 7);
        let w4 = synthetic_weights(&tiednet(4), 7);
        assert_eq!(w1.param_bytes(), w4.param_bytes(), "depth must not grow params");
        // Every repeat resolves to the same physical blob.
        let a = w4.layer("t0c0").unwrap();
        let b = w4.layer("t3c0").unwrap();
        assert_eq!(a.w.data, b.w.data);
        assert_eq!(a.b.data, b.b.data);
        // And the shared blob is stored once under its key.
        assert!(w4.layers.contains_key("tie_c0"));
        assert!(!w4.layers.contains_key("t0c0"));
    }
}
