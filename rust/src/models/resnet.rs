//! ResNet8 / ResNet20 architecture specs and graph builders.
//!
//! Mirrors `python/compile/arch.py` exactly (layer names included) — the
//! manifest's exponent tables are keyed by these names.

use crate::graph::{ConvAttrs, Edge, Graph, InputRole, Op};

/// One convolution layer (geometry only; exponents come from the manifest).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvSpec {
    pub name: String,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub relu: bool,
    pub in_h: usize,
    pub in_w: usize,
}

impl ConvSpec {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.k) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Paper Eq. 8: number of MACs per frame for this layer.
    pub fn macs(&self) -> u64 {
        (self.out_h() * self.out_w() * self.cout * self.cin * self.k * self.k) as u64
    }

    /// Filter taps `k_i = fh*fw` (paper Eq. 10).
    pub fn taps(&self) -> usize {
        self.k * self.k
    }
}

/// A residual block: conv0 -> conv1, skip = identity or 1x1 downsample.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BlockSpec {
    pub name: String,
    pub conv0: ConvSpec,
    pub conv1: ConvSpec,
    pub downsample: Option<ConvSpec>,
}

/// A full network architecture.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArchSpec {
    pub name: String,
    pub stem: ConvSpec,
    pub blocks: Vec<BlockSpec>,
    pub fc_in: usize,
    pub fc_out: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub in_c: usize,
}

impl ArchSpec {
    /// All conv layers in execution order (ILP optimizes over these).
    pub fn conv_layers(&self) -> Vec<&ConvSpec> {
        let mut out = vec![&self.stem];
        for b in &self.blocks {
            if let Some(ds) = &b.downsample {
                out.push(ds);
            }
            out.push(&b.conv0);
            out.push(&b.conv1);
        }
        out
    }

    pub fn find_conv(&self, name: &str) -> Option<&ConvSpec> {
        self.conv_layers().into_iter().find(|c| c.name == name)
    }

    /// Total multiply-accumulates per frame (conv + fc), for Gops/s.
    pub fn total_macs(&self) -> u64 {
        self.conv_layers().iter().map(|c| c.macs()).sum::<u64>() + (self.fc_in * self.fc_out) as u64
    }

    pub fn param_names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.conv_layers().iter().map(|c| c.name.clone()).collect();
        v.push("fc".into());
        v
    }
}

fn make_blocks(stages: &[usize], blocks_per_stage: usize) -> Vec<BlockSpec> {
    let mut blocks = Vec::new();
    let (mut h, mut w, mut cin) = (32usize, 32usize, 16usize);
    for (si, &cout) in stages.iter().enumerate() {
        for bi in 0..blocks_per_stage {
            let first = bi == 0;
            let stride = if first && si > 0 { 2 } else { 1 };
            let bname = format!("s{si}b{bi}");
            let conv0 = ConvSpec {
                name: format!("{bname}c0"), cin, cout, k: 3, stride, pad: 1, relu: true,
                in_h: h, in_w: w,
            };
            let (oh, ow) = (conv0.out_h(), conv0.out_w());
            let conv1 = ConvSpec {
                name: format!("{bname}c1"), cin: cout, cout, k: 3, stride: 1, pad: 1,
                relu: true, in_h: oh, in_w: ow,
            };
            let downsample = (first && si > 0).then(|| ConvSpec {
                name: format!("{bname}ds"), cin, cout, k: 1, stride, pad: 0, relu: false,
                in_h: h, in_w: w,
            });
            blocks.push(BlockSpec { name: bname, conv0, conv1, downsample });
            cin = cout;
            h = oh;
            w = ow;
        }
    }
    blocks
}

/// The classic CIFAR ResNet20 of He et al. (3 stages x 3 blocks).
pub fn resnet20() -> ArchSpec {
    ArchSpec {
        name: "resnet20".into(),
        stem: ConvSpec {
            name: "stem".into(), cin: 3, cout: 16, k: 3, stride: 1, pad: 1, relu: true,
            in_h: 32, in_w: 32,
        },
        blocks: make_blocks(&[16, 32, 64], 3),
        fc_in: 64,
        fc_out: 10,
        in_h: 32,
        in_w: 32,
        in_c: 3,
    }
}

/// The MLPerf-Tiny-style ResNet8 (3 stages x 1 block).
pub fn resnet8() -> ArchSpec {
    ArchSpec {
        name: "resnet8".into(),
        stem: ConvSpec {
            name: "stem".into(), cin: 3, cout: 16, k: 3, stride: 1, pad: 1, relu: true,
            in_h: 32, in_w: 32,
        },
        blocks: make_blocks(&[16, 32, 64], 1),
        fc_in: 64,
        fc_out: 10,
        in_h: 32,
        in_w: 32,
        in_c: 3,
    }
}

/// Exponent lookup: tensor-name -> activation exponent (from the manifest,
/// or defaults matching `arch.py` when absent).
pub type ActExps = std::collections::BTreeMap<String, i32>;
pub type WExps = std::collections::BTreeMap<String, i32>;

fn conv_attrs(spec: &ConvSpec, relu: bool, w_exps: &WExps, act_exps: &ActExps) -> ConvAttrs {
    ConvAttrs {
        cin: spec.cin,
        cout: spec.cout,
        k: spec.k,
        stride: spec.stride,
        pad: spec.pad,
        relu,
        w_exp: w_exps[&spec.name],
        out_exp: act_exps[&spec.name],
        merged_downsample: None,
        forwards_input: false, raw_output: false,
    }
}

/// Build the *pre-optimization* graph: explicit Add nodes for the residual
/// merges, no loop merging, no input forwarding, ReLU folded into convs but
/// the post-add ReLU explicit (paper Fig. 10 topology).  This is the input
/// to the `passes` pipeline.
pub fn build_unoptimized_graph(arch: &ArchSpec, act_exps: &ActExps, w_exps: &WExps) -> Graph {
    let mut g = Graph::new();
    let input = g.add_simple(
        "input",
        Op::Input { h: arch.in_h, w: arch.in_w, c: arch.in_c, exp: act_exps["input"] },
        &[],
    );
    let stem = g.add_simple(
        "stem",
        Op::Conv(conv_attrs(&arch.stem, true, w_exps, act_exps)),
        &[Edge::new(input, 0)],
    );
    let mut prev = stem;
    for blk in &arch.blocks {
        let xin = prev;
        let skip = match &blk.downsample {
            Some(ds) => g.add_simple(
                &ds.name,
                Op::Conv(conv_attrs(ds, false, w_exps, act_exps)),
                &[Edge::new(xin, 0)],
            ),
            None => xin,
        };
        let c0 = g.add_simple(
            &blk.conv0.name,
            Op::Conv(conv_attrs(&blk.conv0, true, w_exps, act_exps)),
            &[Edge::new(xin, 0)],
        );
        // conv1 *without* fused relu, streaming raw int32 accumulators:
        // the pre-optimization dataflow performs the residual merge at
        // accumulator precision and applies ReLU after the add (Fig. 10).
        let c1 = g.add_simple(
            &blk.conv1.name,
            Op::Conv(ConvAttrs {
                relu: false,
                raw_output: true,
                ..conv_attrs(&blk.conv1, false, w_exps, act_exps)
            }),
            &[Edge::new(c0, 0)],
        );
        let add = g.add_simple(
            format!("{}_add", blk.name),
            Op::Add { out_exp: act_exps[&blk.conv1.name] },
            &[Edge::new(c1, 0), Edge::new(skip, 0)],
        );
        prev = g.add_simple(format!("{}_relu", blk.name), Op::Relu, &[Edge::new(add, 0)]);
    }
    let pool = g.add_simple("pool", Op::GlobalAvgPool { out_exp: act_exps["pool"] }, &[Edge::new(prev, 0)]);
    g.add_simple(
        "fc",
        Op::Linear { cin: arch.fc_in, cout: arch.fc_out, w_exp: w_exps["fc"] },
        &[Edge::new(pool, 0)],
    );
    g
}

/// Build the *optimized* graph directly (paper Fig. 14): loop-merged
/// downsamples, input forwarding on identity skips, adds fused into conv1
/// accumulator initialization.  The passes pipeline must transform the
/// unoptimized graph into exactly this dataflow (asserted in tests).
pub fn build_optimized_graph(arch: &ArchSpec, act_exps: &ActExps, w_exps: &WExps) -> Graph {
    let mut g = Graph::new();
    let input = g.add_simple(
        "input",
        Op::Input { h: arch.in_h, w: arch.in_w, c: arch.in_c, exp: act_exps["input"] },
        &[],
    );
    let stem = g.add_simple(
        "stem",
        Op::Conv(conv_attrs(&arch.stem, true, w_exps, act_exps)),
        &[Edge::new(input, 0)],
    );
    let mut prev = stem;
    for blk in &arch.blocks {
        let xin = prev;
        let (c0, skip_edge) = match &blk.downsample {
            Some(ds) => {
                // Loop merge: the downsample conv is computed inside conv0's
                // task; its result appears on conv0's port 1.
                let mut a0 = conv_attrs(&blk.conv0, true, w_exps, act_exps);
                a0.merged_downsample = Some(crate::graph::MergedDownsample {
                    name: ds.name.clone(),
                    cout: ds.cout,
                    k: ds.k,
                    stride: ds.stride,
                    pad: ds.pad,
                    w_exp: w_exps[&ds.name],
                    out_exp: act_exps[&ds.name],
                });
                let c0 = g.add_simple(&blk.conv0.name, Op::Conv(a0), &[Edge::new(xin, 0)]);
                (c0, Edge::new(c0, 1))
            }
            None => {
                // Temporal reuse: conv0 forwards its input on port 1.
                let mut a0 = conv_attrs(&blk.conv0, true, w_exps, act_exps);
                a0.forwards_input = true;
                let c0 = g.add_simple(&blk.conv0.name, Op::Conv(a0), &[Edge::new(xin, 0)]);
                (c0, Edge::new(c0, 1))
            }
        };
        // Add fusion: conv1 takes the skip stream as a SkipInit input and
        // fuses the post-add ReLU.
        let c1 = g.add(
            &blk.conv1.name,
            Op::Conv(conv_attrs(&blk.conv1, true, w_exps, act_exps)),
            vec![(Edge::new(c0, 0), InputRole::Data), (skip_edge, InputRole::SkipInit)],
        );
        prev = c1;
    }
    let pool = g.add_simple("pool", Op::GlobalAvgPool { out_exp: act_exps["pool"] }, &[Edge::new(prev, 0)]);
    g.add_simple(
        "fc",
        Op::Linear { cin: arch.fc_in, cout: arch.fc_out, w_exp: w_exps["fc"] },
        &[Edge::new(pool, 0)],
    );
    g
}

/// Default exponent tables matching `python/compile/arch.py` (used by tests
/// and tooling when no manifest is loaded).
pub fn default_exps(arch: &ArchSpec) -> (ActExps, WExps) {
    let mut act = ActExps::new();
    act.insert("input".into(), -7);
    act.insert("pool".into(), -5);
    for c in arch.conv_layers() {
        act.insert(c.name.clone(), -5);
    }
    let mut w = WExps::new();
    for n in arch.param_names() {
        w.insert(n, -8);
    }
    (act, w)
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;

    #[test]
    fn resnet20_has_expected_structure() {
        let a = resnet20();
        assert_eq!(a.blocks.len(), 9);
        // 1 stem + 9*2 block convs + 2 downsamples = 21 convs
        assert_eq!(a.conv_layers().len(), 21);
        // ~40.5M MACs (He et al. report ~41M for CIFAR ResNet20)
        let m = a.total_macs();
        assert!((40_000_000..42_000_000).contains(&m), "macs = {m}");
    }

    #[test]
    fn resnet8_has_expected_structure() {
        let a = resnet8();
        assert_eq!(a.blocks.len(), 3);
        assert_eq!(a.conv_layers().len(), 9);
        // ~12.5M MACs (MLPerf Tiny ResNet8 class)
        let m = a.total_macs();
        assert!((11_000_000..14_000_000).contains(&m), "macs = {m}");
    }

    #[test]
    fn both_graph_forms_validate_and_shape() {
        for arch in [resnet8(), resnet20()] {
            let (act, w) = default_exps(&arch);
            for g in [
                build_unoptimized_graph(&arch, &act, &w),
                build_optimized_graph(&arch, &act, &w),
            ] {
                g.validate().unwrap_or_else(|e| panic!("{}: {e}", arch.name));
                let shapes = infer_shapes(&g).unwrap();
                // Final logits: 10 channels.
                let out = g.output().unwrap();
                let s = shapes[&crate::graph::Edge::new(out, 0)];
                assert_eq!(s.c, 10);
            }
        }
    }

    #[test]
    fn optimized_graph_has_no_add_nodes() {
        let arch = resnet20();
        let (act, w) = default_exps(&arch);
        let g = build_optimized_graph(&arch, &act, &w);
        assert_eq!(g.count_kind("add"), 0);
        assert_eq!(g.count_kind("relu"), 0);
        // 9 conv1 nodes carry SkipInit inputs.
        let skips = g
            .live()
            .filter(|n| n.inputs.iter().any(|(_, r)| *r == crate::graph::InputRole::SkipInit))
            .count();
        assert_eq!(skips, 9);
    }

    #[test]
    fn unoptimized_graph_has_explicit_adds() {
        let arch = resnet8();
        let (act, w) = default_exps(&arch);
        let g = build_unoptimized_graph(&arch, &act, &w);
        assert_eq!(g.count_kind("add"), 3);
        assert_eq!(g.count_kind("relu"), 3);
    }
}
