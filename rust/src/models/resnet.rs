//! Architecture specs and graph builders.
//!
//! The spec layer describes a network as a sequence of *segments*: plain
//! convolutions and residual segments.  A residual segment carries a conv
//! body plus any number of skip operands — the identity input, a projected
//! (1x1 downsample) input, or a *long* skip reaching back to any earlier
//! named segment — so non-ResNet skip topologies (multi-input adds, skips
//! spanning several blocks) and weight-tied repeated blocks are expressible
//! in the same vocabulary.  `resnet8()` / `resnet20()` remain thin presets
//! that produce graphs bit-identical to the historical hardcoded builders
//! (layer names included — the manifest's exponent tables are keyed by
//! these names, mirroring `python/compile/arch.py`).

use std::collections::BTreeMap;

use crate::graph::{ConvAttrs, Edge, Graph, InputRole, NodeId, Op};

/// One convolution layer (geometry only; exponents come from the manifest).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvSpec {
    pub name: String,
    pub cin: usize,
    pub cout: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub relu: bool,
    pub in_h: usize,
    pub in_w: usize,
}

impl ConvSpec {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.k) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Paper Eq. 8: number of MACs per frame for this layer.
    pub fn macs(&self) -> u64 {
        (self.out_h() * self.out_w() * self.cout * self.cin * self.k * self.k) as u64
    }

    /// Filter taps `k_i = fh*fw` (paper Eq. 10).
    pub fn taps(&self) -> usize {
        self.k * self.k
    }
}

/// One skip operand of a residual segment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SkipSpec {
    /// Source of the skip: `None` = the residual segment's own input
    /// (classic identity skip); `Some(name)` = the output of an earlier
    /// named segment (a *long* skip spanning one or more segments).
    pub from: Option<String>,
    /// Optional projection conv applied to the source (the classic 1x1
    /// strided downsample).
    pub proj: Option<ConvSpec>,
}

impl SkipSpec {
    /// Plain identity skip from the segment input.
    pub fn identity() -> Self {
        SkipSpec { from: None, proj: None }
    }
}

/// A residual segment: a chain of body convs merged with >= 1 skip operand.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ResidualSpec {
    pub name: String,
    /// Body convolutions, applied in order to the segment input.
    pub body: Vec<ConvSpec>,
    /// Skip operands summed into the merge (at least one).
    pub skips: Vec<SkipSpec>,
}

impl ResidualSpec {
    /// Whether the paper's fused dataflow (Fig. 12-13) applies: a two-conv
    /// body with exactly one same-segment skip, either identity (temporal
    /// reuse, Fig. 12a) or a pointwise projection (loop merge, Fig. 12b).
    /// Everything else stays a naive Eq. 21 add.
    pub fn fusable(&self) -> bool {
        self.body.len() == 2
            && self.skips.len() == 1
            && self.skips[0].from.is_none()
            && self.skips[0].proj.as_ref().is_none_or(|p| p.k == 1)
    }
}

/// One element of an architecture: a plain conv or a residual segment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Segment {
    Conv(ConvSpec),
    Residual(ResidualSpec),
}

/// A full network architecture.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArchSpec {
    pub name: String,
    pub segments: Vec<Segment>,
    pub fc_in: usize,
    pub fc_out: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub in_c: usize,
    /// Weight tying: layer name -> shared weight key.  Layers mapping to
    /// the same key execute with one physical parameter blob (the
    /// Neural-ODE-style repeated block); empty for the ResNet presets.
    pub tied: BTreeMap<String, String>,
}

impl ArchSpec {
    /// All conv layers in execution order (ILP optimizes over these).
    /// Within a residual segment, skip projections precede the body —
    /// matching the historical stem, (ds), c0, c1 ordering.
    pub fn conv_layers(&self) -> Vec<&ConvSpec> {
        let mut out = Vec::new();
        for s in &self.segments {
            match s {
                Segment::Conv(c) => out.push(c),
                Segment::Residual(r) => {
                    for sk in &r.skips {
                        if let Some(p) = &sk.proj {
                            out.push(p);
                        }
                    }
                    out.extend(r.body.iter());
                }
            }
        }
        out
    }

    /// The residual segments, in order.
    pub fn residuals(&self) -> impl Iterator<Item = &ResidualSpec> {
        self.segments.iter().filter_map(|s| match s {
            Segment::Residual(r) => Some(r),
            Segment::Conv(_) => None,
        })
    }

    pub fn find_conv(&self, name: &str) -> Option<&ConvSpec> {
        self.conv_layers().into_iter().find(|c| c.name == name)
    }

    /// The weight-storage key for a layer (its own name unless tied).
    pub fn weight_key<'a>(&'a self, name: &'a str) -> &'a str {
        self.tied.get(name).map(String::as_str).unwrap_or(name)
    }

    /// Total multiply-accumulates per frame (conv + fc), for Gops/s.
    pub fn total_macs(&self) -> u64 {
        self.conv_layers().iter().map(|c| c.macs()).sum::<u64>() + (self.fc_in * self.fc_out) as u64
    }

    /// Unique parameter-blob names, in first-use order (tied layers share
    /// one entry under their key).
    pub fn param_names(&self) -> Vec<String> {
        let mut v: Vec<String> = Vec::new();
        for c in self.conv_layers() {
            let key = self.weight_key(&c.name);
            if !v.iter().any(|n| n == key) {
                v.push(key.to_string());
            }
        }
        v.push("fc".into());
        v
    }
}

fn make_blocks(stages: &[usize], blocks_per_stage: usize) -> Vec<Segment> {
    let mut blocks = Vec::new();
    let (mut h, mut w, mut cin) = (32usize, 32usize, 16usize);
    for (si, &cout) in stages.iter().enumerate() {
        for bi in 0..blocks_per_stage {
            let first = bi == 0;
            let stride = if first && si > 0 { 2 } else { 1 };
            let bname = format!("s{si}b{bi}");
            let conv0 = ConvSpec {
                name: format!("{bname}c0"), cin, cout, k: 3, stride, pad: 1, relu: true,
                in_h: h, in_w: w,
            };
            let (oh, ow) = (conv0.out_h(), conv0.out_w());
            let conv1 = ConvSpec {
                name: format!("{bname}c1"), cin: cout, cout, k: 3, stride: 1, pad: 1,
                relu: true, in_h: oh, in_w: ow,
            };
            let downsample = (first && si > 0).then(|| ConvSpec {
                name: format!("{bname}ds"), cin, cout, k: 1, stride, pad: 0, relu: false,
                in_h: h, in_w: w,
            });
            blocks.push(Segment::Residual(ResidualSpec {
                name: bname,
                body: vec![conv0, conv1],
                skips: vec![SkipSpec { from: None, proj: downsample }],
            }));
            cin = cout;
            h = oh;
            w = ow;
        }
    }
    blocks
}

fn cifar_stem() -> ConvSpec {
    ConvSpec {
        name: "stem".into(), cin: 3, cout: 16, k: 3, stride: 1, pad: 1, relu: true,
        in_h: 32, in_w: 32,
    }
}

fn resnet_preset(name: &str, blocks_per_stage: usize) -> ArchSpec {
    let mut segments = vec![Segment::Conv(cifar_stem())];
    segments.extend(make_blocks(&[16, 32, 64], blocks_per_stage));
    ArchSpec {
        name: name.into(),
        segments,
        fc_in: 64,
        fc_out: 10,
        in_h: 32,
        in_w: 32,
        in_c: 3,
        tied: BTreeMap::new(),
    }
}

/// The classic CIFAR ResNet20 of He et al. (3 stages x 3 blocks).
pub fn resnet20() -> ArchSpec {
    resnet_preset("resnet20", 3)
}

/// The MLPerf-Tiny-style ResNet8 (3 stages x 1 block).
pub fn resnet8() -> ArchSpec {
    resnet_preset("resnet8", 1)
}

/// A small non-ResNet skip topology exercising the general graph support:
/// an identity residual, a *multi-input* residual whose merge also takes a
/// long skip reaching back to the stem (3-operand add, kept as a naive
/// Eq. 21 dataflow island), and a strided projection residual.
pub fn skipnet() -> ArchSpec {
    let conv = |name: &str, cin, cout, k, stride, in_hw| ConvSpec {
        name: name.into(), cin, cout, k, stride, pad: if k == 1 { 0 } else { 1 },
        relu: k != 1, in_h: in_hw, in_w: in_hw,
    };
    let segments = vec![
        Segment::Conv(conv("stem", 3, 16, 3, 1, 32)),
        Segment::Residual(ResidualSpec {
            name: "r0".into(),
            body: vec![conv("r0c0", 16, 16, 3, 1, 32), conv("r0c1", 16, 16, 3, 1, 32)],
            skips: vec![SkipSpec::identity()],
        }),
        Segment::Residual(ResidualSpec {
            name: "r1".into(),
            body: vec![conv("r1c0", 16, 16, 3, 1, 32), conv("r1c1", 16, 16, 3, 1, 32)],
            skips: vec![
                SkipSpec::identity(),
                SkipSpec { from: Some("stem".into()), proj: None },
            ],
        }),
        Segment::Residual(ResidualSpec {
            name: "r2".into(),
            body: vec![conv("r2c0", 16, 32, 3, 2, 32), conv("r2c1", 32, 32, 3, 1, 16)],
            skips: vec![SkipSpec { from: None, proj: Some(conv("r2ds", 16, 32, 1, 2, 32)) }],
        }),
    ];
    ArchSpec {
        name: "skipnet".into(),
        segments,
        fc_in: 32,
        fc_out: 10,
        in_h: 32,
        in_w: 32,
        in_c: 3,
        tied: BTreeMap::new(),
    }
}

/// The minimal 2-operand long-skip topology: an ordinary identity residual
/// followed by a residual whose *only* skip reaches back to the stem.  The
/// second merge has exactly the two-operand/single-skip shape the fused
/// dataflow matches structurally — but the skip is not block-local, so add
/// fusion must leave it a naive island sized at the full-frame bound
/// (regression arch for the Eq. 22-vs-long-skip soundness gate).
pub fn longskipnet() -> ArchSpec {
    let conv = |name: &str, relu| ConvSpec {
        name: name.into(), cin: 16, cout: 16, k: 3, stride: 1, pad: 1, relu,
        in_h: 32, in_w: 32,
    };
    let segments = vec![
        Segment::Conv(cifar_stem()),
        Segment::Residual(ResidualSpec {
            name: "r0".into(),
            body: vec![conv("r0c0", true), conv("r0c1", true)],
            skips: vec![SkipSpec::identity()],
        }),
        Segment::Residual(ResidualSpec {
            name: "r1".into(),
            body: vec![conv("r1c0", true), conv("r1c1", true)],
            skips: vec![SkipSpec { from: Some("stem".into()), proj: None }],
        }),
    ];
    ArchSpec {
        name: "longskipnet".into(),
        segments,
        fc_in: 16,
        fc_out: 10,
        in_h: 32,
        in_w: 32,
        in_c: 3,
        tied: BTreeMap::new(),
    }
}

/// A weight-tied ODE-style net: one identity residual block instantiated
/// `n` times, every instance sharing the same two parameter blobs
/// (`tie_c0` / `tie_c1`).  Depth scales with `n` at constant param bytes.
pub fn tiednet(n: usize) -> ArchSpec {
    let mut segments = vec![Segment::Conv(cifar_stem())];
    let mut tied = BTreeMap::new();
    for i in 0..n {
        let c0 = ConvSpec {
            name: format!("t{i}c0"), cin: 16, cout: 16, k: 3, stride: 1, pad: 1, relu: true,
            in_h: 32, in_w: 32,
        };
        let c1 = ConvSpec { name: format!("t{i}c1"), ..c0.clone() };
        tied.insert(c0.name.clone(), "tie_c0".into());
        tied.insert(c1.name.clone(), "tie_c1".into());
        segments.push(Segment::Residual(ResidualSpec {
            name: format!("t{i}"),
            body: vec![c0, c1],
            skips: vec![SkipSpec::identity()],
        }));
    }
    ArchSpec {
        name: "tiednet".into(),
        segments,
        fc_in: 16,
        fc_out: 10,
        in_h: 32,
        in_w: 32,
        in_c: 3,
        tied,
    }
}

/// Exponent lookup: tensor-name -> activation exponent (from the manifest,
/// or defaults matching `arch.py` when absent).
pub type ActExps = std::collections::BTreeMap<String, i32>;
pub type WExps = std::collections::BTreeMap<String, i32>;

fn conv_attrs(spec: &ConvSpec, relu: bool, w_exps: &WExps, act_exps: &ActExps) -> ConvAttrs {
    ConvAttrs {
        cin: spec.cin,
        cout: spec.cout,
        k: spec.k,
        stride: spec.stride,
        pad: spec.pad,
        relu,
        w_exp: w_exps[&spec.name],
        out_exp: act_exps[&spec.name],
        merged_downsample: None,
        forwards_input: false, raw_output: false,
    }
}

/// Resolve a skip operand's source node: the segment input for `from:
/// None`, an earlier named segment's output otherwise.
fn skip_source(sk: &SkipSpec, xin: NodeId, named: &BTreeMap<String, NodeId>) -> NodeId {
    match &sk.from {
        None => xin,
        Some(nm) => named[nm.as_str()],
    }
}

/// Emit a residual segment in its naive (Fig. 10) form: projection convs,
/// body chain with the last conv streaming raw int32 accumulators, an
/// explicit N-input Add at accumulator precision, and the post-add ReLU.
fn emit_naive_residual(
    g: &mut Graph,
    r: &ResidualSpec,
    xin: NodeId,
    named: &BTreeMap<String, NodeId>,
    act_exps: &ActExps,
    w_exps: &WExps,
) -> NodeId {
    let mut skip_nodes = Vec::new();
    for sk in &r.skips {
        let src = skip_source(sk, xin, named);
        skip_nodes.push(match &sk.proj {
            Some(p) => g.add_simple(
                &p.name,
                Op::Conv(conv_attrs(p, false, w_exps, act_exps)),
                &[Edge::new(src, 0)],
            ),
            None => src,
        });
    }
    let last = r.body.len() - 1;
    let mut cur = xin;
    for (i, c) in r.body.iter().enumerate() {
        let attrs = if i == last {
            // The final body conv streams raw int32 accumulators: the naive
            // dataflow performs the merge at accumulator precision and
            // applies ReLU after the add (Fig. 10).
            ConvAttrs { relu: false, raw_output: true, ..conv_attrs(c, false, w_exps, act_exps) }
        } else {
            conv_attrs(c, c.relu, w_exps, act_exps)
        };
        cur = g.add_simple(&c.name, Op::Conv(attrs), &[Edge::new(cur, 0)]);
    }
    let mut add_inputs = vec![Edge::new(cur, 0)];
    add_inputs.extend(skip_nodes.iter().map(|&s| Edge::new(s, 0)));
    let add = g.add_simple(
        format!("{}_add", r.name),
        Op::Add { out_exp: act_exps[&r.body[last].name] },
        &add_inputs,
    );
    g.add_simple(format!("{}_relu", r.name), Op::Relu, &[Edge::new(add, 0)])
}

fn emit_tail(g: &mut Graph, arch: &ArchSpec, prev: NodeId, act_exps: &ActExps, w_exps: &WExps) {
    let pool = g.add_simple("pool", Op::GlobalAvgPool { out_exp: act_exps["pool"] }, &[Edge::new(prev, 0)]);
    g.add_simple(
        "fc",
        Op::Linear { cin: arch.fc_in, cout: arch.fc_out, w_exp: w_exps["fc"] },
        &[Edge::new(pool, 0)],
    );
}

/// Build the *pre-optimization* graph: explicit Add nodes for the residual
/// merges, no loop merging, no input forwarding, ReLU folded into convs but
/// the post-add ReLU explicit (paper Fig. 10 topology).  This is the input
/// to the `passes` pipeline.
pub fn build_unoptimized_graph(arch: &ArchSpec, act_exps: &ActExps, w_exps: &WExps) -> Graph {
    let mut g = Graph::new();
    let mut prev = g.add_simple(
        "input",
        Op::Input { h: arch.in_h, w: arch.in_w, c: arch.in_c, exp: act_exps["input"] },
        &[],
    );
    let mut named: BTreeMap<String, NodeId> = BTreeMap::new();
    for seg in &arch.segments {
        prev = match seg {
            Segment::Conv(c) => {
                let id = g.add_simple(
                    &c.name,
                    Op::Conv(conv_attrs(c, c.relu, w_exps, act_exps)),
                    &[Edge::new(prev, 0)],
                );
                named.insert(c.name.clone(), id);
                id
            }
            Segment::Residual(r) => {
                let id = emit_naive_residual(&mut g, r, prev, &named, act_exps, w_exps);
                named.insert(r.name.clone(), id);
                id
            }
        };
    }
    emit_tail(&mut g, arch, prev, act_exps, w_exps);
    g
}

/// Build the *optimized* graph directly (paper Fig. 14): loop-merged
/// downsamples, input forwarding on identity skips, adds fused into conv1
/// accumulator initialization.  Residual segments where the fused pattern
/// does not apply (multi-input merges, long skips, deep bodies) fall back
/// to the naive Eq. 21 dataflow island.  The passes pipeline must transform
/// the unoptimized graph into exactly this dataflow (asserted in tests).
pub fn build_optimized_graph(arch: &ArchSpec, act_exps: &ActExps, w_exps: &WExps) -> Graph {
    let mut g = Graph::new();
    let mut prev = g.add_simple(
        "input",
        Op::Input { h: arch.in_h, w: arch.in_w, c: arch.in_c, exp: act_exps["input"] },
        &[],
    );
    let mut named: BTreeMap<String, NodeId> = BTreeMap::new();
    for seg in &arch.segments {
        prev = match seg {
            Segment::Conv(c) => {
                let id = g.add_simple(
                    &c.name,
                    Op::Conv(conv_attrs(c, c.relu, w_exps, act_exps)),
                    &[Edge::new(prev, 0)],
                );
                named.insert(c.name.clone(), id);
                id
            }
            Segment::Residual(r) if r.fusable() => {
                let xin = prev;
                let (conv0, conv1) = (&r.body[0], &r.body[1]);
                let (c0, skip_edge) = match &r.skips[0].proj {
                    Some(ds) => {
                        // Loop merge: the downsample conv is computed inside
                        // conv0's task; its result appears on conv0's port 1.
                        let mut a0 = conv_attrs(conv0, true, w_exps, act_exps);
                        a0.merged_downsample = Some(crate::graph::MergedDownsample {
                            name: ds.name.clone(),
                            cout: ds.cout,
                            k: ds.k,
                            stride: ds.stride,
                            pad: ds.pad,
                            w_exp: w_exps[&ds.name],
                            out_exp: act_exps[&ds.name],
                        });
                        let c0 = g.add_simple(&conv0.name, Op::Conv(a0), &[Edge::new(xin, 0)]);
                        (c0, Edge::new(c0, 1))
                    }
                    None => {
                        // Temporal reuse: conv0 forwards its input on port 1.
                        let mut a0 = conv_attrs(conv0, true, w_exps, act_exps);
                        a0.forwards_input = true;
                        let c0 = g.add_simple(&conv0.name, Op::Conv(a0), &[Edge::new(xin, 0)]);
                        (c0, Edge::new(c0, 1))
                    }
                };
                // Add fusion: conv1 takes the skip stream as a SkipInit
                // input and fuses the post-add ReLU.
                let c1 = g.add(
                    &conv1.name,
                    Op::Conv(conv_attrs(conv1, true, w_exps, act_exps)),
                    vec![(Edge::new(c0, 0), InputRole::Data), (skip_edge, InputRole::SkipInit)],
                );
                named.insert(r.name.clone(), c1);
                c1
            }
            Segment::Residual(r) => {
                let id = emit_naive_residual(&mut g, r, prev, &named, act_exps, w_exps);
                named.insert(r.name.clone(), id);
                id
            }
        };
    }
    emit_tail(&mut g, arch, prev, act_exps, w_exps);
    g
}

/// Default exponent tables matching `python/compile/arch.py` (used by tests
/// and tooling when no manifest is loaded).
pub fn default_exps(arch: &ArchSpec) -> (ActExps, WExps) {
    let mut act = ActExps::new();
    act.insert("input".into(), -7);
    act.insert("pool".into(), -5);
    for c in arch.conv_layers() {
        act.insert(c.name.clone(), -5);
    }
    let mut w = WExps::new();
    for c in arch.conv_layers() {
        // Both the layer name and its shared weight key (for tied layers)
        // resolve — builders look up by layer name, blobs by key.
        w.insert(c.name.clone(), -8);
        w.insert(arch.weight_key(&c.name).to_string(), -8);
    }
    w.insert("fc".into(), -8);
    (act, w)
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::graph::infer_shapes;

    #[test]
    fn resnet20_has_expected_structure() {
        let a = resnet20();
        assert_eq!(a.residuals().count(), 9);
        // 1 stem + 9*2 block convs + 2 downsamples = 21 convs
        assert_eq!(a.conv_layers().len(), 21);
        // ~40.5M MACs (He et al. report ~41M for CIFAR ResNet20)
        let m = a.total_macs();
        assert!((40_000_000..42_000_000).contains(&m), "macs = {m}");
    }

    #[test]
    fn resnet8_has_expected_structure() {
        let a = resnet8();
        assert_eq!(a.residuals().count(), 3);
        assert_eq!(a.conv_layers().len(), 9);
        // ~12.5M MACs (MLPerf Tiny ResNet8 class)
        let m = a.total_macs();
        assert!((11_000_000..14_000_000).contains(&m), "macs = {m}");
    }

    #[test]
    fn both_graph_forms_validate_and_shape() {
        for arch in [resnet8(), resnet20(), skipnet(), longskipnet(), tiednet(3)] {
            let (act, w) = default_exps(&arch);
            for g in [
                build_unoptimized_graph(&arch, &act, &w),
                build_optimized_graph(&arch, &act, &w),
            ] {
                g.validate().unwrap_or_else(|e| panic!("{}: {e}", arch.name));
                let shapes = infer_shapes(&g).unwrap();
                // Final logits: 10 channels.
                let out = g.output().unwrap();
                let s = shapes[&crate::graph::Edge::new(out, 0)];
                assert_eq!(s.c, 10);
            }
        }
    }

    #[test]
    fn optimized_graph_has_no_add_nodes() {
        let arch = resnet20();
        let (act, w) = default_exps(&arch);
        let g = build_optimized_graph(&arch, &act, &w);
        assert_eq!(g.count_kind("add"), 0);
        assert_eq!(g.count_kind("relu"), 0);
        // 9 conv1 nodes carry SkipInit inputs.
        let skips = g
            .live()
            .filter(|n| n.inputs.iter().any(|(_, r)| *r == crate::graph::InputRole::SkipInit))
            .count();
        assert_eq!(skips, 9);
    }

    #[test]
    fn unoptimized_graph_has_explicit_adds() {
        let arch = resnet8();
        let (act, w) = default_exps(&arch);
        let g = build_unoptimized_graph(&arch, &act, &w);
        assert_eq!(g.count_kind("add"), 3);
        assert_eq!(g.count_kind("relu"), 3);
    }

    #[test]
    fn skipnet_keeps_multi_input_add_in_optimized_form() {
        let arch = skipnet();
        let (act, w) = default_exps(&arch);
        let g = build_optimized_graph(&arch, &act, &w);
        // r0 / r2 fuse; r1 (3-operand merge with a long skip to the stem)
        // stays a naive island.
        assert_eq!(g.count_kind("add"), 1);
        let add = g.node(g.find("r1_add").expect("r1_add"));
        assert_eq!(add.inputs.len(), 3);
        // The long-skip operand reads the stem's output edge directly.
        let stem = g.find("stem").expect("stem");
        assert!(add.inputs.iter().any(|(e, _)| e.node == stem));
    }

    #[test]
    fn tiednet_shares_parameter_blobs() {
        let a = tiednet(4);
        assert_eq!(a.residuals().count(), 4);
        // 8 tied body convs collapse to 2 keys; + stem + fc = 4 blobs.
        assert_eq!(a.param_names(), vec!["stem", "tie_c0", "tie_c1", "fc"]);
        assert_eq!(a.weight_key("t3c1"), "tie_c1");
        assert_eq!(a.weight_key("stem"), "stem");
    }
}
