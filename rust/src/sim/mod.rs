//! Cycle-approximate simulation of the dataflow accelerator.
//!
//! Two complementary halves:
//!
//! * [`golden`] — *numerics*: executes the graph with the exact integer
//!   arithmetic of the hardware (bit-equal to the jnp oracle and to the
//!   AOT-compiled HLO run through PJRT).
//! * [`engine`] / [`build`] — *timing*: a discrete-event process-network
//!   simulation of the concurrent tasks (window buffers, parameter tasks,
//!   computation pipelines, DMA) connected by bounded FIFOs, reproducing
//!   the paper's Section III dataflow mechanics: startup (window fill)
//!   latency, steady-state initiation interval, backpressure stalls, and —
//!   crucially — *deadlock* when a residual skip FIFO is sized below the
//!   receptive-field bound in the naive dataflow (the failure mode the
//!   Section III-G optimizations exist to avoid).
//! * [`baselines`] — performance models of the comparison systems in
//!   Table 3 (overlay/Vitis-AI-like, FINN-like, AdderNet-like).

pub mod baselines;
mod build;
mod engine;
pub mod golden;

pub use build::{build_network, SimOptions};
pub use engine::{FifoStats, Network, SimReport, Step, TaskModel, TaskStats};
