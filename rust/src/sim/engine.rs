//! The discrete-event process-network engine.
//!
//! Model: a set of tasks connected by bounded FIFOs measured in
//! *activation elements*.  Each task is a deterministic state machine that
//! fires in *steps*; a step has a data precondition (enough elements in
//! the input FIFOs, enough free space in the output FIFOs), a duration in
//! cycles, and element moves (pops at fire time, pushes at completion —
//! space is reserved at fire so two in-flight steps cannot oversubscribe).
//!
//! The paper's task taxonomy maps as: one `Step`-driven task per
//! computation task (its window-buffer tasks are folded into the input
//! FIFO precondition — the window buffer *is* the FIFO chain, Fig. 7),
//! plus DMA source/sink tasks and (naive dataflow only) tee + add tasks.
//!
//! Time advances to the earliest in-flight completion when nothing can
//! fire; if nothing is in flight and work remains, that is a deadlock —
//! reported, not panicked, because deadlock is an *expected result* for
//! undersized residual FIFOs (that is the experiment of Fig. 14).


/// FIFO identifier.
pub type FifoId = usize;
/// Task identifier.
pub type TaskId = usize;

#[derive(Debug, Clone)]
pub struct Fifo {
    pub name: String,
    pub capacity: usize,
    /// Elements present (available to the consumer).
    pub occupancy: usize,
    /// Elements reserved by an in-flight producer step.
    pub reserved: usize,
    pub total_pushed: u64,
    pub max_occupancy: usize,
}

impl Fifo {
    pub fn free(&self) -> usize {
        self.capacity - self.occupancy - self.reserved
    }
}

/// One firing rule evaluation — what a task wants to do next.
#[derive(Debug, Clone, Default)]
pub struct Step {
    /// (fifo, elements) to pop at fire time.
    pub pops: Vec<(FifoId, usize)>,
    /// Additional data precondition: fifo must have received at least this
    /// many elements in total (sliding-window lookahead).
    pub need_total: Vec<(FifoId, u64)>,
    /// (fifo, elements) to push at completion (space reserved at fire).
    pub pushes: Vec<(FifoId, usize)>,
    /// Duration in cycles.
    pub cycles: u64,
}

/// Task behaviour: produce the next step, or None when the frame program
/// is exhausted.
pub trait TaskModel {
    fn next_step(&mut self) -> Option<Step>;
    /// Reset for the next frame (programs are per-frame; the engine calls
    /// this automatically when a task exhausts while frames remain).
    fn reset_frame(&mut self);
    fn name(&self) -> &str;
}

struct TaskState {
    model: Box<dyn TaskModel>,
    /// Current pending (not yet fired) step.
    pending: Option<Step>,
    /// Completion time of the in-flight step, if any.
    busy_until: Option<u64>,
    in_flight: Option<Step>,
    frames_done: u32,
    stall_cycles: u64,
    busy_cycles: u64,
    last_ready_check: u64,
}

/// Simulation result.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Completion time of each frame at the sink (cycles).
    pub frame_done: Vec<u64>,
    /// Steady-state initiation interval (difference of last two frames).
    pub ii_cycles: u64,
    /// First-frame latency (cycles).
    pub latency_cycles: u64,
    pub total_cycles: u64,
    pub deadlocked: bool,
    pub fifo_stats: Vec<FifoStats>,
    pub task_stats: Vec<TaskStats>,
}

#[derive(Debug, Clone)]
pub struct FifoStats {
    pub name: String,
    pub capacity: usize,
    pub max_occupancy: usize,
    pub total_pushed: u64,
}

#[derive(Debug, Clone)]
pub struct TaskStats {
    pub name: String,
    pub busy_cycles: u64,
    pub stall_cycles: u64,
}

impl SimReport {
    pub fn fps(&self, clock_mhz: f64) -> f64 {
        if self.deadlocked || self.ii_cycles == 0 {
            return 0.0;
        }
        clock_mhz * 1e6 / self.ii_cycles as f64
    }

    pub fn latency_ms(&self, clock_mhz: f64) -> f64 {
        self.latency_cycles as f64 / (clock_mhz * 1e6) * 1e3
    }

    pub fn fifo(&self, name: &str) -> Option<&FifoStats> {
        self.fifo_stats.iter().find(|f| f.name == name)
    }
}

/// The process network.
pub struct Network {
    fifos: Vec<Fifo>,
    tasks: Vec<TaskState>,
    /// Index of the sink task whose frame completions are the report.
    sink: TaskId,
    frames: u32,
}

impl Network {
    pub fn new() -> Self {
        Network { fifos: Vec::new(), tasks: Vec::new(), sink: 0, frames: 1 }
    }

    pub fn add_fifo(&mut self, name: impl Into<String>, capacity: usize) -> FifoId {
        self.fifos.push(Fifo {
            name: name.into(),
            capacity,
            occupancy: 0,
            reserved: 0,
            total_pushed: 0,
            max_occupancy: 0,
        });
        self.fifos.len() - 1
    }

    pub fn add_task(&mut self, model: Box<dyn TaskModel>) -> TaskId {
        self.tasks.push(TaskState {
            model,
            pending: None,
            busy_until: None,
            in_flight: None,
            frames_done: 0,
            stall_cycles: 0,
            busy_cycles: 0,
            last_ready_check: 0,
        });
        self.tasks.len() - 1
    }

    pub fn set_sink(&mut self, t: TaskId) {
        self.sink = t;
    }

    /// Run `frames` frames; every task's per-frame program restarts as it
    /// exhausts (data-driven, like `ap_ctrl_none` — tasks never wait for a
    /// global frame boundary).
    pub fn run(&mut self, frames: u32) -> SimReport {
        self.frames = frames;
        let mut now = 0u64;
        let mut frame_done = Vec::new();
        let safety_cap: u64 = 50_000_000_000;

        loop {
            // 1. Complete all in-flight steps due at `now` (pushes land).
            // 2. Fire every ready task.
            // 3. If nothing in flight and everything stalled -> deadlock.
            let mut progressed = true;
            while progressed {
                progressed = false;
                for t in 0..self.tasks.len() {
                    if self.tasks[t].busy_until.is_some()
                        || self.tasks[t].frames_done >= self.frames
                    {
                        continue;
                    }
                    // Get (or fetch) the pending step.
                    if self.tasks[t].pending.is_none() {
                        match self.tasks[t].model.next_step() {
                            Some(s) => self.tasks[t].pending = Some(s),
                            None => {
                                self.tasks[t].frames_done += 1;
                                if t == self.sink {
                                    frame_done.push(now);
                                }
                                if self.tasks[t].frames_done < self.frames {
                                    self.tasks[t].model.reset_frame();
                                    match self.tasks[t].model.next_step() {
                                        Some(s) => self.tasks[t].pending = Some(s),
                                        None => continue,
                                    }
                                } else {
                                    continue;
                                }
                            }
                        }
                    }
                    // Check preconditions.
                    let ready = {
                        let s = self.tasks[t].pending.as_ref().unwrap();
                        s.pops.iter().all(|&(f, n)| self.fifos[f].occupancy >= n)
                            && s.need_total.iter().all(|&(f, n)| self.fifos[f].total_pushed >= n)
                            && s.pushes.iter().all(|&(f, n)| self.fifos[f].free() >= n)
                    };
                    if ready {
                        let s = self.tasks[t].pending.take().unwrap();
                        for &(f, n) in &s.pops {
                            self.fifos[f].occupancy -= n;
                        }
                        for &(f, n) in &s.pushes {
                            self.fifos[f].reserved += n;
                        }
                        let dur = s.cycles.max(1);
                        self.tasks[t].busy_until = Some(now + dur);
                        self.tasks[t].busy_cycles += dur;
                        self.tasks[t].stall_cycles += now - self.tasks[t].last_ready_check;
                        self.tasks[t].in_flight = Some(s);
                        progressed = true;
                    } else {
                        self.tasks[t].last_ready_check = self.tasks[t].last_ready_check.max(now);
                    }
                }
            }

            // All sinks done?
            if self.tasks.iter().all(|t| t.frames_done >= self.frames) {
                return self.report(now, frame_done, false);
            }

            // Advance to the earliest completion.
            let next = self
                .tasks
                .iter()
                .filter_map(|t| t.busy_until)
                .min();
            match next {
                Some(t_next) => {
                    now = t_next;
                    for t in &mut self.tasks {
                        if t.busy_until == Some(now) {
                            t.busy_until = None;
                            if let Some(s) = t.in_flight.take() {
                                for &(f, n) in &s.pushes {
                                    let fifo = &mut self.fifos[f];
                                    fifo.reserved -= n;
                                    fifo.occupancy += n;
                                    fifo.total_pushed += n as u64;
                                    fifo.max_occupancy = fifo.max_occupancy.max(fifo.occupancy);
                                }
                            }
                            t.last_ready_check = now;
                        }
                    }
                }
                None => {
                    // Nothing in flight but work remains: deadlock.
                    return self.report(now, frame_done, true);
                }
            }
            if now > safety_cap {
                return self.report(now, frame_done, true);
            }
        }
    }

    fn report(&self, now: u64, frame_done: Vec<u64>, deadlocked: bool) -> SimReport {
        let ii = match frame_done.len() {
            0 => 0,
            1 => frame_done[0],
            n => frame_done[n - 1] - frame_done[n - 2],
        };
        SimReport {
            latency_cycles: frame_done.first().copied().unwrap_or(0),
            ii_cycles: ii,
            total_cycles: now,
            deadlocked,
            fifo_stats: self
                .fifos
                .iter()
                .map(|f| FifoStats {
                    name: f.name.clone(),
                    capacity: f.capacity,
                    max_occupancy: f.max_occupancy,
                    total_pushed: f.total_pushed,
                })
                .collect(),
            task_stats: self
                .tasks
                .iter()
                .map(|t| TaskStats {
                    name: t.model.name().to_string(),
                    busy_cycles: t.busy_cycles,
                    stall_cycles: t.stall_cycles,
                })
                .collect(),
            frame_done,
        }
    }
}

impl Default for Network {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A source that pushes `count` elements in bursts of `burst`.
    struct Source {
        fifo: FifoId,
        count: usize,
        burst: usize,
        sent: usize,
    }

    impl TaskModel for Source {
        fn next_step(&mut self) -> Option<Step> {
            if self.sent >= self.count {
                return None;
            }
            let n = self.burst.min(self.count - self.sent);
            self.sent += n;
            Some(Step { pushes: vec![(self.fifo, n)], cycles: 1, ..Default::default() })
        }
        fn reset_frame(&mut self) {
            self.sent = 0;
        }
        fn name(&self) -> &str {
            "source"
        }
    }

    /// A sink that pops `count` elements one at a time.
    struct Sink {
        fifo: FifoId,
        count: usize,
        got: usize,
        cycles_per_pop: u64,
    }

    impl TaskModel for Sink {
        fn next_step(&mut self) -> Option<Step> {
            if self.got >= self.count {
                return None;
            }
            self.got += 1;
            Some(Step { pops: vec![(self.fifo, 1)], cycles: self.cycles_per_pop, ..Default::default() })
        }
        fn reset_frame(&mut self) {
            self.got = 0;
        }
        fn name(&self) -> &str {
            "sink"
        }
    }

    #[test]
    fn source_sink_pipeline() {
        let mut net = Network::new();
        let f = net.add_fifo("pipe", 4);
        net.add_task(Box::new(Source { fifo: f, count: 16, burst: 1, sent: 0 }));
        let sink = net.add_task(Box::new(Sink { fifo: f, count: 16, got: 0, cycles_per_pop: 2 }));
        net.set_sink(sink);
        let rep = net.run(2);
        assert!(!rep.deadlocked);
        assert_eq!(rep.frame_done.len(), 2);
        // Sink is the bottleneck at 2 cycles/element: II ~ 32.
        assert!((30..=36).contains(&rep.ii_cycles), "ii = {}", rep.ii_cycles);
        assert!(rep.fifo("pipe").unwrap().max_occupancy <= 4);
    }

    #[test]
    fn undersized_fifo_with_burst_deadlocks() {
        let mut net = Network::new();
        let f = net.add_fifo("tiny", 2);
        // Burst of 4 can never fit in capacity 2 -> the source can never
        // fire -> deadlock detected, not hang.
        net.add_task(Box::new(Source { fifo: f, count: 4, burst: 4, sent: 0 }));
        let sink = net.add_task(Box::new(Sink { fifo: f, count: 4, got: 0, cycles_per_pop: 1 }));
        net.set_sink(sink);
        let rep = net.run(1);
        assert!(rep.deadlocked);
    }
}
