//! Build a simulatable process network from a graph + accelerator config.
//!
//! Mapping (paper Fig. 3):
//! * Input node  -> DMA-in task pushing pixel rows;
//! * Conv node   -> one computation task whose input FIFO *is* the window
//!   buffer (capacity B_i + producer burst); parameter tasks are depth-2
//!   never-stalling streams (Section III-E) and are folded into the task;
//! * fan-out     -> tee task (the "multiple endpoint" problem, Fig. 12) —
//!   only present in the naive dataflow;
//! * Add/ReLU    -> explicit streaming tasks (naive dataflow only);
//! * GlobalAvgPool / Linear -> streaming reduction tasks;
//! * output      -> DMA-out sink; its per-frame completion times give
//!   latency and steady-state initiation interval.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::graph::{infer_shapes, Edge, Graph, InputRole, Op};
use crate::hls::config::AcceleratorConfig;

use super::engine::{FifoId, Network, Step, TaskModel};

/// Simulation options.
#[derive(Debug, Clone)]
pub struct SimOptions {
    pub frames: u32,
    /// Scale factor on every residual skip FIFO capacity (1.0 = as
    /// configured).  Setting < 1.0 on the naive dataflow demonstrates the
    /// deadlock the paper's buffering bound prevents.
    pub skip_factor: f64,
    /// DMA bandwidth in activation bytes per fabric cycle (128-bit AXI).
    pub dma_bytes_per_cycle: usize,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { frames: 3, skip_factor: 1.0, dma_bytes_per_cycle: 16 }
    }
}

// ---------------------------------------------------------------- tasks

/// DMA source: one row of pixels per step.
struct DmaIn {
    name: String,
    out: FifoId,
    rows: usize,
    row_elems: usize,
    cycles_per_row: u64,
    row: usize,
}

impl TaskModel for DmaIn {
    fn next_step(&mut self) -> Option<Step> {
        if self.row >= self.rows {
            return None;
        }
        self.row += 1;
        Some(Step {
            pushes: vec![(self.out, self.row_elems)],
            cycles: self.cycles_per_row,
            ..Default::default()
        })
    }
    fn reset_frame(&mut self) {
        self.row = 0;
    }
    fn name(&self) -> &str {
        &self.name
    }
}

/// A convolution computation task (window buffer folded into input FIFO).
struct ConvTask {
    name: String,
    input: FifoId,
    out: FifoId,
    /// Skip stream consumed as accumulator init (och*owp per group).
    skip: Option<FifoId>,
    /// Port-1 forward stream (temporal reuse): popped elements re-emitted.
    forward: Option<FifoId>,
    /// Merged downsample output (loop merge): pushed alongside out.
    ds_out: Option<(FifoId, usize)>, // (fifo, och_ds)
    // Geometry.
    ih: usize,
    iw: usize,
    ich: usize,
    oh: usize,
    ow: usize,
    och: usize,
    k: usize,
    stride: usize,
    pad: usize,
    ow_par: usize,
    och_groups: usize,
    window_cap: usize,
    // State.
    pg: usize,
    popped: usize,
    frame: u64,
}

impl ConvTask {
    fn groups_per_row(&self) -> usize {
        self.ow.div_ceil(self.ow_par)
    }

    fn total_groups(&self) -> usize {
        self.oh * self.groups_per_row()
    }

    /// Input elements (this frame) that must have arrived before output
    /// position-group `pg` can compute: through the window's last tap.
    fn required(&self, pg: usize) -> usize {
        let oy = pg / self.groups_per_row();
        let oxg = pg % self.groups_per_row();
        let ox_last = ((oxg + 1) * self.ow_par - 1).min(self.ow - 1);
        // Bottom-right tap in input coordinates (clamped by padding).
        let iy = (oy * self.stride + self.k - 1).saturating_sub(self.pad).min(self.ih - 1);
        let ix = (ox_last * self.stride + self.k - 1).saturating_sub(self.pad).min(self.iw - 1);
        (iy * self.iw + ix + 1) * self.ich
    }
}

impl TaskModel for ConvTask {
    fn next_step(&mut self) -> Option<Step> {
        if self.pg >= self.total_groups() {
            return None;
        }
        let pg = self.pg;
        self.pg += 1;
        let frame_total = self.ih * self.iw * self.ich;
        let req = self.required(pg);
        // Retire elements that slid out of the window; drain on last group.
        let keep = if self.pg == self.total_groups() { 0 } else { self.window_cap };
        let pop_n = req.saturating_sub(keep).saturating_sub(self.popped).min(
            if self.pg == self.total_groups() { frame_total - self.popped } else { usize::MAX },
        );
        let pop_n = if self.pg == self.total_groups() { frame_total - self.popped } else { pop_n };
        self.popped += pop_n;

        let ox_first = (pg % self.groups_per_row()) * self.ow_par;
        let positions = (self.ow - ox_first).min(self.ow_par);
        let burst = self.och * positions;

        let mut step = Step {
            pops: vec![(self.input, pop_n)],
            need_total: vec![(self.input, self.frame * frame_total as u64 + req as u64)],
            pushes: vec![(self.out, burst)],
            cycles: (self.ich * self.och_groups) as u64,
        };
        if let Some(sk) = self.skip {
            step.pops.push((sk, burst));
        }
        if let Some(fwd) = self.forward {
            if pop_n > 0 {
                step.pushes.push((fwd, pop_n));
            }
        }
        if let Some((ds, och_ds)) = self.ds_out {
            step.pushes.push((ds, och_ds * positions));
        }
        Some(step)
    }

    fn reset_frame(&mut self) {
        self.pg = 0;
        self.popped = 0;
        self.frame += 1;
    }

    fn name(&self) -> &str {
        &self.name
    }
}

/// Tee: duplicates a stream to two consumers (naive dataflow fan-out).
struct Tee {
    name: String,
    input: FifoId,
    outs: Vec<FifoId>,
    chunk: usize,
    total: usize,
    moved: usize,
}

impl TaskModel for Tee {
    fn next_step(&mut self) -> Option<Step> {
        if self.moved >= self.total {
            return None;
        }
        let n = self.chunk.min(self.total - self.moved);
        self.moved += n;
        Some(Step {
            pops: vec![(self.input, n)],
            pushes: self.outs.iter().map(|&f| (f, n)).collect(),
            cycles: 1,
            ..Default::default()
        })
    }
    fn reset_frame(&mut self) {
        self.moved = 0;
    }
    fn name(&self) -> &str {
        &self.name
    }
}

/// Elementwise binary/unary streaming task (Add / ReLU in the naive flow).
struct Elementwise {
    name: String,
    inputs: Vec<FifoId>,
    out: FifoId,
    chunk: usize,
    total: usize,
    cycles_per_chunk: u64,
    moved: usize,
}

impl TaskModel for Elementwise {
    fn next_step(&mut self) -> Option<Step> {
        if self.moved >= self.total {
            return None;
        }
        let n = self.chunk.min(self.total - self.moved);
        self.moved += n;
        Some(Step {
            pops: self.inputs.iter().map(|&f| (f, n)).collect(),
            pushes: vec![(self.out, n)],
            cycles: self.cycles_per_chunk,
            ..Default::default()
        })
    }
    fn reset_frame(&mut self) {
        self.moved = 0;
    }
    fn name(&self) -> &str {
        &self.name
    }
}

/// Global average pool: streams h*w positions, emits the channel vector.
struct PoolTask {
    name: String,
    input: FifoId,
    out: FifoId,
    positions: usize,
    c: usize,
    pos: usize,
}

impl TaskModel for PoolTask {
    fn next_step(&mut self) -> Option<Step> {
        if self.pos >= self.positions {
            return None;
        }
        self.pos += 1;
        let mut step = Step {
            pops: vec![(self.input, self.c)],
            cycles: 1,
            ..Default::default()
        };
        if self.pos == self.positions {
            step.pushes = vec![(self.out, self.c)];
            step.cycles = 4; // final shift+clip stage
        }
        Some(step)
    }
    fn reset_frame(&mut self) {
        self.pos = 0;
    }
    fn name(&self) -> &str {
        &self.name
    }
}

/// Fully connected classifier + DMA-out sink.
struct LinearSink {
    name: String,
    input: FifoId,
    cin: usize,
    cout: usize,
    simd: usize,
    done: bool,
}

impl TaskModel for LinearSink {
    fn next_step(&mut self) -> Option<Step> {
        if self.done {
            return None;
        }
        self.done = true;
        Some(Step {
            pops: vec![(self.input, self.cin)],
            cycles: ((self.cin * self.cout).div_ceil(self.simd)) as u64,
            ..Default::default()
        })
    }
    fn reset_frame(&mut self) {
        self.done = false;
    }
    fn name(&self) -> &str {
        &self.name
    }
}

// ---------------------------------------------------------------- builder

/// Build the process network for `g` under `cfg`.
///
/// Returns the network with its sink set (the Linear task).
pub fn build_network(g: &Graph, cfg: &AcceleratorConfig, opts: &SimOptions) -> Result<Network> {
    let shapes = infer_shapes(g).map_err(|e| anyhow!("{e}"))?;
    let mut net = Network::new();

    // Count consumers per edge to place tees.
    let mut consumers: BTreeMap<Edge, Vec<usize>> = BTreeMap::new();
    for n in g.live() {
        for (e, _) in &n.inputs {
            consumers.entry(*e).or_default().push(n.id);
        }
    }

    // For each (edge, consumer) pair there is exactly one FIFO; fan-out
    // edges get a tee task in between.
    let mut edge_fifo: BTreeMap<(Edge, usize), FifoId> = BTreeMap::new();

    // Capacity of the FIFO feeding `consumer` on `edge`.
    let consumer_capacity = |edge: &Edge, consumer: usize| -> usize {
        let n = g.node(consumer);
        match &n.op {
            Op::Conv(_) => {
                let lc = &cfg.convs[&consumer];
                let is_skip = n
                    .inputs
                    .iter()
                    .any(|(e, r)| e == edge && *r == InputRole::SkipInit);
                if is_skip {
                    let base = lc.skip_in.as_ref().map(|s| s.capacity()).unwrap_or(256);
                    let scaled = (base as f64 * opts.skip_factor) as usize;
                    scaled + lc.och * lc.ow_par + 64
                } else {
                    // The window buffer + producer burst headroom.
                    let s = shapes[edge];
                    lc.window_cap_with_margin(s.w, s.c)
                }
            }
            Op::Add { .. } => {
                let ac = &cfg.adds[&consumer];
                let s = shapes[edge];
                // Long branch (input 0) vs skip operands (inputs 1..N),
                // each sized from its own planned bound.
                if n.inputs[0].0 == *edge {
                    2 * s.c * 4
                } else {
                    let planned = n
                        .inputs
                        .iter()
                        .skip(1)
                        .position(|(e, _)| e == edge)
                        .and_then(|i| ac.skips.get(i))
                        .copied()
                        .unwrap_or(ac.skip_fifo);
                    ((planned as f64 * opts.skip_factor) as usize).max(4) + 2 * s.c
                }
            }
            Op::Relu | Op::GlobalAvgPool { .. } => {
                let s = shapes[edge];
                4 * s.c
            }
            Op::Linear { cin, .. } => *cin * 2,
            _ => 256,
        }
    };

    // Create FIFOs (with tee tasks where needed).
    let edges: Vec<Edge> = consumers.keys().copied().collect();
    for e in edges {
        let cons = consumers[&e].clone();
        if cons.len() == 1 {
            let cap = consumer_capacity(&e, cons[0]);
            let f = net.add_fifo(
                format!("{}.{} -> {}", g.node(e.node).name, e.port, g.node(cons[0]).name),
                cap,
            );
            edge_fifo.insert((e, cons[0]), f);
        } else {
            // Tee: producer -> tee_in -> per-consumer FIFOs.
            let s = shapes[&e];
            let tee_in = net.add_fifo(
                format!("{}.{} -> tee", g.node(e.node).name, e.port),
                4 * s.c.max(16),
            );
            let mut outs = Vec::new();
            for &c in &cons {
                let cap = consumer_capacity(&e, c);
                let f = net.add_fifo(
                    format!("tee({}) -> {}", g.node(e.node).name, g.node(c).name),
                    cap,
                );
                edge_fifo.insert((e, c), f);
                outs.push(f);
            }
            edge_fifo.insert((e, usize::MAX), tee_in); // producer writes here
            net.add_task(Box::new(Tee {
                name: format!("tee_{}", g.node(e.node).name),
                input: tee_in,
                outs,
                chunk: s.c,
                total: s.h * s.w * s.c,
                moved: 0,
            }));
        }
    }

    // FIFO the producer of `e` writes into.
    let out_fifo = |e: Edge| -> Option<FifoId> {
        if let Some(f) = edge_fifo.get(&(e, usize::MAX)) {
            return Some(*f);
        }
        consumers
            .get(&e)
            .and_then(|cons| cons.first())
            .and_then(|&c| edge_fifo.get(&(e, c)).copied())
    };
    let in_fifo = |e: Edge, consumer: usize| -> Result<FifoId> {
        edge_fifo
            .get(&(e, consumer))
            .copied()
            .ok_or_else(|| anyhow!("no fifo for edge {:?} -> {}", e, consumer))
    };

    let mut sink = None;
    for n in g.live() {
        match &n.op {
            Op::Input { h, w, c, .. } => {
                let out = out_fifo(Edge::new(n.id, 0))
                    .ok_or_else(|| anyhow!("input has no consumer"))?;
                net.add_task(Box::new(DmaIn {
                    name: "dma_in".into(),
                    out,
                    rows: *h,
                    row_elems: w * c,
                    cycles_per_row: ((w * c).div_ceil(opts.dma_bytes_per_cycle)) as u64,
                    row: 0,
                }));
            }
            Op::Conv(a) => {
                let lc = &cfg.convs[&n.id];
                let in_shape = shapes[&n.inputs[0].0];
                let input = in_fifo(n.inputs[0].0, n.id)?;
                let skip = n
                    .inputs
                    .iter()
                    .find(|(_, r)| *r == InputRole::SkipInit)
                    .map(|(e, _)| in_fifo(*e, n.id))
                    .transpose()?;
                let out = out_fifo(Edge::new(n.id, 0))
                    .ok_or_else(|| anyhow!("{} has no consumer", n.name))?;
                let forward = if a.forwards_input { out_fifo(Edge::new(n.id, 1)) } else { None };
                let ds_out = a
                    .merged_downsample
                    .as_ref()
                    .and_then(|m| out_fifo(Edge::new(n.id, 1)).map(|f| (f, m.cout)));
                net.add_task(Box::new(ConvTask {
                    name: n.name.clone(),
                    input,
                    out,
                    skip,
                    forward,
                    ds_out,
                    ih: in_shape.h,
                    iw: in_shape.w,
                    ich: a.cin,
                    oh: lc.oh,
                    ow: lc.ow,
                    och: a.cout,
                    k: a.k,
                    stride: a.stride,
                    pad: a.pad,
                    ow_par: lc.ow_par,
                    // Loop merge runs the downsample in the host loop's
                    // shadow (its unroll is sized for that in configure).
                    och_groups: lc
                        .och_groups
                        .max(lc.merged_ds.as_ref().map_or(0, |m| m.och.div_ceil(m.och_par))),
                    window_cap: lc.window_capacity,
                    pg: 0,
                    popped: 0,
                    frame: 0,
                }));
            }
            Op::Add { .. } => {
                let s = shapes[&Edge::new(n.id, 0)];
                let mut inputs = Vec::with_capacity(n.inputs.len());
                for (e, _) in &n.inputs {
                    inputs.push(in_fifo(*e, n.id)?);
                }
                let out = out_fifo(Edge::new(n.id, 0))
                    .ok_or_else(|| anyhow!("{} has no consumer", n.name))?;
                // Consume at the long branch's production rate.
                let producer_groups = cfg
                    .convs
                    .get(&n.inputs[0].0.node)
                    .map(|l| l.och_groups as u64)
                    .unwrap_or(1);
                net.add_task(Box::new(Elementwise {
                    name: n.name.clone(),
                    inputs,
                    out,
                    chunk: s.c,
                    total: s.h * s.w * s.c,
                    cycles_per_chunk: producer_groups,
                    moved: 0,
                }));
            }
            Op::Relu => {
                let s = shapes[&Edge::new(n.id, 0)];
                let input = in_fifo(n.inputs[0].0, n.id)?;
                let out = out_fifo(Edge::new(n.id, 0))
                    .ok_or_else(|| anyhow!("{} has no consumer", n.name))?;
                net.add_task(Box::new(Elementwise {
                    name: n.name.clone(),
                    inputs: vec![input],
                    out,
                    chunk: s.c,
                    total: s.h * s.w * s.c,
                    cycles_per_chunk: 1,
                    moved: 0,
                }));
            }
            Op::MaxPool { .. } | Op::GlobalAvgPool { .. } => {
                let in_shape = shapes[&n.inputs[0].0];
                let input = in_fifo(n.inputs[0].0, n.id)?;
                let out = out_fifo(Edge::new(n.id, 0))
                    .ok_or_else(|| anyhow!("{} has no consumer", n.name))?;
                net.add_task(Box::new(PoolTask {
                    name: n.name.clone(),
                    input,
                    out,
                    positions: in_shape.h * in_shape.w,
                    c: in_shape.c,
                    pos: 0,
                }));
            }
            Op::Linear { cin, cout, .. } => {
                let input = in_fifo(n.inputs[0].0, n.id)?;
                let t = net.add_task(Box::new(LinearSink {
                    name: n.name.clone(),
                    input,
                    cin: *cin,
                    cout: *cout,
                    simd: 16,
                    done: false,
                }));
                sink = Some(t);
            }
            Op::BatchNorm(_) => anyhow::bail!("simulate post-fold graphs only"),
        }
    }

    let sink = sink.ok_or_else(|| anyhow!("no linear sink in graph"))?;
    net.set_sink(sink);
    Ok(net)
}

impl crate::hls::config::LayerConfig {
    /// Input FIFO capacity for the simulation: the window buffer (Eq. 16/17)
    /// plus the row-advance slack this model's firing granularity needs.
    ///
    /// Hardware slides the window element-by-element as data arrives; the
    /// simulator fires once per output position-group and retires the slid
    /// elements at that coarser granularity, so across an output-row
    /// boundary the FIFO must additionally absorb `stride` input rows
    /// (`stride*iw*ich`) plus one producer burst.  The *reported* buffer
    /// sizes (resources, Eq. 16–23 checks) use the exact `window_capacity`.
    pub fn window_cap_with_margin(&self, in_w: usize, in_c: usize) -> usize {
        self.window_capacity + self.stride * in_w * in_c + self.och * self.ow_par + 4 * in_c + 64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::boards::ULTRA96;
    use crate::hls::config::configure;
    use crate::ilp::{loads_from_arch, solve};
    use crate::models::{
        build_optimized_graph, build_unoptimized_graph, default_exps, resnet8,
    };

    fn sim(optimized: bool, opts: &SimOptions) -> super::super::SimReport {
        let arch = resnet8();
        let (act, w) = default_exps(&arch);
        let g = if optimized {
            build_optimized_graph(&arch, &act, &w)
        } else {
            build_unoptimized_graph(&arch, &act, &w)
        };
        let alloc = solve(&loads_from_arch(&arch, 2), 360).unwrap();
        let cfg = configure(&arch.name, &g, &alloc, &ULTRA96, 2).unwrap();
        let mut net = build_network(&g, &cfg, opts).unwrap();
        net.run(opts.frames)
    }

    #[test]
    fn optimized_resnet8_runs_without_deadlock() {
        let rep = sim(true, &SimOptions::default());
        assert!(!rep.deadlocked, "optimized dataflow must not deadlock");
        assert_eq!(rep.frame_done.len(), 3);
        // Steady-state II within 2x of the ILP bound (pipeline effects).
        let fps = rep.fps(214.0);
        assert!(fps > 4000.0, "fps = {fps}");
    }

    #[test]
    fn naive_resnet8_needs_receptive_field_buffer() {
        // Fully sized (Eq. 21): runs.
        let rep = sim(false, &SimOptions { skip_factor: 1.0, ..Default::default() });
        assert!(!rep.deadlocked, "naive dataflow with Eq.21 buffers must run");
        // Halved (the optimized Eq. 22 size, *without* the graph
        // optimizations): deadlocks — this is the paper's core claim.
        let rep = sim(false, &SimOptions { skip_factor: 0.45, ..Default::default() });
        assert!(rep.deadlocked, "undersized naive skip FIFOs must deadlock");
    }

    #[test]
    fn optimized_skip_occupancy_matches_eq22() {
        let rep = sim(true, &SimOptions::default());
        // Find a fused skip FIFO and check its peak occupancy is within
        // the configured Eq. 22 capacity (plus margin).
        let skip = rep
            .fifo_stats
            .iter()
            .find(|f| f.name.contains("s0b0c0.1 -> s0b0c1"))
            .expect("forwarded skip fifo present");
        assert!(skip.max_occupancy <= skip.capacity);
        assert!(skip.max_occupancy > 0);
    }
}
