//! Golden integer inference: executes a network graph with the exact
//! accelerator numerics (paper Section III-A/C), bit-for-bit equal to
//! the jnp oracle (`python/compile/kernels/ref.py`) and — through the
//! AOT artifacts — to the PJRT-executed HLO.
//!
//! Handles both graph forms: the optimized dataflow (fused skip init,
//! merged downsamples, forwarded inputs) and the naive form (explicit
//! Add/ReLU nodes), which is how we prove the Section III-G transformations
//! numerics-preserving on this side of the language fence too.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::graph::{infer_shapes, ConvAttrs, Edge, Graph, InputRole, Op};
use crate::models::ModelWeights;
use crate::quant::{
    align_skip, clip_i8, clip_i8_wide, requantize, round_shift, round_shift_i64, QTensor, Shape4,
};

/// Run the graph on a batch of inputs. Returns the output-node tensor
/// (int32 logits for the paper's nets).
pub fn run(g: &Graph, weights: &ModelWeights, input: &QTensor) -> Result<QTensor> {
    let shapes = infer_shapes(g).map_err(|e| anyhow!("{e}"))?;
    let mut values: BTreeMap<Edge, QTensor> = BTreeMap::new();
    let mut output = None;

    for n in g.live() {
        let get = |i: usize, values: &BTreeMap<Edge, QTensor>| -> Result<QTensor> {
            let (e, _) = n.inputs[i];
            values
                .get(&e)
                .cloned()
                .ok_or_else(|| anyhow!("{}: missing input {i}", n.name))
        };
        match &n.op {
            Op::Input { h, w, c, exp } => {
                if (input.shape.h, input.shape.w, input.shape.c) != (*h, *w, *c) {
                    bail!("input shape {} vs expected ({h},{w},{c})", input.shape);
                }
                if input.exp != *exp {
                    bail!("input exp {} vs expected {exp}", input.exp);
                }
                values.insert(Edge::new(n.id, 0), input.clone());
            }
            Op::Conv(a) => {
                let x = get(0, &values)?;
                let skip = n
                    .inputs
                    .iter()
                    .position(|(_, r)| *r == InputRole::SkipInit)
                    .map(|i| get(i, &values))
                    .transpose()?;
                let lw = weights.layer(&n.name)?;
                let out = conv2d(&x, a, &lw.w.data, &lw.b.data, lw.acc_exp(), skip.as_ref())?;
                values.insert(Edge::new(n.id, 0), out);
                if a.forwards_input {
                    values.insert(Edge::new(n.id, 1), x.clone());
                }
                if let Some(ds) = &a.merged_downsample {
                    let dsw = weights.layer(&ds.name)?;
                    let ds_attrs = ConvAttrs {
                        cin: a.cin,
                        cout: ds.cout,
                        k: ds.k,
                        stride: ds.stride,
                        pad: ds.pad,
                        relu: false,
                        w_exp: ds.w_exp,
                        out_exp: ds.out_exp,
                        merged_downsample: None,
                        forwards_input: false, raw_output: false,
                    };
                    let out =
                        conv2d(&x, &ds_attrs, &dsw.w.data, &dsw.b.data, dsw.acc_exp(), None)?;
                    values.insert(Edge::new(n.id, 1), out);
                }
            }
            Op::Relu => {
                let x = get(0, &values)?;
                let data = x.data.iter().map(|&v| v.max(0)).collect();
                values.insert(Edge::new(n.id, 0), QTensor { data, ..x });
            }
            Op::Add { out_exp } => {
                // Naive residual add, performed at the finer of the two
                // input exponents then requantized — the dataflow the
                // pre-optimization graph implies.  With the builders'
                // exponent conventions this is bit-identical to the fused
                // accumulator-init form (asserted by tests).  The aligned
                // sum is widened to i64: a raw int32 accumulator stream
                // plus a shifted operand can exceed i32 (debug panic,
                // release wraparound) at large exponent gaps.
                let operands: Vec<QTensor> =
                    (0..n.inputs.len()).map(|i| get(i, &values)).collect::<Result<_>>()?;
                let lo = operands.iter().map(|t| t.exp).min().unwrap_or(*out_exp);
                let shifts: Vec<u32> =
                    operands.iter().map(|t| ((t.exp - lo) as u32).min(63)).collect();
                let elems = operands[0].data.len();
                let data: Vec<i32> = (0..elems)
                    .map(|j| {
                        let s: i64 = operands
                            .iter()
                            .zip(&shifts)
                            .map(|(t, &sh)| (t.data[j] as i64) << sh)
                            .sum();
                        clip_i8_wide(round_shift_i64(s, out_exp - lo))
                    })
                    .collect();
                values.insert(
                    Edge::new(n.id, 0),
                    QTensor { shape: operands[0].shape, exp: *out_exp, data },
                );
            }
            Op::MaxPool { k, stride } => {
                let x = get(0, &values)?;
                values.insert(Edge::new(n.id, 0), maxpool(&x, *k, *stride)?);
            }
            Op::GlobalAvgPool { out_exp } => {
                let x = get(0, &values)?;
                values.insert(Edge::new(n.id, 0), global_avgpool(&x, *out_exp)?);
            }
            Op::Linear { cin, cout, .. } => {
                let x = get(0, &values)?;
                let lw = weights.layer(&n.name)?;
                let out = linear(&x, *cin, *cout, &lw.w.data, &lw.b.data)?;
                output = Some(out.clone());
                values.insert(Edge::new(n.id, 0), out);
            }
            Op::BatchNorm(_) => bail!("golden model runs post-fold graphs only"),
        }
        let _ = &shapes; // shapes pre-validated the graph
    }
    output.ok_or_else(|| anyhow!("graph has no linear output node"))
}

/// Fused integer convolution (ref.py `conv2d_ref` semantics).
fn conv2d(
    x: &QTensor,
    a: &ConvAttrs,
    w: &[i32],
    bias: &[i32],
    acc_exp: i32,
    skip: Option<&QTensor>,
) -> Result<QTensor> {
    let (n, h, wd, cin) = (x.shape.n, x.shape.h, x.shape.w, x.shape.c);
    if cin != a.cin {
        bail!("conv cin mismatch: {} vs {}", cin, a.cin);
    }
    let (k, s, p, cout) = (a.k, a.stride, a.pad, a.cout);
    // Output-extent guards: a kernel larger than the padded input (or a
    // zero stride) must be a shape error, not a usize underflow/division
    // panic — mirrors `graph::shapes` validation.
    if s == 0 {
        bail!("conv stride must be >= 1");
    }
    if k == 0 || h + 2 * p < k || wd + 2 * p < k {
        bail!("conv kernel {k} exceeds padded input {h}x{wd} (pad {p})");
    }
    let oh = (h + 2 * p - k) / s + 1;
    let ow = (wd + 2 * p - k) / s + 1;
    let out_shape = Shape4::new(n, oh, ow, cout);
    let mut out = vec![0i32; out_shape.elems()];

    // Row-level accumulation (the output-stationary structure of the
    // paper's Fig. 4, and the performance-pass shape from EXPERIMENTS.md
    // §Perf): one accumulator row (OW x COUT) is initialized with bias +
    // aligned skip, then every filter tap streams its input row across all
    // output columns with the weight slice `w[tap][ci]` hot in cache and
    // the accumulator stride contiguous in `co`.
    let mut acc_row = vec![0i32; ow * cout];
    for b in 0..n {
        for oy in 0..oh {
            // init: bias (paper Fig. 4) + aligned skip (paper Fig. 13)
            for ox in 0..ow {
                acc_row[ox * cout..(ox + 1) * cout].copy_from_slice(bias);
            }
            if let Some(sk) = skip {
                let s_base = (b * oh + oy) * ow * cout;
                let shift = sk.exp - acc_exp;
                debug_assert!(shift >= 0);
                for (a_, &v) in acc_row.iter_mut().zip(&sk.data[s_base..s_base + ow * cout]) {
                    *a_ += v << shift;
                }
            }
            for ky in 0..k {
                let iy = oy * s + ky;
                if iy < p || iy - p >= h {
                    continue;
                }
                let x_row = ((b * h) + (iy - p)) * wd * cin;
                for kx in 0..k {
                    let w_tap = (ky * k + kx) * cin * cout;
                    for ox in 0..ow {
                        let ix = ox * s + kx;
                        if ix < p || ix - p >= wd {
                            continue;
                        }
                        let x_base = x_row + (ix - p) * cin;
                        let acc = &mut acc_row[ox * cout..(ox + 1) * cout];
                        for ci in 0..cin {
                            let xv = unsafe { *x.data.get_unchecked(x_base + ci) };
                            if xv == 0 {
                                continue;
                            }
                            let ws = &w[w_tap + ci * cout..w_tap + (ci + 1) * cout];
                            for (a_, &wv) in acc.iter_mut().zip(ws) {
                                *a_ += xv * wv;
                            }
                        }
                    }
                }
            }
            let o_base = (b * oh + oy) * ow * cout;
            if a.raw_output {
                out[o_base..o_base + ow * cout].copy_from_slice(&acc_row);
            } else {
                for (o_, &v) in out[o_base..o_base + ow * cout].iter_mut().zip(&acc_row) {
                    *o_ = requantize(v, acc_exp, a.out_exp, a.relu);
                }
            }
        }
    }
    let _ = align_skip; // used by the scalar contract; row path inlines it
    let exp = if a.raw_output { acc_exp } else { a.out_exp };
    Ok(QTensor { shape: out_shape, exp, data: out })
}

fn maxpool(x: &QTensor, k: usize, stride: usize) -> Result<QTensor> {
    let (n, h, w, c) = (x.shape.n, x.shape.h, x.shape.w, x.shape.c);
    if stride == 0 {
        bail!("maxpool stride must be >= 1");
    }
    if k == 0 || k > h || k > w {
        bail!("maxpool window {k} exceeds input {h}x{w}");
    }
    let oh = (h - k) / stride + 1;
    let ow = (w - k) / stride + 1;
    let shape = Shape4::new(n, oh, ow, c);
    let mut out = vec![i32::MIN; shape.elems()];
    for b in 0..n {
        for oy in 0..oh {
            for ox in 0..ow {
                for dy in 0..k {
                    for dx in 0..k {
                        let base = ((b * h + oy * stride + dy) * w + ox * stride + dx) * c;
                        let obase = ((b * oh + oy) * ow + ox) * c;
                        for ch in 0..c {
                            let v = x.data[base + ch];
                            if v > out[obase + ch] {
                                out[obase + ch] = v;
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(QTensor { shape, exp: x.exp, data: out })
}

fn global_avgpool(x: &QTensor, out_exp: i32) -> Result<QTensor> {
    let (n, h, w, c) = (x.shape.n, x.shape.h, x.shape.w, x.shape.c);
    let hw = h * w;
    // The hardware divides by shifting, so the window must be a power of
    // two; a malformed graph gets a typed error instead of panicking the
    // worker thread that runs the golden model.
    if !hw.is_power_of_two() {
        bail!("global pool window {h}x{w} must be 2^k for the shift divide");
    }
    let log_hw = hw.trailing_zeros() as i32;
    let shape = Shape4::new(n, 1, 1, c);
    let mut out = vec![0i32; shape.elems()];
    for b in 0..n {
        for ch in 0..c {
            let mut acc = 0i32;
            for y in 0..h {
                for xx in 0..w {
                    acc += x.data[((b * h + y) * w + xx) * c + ch];
                }
            }
            out[b * c + ch] = clip_i8(round_shift(acc, out_exp - x.exp + log_hw));
        }
    }
    Ok(QTensor { shape, exp: out_exp, data: out })
}

fn linear(x: &QTensor, cin: usize, cout: usize, w: &[i32], bias: &[i32]) -> Result<QTensor> {
    let n = x.shape.n;
    if x.shape.h * x.shape.w * x.shape.c != cin {
        bail!("linear input mismatch");
    }
    let shape = Shape4::new(n, 1, 1, cout);
    let mut out = vec![0i32; shape.elems()];
    for b in 0..n {
        for co in 0..cout {
            let mut acc = bias[co];
            for ci in 0..cin {
                acc += x.data[b * cin + ci] * w[ci * cout + co];
            }
            out[b * cout + co] = acc;
        }
    }
    Ok(QTensor { shape, exp: 0, data: out })
}

/// Argmax over the class axis of logits (N, 1, 1, C).
pub fn argmax_classes(logits: &QTensor) -> Vec<usize> {
    let c = logits.shape.c;
    logits
        .data
        .chunks_exact(c)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by_key(|&(_, v)| *v)
                .map(|(i, _)| i)
                .unwrap()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth_batch, TEST_SEED};
    use crate::models::{
        build_optimized_graph, build_unoptimized_graph, default_exps, resnet8, synthetic_weights,
    };
    use crate::passes::optimize;

    #[test]
    fn optimized_equals_unoptimized_equals_pipelined() {
        let arch = resnet8();
        let (act, w) = default_exps(&arch);
        let weights = synthetic_weights(&arch, 7);
        let (input, _) = synth_batch(0, 2, TEST_SEED);

        let g_opt = build_optimized_graph(&arch, &act, &w);
        let g_naive = build_unoptimized_graph(&arch, &act, &w);
        let mut g_pipe = build_unoptimized_graph(&arch, &act, &w);
        optimize(&mut g_pipe);

        let a = run(&g_opt, &weights, &input).unwrap();
        let b = run(&g_naive, &weights, &input).unwrap();
        let c = run(&g_pipe, &weights, &input).unwrap();
        assert_eq!(a.data, b.data, "fused vs explicit-add must be bit-identical");
        assert_eq!(a.data, c.data, "pass pipeline must preserve numerics");
        assert_eq!(a.shape.c, 10);
    }

    use crate::models::{ConvWeights, WeightTensor};
    use std::collections::BTreeMap;

    fn empty_weights() -> ModelWeights {
        ModelWeights {
            arch: "test".into(),
            layers: BTreeMap::new(),
            aliases: BTreeMap::new(),
            act_exps: BTreeMap::new(),
            w_exps: BTreeMap::new(),
            source: "test".into(),
        }
    }

    fn tensor(name: &str, kind: &str, shape: Vec<usize>, exp: i32, data: Vec<i32>) -> WeightTensor {
        WeightTensor { name: name.into(), kind: kind.into(), shape, exp, data }
    }

    #[test]
    fn add_at_overflow_boundary_is_widened_not_wrapped() {
        // Regression: a raw_output accumulator near i32::MAX feeding an
        // Add used to overflow the i32 aligned sum (panic in debug, wrap
        // in release).  The i64 widening clips it to the int8 grid.
        let mut g = Graph::new();
        let i = g.add_simple("input", Op::Input { h: 1, w: 1, c: 1, exp: -7 }, &[]);
        let c = g.add_simple(
            "c",
            Op::Conv(ConvAttrs {
                cin: 1, cout: 1, k: 1, stride: 1, pad: 0, relu: false,
                w_exp: -8, out_exp: -5, merged_downsample: None, forwards_input: false,
                raw_output: true,
            }),
            &[Edge::new(i, 0)],
        );
        let add = g.add_simple("add", Op::Add { out_exp: -5 }, &[Edge::new(c, 0), Edge::new(i, 0)]);
        let pool = g.add_simple("pool", Op::GlobalAvgPool { out_exp: -5 }, &[Edge::new(add, 0)]);
        g.add_simple("fc", Op::Linear { cin: 1, cout: 2, w_exp: -8 }, &[Edge::new(pool, 0)]);

        let mut weights = empty_weights();
        // Bias at the raw accumulator exponent (-15 = input -7 + w -8),
        // pinned just below the i32 boundary; zero weight keeps the raw
        // conv output exactly at the bias.
        weights.layers.insert(
            "c".into(),
            ConvWeights {
                w: tensor("c", "w", vec![1, 1, 1, 1], -8, vec![0]),
                b: tensor("c", "b", vec![1], -15, vec![i32::MAX - 100]),
            },
        );
        weights.layers.insert(
            "fc".into(),
            ConvWeights {
                w: tensor("fc", "w", vec![1, 2], -8, vec![3, -4]),
                b: tensor("fc", "b", vec![2], -13, vec![10, 20]),
            },
        );
        let input = QTensor::from_vec(Shape4::new(1, 1, 1, 1), -7, vec![1]);
        let out = run(&g, &weights, &input).unwrap();
        // (i32::MAX - 100) + (1 << 8) exceeds i32::MAX; the widened sum
        // round-shifts by 10 and clips to 127, so logits are exact.
        assert_eq!(out.data, vec![10 + 127 * 3, 20 - 127 * 4]);
    }

    #[test]
    fn malformed_global_pool_window_is_an_error_not_a_panic() {
        // 3x3 pool window is not a power of two: the shift divide cannot
        // represent it; run() must return Err instead of asserting.
        let mut g = Graph::new();
        let i = g.add_simple("input", Op::Input { h: 3, w: 3, c: 1, exp: -7 }, &[]);
        let pool = g.add_simple("pool", Op::GlobalAvgPool { out_exp: -5 }, &[Edge::new(i, 0)]);
        g.add_simple("fc", Op::Linear { cin: 1, cout: 2, w_exp: -8 }, &[Edge::new(pool, 0)]);
        let mut weights = empty_weights();
        weights.layers.insert(
            "fc".into(),
            ConvWeights {
                w: tensor("fc", "w", vec![1, 2], -8, vec![1, 1]),
                b: tensor("fc", "b", vec![2], -13, vec![0, 0]),
            },
        );
        let input = QTensor::from_vec(Shape4::new(1, 3, 3, 1), -7, vec![1; 9]);
        let err = run(&g, &weights, &input).unwrap_err();
        assert!(format!("{err:#}").contains("2^k"), "{err:#}");
    }

    #[test]
    fn oversized_kernels_are_shape_errors_not_underflow_panics() {
        // Conv kernel exceeding the padded input.
        let mut g = Graph::new();
        let i = g.add_simple("input", Op::Input { h: 3, w: 3, c: 1, exp: -7 }, &[]);
        g.add_simple(
            "c",
            Op::Conv(ConvAttrs {
                cin: 1, cout: 1, k: 5, stride: 1, pad: 0, relu: false,
                w_exp: -8, out_exp: -5, merged_downsample: None, forwards_input: false,
                raw_output: false,
            }),
            &[Edge::new(i, 0)],
        );
        let input = QTensor::from_vec(Shape4::new(1, 3, 3, 1), -7, vec![1; 9]);
        assert!(run(&g, &empty_weights(), &input).is_err());

        // MaxPool window exceeding the input.
        let mut g = Graph::new();
        let i = g.add_simple("input", Op::Input { h: 3, w: 3, c: 1, exp: -7 }, &[]);
        g.add_simple("mp", Op::MaxPool { k: 5, stride: 1 }, &[Edge::new(i, 0)]);
        assert!(run(&g, &empty_weights(), &input).is_err());

        // Zero stride must also be an error, not a divide-by-zero.
        let mut g = Graph::new();
        let i = g.add_simple("input", Op::Input { h: 3, w: 3, c: 1, exp: -7 }, &[]);
        g.add_simple("mp", Op::MaxPool { k: 2, stride: 0 }, &[Edge::new(i, 0)]);
        assert!(run(&g, &empty_weights(), &input).is_err());
    }

    #[test]
    fn logits_vary_with_input() {
        let arch = resnet8();
        let (act, w) = default_exps(&arch);
        let weights = synthetic_weights(&arch, 7);
        let g = build_optimized_graph(&arch, &act, &w);
        let (i1, _) = synth_batch(0, 1, TEST_SEED);
        let (i2, _) = synth_batch(5, 1, TEST_SEED);
        let a = run(&g, &weights, &i1).unwrap();
        let b = run(&g, &weights, &i2).unwrap();
        assert_ne!(a.data, b.data);
    }
}
