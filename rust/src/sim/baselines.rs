//! Performance models of the comparison systems in the paper's Table 3.
//!
//! The paper compares against bitstreams we cannot run: the WSQ-AdderNet
//! ResNet20/AdderNet accelerators of [32], and the FINN / Vitis-AI ResNet8
//! implementations of [30].  For the Table 3 reproduction we model each
//! comparator's *architecture class* (overlay with off-chip weights vs.
//! pipelined dataflow; DSP-packed vs. LUT-MAC; 8-bit vs. 4-bit) at the
//! fidelity needed for the paper's *relative* claims — who wins and by
//! roughly what factor — not their absolute board numbers.  Parameters are
//! taken from each system's published configuration, and every modeled row
//! is printed next to the paper's reported row by `eval::tables`.

use crate::models::ArchSpec;

/// A modeled Table-3 row.
#[derive(Debug, Clone)]
pub struct BaselineRow {
    pub name: String,
    pub bits: u32,
    pub clock_mhz: f64,
    pub fps: f64,
    pub gops: f64,
    pub latency_ms: f64,
    /// Accuracy delta vs. the 8-bit QAT model (percentage points),
    /// from the published numbers (e.g. 4-bit FINN: -2.8).
    pub accuracy_delta_pp: f64,
}

/// Overlay accelerator model (Vitis-AI DPU class, [30]'s Vitis AI row).
///
/// Architecture: a fixed PE array executes layers *sequentially*; weights
/// and intermediate activations move through off-chip DDR with per-layer
/// scheduling overhead.  Throughput is compute-bound at the array's peak
/// MACs/cycle, but latency pays the layer-serialization and memory round
/// trips — which is why the paper's dataflow design beats it ~28x on
/// latency at similar resources.
pub fn overlay_model(arch: &ArchSpec, clock_mhz: f64, pe_macs_per_cycle: u64) -> BaselineRow {
    let total_macs = arch.total_macs();
    let n_layers = arch.conv_layers().len() as u64 + 1;
    // Per-layer: compute + fixed scheduling/DMA overhead + activation
    // round-trip to DDR (NHWC int8, ~8 bytes/cycle effective).
    let sched_overhead_cycles = 12_000u64; // instruction fetch + reconfig
    let mut cycles = 0u64;
    for c in arch.conv_layers() {
        let compute = c.macs().div_ceil(pe_macs_per_cycle);
        let act_bytes = (c.out_h() * c.out_w() * c.cout) as u64;
        let ddr = act_bytes.div_ceil(8) * 2; // write + read back
        cycles += compute + ddr + sched_overhead_cycles;
    }
    cycles += (arch.fc_in * arch.fc_out) as u64 / 16 + sched_overhead_cycles;
    let _ = n_layers;
    let latency_s = cycles as f64 / (clock_mhz * 1e6);
    // Overlays pipeline across frames poorly (ping-pong buffers): assume
    // 1.5 frames in flight.
    let fps = 1.5 / latency_s;
    BaselineRow {
        name: "overlay (Vitis-AI class)".into(),
        bits: 8,
        clock_mhz,
        fps,
        gops: 2.0 * total_macs as f64 * fps / 1e9,
        latency_ms: latency_s * 1e3,
        accuracy_delta_pp: 0.5, // executes BN in hardware (paper Sec. IV)
    }
}

/// FINN-class dataflow model ([30]'s ResNet8 FINN row): pipelined
/// dataflow like ours, but 4-bit LUT-based MACs and *naive* residual
/// buffering (double-buffered skip tensors).
///
/// Throughput: LUT-bound MAC budget.  A raw 4-bit LUT multiplier is ~15
/// LUTs, but the *effective* fabric cost per sustained MAC/cycle in a
/// folded FINN pipeline — SWU generators, accumulators, thresholding,
/// FIFO glue — calibrates to ~90 LUTs against [30]'s reported ResNet8
/// configuration (13 475 FPS at 225 MHz in 81.4 kLUT).  Latency
/// additionally pays the naive double-buffered residual branches (no
/// Section III-G optimizations).
pub fn finn_model(arch: &ArchSpec, clock_mhz: f64, luts: u64) -> BaselineRow {
    let total_macs = arch.total_macs();
    let mac_budget = (luts as f64 * 0.6 / 90.0) as u64; // sustained 4b MACs
    // Balanced dataflow: bottleneck layer gets its proportional share.
    let c_max = arch.conv_layers().iter().map(|c| c.macs()).max().unwrap();
    let sum_c: u64 = arch.conv_layers().iter().map(|c| c.macs()).sum();
    let bottleneck_macs = (mac_budget as f64 * c_max as f64 / sum_c as f64).max(1.0);
    let ii = (c_max as f64 / bottleneck_macs).max(1.0);
    let fps = clock_mhz * 1e6 / ii;
    // Latency: II + window fills + naive skip buffering stalls (~1.6x II).
    let latency_s = 1.6 * ii / (clock_mhz * 1e6);
    BaselineRow {
        name: "FINN class (4-bit dataflow)".into(),
        bits: 4,
        clock_mhz,
        fps,
        gops: 2.0 * total_macs as f64 * fps / 1e9,
        latency_ms: latency_s * 1e3,
        accuracy_delta_pp: -2.8, // paper Sec. IV: 4-bit FINN trails by 2.8pp
    }
}

/// WSQ-AdderNet-class model ([32]): dataflow-ish accelerator with packed
/// int8 *adder* kernels; reported at 200 MHz with ~half our Gops/s.
///
/// Its packing co-locates adds in DSP+LUT pairs; per the published
/// numbers its efficiency per DSP is ~0.52 of ours at equal precision.
pub fn addernet_model(arch: &ArchSpec, clock_mhz: f64, dsps: u64) -> BaselineRow {
    let total_macs = arch.total_macs();
    // 1 op/DSP/cycle equivalent (no ow_par packing of multiplies) with 85%
    // utilization across the balanced pipeline.
    let macs_per_cycle = dsps as f64 * 0.85;
    let ii = arch.total_macs() as f64 / macs_per_cycle;
    let fps = clock_mhz * 1e6 / ii;
    BaselineRow {
        name: "AdderNet class (packed adders)".into(),
        bits: 8,
        clock_mhz,
        fps,
        gops: 2.0 * total_macs as f64 * fps / 1e9,
        latency_ms: 2.0 * ii / (clock_mhz * 1e6) * 1e3, // double-buffered frames
        accuracy_delta_pp: -1.4, // paper: AdderNet trails our CNN by 1.4pp
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{resnet20, resnet8};

    #[test]
    fn overlay_latency_dominated_by_serialization() {
        let arch = resnet8();
        let row = overlay_model(&arch, 200.0, 2048);
        // Paper: Vitis AI ResNet8 = 1.29 ms latency, 4458 FPS.
        assert!(row.latency_ms > 0.5 && row.latency_ms < 5.0, "{}", row.latency_ms);
        assert!(row.fps < 10_000.0);
    }

    #[test]
    fn dataflow_beats_overlay_on_latency_by_an_order() {
        // Our KV260 ResNet8 latency ~0.046 ms vs overlay ~1.3 ms: >10x.
        let arch = resnet8();
        let overlay = overlay_model(&arch, 200.0, 2048);
        assert!(
            overlay.latency_ms / 0.046 > 10.0,
            "overlay {} ms should be >10x of 0.046 ms",
            overlay.latency_ms
        );
    }

    #[test]
    fn finn_class_trails_on_accuracy() {
        let arch = resnet8();
        let row = finn_model(&arch, 225.0, 117_120);
        assert_eq!(row.bits, 4);
        assert!(row.accuracy_delta_pp < 0.0);
        assert!(row.fps > 1_000.0);
    }

    #[test]
    fn addernet_class_half_our_throughput() {
        let arch = resnet20();
        let row = addernet_model(&arch, 200.0, 609);
        // Paper: AdderNet = 317 Gops/s vs our 616 -> ratio ~0.5.
        let ratio = row.gops / 616.0;
        assert!((0.25..=0.8).contains(&ratio), "gops ratio {ratio}");
    }
}
