//! L3 inference coordinator: the request-path runtime around the compiled
//! accelerator models.
//!
//! The paper's deployment story is a free-running, data-driven accelerator
//! (`ap_ctrl_none`): frames stream in, results stream out, no per-frame
//! control handshake.  The software analogue here is a dedicated executor
//! thread per architecture that drains a request queue through a dynamic
//! batcher (one compiled executable per batch bucket — batch sizes are
//! baked into the AOT artifacts) and streams responses back over channels.
//! Python is never involved.

mod batcher;
mod metrics;
mod server;

pub use batcher::{BatchPlan, Batcher, BatcherConfig};
pub use metrics::{Metrics, MetricsSnapshot};
pub use server::{InferenceServer, Request, Response};
