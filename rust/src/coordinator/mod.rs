//! L3 inference coordinator: the request-path runtime above the
//! backend-agnostic execution API.
//!
//! The paper's deployment story is a free-running, data-driven accelerator
//! (`ap_ctrl_none`): frames stream in, results stream out, no per-frame
//! control handshake.  The software analogue is the [`Router`]: one handle
//! owning a worker pool per architecture.  Each pool drains a shared
//! request queue through the dynamic [`Batcher`] into an
//! [`InferenceBackend`](crate::runtime::InferenceBackend), with
//! `workers_per_arch` executor threads stealing one batch plan at a time.
//! Backends are built *inside* their executor thread via a
//! [`BackendFactory`](crate::runtime::BackendFactory) — PJRT executables
//! are not `Send` — so this module never touches an xla/PJRT type.
//!
//! Picking a backend:
//! * `PjrtFactory` — real AOT-compiled numerics; needs `make artifacts`.
//! * `GoldenFactory` — exact int8/int32 golden numerics, artifact-free;
//!   the default for CI and integration tests.
//! * `SimFactory` — golden numerics paced by the cycle-approximate
//!   dataflow simulator; load testing with realistic accelerator timing.
//!
//! Shutdown: [`Router::shutdown`] drains the queues (every accepted
//! request gets a real response); dropping the handle aborts, failing
//! queued requests with an explicit "server stopped" error.
//!
//! [`InferenceServer`] is the deprecated pre-redesign single-arch PJRT
//! wrapper, kept so existing callers compile.

// Panic-freedom gate: request-path code reports typed errors (and
// recovers poisoned gauges/queues) instead of unwinding worker threads.
// `clippy.toml` disallows Option/Result unwrap+expect; test modules opt
// out locally.
#![deny(clippy::disallowed_methods)]

mod batcher;
mod metrics;
mod router;
mod server;

pub use batcher::{BatchPlan, Batcher, BatcherConfig};
pub use metrics::{Metrics, MetricsSnapshot, BOUNDS_US};
pub use router::{Request, Response, Router, RouterConfig, RouterSnapshot};
#[allow(deprecated)]
pub use server::InferenceServer;
