//! Deprecated single-arch wrapper around the [`Router`].
//!
//! `InferenceServer` predates the backend-agnostic redesign: it was
//! hard-wired to the PJRT engine and to exactly one architecture.  It is
//! kept as a thin shim so existing callers compile; new code should
//! start a [`Router`] with whichever [`BackendFactory`]
//! (`PjrtFactory` / `GoldenFactory` / `SimFactory`) fits the deployment.

use std::path::PathBuf;
use std::sync::mpsc::Receiver;
use std::sync::Arc;

use anyhow::Result;

use crate::runtime::PjrtFactory;

use super::batcher::BatcherConfig;
use super::metrics::{Metrics, MetricsSnapshot};
use super::router::{Response, Router, RouterConfig};

/// Handle to a running single-architecture PJRT inference server.
#[deprecated(note = "use coordinator::Router with a runtime::BackendFactory")]
pub struct InferenceServer {
    arch: String,
    router: Router,
    pub metrics: Arc<Metrics>,
}

#[allow(deprecated)]
impl InferenceServer {
    /// Start a one-arch, one-worker PJRT router.
    pub fn start(
        artifacts_dir: PathBuf,
        arch: &str,
        cfg: BatcherConfig,
    ) -> Result<InferenceServer> {
        let factory: Arc<dyn crate::runtime::BackendFactory> =
            Arc::new(PjrtFactory::new(artifacts_dir, arch));
        let router =
            Router::start(vec![factory], RouterConfig { batcher: cfg, ..Default::default() })?;
        let metrics = router
            .metrics(arch)
            .ok_or_else(|| anyhow::anyhow!("router started without a pool for arch {arch}"))?;
        Ok(InferenceServer { arch: arch.to_string(), router, metrics })
    }

    pub fn arch(&self) -> &str {
        &self.arch
    }

    /// Submit a frame; returns the response channel.
    pub fn submit(&self, pixels: Vec<i32>) -> Result<Receiver<Result<Response>>> {
        self.router.submit(&self.arch, pixels)
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, pixels: Vec<i32>) -> Result<Response> {
        self.router.infer(&self.arch, pixels)
    }

    /// Graceful shutdown (drains the queue), returning the final metrics.
    pub fn shutdown(mut self) -> MetricsSnapshot {
        self.router.drain_and_join();
        self.metrics.snapshot()
    }
}

// Historical `InferenceServer` semantics: dropping the handle *drains*
// the queue (every accepted request still gets a response), unlike
// `Router`'s abort-on-drop.  Existing callers rely on it.
#[allow(deprecated)]
impl Drop for InferenceServer {
    fn drop(&mut self) {
        self.router.drain_and_join();
    }
}
