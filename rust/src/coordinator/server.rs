//! The inference server: per-architecture executor threads draining a
//! request queue through the dynamic batcher into the PJRT engine.
//!
//! The PJRT executables live entirely inside their executor thread (they
//! are created there), so no `Send` bound is needed on the xla types; the
//! outside world talks over channels — mirroring the paper's free-running
//! accelerator fed by DMA streams.

use std::collections::VecDeque;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::data::IMG_ELEMS;
use crate::quant::{QTensor, Shape4};
use crate::runtime::Engine;

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::Metrics;

/// A single-frame inference request.
pub struct Request {
    /// (32, 32, 3) int8-valued pixels @ 2^-7, NHWC flattened.
    pub pixels: Vec<i32>,
    pub submitted: Instant,
    pub resp: Sender<Result<Response>>,
}

/// The response: int32 logits + the predicted class.
#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<i32>,
    pub class: usize,
    pub latency: Duration,
}

/// Handle to a running per-architecture inference server.
pub struct InferenceServer {
    arch: String,
    tx: Sender<Request>,
    pub metrics: Arc<Metrics>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl InferenceServer {
    /// Start the executor thread: it loads + compiles the artifacts for
    /// `arch` and then serves until the handle is dropped.
    pub fn start(
        artifacts_dir: std::path::PathBuf,
        arch: &str,
        cfg: BatcherConfig,
    ) -> Result<InferenceServer> {
        let (tx, rx) = mpsc::channel::<Request>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let metrics = Arc::new(Metrics::new());
        let m = metrics.clone();
        let arch_name = arch.to_string();
        let worker = std::thread::Builder::new()
            .name(format!("exec-{arch}"))
            .spawn(move || {
                let engine = match Engine::load(&artifacts_dir) {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                executor_loop(&engine, &arch_name, cfg, rx, m);
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("executor thread died during startup"))??;
        Ok(InferenceServer { arch: arch.to_string(), tx, metrics, worker: Some(worker) })
    }

    pub fn arch(&self) -> &str {
        &self.arch
    }

    /// Submit a frame; returns the response channel.
    pub fn submit(&self, pixels: Vec<i32>) -> Result<Receiver<Result<Response>>> {
        anyhow::ensure!(pixels.len() == IMG_ELEMS, "expected {IMG_ELEMS} pixels");
        let (resp_tx, resp_rx) = mpsc::channel();
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.tx
            .send(Request { pixels, submitted: Instant::now(), resp: resp_tx })
            .map_err(|_| anyhow!("server stopped"))?;
        Ok(resp_rx)
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, pixels: Vec<i32>) -> Result<Response> {
        self.submit(pixels)?
            .recv()
            .map_err(|_| anyhow!("server dropped request"))?
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        // Closing the channel ends the executor loop.
        let (tx, _) = mpsc::channel();
        let _ = std::mem::replace(&mut self.tx, tx);
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn executor_loop(
    engine: &Engine,
    arch: &str,
    cfg: BatcherConfig,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
) {
    let mut cfg = cfg;
    let engine_buckets = engine.buckets(arch);
    if !engine_buckets.is_empty() {
        cfg.buckets = engine_buckets;
    }
    let batcher = Batcher::new(cfg);
    let mut queue: VecDeque<Request> = VecDeque::new();
    loop {
        // Wait for work (or a flush deadline on a non-empty queue).
        let timeout = if queue.is_empty() {
            Duration::from_millis(50)
        } else {
            let age = queue.front().map(|r| r.submitted.elapsed()).unwrap_or_default();
            batcher.config().max_wait.saturating_sub(age)
        };
        match rx.recv_timeout(timeout) {
            Ok(req) => queue.push_back(req),
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                if queue.is_empty() {
                    return;
                }
            }
        }
        // Drain anything else already queued.
        while let Ok(req) = rx.try_recv() {
            queue.push_back(req);
        }
        let oldest = queue.front().map(|r| r.submitted.elapsed()).unwrap_or_default();
        if !batcher.should_flush(queue.len(), oldest) {
            continue;
        }
        for plan in batcher.plan(queue.len()) {
            let take: Vec<Request> = queue.drain(..plan.take).collect();
            let mut data = vec![0i32; plan.bucket * IMG_ELEMS];
            for (i, r) in take.iter().enumerate() {
                data[i * IMG_ELEMS..(i + 1) * IMG_ELEMS].copy_from_slice(&r.pixels);
            }
            let input = QTensor::from_vec(Shape4::new(plan.bucket, 32, 32, 3), -7, data);
            let name = format!("{arch}_b{}", plan.bucket);
            match engine.model(&name).and_then(|m| m.infer(&input)) {
                Ok(logits) => {
                    metrics.record_batch(plan.take, plan.bucket);
                    let c = logits.shape.c;
                    for (i, r) in take.into_iter().enumerate() {
                        let row = logits.data[i * c..(i + 1) * c].to_vec();
                        let class = row
                            .iter()
                            .enumerate()
                            .max_by_key(|&(_, v)| *v)
                            .map(|(k, _)| k)
                            .unwrap_or(0);
                        let latency = r.submitted.elapsed();
                        metrics.record_latency(latency);
                        let _ = r.resp.send(Ok(Response { logits: row, class, latency }));
                    }
                }
                Err(e) => {
                    metrics.errors.fetch_add(1, Ordering::Relaxed);
                    let msg = format!("{e}");
                    for r in take {
                        let _ = r.resp.send(Err(anyhow!("{msg}")));
                    }
                }
            }
        }
    }
}
