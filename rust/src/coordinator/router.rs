//! The multi-architecture inference router.
//!
//! One handle owns N per-arch worker pools.  Each pool is a shared
//! request queue plus `workers_per_arch` executor threads; every worker
//! builds its *own* backend through the pool's [`BackendFactory`] (PJRT
//! executables are not `Send`, so they are created inside the thread that
//! uses them) and then steals work from the queue one batch plan at a
//! time — the software analogue of multiple free-running accelerator
//! instances fed from one DMA stream.
//!
//! Shutdown semantics:
//! * [`Router::shutdown`] — graceful: stop accepting, let the workers
//!   drain everything already queued, join, return the final snapshot.
//! * `Drop` — abort: stop accepting and fail everything still queued with
//!   an explicit "server stopped" error.  Requests are never silently
//!   discarded.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::Ordering;
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::data::{IMG_C, IMG_ELEMS, IMG_H, IMG_W, INPUT_EXP};
use crate::quant::{QTensor, Shape4};
use crate::runtime::{BackendFactory, InferenceBackend};
use crate::sim::golden;

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::{Metrics, MetricsSnapshot};

/// A single-frame inference request.
pub struct Request {
    /// (32, 32, 3) int8-valued pixels @ 2^-7, NHWC flattened.
    pub pixels: Vec<i32>,
    pub submitted: Instant,
    pub resp: Sender<Result<Response>>,
}

/// The response: int32 logits + the predicted class.
#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<i32>,
    pub class: usize,
    pub latency: Duration,
}

/// Router policy parameters.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Executor threads per architecture pool.  Each worker constructs
    /// its own backend from the pool's factory.
    pub workers_per_arch: usize,
    /// Batching policy.  The bucket list is overridden per worker by what
    /// its backend actually provides.
    pub batcher: BatcherConfig,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { workers_per_arch: 1, batcher: BatcherConfig::default() }
    }
}

/// Queue state shared by one pool's workers.
struct PoolState {
    queue: VecDeque<Request>,
    /// Accepting new submissions.
    open: bool,
    /// Graceful shutdown: process the remaining queue, then exit.
    draining: bool,
    /// Abort: fail the remaining queue with "server stopped", then exit.
    abort: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    cv: Condvar,
}

struct Pool {
    shared: Arc<PoolShared>,
    metrics: Arc<Metrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// Per-arch + aggregate metrics at one instant.
#[derive(Debug, Clone)]
pub struct RouterSnapshot {
    pub per_arch: BTreeMap<String, MetricsSnapshot>,
    pub total: MetricsSnapshot,
}

impl std::fmt::Display for RouterSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "total: {}", self.total)?;
        for (arch, snap) in &self.per_arch {
            write!(f, "\n  {arch}: {snap}")?;
        }
        Ok(())
    }
}

/// Handle to a running multi-arch inference service.
pub struct Router {
    pools: BTreeMap<String, Pool>,
    agg: Arc<Metrics>,
}

impl Router {
    /// Start one worker pool per factory.  Blocks until every worker has
    /// constructed its backend (so artifact/compile errors surface here,
    /// not on the first request).
    pub fn start(factories: Vec<Arc<dyn BackendFactory>>, cfg: RouterConfig) -> Result<Router> {
        anyhow::ensure!(!factories.is_empty(), "router needs at least one backend factory");
        let workers_per_arch = cfg.workers_per_arch.max(1);
        let agg = Arc::new(Metrics::new());
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        // Workers are registered on the router as they spawn, so any
        // early return below aborts + joins them through Drop.
        let mut router = Router { pools: BTreeMap::new(), agg };
        let mut spawned = 0usize;
        for factory in factories {
            let arch = factory.arch().to_string();
            anyhow::ensure!(
                !router.pools.contains_key(&arch),
                "duplicate backend for arch {arch}"
            );
            let shared = Arc::new(PoolShared {
                state: Mutex::new(PoolState {
                    queue: VecDeque::new(),
                    open: true,
                    draining: false,
                    abort: false,
                }),
                cv: Condvar::new(),
            });
            let metrics = Arc::new(Metrics::new());
            router.pools.insert(
                arch.clone(),
                Pool { shared: shared.clone(), metrics: metrics.clone(), workers: Vec::new() },
            );
            for wi in 0..workers_per_arch {
                let factory = factory.clone();
                let shared = shared.clone();
                let metrics = metrics.clone();
                let agg = router.agg.clone();
                let ready = ready_tx.clone();
                let bcfg = cfg.batcher.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("exec-{arch}-{wi}"))
                    .spawn(move || {
                        // Backend construction happens *inside* the
                        // worker: non-Send executables never migrate.
                        let backend = match factory.create() {
                            Ok(b) => {
                                let _ = ready.send(Ok(()));
                                b
                            }
                            Err(e) => {
                                let _ = ready.send(Err(e));
                                return;
                            }
                        };
                        // Release the handshake sender now: if a sibling
                        // worker panics in create() without reporting,
                        // start() must see the channel close, not hang.
                        drop(ready);
                        worker_loop(backend.as_ref(), bcfg, &shared, &metrics, &agg);
                    })?;
                router.pools.get_mut(&arch).unwrap().workers.push(handle);
                spawned += 1;
            }
        }
        drop(ready_tx);
        for _ in 0..spawned {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => return Err(e), // Drop aborts the rest
                Err(_) => return Err(anyhow!("executor thread died during startup")),
            }
        }
        Ok(router)
    }

    /// Architectures this router serves, ascending.
    pub fn archs(&self) -> Vec<String> {
        self.pools.keys().cloned().collect()
    }

    /// Submit a frame for `arch`; returns the response channel.
    pub fn submit(&self, arch: &str, pixels: Vec<i32>) -> Result<Receiver<Result<Response>>> {
        anyhow::ensure!(pixels.len() == IMG_ELEMS, "expected {IMG_ELEMS} pixels");
        let pool = self.pools.get(arch).ok_or_else(|| {
            anyhow!("no backend for arch {arch} (have: {:?})", self.archs())
        })?;
        let (resp_tx, resp_rx) = mpsc::channel();
        {
            let mut st = pool.shared.state.lock().unwrap();
            anyhow::ensure!(st.open, "server stopped");
            // Count while holding the lock: workers also need it to pop,
            // so a snapshot can never observe frames > requests.
            pool.metrics.requests.fetch_add(1, Ordering::Relaxed);
            self.agg.requests.fetch_add(1, Ordering::Relaxed);
            st.queue.push_back(Request {
                pixels,
                submitted: Instant::now(),
                resp: resp_tx,
            });
        }
        pool.shared.cv.notify_one();
        Ok(resp_rx)
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, arch: &str, pixels: Vec<i32>) -> Result<Response> {
        self.submit(arch, pixels)?
            .recv()
            .map_err(|_| anyhow!("server dropped request"))?
    }

    /// One pool's live metrics.
    pub fn metrics(&self, arch: &str) -> Option<Arc<Metrics>> {
        self.pools.get(arch).map(|p| p.metrics.clone())
    }

    /// Aggregate metrics across every pool (exact — workers record into
    /// both their pool's and this histogram).
    pub fn aggregate(&self) -> Arc<Metrics> {
        self.agg.clone()
    }

    /// Point-in-time per-arch + total snapshot.
    pub fn snapshot(&self) -> RouterSnapshot {
        RouterSnapshot {
            per_arch: self
                .pools
                .iter()
                .map(|(a, p)| (a.clone(), p.metrics.snapshot()))
                .collect(),
            total: self.agg.snapshot(),
        }
    }

    /// Graceful shutdown: stop accepting requests, let the workers drain
    /// everything already queued, join them, and return the final
    /// snapshot.  Every request submitted before this call gets a real
    /// response.
    pub fn shutdown(mut self) -> RouterSnapshot {
        self.drain_and_join();
        self.snapshot()
    }

    /// Stop accepting, drain, join.  Idempotent; also used by the
    /// deprecated `InferenceServer` shim to preserve its historical
    /// drain-on-drop behavior.
    pub(super) fn drain_and_join(&mut self) {
        for pool in self.pools.values() {
            let mut st = pool.shared.state.lock().unwrap();
            st.open = false;
            st.draining = true;
            drop(st);
            pool.shared.cv.notify_all();
        }
        for pool in self.pools.values_mut() {
            for w in pool.workers.drain(..) {
                let _ = w.join();
            }
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        // Abort: anything still queued gets an explicit "server stopped"
        // error — never a silently dropped response channel.
        for pool in self.pools.values() {
            let mut st = pool.shared.state.lock().unwrap();
            st.open = false;
            st.abort = true;
            drop(st);
            pool.shared.cv.notify_all();
        }
        for pool in self.pools.values_mut() {
            for w in pool.workers.drain(..) {
                let _ = w.join();
            }
        }
        // If a pool's workers never ran (startup failure), its queue may
        // still hold requests: fail them here.
        for pool in self.pools.values() {
            let mut st = pool.shared.state.lock().unwrap();
            while let Some(r) = st.queue.pop_front() {
                let _ = r.resp.send(Err(anyhow!("server stopped")));
            }
        }
    }
}

/// One executor thread: claim a planned batch under the queue lock,
/// execute it outside the lock (other workers keep stealing), respond.
fn worker_loop(
    backend: &dyn InferenceBackend,
    mut bcfg: BatcherConfig,
    shared: &PoolShared,
    pool_metrics: &Metrics,
    agg: &Metrics,
) {
    let buckets = backend.buckets().to_vec();
    if !buckets.is_empty() {
        bcfg.buckets = buckets;
    }
    // A streaming pool's derived in-flight-capacity bucket must survive
    // the policy's max_bucket filter (tuned for PJRT executables), or the
    // serve path degenerates to single-frame dispatches and frame-level
    // pipelining never engages.
    if let Some(mb) = backend.preferred_max_bucket() {
        bcfg.max_bucket = bcfg.max_bucket.max(mb);
    }
    let batcher = Batcher::new(bcfg);
    loop {
        let mut st = shared.state.lock().unwrap();
        let (plan, batch) = loop {
            if st.abort {
                while let Some(r) = st.queue.pop_front() {
                    let _ = r.resp.send(Err(anyhow!("server stopped")));
                }
                return;
            }
            if let Some(front) = st.queue.front() {
                let oldest = front.submitted.elapsed();
                if st.draining || batcher.should_flush(st.queue.len(), oldest) {
                    let plan = batcher
                        .plan(st.queue.len())
                        .into_iter()
                        .next()
                        .expect("plan of non-empty queue");
                    let batch: Vec<Request> = st.queue.drain(..plan.take).collect();
                    break (plan, batch);
                }
                let wait = batcher.config().max_wait.saturating_sub(oldest);
                let (g, _) = shared
                    .cv
                    .wait_timeout(st, wait.max(Duration::from_micros(100)))
                    .unwrap();
                st = g;
            } else {
                if st.draining {
                    return;
                }
                let (g, _) = shared.cv.wait_timeout(st, Duration::from_millis(50)).unwrap();
                st = g;
            }
        };
        drop(st);

        let mut data = vec![0i32; plan.bucket * IMG_ELEMS];
        for (i, r) in batch.iter().enumerate() {
            data[i * IMG_ELEMS..(i + 1) * IMG_ELEMS].copy_from_slice(&r.pixels);
        }
        let input =
            QTensor::from_vec(Shape4::new(plan.bucket, IMG_H, IMG_W, IMG_C), INPUT_EXP, data);
        match backend.infer_batch(&input) {
            Ok(logits) => {
                pool_metrics.record_batch(plan.take, plan.bucket);
                agg.record_batch(plan.take, plan.bucket);
                // Streaming backends: export the pool's replica-aggregated
                // buffering gauges into the snapshots (ROADMAP item 4).
                if let Some((peak, whole)) = backend.stream_gauges() {
                    pool_metrics.record_stream(peak, whole);
                    agg.record_stream(peak, whole);
                }
                let c = logits.shape.c;
                // Same class selection as the test oracle, so serving and
                // golden can never drift on tie-breaking.
                let classes = golden::argmax_classes(&logits);
                for (i, r) in batch.into_iter().enumerate() {
                    let row = logits.data[i * c..(i + 1) * c].to_vec();
                    let class = classes[i];
                    let latency = r.submitted.elapsed();
                    pool_metrics.record_latency(latency);
                    agg.record_latency(latency);
                    let _ = r.resp.send(Ok(Response { logits: row, class, latency }));
                }
            }
            Err(e) => {
                pool_metrics.errors.fetch_add(1, Ordering::Relaxed);
                agg.errors.fetch_add(1, Ordering::Relaxed);
                let msg = format!("{e}");
                for r in batch {
                    let _ = r.resp.send(Err(anyhow!("{msg}")));
                }
            }
        }
    }
}
