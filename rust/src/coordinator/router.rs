//! The multi-architecture inference router.
//!
//! One handle owns N per-arch worker pools.  Each pool is a shared
//! request queue plus `workers_per_arch` executor threads; every worker
//! builds its *own* backend through the pool's [`BackendFactory`] (PJRT
//! executables are not `Send`, so they are created inside the thread that
//! uses them) and then steals work from the queue one batch plan at a
//! time — the software analogue of multiple free-running accelerator
//! instances fed from one DMA stream.
//!
//! Shutdown semantics:
//! * [`Router::shutdown`] — graceful: stop accepting, let the workers
//!   drain everything already queued, join, return the final snapshot.
//! * `Drop` — abort: stop accepting and fail everything still queued with
//!   an explicit "server stopped" error.  Requests are never silently
//!   discarded.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::data::{IMG_C, IMG_ELEMS, IMG_H, IMG_W, INPUT_EXP};
use crate::obs::BudgetSnapshot;
use crate::quant::{QTensor, Shape4};
use crate::runtime::{BackendFactory, InferenceBackend};
use crate::sim::golden;
use crate::stream::WorkerBudget;

use super::batcher::{BatchPlan, Batcher, BatcherConfig};
use super::metrics::{Metrics, MetricsSnapshot};

/// A single-frame inference request.
pub struct Request {
    /// (32, 32, 3) int8-valued pixels @ 2^-7, NHWC flattened.
    pub pixels: Vec<i32>,
    pub submitted: Instant,
    pub resp: Sender<Result<Response>>,
}

/// The response: int32 logits + the predicted class.
#[derive(Debug, Clone)]
pub struct Response {
    pub logits: Vec<i32>,
    pub class: usize,
    pub latency: Duration,
}

/// Router policy parameters.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Executor threads per architecture pool.  Each worker constructs
    /// its own backend from the pool's factory.
    pub workers_per_arch: usize,
    /// Batching policy.  The bucket list is overridden per worker by what
    /// its backend actually provides.
    pub batcher: BatcherConfig,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { workers_per_arch: 1, batcher: BatcherConfig::default() }
    }
}

/// Queue state shared by one pool's workers.
struct PoolState {
    queue: VecDeque<Request>,
    /// Accepting new submissions.
    open: bool,
    /// Graceful shutdown: process the remaining queue, then exit.
    draining: bool,
    /// Abort: fail the remaining queue with "server stopped", then exit.
    abort: bool,
}

struct PoolShared {
    state: Mutex<PoolState>,
    cv: Condvar,
    /// Depth of the network-ingress admission queue (0 without an
    /// ingress front-end).  Folded into the `load_hint` the workers
    /// report to elastic streaming backends, so socket backlog grows
    /// stream-pool replicas before the router's own queue fills.
    ingress: AtomicUsize,
}

/// Recover a queue-state guard from a poisoned mutex.  `PoolState` holds
/// plain data (a request queue and three flags) with no invariant a
/// mid-panic unwind can break, and the paths that use this — drain,
/// drop, the worker serve loop — must keep responding to queued requests
/// even after a sibling worker panicked, so recovery beats propagating.
fn recover(
    r: Result<MutexGuard<'_, PoolState>, PoisonError<MutexGuard<'_, PoolState>>>,
) -> MutexGuard<'_, PoolState> {
    r.unwrap_or_else(PoisonError::into_inner)
}

impl PoolShared {
    fn new() -> PoolShared {
        PoolShared {
            state: Mutex::new(PoolState {
                queue: VecDeque::new(),
                open: true,
                draining: false,
                abort: false,
            }),
            cv: Condvar::new(),
            ingress: AtomicUsize::new(0),
        }
    }
}

struct Pool {
    shared: Arc<PoolShared>,
    metrics: Arc<Metrics>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

/// Per-arch + aggregate metrics at one instant.
#[derive(Debug, Clone)]
pub struct RouterSnapshot {
    pub per_arch: BTreeMap<String, MetricsSnapshot>,
    pub total: MetricsSnapshot,
    /// Shared worker-budget state when the fleet serves under one
    /// process-wide [`WorkerBudget`] (`None` for unbudgeted routers).
    pub budget: Option<BudgetSnapshot>,
}

impl std::fmt::Display for RouterSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "total: {}", self.total)?;
        for (arch, snap) in &self.per_arch {
            write!(f, "\n  {arch}: {snap}")?;
        }
        if let Some(b) = &self.budget {
            write!(f, "\n{b}")?;
        }
        Ok(())
    }
}

/// Handle to a running multi-arch inference service.
pub struct Router {
    pools: BTreeMap<String, Pool>,
    agg: Arc<Metrics>,
    /// The process-wide worker budget the fleet's streaming pools lease
    /// from, when serving multi-tenant (kept only for reporting — pools
    /// hold their own registration handles).
    budget: Option<Arc<WorkerBudget>>,
}

impl Router {
    /// Start one worker pool per factory.  Blocks until every worker has
    /// constructed its backend (so artifact/compile errors surface here,
    /// not on the first request).
    pub fn start(factories: Vec<Arc<dyn BackendFactory>>, cfg: RouterConfig) -> Result<Router> {
        anyhow::ensure!(!factories.is_empty(), "router needs at least one backend factory");
        let workers_per_arch = cfg.workers_per_arch.max(1);
        let agg = Arc::new(Metrics::new());
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        // Workers are registered on the router as they spawn, so any
        // early return below aborts + joins them through Drop.
        let mut router = Router { pools: BTreeMap::new(), agg, budget: None };
        let mut spawned = 0usize;
        for factory in factories {
            let arch = factory.arch().to_string();
            anyhow::ensure!(
                !router.pools.contains_key(&arch),
                "duplicate backend for arch {arch}"
            );
            let shared = Arc::new(PoolShared::new());
            let metrics = Arc::new(Metrics::new());
            router.pools.insert(
                arch.clone(),
                Pool { shared: shared.clone(), metrics: metrics.clone(), workers: Vec::new() },
            );
            for wi in 0..workers_per_arch {
                let factory = factory.clone();
                let shared = shared.clone();
                let metrics = metrics.clone();
                let agg = router.agg.clone();
                let ready = ready_tx.clone();
                let bcfg = cfg.batcher.clone();
                let handle = std::thread::Builder::new()
                    .name(format!("exec-{arch}-{wi}"))
                    .spawn(move || {
                        // Backend construction happens *inside* the
                        // worker: non-Send executables never migrate.
                        let backend = match factory.create() {
                            Ok(b) => {
                                let _ = ready.send(Ok(()));
                                b
                            }
                            Err(e) => {
                                let _ = ready.send(Err(e));
                                return;
                            }
                        };
                        // Release the handshake sender now: if a sibling
                        // worker panics in create() without reporting,
                        // start() must see the channel close, not hang.
                        drop(ready);
                        worker_loop(backend.as_ref(), bcfg, &shared, &metrics, &agg);
                    })?;
                let pool = router
                    .pools
                    .get_mut(&arch)
                    .ok_or_else(|| anyhow!("pool for arch {arch} vanished during startup"))?;
                pool.workers.push(handle);
                spawned += 1;
            }
        }
        drop(ready_tx);
        for _ in 0..spawned {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => return Err(e), // Drop aborts the rest
                Err(_) => return Err(anyhow!("executor thread died during startup")),
            }
        }
        Ok(router)
    }

    /// Architectures this router serves, ascending.
    pub fn archs(&self) -> Vec<String> {
        self.pools.keys().cloned().collect()
    }

    /// Submit a frame for `arch`; returns the response channel.
    pub fn submit(&self, arch: &str, pixels: Vec<i32>) -> Result<Receiver<Result<Response>>> {
        anyhow::ensure!(pixels.len() == IMG_ELEMS, "expected {IMG_ELEMS} pixels");
        let pool = self.pools.get(arch).ok_or_else(|| {
            anyhow!("no backend for arch {arch} (have: {:?})", self.archs())
        })?;
        let (resp_tx, resp_rx) = mpsc::channel();
        {
            // A poisoned queue mutex means a worker panicked mid-pop; the
            // pool can no longer promise a response, so refuse the frame
            // with a typed error instead of propagating the panic into
            // the caller (typically a network connection handler).
            let mut st = pool
                .shared
                .state
                .lock()
                .map_err(|_| anyhow!("server error: pool queue poisoned by a worker panic"))?;
            anyhow::ensure!(st.open, "server stopped");
            // Count while holding the lock: workers also need it to pop,
            // so a snapshot can never observe frames > requests.
            pool.metrics.requests.fetch_add(1, Ordering::Relaxed);
            self.agg.requests.fetch_add(1, Ordering::Relaxed);
            st.queue.push_back(Request {
                pixels,
                submitted: Instant::now(),
                resp: resp_tx,
            });
        }
        pool.shared.cv.notify_one();
        Ok(resp_rx)
    }

    /// Blocking convenience: submit and wait.
    pub fn infer(&self, arch: &str, pixels: Vec<i32>) -> Result<Response> {
        self.submit(arch, pixels)?
            .recv()
            .map_err(|_| anyhow!("server dropped request"))?
    }

    /// Report the network-ingress admission-queue depth.  The workers
    /// fold this into the queue depth they pass to
    /// [`InferenceBackend::load_hint`], closing the socket-to-replica
    /// elastic loop: backlog still buffered at the ingress tier makes
    /// an elastic streaming pool scale up before the router's own queue
    /// reflects it.  Cheap (one relaxed store per pool); call it on
    /// every ingress push/pop.
    pub fn report_ingress(&self, depth: usize) {
        for pool in self.pools.values() {
            pool.shared.ingress.store(depth, Ordering::Relaxed);
        }
    }

    /// One pool's live metrics.
    pub fn metrics(&self, arch: &str) -> Option<Arc<Metrics>> {
        self.pools.get(arch).map(|p| p.metrics.clone())
    }

    /// Attach the process-wide worker budget the fleet's streaming pools
    /// were built against, so snapshots and `/metrics` can report lease
    /// state.  Call once after [`Router::start`]; reporting-only — the
    /// pools already hold their registrations through their factories.
    pub fn set_budget(&mut self, budget: Arc<WorkerBudget>) {
        self.budget = Some(budget);
    }

    /// Point-in-time state of the shared worker budget (`None` for
    /// unbudgeted routers).
    pub fn budget_snapshot(&self) -> Option<BudgetSnapshot> {
        self.budget.as_ref().map(|b| b.snapshot())
    }

    /// Aggregate metrics across every pool (exact — workers record into
    /// both their pool's and this histogram).
    pub fn aggregate(&self) -> Arc<Metrics> {
        self.agg.clone()
    }

    /// Point-in-time per-arch + total snapshot.  The total's replica
    /// gauges are summed across the per-arch pools (a last-writer-wins
    /// aggregate would show whichever pool reported most recently, not
    /// the fleet's capacity); every other total field comes from the
    /// exact aggregate histogram the workers record into.
    pub fn snapshot(&self) -> RouterSnapshot {
        let per_arch: BTreeMap<String, MetricsSnapshot> = self
            .pools
            .iter()
            .map(|(a, p)| (a.clone(), p.metrics.snapshot()))
            .collect();
        let mut total = self.agg.snapshot();
        total.stream_replicas = per_arch.values().map(|m| m.stream_replicas).sum();
        total.stream_peak_replicas = per_arch.values().map(|m| m.stream_peak_replicas).sum();
        total.budget_workers_held = per_arch.values().map(|m| m.budget_workers_held).sum();
        total.budget_workers_reserved =
            per_arch.values().map(|m| m.budget_workers_reserved).sum();
        total.budget_denied = per_arch.values().map(|m| m.budget_denied).sum();
        RouterSnapshot { per_arch, total, budget: self.budget_snapshot() }
    }

    /// Graceful shutdown: stop accepting requests, let the workers drain
    /// everything already queued, join them, and return the final
    /// snapshot.  Every request submitted before this call gets a real
    /// response.
    pub fn shutdown(mut self) -> RouterSnapshot {
        self.drain_and_join();
        self.snapshot()
    }

    /// Stop accepting, drain, join.  Idempotent; also used by the
    /// deprecated `InferenceServer` shim to preserve its historical
    /// drain-on-drop behavior.
    pub(super) fn drain_and_join(&mut self) {
        for pool in self.pools.values() {
            let mut st = recover(pool.shared.state.lock());
            st.open = false;
            st.draining = true;
            drop(st);
            pool.shared.cv.notify_all();
        }
        for pool in self.pools.values_mut() {
            for w in pool.workers.drain(..) {
                let _ = w.join();
            }
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        // Abort: anything still queued gets an explicit "server stopped"
        // error — never a silently dropped response channel.
        for pool in self.pools.values() {
            let mut st = recover(pool.shared.state.lock());
            st.open = false;
            st.abort = true;
            drop(st);
            pool.shared.cv.notify_all();
        }
        for pool in self.pools.values_mut() {
            for w in pool.workers.drain(..) {
                let _ = w.join();
            }
        }
        // If a pool's workers never ran (startup failure), its queue may
        // still hold requests: fail them here.
        for pool in self.pools.values() {
            let mut st = recover(pool.shared.state.lock());
            while let Some(r) = st.queue.pop_front() {
                respond_counted(&pool.metrics, &self.agg, r, Err(anyhow!("server stopped")));
            }
        }
    }
}

/// The planning surface an executor thread needs — a seam so tests can
/// inject a degenerate planner (e.g. one whose `plan` yields no
/// executions for a non-empty queue, the condition that used to panic
/// the worker).
trait BatchPlanner {
    fn should_flush(&self, queued: usize, oldest_age: Duration) -> bool;
    fn plan(&self, queued: usize) -> Vec<BatchPlan>;
    fn max_wait(&self) -> Duration;
}

impl BatchPlanner for Batcher {
    fn should_flush(&self, queued: usize, oldest_age: Duration) -> bool {
        Batcher::should_flush(self, queued, oldest_age)
    }

    fn plan(&self, queued: usize) -> Vec<BatchPlan> {
        Batcher::plan(self, queued)
    }

    fn max_wait(&self) -> Duration {
        self.config().max_wait
    }
}

/// One executor thread: build the batcher from the backend's bucket
/// preferences, then serve the queue until shutdown.
fn worker_loop(
    backend: &dyn InferenceBackend,
    mut bcfg: BatcherConfig,
    shared: &PoolShared,
    pool_metrics: &Metrics,
    agg: &Metrics,
) {
    let buckets = backend.buckets().to_vec();
    if !buckets.is_empty() {
        bcfg.buckets = buckets;
    }
    // A streaming pool's derived in-flight-capacity bucket must survive
    // the policy's max_bucket filter (tuned for PJRT executables), or the
    // serve path degenerates to single-frame dispatches and frame-level
    // pipelining never engages.
    if let Some(mb) = backend.preferred_max_bucket() {
        bcfg.max_bucket = bcfg.max_bucket.max(mb);
    }
    let batcher = Batcher::new(bcfg);
    serve_queue(backend, &batcher, shared, pool_metrics, agg);
}

/// Deliver one response; a client that dropped its receiver mid-flight
/// (disconnect) makes this a *counted* no-op — never a worker panic or
/// wedge.  The ingress front-end surfaces the counter in snapshots.
fn respond_counted(
    pool_metrics: &Metrics,
    agg: &Metrics,
    r: Request,
    resp: Result<Response>,
) {
    if r.resp.send(resp).is_err() {
        pool_metrics.record_disconnect();
        agg.record_disconnect();
    }
}

/// Claim a planned batch under the queue lock, execute it outside the
/// lock (other workers keep stealing), respond.  Requests are never
/// silently dropped: even a planner that yields no plan for a non-empty
/// queue fails the drained requests with a typed error instead of
/// panicking the worker and stranding them.
fn serve_queue(
    backend: &dyn InferenceBackend,
    planner: &dyn BatchPlanner,
    shared: &PoolShared,
    pool_metrics: &Metrics,
    agg: &Metrics,
) {
    'serve: loop {
        let mut st = recover(shared.state.lock());
        let (plan, batch) = loop {
            if st.abort {
                while let Some(r) = st.queue.pop_front() {
                    respond_counted(pool_metrics, agg, r, Err(anyhow!("server stopped")));
                }
                return;
            }
            // Elastic streaming pools fold the router's queue depth into
            // their replica-scaling signal; a cheap no-op elsewhere.
            // Ingress backlog (frames admitted by the TCP front-end but
            // not yet dispatched here) counts toward the same signal.
            backend.load_hint(
                st.queue
                    .len()
                    .saturating_add(shared.ingress.load(Ordering::Relaxed)),
            );
            if let Some(front) = st.queue.front() {
                let oldest = front.submitted.elapsed();
                if st.draining || planner.should_flush(st.queue.len(), oldest) {
                    match planner.plan(st.queue.len()).into_iter().next() {
                        Some(plan) => {
                            let batch: Vec<Request> = st.queue.drain(..plan.take).collect();
                            break (plan, batch);
                        }
                        None => {
                            // Bugfix (was `.expect("plan of non-empty
                            // queue")`): a worker panic here would
                            // silently strand everything queued.  Fail
                            // the drained requests with the typed
                            // server-side error instead and keep serving.
                            let failed: Vec<Request> = st.queue.drain(..).collect();
                            drop(st);
                            pool_metrics.errors.fetch_add(1, Ordering::Relaxed);
                            agg.errors.fetch_add(1, Ordering::Relaxed);
                            for r in failed {
                                respond_counted(
                                    pool_metrics,
                                    agg,
                                    r,
                                    Err(anyhow!(
                                        "server error: batcher produced no plan for a non-empty queue"
                                    )),
                                );
                            }
                            continue 'serve;
                        }
                    }
                }
                let wait = planner.max_wait().saturating_sub(oldest);
                let (g, _) = shared
                    .cv
                    .wait_timeout(st, wait.max(Duration::from_micros(100)))
                    .unwrap_or_else(PoisonError::into_inner);
                st = g;
            } else {
                if st.draining {
                    return;
                }
                let (g, _) = shared
                    .cv
                    .wait_timeout(st, Duration::from_millis(50))
                    .unwrap_or_else(PoisonError::into_inner);
                st = g;
            }
        };
        drop(st);

        let mut data = vec![0i32; plan.bucket * IMG_ELEMS];
        for (i, r) in batch.iter().enumerate() {
            data[i * IMG_ELEMS..(i + 1) * IMG_ELEMS].copy_from_slice(&r.pixels);
        }
        let input =
            QTensor::from_vec(Shape4::new(plan.bucket, IMG_H, IMG_W, IMG_C), INPUT_EXP, data);
        match backend.infer_batch(&input) {
            Ok(logits) => {
                pool_metrics.record_batch(plan.take, plan.bucket);
                agg.record_batch(plan.take, plan.bucket);
                // Streaming backends: export the pool's replica-aggregated
                // buffering gauges into the snapshots (ROADMAP item 4).
                if let Some((peak, whole)) = backend.stream_gauges() {
                    pool_metrics.record_stream(peak, whole);
                    agg.record_stream(peak, whole);
                }
                // Elastic pools: export the live replica count so the
                // snapshot shows how far the pool has scaled.  Recorded
                // per arch only — the router's snapshot() sums the
                // per-arch gauges into the total (a shared last-writer
                // gauge would misreport multi-pool fleets).
                if let Some(r) = backend.replica_count() {
                    pool_metrics.record_replicas(r as u64);
                }
                // Budgeted pools: export the lease gauges (workers held /
                // reserved, denied grants) the same per-arch way.
                if let Some((held, reserved, denied)) = backend.budget_gauges() {
                    pool_metrics.record_budget(held, reserved, denied);
                }
                // Streaming pools: refresh the stall-attribution report.
                // `record_stalls` throttles internally, so the full
                // stage/edge walk runs at most a few times per second no
                // matter the batch rate.  Per arch only, like replicas.
                pool_metrics.record_stalls(|| backend.stall_report());
                let c = logits.shape.c;
                // Same class selection as the test oracle, so serving and
                // golden can never drift on tie-breaking.
                let classes = golden::argmax_classes(&logits);
                for (i, r) in batch.into_iter().enumerate() {
                    let row = logits.data[i * c..(i + 1) * c].to_vec();
                    let class = classes[i];
                    let latency = r.submitted.elapsed();
                    pool_metrics.record_latency(latency);
                    agg.record_latency(latency);
                    respond_counted(
                        pool_metrics,
                        agg,
                        r,
                        Ok(Response { logits: row, class, latency }),
                    );
                }
            }
            Err(e) => {
                pool_metrics.errors.fetch_add(1, Ordering::Relaxed);
                agg.errors.fetch_add(1, Ordering::Relaxed);
                let msg = format!("{e}");
                for r in batch {
                    respond_counted(pool_metrics, agg, r, Err(anyhow!("{msg}")));
                }
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::quant::QTensor;

    /// A backend that must never be reached: the no-plan path fails the
    /// queue before any execution.
    struct NullBackend;

    impl InferenceBackend for NullBackend {
        fn arch(&self) -> &str {
            "null"
        }

        fn buckets(&self) -> &[usize] {
            &[1]
        }

        fn infer_batch(&self, _input: &QTensor) -> Result<QTensor> {
            Err(anyhow!("NullBackend::infer_batch should not be reached"))
        }
    }

    /// A degenerate planner: always flush, never produce a plan — the
    /// exact condition that used to hit `.expect("plan of non-empty
    /// queue")`, panicking the worker and stranding the queue.
    struct NoPlanPlanner;

    impl BatchPlanner for NoPlanPlanner {
        fn should_flush(&self, queued: usize, _oldest_age: Duration) -> bool {
            queued > 0
        }

        fn plan(&self, _queued: usize) -> Vec<BatchPlan> {
            Vec::new()
        }

        fn max_wait(&self) -> Duration {
            Duration::from_millis(1)
        }
    }

    /// Regression: a planner that yields no plan for a non-empty queue
    /// must fail every drained request with the typed server error (and
    /// keep the worker alive to serve/drain later), not panic.
    #[test]
    fn no_plan_for_nonempty_queue_fails_requests_typed_instead_of_panicking() {
        let shared = Arc::new(PoolShared::new());
        let metrics = Arc::new(Metrics::new());
        let agg = Arc::new(Metrics::new());
        let (resp_tx, resp_rx) = mpsc::channel();
        shared.state.lock().unwrap().queue.push_back(Request {
            pixels: vec![0; IMG_ELEMS],
            submitted: Instant::now(),
            resp: resp_tx,
        });
        let worker = {
            let shared = shared.clone();
            let metrics = metrics.clone();
            let agg = agg.clone();
            std::thread::spawn(move || {
                serve_queue(&NullBackend, &NoPlanPlanner, &shared, &metrics, &agg)
            })
        };
        // The stranded request gets the typed error, not a dropped
        // channel (which would surface as RecvError here).
        let resp = resp_rx
            .recv_timeout(Duration::from_secs(30))
            .expect("worker dropped the request instead of answering it");
        let msg = format!("{:#}", resp.unwrap_err());
        assert!(msg.contains("no plan"), "{msg}");
        assert_eq!(metrics.errors.load(Ordering::Relaxed), 1);
        assert_eq!(agg.errors.load(Ordering::Relaxed), 1);
        // The worker survived: it drains and exits cleanly on request.
        {
            let mut st = shared.state.lock().unwrap();
            st.open = false;
            st.draining = true;
        }
        shared.cv.notify_all();
        worker.join().expect("worker panicked");
    }

    /// A backend that always succeeds with zero logits (10 classes).
    struct ZeroBackend {
        /// Highest load hint observed (for the ingress-fold test).
        max_hint: std::sync::atomic::AtomicUsize,
    }

    impl ZeroBackend {
        fn new() -> ZeroBackend {
            ZeroBackend { max_hint: std::sync::atomic::AtomicUsize::new(0) }
        }
    }

    impl InferenceBackend for ZeroBackend {
        fn arch(&self) -> &str {
            "zero"
        }

        fn buckets(&self) -> &[usize] {
            &[1]
        }

        fn infer_batch(&self, input: &QTensor) -> Result<QTensor> {
            let n = input.shape.n;
            Ok(QTensor::from_vec(Shape4::new(n, 1, 1, 10), 0, vec![0i32; n * 10]))
        }

        fn load_hint(&self, queued: usize) {
            self.max_hint.fetch_max(queued, Ordering::Relaxed);
        }
    }

    /// Flush immediately, one frame at a time.
    struct OnePlanner;

    impl BatchPlanner for OnePlanner {
        fn should_flush(&self, queued: usize, _oldest_age: Duration) -> bool {
            queued > 0
        }

        fn plan(&self, _queued: usize) -> Vec<BatchPlan> {
            vec![BatchPlan { bucket: 1, take: 1 }]
        }

        fn max_wait(&self) -> Duration {
            Duration::from_millis(1)
        }
    }

    fn run_worker(
        shared: &Arc<PoolShared>,
        metrics: &Arc<Metrics>,
        agg: &Arc<Metrics>,
        backend: Arc<ZeroBackend>,
    ) -> std::thread::JoinHandle<()> {
        let shared = shared.clone();
        let metrics = metrics.clone();
        let agg = agg.clone();
        std::thread::spawn(move || {
            serve_queue(backend.as_ref(), &OnePlanner, &shared, &metrics, &agg)
        })
    }

    fn drain_worker(shared: &Arc<PoolShared>, worker: std::thread::JoinHandle<()>) {
        {
            let mut st = shared.state.lock().unwrap();
            st.open = false;
            st.draining = true;
        }
        shared.cv.notify_all();
        worker.join().expect("worker panicked");
    }

    /// Injection test for the disconnect bugfix: a client that dropped
    /// its response `Receiver` mid-flight must cost exactly one counted
    /// disconnect — the worker neither panics nor wedges, and it keeps
    /// serving the connected client queued right behind.
    #[test]
    fn dropped_response_receiver_is_a_counted_noop() {
        let shared = Arc::new(PoolShared::new());
        let metrics = Arc::new(Metrics::new());
        let agg = Arc::new(Metrics::new());
        // First request: receiver already dropped (disconnected client).
        let (gone_tx, gone_rx) = mpsc::channel();
        drop(gone_rx);
        // Second request: a live client waiting behind the dead one.
        let (live_tx, live_rx) = mpsc::channel();
        {
            let mut st = shared.state.lock().unwrap();
            st.queue.push_back(Request {
                pixels: vec![0; IMG_ELEMS],
                submitted: Instant::now(),
                resp: gone_tx,
            });
            st.queue.push_back(Request {
                pixels: vec![0; IMG_ELEMS],
                submitted: Instant::now(),
                resp: live_tx,
            });
        }
        let worker = run_worker(&shared, &metrics, &agg, Arc::new(ZeroBackend::new()));
        shared.cv.notify_all();
        // The live client is served (single worker, FIFO: the dead
        // request was handled first).
        let resp = live_rx
            .recv_timeout(Duration::from_secs(30))
            .expect("worker wedged behind a disconnected client")
            .expect("inference failed");
        assert_eq!(resp.logits.len(), 10);
        assert_eq!(metrics.disconnects.load(Ordering::Relaxed), 1);
        assert_eq!(agg.disconnects.load(Ordering::Relaxed), 1);
        // Both frames executed — the disconnect was a no-op, not a skip.
        assert_eq!(metrics.frames.load(Ordering::Relaxed), 2);
        drain_worker(&shared, worker);
        let s = metrics.snapshot();
        assert_eq!(s.disconnects, 1);
        assert!(format!("{s}").contains("disconnects 1"), "{s}");
    }

    /// The worker's load hint folds the reported ingress depth into the
    /// router queue depth — the signal an elastic stream pool scales on.
    #[test]
    fn load_hint_folds_ingress_depth_into_queue_depth() {
        let shared = Arc::new(PoolShared::new());
        let metrics = Arc::new(Metrics::new());
        let agg = Arc::new(Metrics::new());
        shared.ingress.store(7, Ordering::Relaxed);
        let (resp_tx, resp_rx) = mpsc::channel();
        shared.state.lock().unwrap().queue.push_back(Request {
            pixels: vec![0; IMG_ELEMS],
            submitted: Instant::now(),
            resp: resp_tx,
        });
        let backend = Arc::new(ZeroBackend::new());
        let worker = run_worker(&shared, &metrics, &agg, backend.clone());
        shared.cv.notify_all();
        resp_rx
            .recv_timeout(Duration::from_secs(30))
            .expect("worker did not serve")
            .expect("inference failed");
        drain_worker(&shared, worker);
        // Queue depth 1 + ingress depth 7 = 8 observed by the backend.
        assert_eq!(backend.max_hint.load(Ordering::Relaxed), 8);
    }
}
