//! Serving metrics: counters and a fixed-bucket latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, PoisonError};
use std::time::{Duration, Instant};

use crate::obs::StallReport;

/// Histogram bucket upper bounds in microseconds.
pub const BOUNDS_US: [u64; 12] =
    [50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, u64::MAX];

/// Minimum interval between two stall-report refreshes: the report walks
/// every stage clock and FIFO probe of every replica, which is far too
/// much to redo after each served batch.
const STALL_REFRESH: Duration = Duration::from_millis(250);

/// Thread-safe serving metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub frames: AtomicU64,
    pub batches: AtomicU64,
    pub padded_frames: AtomicU64,
    pub errors: AtomicU64,
    /// Requests shed at ingress admission (queue full / infeasible
    /// deadline) instead of queued.  Shed requests never become
    /// `requests` — they are refused before reaching the router.
    pub shed: AtomicU64,
    /// Requests that expired while queued at ingress and were dropped at
    /// dispatch without backend work.
    pub deadline_expired: AtomicU64,
    /// Responses that could not be delivered because the client vanished
    /// mid-flight (dropped `submit` receiver or a dead socket).  Each is
    /// a counted no-op, never a worker panic.
    pub disconnects: AtomicU64,
    /// Worst streaming-pool buffering report observed: `(peak buffered
    /// elements, whole-tensor comparison base)`, replica-aggregated.
    /// Kept as a pair under one lock so the exported fraction always
    /// comes from a single real report — independent maxima could pair
    /// one backend's peak with another's base.
    stream_gauge: Mutex<(u64, u64)>,
    /// Live pipeline-replica count last reported by a streaming pool
    /// backend (an elastic pool moves this inside its band).
    replicas: AtomicU64,
    /// Highest replica count ever reported — shows how far an elastic
    /// pool scaled even after it drained back.
    peak_replicas: AtomicU64,
    /// Workers this arch's pool currently holds under the shared
    /// [`WorkerBudget`](crate::stream::WorkerBudget) (0 when unbudgeted).
    budget_held: AtomicU64,
    /// Workers reserved for this arch's pool at registration
    /// (`min_replicas x stages`; 0 when unbudgeted).
    budget_reserved: AtomicU64,
    /// Cumulative denied budget grants for this arch's pool.
    budget_denied: AtomicU64,
    /// `record_batch` calls whose `executed < real` — a caller
    /// accounting bug.  The padded-frame delta saturates to zero instead
    /// of wrapping; this counter makes the anomaly visible.
    pub batch_underflows: AtomicU64,
    /// Latest streaming-pool stall-attribution report plus when it was
    /// taken (refreshed at most every [`STALL_REFRESH`]).
    stalls: Mutex<Option<(StallReport, Instant)>>,
    latency: Mutex<Hist>,
}

#[derive(Debug, Default)]
struct Hist {
    counts: [u64; 12],
    sum_us: u64,
    max_us: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(&self, real: usize, executed: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.frames.fetch_add(real as u64, Ordering::Relaxed);
        // `executed < real` is a caller bug; an unchecked subtraction
        // here once wrapped to ~2^64 padded frames and poisoned every
        // padding-efficiency figure downstream.  Saturate and count.
        if executed < real {
            self.batch_underflows.fetch_add(1, Ordering::Relaxed);
        }
        self.padded_frames
            .fetch_add(executed.saturating_sub(real) as u64, Ordering::Relaxed);
    }

    /// Refresh the streaming-pool stall report, at most once per
    /// [`STALL_REFRESH`].  `f` (typically
    /// [`InferenceBackend::stall_report`](crate::runtime::InferenceBackend::stall_report))
    /// is only invoked when the cached report is stale, so the serving
    /// loop can call this after every batch.
    pub fn record_stalls<F: FnOnce() -> Option<StallReport>>(&self, f: F) {
        let mut slot = self.stalls.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some((_, at)) = slot.as_ref() {
            if at.elapsed() < STALL_REFRESH {
                return;
            }
        }
        if let Some(rep) = f() {
            *slot = Some((rep, Instant::now()));
        }
    }

    /// Latest stall-attribution report recorded via [`Self::record_stalls`]
    /// (`None` until a streaming backend has reported one).
    pub fn stall_report(&self) -> Option<StallReport> {
        let slot = self.stalls.lock().unwrap_or_else(PoisonError::into_inner);
        slot.as_ref().map(|(r, _)| r.clone())
    }

    /// Record a streaming backend's buffering report (peak buffered
    /// elements and the whole-tensor base, both aggregated across the
    /// pool's replicas).  The gauge keeps the report with the highest
    /// peak, as a pair, so a snapshot reflects the worst concurrent
    /// buffering observed with its own comparison base.
    pub fn record_stream(&self, peak_elems: u64, whole_elems: u64) {
        // Gauges recover from poison: a panic elsewhere must not stop
        // metrics from recording or reporting (the data is plain u64s).
        let mut g = self.stream_gauge.lock().unwrap_or_else(PoisonError::into_inner);
        if peak_elems > g.0 {
            *g = (peak_elems, whole_elems);
        }
    }

    /// Record a streaming backend's live pipeline-replica count (the
    /// elastic pool gauge): the snapshot keeps the latest value plus the
    /// peak ever observed.  Last-writer-wins — record it into one
    /// metrics instance per pool (the router records per arch only and
    /// sums arches into its total; with several workers per arch, each
    /// owning a pool, the per-arch gauge reflects the last-reporting
    /// worker's pool).
    pub fn record_replicas(&self, n: u64) {
        self.replicas.store(n, Ordering::Relaxed);
        self.peak_replicas.fetch_max(n, Ordering::Relaxed);
    }

    /// Record a streaming backend's shared-budget lease gauges: workers
    /// held, workers reserved and cumulative denied grants for the
    /// backing pool.  Last-writer-wins like [`Self::record_replicas`] —
    /// the values come from one coherent budget read, so they are stored
    /// together, not merged.
    pub fn record_budget(&self, held: u64, reserved: u64, denied: u64) {
        self.budget_held.store(held, Ordering::Relaxed);
        self.budget_reserved.store(reserved, Ordering::Relaxed);
        self.budget_denied.store(denied, Ordering::Relaxed);
    }

    /// Count one load-shed admission refusal.
    pub fn record_shed(&self) {
        self.shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one deadline expiry caught at dequeue.
    pub fn record_expired(&self) {
        self.deadline_expired.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one undeliverable response (client disconnected mid-flight).
    pub fn record_disconnect(&self) {
        self.disconnects.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_latency(&self, d: Duration) {
        let us = d.as_micros() as u64;
        let mut h = self.latency.lock().unwrap_or_else(PoisonError::into_inner);
        let idx = BOUNDS_US.iter().position(|&b| us <= b).unwrap_or(BOUNDS_US.len() - 1);
        h.counts[idx] += 1;
        h.sum_us += us;
        h.max_us = h.max_us.max(us);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let h = self.latency.lock().unwrap_or_else(PoisonError::into_inner);
        let total: u64 = h.counts.iter().sum();
        let pct = |p: f64| -> u64 {
            if total == 0 {
                return 0;
            }
            let target = (total as f64 * p).ceil() as u64;
            let mut acc = 0;
            for (i, &c) in h.counts.iter().enumerate() {
                acc += c;
                if acc >= target {
                    return BOUNDS_US[i];
                }
            }
            u64::MAX
        };
        let frames = self.frames.load(Ordering::Relaxed);
        let padded = self.padded_frames.load(Ordering::Relaxed);
        let executed = frames + padded;
        let (stream_peak, stream_whole) =
            *self.stream_gauge.lock().unwrap_or_else(PoisonError::into_inner);
        let requests = self.requests.load(Ordering::Relaxed);
        let shed = self.shed.load(Ordering::Relaxed);
        let deadline_expired = self.deadline_expired.load(Ordering::Relaxed);
        // Offered load = everything that reached admission: executed
        // requests, sheds, and queued-then-expired frames.
        let offered = requests + shed + deadline_expired;
        MetricsSnapshot {
            requests,
            frames,
            batches: self.batches.load(Ordering::Relaxed),
            padded_frames: padded,
            padding_efficiency: if executed > 0 { frames as f64 / executed as f64 } else { 1.0 },
            errors: self.errors.load(Ordering::Relaxed),
            shed,
            deadline_expired,
            disconnects: self.disconnects.load(Ordering::Relaxed),
            shed_rate: if offered > 0 { shed as f64 / offered as f64 } else { 0.0 },
            mean_latency_us: if total > 0 { h.sum_us / total } else { 0 },
            p50_le_us: pct(0.50),
            p95_le_us: pct(0.95),
            p99_le_us: pct(0.99),
            max_latency_us: h.max_us,
            stream_peak_buffered_elems: stream_peak,
            stream_buffered_fraction: if stream_whole > 0 {
                stream_peak as f64 / stream_whole as f64
            } else {
                0.0
            },
            stream_replicas: self.replicas.load(Ordering::Relaxed),
            stream_peak_replicas: self.peak_replicas.load(Ordering::Relaxed),
            budget_workers_held: self.budget_held.load(Ordering::Relaxed),
            budget_workers_reserved: self.budget_reserved.load(Ordering::Relaxed),
            budget_denied: self.budget_denied.load(Ordering::Relaxed),
            batch_underflows: self.batch_underflows.load(Ordering::Relaxed),
            bottleneck: {
                let slot = self.stalls.lock().unwrap_or_else(PoisonError::into_inner);
                slot.as_ref().and_then(|(r, _)| {
                    let b = r.bottleneck();
                    b.limiting.as_ref()?;
                    Some(b.to_string())
                })
            },
        }
    }
}

/// A point-in-time snapshot for reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub frames: u64,
    pub batches: u64,
    pub padded_frames: u64,
    /// Real frames / executed frames (1.0 when nothing ran yet).
    pub padding_efficiency: f64,
    pub errors: u64,
    /// Ingress admission refusals (queue full / infeasible deadline).
    pub shed: u64,
    /// Requests expired while queued at ingress, dropped at dispatch.
    pub deadline_expired: u64,
    /// Responses dropped because the client vanished mid-flight.
    pub disconnects: u64,
    /// `shed / (requests + shed + deadline_expired)` — the fraction of
    /// offered load refused at admission (0.0 when nothing was offered).
    pub shed_rate: f64,
    pub mean_latency_us: u64,
    /// Latency percentiles as histogram-bucket upper bounds.
    pub p50_le_us: u64,
    pub p95_le_us: u64,
    pub p99_le_us: u64,
    pub max_latency_us: u64,
    /// Peak streamed buffering gauge (elements) from a streaming
    /// backend's pool, aggregated across replicas; 0 when no streaming
    /// backend reported.
    pub stream_peak_buffered_elems: u64,
    /// Peak buffering over the whole-tensor-intermediates base (0.0 when
    /// no streaming backend reported; Eq. 22's point is that this is
    /// well below 1).
    pub stream_buffered_fraction: f64,
    /// Live pipeline replicas last reported by a streaming pool backend
    /// (0 when none reported); an elastic pool moves this inside its
    /// `min..=max` band.
    pub stream_replicas: u64,
    /// Highest replica count ever reported (0 when none reported).
    pub stream_peak_replicas: u64,
    /// Workers held under the shared worker budget (0 when unbudgeted).
    pub budget_workers_held: u64,
    /// Workers reserved at budget registration (0 when unbudgeted; a
    /// nonzero reservation is the "this pool is budgeted" marker).
    pub budget_workers_reserved: u64,
    /// Cumulative budget grants denied to this arch's pool.
    pub budget_denied: u64,
    /// `record_batch` calls with `executed < real` (0 in a healthy run).
    pub batch_underflows: u64,
    /// Rendered [`crate::obs::BottleneckReport`] of the last recorded
    /// stall report (`None` until a streaming backend reported stalls,
    /// or when the report had no stage data).
    pub bottleneck: Option<String>,
}

impl std::fmt::Display for MetricsSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let b = |v: u64| {
            if v == u64::MAX { ">100ms".to_string() } else { format!("<={v}us") }
        };
        write!(
            f,
            "req {}  frames {}  batches {}  padded {} (eff {:.2})  err {}  lat mean {}us p50{} p95{} p99{} max {}us",
            self.requests, self.frames, self.batches, self.padded_frames,
            self.padding_efficiency, self.errors, self.mean_latency_us,
            b(self.p50_le_us), b(self.p95_le_us), b(self.p99_le_us), self.max_latency_us
        )?;
        if self.shed + self.deadline_expired + self.disconnects > 0 {
            write!(
                f,
                "  shed {} ({:.1}% of offered)  expired {}  disconnects {}",
                self.shed,
                self.shed_rate * 100.0,
                self.deadline_expired,
                self.disconnects
            )?;
        }
        if self.stream_peak_buffered_elems > 0 {
            write!(
                f,
                "  stream-buf peak {} elems ({:.4} of whole-tensor)",
                self.stream_peak_buffered_elems, self.stream_buffered_fraction
            )?;
        }
        if self.stream_peak_replicas > 0 {
            write!(f, "  replicas {} (peak {})", self.stream_replicas, self.stream_peak_replicas)?;
        }
        if self.budget_workers_reserved > 0 {
            write!(
                f,
                "  budget holds {} of {} reserved (denied {})",
                self.budget_workers_held, self.budget_workers_reserved, self.budget_denied
            )?;
        }
        if self.batch_underflows > 0 {
            write!(f, "  batch-underflows {}", self.batch_underflows)?;
        }
        if let Some(b) = &self.bottleneck {
            write!(f, "  bottleneck: {b}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn histogram_percentiles() {
        let m = Metrics::new();
        for us in [40u64, 90, 90, 200, 200, 200, 400, 900, 2_000, 80_000] {
            m.record_latency(Duration::from_micros(us));
        }
        let s = m.snapshot();
        assert_eq!(s.p50_le_us, 250);
        assert_eq!(s.p95_le_us, 100_000);
        assert_eq!(s.p99_le_us, 100_000);
        assert_eq!(s.max_latency_us, 80_000);
        assert!(s.mean_latency_us > 0);
    }

    #[test]
    fn batch_accounting() {
        let m = Metrics::new();
        m.record_batch(5, 8);
        m.record_batch(64, 64);
        let s = m.snapshot();
        assert_eq!(s.frames, 69);
        assert_eq!(s.padded_frames, 3);
        assert_eq!(s.batches, 2);
        assert_eq!(s.batch_underflows, 0);
        assert!((s.padding_efficiency - 69.0 / 72.0).abs() < 1e-9);
    }

    #[test]
    fn batch_underflow_saturates_and_is_counted() {
        // Regression: `executed < real` used to wrap `(executed - real)
        // as u64` to ~2^64 padded frames, destroying padding efficiency.
        let m = Metrics::new();
        m.record_batch(8, 5);
        m.record_batch(4, 4);
        let s = m.snapshot();
        assert_eq!(s.frames, 12);
        assert_eq!(s.padded_frames, 0, "underflow must saturate, not wrap");
        assert_eq!(s.batch_underflows, 1);
        assert_eq!(s.padding_efficiency, 1.0);
        assert!(format!("{s}").contains("batch-underflows 1"), "{s}");
    }

    #[test]
    fn stall_reports_are_throttled_and_snapshotted() {
        use crate::obs::{StageRole, StageStall, StallReport};
        let stall = |busy_ns: u64, blocked: u64| StageStall {
            stage: "s0b0c1".to_string(),
            role: StageRole::Stage,
            elapsed_ns: busy_ns + blocked,
            blocked_push_ns: blocked,
            blocked_pop_ns: 0,
            frames: 4,
            worst_push_edge: Some(("s0b0c1.out".to_string(), blocked)),
            worst_pop_edge: None,
        };
        let m = Metrics::new();
        assert!(m.stall_report().is_none());
        assert!(m.snapshot().bottleneck.is_none());
        let mut calls = 0u32;
        m.record_stalls(|| {
            calls += 1;
            Some(StallReport { stages: vec![stall(900, 100)], ..Default::default() })
        });
        // A fresh report is cached: the producer must not run again
        // within the refresh window.
        m.record_stalls(|| {
            calls += 1;
            Some(StallReport::default())
        });
        assert_eq!(calls, 1, "second refresh inside the window must be skipped");
        let rep = m.stall_report().expect("first report cached");
        assert_eq!(rep.stages.len(), 1);
        let b = m.snapshot().bottleneck.expect("bottleneck rendered");
        assert!(b.contains("s0b0c1"), "{b}");
        assert!(format!("{}", m.snapshot()).contains("bottleneck:"));
    }

    #[test]
    fn empty_snapshot_has_unit_efficiency() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.padding_efficiency, 1.0);
        assert_eq!(s.p95_le_us, 0);
        assert_eq!(s.stream_peak_buffered_elems, 0);
        assert_eq!(s.stream_buffered_fraction, 0.0);
        assert!(!format!("{s}").contains("stream-buf"));
    }

    #[test]
    fn shed_rate_over_offered_load_and_display_tail() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!((s.shed, s.deadline_expired, s.disconnects), (0, 0, 0));
        assert_eq!(s.shed_rate, 0.0);
        assert!(!format!("{s}").contains("shed"), "quiet until something sheds: {s}");
        // 6 executed + 3 shed + 1 queued-then-expired = 10 offered.
        m.requests.fetch_add(6, Ordering::Relaxed);
        for _ in 0..3 {
            m.record_shed();
        }
        m.record_expired();
        m.record_disconnect();
        let s = m.snapshot();
        assert_eq!(s.shed, 3);
        assert_eq!(s.deadline_expired, 1);
        assert_eq!(s.disconnects, 1);
        assert!((s.shed_rate - 0.3).abs() < 1e-9, "{}", s.shed_rate);
        let text = format!("{s}");
        assert!(text.contains("shed 3 (30.0% of offered)  expired 1  disconnects 1"), "{text}");
    }

    #[test]
    fn replica_gauge_tracks_latest_and_peak() {
        let m = Metrics::new();
        let s = m.snapshot();
        assert_eq!((s.stream_replicas, s.stream_peak_replicas), (0, 0));
        assert!(!format!("{s}").contains("replicas"));
        m.record_replicas(1);
        m.record_replicas(3);
        m.record_replicas(2);
        let s = m.snapshot();
        // Latest value + peak: an elastic pool that grew to 3 and
        // drained back to 2 reports both transitions.
        assert_eq!(s.stream_replicas, 2);
        assert_eq!(s.stream_peak_replicas, 3);
        assert!(format!("{s}").contains("replicas 2 (peak 3)"), "{s}");
    }

    #[test]
    fn stream_gauges_keep_the_worst_report_as_a_pair() {
        let m = Metrics::new();
        m.record_stream(100, 1000);
        // Lower peak must not regress the gauge — and its (different)
        // whole-tensor base must not be mixed into the kept report.
        m.record_stream(80, 100);
        let s = m.snapshot();
        assert_eq!(s.stream_peak_buffered_elems, 100);
        assert!((s.stream_buffered_fraction - 0.1).abs() < 1e-9);
        assert!(format!("{s}").contains("stream-buf"));
        // A higher peak replaces the pair wholesale.
        m.record_stream(200, 400);
        let s = m.snapshot();
        assert_eq!(s.stream_peak_buffered_elems, 200);
        assert!((s.stream_buffered_fraction - 0.5).abs() < 1e-9);
    }
}
