//! Dynamic batching policy (pure logic — unit-testable without PJRT).
//!
//! The AOT artifacts bake one executable per batch size (e.g. 1/8/64), so
//! the batcher's job is to map a run of queued single-frame requests onto
//! the cheapest sequence of bucket executions, trading latency (wait for
//! more frames) against throughput (bigger buckets amortize dispatch).

use std::time::Duration;

/// Policy parameters.
#[derive(Debug, Clone)]
pub struct BatcherConfig {
    /// Available bucket sizes, ascending (from the artifacts manifest).
    pub buckets: Vec<usize>,
    /// Max time the oldest request may wait before a forced flush.
    pub max_wait: Duration,
    /// Flush immediately once this many frames are queued.
    pub max_queue: usize,
    /// Largest bucket the policy will dispatch.  Measured on this CPU
    /// PJRT backend, per-frame throughput peaks at b8 and *degrades* at
    /// b64 (cache residency), so the default caps there — see
    /// EXPERIMENTS.md §Perf (coordinator entry).
    pub max_bucket: usize,
    /// Frames-equivalent fixed cost charged per dispatched execution in
    /// the planning cost model (padding 5 frames into a bucket of 8 beats
    /// five single-frame dispatches, but 9 frames still split 8 + 1).
    pub dispatch_overhead: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            buckets: vec![1, 8, 64],
            max_wait: Duration::from_millis(2),
            max_queue: 64,
            max_bucket: 8,
            dispatch_overhead: 4,
        }
    }
}

/// A planned execution: run bucket `bucket` on `take` real frames
/// (bucket - take frames are zero padding).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BatchPlan {
    pub bucket: usize,
    pub take: usize,
}

/// The batching policy.
#[derive(Debug, Clone)]
pub struct Batcher {
    cfg: BatcherConfig,
}

impl Batcher {
    pub fn new(mut cfg: BatcherConfig) -> Self {
        cfg.buckets.sort_unstable();
        cfg.buckets.dedup();
        let cap = cfg.max_bucket.max(*cfg.buckets.first().unwrap_or(&1));
        cfg.buckets.retain(|&b| b <= cap);
        if cfg.buckets.is_empty() {
            // An empty bucket list (misconfigured manifest) degrades to
            // single-frame dispatch instead of panicking the executor
            // thread that builds its batcher from backend preferences.
            cfg.buckets.push(1);
        }
        Batcher { cfg }
    }

    pub fn config(&self) -> &BatcherConfig {
        &self.cfg
    }

    /// Should we flush now, given `queued` frames and the oldest request's
    /// age?  (The server calls this on every queue event / tick.)
    pub fn should_flush(&self, queued: usize, oldest_age: Duration) -> bool {
        queued > 0 && (queued >= self.cfg.max_queue || oldest_age >= self.cfg.max_wait)
    }

    /// Plan bucket executions for `queued` frames.
    ///
    /// At each step, compare (a) greedy largest-fit decomposition of the
    /// remainder against (b) padding the whole remainder into the smallest
    /// covering bucket, under a cost of `bucket + dispatch_overhead` frames
    /// per execution — padding 5 frames into a bucket of 8 beats five
    /// single-frame dispatches, but 9 frames still split into 8 + 1.
    ///
    /// By construction the result never costs more (per [`Self::plan_cost`])
    /// than the pure greedy largest-fit decomposition — asserted by the
    /// property test in `rust/tests/props.rs`.
    pub fn plan(&self, queued: usize) -> Vec<BatchPlan> {
        let overhead = self.cfg.dispatch_overhead;
        let mut plans = Vec::new();
        // `new()` guarantees a non-empty bucket list; the guard keeps this
        // loop panic-free even if that invariant is ever broken.
        let Some(&smallest) = self.cfg.buckets.first() else {
            return plans;
        };
        let mut left = queued;
        while left > 0 {
            // Option A: greedy decomposition cost of `left`.
            let mut greedy_cost = 0usize;
            let mut l = left;
            let mut first_greedy = None;
            while l > 0 {
                let b = self
                    .cfg
                    .buckets
                    .iter()
                    .rev()
                    .find(|&&b| b <= l)
                    .copied()
                    .unwrap_or(smallest);
                if first_greedy.is_none() {
                    first_greedy = Some(b);
                }
                greedy_cost += b + overhead;
                l -= b.min(l);
            }
            // Option B: pad into the smallest covering bucket.
            let pad = self.cfg.buckets.iter().find(|&&b| b >= left).copied();
            match pad {
                Some(b) if b + overhead < greedy_cost => {
                    plans.push(BatchPlan { bucket: b, take: left });
                    left = 0;
                }
                _ => {
                    // The greedy pass above always visits at least one
                    // bucket when `left > 0`.
                    let b = first_greedy.unwrap_or(smallest);
                    let take = b.min(left);
                    plans.push(BatchPlan { bucket: b, take });
                    left -= take;
                }
            }
        }
        plans
    }

    /// Cost of a plan under the dispatch-overhead model: each execution
    /// costs its bucket's frames plus the fixed dispatch overhead.
    pub fn plan_cost(&self, plans: &[BatchPlan]) -> usize {
        plans.iter().map(|p| p.bucket + self.cfg.dispatch_overhead).sum()
    }

    /// Padding efficiency of a plan: real frames / executed frames.
    pub fn efficiency(plans: &[BatchPlan]) -> f64 {
        let real: usize = plans.iter().map(|p| p.take).sum();
        let exec: usize = plans.iter().map(|p| p.bucket).sum();
        if exec == 0 {
            1.0
        } else {
            real as f64 / exec as f64
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    fn batcher() -> Batcher {
        Batcher::new(BatcherConfig { buckets: vec![1, 8, 64], max_bucket: 64, ..Default::default() })
    }

    #[test]
    fn plans_greedy_largest_fit() {
        let b = batcher();
        assert_eq!(
            b.plan(70),
            vec![BatchPlan { bucket: 64, take: 64 }, BatchPlan { bucket: 8, take: 6 }]
        );
        assert_eq!(b.plan(8), vec![BatchPlan { bucket: 8, take: 8 }]);
        assert_eq!(b.plan(1), vec![BatchPlan { bucket: 1, take: 1 }]);
    }

    #[test]
    fn pads_remainder_into_next_bucket() {
        let b = batcher();
        let plans = b.plan(5);
        assert_eq!(plans, vec![BatchPlan { bucket: 8, take: 5 }]);
        assert!((Batcher::efficiency(&plans) - 5.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn flush_on_age_or_size() {
        let b = batcher();
        assert!(!b.should_flush(0, Duration::from_secs(1)));
        assert!(b.should_flush(64, Duration::ZERO));
        assert!(b.should_flush(1, Duration::from_millis(3)));
        assert!(!b.should_flush(1, Duration::from_micros(100)));
    }

    #[test]
    fn covers_every_queue_size() {
        let b = batcher();
        for q in 1..200 {
            let plans = b.plan(q);
            let total: usize = plans.iter().map(|p| p.take).sum();
            assert_eq!(total, q, "queue {q}");
            assert!(Batcher::efficiency(&plans) > 0.1);
        }
    }
}
