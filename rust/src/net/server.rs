//! The TCP ingress front-end: sockets in, router out.
//!
//! Thread shape:
//!
//! * one **acceptor** (nonblocking accept loop, bounded by `max_conns`);
//! * two threads per connection — a **reader** that decodes frames,
//!   assigns tickets and offers work to the bounded
//!   [`AdmissionQueue`], and a **writer** that emits responses strictly
//!   in ticket order (the reader enqueues one response *slot* per
//!   request before the outcome is known, so pipelined clients never
//!   see reordering);
//! * `dispatchers` **dispatcher** threads that pop admitted requests,
//!   re-check the deadline (a request can expire while queued), submit
//!   to the [`Router`], and forward the backend's answer into the slot.
//!
//! Admission is where the firehose is survived: a full queue or an
//! infeasible deadline sheds immediately with a retry-after hint
//! (`SHED` on the wire) instead of queueing unboundedly, and the
//! ingress queue depth is reported into the router's
//! [`load_hint`](crate::runtime::InferenceBackend::load_hint) path on
//! every depth change so an elastic streaming pool can grow replicas
//! *before* the backend's own queue backs up — the socket-to-replica
//! elastic loop from the ROADMAP's production-ingress item.
//!
//! Shed and deadline-expired requests are also recorded into the
//! router's per-arch [`Metrics`] (and its aggregate), so a
//! `RouterSnapshot` shows the ingress tail: shed counts, shed rate and
//! expiries alongside the serving latency percentiles.

use std::collections::BTreeMap;
use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::coordinator::{Metrics, Router};

use super::admission::{AdmissionConfig, AdmissionQueue, Offer, Pop, ShedReason};
use super::protocol::{
    write_frame, ErrorCode, RequestFrame, ResponseFrame, WireError, MAX_REQUEST_BYTES,
};

/// Ingress policy knobs (see the README's "Network ingress" section).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Bind address; port 0 asks the OS for an ephemeral port (read the
    /// chosen one back from [`IngressServer::local_addr`]).
    pub addr: String,
    /// Bounded admission-queue capacity; offers beyond it shed.
    pub queue_capacity: usize,
    /// Dispatcher threads bridging the queue to the router.  Also the
    /// ingress-side in-flight cap: each dispatcher waits for its
    /// request's response before popping the next, so total buffered
    /// work is `queue_capacity + dispatchers` frames.
    pub dispatchers: usize,
    /// Deadline applied when a request carries `deadline_ms == 0`.
    pub default_deadline: Duration,
    /// Upper clamp on client-supplied deadlines.
    pub max_deadline: Duration,
    /// Floor for the retry-after hint on shed responses.
    pub min_retry_after: Duration,
    /// Maximum concurrent connections; beyond it new sockets are
    /// dropped at accept (counted, never queued).
    pub max_conns: usize,
    /// Bind address for the HTTP metrics exposition endpoint
    /// ([`super::metrics::MetricsServer`]); `None` = no endpoint.
    pub metrics_addr: Option<String>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            addr: "127.0.0.1:0".to_string(),
            queue_capacity: 64,
            dispatchers: 2,
            default_deadline: Duration::from_millis(500),
            max_deadline: Duration::from_secs(10),
            min_retry_after: Duration::from_millis(5),
            max_conns: 256,
            metrics_addr: None,
        }
    }
}

/// One admitted request, queued between a connection reader and the
/// dispatchers.
struct Admitted {
    arch: String,
    pixels: Vec<i32>,
    ticket: u64,
    accepted: Instant,
    deadline: Instant,
    /// The connection writer's in-order response slot.
    done: Sender<ResponseFrame>,
}

/// Ingress counters (atomics; see [`IngressSnapshot`] for the exported
/// point-in-time view).
#[derive(Debug, Default)]
struct IngressStats {
    connections: AtomicU64,
    refused_conns: AtomicU64,
    accepted: AtomicU64,
    shed: AtomicU64,
    expired: AtomicU64,
    responses: AtomicU64,
    disconnects: AtomicU64,
    bad_frames: AtomicU64,
}

/// Point-in-time ingress counters + queue gauges.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IngressSnapshot {
    /// Sockets accepted over the server's lifetime.
    pub connections: u64,
    /// Sockets dropped at accept because `max_conns` was reached.
    pub refused_conns: u64,
    /// Requests admitted into the bounded queue.
    pub accepted: u64,
    /// Requests shed at admission (queue full or deadline infeasible).
    pub shed: u64,
    /// Requests that expired while queued (caught at dispatch).
    pub expired: u64,
    /// Response frames written to sockets.
    pub responses: u64,
    /// Connections that vanished mid-flight (write failed or the
    /// response slot was gone).
    pub disconnects: u64,
    /// Malformed request frames answered with a typed error.
    pub bad_frames: u64,
    /// Live admission-queue depth.
    pub queue_depth: usize,
    /// Highest queue depth ever observed (the soak bound: never above
    /// `queue_capacity`).
    pub queue_peak_depth: usize,
    pub queue_capacity: usize,
}

impl std::fmt::Display for IngressSnapshot {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "conns {} (refused {})  accepted {}  shed {}  expired {}  responses {}  \
             disconnects {}  bad-frames {}  queue {}/{} (peak {})",
            self.connections, self.refused_conns, self.accepted, self.shed, self.expired,
            self.responses, self.disconnects, self.bad_frames, self.queue_depth,
            self.queue_capacity, self.queue_peak_depth
        )
    }
}

/// Everything the acceptor, connection and dispatcher threads share.
struct ServerShared {
    router: Arc<Router>,
    queue: AdmissionQueue<Admitted>,
    cfg: ServerConfig,
    stop: AtomicBool,
    stats: IngressStats,
    archs: Vec<String>,
    /// Per-arch router metrics plus the aggregate — shed/expired are
    /// recorded here so they surface in `RouterSnapshot`.
    metrics: BTreeMap<String, Arc<Metrics>>,
    agg: Arc<Metrics>,
}

impl ServerShared {
    fn record_shed(&self, arch: &str) {
        self.stats.shed.fetch_add(1, Ordering::Relaxed);
        if let Some(m) = self.metrics.get(arch) {
            m.record_shed();
        }
        self.agg.record_shed();
    }

    fn record_expired(&self, arch: &str) {
        self.stats.expired.fetch_add(1, Ordering::Relaxed);
        if let Some(m) = self.metrics.get(arch) {
            m.record_expired();
        }
        self.agg.record_expired();
    }

    fn snapshot(&self) -> IngressSnapshot {
        IngressSnapshot {
            connections: self.stats.connections.load(Ordering::Relaxed),
            refused_conns: self.stats.refused_conns.load(Ordering::Relaxed),
            accepted: self.stats.accepted.load(Ordering::Relaxed),
            shed: self.stats.shed.load(Ordering::Relaxed),
            expired: self.stats.expired.load(Ordering::Relaxed),
            responses: self.stats.responses.load(Ordering::Relaxed),
            disconnects: self.stats.disconnects.load(Ordering::Relaxed),
            bad_frames: self.stats.bad_frames.load(Ordering::Relaxed),
            queue_depth: self.queue.depth(),
            queue_peak_depth: self.queue.peak_depth(),
            queue_capacity: self.queue.capacity(),
        }
    }
}

/// Handle to a running TCP ingress front-end.
pub struct IngressServer {
    addr: SocketAddr,
    shared: Arc<ServerShared>,
    acceptor: Option<JoinHandle<()>>,
    dispatchers: Vec<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
    /// The optional HTTP exposition endpoint (`metrics_addr`), stopped
    /// with the server so its router `Arc` is released at shutdown.
    metrics_srv: Option<super::metrics::MetricsServer>,
}

impl IngressServer {
    /// Bind, spawn the acceptor and dispatcher threads, return the
    /// handle.  The router stays owned by the caller (`Arc`); the
    /// server only submits into it and reports ingress depth.
    pub fn start(router: Arc<Router>, cfg: ServerConfig) -> Result<IngressServer> {
        let listener = TcpListener::bind(&cfg.addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let archs = router.archs();
        let metrics: BTreeMap<String, Arc<Metrics>> = archs
            .iter()
            .filter_map(|a| router.metrics(a).map(|m| (a.clone(), m)))
            .collect();
        let agg = router.aggregate();
        let metrics_srv = match &cfg.metrics_addr {
            Some(a) => Some(super::metrics::MetricsServer::start(router.clone(), a)?),
            None => None,
        };
        let queue = AdmissionQueue::new(AdmissionConfig {
            capacity: cfg.queue_capacity,
            dispatchers: cfg.dispatchers,
            min_retry_after: cfg.min_retry_after,
        });
        let shared = Arc::new(ServerShared {
            router,
            queue,
            cfg: cfg.clone(),
            stop: AtomicBool::new(false),
            stats: IngressStats::default(),
            archs,
            metrics,
            agg,
        });
        let mut dispatchers = Vec::new();
        for di in 0..cfg.dispatchers.max(1) {
            let shared = shared.clone();
            dispatchers.push(
                std::thread::Builder::new()
                    .name(format!("ingress-dispatch-{di}"))
                    .spawn(move || dispatcher_loop(&shared))?,
            );
        }
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let live_conns = Arc::new(AtomicUsize::new(0));
        let acceptor = {
            let shared = shared.clone();
            let conns = conns.clone();
            std::thread::Builder::new().name("ingress-accept".to_string()).spawn(move || {
                accept_loop(&shared, &listener, &conns, &live_conns)
            })?
        };
        Ok(IngressServer {
            addr,
            shared,
            acceptor: Some(acceptor),
            dispatchers,
            conns,
            metrics_srv,
        })
    }

    /// The bound address (resolves port 0 to the OS-chosen port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The metrics endpoint's bound address (`None` when the config did
    /// not request one).
    pub fn metrics_addr(&self) -> Option<SocketAddr> {
        self.metrics_srv.as_ref().map(|m| m.local_addr())
    }

    /// Live ingress counters and queue gauges.
    pub fn snapshot(&self) -> IngressSnapshot {
        self.shared.snapshot()
    }

    /// Stop accepting, drain the admission queue (every queued request
    /// is answered — with its result if already dispatched, with a
    /// typed shutdown error otherwise), join every thread, and return
    /// the final counters.  The router is left running.
    pub fn shutdown(mut self) -> IngressSnapshot {
        self.stop_and_join();
        self.shared.snapshot()
    }

    fn stop_and_join(&mut self) {
        // Stop the exposition endpoint first: it holds its own router
        // Arc, which callers expect released once shutdown returns.
        if let Some(m) = self.metrics_srv.take() {
            m.shutdown();
        }
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.queue.close();
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for d in self.dispatchers.drain(..) {
            let _ = d.join();
        }
        let handles: Vec<JoinHandle<()>> = match self.conns.lock() {
            Ok(mut g) => g.drain(..).collect(),
            Err(p) => p.into_inner().drain(..).collect(),
        };
        for h in handles {
            let _ = h.join();
        }
        self.shared.router.report_ingress(0);
    }
}

impl Drop for IngressServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

// ------------------------------------------------------------ acceptor

fn accept_loop(
    shared: &Arc<ServerShared>,
    listener: &TcpListener,
    conns: &Arc<Mutex<Vec<JoinHandle<()>>>>,
    live: &Arc<AtomicUsize>,
) {
    while !shared.stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                shared.stats.connections.fetch_add(1, Ordering::Relaxed);
                if live.load(Ordering::Relaxed) >= shared.cfg.max_conns {
                    shared.stats.refused_conns.fetch_add(1, Ordering::Relaxed);
                    drop(stream);
                    continue;
                }
                live.fetch_add(1, Ordering::Relaxed);
                let shared = shared.clone();
                let live = live.clone();
                let handle = std::thread::Builder::new()
                    .name("ingress-conn".to_string())
                    .spawn(move || {
                        conn_loop(&shared, stream);
                        live.fetch_sub(1, Ordering::Relaxed);
                    });
                match handle {
                    Ok(h) => {
                        let mut g = match conns.lock() {
                            Ok(g) => g,
                            Err(p) => p.into_inner(),
                        };
                        // Reap finished connections so a long-lived
                        // server doesn't accumulate dead JoinHandles.
                        g.retain(|h| !h.is_finished());
                        g.push(h);
                    }
                    Err(_) => {
                        live.fetch_sub(1, Ordering::Relaxed);
                        shared.stats.refused_conns.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

// ---------------------------------------------------------- connection

/// Read one length-prefixed frame, tolerating read timeouts (the socket
/// has a short read timeout so shutdown is observed); partial frames
/// are accumulated across timeouts.  `Ok(None)` = clean close or stop.
fn read_frame_cancellable(
    stream: &mut TcpStream,
    max: usize,
    stop: &AtomicBool,
) -> Result<Option<Vec<u8>>, WireError> {
    let mut prefix = [0u8; 4];
    let mut have = 0usize;
    while have < 4 {
        if stop.load(Ordering::Relaxed) {
            return Ok(None);
        }
        match stream.read(&mut prefix[have..]) {
            Ok(0) => {
                if have == 0 {
                    return Ok(None);
                }
                return Err(WireError::Truncated { need: 4, have });
            }
            Ok(n) => have += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                continue
            }
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(prefix) as usize;
    if len > max {
        return Err(WireError::Oversized { len, max });
    }
    let mut body = vec![0u8; len];
    let mut have = 0usize;
    while have < len {
        if stop.load(Ordering::Relaxed) {
            return Ok(None);
        }
        match stream.read(&mut body[have..]) {
            Ok(0) => return Err(WireError::Truncated { need: len, have }),
            Ok(n) => have += n,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                continue
            }
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    Ok(Some(body))
}

/// Per-connection reader: decode, admit (or shed/reject), and keep the
/// writer's slot queue in strict ticket order.
fn conn_loop(shared: &Arc<ServerShared>, mut stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(100)));
    let wstream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => {
            shared.stats.disconnects.fetch_add(1, Ordering::Relaxed);
            return;
        }
    };
    // In-order response slots: the reader enqueues one slot per request
    // *before* its outcome exists; the writer resolves them in order.
    let (slot_tx, slot_rx) = mpsc::channel::<Receiver<ResponseFrame>>();
    let writer = {
        let shared = shared.clone();
        std::thread::Builder::new()
            .name("ingress-write".to_string())
            .spawn(move || writer_loop(&shared, wstream, slot_rx))
    };
    let writer = match writer {
        Ok(w) => w,
        Err(_) => {
            shared.stats.disconnects.fetch_add(1, Ordering::Relaxed);
            return;
        }
    };

    let mut ticket: u64 = 0;
    loop {
        let body = match read_frame_cancellable(&mut stream, MAX_REQUEST_BYTES, &shared.stop) {
            Ok(Some(b)) => b,
            Ok(None) => break, // clean close or server stop
            Err(WireError::Oversized { len, max }) => {
                // The framing itself is untrustworthy past this point:
                // answer typed, then close.
                ticket += 1;
                shared.stats.bad_frames.fetch_add(1, Ordering::Relaxed);
                respond(
                    &slot_tx,
                    ResponseFrame::Error {
                        ticket,
                        code: ErrorCode::BadRequest,
                        msg: format!("oversized frame: {len} bytes (max {max})"),
                    },
                );
                break;
            }
            Err(_) => {
                // Mid-frame EOF or a transport error: the client is gone.
                shared.stats.disconnects.fetch_add(1, Ordering::Relaxed);
                break;
            }
        };
        ticket += 1;
        let req = match RequestFrame::decode(&body) {
            Ok(r) => r,
            Err(we) => {
                // Frame boundaries are still intact (the length prefix
                // was honored): reject this request typed and keep the
                // connection.
                shared.stats.bad_frames.fetch_add(1, Ordering::Relaxed);
                respond(
                    &slot_tx,
                    ResponseFrame::Error {
                        ticket,
                        code: ErrorCode::BadRequest,
                        msg: we.to_string(),
                    },
                );
                continue;
            }
        };
        if !shared.archs.iter().any(|a| a == &req.arch) {
            shared.stats.bad_frames.fetch_add(1, Ordering::Relaxed);
            respond(
                &slot_tx,
                ResponseFrame::Error {
                    ticket,
                    code: ErrorCode::UnknownArch,
                    msg: format!("no backend for arch {} (have: {:?})", req.arch, shared.archs),
                },
            );
            continue;
        }
        let budget = if req.deadline_ms == 0 {
            shared.cfg.default_deadline
        } else {
            Duration::from_millis(req.deadline_ms as u64).min(shared.cfg.max_deadline)
        };
        let accepted = Instant::now();
        let (done_tx, done_rx) = mpsc::channel::<ResponseFrame>();
        if slot_tx.send(done_rx).is_err() {
            // Writer died (socket gone): stop reading.
            shared.stats.disconnects.fetch_add(1, Ordering::Relaxed);
            break;
        }
        let item = Admitted {
            arch: req.arch,
            pixels: req.pixels,
            ticket,
            accepted,
            deadline: accepted + budget,
            done: done_tx,
        };
        match shared.queue.offer(item, budget) {
            Offer::Admitted { depth } => {
                shared.stats.accepted.fetch_add(1, Ordering::Relaxed);
                shared.router.report_ingress(depth);
            }
            Offer::Shed { item, reason: _reason, retry_after } => {
                shared.record_shed(&item.arch);
                let _ = item.done.send(ResponseFrame::Shed {
                    ticket: item.ticket,
                    retry_after_ms: (retry_after.as_millis() as u32).max(1),
                });
            }
        }
    }
    // Closing the slot channel lets the writer drain outstanding
    // responses and exit.
    drop(slot_tx);
    let _ = writer.join();
}

/// Push an immediately-resolved response slot (shed / typed error).
fn respond(slot_tx: &Sender<Receiver<ResponseFrame>>, resp: ResponseFrame) {
    let (tx, rx) = mpsc::channel();
    let _ = tx.send(resp);
    let _ = slot_tx.send(rx);
}

/// Per-connection writer: resolve slots in ticket order, write frames.
/// A failed write marks the connection broken (counted once); the
/// remaining slots still drain so dispatchers never block on a dead
/// connection's channel.
fn writer_loop(
    shared: &Arc<ServerShared>,
    mut stream: TcpStream,
    slots: Receiver<Receiver<ResponseFrame>>,
) {
    let mut broken = false;
    for slot in slots.iter() {
        let resp = match slot.recv() {
            Ok(r) => r,
            // The producer vanished without answering (dispatcher
            // panic): nothing to write for this slot.
            Err(_) => continue,
        };
        if broken {
            continue;
        }
        if write_frame(&mut stream, &resp.encode()).is_err() {
            broken = true;
            shared.stats.disconnects.fetch_add(1, Ordering::Relaxed);
        } else {
            shared.stats.responses.fetch_add(1, Ordering::Relaxed);
        }
    }
}

// ---------------------------------------------------------- dispatcher

/// Pop admitted requests, enforce the deadline again at dequeue, bridge
/// to the router, and resolve the connection's response slot.
fn dispatcher_loop(shared: &Arc<ServerShared>) {
    loop {
        let (item, depth) = match shared.queue.pop(Duration::from_millis(50)) {
            Pop::Closed => return,
            Pop::Empty => continue,
            Pop::Item { item, depth } => (item, depth),
        };
        shared.router.report_ingress(depth);
        let Admitted { arch, pixels, ticket, accepted, deadline, done } = item;
        if Instant::now() >= deadline {
            // Expired while queued: enforced here, at dequeue, as well
            // as estimated at admission.
            shared.record_expired(&arch);
            send_or_count_disconnect(shared, &done, ResponseFrame::Expired { ticket });
            continue;
        }
        if shared.stop.load(Ordering::Relaxed) {
            send_or_count_disconnect(
                shared,
                &done,
                ResponseFrame::Error {
                    ticket,
                    code: ErrorCode::Shutdown,
                    msg: "ingress server stopped before dispatch".to_string(),
                },
            );
            continue;
        }
        let t0 = Instant::now();
        let resp = match shared.router.submit(&arch, pixels) {
            Err(e) => ResponseFrame::Error {
                ticket,
                code: ErrorCode::Shutdown,
                msg: format!("{e:#}"),
            },
            Ok(rx) => match rx.recv() {
                Err(_) => ResponseFrame::Error {
                    ticket,
                    code: ErrorCode::Shutdown,
                    msg: "server stopped".to_string(),
                },
                Ok(Err(e)) => ResponseFrame::Error {
                    ticket,
                    code: ErrorCode::Backend,
                    msg: format!("{e:#}"),
                },
                Ok(Ok(r)) => {
                    shared.queue.record_service(t0.elapsed());
                    ResponseFrame::Ok {
                        ticket,
                        latency_us: accepted.elapsed().as_micros() as u64,
                        class: r.class as u16,
                        logits: r.logits,
                    }
                }
            },
        };
        send_or_count_disconnect(shared, &done, resp);
    }
}

fn send_or_count_disconnect(
    shared: &Arc<ServerShared>,
    done: &Sender<ResponseFrame>,
    resp: ResponseFrame,
) {
    if done.send(resp).is_err() {
        // The connection (and its writer) are gone; completing the work
        // for a vanished client is a counted no-op, exactly like the
        // router-level disconnect path.
        shared.stats.disconnects.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::coordinator::RouterConfig;
    use crate::data::{synth_batch, IMG_ELEMS, TEST_SEED};
    use crate::net::client::Client;
    use crate::net::protocol::{read_frame, MAGIC, MAX_RESPONSE_BYTES, VERSION};
    use crate::quant::{QTensor, Shape4};
    use crate::runtime::{BackendFactory, GoldenBackend, GoldenFactory, InferenceBackend};

    /// A backend that sleeps per batch and returns fixed logits — makes
    /// overload and expiry deterministic without golden compute cost.
    struct SlowBackend {
        delay: Duration,
    }

    impl InferenceBackend for SlowBackend {
        fn arch(&self) -> &str {
            "resnet8"
        }

        fn buckets(&self) -> &[usize] {
            &[1, 8]
        }

        fn infer_batch(&self, input: &QTensor) -> Result<QTensor> {
            std::thread::sleep(self.delay);
            let n = input.shape.n;
            Ok(QTensor::from_vec(Shape4::new(n, 1, 1, 10), 0, vec![0i32; n * 10]))
        }
    }

    struct SlowFactory {
        delay: Duration,
    }

    impl BackendFactory for SlowFactory {
        fn arch(&self) -> &str {
            "resnet8"
        }

        fn create(&self) -> Result<Box<dyn InferenceBackend>> {
            Ok(Box::new(SlowBackend { delay: self.delay }))
        }
    }

    fn start_slow(
        delay_ms: u64,
        cfg: ServerConfig,
    ) -> (Arc<Router>, IngressServer) {
        let router = Arc::new(
            Router::start(
                vec![Arc::new(SlowFactory { delay: Duration::from_millis(delay_ms) })],
                RouterConfig::default(),
            )
            .unwrap(),
        );
        let server = IngressServer::start(router.clone(), cfg).unwrap();
        (router, server)
    }

    fn addr_of(server: &IngressServer) -> String {
        format!("{}", server.local_addr())
    }

    #[test]
    fn loopback_round_trip_is_bit_exact_and_in_order() {
        let router = Arc::new(
            Router::start(
                vec![Arc::new(GoldenFactory::synthetic("resnet8", 7))],
                RouterConfig::default(),
            )
            .unwrap(),
        );
        let server = IngressServer::start(router.clone(), ServerConfig::default()).unwrap();
        let frames = 4usize;
        let (input, _) = synth_batch(0, frames, TEST_SEED);
        let golden = GoldenBackend::synthetic("resnet8", 7, &[frames]).unwrap();
        let want = golden.infer_batch(&input).unwrap();

        let mut client = Client::connect(&addr_of(&server)).unwrap();
        for i in 0..frames {
            let t = client
                .send("resnet8", 0, &input.data[i * IMG_ELEMS..(i + 1) * IMG_ELEMS])
                .unwrap();
            assert_eq!(t, (i + 1) as u64);
        }
        for i in 0..frames {
            match client.recv().unwrap() {
                ResponseFrame::Ok { ticket, logits, .. } => {
                    assert_eq!(ticket, (i + 1) as u64, "responses must arrive in order");
                    assert_eq!(
                        logits,
                        want.data[i * 10..(i + 1) * 10].to_vec(),
                        "frame {i}: wire logits must be bit-exact vs golden"
                    );
                }
                other => panic!("frame {i}: expected Ok, got {other:?}"),
            }
        }
        let snap = server.shutdown();
        assert_eq!(snap.accepted, frames as u64);
        assert_eq!(snap.responses, frames as u64);
        assert_eq!(snap.shed, 0);
        assert_eq!(snap.disconnects, 0);
        // Shed/expired counters surface through the router snapshot too.
        let rs = router.snapshot();
        assert_eq!(rs.total.shed, 0);
        assert_eq!(rs.total.requests, frames as u64);
    }

    #[test]
    fn overload_sheds_with_retry_hint_and_bounded_queue() {
        let (router, server) = start_slow(
            3,
            ServerConfig {
                queue_capacity: 4,
                dispatchers: 1,
                min_retry_after: Duration::from_millis(5),
                ..Default::default()
            },
        );
        let frames = 64usize;
        let pixels = vec![0i32; IMG_ELEMS];
        let mut client = Client::connect(&addr_of(&server)).unwrap();
        for _ in 0..frames {
            client.send("resnet8", 60_000, &pixels).unwrap();
        }
        let (mut oks, mut sheds) = (0usize, 0usize);
        for i in 0..frames {
            match client.recv().unwrap() {
                ResponseFrame::Ok { ticket, .. } => {
                    assert_eq!(ticket, (i + 1) as u64);
                    oks += 1;
                }
                ResponseFrame::Shed { ticket, retry_after_ms } => {
                    assert_eq!(ticket, (i + 1) as u64);
                    assert!(retry_after_ms >= 1, "shed must carry a retry-after hint");
                    sheds += 1;
                }
                other => panic!("unexpected response {other:?}"),
            }
        }
        assert_eq!(oks + sheds, frames, "every request is answered exactly once");
        assert!(sheds > 0, "a 16x overcommit against a 4-deep queue must shed");
        assert!(oks > 0, "the queue still serves what it admitted");
        let snap = server.shutdown();
        assert!(
            snap.queue_peak_depth <= 4,
            "admission queue exceeded its cap: {}",
            snap.queue_peak_depth
        );
        assert_eq!(snap.shed as usize, sheds);
        // The shed count flows into the router's serving metrics.
        let rs = router.snapshot();
        assert_eq!(rs.total.shed as usize, sheds);
        assert!(rs.total.shed_rate > 0.0);
    }

    #[test]
    fn queued_requests_expire_at_dequeue() {
        let (router, server) = start_slow(
            30,
            ServerConfig { queue_capacity: 16, dispatchers: 1, ..Default::default() },
        );
        let pixels = vec![0i32; IMG_ELEMS];
        let mut client = Client::connect(&addr_of(&server)).unwrap();
        // One long-deadline request occupies the single dispatcher for
        // ~30 ms; three 5 ms-deadline requests queue behind it and must
        // be expired at dispatch (no service history yet, so admission
        // cannot predict the wait).
        client.send("resnet8", 1_000, &pixels).unwrap();
        for _ in 0..3 {
            client.send("resnet8", 5, &pixels).unwrap();
        }
        assert!(matches!(client.recv().unwrap(), ResponseFrame::Ok { ticket: 1, .. }));
        for i in 0..3 {
            match client.recv().unwrap() {
                ResponseFrame::Expired { ticket } => assert_eq!(ticket, (i + 2) as u64),
                other => panic!("expected Expired, got {other:?}"),
            }
        }
        let snap = server.shutdown();
        assert_eq!(snap.expired, 3);
        let rs = router.snapshot();
        assert_eq!(rs.total.deadline_expired, 3);
        // Only the executed request reached the router.
        assert_eq!(rs.total.requests, 1);
    }

    #[test]
    fn malformed_frames_get_typed_errors_and_do_not_kill_the_server() {
        use std::io::Write;
        let (_router, server) = start_slow(0, ServerConfig::default());
        let addr = addr_of(&server);

        // Bad magic: typed error, connection survives, a valid request
        // on the same socket still works.
        {
            let mut raw = TcpStream::connect(&addr).unwrap();
            let mut bad = RequestFrame {
                arch: "resnet8".into(),
                deadline_ms: 0,
                pixels: vec![0; IMG_ELEMS],
            }
            .encode();
            bad[0] ^= 0xFF;
            write_frame(&mut raw, &bad).unwrap();
            let body = read_frame(&mut raw, MAX_RESPONSE_BYTES).unwrap().unwrap();
            match ResponseFrame::decode(&body).unwrap() {
                ResponseFrame::Error { ticket: 1, code: ErrorCode::BadRequest, msg } => {
                    assert!(msg.contains("magic"), "{msg}");
                }
                other => panic!("expected BadRequest error, got {other:?}"),
            }
            let good = RequestFrame {
                arch: "resnet8".into(),
                deadline_ms: 0,
                pixels: vec![0; IMG_ELEMS],
            }
            .encode();
            write_frame(&mut raw, &good).unwrap();
            let body = read_frame(&mut raw, MAX_RESPONSE_BYTES).unwrap().unwrap();
            assert!(matches!(
                ResponseFrame::decode(&body).unwrap(),
                ResponseFrame::Ok { ticket: 2, .. }
            ));
        }

        // Unknown arch: typed error.
        {
            let mut raw = TcpStream::connect(&addr).unwrap();
            let req = RequestFrame {
                arch: "resnet99".into(),
                deadline_ms: 0,
                pixels: vec![0; IMG_ELEMS],
            };
            write_frame(&mut raw, &req.encode()).unwrap();
            let body = read_frame(&mut raw, MAX_RESPONSE_BYTES).unwrap().unwrap();
            assert!(matches!(
                ResponseFrame::decode(&body).unwrap(),
                ResponseFrame::Error { code: ErrorCode::UnknownArch, .. }
            ));
        }

        // Oversized length prefix: typed error, then the server closes
        // this connection (framing is no longer trustworthy)...
        {
            let mut raw = TcpStream::connect(&addr).unwrap();
            raw.write_all(&(u32::MAX).to_le_bytes()).unwrap();
            raw.flush().unwrap();
            let body = read_frame(&mut raw, MAX_RESPONSE_BYTES).unwrap().unwrap();
            assert!(matches!(
                ResponseFrame::decode(&body).unwrap(),
                ResponseFrame::Error { code: ErrorCode::BadRequest, .. }
            ));
            assert!(read_frame(&mut raw, MAX_RESPONSE_BYTES).unwrap().is_none());
        }

        // ...and a fresh connection is still served: no panic wedged
        // the acceptor or dispatchers.
        let mut client = Client::connect(&addr).unwrap();
        let resp = client.request("resnet8", 0, &vec![0i32; IMG_ELEMS]).unwrap();
        assert!(matches!(resp, ResponseFrame::Ok { .. }));
        let snap = server.shutdown();
        assert_eq!(snap.bad_frames, 3);
        // Sanity on the wire constants used above.
        assert_eq!(MAGIC.to_le_bytes()[0], b'S');
        assert_eq!(VERSION, 1);
    }

    #[test]
    fn shutdown_answers_everything_already_queued() {
        let (_router, server) = start_slow(
            20,
            ServerConfig { queue_capacity: 16, dispatchers: 1, ..Default::default() },
        );
        let pixels = vec![0i32; IMG_ELEMS];
        let mut client = Client::connect(&addr_of(&server)).unwrap();
        let frames = 6usize;
        for _ in 0..frames {
            client.send("resnet8", 60_000, &pixels).unwrap();
        }
        // Give the first request a moment to reach the dispatcher, then
        // shut down with the rest still queued.
        std::thread::sleep(Duration::from_millis(10));
        let snap = server.shutdown();
        // Every admitted request was answered: as Ok (already
        // dispatched), or with the typed shutdown error.
        let mut got = 0usize;
        loop {
            match client.recv() {
                Ok(resp) => {
                    got += 1;
                    assert!(matches!(
                        resp,
                        ResponseFrame::Ok { .. }
                            | ResponseFrame::Error { code: ErrorCode::Shutdown, .. }
                            | ResponseFrame::Shed { .. }
                    ));
                }
                Err(WireError::Closed) => break,
                Err(e) => panic!("client read failed: {e}"),
            }
        }
        assert_eq!(got, frames, "shutdown must answer every request, got {got}");
        assert_eq!(snap.responses, frames as u64);
    }
}
