//! Blocking TCP client for the ingress protocol, plus the shared
//! traffic driver used by the example client, the `client` subcommand,
//! the soak bench and the integration tests.
//!
//! [`Client`] is deliberately thin: connect, send a frame, receive the
//! next in-order response.  [`drive`] layers a paced closed-ish loop on
//! top — at most `window` requests outstanding, optional target FPS —
//! and returns a [`DriveReport`] with client-observed latency
//! percentiles, shed/expiry accounting and an ordering check, so every
//! caller asserts the same invariants the ISSUE's soak criteria name.

use std::collections::VecDeque;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::data::{synth_batch, IMG_ELEMS, TEST_SEED};

use super::protocol::{
    read_frame, write_frame, RequestFrame, ResponseFrame, WireError, MAX_RESPONSE_BYTES,
};

/// A blocking ingress-protocol client over one TCP connection.
pub struct Client {
    stream: TcpStream,
    next_ticket: u64,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client, WireError> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(Client { stream, next_ticket: 0 })
    }

    /// Send one request; returns the ticket the server will answer it
    /// with (tickets are per-connection, 1-based, in send order).
    pub fn send(&mut self, arch: &str, deadline_ms: u32, pixels: &[i32]) -> Result<u64, WireError> {
        let body = RequestFrame {
            arch: arch.to_string(),
            deadline_ms,
            pixels: pixels.to_vec(),
        }
        .encode();
        write_frame(&mut self.stream, &body)?;
        self.next_ticket += 1;
        Ok(self.next_ticket)
    }

    /// Receive the next response (they arrive in ticket order).
    /// A clean server-side close is [`WireError::Closed`].
    pub fn recv(&mut self) -> Result<ResponseFrame, WireError> {
        match read_frame(&mut self.stream, MAX_RESPONSE_BYTES)? {
            Some(body) => ResponseFrame::decode(&body),
            None => Err(WireError::Closed),
        }
    }

    /// Blocking convenience: send one request and wait for its answer.
    pub fn request(
        &mut self,
        arch: &str,
        deadline_ms: u32,
        pixels: &[i32],
    ) -> Result<ResponseFrame, WireError> {
        self.send(arch, deadline_ms, pixels)?;
        self.recv()
    }
}

/// Traffic-driver parameters.
#[derive(Debug, Clone)]
pub struct DriveConfig {
    /// Server address, `host:port`.
    pub addr: String,
    pub arch: String,
    /// Frames to send (synthetic CIFAR-10, deterministic).
    pub frames: usize,
    /// Target send rate; 0.0 = open loop (as fast as the window allows).
    pub fps: f64,
    /// Per-request deadline (0 = server default).
    pub deadline_ms: u32,
    /// Maximum outstanding (pipelined) requests on the connection.
    pub window: usize,
}

impl Default for DriveConfig {
    fn default() -> Self {
        DriveConfig {
            addr: "127.0.0.1:7433".to_string(),
            arch: "resnet8".to_string(),
            frames: 256,
            fps: 0.0,
            deadline_ms: 0,
            window: 8,
        }
    }
}

/// What one [`drive`] run observed, from the client's side of the wire.
#[derive(Debug, Clone)]
pub struct DriveReport {
    pub sent: usize,
    pub oks: usize,
    pub sheds: usize,
    pub expired: usize,
    pub errors: usize,
    /// Responses whose ticket did not match the oldest outstanding
    /// request — must stay 0 (the protocol guarantees per-connection
    /// ordering).
    pub out_of_order: usize,
    /// Shed responses carrying a zero retry-after hint — must stay 0.
    pub sheds_without_hint: usize,
    /// Client-observed latency of OK responses, microseconds.
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
    pub wall: Duration,
}

impl DriveReport {
    /// Every request answered exactly once, in order, and every shed
    /// carried a retry hint — the smoke/soak acceptance predicate.
    pub fn accounted(&self) -> bool {
        self.oks + self.sheds + self.expired + self.errors == self.sent
            && self.out_of_order == 0
            && self.sheds_without_hint == 0
    }

    /// Fraction of sent requests that were shed.
    pub fn shed_rate(&self) -> f64 {
        if self.sent == 0 {
            0.0
        } else {
            self.sheds as f64 / self.sent as f64
        }
    }

    /// Achieved OK throughput in frames/second.
    pub fn ok_fps(&self) -> f64 {
        let s = self.wall.as_secs_f64();
        if s > 0.0 {
            self.oks as f64 / s
        } else {
            0.0
        }
    }
}

impl std::fmt::Display for DriveReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "sent {}  ok {}  shed {} ({:.1}%)  expired {}  err {}  \
             lat p50 {}us p95 {}us p99 {}us max {}us  wall {:.2}s  {:.0} ok-fps",
            self.sent,
            self.oks,
            self.sheds,
            self.shed_rate() * 100.0,
            self.expired,
            self.errors,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.max_us,
            self.wall.as_secs_f64(),
            self.ok_fps()
        )
    }
}

/// Exact percentile over observed samples (nearest-rank; 0 when empty).
fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((sorted.len() as f64 * p).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Stream deterministic synthetic CIFAR frames at the configured pace
/// and account for every response.
pub fn drive(cfg: &DriveConfig) -> Result<DriveReport, WireError> {
    let mut client = Client::connect(&cfg.addr)?;
    let window = cfg.window.max(1);
    // A modest pool of distinct frames, cycled — enough to exercise the
    // wire without regenerating pixels per request.
    let pool = cfg.frames.clamp(1, 64);
    let (batch, _labels) = synth_batch(0, pool, TEST_SEED);
    let mut report = DriveReport {
        sent: 0,
        oks: 0,
        sheds: 0,
        expired: 0,
        errors: 0,
        out_of_order: 0,
        sheds_without_hint: 0,
        p50_us: 0,
        p95_us: 0,
        p99_us: 0,
        max_us: 0,
        wall: Duration::ZERO,
    };
    let mut latencies: Vec<u64> = Vec::with_capacity(cfg.frames);
    let mut inflight: VecDeque<(u64, Instant)> = VecDeque::with_capacity(window);
    let start = Instant::now();
    for i in 0..cfg.frames {
        if cfg.fps > 0.0 {
            let due = start + Duration::from_secs_f64(i as f64 / cfg.fps);
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
        }
        while inflight.len() >= window {
            recv_one(&mut client, &mut inflight, &mut report, &mut latencies)?;
        }
        let fi = i % pool;
        let ticket =
            client.send(&cfg.arch, cfg.deadline_ms, &batch.data[fi * IMG_ELEMS..(fi + 1) * IMG_ELEMS])?;
        report.sent += 1;
        inflight.push_back((ticket, Instant::now()));
    }
    while !inflight.is_empty() {
        recv_one(&mut client, &mut inflight, &mut report, &mut latencies)?;
    }
    report.wall = start.elapsed();
    latencies.sort_unstable();
    report.p50_us = percentile(&latencies, 0.50);
    report.p95_us = percentile(&latencies, 0.95);
    report.p99_us = percentile(&latencies, 0.99);
    report.max_us = latencies.last().copied().unwrap_or(0);
    Ok(report)
}

fn recv_one(
    client: &mut Client,
    inflight: &mut VecDeque<(u64, Instant)>,
    report: &mut DriveReport,
    latencies: &mut Vec<u64>,
) -> Result<(), WireError> {
    let resp = client.recv()?;
    // Both call sites guard on a non-empty window, but a response with
    // nothing outstanding (a server double-answer) must surface as a
    // protocol error, not a client panic.
    let Some((want_ticket, sent_at)) = inflight.pop_front() else {
        return Err(WireError::Protocol("response with no outstanding request"));
    };
    if resp.ticket() != want_ticket {
        report.out_of_order += 1;
    }
    match resp {
        ResponseFrame::Ok { .. } => {
            report.oks += 1;
            latencies.push(sent_at.elapsed().as_micros() as u64);
        }
        ResponseFrame::Shed { retry_after_ms, .. } => {
            report.sheds += 1;
            if retry_after_ms == 0 {
                report.sheds_without_hint += 1;
            }
        }
        ResponseFrame::Expired { .. } => report.expired += 1,
        ResponseFrame::Error { .. } => report.errors += 1,
    }
    Ok(())
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn percentile_is_nearest_rank() {
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[7], 0.5), 7);
        let s: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&s, 0.50), 50);
        assert_eq!(percentile(&s, 0.95), 95);
        assert_eq!(percentile(&s, 0.99), 99);
        assert_eq!(percentile(&s, 1.0), 100);
    }

    #[test]
    fn report_accounting_predicate() {
        let mut r = DriveReport {
            sent: 10,
            oks: 6,
            sheds: 3,
            expired: 1,
            errors: 0,
            out_of_order: 0,
            sheds_without_hint: 0,
            p50_us: 0,
            p95_us: 0,
            p99_us: 0,
            max_us: 0,
            wall: Duration::from_secs(1),
        };
        assert!(r.accounted());
        assert!((r.shed_rate() - 0.3).abs() < 1e-9);
        r.out_of_order = 1;
        assert!(!r.accounted());
        r.out_of_order = 0;
        r.errors = 1;
        assert!(!r.accounted(), "over-answered runs must fail accounting");
    }
}
