//! Bounded admission ahead of the router: explicit load-shedding instead
//! of unbounded queueing.
//!
//! The queue is the only buffering stage between the socket readers and
//! the dispatcher threads that feed [`crate::coordinator::Router`], and
//! it is *bounded*: when it is full, or when the waiting work ahead of a
//! request makes its deadline infeasible (estimated from an EWMA of
//! measured service times), [`AdmissionQueue::offer`] hands the item
//! back with a SHED decision and a retry-after hint — the caller replies
//! on the wire instead of queueing.  Deadlines are enforced a second
//! time at dequeue by the dispatchers (a request can expire while
//! queued), so an accepted-then-stale frame is dropped before it wastes
//! backend work.
//!
//! Depth and peak-depth gauges are exported so the server can (a) feed
//! the ingress depth into the router's `load_hint` path — closing the
//! socket-to-replica elastic loop — and (b) let the soak tests assert
//! the queue really never exceeds its cap.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Admission policy knobs.
#[derive(Debug, Clone)]
pub struct AdmissionConfig {
    /// Maximum queued requests; offers beyond this shed.
    pub capacity: usize,
    /// Dispatcher threads draining the queue (used by the wait
    /// estimate: `depth * service / dispatchers`).
    pub dispatchers: usize,
    /// Floor for the retry-after hint on shed responses.
    pub min_retry_after: Duration,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            capacity: 64,
            dispatchers: 2,
            min_retry_after: Duration::from_millis(5),
        }
    }
}

/// Why an offer was shed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedReason {
    /// The queue is at capacity.
    QueueFull,
    /// The estimated queue wait already exceeds the request's remaining
    /// deadline — executing it would only produce a late answer.
    DeadlineInfeasible,
}

/// Outcome of an [`AdmissionQueue::offer`].
pub enum Offer<T> {
    /// Queued; `depth` is the post-push queue depth (for gauges).
    Admitted { depth: usize },
    /// Shed: the item is handed back with a retry-after hint.
    Shed { item: T, reason: ShedReason, retry_after: Duration },
}

/// Outcome of an [`AdmissionQueue::pop`].
pub enum Pop<T> {
    Item { item: T, depth: usize },
    /// Nothing arrived within the timeout; the queue is still open.
    Empty,
    /// Closed and fully drained — the dispatcher should exit.
    Closed,
}

struct QState<T> {
    q: VecDeque<T>,
    open: bool,
}

/// The bounded, sheddable ingress queue.
pub struct AdmissionQueue<T> {
    state: Mutex<QState<T>>,
    cv: Condvar,
    cfg: AdmissionConfig,
    depth: AtomicUsize,
    peak_depth: AtomicUsize,
    /// EWMA of measured dispatch-to-response service time, microseconds.
    /// 0 until the first observation.
    est_service_us: AtomicU64,
}

impl<T> AdmissionQueue<T> {
    pub fn new(cfg: AdmissionConfig) -> AdmissionQueue<T> {
        AdmissionQueue {
            state: Mutex::new(QState { q: VecDeque::new(), open: true }),
            cv: Condvar::new(),
            cfg: AdmissionConfig {
                capacity: cfg.capacity.max(1),
                dispatchers: cfg.dispatchers.max(1),
                ..cfg
            },
            depth: AtomicUsize::new(0),
            peak_depth: AtomicUsize::new(0),
            est_service_us: AtomicU64::new(0),
        }
    }

    /// Current queue depth (gauge; exported to `load_hint`).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Highest depth ever observed — the soak tests assert this never
    /// exceeds the configured capacity.
    pub fn peak_depth(&self) -> usize {
        self.peak_depth.load(Ordering::Relaxed)
    }

    /// The queue capacity (cap on `peak_depth`).
    pub fn capacity(&self) -> usize {
        self.cfg.capacity
    }

    /// Record a measured service time (dispatch to backend response);
    /// feeds the deadline-feasibility estimate as an EWMA (alpha 1/8).
    pub fn record_service(&self, service: Duration) {
        let obs = (service.as_micros() as u64).max(1);
        let old = self.est_service_us.load(Ordering::Relaxed);
        let new = if old == 0 { obs } else { (old * 7 + obs) / 8 };
        self.est_service_us.store(new, Ordering::Relaxed);
    }

    /// Estimated wait for a request entering at `depth`, from the
    /// service EWMA and the dispatcher count.  Zero until the first
    /// service observation (the estimate fails open: with no history,
    /// only a full queue sheds).
    pub fn estimated_wait(&self, depth: usize) -> Duration {
        let est = self.est_service_us.load(Ordering::Relaxed);
        Duration::from_micros(est * (depth as u64) / self.cfg.dispatchers as u64)
    }

    fn retry_after(&self, depth: usize) -> Duration {
        self.estimated_wait(depth.max(1)).max(self.cfg.min_retry_after)
    }

    /// Offer one request: queue it, or shed with a retry-after hint when
    /// the queue is full / the deadline cannot be met.  Never blocks.
    pub fn offer(&self, item: T, remaining_deadline: Duration) -> Offer<T> {
        let mut st = match self.state.lock() {
            Ok(g) => g,
            // A poisoned queue lock means a dispatcher panicked; shed
            // rather than propagate the panic into the reader thread.
            Err(p) => p.into_inner(),
        };
        if !st.open || st.q.len() >= self.cfg.capacity {
            let retry = self.retry_after(self.cfg.capacity);
            return Offer::Shed { item, reason: ShedReason::QueueFull, retry_after: retry };
        }
        let wait = self.estimated_wait(st.q.len() + 1);
        if !wait.is_zero() && wait > remaining_deadline {
            let retry = self.retry_after(st.q.len() + 1);
            return Offer::Shed {
                item,
                reason: ShedReason::DeadlineInfeasible,
                retry_after: retry,
            };
        }
        st.q.push_back(item);
        let depth = st.q.len();
        drop(st);
        self.depth.store(depth, Ordering::Relaxed);
        self.peak_depth.fetch_max(depth, Ordering::Relaxed);
        self.cv.notify_one();
        Offer::Admitted { depth }
    }

    /// Pop the oldest request, waiting up to `timeout`.  After
    /// [`close`](Self::close), the remaining items keep draining and
    /// `Closed` is returned once the queue is empty.
    pub fn pop(&self, timeout: Duration) -> Pop<T> {
        let mut st = match self.state.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if st.q.is_empty() && st.open {
            let (g, _) = match self.cv.wait_timeout(st, timeout) {
                Ok(r) => r,
                Err(p) => {
                    let (g, t) = p.into_inner();
                    (g, t)
                }
            };
            st = g;
        }
        match st.q.pop_front() {
            Some(item) => {
                let depth = st.q.len();
                drop(st);
                self.depth.store(depth, Ordering::Relaxed);
                Pop::Item { item, depth }
            }
            None => {
                if st.open {
                    Pop::Empty
                } else {
                    Pop::Closed
                }
            }
        }
    }

    /// Stop accepting offers (they shed from now on); queued items keep
    /// draining through `pop`, which reports `Closed` once empty.
    pub fn close(&self) {
        let mut st = match self.state.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        st.open = false;
        drop(st);
        self.cv.notify_all();
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    fn q(capacity: usize) -> AdmissionQueue<u32> {
        AdmissionQueue::new(AdmissionConfig {
            capacity,
            dispatchers: 1,
            min_retry_after: Duration::from_millis(3),
        })
    }

    #[test]
    fn sheds_when_full_with_retry_hint() {
        let queue = q(2);
        let d = Duration::from_secs(1);
        assert!(matches!(queue.offer(1, d), Offer::Admitted { depth: 1 }));
        assert!(matches!(queue.offer(2, d), Offer::Admitted { depth: 2 }));
        match queue.offer(3, d) {
            Offer::Shed { item, reason, retry_after } => {
                assert_eq!(item, 3);
                assert_eq!(reason, ShedReason::QueueFull);
                // No service history yet: the hint falls back to the floor.
                assert_eq!(retry_after, Duration::from_millis(3));
            }
            Offer::Admitted { .. } => panic!("full queue must shed"),
        }
        assert_eq!(queue.depth(), 2);
        assert_eq!(queue.peak_depth(), 2);
        // Draining frees a slot.
        assert!(matches!(queue.pop(Duration::ZERO), Pop::Item { item: 1, depth: 1 }));
        assert!(matches!(queue.offer(4, d), Offer::Admitted { depth: 2 }));
        assert_eq!(queue.peak_depth(), 2, "peak never exceeded the cap");
    }

    #[test]
    fn sheds_infeasible_deadlines_once_calibrated() {
        let queue = q(16);
        // 10 ms measured service; one dispatcher.
        queue.record_service(Duration::from_millis(10));
        assert!(matches!(queue.offer(1, Duration::from_secs(1)), Offer::Admitted { .. }));
        // Entering at depth 2 means ~20 ms of wait; a 5 ms deadline is
        // infeasible and sheds with a calibrated (not floor) hint.
        match queue.offer(2, Duration::from_millis(5)) {
            Offer::Shed { reason, retry_after, .. } => {
                assert_eq!(reason, ShedReason::DeadlineInfeasible);
                assert!(retry_after >= Duration::from_millis(10), "{retry_after:?}");
            }
            Offer::Admitted { .. } => panic!("infeasible deadline must shed"),
        }
        // A generous deadline still gets in.
        assert!(matches!(queue.offer(3, Duration::from_secs(1)), Offer::Admitted { .. }));
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let queue = q(4);
        assert!(matches!(queue.offer(1, Duration::from_secs(1)), Offer::Admitted { .. }));
        queue.close();
        // Offers shed once closed.
        assert!(matches!(
            queue.offer(2, Duration::from_secs(1)),
            Offer::Shed { reason: ShedReason::QueueFull, .. }
        ));
        // Remaining items drain, then Closed.
        assert!(matches!(queue.pop(Duration::ZERO), Pop::Item { item: 1, .. }));
        assert!(matches!(queue.pop(Duration::ZERO), Pop::Closed));
    }

    #[test]
    fn ewma_tracks_service_observations() {
        let queue = q(4);
        assert!(queue.estimated_wait(4).is_zero(), "fails open with no history");
        queue.record_service(Duration::from_millis(8));
        assert_eq!(queue.estimated_wait(1), Duration::from_millis(8));
        for _ in 0..64 {
            queue.record_service(Duration::from_millis(2));
        }
        let est = queue.estimated_wait(1);
        assert!(est < Duration::from_millis(4), "EWMA must converge down: {est:?}");
        assert!(est >= Duration::from_millis(2));
    }
}
