//! The ingress wire protocol: length-prefixed binary frames.
//!
//! Every frame on the wire is a little-endian `u32` body length followed
//! by the body.  A request body carries magic, protocol version, the
//! architecture name, a per-request deadline and the raw `i32` pixel
//! payload; a response body carries the connection-ordered ticket and a
//! status-specific tail (logits, a retry-after hint, or a typed error
//! code).  Responses on one connection are always written in request
//! (ticket) order, so a pipelining client needs no reordering buffer.
//!
//! Request body layout (after the `u32` length prefix):
//!
//! | offset | size | field                                          |
//! |--------|------|------------------------------------------------|
//! | 0      | 4    | magic `0x5248_4C53` ("RHLS")                   |
//! | 4      | 1    | version (currently 1)                          |
//! | 5      | 1    | arch name length `L` (<= 64)                   |
//! | 6      | L    | arch name, UTF-8                               |
//! | 6+L    | 4    | deadline_ms (0 = server default)               |
//! | 10+L   | 4    | pixel count (must equal `IMG_ELEMS`)           |
//! | 14+L   | 4n   | pixels, `i32` each                             |
//!
//! Response body layout:
//!
//! | offset | size | field                                          |
//! |--------|------|------------------------------------------------|
//! | 0      | 4    | magic                                          |
//! | 4      | 1    | version                                        |
//! | 5      | 1    | status: 0 OK, 1 SHED, 2 EXPIRED, 3 ERROR       |
//! | 6      | 8    | ticket (per-connection, 1-based, in order)     |
//! | 14     | ...  | status tail (see [`ResponseFrame`])            |
//!
//! Decoding malformed input never panics: every failure is a typed
//! [`WireError`], property-tested in this module and injection-tested
//! over a real socket in the server tests.

use std::io::{self, Read, Write};

use crate::data::IMG_ELEMS;

/// Frame magic: `"RHLS"` read as a little-endian `u32`.
pub const MAGIC: u32 = 0x5248_4C53;
/// Protocol version this build speaks.
pub const VERSION: u8 = 1;
/// Longest accepted architecture name.
pub const MAX_ARCH_LEN: usize = 64;

/// Largest legal request body: the fixed header at the longest arch name
/// plus the full pixel payload.  Anything larger is rejected from the
/// length prefix alone, before any allocation.
pub const MAX_REQUEST_BYTES: usize = 14 + MAX_ARCH_LEN + 4 * IMG_ELEMS;
/// Largest legal response body (OK status with a full logits row; the
/// bound is generous so richer tails fit without a version bump).
pub const MAX_RESPONSE_BYTES: usize = 14 + 8 + 2 + 2 + 4 * 1024;

/// Typed wire-protocol failure.  `Io` wraps transport errors from the
/// framed read/write helpers; everything else is a malformed or
/// out-of-contract frame.
#[derive(Debug)]
pub enum WireError {
    /// The stream ended (or the body was shorter than its fields claim).
    Truncated { need: usize, have: usize },
    /// Length prefix above the per-direction cap.
    Oversized { len: usize, max: usize },
    /// First four body bytes were not [`MAGIC`].
    BadMagic(u32),
    /// Version byte this build does not speak.
    BadVersion(u8),
    /// Arch name too long or not UTF-8.
    BadArchName,
    /// Pixel count other than the `IMG_ELEMS` contract.
    BadPixelCount { got: usize, want: usize },
    /// Unknown response status byte.
    BadStatus(u8),
    /// Unknown typed error code in an ERROR response.
    BadErrorCode(u8),
    /// The peer closed the connection at a frame boundary.
    Closed,
    /// The peer violated the request/response protocol (e.g. a response
    /// arrived with no request outstanding).
    Protocol(&'static str),
    /// Transport failure underneath the framing.
    Io(io::Error),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} bytes, have {have}")
            }
            WireError::Oversized { len, max } => {
                write!(f, "oversized frame: {len} bytes (max {max})")
            }
            WireError::BadMagic(m) => write!(f, "bad magic {m:#010x} (want {MAGIC:#010x})"),
            WireError::BadVersion(v) => write!(f, "unsupported protocol version {v}"),
            WireError::BadArchName => write!(f, "bad arch name (too long or not UTF-8)"),
            WireError::BadPixelCount { got, want } => {
                write!(f, "bad pixel count {got} (want {want})")
            }
            WireError::BadStatus(s) => write!(f, "unknown response status {s}"),
            WireError::BadErrorCode(c) => write!(f, "unknown error code {c}"),
            WireError::Closed => write!(f, "connection closed"),
            WireError::Protocol(what) => write!(f, "protocol violation: {what}"),
            WireError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<io::Error> for WireError {
    fn from(e: io::Error) -> WireError {
        WireError::Io(e)
    }
}

/// Typed server-side error codes carried by ERROR responses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Request frame failed to decode (bad magic/version/fields); the
    /// response message carries the detail.
    BadRequest = 1,
    /// No backend pool for the requested architecture.
    UnknownArch = 2,
    /// The backend failed the request (typed router/pool error text).
    Backend = 3,
    /// The server is shutting down; the request was not executed.
    Shutdown = 4,
}

impl ErrorCode {
    pub fn from_u8(v: u8) -> Result<ErrorCode, WireError> {
        match v {
            1 => Ok(ErrorCode::BadRequest),
            2 => Ok(ErrorCode::UnknownArch),
            3 => Ok(ErrorCode::Backend),
            4 => Ok(ErrorCode::Shutdown),
            other => Err(WireError::BadErrorCode(other)),
        }
    }
}

/// A decoded inference request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RequestFrame {
    pub arch: String,
    /// Client deadline in milliseconds; 0 defers to the server default.
    pub deadline_ms: u32,
    /// `(32, 32, 3)` int8-valued pixels @ 2^-7, NHWC flattened.
    pub pixels: Vec<i32>,
}

/// A decoded response.  `ticket` is the server-assigned per-connection
/// sequence number (1-based); responses arrive in ticket order.
#[derive(Debug, Clone, PartialEq)]
pub enum ResponseFrame {
    Ok { ticket: u64, latency_us: u64, class: u16, logits: Vec<i32> },
    /// Load-shed at admission: not executed; retry after the hint.
    Shed { ticket: u64, retry_after_ms: u32 },
    /// Deadline already expired (at admission or at dispatch); dropped.
    Expired { ticket: u64 },
    Error { ticket: u64, code: ErrorCode, msg: String },
}

impl ResponseFrame {
    pub fn ticket(&self) -> u64 {
        match self {
            ResponseFrame::Ok { ticket, .. }
            | ResponseFrame::Shed { ticket, .. }
            | ResponseFrame::Expired { ticket }
            | ResponseFrame::Error { ticket, .. } => *ticket,
        }
    }
}

// ------------------------------------------------------------ encoding

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

impl RequestFrame {
    /// Encode the body (no length prefix; see [`write_frame`]).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(14 + self.arch.len() + 4 * self.pixels.len());
        put_u32(&mut out, MAGIC);
        out.push(VERSION);
        debug_assert!(self.arch.len() <= MAX_ARCH_LEN);
        out.push(self.arch.len().min(MAX_ARCH_LEN) as u8);
        out.extend_from_slice(&self.arch.as_bytes()[..self.arch.len().min(MAX_ARCH_LEN)]);
        put_u32(&mut out, self.deadline_ms);
        put_u32(&mut out, self.pixels.len() as u32);
        for p in &self.pixels {
            put_u32(&mut out, *p as u32);
        }
        out
    }

    /// Decode a request body.  Never panics on malformed input.
    pub fn decode(body: &[u8]) -> Result<RequestFrame, WireError> {
        let mut c = Cursor { buf: body, pos: 0 };
        let magic = c.take_u32()?;
        if magic != MAGIC {
            return Err(WireError::BadMagic(magic));
        }
        let version = c.take_u8()?;
        if version != VERSION {
            return Err(WireError::BadVersion(version));
        }
        let arch_len = c.take_u8()? as usize;
        if arch_len > MAX_ARCH_LEN {
            return Err(WireError::BadArchName);
        }
        let arch = std::str::from_utf8(c.take_bytes(arch_len)?)
            .map_err(|_| WireError::BadArchName)?
            .to_string();
        let deadline_ms = c.take_u32()?;
        let n = c.take_u32()? as usize;
        if n != IMG_ELEMS {
            return Err(WireError::BadPixelCount { got: n, want: IMG_ELEMS });
        }
        let mut pixels = Vec::with_capacity(n);
        for _ in 0..n {
            pixels.push(c.take_u32()? as i32);
        }
        c.finish()?;
        Ok(RequestFrame { arch, deadline_ms, pixels })
    }
}

impl ResponseFrame {
    /// Encode the body (no length prefix; see [`write_frame`]).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(64);
        put_u32(&mut out, MAGIC);
        out.push(VERSION);
        match self {
            ResponseFrame::Ok { ticket, latency_us, class, logits } => {
                out.push(0);
                put_u64(&mut out, *ticket);
                put_u64(&mut out, *latency_us);
                put_u16(&mut out, *class);
                put_u16(&mut out, logits.len() as u16);
                for l in logits {
                    put_u32(&mut out, *l as u32);
                }
            }
            ResponseFrame::Shed { ticket, retry_after_ms } => {
                out.push(1);
                put_u64(&mut out, *ticket);
                put_u32(&mut out, *retry_after_ms);
            }
            ResponseFrame::Expired { ticket } => {
                out.push(2);
                put_u64(&mut out, *ticket);
            }
            ResponseFrame::Error { ticket, code, msg } => {
                out.push(3);
                put_u64(&mut out, *ticket);
                out.push(*code as u8);
                let msg = &msg.as_bytes()[..msg.len().min(u16::MAX as usize)];
                put_u16(&mut out, msg.len() as u16);
                out.extend_from_slice(msg);
            }
        }
        out
    }

    /// Decode a response body.  Never panics on malformed input.
    pub fn decode(body: &[u8]) -> Result<ResponseFrame, WireError> {
        let mut c = Cursor { buf: body, pos: 0 };
        let magic = c.take_u32()?;
        if magic != MAGIC {
            return Err(WireError::BadMagic(magic));
        }
        let version = c.take_u8()?;
        if version != VERSION {
            return Err(WireError::BadVersion(version));
        }
        let status = c.take_u8()?;
        let ticket = c.take_u64()?;
        let frame = match status {
            0 => {
                let latency_us = c.take_u64()?;
                let class = c.take_u16()?;
                let n = c.take_u16()? as usize;
                let mut logits = Vec::with_capacity(n);
                for _ in 0..n {
                    logits.push(c.take_u32()? as i32);
                }
                ResponseFrame::Ok { ticket, latency_us, class, logits }
            }
            1 => ResponseFrame::Shed { ticket, retry_after_ms: c.take_u32()? },
            2 => ResponseFrame::Expired { ticket },
            3 => {
                let code = ErrorCode::from_u8(c.take_u8()?)?;
                let n = c.take_u16()? as usize;
                let msg = std::str::from_utf8(c.take_bytes(n)?)
                    .map_err(|_| WireError::BadArchName)?
                    .to_string();
                ResponseFrame::Error { ticket, code, msg }
            }
            other => return Err(WireError::BadStatus(other)),
        };
        c.finish()?;
        Ok(frame)
    }
}

/// Bounds-checked little-endian reader over a frame body.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take_bytes(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.buf.len() - self.pos < n {
            return Err(WireError::Truncated {
                need: self.pos + n,
                have: self.buf.len(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn take_u8(&mut self) -> Result<u8, WireError> {
        Ok(self.take_bytes(1)?[0])
    }

    fn take_u16(&mut self) -> Result<u16, WireError> {
        let b = self.take_bytes(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn take_u32(&mut self) -> Result<u32, WireError> {
        let b = self.take_bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn take_u64(&mut self) -> Result<u64, WireError> {
        let b = self.take_bytes(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    /// Trailing bytes after the last field are a framing bug on the
    /// peer's side — reject them rather than silently ignoring.
    fn finish(&self) -> Result<(), WireError> {
        if self.pos != self.buf.len() {
            return Err(WireError::Truncated { need: self.pos, have: self.buf.len() });
        }
        Ok(())
    }
}

// ------------------------------------------------------------- framing

/// Write one length-prefixed frame.
pub fn write_frame(w: &mut impl Write, body: &[u8]) -> io::Result<()> {
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Read one length-prefixed frame body, bounded by `max` bytes.
///
/// Returns `Ok(None)` on a clean close (EOF exactly at a frame
/// boundary); EOF inside a frame is [`WireError::Truncated`]; a length
/// prefix above `max` is [`WireError::Oversized`] and is rejected before
/// any payload allocation.
pub fn read_frame(r: &mut impl Read, max: usize) -> Result<Option<Vec<u8>>, WireError> {
    let mut prefix = [0u8; 4];
    let mut have = 0usize;
    while have < 4 {
        match r.read(&mut prefix[have..]) {
            Ok(0) => {
                if have == 0 {
                    return Ok(None);
                }
                return Err(WireError::Truncated { need: 4, have });
            }
            Ok(n) => have += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    let len = u32::from_le_bytes(prefix) as usize;
    if len > max {
        return Err(WireError::Oversized { len, max });
    }
    let mut body = vec![0u8; len];
    let mut have = 0usize;
    while have < len {
        match r.read(&mut body[have..]) {
            Ok(0) => return Err(WireError::Truncated { need: len, have }),
            Ok(n) => have += n,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    Ok(Some(body))
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn req(rng: &mut crate::util::rng::Lcg64) -> RequestFrame {
        let arch = match rng.below(3) {
            0 => "resnet8",
            1 => "resnet20",
            _ => "a-b_c.64",
        };
        RequestFrame {
            arch: arch.to_string(),
            deadline_ms: rng.next_u64() as u32,
            pixels: (0..IMG_ELEMS).map(|_| rng.range_i64(-128, 127) as i32).collect(),
        }
    }

    #[test]
    fn request_roundtrip_property() {
        forall("request encode/decode roundtrip", 25, |rng| {
            let r = req(rng);
            let body = r.encode();
            assert!(body.len() <= MAX_REQUEST_BYTES);
            assert_eq!(RequestFrame::decode(&body).unwrap(), r);
        });
    }

    #[test]
    fn response_roundtrip_property() {
        forall("response encode/decode roundtrip", 50, |rng| {
            let ticket = rng.next_u64();
            let r = match rng.below(4) {
                0 => ResponseFrame::Ok {
                    ticket,
                    latency_us: rng.next_u64(),
                    class: rng.below(10) as u16,
                    logits: (0..10).map(|_| rng.range_i64(i32::MIN as i64, i32::MAX as i64) as i32).collect(),
                },
                1 => ResponseFrame::Shed { ticket, retry_after_ms: rng.next_u64() as u32 },
                2 => ResponseFrame::Expired { ticket },
                _ => ResponseFrame::Error {
                    ticket,
                    code: ErrorCode::Backend,
                    msg: "stage r1/conv0 poisoned".to_string(),
                },
            };
            let body = r.encode();
            assert!(body.len() <= MAX_RESPONSE_BYTES);
            assert_eq!(ResponseFrame::decode(&body).unwrap(), r);
        });
    }

    #[test]
    fn truncated_bodies_yield_typed_errors_never_panics() {
        let full = RequestFrame {
            arch: "resnet8".into(),
            deadline_ms: 20,
            pixels: vec![0; IMG_ELEMS],
        }
        .encode();
        // Every prefix of a valid frame must fail typed, not panic.
        for cut in 0..full.len().min(64) {
            assert!(RequestFrame::decode(&full[..cut]).is_err(), "cut {cut} accepted");
        }
        // And a few cuts through the payload region.
        for cut in [full.len() - 1, full.len() - 5, 20, 100] {
            assert!(matches!(
                RequestFrame::decode(&full[..cut]),
                Err(WireError::Truncated { .. })
            ));
        }
        // Trailing garbage is rejected too.
        let mut long = full.clone();
        long.push(0xAB);
        assert!(RequestFrame::decode(&long).is_err());
    }

    #[test]
    fn bad_magic_version_and_pixel_count_are_typed() {
        let mut body = RequestFrame {
            arch: "resnet8".into(),
            deadline_ms: 0,
            pixels: vec![0; IMG_ELEMS],
        }
        .encode();
        let mut bad_magic = body.clone();
        bad_magic[0] ^= 0xFF;
        assert!(matches!(RequestFrame::decode(&bad_magic), Err(WireError::BadMagic(_))));
        let mut bad_version = body.clone();
        bad_version[4] = 99;
        assert!(matches!(RequestFrame::decode(&bad_version), Err(WireError::BadVersion(99))));
        // Lie about the pixel count.
        body[10 + 7..14 + 7].copy_from_slice(&7u32.to_le_bytes());
        assert!(matches!(
            RequestFrame::decode(&body),
            Err(WireError::BadPixelCount { got: 7, .. })
        ));
        assert!(matches!(ResponseFrame::decode(&[1, 2, 3]), Err(WireError::Truncated { .. })));
        let mut resp = ResponseFrame::Expired { ticket: 1 }.encode();
        resp[5] = 250;
        assert!(matches!(ResponseFrame::decode(&resp), Err(WireError::BadStatus(250))));
    }

    #[test]
    fn framed_io_roundtrip_and_limits() {
        let body = ResponseFrame::Shed { ticket: 9, retry_after_ms: 12 }.encode();
        let mut wire = Vec::new();
        write_frame(&mut wire, &body).unwrap();
        let mut rd = &wire[..];
        let got = read_frame(&mut rd, MAX_RESPONSE_BYTES).unwrap().unwrap();
        assert_eq!(got, body);
        // Clean EOF at the boundary.
        assert!(read_frame(&mut rd, MAX_RESPONSE_BYTES).unwrap().is_none());
        // EOF inside the prefix and inside the body are Truncated.
        let mut cut = &wire[..2];
        assert!(matches!(
            read_frame(&mut cut, MAX_RESPONSE_BYTES),
            Err(WireError::Truncated { .. })
        ));
        let mut cut = &wire[..wire.len() - 3];
        assert!(matches!(
            read_frame(&mut cut, MAX_RESPONSE_BYTES),
            Err(WireError::Truncated { .. })
        ));
        // An oversized length prefix is rejected before allocation.
        let huge = (u32::MAX).to_le_bytes();
        let mut rd = &huge[..];
        assert!(matches!(
            read_frame(&mut rd, MAX_REQUEST_BYTES),
            Err(WireError::Oversized { .. })
        ));
    }
}
