//! Network ingress: the TCP front-end ahead of [`crate::coordinator`].
//!
//! The paper's accelerators are judged under sustained overload (the
//! KV260 ResNet8 point is 30153 FPS); this module is the serving-side
//! analogue — the subsystem that survives a firehose at bounded memory
//! and bounded tail latency instead of queueing unboundedly:
//!
//! * [`protocol`] — the length-prefixed binary wire format (requests:
//!   magic/version/arch/deadline/pixels; responses: in-order ticket +
//!   OK/SHED/EXPIRED/ERROR tails), with typed, panic-free decoding;
//! * [`admission`] — the bounded queue between socket readers and the
//!   router dispatchers: shed-on-full and shed-on-infeasible-deadline
//!   with retry-after hints, depth gauges for the elastic loop;
//! * [`server`] — [`server::IngressServer`]: acceptor, per-connection
//!   reader/writer pairs (responses strictly in ticket order),
//!   dispatcher pool, second deadline check at dequeue, and ingress
//!   depth reported into [`crate::coordinator::Router::report_ingress`]
//!   so stream pools grow replicas from socket backlog;
//! * [`client`] — the blocking client plus the [`client::drive`]
//!   traffic generator shared by the example, the `client` subcommand,
//!   the soak bench and the integration tests;
//! * [`metrics`] — the HTTP exposition endpoint
//!   ([`metrics::MetricsServer`], `repro listen --metrics-port`):
//!   Prometheus text at `/metrics` and JSON at `/stats.json`, covering
//!   serving counters, latency percentiles and the streaming pools'
//!   [`StallReport`](crate::obs::StallReport) stall attribution.
//!
//! Everything is `std`-only: no async runtime, no wire-format crates.

// Panic-freedom gate: ingress code answers malformed/hostile input with
// typed errors, never by unwinding a connection or dispatcher thread.
// `clippy.toml` disallows Option/Result unwrap+expect; test modules opt
// out locally.
#![deny(clippy::disallowed_methods)]

pub mod admission;
pub mod client;
pub mod metrics;
pub mod protocol;
pub mod server;

pub use admission::{AdmissionConfig, AdmissionQueue, Offer, Pop, ShedReason};
pub use client::{drive, Client, DriveConfig, DriveReport};
pub use metrics::MetricsServer;
pub use protocol::{ErrorCode, RequestFrame, ResponseFrame, WireError};
pub use server::{IngressServer, IngressSnapshot, ServerConfig};
