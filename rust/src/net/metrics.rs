//! The metrics exposition endpoint: a tiny HTTP server over the
//! router's serving metrics and the streaming pools' stall reports.
//!
//! Two representations of the same snapshot:
//!
//! * `GET /metrics` — Prometheus text exposition (families listed in the
//!   README's "Observability" section): serving counters and latency
//!   percentiles per arch, plus — when a streaming backend has reported
//!   — per-stage busy/blocked fractions, per-FIFO occupancy histograms
//!   and the elastic replica gauges from
//!   [`StallReport`](crate::obs::StallReport);
//! * `GET /` or `GET /stats.json` — the same data as one JSON document,
//!   including the rendered bottleneck verdict (what `repro stats
//!   --addr` fetches).
//!
//! Same idioms as [`super::server`]: std-only, a nonblocking accept
//! loop polling a stop flag, one short-lived handler per connection
//! (scrapes are rare and tiny — no per-connection thread pair needed).

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::coordinator::{MetricsSnapshot, Router};
use crate::util::Json;

/// Upper bound on an incoming scrape request (request line + headers).
const MAX_HTTP_REQUEST: usize = 8 * 1024;

/// Handle to a running exposition endpoint.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (port 0 = OS-chosen; read it back from
    /// [`Self::local_addr`]) and serve scrapes until shutdown.  Holds an
    /// `Arc` to the router — drop the server before tearing the router
    /// down.
    pub fn start(router: Arc<Router>, addr: &str) -> Result<MetricsServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let thread = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("metrics-http".to_string())
                .spawn(move || serve_loop(&listener, &router, &stop))?
        };
        Ok(MetricsServer { addr: local, stop, thread: Some(thread) })
    }

    /// The bound address (resolves port 0 to the OS-chosen port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept loop and join it.  Idempotent via `Drop`.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn serve_loop(listener: &TcpListener, router: &Router, stop: &AtomicBool) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _peer)) => handle_conn(stream, router),
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(5)),
        }
    }
}

/// Serve one scrape: read the request head, answer, close.  Any I/O
/// failure just drops the connection — a scraper retries, and a handler
/// panic is impossible (no unwrap on the request path).
fn handle_conn(mut stream: TcpStream, router: &Router) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let _ = stream.set_nodelay(true);
    let Some(head) = read_request_head(&mut stream) else { return };
    let mut parts = head.split_whitespace();
    let method = parts.next().unwrap_or("");
    let path = parts.next().unwrap_or("");
    // Strip any query string: `/metrics?x=1` still scrapes.
    let path = path.split('?').next().unwrap_or(path);
    let (status, ctype, body) = if method != "GET" {
        ("405 Method Not Allowed", "text/plain", "method not allowed\n".to_string())
    } else {
        match path {
            "/metrics" => {
                ("200 OK", "text/plain; version=0.0.4", prometheus_text(router))
            }
            "/" | "/stats.json" => {
                ("200 OK", "application/json", format!("{}\n", stats_json(router)))
            }
            _ => ("404 Not Found", "text/plain", "not found\n".to_string()),
        }
    };
    let _ = write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let _ = stream.write_all(body.as_bytes());
    let _ = stream.flush();
}

/// Read up to the end of the HTTP request head (`\r\n\r\n`), bounded by
/// [`MAX_HTTP_REQUEST`]; returns the first line.  `None` on timeout,
/// disconnect or an oversized head.
fn read_request_head(stream: &mut TcpStream) -> Option<String> {
    let mut buf = Vec::new();
    let mut chunk = [0u8; 1024];
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => return None,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.windows(4).any(|w| w == b"\r\n\r\n") {
                    break;
                }
                if buf.len() > MAX_HTTP_REQUEST {
                    return None;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return None,
        }
    }
    let text = String::from_utf8_lossy(&buf);
    text.lines().next().map(|l| l.to_string())
}

/// `(family, type)` pairs for every family the exposition can emit.
/// Headers are written unconditionally so scrapers see stable metadata
/// even before a streaming backend reports stalls.
const FAMILIES: &[(&str, &str)] = &[
    ("repro_requests_total", "counter"),
    ("repro_frames_total", "counter"),
    ("repro_batches_total", "counter"),
    ("repro_padded_frames_total", "counter"),
    ("repro_errors_total", "counter"),
    ("repro_shed_total", "counter"),
    ("repro_deadline_expired_total", "counter"),
    ("repro_disconnects_total", "counter"),
    ("repro_batch_underflows_total", "counter"),
    ("repro_latency_us", "gauge"),
    ("repro_stream_buffered_peak_elems", "gauge"),
    ("repro_stream_buffered_fraction", "gauge"),
    ("repro_stage_busy_fraction", "gauge"),
    ("repro_stage_blocked_fraction", "gauge"),
    ("repro_stage_frames_total", "counter"),
    ("repro_fifo_capacity_elems", "gauge"),
    ("repro_fifo_occupancy_peak_elems", "gauge"),
    ("repro_fifo_blocked_seconds_total", "counter"),
    ("repro_fifo_occupancy_bucket", "counter"),
    ("repro_stream_replicas", "gauge"),
    ("repro_stream_peak_replicas", "gauge"),
    ("repro_stream_scale_events_total", "counter"),
    ("repro_stream_frames_total", "counter"),
    ("repro_budget_total_workers", "gauge"),
    ("repro_budget_utilization", "gauge"),
    ("repro_budget_denied_total", "counter"),
    ("repro_budget_held_workers", "gauge"),
    ("repro_budget_reserved_workers", "gauge"),
    ("repro_budget_denied_grants_total", "counter"),
];

/// The full Prometheus text exposition for one scrape.
pub fn prometheus_text(router: &Router) -> String {
    let mut out = String::new();
    for (name, ty) in FAMILIES {
        let _ = writeln!(out, "# TYPE {name} {ty}");
    }
    for arch in router.archs() {
        let Some(m) = router.metrics(&arch) else { continue };
        let labels = format!("arch=\"{arch}\"");
        serving_samples(&labels, &m.snapshot(), &mut out);
        if let Some(stalls) = m.stall_report() {
            stalls.prometheus_samples(&labels, &mut out);
        }
    }
    // Shared worker-budget families come from the router's one budget
    // snapshot (per-arch lease rows carry their own `arch` labels) — not
    // from the per-arch serving snapshots, which would emit duplicate
    // series for the same lease.
    if let Some(b) = router.budget_snapshot() {
        b.prometheus_samples(&mut out);
    }
    out
}

/// Serving-counter and latency samples for one arch.
fn serving_samples(labels: &str, s: &MetricsSnapshot, out: &mut String) {
    let _ = writeln!(out, "repro_requests_total{{{labels}}} {}", s.requests);
    let _ = writeln!(out, "repro_frames_total{{{labels}}} {}", s.frames);
    let _ = writeln!(out, "repro_batches_total{{{labels}}} {}", s.batches);
    let _ = writeln!(out, "repro_padded_frames_total{{{labels}}} {}", s.padded_frames);
    let _ = writeln!(out, "repro_errors_total{{{labels}}} {}", s.errors);
    let _ = writeln!(out, "repro_shed_total{{{labels}}} {}", s.shed);
    let _ = writeln!(out, "repro_deadline_expired_total{{{labels}}} {}", s.deadline_expired);
    let _ = writeln!(out, "repro_disconnects_total{{{labels}}} {}", s.disconnects);
    let _ = writeln!(out, "repro_batch_underflows_total{{{labels}}} {}", s.batch_underflows);
    for (q, v) in [
        ("mean", s.mean_latency_us),
        ("p50", s.p50_le_us),
        ("p95", s.p95_le_us),
        ("p99", s.p99_le_us),
        ("max", s.max_latency_us),
    ] {
        let _ = writeln!(out, "repro_latency_us{{{labels},quantile=\"{q}\"}} {v}");
    }
    let _ = writeln!(
        out,
        "repro_stream_buffered_peak_elems{{{labels}}} {}",
        s.stream_peak_buffered_elems
    );
    let _ = writeln!(
        out,
        "repro_stream_buffered_fraction{{{labels}}} {:.6}",
        s.stream_buffered_fraction
    );
    // Replica gauges are emitted here, per arch, unconditionally (0 for
    // non-streaming backends) — not from the stall report, whose samples
    // only appear once a streaming pool has reported.  A dashboard can
    // therefore always plot `repro_stream_replicas{arch=...}` per arch,
    // and an idle arch is an explicit 0, not a missing series.
    let _ = writeln!(out, "repro_stream_replicas{{{labels}}} {}", s.stream_replicas);
    let _ = writeln!(out, "repro_stream_peak_replicas{{{labels}}} {}", s.stream_peak_replicas);
}

/// One arch's serving snapshot as a JSON object.
fn snapshot_json(s: &MetricsSnapshot) -> Json {
    let mut o = BTreeMap::new();
    o.insert("requests".to_string(), Json::Int(s.requests as i64));
    o.insert("frames".to_string(), Json::Int(s.frames as i64));
    o.insert("batches".to_string(), Json::Int(s.batches as i64));
    o.insert("padded_frames".to_string(), Json::Int(s.padded_frames as i64));
    o.insert("padding_efficiency".to_string(), Json::Float(s.padding_efficiency));
    o.insert("errors".to_string(), Json::Int(s.errors as i64));
    o.insert("shed".to_string(), Json::Int(s.shed as i64));
    o.insert("deadline_expired".to_string(), Json::Int(s.deadline_expired as i64));
    o.insert("disconnects".to_string(), Json::Int(s.disconnects as i64));
    o.insert("shed_rate".to_string(), Json::Float(s.shed_rate));
    o.insert("batch_underflows".to_string(), Json::Int(s.batch_underflows as i64));
    o.insert("mean_latency_us".to_string(), Json::Int(s.mean_latency_us as i64));
    o.insert("p50_le_us".to_string(), Json::Int(s.p50_le_us as i64));
    o.insert("p95_le_us".to_string(), Json::Int(s.p95_le_us as i64));
    o.insert("p99_le_us".to_string(), Json::Int(s.p99_le_us as i64));
    o.insert("max_latency_us".to_string(), Json::Int(s.max_latency_us as i64));
    o.insert(
        "stream_peak_buffered_elems".to_string(),
        Json::Int(s.stream_peak_buffered_elems as i64),
    );
    o.insert("stream_buffered_fraction".to_string(), Json::Float(s.stream_buffered_fraction));
    o.insert("stream_replicas".to_string(), Json::Int(s.stream_replicas as i64));
    o.insert("stream_peak_replicas".to_string(), Json::Int(s.stream_peak_replicas as i64));
    o.insert("budget_workers_held".to_string(), Json::Int(s.budget_workers_held as i64));
    o.insert(
        "budget_workers_reserved".to_string(),
        Json::Int(s.budget_workers_reserved as i64),
    );
    o.insert("budget_denied".to_string(), Json::Int(s.budget_denied as i64));
    match &s.bottleneck {
        Some(b) => o.insert("bottleneck".to_string(), Json::Str(b.clone())),
        None => o.insert("bottleneck".to_string(), Json::Null),
    };
    Json::Object(o)
}

/// The `/stats.json` document: per-arch serving metrics + stall report,
/// plus the router-level total.
pub fn stats_json(router: &Router) -> Json {
    let snap = router.snapshot();
    let mut archs = BTreeMap::new();
    for arch in router.archs() {
        let Some(m) = router.metrics(&arch) else { continue };
        let mut entry = BTreeMap::new();
        entry.insert("metrics".to_string(), snapshot_json(&m.snapshot()));
        entry.insert(
            "stalls".to_string(),
            m.stall_report().map_or(Json::Null, |r| r.to_json()),
        );
        archs.insert(arch, Json::Object(entry));
    }
    let mut o = BTreeMap::new();
    o.insert("archs".to_string(), Json::Object(archs));
    o.insert("total".to_string(), snapshot_json(&snap.total));
    o.insert(
        "budget".to_string(),
        snap.budget.as_ref().map_or(Json::Null, |b| b.to_json()),
    );
    Json::Object(o)
}

/// Minimal blocking HTTP GET against an exposition endpoint (what
/// `repro stats --addr` uses — no HTTP client crates offline).  Returns
/// the response body of a 200, an error otherwise.
pub fn fetch(addr: &str, path: &str) -> Result<String> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    write!(stream, "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n")?;
    stream.flush()?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    let text = String::from_utf8_lossy(&raw).into_owned();
    let (head, body) = text
        .split_once("\r\n\r\n")
        .ok_or_else(|| anyhow!("malformed HTTP response from {addr}"))?;
    let status = head.lines().next().unwrap_or("");
    anyhow::ensure!(
        status.split_whitespace().nth(1) == Some("200"),
        "{addr}{path}: {status}"
    );
    Ok(body.to_string())
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::coordinator::RouterConfig;
    use crate::data::IMG_ELEMS;
    use crate::runtime::GoldenFactory;

    fn start_router() -> Arc<Router> {
        Arc::new(
            Router::start(
                vec![Arc::new(GoldenFactory::synthetic("resnet8", 7))],
                RouterConfig::default(),
            )
            .unwrap(),
        )
    }

    #[test]
    fn exposition_serves_prometheus_and_json() {
        let router = start_router();
        router.infer("resnet8", vec![0i32; IMG_ELEMS]).unwrap();
        let server = MetricsServer::start(router.clone(), "127.0.0.1:0").unwrap();
        let addr = format!("{}", server.local_addr());

        let prom = fetch(&addr, "/metrics").unwrap();
        assert!(prom.contains("# TYPE repro_requests_total counter"), "{prom}");
        assert!(prom.contains("# TYPE repro_stage_busy_fraction gauge"), "{prom}");
        assert!(prom.contains("repro_requests_total{arch=\"resnet8\"} 1"), "{prom}");
        assert!(prom.contains("repro_latency_us{arch=\"resnet8\",quantile=\"p99\"}"), "{prom}");
        // Per-arch replica gauges are unconditional: a non-streaming
        // backend exports an explicit 0, never a missing series.
        assert!(prom.contains("repro_stream_replicas{arch=\"resnet8\"} 0"), "{prom}");
        assert!(prom.contains("repro_stream_peak_replicas{arch=\"resnet8\"} 0"), "{prom}");

        let body = fetch(&addr, "/stats.json").unwrap();
        let j = Json::parse(&body).unwrap();
        assert_eq!(
            j.at("archs/resnet8/metrics/requests").and_then(|v| v.as_i64()),
            Some(1)
        );
        // Golden backend: no streaming pool, so no stall report.
        assert_eq!(j.at("archs/resnet8/stalls"), Some(&Json::Null));
        assert_eq!(j.at("total/requests").and_then(|v| v.as_i64()), Some(1));
        // No shared worker budget on this router: explicit null.
        assert_eq!(j.at("budget"), Some(&Json::Null));

        // Root serves the same JSON; unknown paths 404 (surfaced as a
        // typed error by fetch).
        assert!(fetch(&addr, "/").is_ok());
        let err = fetch(&addr, "/nope").unwrap_err().to_string();
        assert!(err.contains("404"), "{err}");

        server.shutdown();
        drop(router);
    }
}
