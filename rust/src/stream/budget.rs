//! Process-wide worker budget: stage replicas as leases.
//!
//! Every arch behind the router used to own its replica band outright,
//! so a ResNet8+ResNet20 fleet pinned `sum(arches x max_replicas x
//! stages)` threads even when most pools idled.  A [`WorkerBudget`] is
//! the shared substrate instead: one process-level cap on live stage
//! workers, per-pool *reservations* (`min_replicas x stages`, so every
//! arch can always field its floor), and everything above the
//! reservations as *borrowable headroom* — an idle arch's unused share
//! serves whichever pool is bursting.
//!
//! The lease lifecycle:
//!
//! ```text
//!   StreamPool::new ──register(arch, min_replicas x stages)──▶ BudgetHandle
//!        │                                                        │
//!   add_replica ────acquire(stages)──▶ WorkerLease ──▶ stored in the
//!        │              │ (denied: counted, queued)     ReplicaHandle
//!   retire_one / drain / failed spawn ──drop(lease)──▶ workers returned
//!        │
//!   pool drop ──drop(handle)──▶ reservation released (deregistered)
//! ```
//!
//! Grant rule — the budget charges each client `max(held, reserved)`,
//! so reservations stay satisfiable no matter who is borrowing:
//!
//! * an acquire that keeps the client at or under its reservation
//!   ALWAYS succeeds (the charge does not grow);
//! * an acquire above the reservation (a borrow) succeeds only if the
//!   total charge stays within the cap AND no *other* client is ahead
//!   of it in the FIFO waiter queue — first denied, first served, so a
//!   starved arch cannot be locked out by a faster-polling one;
//! * a denied acquire enqueues the client and bumps the denial
//!   counters; [`BudgetHandle::should_yield`] then hints current
//!   borrowers to retire a replica voluntarily (the elastic
//!   controller's preemption path — rebalancing never kills a replica
//!   mid-frame from the outside).
//!
//! Everything here is bookkeeping under one mutex: poison-tolerant
//! (`PoisonError::into_inner` — the state is plain counters, always
//! consistent at rest), no locks held across thread operations, and no
//! panicking calls (the module rides under `stream/`'s
//! `deny(clippy::disallowed_methods)` gate).

use std::collections::{BTreeMap, VecDeque};
use std::fmt;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use crate::obs::{BudgetLease as LeaseRow, BudgetSnapshot};

/// Budget registration can fail in exactly one way: the cap cannot
/// cover the sum of reservations, so some pool could never field its
/// `min_replicas`.  Surfaced as a typed error from `StreamPool::new`
/// (and from `serve`/`listen --worker-budget N` at startup).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BudgetError {
    /// `required` = existing committed workers + the new reservation.
    Insufficient { arch: String, required: usize, total: usize },
}

impl fmt::Display for BudgetError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            BudgetError::Insufficient { arch, required, total } => write!(
                f,
                "worker budget too small: registering {arch} needs {required} worker(s) \
                 reserved but the budget caps at {total} (raise --worker-budget to at \
                 least the sum of min_replicas x stages over all arches)"
            ),
        }
    }
}

impl std::error::Error for BudgetError {}

#[derive(Debug)]
struct Client {
    arch: String,
    reserved: usize,
    held: usize,
    denied: u64,
}

#[derive(Debug, Default)]
struct State {
    clients: BTreeMap<u64, Client>,
    next_id: u64,
    /// FIFO of client ids with an outstanding denied borrow.
    waiters: VecDeque<u64>,
    denied_total: u64,
}

impl State {
    /// What the grant rule charges: `sum(max(held, reserved))`.
    fn committed(&self) -> usize {
        self.clients.values().map(|c| c.held.max(c.reserved)).sum()
    }

    fn held(&self) -> usize {
        self.clients.values().map(|c| c.held).sum()
    }
}

/// The shared substrate: a hard cap on live stage workers plus the
/// per-client ledger.  Construct once, share via `Arc` through
/// `StreamConfig::budget` / `StreamFactory::with_budget`.
#[derive(Debug)]
pub struct WorkerBudget {
    total: usize,
    state: Mutex<State>,
}

/// Poison-tolerant lock: the ledger is plain counters, consistent at
/// rest, so a panicked peer must not wedge scaling or shutdown.
fn recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

impl WorkerBudget {
    /// A budget capping live stage workers at `total` process-wide.
    pub fn new(total: usize) -> Self {
        WorkerBudget { total, state: Mutex::new(State::default()) }
    }

    /// The hard cap this budget was built with.
    pub fn total(&self) -> usize {
        self.total
    }

    /// Register a pool: reserve `reserved` workers (its
    /// `min_replicas x stages` floor) for as long as the returned
    /// handle lives.  Fails with [`BudgetError::Insufficient`] when the
    /// cap cannot cover every reservation — callers surface that at
    /// startup rather than starving at runtime.
    pub fn register(
        self: &Arc<Self>,
        arch: &str,
        reserved: usize,
    ) -> Result<BudgetHandle, BudgetError> {
        let mut st = recover(&self.state);
        let required = st.committed() + reserved;
        if required > self.total {
            return Err(BudgetError::Insufficient {
                arch: arch.to_string(),
                required,
                total: self.total,
            });
        }
        let id = st.next_id;
        st.next_id += 1;
        st.clients
            .insert(id, Client { arch: arch.to_string(), reserved, held: 0, denied: 0 });
        Ok(BudgetHandle { budget: Arc::clone(self), id })
    }

    /// Point-in-time view for reports, `/metrics` and `stats.json`.
    pub fn snapshot(&self) -> BudgetSnapshot {
        let st = recover(&self.state);
        BudgetSnapshot {
            total: self.total,
            held: st.held(),
            committed: st.committed(),
            denied: st.denied_total,
            leases: st
                .clients
                .iter()
                .map(|(id, c)| LeaseRow {
                    arch: c.arch.clone(),
                    reserved: c.reserved,
                    held: c.held,
                    denied: c.denied,
                    waiting: st.waiters.contains(id),
                })
                .collect(),
        }
    }

    fn acquire(&self, id: u64, workers: usize) -> bool {
        let mut st = recover(&self.state);
        let committed_others: usize = st
            .clients
            .iter()
            .filter(|(cid, _)| **cid != id)
            .map(|(_, c)| c.held.max(c.reserved))
            .sum();
        let Some(client) = st.clients.get(&id) else { return false };
        let within_reservation = client.held + workers <= client.reserved;
        let fits = committed_others + (client.held + workers).max(client.reserved) <= self.total;
        // Borrows defer to earlier-denied peers; reservation-backed
        // grants never do (the invariant keeps them always satisfiable).
        let cut_in_line = !within_reservation
            && st.waiters.front().is_some_and(|front| *front != id);
        if fits && !cut_in_line {
            if let Some(c) = st.clients.get_mut(&id) {
                c.held += workers;
            }
            st.waiters.retain(|w| *w != id);
            true
        } else {
            st.denied_total += 1;
            if let Some(c) = st.clients.get_mut(&id) {
                c.denied += 1;
            }
            if !st.waiters.contains(&id) {
                st.waiters.push_back(id);
            }
            false
        }
    }

    fn release(&self, id: u64, workers: usize) {
        let mut st = recover(&self.state);
        if let Some(c) = st.clients.get_mut(&id) {
            c.held = c.held.saturating_sub(workers);
        }
    }

    fn deregister(&self, id: u64) {
        let mut st = recover(&self.state);
        st.clients.remove(&id);
        st.waiters.retain(|w| *w != id);
    }

    fn cancel_bid(&self, id: u64) {
        let mut st = recover(&self.state);
        st.waiters.retain(|w| *w != id);
    }

    fn should_yield(&self, id: u64) -> bool {
        let st = recover(&self.state);
        let Some(client) = st.clients.get(&id) else { return false };
        client.held > client.reserved && st.waiters.iter().any(|w| *w != id)
    }

    fn client_stat(&self, id: u64) -> Option<(usize, usize, u64)> {
        let st = recover(&self.state);
        st.clients.get(&id).map(|c| (c.held, c.reserved, c.denied))
    }
}

/// One pool's registration: the door through which it bids for worker
/// leases.  Dropping the handle releases the reservation.
#[derive(Debug)]
pub struct BudgetHandle {
    budget: Arc<WorkerBudget>,
    id: u64,
}

impl BudgetHandle {
    /// Bid for `workers` more workers (one replica's stages).  `None`
    /// means denied — non-fatal by design: the elastic controller just
    /// retries at its next sample, and the denial is visible in the
    /// gauges.  The grant, when it comes, is a [`WorkerLease`] that
    /// returns the workers on drop, so no failure path can leak them.
    pub fn acquire(&self, workers: usize) -> Option<WorkerLease> {
        self.budget.acquire(self.id, workers).then(|| WorkerLease {
            budget: Arc::clone(&self.budget),
            id: self.id,
            workers,
        })
    }

    /// Preemption hint: true when this pool holds borrowed workers
    /// (above its reservation) while some other pool's bid sits in the
    /// waiter queue.  The elastic controller answers by retiring one
    /// replica — cooperative rebalance, never a mid-frame kill.
    pub fn should_yield(&self) -> bool {
        self.budget.should_yield(self.id)
    }

    /// Withdraw an outstanding denied bid from the waiter queue.  A
    /// queued client blocks every later borrow (FIFO fairness), so a
    /// controller that no longer wants to grow MUST cancel — otherwise
    /// a pool that was denied during a burst and then went idle would
    /// freeze everyone else's headroom forever.
    pub fn cancel_bid(&self) {
        self.budget.cancel_bid(self.id);
    }

    /// This client's `(held, reserved, denied)` row, for per-arch
    /// metrics gauges.
    pub fn stat(&self) -> Option<(usize, usize, u64)> {
        self.budget.client_stat(self.id)
    }

    /// Snapshot of the whole budget this handle belongs to.
    pub fn budget_snapshot(&self) -> BudgetSnapshot {
        self.budget.snapshot()
    }
}

impl Drop for BudgetHandle {
    fn drop(&mut self) {
        self.budget.deregister(self.id);
    }
}

/// A granted lease on `workers` workers.  Held inside the replica's
/// `ReplicaHandle`; dropping it (retire, drain, or any failed-spawn
/// path) returns the workers to the budget.
#[derive(Debug)]
pub struct WorkerLease {
    budget: Arc<WorkerBudget>,
    id: u64,
    workers: usize,
}

impl WorkerLease {
    /// Workers this lease covers.
    pub fn workers(&self) -> usize {
        self.workers
    }
}

impl Drop for WorkerLease {
    fn drop(&mut self) {
        self.budget.release(self.id, self.workers);
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    #[test]
    fn reservation_grants_always_succeed_and_cap_is_never_exceeded() {
        let b = Arc::new(WorkerBudget::new(10));
        let h8 = b.register("resnet8", 4).unwrap();
        let h20 = b.register("resnet20", 4).unwrap();
        // Within-reservation bids always land, even interleaved.
        let l8 = h8.acquire(4).expect("reservation-backed grant");
        let l20 = h20.acquire(4).expect("reservation-backed grant");
        let snap = b.snapshot();
        assert_eq!((snap.held, snap.committed, snap.total), (8, 8, 10));
        // Borrow up to the cap, not past it.
        let borrow = h8.acquire(2).expect("headroom borrow");
        assert!(h8.acquire(1).is_none(), "cap must hold");
        assert!(h20.acquire(1).is_none(), "cap must hold for the peer too");
        assert_eq!(b.snapshot().held, 10);
        drop(borrow);
        assert_eq!(b.snapshot().held, 8);
        drop((l8, l20));
        assert_eq!(b.snapshot().held, 0);
        // Reservations stay charged until the handles drop.
        assert_eq!(b.snapshot().committed, 8);
        drop((h8, h20));
        assert_eq!(b.snapshot().committed, 0);
    }

    #[test]
    fn registration_over_cap_is_a_typed_error() {
        let b = Arc::new(WorkerBudget::new(6));
        let _h = b.register("resnet8", 4).unwrap();
        let err = b.register("resnet20", 4).unwrap_err();
        assert_eq!(
            err,
            BudgetError::Insufficient { arch: "resnet20".into(), required: 8, total: 6 }
        );
        assert!(err.to_string().contains("--worker-budget"));
    }

    #[test]
    fn denied_borrower_gets_freed_headroom_before_a_late_bidder() {
        let b = Arc::new(WorkerBudget::new(6));
        let first = b.register("resnet8", 2).unwrap();
        let second = b.register("resnet20", 2).unwrap();
        let _f = first.acquire(2).unwrap();
        let s_extra = second.acquire(4).expect("borrow all headroom");
        // `first` is denied a borrow and queues.
        assert!(first.acquire(2).is_none());
        assert!(b.snapshot().leases.iter().any(|l| l.arch == "resnet8" && l.waiting));
        assert!(second.should_yield(), "borrower must see the starved peer");
        assert!(!first.should_yield(), "non-borrower never yields");
        drop(s_extra);
        // Headroom is free again, but `second` now has to wait its
        // turn: `first` queued earlier and takes the grant.
        assert!(second.acquire(4).is_none(), "late bidder must not cut the queue");
        let f2 = first.acquire(2).expect("queued client served first");
        assert!(!b.snapshot().leases.iter().any(|l| l.arch == "resnet8" && l.waiting));
        drop(f2);
        let denied = b.snapshot().denied;
        assert!(denied >= 2, "denials must be counted (got {denied})");
    }

    #[test]
    fn handle_drop_releases_reservation_and_queued_slot() {
        let b = Arc::new(WorkerBudget::new(4));
        let h1 = b.register("resnet8", 2).unwrap();
        let h2 = b.register("resnet20", 2).unwrap();
        assert!(h1.acquire(3).is_none(), "borrow over cap denied");
        drop(h2);
        // The peer's reservation is gone: the same borrow now fits, and
        // h1's queued slot does not block itself.
        assert!(h1.acquire(3).is_some());
        assert_eq!(b.snapshot().committed, 3);
    }

    /// Satellite: grant/release laws under an adversarial schedule.
    /// A model executes random acquire/release/yield steps against the
    /// real budget and checks after every step that (1) held and
    /// committed never exceed the cap, (2) a reservation-backed bid is
    /// never denied, (3) a denied client is granted once enough
    /// borrowed headroom drains — no starvation.
    #[test]
    fn prop_budget_laws_hold_under_adversarial_schedules() {
        forall("worker budget grant/release laws", 64, |rng| {
            let total = rng.range_i64(4, 24) as usize;
            let n_clients = rng.range_i64(2, 4) as usize;
            let b = Arc::new(WorkerBudget::new(total));
            let mut reserved = Vec::new();
            let mut handles = Vec::new();
            let mut left = total;
            for i in 0..n_clients {
                let r = rng.range_i64(1, 1 + (left / (n_clients - i)) as i64) as usize;
                left -= r;
                reserved.push(r);
                handles.push(b.register(&format!("arch{i}"), r).expect("fits"));
            }
            let mut leases: Vec<Vec<WorkerLease>> = (0..n_clients).map(|_| Vec::new()).collect();
            let held = |leases: &[Vec<WorkerLease>], i: usize| -> usize {
                leases[i].iter().map(WorkerLease::workers).sum()
            };
            for _ in 0..200 {
                let i = rng.range_i64(0, n_clients as i64 - 1) as usize;
                match rng.range_i64(0, 3) {
                    0 => {
                        // Law 2: a bid within the reservation always lands.
                        let h = held(&leases, i);
                        if h < reserved[i] {
                            let want = rng.range_i64(1, (reserved[i] - h) as i64) as usize;
                            let lease = handles[i]
                                .acquire(want)
                                .expect("reservation-backed bid denied");
                            leases[i].push(lease);
                        }
                    }
                    1 => {
                        // Adversarial borrow of arbitrary size.
                        let want = rng.range_i64(1, 1 + total as i64) as usize;
                        if let Some(lease) = handles[i].acquire(want) {
                            leases[i].push(lease);
                        }
                    }
                    2 => {
                        if !leases[i].is_empty() {
                            let k =
                                rng.range_i64(0, leases[i].len() as i64 - 1) as usize;
                            leases[i].swap_remove(k);
                        }
                    }
                    _ => {
                        // A borrower that sees the yield hint gives one
                        // lease back (the controller's preemption).
                        if handles[i].should_yield() && !leases[i].is_empty() {
                            leases[i].pop();
                        }
                    }
                }
                // Law 1: the cap holds after every step.
                let snap = b.snapshot();
                assert!(
                    snap.held <= total && snap.committed <= total,
                    "cap breached: held {} committed {} total {total}",
                    snap.held,
                    snap.committed
                );
                let model_held: usize = (0..n_clients).map(|i| held(&leases, i)).sum();
                assert_eq!(snap.held, model_held, "ledger drifted from the leases");
            }
            // Law 3 (no starvation / no leaked accounting): once every
            // lease drains and stale bids are withdrawn, a single
            // client must be grantable the ENTIRE remaining headroom —
            // nothing the adversarial schedule did may leave workers
            // stranded or a ghost waiter blocking the queue.
            for l in &mut leases {
                l.clear();
            }
            for h in &handles {
                h.cancel_bid();
            }
            let sum_reserved: usize = reserved.iter().sum();
            let i = rng.range_i64(0, n_clients as i64 - 1) as usize;
            let all_headroom = total - sum_reserved + reserved[i];
            if all_headroom > 0 {
                let lease = handles[i].acquire(all_headroom);
                assert!(
                    lease.is_some(),
                    "drained budget refused the full headroom: {:?}",
                    b.snapshot()
                );
                assert_eq!(b.snapshot().committed, total, "full headroom = exactly the cap");
            }
        });
    }
}
