//! Bounded inter-stage streams for the pipelined executor.
//!
//! A [`Fifo`] carries *pixel tokens* — the channel vector of one spatial
//! position, matching the depth-first streaming order of the accelerator
//! (paper Section III-F) — and accounts its capacity in **activation
//! elements**, so depths plug in directly from [`hls::streams`]
//! (parameter/output/skip/DMA sizing, Section III-E).
//!
//! Blocking is *bounded*: a push or pop that makes no progress within the
//! configured timeout returns [`StreamError::Stalled`] instead of hanging.
//! Deadlock from an undersized FIFO is therefore an **error result**, the
//! executor analogue of the dataflow simulator reporting `deadlocked`
//! rather than spinning (paper Fig. 14's failure mode).
//!
//! [`hls::streams`]: crate::hls::streams

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::hls::streams::StreamKind;
use crate::obs;

/// How often a blocked stream operation re-checks the abort flag.
const POLL: Duration = Duration::from_millis(20);

/// Why a streaming stage gave up.
#[derive(Debug)]
pub enum StreamError {
    /// No progress on a stream operation within the bounded wait — an
    /// undersized FIFO deadlock or a wedged peer stage.
    Stalled {
        fifo: String,
        op: &'static str,
        waited: Duration,
    },
    /// Another stage failed first; this one was woken to unwind.
    Aborted,
    /// A peer stage panicked (its error was lost with the thread).
    Panicked,
    /// The pool's bookkeeping broke an invariant (e.g. a completed frame
    /// with no pending submitter).  Degrades the replica into the typed
    /// error path instead of aborting the serving process.
    Inconsistent { what: &'static str },
}

impl std::fmt::Display for StreamError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StreamError::Stalled { fifo, op, waited } => write!(
                f,
                "stream stalled: no progress on {op} of FIFO `{fifo}` within {waited:?} \
                 (undersized FIFO deadlock or wedged stage)"
            ),
            StreamError::Aborted => write!(f, "stream stage unwound after a peer failed"),
            StreamError::Panicked => write!(f, "a stream stage panicked"),
            StreamError::Inconsistent { what } => {
                write!(f, "stream pool state inconsistent: {what}")
            }
        }
    }
}

impl std::error::Error for StreamError {}

/// Capacity/occupancy record for one buffer (FIFO or line buffer), in
/// activation elements.
#[derive(Debug, Clone)]
pub struct BufferStat {
    pub name: String,
    pub kind: StreamKind,
    /// Bound enforced (FIFOs) or implied by the row-granular algorithm
    /// (line buffers), in elements.
    pub capacity: usize,
    /// Peak elements held at any instant.
    pub peak: usize,
}

/// Live peak-occupancy gauge for buffers owned by a stage thread (line
/// buffers): pre-registered with the pool at plan time so buffering stats
/// stay readable *while* the persistent pipeline runs, without joining
/// the stage.  The stage publishes its held-element count after every
/// mutation; readers take a consistent monotone peak.
#[derive(Debug)]
pub struct PeakGauge {
    name: String,
    kind: StreamKind,
    capacity: usize,
    peak: AtomicUsize,
    probe: Arc<obs::FifoProbe>,
}

impl PeakGauge {
    pub fn new(name: String, kind: StreamKind, capacity: usize) -> Arc<PeakGauge> {
        Arc::new(PeakGauge {
            name,
            kind,
            capacity,
            peak: AtomicUsize::new(0),
            probe: obs::FifoProbe::new(),
        })
    }

    /// Record an observed occupancy (elements currently held).
    pub fn observe(&self, held: usize) {
        self.peak.fetch_max(held, Ordering::Relaxed);
        if obs::enabled() {
            self.probe.observe_occupancy(held, self.capacity);
        }
    }

    /// Full edge telemetry (occupancy histogram; gauges never block, so
    /// the stall counters stay zero).
    pub fn edge_stat(&self) -> obs::EdgeStat {
        obs::EdgeStat {
            name: self.name.clone(),
            kind: self.kind,
            capacity: self.capacity,
            peak: self.peak.load(Ordering::Relaxed),
            blocked_push_ns: 0,
            blocked_pop_ns: 0,
            push_blocks: 0,
            pop_blocks: 0,
            occ_hist: self.probe.occ_hist(),
        }
    }

    /// Peak elements observed (no allocation — for cheap serving gauges).
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn stat(&self) -> BufferStat {
        BufferStat {
            name: self.name.clone(),
            kind: self.kind,
            capacity: self.capacity,
            peak: self.peak.load(Ordering::Relaxed),
        }
    }
}

struct FifoState {
    queue: VecDeque<Box<[i32]>>,
    occupancy: usize,
    peak: usize,
}

/// A bounded, element-accounted stream of pixel tokens.
pub struct Fifo {
    name: String,
    kind: StreamKind,
    capacity: usize,
    timeout: Duration,
    abort: Arc<AtomicBool>,
    state: Mutex<FifoState>,
    cv: Condvar,
    /// Stall/occupancy telemetry, shared with the producer's and
    /// consumer's [`obs::StageClock`]s.
    probe: Arc<obs::FifoProbe>,
}

impl Fifo {
    /// Hot-path lock: a poisoned mutex (a peer panicked while holding it)
    /// becomes the typed `Inconsistent` error, degrading the replica
    /// instead of cascading the panic through every stage thread.
    fn locked(&self) -> Result<MutexGuard<'_, FifoState>, StreamError> {
        self.state
            .lock()
            .map_err(|_| StreamError::Inconsistent { what: "fifo mutex poisoned" })
    }

    pub fn new(
        name: String,
        kind: StreamKind,
        capacity: usize,
        abort: Arc<AtomicBool>,
        timeout: Duration,
    ) -> Arc<Fifo> {
        Arc::new(Fifo {
            name,
            kind,
            capacity: capacity.max(1),
            timeout,
            abort,
            state: Mutex::new(FifoState { queue: VecDeque::new(), occupancy: 0, peak: 0 }),
            cv: Condvar::new(),
            probe: obs::FifoProbe::new(),
        })
    }

    /// Push one token, blocking (bounded) until `token.len()` elements fit.
    ///
    /// A zero-length token occupies no capacity and therefore always fits,
    /// even into a full FIFO — the pool's end-of-stream sentinel relies on
    /// this so shutdown can never itself deadlock.
    pub fn push(&self, token: Box<[i32]>) -> Result<(), StreamError> {
        let deadline = Instant::now() + self.timeout;
        // Blocked wall time is measured only once the push actually has
        // to wait; the uncontended path records one relaxed increment
        // (the occupancy histogram) and nothing else.
        let mut blocked_since: Option<Instant> = None;
        let mut st = self.locked()?;
        loop {
            if st.occupancy + token.len() <= self.capacity {
                st.occupancy += token.len();
                st.peak = st.peak.max(st.occupancy);
                st.queue.push_back(token);
                self.cv.notify_all();
                if obs::enabled() {
                    self.probe.observe_occupancy(st.occupancy, self.capacity);
                    if let Some(t0) = blocked_since {
                        self.probe.record_push_block(t0.elapsed());
                    }
                }
                return Ok(());
            }
            if blocked_since.is_none() && obs::enabled() {
                blocked_since = Some(Instant::now());
            }
            st = match self.wait(st, deadline, "push") {
                Ok(g) => g,
                Err(e) => {
                    // Account the wait even when the push fails: a
                    // stalled edge is exactly what the bottleneck report
                    // must name.
                    if let Some(t0) = blocked_since {
                        self.probe.record_push_block(t0.elapsed());
                    }
                    return Err(e);
                }
            };
        }
    }

    /// Pop the oldest token, blocking *without* the stall deadline — for
    /// frame-boundary waits where indefinite idle is legitimate (a
    /// persistent pool waiting for its next frame; the sink waiting for
    /// the next result).  Still unblocks promptly on abort, and any real
    /// deadlock cycle necessarily blocks some peer on a bounded push or
    /// mid-frame pop, so stall detection is not weakened.
    pub fn pop_idle(&self) -> Result<Box<[i32]>, StreamError> {
        let mut blocked_since: Option<Instant> = None;
        let mut st = self.locked()?;
        loop {
            if let Some(tok) = st.queue.pop_front() {
                st.occupancy -= tok.len();
                self.cv.notify_all();
                if let Some(t0) = blocked_since {
                    self.probe.record_pop_block(t0.elapsed());
                }
                return Ok(tok);
            }
            if self.abort.load(Ordering::SeqCst) {
                return Err(StreamError::Aborted);
            }
            if blocked_since.is_none() && obs::enabled() {
                blocked_since = Some(Instant::now());
            }
            let (g, _) = self
                .cv
                .wait_timeout(st, POLL)
                .map_err(|_| StreamError::Inconsistent { what: "fifo mutex poisoned" })?;
            st = g;
        }
    }

    /// Pop the oldest token, blocking (bounded) until one is available.
    pub fn pop(&self) -> Result<Box<[i32]>, StreamError> {
        let deadline = Instant::now() + self.timeout;
        let mut blocked_since: Option<Instant> = None;
        let mut st = self.locked()?;
        loop {
            if let Some(tok) = st.queue.pop_front() {
                st.occupancy -= tok.len();
                self.cv.notify_all();
                if let Some(t0) = blocked_since {
                    self.probe.record_pop_block(t0.elapsed());
                }
                return Ok(tok);
            }
            if blocked_since.is_none() && obs::enabled() {
                blocked_since = Some(Instant::now());
            }
            st = match self.wait(st, deadline, "pop") {
                Ok(g) => g,
                Err(e) => {
                    if let Some(t0) = blocked_since {
                        self.probe.record_pop_block(t0.elapsed());
                    }
                    return Err(e);
                }
            };
        }
    }

    fn wait<'a>(
        &self,
        st: MutexGuard<'a, FifoState>,
        deadline: Instant,
        op: &'static str,
    ) -> Result<MutexGuard<'a, FifoState>, StreamError> {
        if self.abort.load(Ordering::SeqCst) {
            return Err(StreamError::Aborted);
        }
        let now = Instant::now();
        if now >= deadline {
            return Err(StreamError::Stalled { fifo: self.name.clone(), op, waited: self.timeout });
        }
        let slice = POLL.min(deadline - now);
        let (st, _) = self
            .cv
            .wait_timeout(st, slice)
            .map_err(|_| StreamError::Inconsistent { what: "fifo mutex poisoned" })?;
        Ok(st)
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Peak elements held at any instant (no allocation — for cheap
    /// serving gauges; `stat()` carries the full named record).
    pub fn peak(&self) -> usize {
        // Gauges must stay readable even after a stage panicked with the
        // lock held — the occupancy fields are monotone and plain data.
        self.state.lock().unwrap_or_else(PoisonError::into_inner).peak
    }

    pub fn stat(&self) -> BufferStat {
        let st = self.state.lock().unwrap_or_else(PoisonError::into_inner);
        BufferStat {
            name: self.name.clone(),
            kind: self.kind,
            capacity: self.capacity,
            peak: st.peak,
        }
    }

    /// The stall/occupancy probe shared with this edge's producer and
    /// consumer stage clocks.
    pub fn probe(&self) -> Arc<obs::FifoProbe> {
        self.probe.clone()
    }

    /// Full edge telemetry: sizing/peak plus the probe counters.
    pub fn edge_stat(&self) -> obs::EdgeStat {
        let stat = self.stat();
        obs::EdgeStat {
            name: stat.name,
            kind: stat.kind,
            capacity: stat.capacity,
            peak: stat.peak,
            blocked_push_ns: self.probe.blocked_push_ns(),
            blocked_pop_ns: self.probe.blocked_pop_ns(),
            push_blocks: self.probe.push_blocks(),
            pop_blocks: self.probe.pop_blocks(),
            occ_hist: self.probe.occ_hist(),
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    fn fifo(cap: usize, timeout_ms: u64) -> Arc<Fifo> {
        Fifo::new(
            "t".into(),
            StreamKind::Output,
            cap,
            Arc::new(AtomicBool::new(false)),
            Duration::from_millis(timeout_ms),
        )
    }

    #[test]
    fn push_pop_roundtrip_tracks_peak() {
        let f = fifo(8, 200);
        f.push(vec![1, 2, 3].into_boxed_slice()).unwrap();
        f.push(vec![4, 5].into_boxed_slice()).unwrap();
        assert_eq!(f.stat().peak, 5);
        assert_eq!(&*f.pop().unwrap(), &[1, 2, 3]);
        assert_eq!(&*f.pop().unwrap(), &[4, 5]);
        assert_eq!(f.stat().peak, 5);
    }

    #[test]
    fn oversized_token_stalls_with_error_not_hang() {
        let f = fifo(2, 50);
        let err = f.push(vec![0; 4].into_boxed_slice()).unwrap_err();
        assert!(matches!(err, StreamError::Stalled { .. }), "{err}");
    }

    #[test]
    fn pop_on_empty_times_out() {
        let f = fifo(4, 50);
        assert!(matches!(f.pop().unwrap_err(), StreamError::Stalled { .. }));
    }

    #[test]
    fn blocked_push_wakes_when_consumer_drains() {
        let f = fifo(3, 2_000);
        f.push(vec![0; 3].into_boxed_slice()).unwrap();
        let f2 = f.clone();
        let h = std::thread::spawn(move || f2.push(vec![7; 3].into_boxed_slice()));
        std::thread::sleep(Duration::from_millis(30));
        assert_eq!(f.pop().unwrap().len(), 3);
        h.join().unwrap().unwrap();
        assert_eq!(&*f.pop().unwrap(), &[7, 7, 7]);
    }

    #[test]
    fn pop_idle_outlives_the_stall_deadline_but_honors_abort() {
        // A frame-boundary pop must not trip stall detection while the
        // pool is simply idle...
        let f = fifo(4, 50);
        let f2 = f.clone();
        let h = std::thread::spawn(move || f2.pop_idle());
        std::thread::sleep(Duration::from_millis(150)); // > the 50ms deadline
        f.push(vec![42].into_boxed_slice()).unwrap();
        assert_eq!(&*h.join().unwrap().unwrap(), &[42]);
        // ...and must still unblock promptly when a peer aborts.
        let abort = Arc::new(AtomicBool::new(false));
        let f = Fifo::new("i".into(), StreamKind::Dma, 4, abort.clone(), Duration::from_secs(30));
        let f2 = f.clone();
        let h = std::thread::spawn(move || f2.pop_idle());
        std::thread::sleep(Duration::from_millis(30));
        abort.store(true, Ordering::SeqCst);
        assert!(matches!(h.join().unwrap().unwrap_err(), StreamError::Aborted));
    }

    #[test]
    fn probe_attributes_blocked_time_to_the_right_side() {
        let f = fifo(3, 2_000);
        f.push(vec![0; 3].into_boxed_slice()).unwrap();
        let f2 = f.clone();
        let h = std::thread::spawn(move || f2.push(vec![7; 3].into_boxed_slice()));
        std::thread::sleep(Duration::from_millis(40));
        assert_eq!(f.pop().unwrap().len(), 3);
        h.join().unwrap().unwrap();
        let e = f.edge_stat();
        assert_eq!(e.push_blocks, 1, "exactly the second push waited");
        assert!(e.blocked_push_ns >= 20_000_000, "waited ~40ms, got {}ns", e.blocked_push_ns);
        assert_eq!(e.pop_blocks, 0, "the pop found a token immediately");
        // Both pushes filled the FIFO to capacity -> top occupancy bucket.
        assert_eq!(e.occ_hist[crate::obs::OCC_BUCKETS - 1], 2);
        assert_eq!(e.peak, 3);
        assert_eq!(e.capacity, 3);
    }

    #[test]
    fn stalled_push_still_accounts_its_wait() {
        let f = fifo(2, 60);
        f.push(vec![1, 2].into_boxed_slice()).unwrap();
        let err = f.push(vec![3, 4].into_boxed_slice()).unwrap_err();
        assert!(matches!(err, StreamError::Stalled { .. }));
        let e = f.edge_stat();
        assert_eq!(e.push_blocks, 1);
        assert!(e.blocked_push_ns >= 40_000_000, "got {}ns", e.blocked_push_ns);
    }

    #[test]
    fn peak_gauge_histograms_observed_occupancy() {
        let g = PeakGauge::new("lb".into(), StreamKind::WindowSlice, 64);
        g.observe(8);
        g.observe(60);
        let e = g.edge_stat();
        assert_eq!(e.peak, 60);
        assert_eq!(e.blocked_push_ns, 0);
        assert_eq!(e.occ_hist[1], 1); // 8/64 -> bucket 1
        assert_eq!(e.occ_hist[7], 1); // 60/64 -> top bucket
    }

    #[test]
    fn abort_unblocks_waiters() {
        let abort = Arc::new(AtomicBool::new(false));
        let f = Fifo::new(
            "a".into(),
            StreamKind::Skip,
            4,
            abort.clone(),
            Duration::from_secs(30),
        );
        let f2 = f.clone();
        let h = std::thread::spawn(move || f2.pop());
        std::thread::sleep(Duration::from_millis(30));
        abort.store(true, Ordering::SeqCst);
        assert!(matches!(h.join().unwrap().unwrap_err(), StreamError::Aborted));
    }
}
