//! The persistent frame-pipelined stream pool.
//!
//! [`StreamPool`] stamps pipeline replicas out of one shared
//! [`PipelineBlueprint`] (planned **once** per pool: FIFO/gauge specs,
//! shapes, ILP lookups, weight validation) and keeps every stage thread
//! alive across frames: frames are submitted to a shared work queue,
//! each replica's *feeder* thread claims the next frame and streams its
//! pixels into the replica's DMA FIFO, and the replica's *sink* thread
//! pops logits and answers the frame's response channel.  Because stages
//! never restart, frame N+1 enters conv0 while frame N is still in the
//! classifier — the frame-level pipelining that gives the paper's
//! free-running dataflow its throughput (Section III-B), which the
//! per-call [`run_streaming`](super::run_streaming) executor pays
//! pipeline-fill latency to approximate one frame at a time.
//!
//! Sizing comes from the board/ILP configuration
//! ([`planned_config`] → `hls::config::configure`): FIFO depths are
//! exactly the depths codegen emits, and each conv stage splits its
//! output channels across up to `och_par` worker threads (the layer's
//! ILP allocation, capped by `StreamConfig::och_worker_cap`).
//!
//! The replica count is either fixed (`StreamConfig::replicas`) or
//! **elastic** (`StreamConfig::elastic`): a controller thread samples
//! the queue depth + in-flight count and grows/drains whole replicas
//! between `min_replicas..=max_replicas` — see [`super::elastic`].
//!
//! Delivery and shutdown guarantees:
//! * results are delivered **per submission** — in-order for a caller
//!   that waits on its tickets in submit order, regardless of
//!   cross-replica completion order;
//! * dropping (or [`shutdown`](StreamPool::shutdown)ing) the pool closes
//!   the queue, flows a zero-length end-of-stream sentinel through every
//!   replica, **drains frames mid-pipeline** (every accepted frame gets a
//!   real response), and joins every thread — no leaks, no lost
//!   responses; a replica drained by the elastic controller gets the
//!   same sentinel treatment, never a mid-frame cut;
//! * a stage failure (e.g. an undersized-FIFO [`StreamError::Stalled`])
//!   aborts its replica, poisons the pool, and fails queued + in-flight
//!   frames with the typed error message — never a hang; a mutex
//!   poisoned by a panicked thread maps to the same typed
//!   [`StreamError::Inconsistent`] path instead of an unwrap panic.
//!
//! [`PipelineBlueprint`]: super::stage::PipelineBlueprint

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::graph::{infer_shapes, Edge, Graph, Op};
use crate::hls::config::{configure, AcceleratorConfig};
use crate::ilp::{solve, LayerLoad};
use crate::models::ModelWeights;
use crate::obs::{self, FifoProbe, PipelineObs, SpanRing, StageClock};
use crate::quant::{QTensor, Shape4};

use super::budget::{BudgetHandle, WorkerLease};
use super::elastic::{controller_loop, LoadSample};
use super::fifo::{BufferStat, Fifo, PeakGauge, StreamError};
use super::stage::{
    eos, guarded, plan_pipeline, push_all, run_stage, PipelineBlueprint, PipelinePlan,
};
use super::{StreamConfig, StreamStats};

/// How often a feeder blocked on an empty queue re-checks the abort flag.
const POLL: Duration = Duration::from_millis(20);

type FrameResult = Result<Vec<i32>, String>;

/// In-flight frame bookkeeping a feeder hands its replica's sink: the
/// responder plus the span timestamps (submit instant, queue wait).
struct PendingFrame {
    resp: mpsc::Sender<FrameResult>,
    submitted: Instant,
    queued_ns: u64,
}

type Pending = Arc<Mutex<VecDeque<PendingFrame>>>;

/// Recover the guard of a poisoned mutex: shutdown, poison and stats
/// paths must always complete even if a stage thread panicked while
/// holding the lock (the guarded data is plain bookkeeping, still valid).
fn recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Lock for the serving hot path: a poisoned lock becomes the typed
/// [`StreamError::Inconsistent`] (degrading into the router's error
/// path) instead of an opaque unwrap panic.
fn locked<'a, T>(m: &'a Mutex<T>, what: &'static str) -> Result<MutexGuard<'a, T>, StreamError> {
    m.lock().map_err(|_| StreamError::Inconsistent { what })
}

/// Build per-layer ILP inputs from the graph itself (Eq. 8): the pool
/// has no `ArchSpec` — serving constructs everything from graph+weights.
fn loads_from_graph(g: &Graph, ow_par: usize) -> Result<Vec<LayerLoad>> {
    let shapes = infer_shapes(g).map_err(|e| anyhow!("{e}"))?;
    let mut loads = Vec::new();
    for n in g.live() {
        if let Op::Conv(a) = &n.op {
            let os = shapes[&Edge::new(n.id, 0)];
            loads.push(LayerLoad {
                name: n.name.clone(),
                macs: (os.h * os.w * a.cout * a.cin * a.k * a.k) as u64,
                taps: a.k * a.k,
                och: a.cout,
                ow_par,
            });
            if let Some(m) = &a.merged_downsample {
                let ds = shapes[&Edge::new(n.id, 1)];
                loads.push(LayerLoad {
                    name: m.name.clone(),
                    macs: (ds.h * ds.w * m.cout * a.cin * m.k * m.k) as u64,
                    taps: m.k * m.k,
                    och: m.cout,
                    ow_par,
                });
            }
        }
    }
    anyhow::ensure!(!loads.is_empty(), "graph has no conv layers");
    Ok(loads)
}

/// The board/ILP-derived accelerator configuration the pool sizes its
/// FIFO depths, `ow_par`, and per-layer `och_par` worker counts from —
/// the executor validates exactly the depths codegen emits (ROADMAP
/// item 3), instead of a fixed ow_par=1 policy.
pub fn planned_config(name: &str, g: &Graph, cfg: &StreamConfig) -> Result<AcceleratorConfig> {
    let loads = loads_from_graph(g, cfg.ow_par)?;
    let alloc = solve(&loads, cfg.board.n_par() as u64)
        .ok_or_else(|| anyhow!("no feasible ILP allocation on {}", cfg.board.name))?;
    configure(name, g, &alloc, cfg.board, cfg.ow_par)
}

/// Response handle for one submitted frame.
pub struct FrameTicket {
    rx: mpsc::Receiver<FrameResult>,
}

impl FrameTicket {
    /// Block until the frame's logits row (or the pipeline's typed error
    /// message) arrives.
    pub fn wait(self) -> Result<Vec<i32>> {
        match self.rx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(msg)) => Err(anyhow!("{msg}")),
            Err(_) => Err(anyhow!("stream pool dropped the frame (worker died)")),
        }
    }
}

struct Job {
    pixels: Box<[i32]>,
    resp: mpsc::Sender<FrameResult>,
    /// When the frame entered the pool (frame-span origin).
    submitted: Instant,
}

struct QueueState {
    jobs: VecDeque<Job>,
    open: bool,
    poison: Option<String>,
}

struct Shared {
    q: Mutex<QueueState>,
    cv: Condvar,
}

struct ReplicaHandle {
    /// Replica id (tag `r{id}/` for id > 0); returned to the free list
    /// on retirement so an oscillating elastic pool reuses tags instead
    /// of growing an unbounded name space.
    id: usize,
    supervisor: Option<JoinHandle<()>>,
    fifos: Vec<Arc<Fifo>>,
    gauges: Vec<Arc<PeakGauge>>,
    /// Raised by the elastic controller to drain this replica: its
    /// feeder stops claiming frames (between frames, never mid-frame)
    /// and flows the end-of-stream sentinel.
    retire: Arc<AtomicBool>,
    /// This replica's stall clocks and span ring.
    obs: PipelineObs,
    /// The worker-budget lease backing this replica's stage threads
    /// (`None` for pools outside a shared budget).  RAII: dropping the
    /// handle — retire, drain, or any failed-spawn unwind in
    /// `add_replica` — returns the workers to the budget, so no error
    /// path can leak a lease.
    _lease: Option<WorkerLease>,
}

/// Everything the pool's threads (and the elastic controller) share.
pub(crate) struct PoolInner {
    name: String,
    shared: Arc<Shared>,
    error: Arc<Mutex<Option<String>>>,
    frames_done: Arc<AtomicUsize>,
    frames_submitted: AtomicUsize,
    /// The router's queue-depth hint (`InferenceBackend::load_hint`),
    /// taken-and-reset by each controller sample.
    router_hint: AtomicUsize,
    replicas: Mutex<Vec<ReplicaHandle>>,
    /// Final buffer stats of the most recent drain of each replica tag.
    /// Bounded: retired ids return to `free_ids` and a re-grown replica
    /// purges its tag's old entries, so an oscillating pool holds at
    /// most one drained stat set per band slot — never one per cycle.
    retired: Mutex<Vec<BufferStat>>,
    peak_replicas: AtomicUsize,
    /// Replica ids freed by retirement, reused before minting new ones.
    free_ids: Mutex<Vec<usize>>,
    next_replica: AtomicUsize,
    /// Elastic scale events since pool start (controller-incremented).
    pub(crate) scale_ups: std::sync::atomic::AtomicU64,
    pub(crate) scale_downs: std::sync::atomic::AtomicU64,
    /// Stops the elastic controller (checked every sample).
    pub(crate) ctl_stop: AtomicBool,
    blueprint: PipelineBlueprint,
    weights: Arc<ModelWeights>,
    min_replicas: usize,
    max_replicas: usize,
    /// Registration against the process-wide [`super::WorkerBudget`]
    /// (reservation = `min_replicas x stages`); every replica's stage
    /// threads are leased through it.  Dropping the handle on pool
    /// teardown releases the reservation.
    budget: Option<BudgetHandle>,
    /// Injection hook for the lease-leak audit: force the next
    /// `add_replica` to fail after its lease is acquired, exercising
    /// the error path a real spawn failure would take.
    #[cfg(test)]
    fail_next_spawn: AtomicBool,
}

impl PoolInner {
    /// Live replica count.
    pub(crate) fn replica_count(&self) -> usize {
        recover(&self.replicas).len()
    }

    /// One controller load sample; `None` means the pool is stopping
    /// (closed or poisoned) and the controller should exit.
    pub(crate) fn sample(&self) -> Option<LoadSample> {
        let depth = {
            let st = self.shared.q.lock().ok()?;
            if !st.open || st.poison.is_some() {
                return None;
            }
            st.jobs.len()
        };
        let hint = self.router_hint.swap(0, Ordering::Relaxed);
        let submitted = self.frames_submitted.load(Ordering::Relaxed);
        let done = self.frames_done.load(Ordering::Relaxed);
        Some(LoadSample {
            queue_depth: depth.saturating_add(hint),
            in_flight: submitted.saturating_sub(done),
        })
    }

    /// Workers one replica costs against the budget: its stage-thread
    /// count (the feeder/sink/supervisor service threads ride along
    /// uncounted — one cheap, mostly-blocked trio per replica).
    pub(crate) fn workers_per_replica(&self) -> usize {
        self.blueprint.stages_per_replica().max(1)
    }

    /// Preemption hint from the shared budget: this pool holds borrowed
    /// workers while another pool's bid is queued.
    pub(crate) fn should_yield(&self) -> bool {
        self.budget.as_ref().is_some_and(BudgetHandle::should_yield)
    }

    /// Withdraw any queued borrow bid (the controller stopped wanting
    /// to grow); no-op without a budget or a queued bid.
    pub(crate) fn cancel_bid(&self) {
        if let Some(b) = &self.budget {
            b.cancel_bid();
        }
    }

    /// Stamp and launch one replica from the shared blueprint.  Cheap
    /// (no re-planning); on a spawn failure the partial thread set is
    /// aborted and joined before the error propagates.  Under a shared
    /// worker budget the replica's stage threads are leased FIRST — a
    /// denied bid fails here before any thread exists, and the lease is
    /// an RAII guard local to this call until the replica joins the
    /// live set, so every later error return releases it.
    pub(crate) fn add_replica(&self) -> Result<()> {
        let lease: Option<WorkerLease> = match &self.budget {
            Some(b) => {
                let workers = self.workers_per_replica();
                Some(b.acquire(workers).ok_or_else(|| {
                    anyhow!(
                        "worker budget denied {workers} worker(s) for {} (cap {}): \
                         peers hold the headroom",
                        self.name,
                        b.budget_snapshot().total
                    )
                })?)
            }
            None => None,
        };
        #[cfg(test)]
        if self.fail_next_spawn.swap(false, Ordering::SeqCst) {
            return Err(anyhow!("injected replica spawn failure"));
        }
        let id = match recover(&self.free_ids).pop() {
            Some(id) => id,
            None => self.next_replica.fetch_add(1, Ordering::SeqCst),
        };
        let tag = if id == 0 { String::new() } else { format!("r{id}/") };
        if !tag.is_empty() {
            // This tag's slot is live again: its previous drain's stats
            // are superseded (their worst pair already reached the
            // metrics layer while the old replica served).
            recover(&self.retired).retain(|b| !b.name.starts_with(&tag));
        }
        let abort = Arc::new(AtomicBool::new(false));
        let retire = Arc::new(AtomicBool::new(false));
        let plan = self.blueprint.instantiate(&abort, &tag);
        let fifos = plan.fifos.clone();
        let gauges = plan.gauges.clone();
        // The replica's observability bundle is wired straight off the
        // plan topology: each stage's clock shares the probes of its own
        // FIFO ports, so stall time attributes itself.
        let robs = PipelineObs::new(
            &tag,
            plan.stages
                .iter()
                .map(|st| {
                    let (ins, outs) = st.ports();
                    (st.name().to_string(), ins, outs)
                })
                .collect(),
            plan.sources.iter().map(|f| (f.name().to_string(), f.probe())).collect(),
            (plan.sink.name().to_string(), plan.sink.probe()),
        );
        let pending: Pending = Arc::new(Mutex::new(VecDeque::new()));
        let handles = spawn_replica(
            &self.name,
            id,
            plan,
            self.weights.clone(),
            self.shared.clone(),
            pending.clone(),
            abort.clone(),
            retire.clone(),
            self.frames_done.clone(),
            self.blueprint.in_c,
            self.blueprint.out_tokens,
            &robs,
        )?;
        // The handles live in a cell the supervisor takes on startup: if
        // its spawn fails, they are still here to abort + join, so the
        // replica's threads are never detached.
        let handle_cell = Arc::new(Mutex::new(Some(handles)));
        let sup = {
            let cell = handle_cell.clone();
            let shared = self.shared.clone();
            let error = self.error.clone();
            let sup_res = thread::Builder::new()
                .name(format!("strm-{}-r{id}-sup", self.name))
                .spawn(move || {
                    // A poisoned or already-claimed cell is a bookkeeping
                    // bug, not a reason to abort the process: recover the
                    // guard, and poison the pool with the typed error so
                    // the router's error path reports it.
                    match recover(&cell).take() {
                        Some(handles) => supervise(handles, &shared, &pending, &error),
                        None => fail_pool(
                            &shared,
                            &pending,
                            &error,
                            &StreamError::Inconsistent {
                                what: "replica thread handles were already claimed",
                            },
                        ),
                    }
                });
            match sup_res {
                Ok(h) => h,
                Err(e) => {
                    abort.store(true, Ordering::SeqCst);
                    if let Some(hs) = recover(&handle_cell).take() {
                        for h in hs {
                            let _ = h.join();
                        }
                    }
                    return Err(anyhow!("failed to spawn pool supervisor: {e}"));
                }
            }
        };
        let mut reps = recover(&self.replicas);
        reps.push(ReplicaHandle {
            id,
            supervisor: Some(sup),
            fifos,
            gauges,
            retire,
            obs: robs,
            _lease: lease,
        });
        self.peak_replicas.fetch_max(reps.len(), Ordering::Relaxed);
        Ok(())
    }

    /// Drain and join the newest replica (LIFO), unless the pool is
    /// already at `min_replicas`.  The replica's feeder stops claiming
    /// frames between frames and flows the end-of-stream sentinel; its
    /// threads are joined before this returns, and its final buffer
    /// stats move to the retired set.  Returns whether a replica was
    /// actually drained.
    ///
    /// The handle stays in the live set for the whole drain: should the
    /// retiring replica still finish a late-claimed frame, concurrent
    /// `replicas()`/`stats()`/`buffered_gauges()` readers keep seeing
    /// its threads and buffers until the join completes — the replica
    /// gauge only drops afterwards.  (Only the controller and the
    /// post-controller shutdown mutate the live set, so the tail handle
    /// cannot change identity mid-drain.)
    pub(crate) fn retire_one(&self) -> bool {
        let sup = {
            let mut reps = recover(&self.replicas);
            if reps.len() <= self.min_replicas {
                return false;
            }
            let Some(h) = reps.last_mut() else { return false };
            h.retire.store(true, Ordering::SeqCst);
            h.supervisor.take()
        };
        self.shared.cv.notify_all();
        if let Some(sup) = sup {
            let _ = sup.join();
        }
        let Some(h) = recover(&self.replicas).pop() else { return false };
        {
            let mut retired = recover(&self.retired);
            retired.extend(h.fifos.iter().map(|f| f.stat()));
            retired.extend(h.gauges.iter().map(|g| g.stat()));
        }
        recover(&self.free_ids).push(h.id);
        true
    }
}

/// A running pool of persistent pipeline replicas behind one work queue.
pub struct StreamPool {
    inner: Arc<PoolInner>,
    controller: Option<JoinHandle<()>>,
}

impl StreamPool {
    /// Plan the pool once (ILP/board configuration + one pipeline
    /// blueprint), then launch its replicas: a fixed `cfg.replicas`, or
    /// — with `cfg.elastic` set — `min_replicas` plus a controller
    /// thread that grows/drains the pool under load.  `name` labels
    /// threads and the configuration.
    pub fn new(
        name: &str,
        g: &Graph,
        weights: Arc<ModelWeights>,
        cfg: StreamConfig,
    ) -> Result<StreamPool> {
        let elastic = cfg.elastic.clone();
        let (initial, min_replicas, max_replicas) = match &elastic {
            Some(e) => {
                let min = e.min_replicas.max(1);
                anyhow::ensure!(
                    e.max_replicas >= min,
                    "elastic band empty: max_replicas {} < min_replicas {min}",
                    e.max_replicas
                );
                (min, min, e.max_replicas)
            }
            None => {
                let r = cfg.replicas.max(1);
                (r, r, r)
            }
        };
        let acfg = planned_config(name, g, &cfg)?;
        let blueprint = plan_pipeline(g, &weights, &cfg, &acfg)?;
        // Register against the shared worker budget before any replica
        // spawns: the reservation (`min_replicas x stages`) guarantees
        // the floor is always grantable, and an impossible cap is a
        // typed startup error instead of runtime starvation.
        let budget = match &cfg.budget {
            Some(b) => {
                let stages = blueprint.stages_per_replica().max(1);
                Some(b.register(name, min_replicas.saturating_mul(stages))?)
            }
            None => None,
        };
        let inner = Arc::new(PoolInner {
            name: name.to_string(),
            shared: Arc::new(Shared {
                q: Mutex::new(QueueState { jobs: VecDeque::new(), open: true, poison: None }),
                cv: Condvar::new(),
            }),
            error: Arc::new(Mutex::new(None)),
            frames_done: Arc::new(AtomicUsize::new(0)),
            frames_submitted: AtomicUsize::new(0),
            router_hint: AtomicUsize::new(0),
            replicas: Mutex::new(Vec::with_capacity(initial)),
            retired: Mutex::new(Vec::new()),
            peak_replicas: AtomicUsize::new(0),
            free_ids: Mutex::new(Vec::new()),
            next_replica: AtomicUsize::new(0),
            scale_ups: std::sync::atomic::AtomicU64::new(0),
            scale_downs: std::sync::atomic::AtomicU64::new(0),
            ctl_stop: AtomicBool::new(false),
            blueprint,
            weights,
            min_replicas,
            max_replicas,
            budget,
            #[cfg(test)]
            fail_next_spawn: AtomicBool::new(false),
        });
        let mut pool = StreamPool { inner: inner.clone(), controller: None };
        for _ in 0..initial {
            // If a later replica fails to spawn, dropping `pool` closes
            // the queue and joins the replicas already running.
            inner.add_replica()?;
        }
        if let Some(e) = elastic {
            let high = inner.blueprint.stages_per_replica().max(1);
            let ctl = thread::Builder::new()
                .name(format!("strm-{name}-elastic"))
                .spawn({
                    let inner = inner.clone();
                    move || controller_loop(&inner, &e, high)
                })
                .map_err(|err| anyhow!("failed to spawn elastic controller: {err}"))?;
            pool.controller = Some(ctl);
        }
        Ok(pool)
    }

    /// Submit one frame (row-major `h*w*c` pixels at the input exponent);
    /// returns immediately with the frame's response ticket.
    pub fn submit(&self, pixels: &[i32]) -> Result<FrameTicket> {
        let bp = &self.inner.blueprint;
        let want = bp.in_h * bp.in_w * bp.in_c;
        anyhow::ensure!(
            pixels.len() == want,
            "frame has {} pixels, expected {want} ({}x{}x{})",
            pixels.len(),
            bp.in_h,
            bp.in_w,
            bp.in_c
        );
        let (tx, rx) = mpsc::channel();
        {
            let mut st = locked(&self.inner.shared.q, "work-queue lock poisoned")
                .map_err(|e| anyhow!("{e}"))?;
            if let Some(p) = &st.poison {
                return Err(anyhow!("{p}"));
            }
            anyhow::ensure!(st.open, "stream pool stopped");
            st.jobs.push_back(Job {
                pixels: Box::from(pixels),
                resp: tx,
                submitted: Instant::now(),
            });
            self.inner.frames_submitted.fetch_add(1, Ordering::Relaxed);
        }
        self.inner.shared.cv.notify_one();
        Ok(FrameTicket { rx })
    }

    /// Run a whole batch through the pool: every frame is enqueued before
    /// the first result is awaited, so up to the pool's in-flight
    /// capacity of frames pipeline concurrently.  Results are assembled
    /// in submission order (bit-identical to the golden model).
    pub fn infer(&self, input: &QTensor) -> Result<QTensor> {
        let bp = &self.inner.blueprint;
        let n = input.shape.n;
        anyhow::ensure!(n >= 1, "empty input batch");
        anyhow::ensure!(
            (input.shape.h, input.shape.w, input.shape.c) == (bp.in_h, bp.in_w, bp.in_c),
            "input shape {} vs expected ({},{},{})",
            input.shape,
            bp.in_h,
            bp.in_w,
            bp.in_c
        );
        anyhow::ensure!(
            input.exp == bp.in_exp,
            "input exp {} vs expected {}",
            input.exp,
            bp.in_exp
        );
        let frame = bp.in_h * bp.in_w * bp.in_c;
        let mut tickets = Vec::with_capacity(n);
        for i in 0..n {
            tickets.push(self.submit(&input.data[i * frame..(i + 1) * frame])?);
        }
        let classes = bp.classes;
        let mut out = Vec::with_capacity(n * classes);
        for t in tickets {
            out.extend_from_slice(&t.wait()?);
        }
        Ok(QTensor::from_vec(Shape4::new(n, 1, 1, classes), 0, out))
    }

    /// Live pipeline replicas behind the shared queue (an elastic pool
    /// moves this between its band's min and max).
    pub fn replicas(&self) -> usize {
        self.inner.replica_count()
    }

    /// The highest live replica count the pool ever reached.
    pub fn peak_replicas(&self) -> usize {
        self.inner.peak_replicas.load(Ordering::Relaxed)
    }

    /// The replica band floor (equals `replicas` for a fixed pool).
    pub fn min_replicas(&self) -> usize {
        self.inner.min_replicas
    }

    /// The replica band ceiling (equals `replicas` for a fixed pool).
    pub fn max_replicas(&self) -> usize {
        self.inner.max_replicas
    }

    /// Frames the pool can usefully hold in flight at its band maximum:
    /// one per stage per replica (each persistent stage works on its own
    /// frame).  Batcher buckets are sized to this, so an elastic pool is
    /// handed enough queued frames to justify growing.
    pub fn capacity(&self) -> usize {
        (self.inner.blueprint.stages_per_replica() * self.inner.max_replicas).max(1)
    }

    /// Stage workers one replica costs against a shared
    /// [`super::WorkerBudget`] (the lease unit: `stages` threads — the
    /// feeder/sink/supervisor service trio rides along uncounted).
    pub fn workers_per_replica(&self) -> usize {
        self.inner.workers_per_replica()
    }

    /// This pool's `(held, reserved, denied)` worker-budget row, `None`
    /// without a shared budget.  Feeds the per-arch lease gauges.
    pub fn budget_stat(&self) -> Option<(usize, usize, u64)> {
        self.inner.budget.as_ref().and_then(BudgetHandle::stat)
    }

    /// Logit classes per frame.
    pub fn classes(&self) -> usize {
        self.inner.blueprint.classes
    }

    /// Frames completed since the pool started.
    pub fn frames(&self) -> usize {
        self.inner.frames_done.load(Ordering::Relaxed)
    }

    /// Serving-layer load hint (the router's per-arch queue depth): the
    /// elastic controller folds the highest hint since its last sample
    /// into the scaling signal.  No-op for a fixed pool beyond a cheap
    /// atomic store.
    pub fn load_hint(&self, queued: usize) {
        self.inner.router_hint.fetch_max(queued, Ordering::Relaxed);
    }

    /// First pipeline error, if any replica failed.
    pub fn error(&self) -> Option<String> {
        recover(&self.inner.error).clone()
    }

    /// Cumulative buffering snapshot, readable while the pool runs:
    /// every live replica's FIFOs and line buffers (replica `i > 0`
    /// names are prefixed `r{i}/`), plus the final stats of replicas the
    /// elastic controller drained; the whole-tensor comparison is scaled
    /// by the peak replica count (a non-streaming executor running R
    /// concurrent frames materializes R whole-tensor sets).
    pub fn stats(&self) -> StreamStats {
        let mut buffers = Vec::new();
        {
            let reps = recover(&self.inner.replicas);
            for r in reps.iter() {
                buffers.extend(r.fifos.iter().map(|f| f.stat()));
                buffers.extend(r.gauges.iter().map(|g| g.stat()));
            }
        }
        buffers.extend(recover(&self.inner.retired).iter().cloned());
        StreamStats {
            buffers,
            frames: self.frames(),
            whole_tensor_elems: self.inner.blueprint.whole_tensor_elems
                * self.peak_replicas().max(1),
        }
    }

    /// Cheap gauge pair for the serving metrics, recorded after every
    /// batch: `(summed peak occupancy across every *live* replica's
    /// buffers, peak-replica-scaled whole-tensor base)` — atomics/locks
    /// only, no per-buffer name clones (use
    /// [`stats`](StreamPool::stats) for the full named report).
    /// Drained replicas are deliberately excluded: their worst pair was
    /// exported while they served (the metrics layer keeps the maximum),
    /// and summing every past generation on top of the live ones would
    /// inflate the buffered fraction without bound on an oscillating
    /// elastic pool.
    pub fn buffered_gauges(&self) -> (usize, usize) {
        let peak: usize = {
            let reps = recover(&self.inner.replicas);
            reps.iter()
                .map(|r| {
                    r.fifos.iter().map(|f| f.peak()).sum::<usize>()
                        + r.gauges.iter().map(|g| g.peak()).sum::<usize>()
                })
                .sum()
        };
        (peak, self.inner.blueprint.whole_tensor_elems * self.peak_replicas().max(1))
    }

    /// Replica-aggregated stall/occupancy report: per-stage wall-time
    /// splits (feeder + layer stages + sink, replica tags stripped and
    /// counters summed), per-edge FIFO telemetry, and the pool gauges.
    /// Readable while the pool runs — atomics and the bookkeeping locks
    /// only, never a stage-thread join.
    pub fn stall_report(&self) -> obs::StallReport {
        let (stage_rows, edge_rows, replicas) = {
            let reps = recover(&self.inner.replicas);
            let mut stage_rows = Vec::new();
            let mut edge_rows = Vec::new();
            for r in reps.iter() {
                stage_rows.extend(r.obs.stalls());
                edge_rows.extend(r.fifos.iter().map(|f| f.edge_stat()));
                edge_rows.extend(r.gauges.iter().map(|g| g.edge_stat()));
            }
            (stage_rows, edge_rows, reps.len())
        };
        obs::StallReport {
            stages: obs::StallReport::aggregate_stages(stage_rows),
            edges: obs::StallReport::aggregate_edges(edge_rows),
            frames: self.frames() as u64,
            replicas,
            peak_replicas: self.peak_replicas(),
            scale_ups: self.inner.scale_ups.load(Ordering::Relaxed),
            scale_downs: self.inner.scale_downs.load(Ordering::Relaxed),
            budget: self.inner.budget.as_ref().map(BudgetHandle::budget_snapshot),
        }
    }

    /// The pipeline-limiting verdict derived from the current stall
    /// report.
    pub fn bottleneck(&self) -> obs::BottleneckReport {
        self.stall_report().bottleneck()
    }

    /// Frame spans still held in the replicas' bounded rings, oldest
    /// first per replica (best effort — see [`obs::PipelineObs`]).
    pub fn recent_spans(&self) -> Vec<obs::FrameSpan> {
        let reps = recover(&self.inner.replicas);
        reps.iter().flat_map(|r| r.obs.recent_spans()).collect()
    }

    /// Graceful shutdown: stop accepting frames, drain everything
    /// in-flight (every accepted frame still gets its response), join all
    /// threads, and return the final buffering stats.
    pub fn shutdown(mut self) -> StreamStats {
        self.close_and_join();
        self.stats()
    }

    fn close_and_join(&mut self) {
        // Stop the elastic controller first so it cannot add or retire
        // replicas concurrently with the drain.
        self.inner.ctl_stop.store(true, Ordering::SeqCst);
        if let Some(c) = self.controller.take() {
            let _ = c.join();
        }
        {
            let mut st = recover(&self.inner.shared.q);
            st.open = false;
        }
        self.inner.shared.cv.notify_all();
        let handles: Vec<ReplicaHandle> = recover(&self.inner.replicas).drain(..).collect();
        let mut retired = Vec::new();
        for mut r in handles {
            if let Some(h) = r.supervisor.take() {
                let _ = h.join();
            }
            retired.extend(r.fifos.iter().map(|f| f.stat()));
            retired.extend(r.gauges.iter().map(|g| g.stat()));
        }
        recover(&self.inner.retired).extend(retired);
    }
}

impl Drop for StreamPool {
    fn drop(&mut self) {
        // Same drain semantics as shutdown(): frames mid-pipeline finish,
        // every thread is joined — a dropped pool never leaks threads or
        // responses.
        self.close_and_join();
    }
}

/// Spawn one replica's feeder + stage + sink threads; on a spawn failure
/// the replica's partial thread set is aborted and joined before the
/// error propagates.
#[allow(clippy::too_many_arguments)]
fn spawn_replica(
    name: &str,
    r: usize,
    plan: PipelinePlan,
    weights: Arc<ModelWeights>,
    shared: Arc<Shared>,
    pending: Pending,
    abort: Arc<AtomicBool>,
    retire: Arc<AtomicBool>,
    frames_done: Arc<AtomicUsize>,
    in_c: usize,
    out_tokens: usize,
    robs: &PipelineObs,
) -> Result<Vec<JoinHandle<Result<(), StreamError>>>> {
    let PipelinePlan { stages, sources, sink, .. } = plan;
    let mut handles: Vec<JoinHandle<Result<(), StreamError>>> = Vec::new();
    let res = (|| -> Result<()> {
        spawn_thread(format!("strm-{name}-r{r}-feed"), &mut handles, &abort, {
            let shared = shared.clone();
            let abort = abort.clone();
            let pending = pending.clone();
            let clock = robs.feeder.clone();
            let queue_probe = robs.queue_probe.clone();
            move || {
                feeder_loop(&shared, &abort, &retire, &sources, &pending, in_c, &clock, &queue_probe)
            }
        })?;
        for (st, clock) in stages.into_iter().zip(robs.stages.iter().cloned()) {
            let w = weights.clone();
            spawn_thread(format!("strm-{}", st.name()), &mut handles, &abort, move || {
                run_stage(&st, &w, &clock)
            })?;
        }
        spawn_thread(format!("strm-{name}-r{r}-sink"), &mut handles, &abort, {
            let pending = pending.clone();
            let frames_done = frames_done.clone();
            let clock = robs.sink.clone();
            let spans = robs.spans.clone();
            move || sink_loop(&sink, out_tokens, &pending, &frames_done, &clock, &spans)
        })?;
        Ok(())
    })();
    match res {
        Ok(()) => Ok(handles),
        Err(e) => {
            abort.store(true, Ordering::SeqCst);
            for h in handles {
                let _ = h.join();
            }
            Err(e)
        }
    }
}

fn spawn_thread(
    name: String,
    handles: &mut Vec<JoinHandle<Result<(), StreamError>>>,
    abort: &Arc<AtomicBool>,
    f: impl FnOnce() -> Result<(), StreamError> + Send + 'static,
) -> Result<()> {
    let a = abort.clone();
    let h = thread::Builder::new()
        .name(name)
        .spawn(move || guarded(&a, f))
        .map_err(|e| anyhow!("failed to spawn stream pool thread: {e}"))?;
    handles.push(h);
    Ok(())
}

/// Claim frames off the shared queue and stream their pixels into the
/// replica's DMA FIFO(s); on queue close, pool poison, or a retire
/// request from the elastic controller, flow the end-of-stream sentinel
/// so the replica drains and exits cleanly — retirement is only ever
/// observed *between* frames, never mid-frame.
#[allow(clippy::too_many_arguments)]
fn feeder_loop(
    shared: &Shared,
    abort: &AtomicBool,
    retire: &AtomicBool,
    sources: &[Arc<Fifo>],
    pending: &Pending,
    in_c: usize,
    clock: &StageClock,
    queue_probe: &FifoProbe,
) -> Result<(), StreamError> {
    loop {
        let job = {
            // Time blocked waiting for work is the feeder's
            // "blocked-on-pop" — recorded against its synthetic queue
            // probe only once it actually waits.
            let mut blocked_since: Option<Instant> = None;
            let mut st = locked(&shared.q, "work-queue lock poisoned")?;
            let claimed = loop {
                if abort.load(Ordering::SeqCst) {
                    return Err(StreamError::Aborted);
                }
                if retire.load(Ordering::SeqCst) {
                    break None;
                }
                if st.poison.is_some() {
                    break None;
                }
                if let Some(j) = st.jobs.pop_front() {
                    break Some(j);
                }
                if !st.open {
                    break None;
                }
                if blocked_since.is_none() && obs::enabled() {
                    blocked_since = Some(Instant::now());
                }
                let (g, _) = shared
                    .cv
                    .wait_timeout(st, POLL)
                    .map_err(|_| StreamError::Inconsistent { what: "work-queue lock poisoned" })?;
                st = g;
            };
            drop(st);
            if let (Some(t0), Some(_)) = (blocked_since, claimed.as_ref()) {
                queue_probe.record_pop_block(t0.elapsed());
            }
            claimed
        };
        match job {
            Some(job) => {
                let queued_ns = job.submitted.elapsed().as_nanos() as u64;
                // Register the responder *before* the first pixel: the
                // sink pairs results with this queue in feed order.
                locked(pending, "pending-responders lock poisoned")?.push_back(PendingFrame {
                    resp: job.resp,
                    submitted: job.submitted,
                    queued_ns,
                });
                for px in job.pixels.chunks_exact(in_c) {
                    push_all(sources, Box::from(px))?;
                }
                clock.frame_done();
            }
            None => {
                for f in sources {
                    f.push(eos())?;
                }
                return Ok(());
            }
        }
    }
}

/// Pop one frame's worth of output tokens (one logits token for a
/// classifier head, `out_tokens` pixel tokens for a spatial head) and
/// answer the frame's responder with the concatenated values.
fn sink_loop(
    sink: &Fifo,
    out_tokens: usize,
    pending: &Pending,
    frames_done: &AtomicUsize,
    clock: &StageClock,
    spans: &SpanRing,
) -> Result<(), StreamError> {
    loop {
        // Deadline-free: the sink legitimately idles while the pool has
        // no traffic (mid-frame stalls surface on the stages' bounded
        // pushes/pops and unblock this pop via the abort flag).
        let mut tok = sink.pop_idle()?.to_vec();
        if tok.is_empty() {
            return Ok(());
        }
        for _ in 1..out_tokens {
            tok.extend_from_slice(&sink.pop()?);
        }
        // Invariant: the feeder registered a responder before streaming
        // the frame, and this replica completes frames in feed order.  A
        // violated invariant degrades this replica into the supervisor's
        // typed error path (poisoning the pool) instead of aborting the
        // serving process.
        let pf = locked(pending, "pending-responders lock poisoned")?
            .pop_front()
            .ok_or(StreamError::Inconsistent {
                what: "sink produced a frame with no pending submitter",
            })?;
        let _ = pf.resp.send(Ok(tok));
        if obs::enabled() {
            // Replica-local frame index = completed frames so far; the
            // span must be in the ring before frame_done makes it
            // visible to readers.
            spans.record(
                clock.frames(),
                Duration::from_nanos(pf.queued_ns),
                pf.submitted.elapsed(),
            );
        }
        clock.frame_done();
        frames_done.fetch_add(1, Ordering::Relaxed);
    }
}

/// Join every replica thread; on failure, record the first real error,
/// poison the pool (queued and in-flight frames fail with the typed
/// message — never a silent drop, never a hang).
fn supervise(
    handles: Vec<JoinHandle<Result<(), StreamError>>>,
    shared: &Shared,
    pending: &Pending,
    error: &Mutex<Option<String>>,
) {
    let mut first: Option<StreamError> = None;
    for h in handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                if !matches!(e, StreamError::Aborted) && first.is_none() {
                    first = Some(e);
                }
            }
            Err(_) => {
                if first.is_none() {
                    first = Some(StreamError::Panicked);
                }
            }
        }
    }
    if let Some(e) = first {
        fail_pool(shared, pending, error, &e);
    }
}

/// Poison the pool with a typed error: record it, close the queue, fail
/// every queued and in-flight frame with the message.  Shared by the
/// supervisor's join path and its startup invariant checks, so a
/// degraded replica always lands in the router's error path.  All locks
/// are taken poison-tolerantly: a panicked stage must not be able to
/// block the poison report itself.
fn fail_pool(shared: &Shared, pending: &Pending, error: &Mutex<Option<String>>, e: &StreamError) {
    let msg = format!("streaming execution failed: {e}");
    {
        let mut slot = recover(error);
        if slot.is_none() {
            *slot = Some(msg.clone());
        }
    }
    let drained: Vec<Job> = {
        let mut st = recover(&shared.q);
        if st.poison.is_none() {
            st.poison = Some(msg.clone());
        }
        st.jobs.drain(..).collect()
    };
    shared.cv.notify_all();
    for j in drained {
        let _ = j.resp.send(Err(msg.clone()));
    }
    for pf in recover(pending).drain(..) {
        let _ = pf.resp.send(Err(msg.clone()));
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::hls::streams::StreamKind;
    use crate::obs::StageRole;

    fn sink_clock() -> (Arc<StageClock>, Arc<SpanRing>) {
        (
            StageClock::new("sink".into(), StageRole::Sink, Instant::now(), vec![], vec![]),
            SpanRing::new(),
        )
    }

    fn pending_frame(resp: mpsc::Sender<FrameResult>) -> PendingFrame {
        PendingFrame { resp, submitted: Instant::now(), queued_ns: 0 }
    }

    /// Regression (was `.expect("sink produced a frame with no pending
    /// submitter")`): an inconsistent pending queue must surface as the
    /// typed error, not a process abort.
    #[test]
    fn sink_without_pending_submitter_is_a_typed_error() {
        let abort = Arc::new(AtomicBool::new(false));
        let sink = Fifo::new(
            "t.out".into(),
            StreamKind::Dma,
            16,
            abort,
            Duration::from_millis(200),
        );
        sink.push(vec![1, 2, 3].into_boxed_slice()).unwrap();
        let pending: Pending = Arc::new(Mutex::new(VecDeque::new()));
        let frames = AtomicUsize::new(0);
        let (clock, spans) = sink_clock();
        let err = sink_loop(&sink, &pending, &frames, &clock, &spans).unwrap_err();
        assert!(
            matches!(err, StreamError::Inconsistent { .. }),
            "expected Inconsistent, got {err:?}"
        );
        assert!(format!("{err}").contains("no pending submitter"), "{err}");
        assert_eq!(frames.load(Ordering::Relaxed), 0);
    }

    /// Regression (was `.expect("handles unclaimed")`): the supervisor's
    /// inconsistent-state path poisons the pool — queued and in-flight
    /// frames fail with the typed message, new submissions fail fast.
    #[test]
    fn fail_pool_poisons_queue_and_pending() {
        let shared = Arc::new(Shared {
            q: Mutex::new(QueueState { jobs: VecDeque::new(), open: true, poison: None }),
            cv: Condvar::new(),
        });
        let (qtx, qrx) = mpsc::channel();
        shared
            .q
            .lock()
            .unwrap()
            .jobs
            .push_back(Job { pixels: Box::from([0i32; 4]), resp: qtx, submitted: Instant::now() });
        let pending: Pending = Arc::new(Mutex::new(VecDeque::new()));
        let (ptx, prx) = mpsc::channel();
        pending.lock().unwrap().push_back(pending_frame(ptx));
        let error = Mutex::new(None);
        fail_pool(
            &shared,
            &pending,
            &error,
            &StreamError::Inconsistent { what: "replica thread handles were already claimed" },
        );
        // Every queued and in-flight frame got the typed failure...
        let queued = qrx.recv().unwrap().unwrap_err();
        assert!(queued.contains("already claimed"), "{queued}");
        let inflight = prx.recv().unwrap().unwrap_err();
        assert!(inflight.contains("already claimed"), "{inflight}");
        // ...the error is recorded, and the queue is poisoned for
        // follow-up submissions (StreamPool::submit checks this field).
        assert!(error.lock().unwrap().as_deref().unwrap().contains("inconsistent"));
        let st = shared.q.lock().unwrap();
        assert!(st.poison.as_deref().unwrap().contains("already claimed"));
        assert!(st.jobs.is_empty());
    }

    /// Regression for the `lock().unwrap()` audit: a work-queue mutex
    /// poisoned by a panicked thread must degrade the feeder into the
    /// typed `Inconsistent` error (the supervisor then poisons the pool
    /// through the recovered guard) — not convert every later call into
    /// an opaque unwrap panic.
    #[test]
    fn poisoned_work_queue_is_typed_for_the_feeder_and_recoverable_for_poisoning() {
        let shared = Arc::new(Shared {
            q: Mutex::new(QueueState { jobs: VecDeque::new(), open: true, poison: None }),
            cv: Condvar::new(),
        });
        let s2 = shared.clone();
        let _ = thread::spawn(move || {
            let _g = s2.q.lock().unwrap();
            panic!("poison the queue lock");
        })
        .join();
        assert!(shared.q.lock().is_err(), "queue lock should be poisoned");
        let abort = AtomicBool::new(false);
        let retire = AtomicBool::new(false);
        let pending: Pending = Arc::new(Mutex::new(VecDeque::new()));
        let clock =
            StageClock::new("feeder".into(), StageRole::Feeder, Instant::now(), vec![], vec![]);
        let probe = FifoProbe::new();
        let err =
            feeder_loop(&shared, &abort, &retire, &[], &pending, 3, &clock, &probe).unwrap_err();
        assert!(matches!(err, StreamError::Inconsistent { .. }), "{err}");
        assert!(format!("{err}").contains("lock poisoned"), "{err}");
        // fail_pool still completes on the poisoned lock (recovered
        // guard) so the pool lands in the normal poisoned-queue state.
        let error = Mutex::new(None);
        fail_pool(&shared, &pending, &error, &err);
        assert!(recover(&shared.q).poison.as_deref().unwrap().contains("lock poisoned"));
    }

    /// An oscillating elastic pool must not grow its diagnostic state
    /// without bound: a drained replica's id (and `r{id}/` tag) is
    /// reused by the next grow, which purges the tag's superseded
    /// retired stats — so the retired set holds at most one drained
    /// stat set per band slot, never one per grow/drain cycle.
    #[test]
    fn retired_replica_tags_are_reused_and_stats_stay_bounded() {
        use crate::models::{arch_by_name, build_optimized_graph, synthetic_weights};
        use crate::stream::ElasticConfig;

        let arch = arch_by_name("resnet8").unwrap();
        let weights = synthetic_weights(&arch, 7);
        let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
        let cfg = StreamConfig {
            elastic: Some(ElasticConfig {
                min_replicas: 1,
                max_replicas: 2,
                // Effectively passive: no load to scale up on, and the
                // idle streak can never reach this before the test ends.
                scale_down_samples: 1_000_000,
                ..Default::default()
            }),
            ..Default::default()
        };
        let pool = StreamPool::new("resnet8", &g, Arc::new(weights), cfg).unwrap();
        assert_eq!(pool.replicas(), 1);
        pool.inner.add_replica().unwrap();
        assert_eq!(pool.replicas(), 2);
        assert!(pool.inner.retire_one());
        assert_eq!(pool.replicas(), 1);
        let drained = recover(&pool.inner.retired).len();
        assert!(drained > 0, "drained replica must leave its final stats");
        assert!(recover(&pool.inner.retired).iter().all(|b| b.name.starts_with("r1/")));
        // Grow + drain again: the tag is reused, the set does not grow.
        pool.inner.add_replica().unwrap();
        assert_eq!(recover(&pool.inner.retired).len(), 0, "re-grown tag purges old stats");
        assert!(pool.inner.retire_one());
        assert_eq!(recover(&pool.inner.retired).len(), drained);
        assert_eq!(pool.peak_replicas(), 2);
        // Live-only gauges: the drained replica's history must not
        // inflate the per-batch buffered gauge (the metrics layer keeps
        // the worst pair recorded while it served).
        let (peak, _) = pool.buffered_gauges();
        assert_eq!(peak, 0, "idle live replica; retired peaks excluded");
    }

    /// Lease-leak audit: a scale-up that fails *after* its budget lease
    /// was granted must return the lease.  The `fail_next_spawn` hook
    /// injects the failure in the exact window a leak would hide in —
    /// between lease acquisition and replica construction — and the
    /// budget's held gauge must come back to its pre-call value, with
    /// the headroom still grantable to a real grow afterwards.
    #[test]
    fn failed_scale_up_returns_its_budget_lease() {
        use crate::models::{arch_by_name, build_optimized_graph, synthetic_weights};
        use crate::stream::{ElasticConfig, WorkerBudget};

        let arch = arch_by_name("resnet8").unwrap();
        let weights = synthetic_weights(&arch, 7);
        let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
        // Generous cap: denial is not what this test exercises.
        let budget = Arc::new(WorkerBudget::new(1024));
        let cfg = StreamConfig {
            elastic: Some(ElasticConfig {
                min_replicas: 1,
                max_replicas: 3,
                // Passive controller: the test drives scaling by hand.
                scale_down_samples: 1_000_000,
                ..Default::default()
            }),
            budget: Some(budget.clone()),
            ..Default::default()
        };
        let pool = StreamPool::new("resnet8", &g, Arc::new(weights), cfg).unwrap();
        let per = pool.workers_per_replica();
        assert!(per >= 1);
        // The initial replica holds exactly the reservation.
        let (held0, reserved, _) = pool.budget_stat().unwrap();
        assert_eq!(held0, per);
        assert_eq!(reserved, per);
        assert_eq!(budget.snapshot().held, per);
        // Inject: the next spawn fails after the lease is acquired.
        pool.inner.fail_next_spawn.store(true, Ordering::SeqCst);
        let err = pool.inner.add_replica().unwrap_err();
        assert!(format!("{err}").contains("injected replica spawn failure"), "{err}");
        assert_eq!(pool.replicas(), 1);
        let (held1, _, _) = pool.budget_stat().unwrap();
        assert_eq!(held1, held0, "failed scale-up leaked a worker lease");
        assert_eq!(budget.snapshot().held, per);
        // The returned headroom still grants: a real grow borrows it...
        pool.inner.add_replica().unwrap();
        assert_eq!(pool.replicas(), 2);
        assert_eq!(budget.snapshot().held, 2 * per);
        // ...and retiring returns it again.
        assert!(pool.inner.retire_one());
        assert_eq!(budget.snapshot().held, per);
    }

    /// Same audit for the sink's pending-responders lock.
    #[test]
    fn poisoned_pending_lock_is_typed_for_the_sink() {
        let pending: Pending = Arc::new(Mutex::new(VecDeque::new()));
        let p2 = pending.clone();
        let _ = thread::spawn(move || {
            let _g = p2.lock().unwrap();
            panic!("poison the pending lock");
        })
        .join();
        let sink = Fifo::new(
            "t.out".into(),
            StreamKind::Dma,
            16,
            Arc::new(AtomicBool::new(false)),
            Duration::from_millis(200),
        );
        sink.push(vec![1].into_boxed_slice()).unwrap();
        let frames = AtomicUsize::new(0);
        let (clock, spans) = sink_clock();
        let err = sink_loop(&sink, &pending, &frames, &clock, &spans).unwrap_err();
        assert!(matches!(err, StreamError::Inconsistent { .. }), "{err}");
        assert!(format!("{err}").contains("lock poisoned"), "{err}");
    }
}
