//! The persistent frame-pipelined stream pool.
//!
//! [`StreamPool`] spawns `StreamConfig::replicas` copies of the streaming
//! pipeline **once** and keeps every stage thread alive across frames:
//! frames are submitted to a shared work queue, each replica's *feeder*
//! thread claims the next frame and streams its pixels into the replica's
//! DMA FIFO, and the replica's *sink* thread pops logits and answers the
//! frame's response channel.  Because stages never restart, frame N+1
//! enters conv0 while frame N is still in the classifier — the
//! frame-level pipelining that gives the paper's free-running dataflow
//! its throughput (Section III-B), which the per-call
//! [`run_streaming`](super::run_streaming) executor pays pipeline-fill
//! latency to approximate one frame at a time.
//!
//! Sizing comes from the board/ILP configuration
//! ([`planned_config`] → `hls::config::configure`): FIFO depths are
//! exactly the depths codegen emits, and each conv stage splits its
//! output channels across up to `och_par` worker threads (the layer's
//! ILP allocation, capped by `StreamConfig::och_worker_cap`).
//!
//! Delivery and shutdown guarantees:
//! * results are delivered **per submission** — in-order for a caller
//!   that waits on its tickets in submit order, regardless of
//!   cross-replica completion order;
//! * dropping (or [`shutdown`](StreamPool::shutdown)ing) the pool closes
//!   the queue, flows a zero-length end-of-stream sentinel through every
//!   replica, **drains frames mid-pipeline** (every accepted frame gets a
//!   real response), and joins every thread — no leaks, no lost
//!   responses;
//! * a stage failure (e.g. an undersized-FIFO [`StreamError::Stalled`])
//!   aborts its replica, poisons the pool, and fails queued + in-flight
//!   frames with the typed error message — never a hang.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::graph::{infer_shapes, Edge, Graph, Op};
use crate::hls::config::{configure, AcceleratorConfig};
use crate::ilp::{solve, LayerLoad};
use crate::models::ModelWeights;
use crate::quant::{QTensor, Shape4};

use super::fifo::{Fifo, PeakGauge, StreamError};
use super::stage::{eos, guarded, plan_pipeline, push_all, run_stage, PipelinePlan};
use super::{StreamConfig, StreamStats};

/// How often a feeder blocked on an empty queue re-checks the abort flag.
const POLL: Duration = Duration::from_millis(20);

type FrameResult = Result<Vec<i32>, String>;
type Pending = Arc<Mutex<VecDeque<mpsc::Sender<FrameResult>>>>;

/// Build per-layer ILP inputs from the graph itself (Eq. 8): the pool
/// has no `ArchSpec` — serving constructs everything from graph+weights.
fn loads_from_graph(g: &Graph, ow_par: usize) -> Result<Vec<LayerLoad>> {
    let shapes = infer_shapes(g).map_err(|e| anyhow!("{e}"))?;
    let mut loads = Vec::new();
    for n in g.live() {
        if let Op::Conv(a) = &n.op {
            let os = shapes[&Edge::new(n.id, 0)];
            loads.push(LayerLoad {
                name: n.name.clone(),
                macs: (os.h * os.w * a.cout * a.cin * a.k * a.k) as u64,
                taps: a.k * a.k,
                och: a.cout,
                ow_par,
            });
            if let Some(m) = &a.merged_downsample {
                let ds = shapes[&Edge::new(n.id, 1)];
                loads.push(LayerLoad {
                    name: m.name.clone(),
                    macs: (ds.h * ds.w * m.cout * a.cin * m.k * m.k) as u64,
                    taps: m.k * m.k,
                    och: m.cout,
                    ow_par,
                });
            }
        }
    }
    anyhow::ensure!(!loads.is_empty(), "graph has no conv layers");
    Ok(loads)
}

/// The board/ILP-derived accelerator configuration the pool sizes its
/// FIFO depths, `ow_par`, and per-layer `och_par` worker counts from —
/// the executor validates exactly the depths codegen emits (ROADMAP
/// item 3), instead of a fixed ow_par=1 policy.
pub fn planned_config(name: &str, g: &Graph, cfg: &StreamConfig) -> Result<AcceleratorConfig> {
    let loads = loads_from_graph(g, cfg.ow_par)?;
    let alloc = solve(&loads, cfg.board.n_par() as u64)
        .ok_or_else(|| anyhow!("no feasible ILP allocation on {}", cfg.board.name))?;
    configure(name, g, &alloc, cfg.board, cfg.ow_par)
}

/// Response handle for one submitted frame.
pub struct FrameTicket {
    rx: mpsc::Receiver<FrameResult>,
}

impl FrameTicket {
    /// Block until the frame's logits row (or the pipeline's typed error
    /// message) arrives.
    pub fn wait(self) -> Result<Vec<i32>> {
        match self.rx.recv() {
            Ok(Ok(v)) => Ok(v),
            Ok(Err(msg)) => Err(anyhow!("{msg}")),
            Err(_) => Err(anyhow!("stream pool dropped the frame (worker died)")),
        }
    }
}

struct Job {
    pixels: Box<[i32]>,
    resp: mpsc::Sender<FrameResult>,
}

struct QueueState {
    jobs: VecDeque<Job>,
    open: bool,
    poison: Option<String>,
}

struct Shared {
    q: Mutex<QueueState>,
    cv: Condvar,
}

struct ReplicaHandle {
    supervisor: Option<JoinHandle<()>>,
    fifos: Vec<Arc<Fifo>>,
    gauges: Vec<Arc<PeakGauge>>,
}

/// A running pool of persistent pipeline replicas behind one work queue.
pub struct StreamPool {
    shared: Arc<Shared>,
    replicas: Vec<ReplicaHandle>,
    error: Arc<Mutex<Option<String>>>,
    frames_done: Arc<AtomicUsize>,
    whole_tensor_elems: usize,
    stages_per_replica: usize,
    classes: usize,
    in_h: usize,
    in_w: usize,
    in_c: usize,
    in_exp: i32,
}

impl StreamPool {
    /// Plan and launch the pool: ILP/board configuration once, then
    /// `cfg.replicas` pipeline replicas whose stage threads stay alive
    /// until shutdown.  `name` labels threads and the configuration.
    pub fn new(
        name: &str,
        g: &Graph,
        weights: Arc<ModelWeights>,
        cfg: StreamConfig,
    ) -> Result<StreamPool> {
        let n_replicas = cfg.replicas.max(1);
        let acfg = planned_config(name, g, &cfg)?;
        let shared = Arc::new(Shared {
            q: Mutex::new(QueueState { jobs: VecDeque::new(), open: true, poison: None }),
            cv: Condvar::new(),
        });
        let error = Arc::new(Mutex::new(None));
        let frames_done = Arc::new(AtomicUsize::new(0));
        let mut pool = StreamPool {
            shared: shared.clone(),
            replicas: Vec::with_capacity(n_replicas),
            error: error.clone(),
            frames_done: frames_done.clone(),
            whole_tensor_elems: 0,
            stages_per_replica: 0,
            classes: 0,
            in_h: 0,
            in_w: 0,
            in_c: 0,
            in_exp: 0,
        };
        for r in 0..n_replicas {
            let abort = Arc::new(AtomicBool::new(false));
            let tag = if r == 0 { String::new() } else { format!("r{r}/") };
            let plan = plan_pipeline(g, &weights, &cfg, &acfg, abort.clone(), &tag)?;
            if r == 0 {
                pool.whole_tensor_elems = plan.whole_tensor_elems;
                pool.stages_per_replica = plan.stages.len();
                pool.classes = plan.classes;
                pool.in_h = plan.in_h;
                pool.in_w = plan.in_w;
                pool.in_c = plan.in_c;
                pool.in_exp = plan.in_exp;
            }
            let fifos = plan.fifos.clone();
            let gauges = plan.gauges.clone();
            let pending: Pending = Arc::new(Mutex::new(VecDeque::new()));
            // If anything below fails, dropping `pool` closes the queue
            // and joins the replicas already running.
            let handles = spawn_replica(
                name,
                r,
                plan,
                weights.clone(),
                shared.clone(),
                pending.clone(),
                abort.clone(),
                frames_done.clone(),
            )?;
            // The handles live in a cell the supervisor takes on startup:
            // if its spawn fails, they are still here to abort + join, so
            // the replica's threads are never detached.
            let handle_cell = Arc::new(Mutex::new(Some(handles)));
            let sup = {
                let cell = handle_cell.clone();
                let shared = shared.clone();
                let error = error.clone();
                let sup_res = thread::Builder::new()
                    .name(format!("strm-{name}-r{r}-sup"))
                    .spawn(move || {
                        // A claimed cell is a bookkeeping bug, not a reason
                        // to abort the process: poison the pool with the
                        // typed error so the router's error path reports it.
                        match cell.lock().unwrap().take() {
                            Some(handles) => supervise(handles, &shared, &pending, &error),
                            None => fail_pool(
                                &shared,
                                &pending,
                                &error,
                                &StreamError::Inconsistent {
                                    what: "replica thread handles were already claimed",
                                },
                            ),
                        }
                    });
                match sup_res {
                    Ok(h) => h,
                    Err(e) => {
                        abort.store(true, Ordering::SeqCst);
                        if let Some(hs) = handle_cell.lock().unwrap().take() {
                            for h in hs {
                                let _ = h.join();
                            }
                        }
                        return Err(anyhow!("failed to spawn pool supervisor: {e}"));
                    }
                }
            };
            pool.replicas.push(ReplicaHandle { supervisor: Some(sup), fifos, gauges });
        }
        Ok(pool)
    }

    /// Submit one frame (row-major `h*w*c` pixels at the input exponent);
    /// returns immediately with the frame's response ticket.
    pub fn submit(&self, pixels: &[i32]) -> Result<FrameTicket> {
        let want = self.in_h * self.in_w * self.in_c;
        anyhow::ensure!(
            pixels.len() == want,
            "frame has {} pixels, expected {want} ({}x{}x{})",
            pixels.len(),
            self.in_h,
            self.in_w,
            self.in_c
        );
        let (tx, rx) = mpsc::channel();
        {
            let mut st = self.shared.q.lock().unwrap();
            if let Some(p) = &st.poison {
                return Err(anyhow!("{p}"));
            }
            anyhow::ensure!(st.open, "stream pool stopped");
            st.jobs.push_back(Job { pixels: Box::from(pixels), resp: tx });
        }
        self.shared.cv.notify_one();
        Ok(FrameTicket { rx })
    }

    /// Run a whole batch through the pool: every frame is enqueued before
    /// the first result is awaited, so up to the pool's in-flight
    /// capacity of frames pipeline concurrently.  Results are assembled
    /// in submission order (bit-identical to the golden model).
    pub fn infer(&self, input: &QTensor) -> Result<QTensor> {
        let n = input.shape.n;
        anyhow::ensure!(n >= 1, "empty input batch");
        anyhow::ensure!(
            (input.shape.h, input.shape.w, input.shape.c) == (self.in_h, self.in_w, self.in_c),
            "input shape {} vs expected ({},{},{})",
            input.shape,
            self.in_h,
            self.in_w,
            self.in_c
        );
        anyhow::ensure!(
            input.exp == self.in_exp,
            "input exp {} vs expected {}",
            input.exp,
            self.in_exp
        );
        let frame = self.in_h * self.in_w * self.in_c;
        let mut tickets = Vec::with_capacity(n);
        for i in 0..n {
            tickets.push(self.submit(&input.data[i * frame..(i + 1) * frame])?);
        }
        let mut out = Vec::with_capacity(n * self.classes);
        for t in tickets {
            out.extend_from_slice(&t.wait()?);
        }
        Ok(QTensor::from_vec(Shape4::new(n, 1, 1, self.classes), 0, out))
    }

    /// Pipeline replicas behind the shared queue.
    pub fn replicas(&self) -> usize {
        self.replicas.len()
    }

    /// Frames the pool can usefully hold in flight: one per stage per
    /// replica (each persistent stage works on its own frame).  Batcher
    /// buckets are sized to this.
    pub fn capacity(&self) -> usize {
        (self.stages_per_replica * self.replicas.len()).max(1)
    }

    /// Logit classes per frame.
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Frames completed since the pool started.
    pub fn frames(&self) -> usize {
        self.frames_done.load(Ordering::Relaxed)
    }

    /// First pipeline error, if any replica failed.
    pub fn error(&self) -> Option<String> {
        self.error.lock().unwrap().clone()
    }

    /// Cumulative buffering snapshot, readable while the pool runs:
    /// every replica's FIFOs and line buffers (replica `i > 0` names are
    /// prefixed `r{i}/`), with the whole-tensor comparison scaled by the
    /// replica count (a non-streaming executor running R concurrent
    /// frames materializes R whole-tensor sets).
    pub fn stats(&self) -> StreamStats {
        let mut buffers = Vec::new();
        for r in &self.replicas {
            buffers.extend(r.fifos.iter().map(|f| f.stat()));
            buffers.extend(r.gauges.iter().map(|g| g.stat()));
        }
        StreamStats {
            buffers,
            frames: self.frames(),
            whole_tensor_elems: self.whole_tensor_elems * self.replicas.len().max(1),
        }
    }

    /// Cheap gauge pair for the serving metrics, recorded after every
    /// batch: `(summed peak occupancy across every replica's buffers,
    /// replica-scaled whole-tensor base)` — atomics/locks only, no
    /// per-buffer name clones (use [`stats`](StreamPool::stats) for the
    /// full named report).
    pub fn buffered_gauges(&self) -> (usize, usize) {
        let peak: usize = self
            .replicas
            .iter()
            .map(|r| {
                r.fifos.iter().map(|f| f.peak()).sum::<usize>()
                    + r.gauges.iter().map(|g| g.peak()).sum::<usize>()
            })
            .sum();
        (peak, self.whole_tensor_elems * self.replicas.len().max(1))
    }

    /// Graceful shutdown: stop accepting frames, drain everything
    /// in-flight (every accepted frame still gets its response), join all
    /// threads, and return the final buffering stats.
    pub fn shutdown(mut self) -> StreamStats {
        self.close_and_join();
        self.stats()
    }

    fn close_and_join(&mut self) {
        {
            let mut st = self.shared.q.lock().unwrap();
            st.open = false;
        }
        self.shared.cv.notify_all();
        for r in &mut self.replicas {
            if let Some(h) = r.supervisor.take() {
                let _ = h.join();
            }
        }
    }
}

impl Drop for StreamPool {
    fn drop(&mut self) {
        // Same drain semantics as shutdown(): frames mid-pipeline finish,
        // every thread is joined — a dropped pool never leaks threads or
        // responses.
        self.close_and_join();
    }
}

/// Spawn one replica's feeder + stage + sink threads; on a spawn failure
/// the replica's partial thread set is aborted and joined before the
/// error propagates.
#[allow(clippy::too_many_arguments)]
fn spawn_replica(
    name: &str,
    r: usize,
    plan: PipelinePlan,
    weights: Arc<ModelWeights>,
    shared: Arc<Shared>,
    pending: Pending,
    abort: Arc<AtomicBool>,
    frames_done: Arc<AtomicUsize>,
) -> Result<Vec<JoinHandle<Result<(), StreamError>>>> {
    let PipelinePlan { stages, sources, sink, in_c, .. } = plan;
    let mut handles: Vec<JoinHandle<Result<(), StreamError>>> = Vec::new();
    let res = (|| -> Result<()> {
        spawn_thread(format!("strm-{name}-r{r}-feed"), &mut handles, &abort, {
            let shared = shared.clone();
            let abort = abort.clone();
            let pending = pending.clone();
            move || feeder_loop(&shared, &abort, &sources, &pending, in_c)
        })?;
        for st in stages {
            let w = weights.clone();
            spawn_thread(format!("strm-{}", st.name()), &mut handles, &abort, move || {
                run_stage(&st, &w)
            })?;
        }
        spawn_thread(format!("strm-{name}-r{r}-sink"), &mut handles, &abort, {
            let pending = pending.clone();
            let frames_done = frames_done.clone();
            move || sink_loop(&sink, &pending, &frames_done)
        })?;
        Ok(())
    })();
    match res {
        Ok(()) => Ok(handles),
        Err(e) => {
            abort.store(true, Ordering::SeqCst);
            for h in handles {
                let _ = h.join();
            }
            Err(e)
        }
    }
}

fn spawn_thread(
    name: String,
    handles: &mut Vec<JoinHandle<Result<(), StreamError>>>,
    abort: &Arc<AtomicBool>,
    f: impl FnOnce() -> Result<(), StreamError> + Send + 'static,
) -> Result<()> {
    let a = abort.clone();
    let h = thread::Builder::new()
        .name(name)
        .spawn(move || guarded(&a, f))
        .map_err(|e| anyhow!("failed to spawn stream pool thread: {e}"))?;
    handles.push(h);
    Ok(())
}

/// Claim frames off the shared queue and stream their pixels into the
/// replica's DMA FIFO(s); on queue close (or pool poison) flow the
/// end-of-stream sentinel so the replica drains and exits cleanly.
fn feeder_loop(
    shared: &Shared,
    abort: &AtomicBool,
    sources: &[Arc<Fifo>],
    pending: &Pending,
    in_c: usize,
) -> Result<(), StreamError> {
    loop {
        let job = {
            let mut st = shared.q.lock().unwrap();
            loop {
                if abort.load(Ordering::SeqCst) {
                    return Err(StreamError::Aborted);
                }
                if st.poison.is_some() {
                    break None;
                }
                if let Some(j) = st.jobs.pop_front() {
                    break Some(j);
                }
                if !st.open {
                    break None;
                }
                let (g, _) = shared.cv.wait_timeout(st, POLL).unwrap();
                st = g;
            }
        };
        match job {
            Some(job) => {
                // Register the responder *before* the first pixel: the
                // sink pairs results with this queue in feed order.
                pending.lock().unwrap().push_back(job.resp);
                for px in job.pixels.chunks_exact(in_c) {
                    push_all(sources, Box::from(px))?;
                }
            }
            None => {
                for f in sources {
                    f.push(eos())?;
                }
                return Ok(());
            }
        }
    }
}

/// Pop one logits token per frame and answer the frame's responder.
fn sink_loop(
    sink: &Fifo,
    pending: &Pending,
    frames_done: &AtomicUsize,
) -> Result<(), StreamError> {
    loop {
        // Deadline-free: the sink legitimately idles while the pool has
        // no traffic (mid-frame stalls surface on the stages' bounded
        // pushes/pops and unblock this pop via the abort flag).
        let tok = sink.pop_idle()?;
        if tok.is_empty() {
            return Ok(());
        }
        // Invariant: the feeder registered a responder before streaming
        // the frame, and this replica completes frames in feed order.  A
        // violated invariant degrades this replica into the supervisor's
        // typed error path (poisoning the pool) instead of aborting the
        // serving process.
        let resp = pending.lock().unwrap().pop_front().ok_or(StreamError::Inconsistent {
            what: "sink produced a frame with no pending submitter",
        })?;
        let _ = resp.send(Ok(tok.to_vec()));
        frames_done.fetch_add(1, Ordering::Relaxed);
    }
}

/// Join every replica thread; on failure, record the first real error,
/// poison the pool (queued and in-flight frames fail with the typed
/// message — never a silent drop, never a hang).
fn supervise(
    handles: Vec<JoinHandle<Result<(), StreamError>>>,
    shared: &Shared,
    pending: &Pending,
    error: &Mutex<Option<String>>,
) {
    let mut first: Option<StreamError> = None;
    for h in handles {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                if !matches!(e, StreamError::Aborted) && first.is_none() {
                    first = Some(e);
                }
            }
            Err(_) => {
                if first.is_none() {
                    first = Some(StreamError::Panicked);
                }
            }
        }
    }
    if let Some(e) = first {
        fail_pool(shared, pending, error, &e);
    }
}

/// Poison the pool with a typed error: record it, close the queue, fail
/// every queued and in-flight frame with the message.  Shared by the
/// supervisor's join path and its startup invariant checks, so a
/// degraded replica always lands in the router's error path.
fn fail_pool(shared: &Shared, pending: &Pending, error: &Mutex<Option<String>>, e: &StreamError) {
    let msg = format!("streaming execution failed: {e}");
    {
        let mut slot = error.lock().unwrap();
        if slot.is_none() {
            *slot = Some(msg.clone());
        }
    }
    let drained: Vec<Job> = {
        let mut st = shared.q.lock().unwrap();
        if st.poison.is_none() {
            st.poison = Some(msg.clone());
        }
        st.jobs.drain(..).collect()
    };
    shared.cv.notify_all();
    for j in drained {
        let _ = j.resp.send(Err(msg.clone()));
    }
    for tx in pending.lock().unwrap().drain(..) {
        let _ = tx.send(Err(msg.clone()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hls::streams::StreamKind;

    /// Regression (was `.expect("sink produced a frame with no pending
    /// submitter")`): an inconsistent pending queue must surface as the
    /// typed error, not a process abort.
    #[test]
    fn sink_without_pending_submitter_is_a_typed_error() {
        let abort = Arc::new(AtomicBool::new(false));
        let sink = Fifo::new(
            "t.out".into(),
            StreamKind::Dma,
            16,
            abort,
            Duration::from_millis(200),
        );
        sink.push(vec![1, 2, 3].into_boxed_slice()).unwrap();
        let pending: Pending = Arc::new(Mutex::new(VecDeque::new()));
        let frames = AtomicUsize::new(0);
        let err = sink_loop(&sink, &pending, &frames).unwrap_err();
        assert!(
            matches!(err, StreamError::Inconsistent { .. }),
            "expected Inconsistent, got {err:?}"
        );
        assert!(format!("{err}").contains("no pending submitter"), "{err}");
        assert_eq!(frames.load(Ordering::Relaxed), 0);
    }

    /// Regression (was `.expect("handles unclaimed")`): the supervisor's
    /// inconsistent-state path poisons the pool — queued and in-flight
    /// frames fail with the typed message, new submissions fail fast.
    #[test]
    fn fail_pool_poisons_queue_and_pending() {
        let shared = Arc::new(Shared {
            q: Mutex::new(QueueState { jobs: VecDeque::new(), open: true, poison: None }),
            cv: Condvar::new(),
        });
        let (qtx, qrx) = mpsc::channel();
        shared
            .q
            .lock()
            .unwrap()
            .jobs
            .push_back(Job { pixels: Box::from([0i32; 4]), resp: qtx });
        let pending: Pending = Arc::new(Mutex::new(VecDeque::new()));
        let (ptx, prx) = mpsc::channel();
        pending.lock().unwrap().push_back(ptx);
        let error = Mutex::new(None);
        fail_pool(
            &shared,
            &pending,
            &error,
            &StreamError::Inconsistent { what: "replica thread handles were already claimed" },
        );
        // Every queued and in-flight frame got the typed failure...
        let queued = qrx.recv().unwrap().unwrap_err();
        assert!(queued.contains("already claimed"), "{queued}");
        let inflight = prx.recv().unwrap().unwrap_err();
        assert!(inflight.contains("already claimed"), "{inflight}");
        // ...the error is recorded, and the queue is poisoned for
        // follow-up submissions (StreamPool::submit checks this field).
        assert!(error.lock().unwrap().as_deref().unwrap().contains("inconsistent"));
        let st = shared.q.lock().unwrap();
        assert!(st.poison.as_deref().unwrap().contains("already claimed"));
        assert!(st.jobs.is_empty());
    }
}
