//! Stage plans and stage bodies for the persistent streaming pipeline.
//!
//! [`plan_pipeline`] lowers the (optimized or naive) graph **once per
//! pool** into a [`PipelineBlueprint`]: validated stage templates plus
//! the sized [`BufferSpec`]s of every inter-stage FIFO and window gauge,
//! with depths from the board/ILP-derived [`AcceleratorConfig`]
//! (`hls::config::configure` — the exact depths codegen emits, not a
//! fixed ow_par=1 policy).  [`PipelineBlueprint::instantiate`] then
//! stamps out one *replica* cheaply — fresh tagged [`Fifo`]s and gauges
//! wired into the shared templates — so an elastic pool can add a
//! replica mid-flight without re-running shape inference, ILP lookups or
//! weight validation.  [`run_stage`] is the body a pool thread runs
//! *forever*: each stage loops over frames until it pops the zero-length
//! end-of-stream sentinel, which it propagates on every output port
//! before returning — so shutdown drains frames mid-pipeline instead of
//! dropping them.
//!
//! Parallelism mirrors the paper's model at execution time:
//! * **frame-level pipelining** — stages never restart between frames, so
//!   frame N+1 enters conv0 while frame N is still in the classifier;
//! * **channel parallelism** — a conv stage splits its output channels
//!   across up to `och_par` worker threads (the layer's ILP
//!   [`LayerAlloc`](crate::ilp::LayerAlloc) unroll, capped by
//!   `StreamConfig::och_worker_cap`), each computing a contiguous channel
//!   range of every window position; the stage reassembles tokens in
//!   stream order, so numerics stay bit-identical to the golden model;
//! * **column parallelism** (slice-granular mode) — each window-position
//!   group of `ow_par` adjacent output columns (the widened Fig. 8
//!   window) is additionally split across up to `ow_par` column workers
//!   (capped by `StreamConfig::ow_worker_cap`), the execution-time
//!   counterpart of the ILP's `ow_par = 2` DSP-packing assumption
//!   (`hls::packing::macs_per_cycle`).
//!
//! Window storage is slice-granular by default
//! ([`StreamConfig::window_storage`]): a conv stage holds exactly the
//! Eq. 16/17 span (`slice_plan` total plus the in-flight pixel) in a
//! [`SliceWindow`], consuming and evicting pixel-by-pixel per window
//! group; `WindowStorage::Rows` keeps the legacy whole-row
//! [`LineBuffer`] path (`fh` rows, the bound rounded up to rows).
//!
//! The naive dataflow (`StreamConfig::naive_add`) adds explicit
//! [`AddPlan`] stages fed by Eq. 21-sized skip FIFOs and tee'd producers
//! (one FIFO per consumer, pushed in consumer order) — the configuration
//! the paper's Fig. 14 shows deadlocking when undersized, surfaced here
//! as a typed [`StreamError::Stalled`].

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use crate::graph::{infer_shapes, Edge, Graph, InputRole, Op};
use crate::hls::config::AcceleratorConfig;
use crate::hls::streams::{dma_stream, output_stream, StreamKind};
use crate::hls::window::SlicePlan;
use crate::models::{ConvWeights, ModelWeights};
use crate::obs::StageClock;
use crate::quant::{clip_i8, clip_i8_wide, requantize, round_shift, round_shift_i64};

use super::fifo::{Fifo, PeakGauge, StreamError};
use super::line_buffer::{LineBuffer, SliceWindow};
use super::{StreamConfig, WindowStorage};

// --------------------------------------------------------------- helpers

/// Run `f`, raising the shared abort flag on error *or panic* so every
/// peer blocked on a FIFO unwinds within one poll interval.
pub(crate) fn guarded<T>(
    abort: &AtomicBool,
    f: impl FnOnce() -> Result<T, StreamError>,
) -> Result<T, StreamError> {
    struct Guard<'a>(&'a AtomicBool, bool);
    impl Drop for Guard<'_> {
        fn drop(&mut self) {
            if self.1 {
                self.0.store(true, Ordering::SeqCst);
            }
        }
    }
    let mut g = Guard(abort, true);
    let r = f();
    if r.is_ok() {
        g.1 = false;
    }
    r
}

/// The end-of-stream sentinel: a zero-length token (always fits, even in
/// a full FIFO, so shutdown can never itself deadlock).
pub(crate) fn eos() -> Box<[i32]> {
    Vec::new().into_boxed_slice()
}

/// Push one token to every consumer FIFO of an output port (tee), in
/// consumer order — deterministic, and the blocking producer-side tee is
/// exactly what makes the naive dataflow's Fig. 14 deadlock reproducible.
pub(crate) fn push_all(outs: &[Arc<Fifo>], tok: Box<[i32]>) -> Result<(), StreamError> {
    let Some((last, rest)) = outs.split_last() else {
        return Err(StreamError::Inconsistent { what: "stage has no output port" });
    };
    for o in rest {
        o.push(tok.clone())?;
    }
    last.push(tok)
}

fn push_eos(outs: &[Arc<Fifo>]) -> Result<(), StreamError> {
    for o in outs {
        o.push(eos())?;
    }
    Ok(())
}

/// Pop the head token of the next frame; `None` = end-of-stream.  Uses
/// the deadline-free pop: a persistent stage legitimately idles here for
/// as long as the pool has no traffic.
fn next_frame(input: &Fifo) -> Result<Option<Box<[i32]>>, StreamError> {
    let t = input.pop_idle()?;
    Ok(if t.is_empty() { None } else { Some(t) })
}

/// Assemble one input row, consuming the frame-head token first if it is
/// still pending.
fn pull_row(
    input: &Fifo,
    head: &mut Option<Box<[i32]>>,
    iw: usize,
    ich: usize,
) -> Result<Arc<[i32]>, StreamError> {
    let mut row = vec![0i32; iw * ich];
    for x in 0..iw {
        let t = match head.take() {
            Some(t) => t,
            None => input.pop()?,
        };
        row[x * ich..(x + 1) * ich].copy_from_slice(&t);
    }
    Ok(Arc::from(row))
}

fn forward_rows(
    outs: &[Arc<Fifo>],
    rows: &[Arc<[i32]>],
    ich: usize,
) -> Result<(), StreamError> {
    for row in rows {
        for px in row.chunks_exact(ich) {
            push_all(outs, Box::from(px))?;
        }
    }
    Ok(())
}

/// Pull one pixel token (`ich` channel values), consuming the frame-head
/// token first if it is still pending.
fn pull_pixel(
    input: &Fifo,
    head: &mut Option<Box<[i32]>>,
) -> Result<Arc<[i32]>, StreamError> {
    let t = match head.take() {
        Some(t) => t,
        None => input.pop()?,
    };
    Ok(Arc::from(t))
}

/// Forward evicted pixel tokens in stream order (the temporal-reuse skip
/// stream of the slice-granular path).
fn forward_pixels(outs: &[Arc<Fifo>], pixels: &[Arc<[i32]>]) -> Result<(), StreamError> {
    for px in pixels {
        push_all(outs, Box::from(&px[..]))?;
    }
    Ok(())
}

// ------------------------------------------------------------ stage plan
//
// Every plan struct is generic over its port type `P` (and gauge type
// `G` where the stage owns a window gauge): the blueprint stores
// *templates* (`P = usize` — an index into the pool-wide
// [`BufferSpec`] table; `G = BufferSpec`), and each replica
// instantiation maps them to *runnable* plans (`P = Arc<Fifo>`,
// `G = Arc<PeakGauge>`) — so the expensive planning/validation pass
// runs once per pool, never once per replica.

/// Sized spec of one runtime buffer (FIFO or live window gauge): the
/// pool-wide planning artifact a replica instantiation turns into a
/// tagged live object.
pub(crate) struct BufferSpec {
    pub name: String,
    pub kind: StreamKind,
    pub capacity: usize,
}

impl BufferSpec {
    fn fifo(&self, tag: &str, abort: &Arc<AtomicBool>, timeout: Duration) -> Arc<Fifo> {
        Fifo::new(format!("{tag}{}", self.name), self.kind, self.capacity, abort.clone(), timeout)
    }

    fn gauge(&self, tag: &str) -> Arc<PeakGauge> {
        PeakGauge::new(format!("{tag}{}", self.name), self.kind, self.capacity)
    }
}

pub(crate) struct SkipPlan<P> {
    pub fifo: P,
    /// `skip_exp - acc_exp` (>= 0 by the builders' exponent contract).
    pub shift: u32,
}

/// Loop-merged pointwise downsample computed inside the host conv task
/// (paper Fig. 12b); always sequential — the ILP's parallelism for it is
/// absorbed into the host stage's schedule.
pub(crate) struct DsPlan<P> {
    pub layer: String,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub oh: usize,
    pub ow: usize,
    pub och: usize,
    pub out_exp: i32,
    pub acc_exp: i32,
    pub outs: Vec<P>,
}

pub(crate) struct ConvPlan<P, G> {
    pub name: String,
    /// Weights key (layer name).
    pub layer: String,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub relu: bool,
    /// Emit raw int32 accumulators (naive dataflow into an Add stage).
    pub raw: bool,
    pub out_exp: i32,
    pub acc_exp: i32,
    pub ih: usize,
    pub iw: usize,
    pub ich: usize,
    pub oh: usize,
    pub ow: usize,
    pub och: usize,
    pub input: P,
    pub outs: Vec<P>,
    pub skip: Option<SkipPlan<P>>,
    /// Temporal reuse (Fig. 12a): evicted line-buffer rows re-emitted on
    /// port 1 as the skip stream.
    pub forward: Option<Vec<P>>,
    pub ds: Option<DsPlan<P>>,
    /// Contiguous output-channel ranges, one per channel-parallel worker
    /// thread (len 1 = inline, no workers).
    pub worker_ranges: Vec<(usize, usize)>,
    /// Window storage mode (slice-granular by default).
    pub storage: WindowStorage,
    /// Execution window-group width: `ow_par` adjacent output columns are
    /// consumed per step in slice-granular mode (1 for strided convs —
    /// their packed window would span `fw + stride*(ow_par-1)` input
    /// columns, beyond the Eq. 17 widening; Eq. 16 applies instead).
    pub ow_par: usize,
    /// Column-parallel workers per window group (1 = no column split);
    /// total worker threads = `col_workers * worker_ranges.len()`.
    pub col_workers: usize,
    /// The layer's configured slice plan (Figs. 7/9), passed through
    /// from `hls::config::configure` so the stage's [`SliceWindow`] is
    /// built against exactly the sized chain.  Its per-slice view
    /// (`SliceWindow::slice_occupancy`) is an analysis/bench API, not
    /// live telemetry — the runtime gauge tracks total occupancy only.
    pub window: SlicePlan,
    pub gauge: G,
}

pub(crate) struct PoolPlan<P, G> {
    pub name: String,
    pub k: usize,
    pub stride: usize,
    pub ih: usize,
    pub iw: usize,
    pub c: usize,
    pub oh: usize,
    pub ow: usize,
    pub input: P,
    pub outs: Vec<P>,
    pub gauge: G,
}

pub(crate) struct GapPlan<P> {
    pub name: String,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub in_exp: i32,
    pub out_exp: i32,
    pub input: P,
    pub outs: Vec<P>,
}

pub(crate) struct LinearPlan<P> {
    pub name: String,
    /// Weights key (layer name, untagged).
    pub layer: String,
    pub cout: usize,
    /// Pixel tokens per frame on the input stream.
    pub tokens: usize,
    pub cin: usize,
    pub input: P,
    pub outs: Vec<P>,
}

pub(crate) struct ReluPlan<P> {
    pub name: String,
    pub tokens: usize,
    pub input: P,
    pub outs: Vec<P>,
}

/// Explicit residual-merge task (naive dataflow, or a non-fusable merge
/// left as a naive island inside an optimized graph): pops the long-path
/// raw accumulator stream and every buffered skip stream in lockstep,
/// widens to i64, requantizes — golden's `Op::Add` semantics for any
/// operand count.
pub(crate) struct AddPlan<P> {
    pub name: String,
    pub tokens: usize,
    /// Long-branch alignment shift (operand 0).
    pub sa: u32,
    /// Per-skip-operand alignment shifts (input ports `1..N`).
    pub sb: Vec<u32>,
    pub shift: i32,
    pub in_a: P,
    /// Skip operand streams, input ports `1..N` (`len == sb.len()`).
    pub in_b: Vec<P>,
    pub outs: Vec<P>,
}

pub(crate) enum StagePlan<P, G> {
    Conv(ConvPlan<P, G>),
    Pool(PoolPlan<P, G>),
    Gap(GapPlan<P>),
    Linear(LinearPlan<P>),
    Relu(ReluPlan<P>),
    Add(AddPlan<P>),
}

/// A blueprint-side stage: ports are indices into the pool's
/// [`BufferSpec`] table, gauges are their specs.
pub(crate) type StageTemplate = StagePlan<usize, BufferSpec>;
/// A runnable replica stage: ports are live FIFOs, gauges are live.
pub(crate) type RunStagePlan = StagePlan<Arc<Fifo>, Arc<PeakGauge>>;

type RunConvPlan = ConvPlan<Arc<Fifo>, Arc<PeakGauge>>;
type RunDsPlan = DsPlan<Arc<Fifo>>;
type RunPoolPlan = PoolPlan<Arc<Fifo>, Arc<PeakGauge>>;
type RunGapPlan = GapPlan<Arc<Fifo>>;
type RunLinearPlan = LinearPlan<Arc<Fifo>>;
type RunReluPlan = ReluPlan<Arc<Fifo>>;
type RunAddPlan = AddPlan<Arc<Fifo>>;

impl<P, G> StagePlan<P, G> {
    /// Stage name (replica-tagged on runnable plans), used for pool
    /// thread names so a wedged replica's diagnostics identify exactly
    /// which copy failed.
    pub(crate) fn name(&self) -> &str {
        match self {
            StagePlan::Conv(p) => &p.name,
            StagePlan::Pool(p) => &p.name,
            StagePlan::Gap(p) => &p.name,
            StagePlan::Linear(p) => &p.name,
            StagePlan::Relu(p) => &p.name,
            StagePlan::Add(p) => &p.name,
        }
    }
}

impl StagePlan<usize, BufferSpec> {
    /// Stamp the template into a runnable stage for one replica: ports
    /// resolve against the replica's freshly built FIFOs, window gauges
    /// are created (tagged) and registered with the replica.
    fn instantiate(
        &self,
        f: &[Arc<Fifo>],
        tag: &str,
        gauges: &mut Vec<Arc<PeakGauge>>,
    ) -> RunStagePlan {
        let port = |i: &usize| f[*i].clone();
        let ports = |v: &[usize]| v.iter().map(|&i| f[i].clone()).collect::<Vec<_>>();
        match self {
            StagePlan::Conv(p) => {
                let gauge = p.gauge.gauge(tag);
                gauges.push(gauge.clone());
                StagePlan::Conv(ConvPlan {
                    name: format!("{tag}{}", p.name),
                    layer: p.layer.clone(),
                    k: p.k,
                    stride: p.stride,
                    pad: p.pad,
                    relu: p.relu,
                    raw: p.raw,
                    out_exp: p.out_exp,
                    acc_exp: p.acc_exp,
                    ih: p.ih,
                    iw: p.iw,
                    ich: p.ich,
                    oh: p.oh,
                    ow: p.ow,
                    och: p.och,
                    input: port(&p.input),
                    outs: ports(&p.outs),
                    skip: p.skip.as_ref().map(|s| SkipPlan { fifo: port(&s.fifo), shift: s.shift }),
                    forward: p.forward.as_ref().map(|v| ports(v)),
                    ds: p.ds.as_ref().map(|d| DsPlan {
                        layer: d.layer.clone(),
                        k: d.k,
                        stride: d.stride,
                        pad: d.pad,
                        oh: d.oh,
                        ow: d.ow,
                        och: d.och,
                        out_exp: d.out_exp,
                        acc_exp: d.acc_exp,
                        outs: ports(&d.outs),
                    }),
                    worker_ranges: p.worker_ranges.clone(),
                    storage: p.storage,
                    ow_par: p.ow_par,
                    col_workers: p.col_workers,
                    window: p.window.clone(),
                    gauge,
                })
            }
            StagePlan::Pool(p) => {
                let gauge = p.gauge.gauge(tag);
                gauges.push(gauge.clone());
                StagePlan::Pool(PoolPlan {
                    name: format!("{tag}{}", p.name),
                    k: p.k,
                    stride: p.stride,
                    ih: p.ih,
                    iw: p.iw,
                    c: p.c,
                    oh: p.oh,
                    ow: p.ow,
                    input: port(&p.input),
                    outs: ports(&p.outs),
                    gauge,
                })
            }
            StagePlan::Gap(p) => StagePlan::Gap(GapPlan {
                name: format!("{tag}{}", p.name),
                h: p.h,
                w: p.w,
                c: p.c,
                in_exp: p.in_exp,
                out_exp: p.out_exp,
                input: port(&p.input),
                outs: ports(&p.outs),
            }),
            StagePlan::Linear(p) => StagePlan::Linear(LinearPlan {
                name: format!("{tag}{}", p.name),
                layer: p.layer.clone(),
                cout: p.cout,
                tokens: p.tokens,
                cin: p.cin,
                input: port(&p.input),
                outs: ports(&p.outs),
            }),
            StagePlan::Relu(p) => StagePlan::Relu(ReluPlan {
                name: format!("{tag}{}", p.name),
                tokens: p.tokens,
                input: port(&p.input),
                outs: ports(&p.outs),
            }),
            StagePlan::Add(p) => StagePlan::Add(AddPlan {
                name: format!("{tag}{}", p.name),
                tokens: p.tokens,
                sa: p.sa,
                sb: p.sb.clone(),
                shift: p.shift,
                in_a: port(&p.in_a),
                in_b: ports(&p.in_b),
                outs: ports(&p.outs),
            }),
        }
    }
}

/// FIFO probes of one side of a stage, labeled with their FIFO names.
pub(crate) type PortProbes = Vec<(String, Arc<crate::obs::FifoProbe>)>;

impl RunStagePlan {
    /// The `(inputs, outputs)` FIFO probes of this stage's thread — the
    /// topology its [`StageClock`] attributes stall time with.  Each FIFO
    /// has exactly one producer and one consumer stage, and conv
    /// channel/column workers talk to their host over mpsc channels,
    /// never FIFOs, so every blocking op on these ports is by this
    /// stage's own thread.
    pub(crate) fn ports(&self) -> (PortProbes, PortProbes) {
        let tap = |f: &Arc<Fifo>| (f.name().to_string(), f.probe());
        let taps = |v: &[Arc<Fifo>]| v.iter().map(tap).collect::<Vec<_>>();
        match self {
            StagePlan::Conv(p) => {
                let mut ins = vec![tap(&p.input)];
                if let Some(sk) = &p.skip {
                    ins.push(tap(&sk.fifo));
                }
                let mut outs = taps(&p.outs);
                if let Some(fwd) = &p.forward {
                    outs.extend(fwd.iter().map(tap));
                }
                if let Some(ds) = &p.ds {
                    outs.extend(ds.outs.iter().map(tap));
                }
                (ins, outs)
            }
            StagePlan::Pool(p) => (vec![tap(&p.input)], taps(&p.outs)),
            StagePlan::Gap(p) => (vec![tap(&p.input)], taps(&p.outs)),
            StagePlan::Linear(p) => (vec![tap(&p.input)], taps(&p.outs)),
            StagePlan::Relu(p) => (vec![tap(&p.input)], taps(&p.outs)),
            StagePlan::Add(p) => {
                let mut ins = vec![tap(&p.in_a)];
                ins.extend(p.in_b.iter().map(tap));
                (ins, taps(&p.outs))
            }
        }
    }
}

/// The pool-wide plan, built **once**: validated stage templates, the
/// sized buffer table, and the scalar frame geometry.  Replicas are
/// stamped out of it with [`instantiate`](PipelineBlueprint::instantiate).
pub(crate) struct PipelineBlueprint {
    stages: Vec<StageTemplate>,
    fifo_specs: Vec<BufferSpec>,
    /// Port indices of the network input node's consumer FIFO(s) (the
    /// feeder pushes each pixel to all of them — a tee in naive mode).
    source_ports: Vec<usize>,
    /// The output stream the sink pops `out_tokens` tokens per frame.
    sink_port: usize,
    timeout: Duration,
    /// Total output values per frame (`c` for a classifier head,
    /// `h*w*c` for a spatial head).
    pub classes: usize,
    /// Tokens the sink pops per frame (1 for a classifier/GAP head,
    /// `h*w` for a spatial head).
    pub out_tokens: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub in_c: usize,
    pub in_exp: i32,
    /// What a non-streaming executor materializes per frame.
    pub whole_tensor_elems: usize,
}

impl PipelineBlueprint {
    /// Stages per replica — the pool's per-replica in-flight capacity.
    pub(crate) fn stages_per_replica(&self) -> usize {
        self.stages.len()
    }

    /// Stamp one replica: fresh `tag`-prefixed FIFOs and gauges on
    /// `abort`, wired into the shared stage templates.  Cheap (no shape
    /// inference, no ILP lookups, no weight validation) — this is what
    /// lets the elastic controller add a replica mid-flight.
    pub(crate) fn instantiate(&self, abort: &Arc<AtomicBool>, tag: &str) -> PipelinePlan {
        let fifos: Vec<Arc<Fifo>> = self
            .fifo_specs
            .iter()
            .map(|s| s.fifo(tag, abort, self.timeout))
            .collect();
        let mut gauges = Vec::new();
        let stages = self.stages.iter().map(|t| t.instantiate(&fifos, tag, &mut gauges)).collect();
        PipelinePlan {
            stages,
            sources: self.source_ports.iter().map(|&i| fifos[i].clone()).collect(),
            sink: fifos[self.sink_port].clone(),
            fifos,
            gauges,
        }
    }
}

/// One replica's runnable lowering: stages + live streams + live gauges.
pub(crate) struct PipelinePlan {
    pub stages: Vec<RunStagePlan>,
    pub sources: Vec<Arc<Fifo>>,
    pub sink: Arc<Fifo>,
    pub fifos: Vec<Arc<Fifo>>,
    pub gauges: Vec<Arc<PeakGauge>>,
}

/// Lower `g` into the pool-wide [`PipelineBlueprint`] — run **once per
/// pool**, however many replicas it grows to.
///
/// FIFO depths come from `acfg` (the board/ILP configuration): conv
/// output streams at their `och_groups x och_par x ow_par` burst
/// capacity, fused skip streams at Eq. 22, naive Add skip streams at
/// Eq. 21.  All weight lookups are validated here, so a stage body's
/// later lookup failure is a bookkeeping inconsistency (typed error),
/// never a user-input error.
pub(crate) fn plan_pipeline(
    g: &Graph,
    weights: &ModelWeights,
    cfg: &StreamConfig,
    acfg: &AcceleratorConfig,
) -> Result<PipelineBlueprint> {
    // Static preflight (deadlock-freedom + window feasibility): refuse a
    // provably-unsafe configuration with a typed, downcastable
    // `analysis::AnalysisError` before any FIFO exists, let alone a
    // thread.  The deadlock-regression tests clear `static_checks` to
    // exercise the runtime `Stalled` watchdog behind this gate.
    if cfg.static_checks {
        crate::analysis::preflight(g, cfg, acfg)?;
    }
    let shapes = infer_shapes(g).map_err(|e| anyhow!("{e}"))?;
    let timeout = cfg.progress_timeout;

    // Pass 1: one FIFO spec per (consumed edge, consumer) pair — a
    // producer whose edge has several consumers pushes to each (tee).
    // `edge_outs` accumulates each edge's consumer FIFO ports in the same
    // pass (consumer order), so pass 2's fan-out lookups are O(1) instead
    // of a scan over every (edge, consumer) pair per producer port.
    let mut fifo_specs: Vec<BufferSpec> = Vec::new();
    let mut fifo_of: std::collections::BTreeMap<(Edge, usize), usize> =
        std::collections::BTreeMap::new();
    let mut edge_outs: std::collections::BTreeMap<Edge, Vec<usize>> =
        std::collections::BTreeMap::new();
    for n in g.live() {
        for (i, (e, role)) in n.inputs.iter().enumerate() {
            let es = shapes
                .get(e)
                .copied()
                .ok_or_else(|| anyhow!("{}: unshaped input edge", n.name))?;
            let producer = g.node(e.node);
            let (name, kind, cap) = match role {
                InputRole::SkipInit => {
                    let lc = acfg
                        .convs
                        .get(&n.id)
                        .ok_or_else(|| anyhow!("{}: skip input on a non-conv node", n.name))?;
                    // Eq. 22: the optimized B_sc is the consumer's own
                    // window-buffer size (configure's skip_in spec).
                    let spec = lc
                        .skip_in
                        .as_ref()
                        .ok_or_else(|| anyhow!("{}: config lost the skip stream", n.name))?;
                    let cap = cfg.skip_capacity_override.unwrap_or_else(|| spec.capacity());
                    (format!("{}.skip", n.name), StreamKind::Skip, cap)
                }
                InputRole::Data => {
                    if matches!(producer.op, Op::Input { .. }) {
                        let spec = dma_stream(es.w * es.c);
                        (format!("{}.in", n.name), StreamKind::Dma, spec.capacity())
                    } else if matches!(n.op, Op::Add { .. }) && i >= 1 {
                        // Naive residual skip: the per-operand bound from
                        // the configuration — Eq. 21 for block-local skips,
                        // full-frame for long skips (paper Fig. 14).
                        let bound = acfg
                            .adds
                            .get(&n.id)
                            .and_then(|a| a.skips.get(i - 1))
                            .copied()
                            .ok_or_else(|| {
                                anyhow!("{}: no skip sizing for add operand {i}", n.name)
                            })?;
                        let cap = cfg.skip_capacity_override.unwrap_or(bound);
                        let name = if i == 1 {
                            format!("{}.skip", n.name)
                        } else {
                            format!("{}.skip{i}", n.name)
                        };
                        (name, StreamKind::Skip, cap)
                    } else if matches!(producer.op, Op::Conv(_)) {
                        // The producing conv's configured output burst.
                        let lc = acfg
                            .convs
                            .get(&e.node)
                            .ok_or_else(|| anyhow!("{}: unconfigured conv", producer.name))?;
                        let spec = if e.port == 0 {
                            &lc.out_stream
                        } else {
                            &lc.merged_ds
                                .as_ref()
                                .ok_or_else(|| {
                                    anyhow!("{}: port 1 data without a downsample", producer.name)
                                })?
                                .out_stream
                        };
                        (format!("{}.in", n.name), StreamKind::Output, spec.capacity())
                    } else {
                        // Non-conv producers (relu/pool/add): one full
                        // pixel burst.
                        let spec = output_stream(es.c, es.c, 1);
                        (format!("{}.in", n.name), StreamKind::Output, spec.capacity())
                    }
                }
            };
            let idx = fifo_specs.len();
            fifo_specs.push(BufferSpec { name, kind, capacity: cap });
            fifo_of.insert((*e, n.id), idx);
            edge_outs.entry(*e).or_default().push(idx);
        }
    }

    // The network output: any unique sink node works — a classifier
    // drains one logits token per frame, a spatial head (conv/relu tail)
    // drains one token per output pixel (`out_tokens`).
    let out_node = g
        .output()
        .ok_or_else(|| anyhow!("graph has no unique output node"))?;
    let out_shape = shapes[&Edge::new(out_node, 0)];
    let out_tokens = (out_shape.h * out_shape.w).max(1);
    let classes = out_shape.h * out_shape.w * out_shape.c;
    let sink_port = fifo_specs.len();
    fifo_specs.push(BufferSpec {
        name: format!("{}.out", g.node(out_node).name),
        kind: StreamKind::Dma,
        capacity: dma_stream(out_shape.c).capacity().max(out_tokens),
    });

    // All consumer FIFO ports of an output port, in consumer order
    // (precomputed in pass 1 — one map lookup per producer port).
    let outs_for = |e: Edge| -> Vec<usize> { edge_outs.get(&e).cloned().unwrap_or_default() };
    let outs_for_node = |id: usize| -> Result<Vec<usize>> {
        if id == out_node {
            return Ok(vec![sink_port]);
        }
        let outs = outs_for(Edge::new(id, 0));
        anyhow::ensure!(!outs.is_empty(), "output of {} has no consumer", g.node(id).name);
        Ok(outs)
    };

    // Pass 2: build the stage templates.
    let mut stages: Vec<StageTemplate> = Vec::new();
    let mut sources: Option<Vec<usize>> = None;
    let mut input_spec = None;
    for n in g.live() {
        match &n.op {
            Op::Input { h, w, c, exp } => {
                anyhow::ensure!(sources.is_none(), "stream backend supports one input node");
                sources = Some(outs_for_node(n.id)?);
                input_spec = Some((*h, *w, *c, *exp));
            }
            Op::Conv(a) => {
                // A raw int32 accumulator stream is only plannable when
                // every consumer is an Add stage this plan will also run:
                // all of them in naive mode, only the non-fusable naive
                // islands (multi-input / long-skip merges) otherwise.
                let raw_ok = cfg.naive_add
                    || !a.raw_output
                    || g.consumers(Edge::new(n.id, 0)).iter().all(|&c| {
                        matches!(g.node(c).op, Op::Add { .. })
                            && !crate::passes::is_fusable_residual(g, c)
                    });
                anyhow::ensure!(
                    raw_ok,
                    "stream backend runs optimized graphs only unless naive_add is set \
                     ({}: raw int32 accumulator streams feed explicit Add nodes)",
                    n.name
                );
                let in_shape = shapes[&n.inputs[0].0];
                let os = shapes[&Edge::new(n.id, 0)];
                let lw = weights.layer(&n.name)?;
                anyhow::ensure!(
                    lw.w.data.len() == a.k * a.k * a.cin * a.cout && lw.b.data.len() == a.cout,
                    "{}: weight/bias sizes do not match conv geometry",
                    n.name
                );
                let skip = n
                    .inputs
                    .iter()
                    .find(|(_, r)| *r == InputRole::SkipInit)
                    .map(|(e, _)| -> Result<SkipPlan<usize>> {
                        let se = shapes[e];
                        anyhow::ensure!(
                            (se.h, se.w, se.c) == (os.h, os.w, os.c),
                            "{}: skip stream shape mismatch",
                            n.name
                        );
                        let shift = se.exp - lw.acc_exp();
                        anyhow::ensure!(shift >= 0, "{}: skip exp below acc exp", n.name);
                        Ok(SkipPlan { fifo: fifo_of[&(*e, n.id)], shift: shift as u32 })
                    })
                    .transpose()?;
                let aux = outs_for(Edge::new(n.id, 1));
                let (forward, ds) = if a.forwards_input {
                    (if aux.is_empty() { None } else { Some(aux) }, None)
                } else if let Some(m) = &a.merged_downsample {
                    if aux.is_empty() {
                        // Port 1 unconsumed: skip the downsample entirely.
                        (None, None)
                    } else {
                        let dss = shapes[&Edge::new(n.id, 1)];
                        let dsw = weights.layer(&m.name)?;
                        anyhow::ensure!(
                            dsw.w.data.len() == m.k * m.k * a.cin * m.cout
                                && dsw.b.data.len() == m.cout,
                            "{}: merged downsample weight sizes mismatch",
                            m.name
                        );
                        let ds = DsPlan {
                            layer: m.name.clone(),
                            k: m.k,
                            stride: m.stride,
                            pad: m.pad,
                            oh: dss.h,
                            ow: dss.w,
                            och: m.cout,
                            out_exp: m.out_exp,
                            acc_exp: dsw.acc_exp(),
                            outs: aux,
                        };
                        (None, Some(ds))
                    }
                } else {
                    (None, None)
                };
                // Channel parallelism: the ILP's och_par for this layer,
                // capped by the host-thread budget, as contiguous ranges.
                let lc = acfg
                    .convs
                    .get(&n.id)
                    .ok_or_else(|| anyhow!("{}: no ILP allocation", n.name))?;
                let nw = cfg.och_worker_cap.max(1).min(lc.och_par).min(a.cout).max(1);
                let chunk = a.cout.div_ceil(nw);
                let mut worker_ranges = Vec::new();
                let mut lo = 0usize;
                while lo < a.cout {
                    let hi = (lo + chunk).min(a.cout);
                    worker_ranges.push((lo, hi));
                    lo = hi;
                }
                // Execution group width + column workers (slice mode,
                // stride-1 convs only: the Eq. 17 widening assumes
                // unit-stride adjacent windows).
                let ow_par_exec = match cfg.window_storage {
                    WindowStorage::Slices if a.stride == 1 => lc.ow_par.max(1).min(os.w.max(1)),
                    _ => 1,
                };
                let col_workers = match cfg.window_storage {
                    WindowStorage::Slices => {
                        ow_par_exec.min(cfg.ow_worker_cap.max(1)).max(1)
                    }
                    WindowStorage::Rows => 1,
                };
                // Gauge bound: the exact Eq. 16/17 span (buffered B_i plus
                // the in-flight pixel) in slice mode; the row-rounded
                // legacy bound otherwise.
                let rows_bound = if ds.is_some() { a.k + 1 } else { a.k };
                let window_bound = match cfg.window_storage {
                    WindowStorage::Slices => lc.window_capacity + a.cin,
                    WindowStorage::Rows => rows_bound * in_shape.w * a.cin,
                };
                let gauge = BufferSpec {
                    name: format!("{}.window", n.name),
                    kind: StreamKind::WindowSlice,
                    capacity: window_bound,
                };
                let window = lc.window.clone();
                stages.push(StagePlan::Conv(ConvPlan {
                    name: n.name.clone(),
                    layer: n.name.clone(),
                    k: a.k,
                    stride: a.stride,
                    pad: a.pad,
                    relu: a.relu,
                    raw: a.raw_output,
                    out_exp: a.out_exp,
                    acc_exp: lw.acc_exp(),
                    ih: in_shape.h,
                    iw: in_shape.w,
                    ich: a.cin,
                    oh: os.h,
                    ow: os.w,
                    och: a.cout,
                    input: fifo_of[&(n.inputs[0].0, n.id)],
                    outs: outs_for_node(n.id)?,
                    skip,
                    forward,
                    ds,
                    worker_ranges,
                    storage: cfg.window_storage,
                    ow_par: ow_par_exec,
                    col_workers,
                    window,
                    gauge,
                }));
            }
            Op::MaxPool { k, stride } => {
                // Window/stride bounds already validated by infer_shapes.
                let s = shapes[&n.inputs[0].0];
                let os = shapes[&Edge::new(n.id, 0)];
                let gauge = BufferSpec {
                    name: format!("{}.window", n.name),
                    kind: StreamKind::WindowSlice,
                    capacity: k * s.w * s.c,
                };
                stages.push(StagePlan::Pool(PoolPlan {
                    name: n.name.clone(),
                    k: *k,
                    stride: *stride,
                    ih: s.h,
                    iw: s.w,
                    c: s.c,
                    oh: os.h,
                    ow: os.w,
                    input: fifo_of[&(n.inputs[0].0, n.id)],
                    outs: outs_for_node(n.id)?,
                    gauge,
                }));
            }
            Op::GlobalAvgPool { out_exp } => {
                let s = shapes[&n.inputs[0].0];
                anyhow::ensure!(
                    (s.h * s.w).is_power_of_two(),
                    "{}: global pool window {}x{} must be 2^k",
                    n.name,
                    s.h,
                    s.w
                );
                stages.push(StagePlan::Gap(GapPlan {
                    name: n.name.clone(),
                    h: s.h,
                    w: s.w,
                    c: s.c,
                    in_exp: s.exp,
                    out_exp: *out_exp,
                    input: fifo_of[&(n.inputs[0].0, n.id)],
                    outs: outs_for_node(n.id)?,
                }));
            }
            Op::Linear { cin, cout, .. } => {
                let s = shapes[&n.inputs[0].0];
                let lw = weights.layer(&n.name)?;
                anyhow::ensure!(
                    lw.w.data.len() == cin * cout && lw.b.data.len() == *cout,
                    "{}: linear weight sizes mismatch",
                    n.name
                );
                stages.push(StagePlan::Linear(LinearPlan {
                    name: n.name.clone(),
                    layer: n.name.clone(),
                    cout: *cout,
                    tokens: s.h * s.w,
                    cin: *cin,
                    input: fifo_of[&(n.inputs[0].0, n.id)],
                    outs: outs_for_node(n.id)?,
                }));
            }
            Op::Relu => {
                let s = shapes[&n.inputs[0].0];
                stages.push(StagePlan::Relu(ReluPlan {
                    name: n.name.clone(),
                    tokens: s.h * s.w,
                    input: fifo_of[&(n.inputs[0].0, n.id)],
                    outs: outs_for_node(n.id)?,
                }));
            }
            Op::Add { out_exp } => {
                // Fusable residual merges are the optimizer's job — refuse
                // them outside naive mode so a half-optimized graph cannot
                // silently run the slow dataflow.  Non-fusable merges
                // (multi-input or shared long branches) have no fused form
                // and are planned as naive islands in either mode.
                anyhow::ensure!(
                    cfg.naive_add || !crate::passes::is_fusable_residual(g, n.id),
                    "stream backend runs optimized graphs only unless naive_add is set \
                     ({} is a fusable add node)",
                    n.name
                );
                let os = shapes[&Edge::new(n.id, 0)];
                // Operand exponents: a raw conv streams accumulators at
                // its weights' acc exponent (golden's Op::Add contract).
                let exp_of = |e: &Edge| -> Result<i32> {
                    let p = g.node(e.node);
                    if let Op::Conv(a) = &p.op {
                        if a.raw_output {
                            return Ok(weights.layer(&p.name)?.acc_exp());
                        }
                    }
                    Ok(shapes[e].exp)
                };
                let exps: Vec<i32> =
                    n.inputs.iter().map(|(e, _)| exp_of(e)).collect::<Result<_>>()?;
                let lo = exps.iter().copied().min().unwrap_or(*out_exp);
                stages.push(StagePlan::Add(AddPlan {
                    name: n.name.clone(),
                    tokens: os.h * os.w,
                    sa: ((exps[0] - lo) as u32).min(63),
                    sb: exps[1..].iter().map(|&e| ((e - lo) as u32).min(63)).collect(),
                    shift: out_exp - lo,
                    in_a: fifo_of[&(n.inputs[0].0, n.id)],
                    in_b: n.inputs[1..]
                        .iter()
                        .map(|(e, _)| fifo_of[&(*e, n.id)])
                        .collect(),
                    outs: outs_for_node(n.id)?,
                }));
            }
            Op::BatchNorm(_) => {
                bail!("stream backend runs post-fold graphs only ({} is a batchnorm)", n.name);
            }
        }
    }
    let sources = sources.ok_or_else(|| anyhow!("graph has no input node"))?;
    let Some((in_h, in_w, in_c, in_exp)) = input_spec else {
        bail!("graph input recorded no spec");
    };

    let whole_tensor_elems: usize = shapes
        .iter()
        .filter(|(e, _)| {
            !matches!(g.node(e.node).op, Op::Input { .. }) && !(e.node == out_node && e.port == 0)
        })
        .map(|(_, s)| s.h * s.w * s.c)
        .sum();

    Ok(PipelineBlueprint {
        stages,
        fifo_specs,
        source_ports: sources,
        sink_port,
        timeout,
        classes,
        out_tokens,
        in_h,
        in_w,
        in_c,
        in_exp,
        whole_tensor_elems,
    })
}

// ------------------------------------- column/channel-parallel workers

/// Per-row work unit fanned out to the channel workers (row-granular
/// mode): cheap Arc clones of the resident window rows plus the row's
/// skip tokens.
struct RowJob {
    rows: Vec<Arc<[i32]>>,
    first_abs: usize,
    oy: usize,
    skip: Option<Arc<Vec<Box<[i32]>>>>,
}

/// Per-window-group work unit fanned out to the column x channel worker
/// grid (slice-granular mode): Arc clones of exactly the pixels the
/// group's `cols` adjacent windows can touch.
#[derive(Clone)]
struct GroupJob {
    /// Row-major over the clamped span: `pixels[r * span_w + c]` is input
    /// pixel `(y0 + r, x0 + c)`.
    pixels: Vec<Arc<[i32]>>,
    y0: usize,
    x0: usize,
    span_w: usize,
    oy: usize,
    /// First output column of the group.
    ox0: usize,
    /// Columns in this group (`ow_par`, or the `ow % ow_par` remainder).
    cols: usize,
    /// The group's skip tokens, indexed by column-within-group.
    skip: Option<Arc<Vec<Box<[i32]>>>>,
}

#[derive(Clone)]
struct ConvGeom {
    k: usize,
    stride: usize,
    pad: usize,
    ih: usize,
    iw: usize,
    ich: usize,
    ow: usize,
    och: usize,
    relu: bool,
    raw: bool,
    acc_exp: i32,
    out_exp: i32,
    skip_shift: u32,
}

/// Read access to (valid, pad-adjusted) input pixels for the kernel —
/// abstracts over the row-granular and pixel-granular storages so both
/// monomorphize the one shared core.
trait PixelSource {
    /// Channel vector of input pixel `(iy, ix)` (already pad-adjusted
    /// and in-bounds — the core's tap loop guarantees it).
    fn pixel(&self, iy: usize, ix: usize) -> &[i32];
}

/// Whole-row storage view (`LineBuffer` snapshots / row worker jobs).
struct RowsView<'a> {
    rows: &'a [Arc<[i32]>],
    first_row: usize,
    ich: usize,
}

impl PixelSource for RowsView<'_> {
    fn pixel(&self, iy: usize, ix: usize) -> &[i32] {
        let row = &self.rows[iy - self.first_row];
        &row[ix * self.ich..(ix + 1) * self.ich]
    }
}

/// Resident pixel-window view (`SliceWindow`, inline slice-mode path).
struct WinView<'a> {
    win: &'a SliceWindow,
    iw: usize,
}

impl PixelSource for WinView<'_> {
    fn pixel(&self, iy: usize, ix: usize) -> &[i32] {
        self.win.pixel(iy * self.iw + ix)
    }
}

/// Clamped span snapshot carried by a [`GroupJob`] to the worker grid.
struct SpanView<'a> {
    pixels: &'a [Arc<[i32]>],
    y0: usize,
    x0: usize,
    span_w: usize,
}

impl PixelSource for SpanView<'_> {
    fn pixel(&self, iy: usize, ix: usize) -> &[i32] {
        &self.pixels[(iy - self.y0) * self.span_w + (ix - self.x0)]
    }
}

/// THE conv kernel core: compute channels `[lo, hi)` of the single
/// window at output position `(oy, ox)` into `out` (`hi - lo` values),
/// with `acc` as same-sized scratch.  Every path — the inline row and
/// slice stages, the channel-parallel row workers, the column x channel
/// group workers, and the merged-downsample emission — runs this one
/// function, so the bias + aligned-skip accumulator init, tap order and
/// requantize contract cannot drift between them — the property
/// bit-exactness vs golden rests on.
#[allow(clippy::too_many_arguments)]
fn conv_pos_core<V: PixelSource>(
    geom: &ConvGeom,
    w: &[i32],
    bias: &[i32],
    v: &V,
    oy: usize,
    ox: usize,
    skip: Option<&[i32]>,
    lo: usize,
    hi: usize,
    acc: &mut [i32],
    out: &mut [i32],
) {
    debug_assert_eq!(out.len(), hi - lo);
    debug_assert_eq!(acc.len(), hi - lo);
    // Accumulator init: bias (Fig. 4), then the aligned skip stream
    // (Fig. 13) — same order as golden's conv2d.
    acc.copy_from_slice(&bias[lo..hi]);
    if let Some(sk) = skip {
        for (a, &sv) in acc.iter_mut().zip(&sk[lo..hi]) {
            *a += sv << geom.skip_shift;
        }
    }
    for ky in 0..geom.k {
        let iy = oy * geom.stride + ky;
        if iy < geom.pad || iy - geom.pad >= geom.ih {
            continue;
        }
        for kx in 0..geom.k {
            let ix = ox * geom.stride + kx;
            if ix < geom.pad || ix - geom.pad >= geom.iw {
                continue;
            }
            let px = v.pixel(iy - geom.pad, ix - geom.pad);
            let wtap = (ky * geom.k + kx) * geom.ich * geom.och;
            for ci in 0..geom.ich {
                let xv = px[ci];
                if xv == 0 {
                    continue;
                }
                let ws = &w[wtap + ci * geom.och + lo..wtap + ci * geom.och + hi];
                for (a, &wv) in acc.iter_mut().zip(ws) {
                    *a += xv * wv;
                }
            }
        }
    }
    if geom.raw {
        out.copy_from_slice(acc);
    } else {
        for (o, &av) in out.iter_mut().zip(acc.iter()) {
            *o = requantize(av, geom.acc_exp, geom.out_exp, geom.relu);
        }
    }
}

/// Row-granular wrapper: channels `[lo, hi)` of every window position of
/// output row `oy` into `out` (`ow x (hi-lo)`, row-major by position),
/// reading the resident rows starting at absolute index `first_abs`.
#[allow(clippy::too_many_arguments)]
fn conv_row_kernel(
    geom: &ConvGeom,
    w: &[i32],
    bias: &[i32],
    rows: &[Arc<[i32]>],
    first_abs: usize,
    oy: usize,
    skip: Option<&[Box<[i32]>]>,
    lo: usize,
    hi: usize,
    out: &mut [i32],
) {
    let chunk = hi - lo;
    debug_assert_eq!(out.len(), geom.ow * chunk);
    let v = RowsView { rows, first_row: first_abs, ich: geom.ich };
    let mut acc = vec![0i32; chunk];
    for ox in 0..geom.ow {
        let sk = skip.map(|s| &*s[ox]);
        conv_pos_core(
            geom,
            w,
            bias,
            &v,
            oy,
            ox,
            sk,
            lo,
            hi,
            &mut acc,
            &mut out[ox * chunk..(ox + 1) * chunk],
        );
    }
}

fn conv_geom<P, G>(p: &ConvPlan<P, G>) -> ConvGeom {
    ConvGeom {
        k: p.k,
        stride: p.stride,
        pad: p.pad,
        ih: p.ih,
        iw: p.iw,
        ich: p.ich,
        ow: p.ow,
        och: p.och,
        relu: p.relu,
        raw: p.raw,
        acc_exp: p.acc_exp,
        out_exp: p.out_exp,
        skip_shift: p.skip.as_ref().map_or(0, |s| s.shift),
    }
}

/// The merged downsample as kernel geometry: same input rows as the host
/// conv, its own window/channel shape, never raw, no skip init.
fn ds_geom<P, G>(ds: &DsPlan<P>, host: &ConvPlan<P, G>) -> ConvGeom {
    ConvGeom {
        k: ds.k,
        stride: ds.stride,
        pad: ds.pad,
        ih: host.ih,
        iw: host.iw,
        ich: host.ich,
        ow: ds.ow,
        och: ds.och,
        relu: false,
        raw: false,
        acc_exp: ds.acc_exp,
        out_exp: ds.out_exp,
        skip_shift: 0,
    }
}

/// One worker's answer to one fanned-out job: its channel-range outputs,
/// or the typed error that degrades the stage (and pool) instead of a
/// worker panic.
type WorkerResult = Result<Vec<i32>, StreamError>;

/// Resolve a plan-validated weights layer inside a running stage or
/// worker.  [`plan_pipeline`] validated every layer the plan references,
/// so a miss here means the pool's bookkeeping (not the user's graph)
/// broke — it degrades into the typed [`StreamError::Inconsistent`] path
/// that poisons the pool, instead of panicking the thread and wedging
/// the replica.
fn stage_layer<'a>(
    weights: &'a ModelWeights,
    name: &str,
    what: &'static str,
) -> Result<&'a ConvWeights, StreamError> {
    weights.layer(name).map_err(|_| StreamError::Inconsistent { what })
}

/// Worker body: run the shared kernel over this worker's channel range
/// for every row job the stage fans out.
fn conv_worker(
    geom: ConvGeom,
    layer: String,
    weights: Arc<ModelWeights>,
    lo: usize,
    hi: usize,
    jobs: mpsc::Receiver<RowJob>,
    results: mpsc::SyncSender<WorkerResult>,
) {
    let lw = match stage_layer(
        &weights,
        &layer,
        "conv worker weights missing after plan validation",
    ) {
        Ok(lw) => lw,
        Err(e) => {
            // Report the typed inconsistency on the result channel (the
            // stage's next recv propagates it) and exit.
            let _ = results.send(Err(e));
            return;
        }
    };
    let w = lw.w.data.as_slice();
    let bias = lw.b.data.as_slice();
    let chunk = hi - lo;
    while let Ok(job) = jobs.recv() {
        let mut out = vec![0i32; geom.ow * chunk];
        conv_row_kernel(
            &geom,
            w,
            bias,
            &job.rows,
            job.first_abs,
            job.oy,
            job.skip.as_ref().map(|v| v.as_slice()),
            lo,
            hi,
            &mut out,
        );
        if results.send(Ok(out)).is_err() {
            return; // stage unwound — exit quietly
        }
    }
}

/// Group-worker body (slice mode): for every fanned-out window group,
/// run the shared core over this worker's strided column set
/// (`col0, col0 + col_stride, ...` within the group) and channel range.
/// Remainder groups (`cols < ow_par`) simply yield fewer (possibly zero)
/// columns — no dropped or duplicated tail columns by construction.
#[allow(clippy::too_many_arguments)]
fn conv_group_worker(
    geom: ConvGeom,
    layer: String,
    weights: Arc<ModelWeights>,
    col0: usize,
    col_stride: usize,
    lo: usize,
    hi: usize,
    jobs: mpsc::Receiver<GroupJob>,
    results: mpsc::SyncSender<WorkerResult>,
) {
    let lw = match stage_layer(
        &weights,
        &layer,
        "conv worker weights missing after plan validation",
    ) {
        Ok(lw) => lw,
        Err(e) => {
            let _ = results.send(Err(e));
            return;
        }
    };
    let w = lw.w.data.as_slice();
    let bias = lw.b.data.as_slice();
    let chunk = hi - lo;
    let mut acc = vec![0i32; chunk];
    while let Ok(job) = jobs.recv() {
        let v = SpanView { pixels: &job.pixels, y0: job.y0, x0: job.x0, span_w: job.span_w };
        let mut out = Vec::new();
        for c in (col0..job.cols).step_by(col_stride) {
            let start = out.len();
            out.resize(start + chunk, 0);
            let sk = job.skip.as_ref().map(|s| &*s[c]);
            conv_pos_core(
                &geom,
                w,
                bias,
                &v,
                job.oy,
                job.ox0 + c,
                sk,
                lo,
                hi,
                &mut acc,
                &mut out[start..],
            );
        }
        if results.send(Ok(out)).is_err() {
            return; // stage unwound — exit quietly
        }
    }
}

/// A worker thread's whole-lifetime body, handed its job/result ends.
type WorkerBody<J> = Box<dyn FnOnce(mpsc::Receiver<J>, mpsc::SyncSender<WorkerResult>) + Send>;

/// Handle on a conv stage's worker threads; dropping it closes both
/// channel ends first so every worker exits its loop, then joins.
struct Workers<J> {
    txs: Vec<mpsc::SyncSender<J>>,
    rxs: Vec<mpsc::Receiver<WorkerResult>>,
    handles: Vec<Option<thread::JoinHandle<()>>>,
}

impl<J> Drop for Workers<J> {
    fn drop(&mut self) {
        self.txs.clear();
        self.rxs.clear();
        for h in &mut self.handles {
            if let Some(h) = h.take() {
                let _ = h.join();
            }
        }
    }
}

impl<J: Send + 'static> Workers<J> {
    fn spawn(specs: Vec<WorkerBody<J>>) -> Workers<J> {
        let mut txs = Vec::new();
        let mut rxs = Vec::new();
        let mut handles = Vec::new();
        for body in specs {
            let (jtx, jrx) = mpsc::sync_channel::<J>(1);
            let (rtx, rrx) = mpsc::sync_channel::<WorkerResult>(1);
            handles.push(Some(thread::spawn(move || body(jrx, rtx))));
            txs.push(jtx);
            rxs.push(rrx);
        }
        Workers { txs, rxs, handles }
    }
}

/// Channel-range workers for the row-granular path.
fn spawn_row_workers(p: &RunConvPlan, weights: &Arc<ModelWeights>) -> Workers<RowJob> {
    let geom = conv_geom(p);
    let specs: Vec<WorkerBody<RowJob>> = p
        .worker_ranges
        .iter()
        .map(|&(lo, hi)| {
            let g = geom.clone();
            let wts = weights.clone();
            let layer = p.layer.clone();
            Box::new(move |jobs, results| conv_worker(g, layer, wts, lo, hi, jobs, results))
                as WorkerBody<RowJob>
        })
        .collect();
    Workers::spawn(specs)
}

/// The column x channel worker grid for the slice-granular path, in
/// column-major worker order: worker `c * nranges + ri` owns group
/// columns `{c, c + col_workers, ...}` and channel range `ri`.
fn spawn_group_workers(p: &RunConvPlan, weights: &Arc<ModelWeights>) -> Workers<GroupJob> {
    let geom = conv_geom(p);
    let cw = p.col_workers.max(1);
    let mut specs: Vec<WorkerBody<GroupJob>> = Vec::new();
    for c in 0..cw {
        for &(lo, hi) in &p.worker_ranges {
            let g = geom.clone();
            let wts = weights.clone();
            let layer = p.layer.clone();
            specs.push(Box::new(move |jobs, results| {
                conv_group_worker(g, layer, wts, c, cw, lo, hi, jobs, results)
            }));
        }
    }
    Workers::spawn(specs)
}

// ---------------------------------------------------------- stage bodies

/// Emit one merged-downsample output row through the shared kernel.
fn emit_ds_row(
    ds: &RunDsPlan,
    geom: &ConvGeom,
    dw: &[i32],
    db: &[i32],
    lb: &LineBuffer,
    dy: usize,
) -> Result<(), StreamError> {
    let (first_abs, rows) = lb.resident();
    let mut out = vec![0i32; ds.ow * ds.och];
    conv_row_kernel(geom, dw, db, &rows, first_abs, dy, None, 0, ds.och, &mut out);
    for ox in 0..ds.ow {
        push_all(&ds.outs, Box::from(&out[ox * ds.och..(ox + 1) * ds.och]))?;
    }
    Ok(())
}

/// Emit every downsample row whose input rows are already resident.
fn emit_ready_ds_rows(
    ds_next: &mut usize,
    ds: &RunDsPlan,
    geom: &ConvGeom,
    dw: &[i32],
    db: &[i32],
    lb: &LineBuffer,
) -> Result<(), StreamError> {
    while *ds_next < ds.oh {
        let last = (*ds_next * ds.stride + ds.k).saturating_sub(1 + ds.pad).min(geom.ih - 1);
        if lb.next_row() <= last {
            break;
        }
        emit_ds_row(ds, geom, dw, db, lb, *ds_next)?;
        *ds_next += 1;
    }
    Ok(())
}

/// Dispatch on the planned window-storage mode.
fn run_conv(
    p: &RunConvPlan,
    weights: &Arc<ModelWeights>,
    clock: &StageClock,
) -> Result<(), StreamError> {
    match p.storage {
        WindowStorage::Rows => run_conv_rows(p, weights, clock),
        WindowStorage::Slices => run_conv_slices(p, weights, clock),
    }
}

fn run_conv_rows(
    p: &RunConvPlan,
    weights: &Arc<ModelWeights>,
    clock: &StageClock,
) -> Result<(), StreamError> {
    let lw = stage_layer(weights, &p.layer, "conv stage weights missing after plan validation")?;
    let w = lw.w.data.as_slice();
    let bias = lw.b.data.as_slice();
    let geom = conv_geom(p);
    // Merged downsample: kernel geometry + weights, resolved once.
    let ds_ctx = match p.ds.as_ref() {
        Some(d) => {
            let dw = stage_layer(
                weights,
                &d.layer,
                "downsample weights missing after plan validation",
            )?;
            Some((ds_geom(d, p), dw))
        }
        None => None,
    };
    let (k, s, pad) = (p.k, p.stride, p.pad);
    let mut lb = LineBuffer::new(p.iw * p.ich);
    let workers =
        if p.worker_ranges.len() > 1 { Some(spawn_row_workers(p, weights)) } else { None };
    let mut rowbuf = vec![0i32; p.ow * p.och];
    loop {
        let mut head = match next_frame(&p.input)? {
            Some(t) => Some(t),
            None => {
                // End of stream: consume the skip sentinel, propagate on
                // every output port, unwind the workers (Workers drop).
                if let Some(sk) = &p.skip {
                    let t = sk.fifo.pop()?;
                    debug_assert!(t.is_empty(), "skip stream out of frame sync");
                }
                push_eos(&p.outs)?;
                if let Some(fwd) = &p.forward {
                    push_eos(fwd)?;
                }
                if let Some(ds) = &p.ds {
                    push_eos(&ds.outs)?;
                }
                return Ok(());
            }
        };
        let mut ds_next = 0usize;
        for oy in 0..p.oh {
            // Pull rows until the window for output row `oy` is resident.
            let last = (oy * s + k).saturating_sub(1 + pad).min(p.ih - 1);
            while lb.next_row() <= last {
                lb.push_row(pull_row(&p.input, &mut head, p.iw, p.ich)?);
                p.gauge.observe(lb.held());
            }
            // Pop the row's skip tokens once (frees Eq. 22 capacity to the
            // producer no later than the per-pixel schedule would), then
            // run the shared kernel — fanned across the channel workers,
            // or inline over the full channel range.
            let skip_row: Option<Vec<Box<[i32]>>> = match &p.skip {
                Some(sk) => {
                    let mut v = Vec::with_capacity(p.ow);
                    for _ in 0..p.ow {
                        v.push(sk.fifo.pop()?);
                    }
                    Some(v)
                }
                None => None,
            };
            let (first_abs, rows) = lb.resident();
            match &workers {
                Some(wk) => {
                    let skip_shared = skip_row.map(Arc::new);
                    for tx in &wk.txs {
                        let job = RowJob {
                            rows: rows.clone(),
                            first_abs,
                            oy,
                            skip: skip_shared.clone(),
                        };
                        // A dead worker surfaces on its result channel
                        // below (typed error or disconnect), so a failed
                        // send is not terminal by itself.
                        let _ = tx.send(job);
                    }
                    let mut bufs = Vec::with_capacity(wk.rxs.len());
                    for rx in &wk.rxs {
                        match rx.recv() {
                            Ok(Ok(b)) => bufs.push(b),
                            Ok(Err(e)) => return Err(e),
                            Err(_) => return Err(StreamError::Panicked),
                        }
                    }
                    for ox in 0..p.ow {
                        let mut tok = vec![0i32; p.och];
                        for ((lo, hi), buf) in p.worker_ranges.iter().zip(&bufs) {
                            let c = hi - lo;
                            tok[*lo..*hi].copy_from_slice(&buf[ox * c..(ox + 1) * c]);
                        }
                        push_all(&p.outs, tok.into_boxed_slice())?;
                    }
                }
                None => {
                    conv_row_kernel(
                        &geom,
                        w,
                        bias,
                        &rows,
                        first_abs,
                        oy,
                        skip_row.as_deref(),
                        0,
                        p.och,
                        &mut rowbuf,
                    );
                    for ox in 0..p.ow {
                        push_all(&p.outs, Box::from(&rowbuf[ox * p.och..(ox + 1) * p.och]))?;
                    }
                }
            }
            if let (Some(ds), Some((dg, dw))) = (&p.ds, ds_ctx.as_ref()) {
                emit_ready_ds_rows(&mut ds_next, ds, dg, &dw.w.data, &dw.b.data, &lb)?;
            }
            // Evict rows that neither the host's next output row nor the
            // pending downsample rows can still reach; forwarded rows are
            // the temporal-reuse skip stream.
            let next_host =
                if oy + 1 < p.oh { ((oy + 1) * s).saturating_sub(pad) } else { p.ih };
            let next_ds = match &p.ds {
                Some(ds) if ds_next < ds.oh => (ds_next * ds.stride).saturating_sub(ds.pad),
                _ => p.ih,
            };
            let evicted = lb.evict_below(next_host.min(next_ds));
            if let Some(fwd) = &p.forward {
                forward_rows(fwd, &evicted, p.ich)?;
            }
        }
        // Frame drain: finish the downsample program, consume any input
        // rows the host windows never reached, and flush the line buffer
        // (the skip consumer expects the complete forwarded tensor).
        if let (Some(ds), Some((dg, dw))) = (&p.ds, ds_ctx.as_ref()) {
            while ds_next < ds.oh {
                let last = (ds_next * ds.stride + ds.k).saturating_sub(1 + ds.pad).min(p.ih - 1);
                while lb.next_row() <= last {
                    lb.push_row(pull_row(&p.input, &mut head, p.iw, p.ich)?);
                    p.gauge.observe(lb.held());
                }
                emit_ds_row(ds, dg, &dw.w.data, &dw.b.data, &lb, ds_next)?;
                ds_next += 1;
            }
        }
        while lb.next_row() < p.ih {
            lb.push_row(pull_row(&p.input, &mut head, p.iw, p.ich)?);
            p.gauge.observe(lb.held());
        }
        let rest = lb.flush();
        if let Some(fwd) = &p.forward {
            forward_rows(fwd, &rest, p.ich)?;
        }
        clock.frame_done();
    }
}

/// Emit one merged-downsample output row from the resident pixel window.
fn emit_ds_row_slices(
    ds: &RunDsPlan,
    geom: &ConvGeom,
    dw: &[i32],
    db: &[i32],
    win: &SliceWindow,
    iw: usize,
    dy: usize,
) -> Result<(), StreamError> {
    let v = WinView { win, iw };
    let mut acc = vec![0i32; ds.och];
    let mut out = vec![0i32; ds.och];
    for ox in 0..ds.ow {
        conv_pos_core(geom, dw, db, &v, dy, ox, None, 0, ds.och, &mut acc, &mut out);
        push_all(&ds.outs, Box::from(&out[..]))?;
    }
    Ok(())
}

/// Emit every downsample row whose input pixels are already resident.
#[allow(clippy::too_many_arguments)]
fn emit_ready_ds_rows_slices(
    ds_next: &mut usize,
    ds: &RunDsPlan,
    geom: &ConvGeom,
    dw: &[i32],
    db: &[i32],
    win: &SliceWindow,
    iw: usize,
) -> Result<(), StreamError> {
    while *ds_next < ds.oh {
        let last = (*ds_next * ds.stride + ds.k).saturating_sub(1 + ds.pad).min(geom.ih - 1);
        if win.next_pixel() < (last + 1) * iw {
            break;
        }
        emit_ds_row_slices(ds, geom, dw, db, win, iw, *ds_next)?;
        *ds_next += 1;
    }
    Ok(())
}

/// Slice-granular conv stage (the default): consume the depth-first
/// pixel stream one `ow_par`-wide window group at a time, holding
/// exactly the Eq. 16/17 span (`slice_plan` total plus the in-flight
/// pixel) and evicting pixel-by-pixel in stream order behind the last
/// window — host or pending merged downsample — that can still reach
/// each pixel.  Evicted pixels are the temporal-reuse skip stream.
fn run_conv_slices(
    p: &RunConvPlan,
    weights: &Arc<ModelWeights>,
    clock: &StageClock,
) -> Result<(), StreamError> {
    let lw = stage_layer(weights, &p.layer, "conv stage weights missing after plan validation")?;
    let w = lw.w.data.as_slice();
    let bias = lw.b.data.as_slice();
    let geom = conv_geom(p);
    let ds_ctx = match p.ds.as_ref() {
        Some(d) => {
            let dw = stage_layer(
                weights,
                &d.layer,
                "downsample weights missing after plan validation",
            )?;
            Some((ds_geom(d, p), dw))
        }
        None => None,
    };
    let (k, s, pad) = (p.k, p.stride, p.pad);
    let owp = p.ow_par.max(1);
    let groups = p.ow.div_ceil(owp);
    let nranges = p.worker_ranges.len();
    let cw = p.col_workers.max(1);
    let mut win = SliceWindow::new(p.ich, &p.window);
    let workers =
        if cw * nranges > 1 { Some(spawn_group_workers(p, weights)) } else { None };
    let mut acc = vec![0i32; p.och];
    let mut tokbuf = vec![0i32; p.och];
    loop {
        let mut head = match next_frame(&p.input)? {
            Some(t) => Some(t),
            None => {
                // End of stream: consume the skip sentinel, propagate on
                // every output port, unwind the workers (Workers drop).
                if let Some(sk) = &p.skip {
                    let t = sk.fifo.pop()?;
                    debug_assert!(t.is_empty(), "skip stream out of frame sync");
                }
                push_eos(&p.outs)?;
                if let Some(fwd) = &p.forward {
                    push_eos(fwd)?;
                }
                if let Some(ds) = &p.ds {
                    push_eos(&ds.outs)?;
                }
                return Ok(());
            }
        };
        let mut ds_next = 0usize;
        for oy in 0..p.oh {
            for xg in 0..groups {
                let ox0 = xg * owp;
                let cols = owp.min(p.ow - ox0);
                // Pull pixels until the group's widened window (Fig. 8:
                // `cols` adjacent computation windows) is resident.
                let y_last = (oy * s + k).saturating_sub(1 + pad).min(p.ih - 1);
                let x_last =
                    ((ox0 + cols - 1) * s + k).saturating_sub(1 + pad).min(p.iw - 1);
                while win.next_pixel() <= y_last * p.iw + x_last {
                    win.push_pixel(pull_pixel(&p.input, &mut head)?);
                    p.gauge.observe(win.held());
                }
                // Pop the group's skip tokens once (frees Eq. 22 capacity
                // to the producer at the per-group schedule).
                let skip_g: Option<Vec<Box<[i32]>>> = match &p.skip {
                    Some(sk) => {
                        let mut v = Vec::with_capacity(cols);
                        for _ in 0..cols {
                            v.push(sk.fifo.pop()?);
                        }
                        Some(v)
                    }
                    None => None,
                };
                match &workers {
                    Some(wk) => {
                        // Snapshot the clamped pixel span the group's
                        // windows can touch; fan it to the worker grid.
                        let y0 = (oy * s).saturating_sub(pad);
                        let x0 = (ox0 * s).saturating_sub(pad);
                        let span_w = x_last + 1 - x0;
                        let mut pixels = Vec::with_capacity((y_last + 1 - y0) * span_w);
                        for y in y0..=y_last {
                            for x in x0..=x_last {
                                pixels.push(win.pixel_arc(y * p.iw + x).clone());
                            }
                        }
                        let job = GroupJob {
                            pixels,
                            y0,
                            x0,
                            span_w,
                            oy,
                            ox0,
                            cols,
                            skip: skip_g.map(Arc::new),
                        };
                        for tx in &wk.txs {
                            // A dead worker surfaces on its result
                            // channel below, so a failed send is not
                            // terminal by itself.
                            let _ = tx.send(job.clone());
                        }
                        let mut bufs = Vec::with_capacity(wk.rxs.len());
                        for rx in &wk.rxs {
                            match rx.recv() {
                                Ok(Ok(b)) => bufs.push(b),
                                Ok(Err(e)) => return Err(e),
                                Err(_) => return Err(StreamError::Panicked),
                            }
                        }
                        // Reassemble in stream (column) order: column c's
                        // channel range ri came from worker
                        // `(c % cw) * nranges + ri`, slot `c / cw`.
                        for c in 0..cols {
                            let mut tok = vec![0i32; p.och];
                            for (ri, (lo, hi)) in p.worker_ranges.iter().enumerate() {
                                let chunk = hi - lo;
                                let buf = &bufs[(c % cw) * nranges + ri];
                                tok[*lo..*hi].copy_from_slice(
                                    &buf[(c / cw) * chunk..(c / cw + 1) * chunk],
                                );
                            }
                            push_all(&p.outs, tok.into_boxed_slice())?;
                        }
                    }
                    None => {
                        let v = WinView { win: &win, iw: p.iw };
                        for c in 0..cols {
                            let sk = skip_g.as_ref().map(|sg| &*sg[c]);
                            conv_pos_core(
                                &geom,
                                w,
                                bias,
                                &v,
                                oy,
                                ox0 + c,
                                sk,
                                0,
                                p.och,
                                &mut acc,
                                &mut tokbuf,
                            );
                            push_all(&p.outs, Box::from(&tokbuf[..]))?;
                        }
                    }
                }
                // Evict (and forward) every pixel no future host window
                // or pending downsample row can still reach.
                let next_host = if xg + 1 < groups {
                    (oy * s).saturating_sub(pad) * p.iw
                        + ((ox0 + owp) * s).saturating_sub(pad)
                } else if oy + 1 < p.oh {
                    ((oy + 1) * s).saturating_sub(pad) * p.iw
                } else {
                    p.ih * p.iw
                };
                let next_ds = match &p.ds {
                    Some(ds) if ds_next < ds.oh => {
                        (ds_next * ds.stride).saturating_sub(ds.pad) * p.iw
                    }
                    _ => p.ih * p.iw,
                };
                let evicted = win.evict_below(next_host.min(next_ds));
                if let Some(fwd) = &p.forward {
                    forward_pixels(fwd, &evicted)?;
                }
            }
            if let (Some(ds), Some((dg, dwts))) = (&p.ds, ds_ctx.as_ref()) {
                emit_ready_ds_rows_slices(
                    &mut ds_next,
                    ds,
                    dg,
                    &dwts.w.data,
                    &dwts.b.data,
                    &win,
                    p.iw,
                )?;
                // The downsample advanced: release what only it retained.
                let next_host = if oy + 1 < p.oh {
                    ((oy + 1) * s).saturating_sub(pad) * p.iw
                } else {
                    p.ih * p.iw
                };
                let next_ds = if ds_next < ds.oh {
                    (ds_next * ds.stride).saturating_sub(ds.pad) * p.iw
                } else {
                    p.ih * p.iw
                };
                let evicted = win.evict_below(next_host.min(next_ds));
                if let Some(fwd) = &p.forward {
                    forward_pixels(fwd, &evicted)?;
                }
            }
        }
        // Frame drain: finish the downsample program (pulling pixel by
        // pixel through the one emit-when-ready helper), then release
        // every resident and consume-and-forward any pixels no window
        // ever reaches *without* re-buffering them — the Eq. 16/17 gauge
        // must never count unreachable pixels (e.g. the odd rows a
        // standalone strided conv skips in naive mode).
        if let (Some(ds), Some((dg, dwts))) = (&p.ds, ds_ctx.as_ref()) {
            while ds_next < ds.oh {
                emit_ready_ds_rows_slices(
                    &mut ds_next,
                    ds,
                    dg,
                    &dwts.w.data,
                    &dwts.b.data,
                    &win,
                    p.iw,
                )?;
                if ds_next < ds.oh {
                    win.push_pixel(pull_pixel(&p.input, &mut head)?);
                    p.gauge.observe(win.held());
                }
            }
        }
        let rest = win.evict_below(win.next_pixel());
        if let Some(fwd) = &p.forward {
            forward_pixels(fwd, &rest)?;
        }
        let mut unreached = (p.ih * p.iw).saturating_sub(win.next_pixel());
        while unreached > 0 {
            let px = pull_pixel(&p.input, &mut head)?;
            if let Some(fwd) = &p.forward {
                push_all(fwd, Box::from(&px[..]))?;
            }
            unreached -= 1;
        }
        win.flush();
        clock.frame_done();
    }
}

fn run_pool(p: &RunPoolPlan, clock: &StageClock) -> Result<(), StreamError> {
    let mut lb = LineBuffer::new(p.iw * p.c);
    loop {
        let mut head = match next_frame(&p.input)? {
            Some(t) => Some(t),
            None => {
                push_eos(&p.outs)?;
                return Ok(());
            }
        };
        for oy in 0..p.oh {
            let last = (oy * p.stride + p.k - 1).min(p.ih - 1);
            while lb.next_row() <= last {
                lb.push_row(pull_row(&p.input, &mut head, p.iw, p.c)?);
                p.gauge.observe(lb.held());
            }
            for ox in 0..p.ow {
                let mut best = vec![i32::MIN; p.c];
                for ky in 0..p.k {
                    let row = lb.row(oy * p.stride + ky);
                    for kx in 0..p.k {
                        let base = (ox * p.stride + kx) * p.c;
                        for (ch, b) in best.iter_mut().enumerate() {
                            *b = (*b).max(row[base + ch]);
                        }
                    }
                }
                push_all(&p.outs, best.into_boxed_slice())?;
            }
            let next = if oy + 1 < p.oh { (oy + 1) * p.stride } else { p.ih };
            lb.evict_below(next);
        }
        while lb.next_row() < p.ih {
            lb.push_row(pull_row(&p.input, &mut head, p.iw, p.c)?);
            p.gauge.observe(lb.held());
        }
        lb.flush();
        clock.frame_done();
    }
}

fn run_gap(p: &RunGapPlan, clock: &StageClock) -> Result<(), StreamError> {
    let hw = p.h * p.w;
    // Power-of-two validated at plan time.
    let shift = p.out_exp - p.in_exp + hw.trailing_zeros() as i32;
    loop {
        let head = match next_frame(&p.input)? {
            Some(t) => t,
            None => {
                push_eos(&p.outs)?;
                return Ok(());
            }
        };
        let mut acc = vec![0i32; p.c];
        for (a, &v) in acc.iter_mut().zip(head.iter()) {
            *a += v;
        }
        for _ in 1..hw {
            let t = p.input.pop()?;
            for (a, &v) in acc.iter_mut().zip(t.iter()) {
                *a += v;
            }
        }
        let tok: Box<[i32]> = acc.iter().map(|&v| clip_i8(round_shift(v, shift))).collect();
        push_all(&p.outs, tok)?;
        clock.frame_done();
    }
}

fn run_linear(
    p: &RunLinearPlan,
    weights: &Arc<ModelWeights>,
    clock: &StageClock,
) -> Result<(), StreamError> {
    let lw =
        stage_layer(weights, &p.layer, "linear stage weights missing after plan validation")?;
    let w = lw.w.data.as_slice();
    let bias = lw.b.data.as_slice();
    loop {
        let head = match next_frame(&p.input)? {
            Some(t) => t,
            None => {
                push_eos(&p.outs)?;
                return Ok(());
            }
        };
        let mut xbuf = Vec::with_capacity(p.cin);
        xbuf.extend_from_slice(&head);
        for _ in 1..p.tokens {
            let t = p.input.pop()?;
            xbuf.extend_from_slice(&t);
        }
        let mut out = vec![0i32; p.cout];
        for (co, o) in out.iter_mut().enumerate() {
            let mut a = bias[co];
            for (ci, &xv) in xbuf.iter().enumerate() {
                a += xv * w[ci * p.cout + co];
            }
            *o = a;
        }
        push_all(&p.outs, out.into_boxed_slice())?;
        clock.frame_done();
    }
}

fn run_relu(p: &RunReluPlan, clock: &StageClock) -> Result<(), StreamError> {
    loop {
        let head = match next_frame(&p.input)? {
            Some(t) => t,
            None => {
                push_eos(&p.outs)?;
                return Ok(());
            }
        };
        let mut t = head;
        for i in 0..p.tokens {
            if i > 0 {
                t = p.input.pop()?;
            }
            let tok: Box<[i32]> = t.iter().map(|&v| v.max(0)).collect();
            push_all(&p.outs, tok)?;
        }
        clock.frame_done();
    }
}

fn run_add(p: &RunAddPlan, clock: &StageClock) -> Result<(), StreamError> {
    loop {
        let mut a = match next_frame(&p.in_a)? {
            Some(t) => t,
            None => {
                for f in &p.in_b {
                    let t = f.pop()?;
                    debug_assert!(t.is_empty(), "skip stream out of frame sync");
                }
                push_eos(&p.outs)?;
                return Ok(());
            }
        };
        for i in 0..p.tokens {
            if i > 0 {
                a = p.in_a.pop()?;
            }
            // Align every operand at the finest exponent, widen to i64 (a
            // raw int32 accumulator plus shifted operands can exceed i32),
            // then requantize once — bit-identical to golden's Op::Add.
            let mut sum: Vec<i64> = a.iter().map(|&x| (x as i64) << p.sa).collect();
            for (f, &sb) in p.in_b.iter().zip(&p.sb) {
                let b = f.pop()?;
                for (s, &y) in sum.iter_mut().zip(b.iter()) {
                    *s += (y as i64) << sb;
                }
            }
            let tok: Box<[i32]> =
                sum.iter().map(|&s| clip_i8_wide(round_shift_i64(s, p.shift))).collect();
            push_all(&p.outs, tok)?;
        }
        clock.frame_done();
    }
}

/// Run one stage until end-of-stream (or error).  This is the body a
/// pool thread executes for its whole lifetime.  `clock` is the stage's
/// observability clock; each loop stamps it once per completed frame
/// (the frame-boundary flush of the span rings).
pub(crate) fn run_stage(
    stage: &RunStagePlan,
    weights: &Arc<ModelWeights>,
    clock: &StageClock,
) -> Result<(), StreamError> {
    match stage {
        StagePlan::Conv(p) => run_conv(p, weights, clock),
        StagePlan::Pool(p) => run_pool(p, clock),
        StagePlan::Gap(p) => run_gap(p, clock),
        StagePlan::Linear(p) => run_linear(p, weights, clock),
        StagePlan::Relu(p) => run_relu(p, clock),
        StagePlan::Add(p) => run_add(p, clock),
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::models::{arch_by_name, build_optimized_graph, synthetic_weights};
    use crate::stream::{planned_config, StreamConfig};

    fn blueprint() -> (PipelineBlueprint, ModelWeights) {
        let arch = arch_by_name("resnet8").unwrap();
        let weights = synthetic_weights(&arch, 7);
        let g = build_optimized_graph(&arch, &weights.act_exps, &weights.w_exps);
        let cfg = StreamConfig::default();
        let acfg = planned_config("resnet8", &g, &cfg).unwrap();
        (plan_pipeline(&g, &weights, &cfg, &acfg).unwrap(), weights)
    }

    /// The pool-elasticity hoist: one blueprint stamps out any number of
    /// replicas — same sized FIFO/gauge chain, tag-distinguished names —
    /// without re-running shape inference or weight validation.
    #[test]
    fn blueprint_instantiates_tagged_replicas_from_one_plan() {
        let (bp, _) = blueprint();
        let r0 = bp.instantiate(&Arc::new(AtomicBool::new(false)), "");
        let r1 = bp.instantiate(&Arc::new(AtomicBool::new(false)), "r1/");
        assert_eq!(r0.stages.len(), bp.stages_per_replica());
        assert_eq!(r0.fifos.len(), r1.fifos.len());
        assert_eq!(r0.gauges.len(), r1.gauges.len());
        for (a, b) in r0.fifos.iter().zip(&r1.fifos) {
            assert_eq!(a.capacity(), b.capacity());
            assert_eq!(format!("r1/{}", a.name()), b.name());
        }
        for (a, b) in r0.gauges.iter().zip(&r1.gauges) {
            assert_eq!(format!("r1/{}", a.stat().name), b.stat().name);
        }
    }

    /// Regression (was `.expect("plan-validated layer")`): a conv stage
    /// whose weights key vanished after planning degrades into the typed
    /// error the supervisor poisons the pool with, instead of panicking
    /// the stage thread and wedging the replica.
    #[test]
    fn conv_stage_with_missing_weights_is_a_typed_inconsistency() {
        let (bp, weights) = blueprint();
        let mut plan = bp.instantiate(&Arc::new(AtomicBool::new(false)), "");
        let idx = plan
            .stages
            .iter()
            .position(|s| matches!(s, StagePlan::Conv(_)))
            .unwrap();
        if let StagePlan::Conv(p) = &mut plan.stages[idx] {
            p.layer = "no-such-layer".into();
        }
        let weights = Arc::new(weights);
        let clock = test_clock();
        let err = run_stage(&plan.stages[idx], &weights, &clock).unwrap_err();
        assert!(matches!(err, StreamError::Inconsistent { .. }), "{err}");
        assert!(format!("{err}").contains("weights missing"), "{err}");
    }

    fn test_clock() -> Arc<StageClock> {
        StageClock::new(
            "test".into(),
            crate::obs::StageRole::Stage,
            std::time::Instant::now(),
            Vec::new(),
            Vec::new(),
        )
    }

    /// The stall-attribution topology: every FIFO appears on exactly one
    /// stage's input side and (unless fed by the feeder or drained by the
    /// sink) exactly one stage's output side.
    #[test]
    fn stage_ports_cover_every_fifo_exactly_once_per_side() {
        let (bp, _) = blueprint();
        let plan = bp.instantiate(&Arc::new(AtomicBool::new(false)), "");
        let mut consumed: Vec<String> = Vec::new();
        let mut produced: Vec<String> = Vec::new();
        for st in &plan.stages {
            let (ins, outs) = st.ports();
            assert!(!ins.is_empty(), "{}: a stage always consumes", st.name());
            consumed.extend(ins.into_iter().map(|(n, _)| n));
            produced.extend(outs.into_iter().map(|(n, _)| n));
        }
        let all: Vec<&str> = plan.fifos.iter().map(|f| f.name()).collect();
        for name in &all {
            // The sink FIFO is drained by the sink pseudo-thread, every
            // other FIFO by exactly one stage; source FIFOs are fed by
            // the feeder pseudo-thread, every other FIFO by one stage.
            let is_sink = plan.sink.name() == *name;
            let is_source = plan.sources.iter().any(|f| f.name() == *name);
            assert_eq!(
                consumed.iter().filter(|n| n == name).count(),
                if is_sink { 0 } else { 1 },
                "{name}: exactly one consumer"
            );
            assert_eq!(
                produced.iter().filter(|n| n == name).count(),
                if is_source { 0 } else { 1 },
                "{name}: exactly one producer"
            );
        }
    }

    /// Regression for the worker-thread lookup (was a worker panic that
    /// wedged its stage): channel/column workers report the typed
    /// inconsistency on their result channel, which the stage propagates.
    #[test]
    fn conv_workers_report_missing_weights_as_typed_errors() {
        let arch = arch_by_name("resnet8").unwrap();
        let weights = Arc::new(synthetic_weights(&arch, 7));
        let geom = ConvGeom {
            k: 1,
            stride: 1,
            pad: 0,
            ih: 1,
            iw: 1,
            ich: 1,
            ow: 1,
            och: 1,
            relu: false,
            raw: true,
            acc_exp: 0,
            out_exp: 0,
            skip_shift: 0,
        };
        let (_jtx, jrx) = mpsc::sync_channel::<RowJob>(1);
        let (rtx, rrx) = mpsc::sync_channel::<WorkerResult>(1);
        conv_worker(geom.clone(), "bogus".into(), weights.clone(), 0, 1, jrx, rtx);
        assert!(matches!(rrx.recv().unwrap(), Err(StreamError::Inconsistent { .. })));
        let (_jtx, jrx) = mpsc::sync_channel::<GroupJob>(1);
        let (rtx, rrx) = mpsc::sync_channel::<WorkerResult>(1);
        conv_group_worker(geom, "bogus".into(), weights, 0, 1, 0, 1, jrx, rtx);
        assert!(matches!(rrx.recv().unwrap(), Err(StreamError::Inconsistent { .. })));
    }
}
