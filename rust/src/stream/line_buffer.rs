//! Software window buffers for the streaming executor (paper Section
//! III-F, Eqs. 16–17), in two granularities:
//!
//! * [`SliceWindow`] — pixel-granular, the execution counterpart of the
//!   hardware window buffer's FIFO slice chain (Figs. 7/9,
//!   [`hls::window::slice_plan`](crate::hls::window::slice_plan)): the
//!   stage holds exactly the Eq. 16/17 span — `B_i` buffered elements
//!   plus the in-flight pixel — and evicts pixel-by-pixel in stream
//!   order behind the last window that can still reach each pixel.
//! * [`LineBuffer`] — the row-granular legacy mode: retains at most `fh`
//!   complete input rows (`fh * iw * ich` elements — the Eq. 16 bound
//!   rounded up to whole rows), evicting whole rows.
//!
//! Eviction order is stream order in both, which is what lets the
//! temporal-reuse path (paper Fig. 12a) forward evicted pixels as the
//! skip stream with no second buffer.
//!
//! Pixels/rows are reference-counted (`Arc<[i32]>`) so a conv stage can
//! hand the resident window to its column/channel-parallel workers
//! without copying pixel data — the workers hold cheap clones while the
//! stage keeps evicting/forwarding at its own pace.  Occupancy reporting
//! is external: the owning stage publishes `held()` into its
//! pre-registered [`PeakGauge`](super::PeakGauge) after every push, so
//! the pool can read peaks while the pipeline runs.

use std::collections::VecDeque;
use std::sync::Arc;

use crate::hls::window::SlicePlan;

/// Sliding window of input rows with absolute row indexing.
pub struct LineBuffer {
    rows: VecDeque<Arc<[i32]>>,
    /// Absolute index (within the current frame) of `rows[0]`.
    first: usize,
    row_elems: usize,
    held: usize,
}

impl LineBuffer {
    pub fn new(row_elems: usize) -> LineBuffer {
        LineBuffer { rows: VecDeque::new(), first: 0, row_elems, held: 0 }
    }

    /// Absolute index of the next row to be pushed (== rows consumed from
    /// the input stream this frame).
    pub fn next_row(&self) -> usize {
        self.first + self.rows.len()
    }

    pub fn push_row(&mut self, row: Arc<[i32]>) {
        debug_assert_eq!(row.len(), self.row_elems);
        self.held += row.len();
        self.rows.push_back(row);
    }

    /// Row at absolute index `abs` (must be resident).
    pub fn row(&self, abs: usize) -> &[i32] {
        &self.rows[abs - self.first]
    }

    /// Elements currently held (published to the stage's peak gauge).
    pub fn held(&self) -> usize {
        self.held
    }

    /// Snapshot of the resident rows for channel-parallel workers:
    /// `(absolute index of the first row, cheap Arc clones in order)`.
    pub fn resident(&self) -> (usize, Vec<Arc<[i32]>>) {
        (self.first, self.rows.iter().cloned().collect())
    }

    /// Drop every resident row with absolute index `< abs`, returning them
    /// in stream order (for skip-path forwarding).
    pub fn evict_below(&mut self, abs: usize) -> Vec<Arc<[i32]>> {
        let mut out = Vec::new();
        while self.first < abs {
            match self.rows.pop_front() {
                Some(r) => {
                    self.held -= r.len();
                    self.first += 1;
                    out.push(r);
                }
                None => break,
            }
        }
        out
    }

    /// End-of-frame: drain the remaining rows in order and reset indices.
    pub fn flush(&mut self) -> Vec<Arc<[i32]>> {
        let out: Vec<_> = self.rows.drain(..).collect();
        self.held = 0;
        self.first = 0;
        out
    }
}

/// Pixel-granular sliding window with absolute pixel indexing — the
/// slice-chain storage mode (Figs. 7/9).  One "pixel" is the
/// `ich`-element channel vector of a spatial position, exactly one
/// stream token.
pub struct SliceWindow {
    pixels: VecDeque<Arc<[i32]>>,
    /// Absolute index (within the current frame, `y * iw + x`) of
    /// `pixels[0]`.
    first: usize,
    ich: usize,
    held: usize,
    /// Slice sizes of the configured chain (oldest-to-newest, from
    /// `hls::window::slice_plan`), for the per-slice occupancy view.
    slice_sizes: Vec<usize>,
}

impl SliceWindow {
    pub fn new(ich: usize, plan: &SlicePlan) -> SliceWindow {
        SliceWindow {
            pixels: VecDeque::new(),
            first: 0,
            ich,
            held: 0,
            slice_sizes: plan.sizes.clone(),
        }
    }

    /// Absolute index of the next pixel to be pushed (== pixels consumed
    /// from the input stream this frame).
    pub fn next_pixel(&self) -> usize {
        self.first + self.pixels.len()
    }

    pub fn push_pixel(&mut self, px: Arc<[i32]>) {
        debug_assert_eq!(px.len(), self.ich);
        self.held += px.len();
        self.pixels.push_back(px);
    }

    /// Channel vector of the pixel at absolute index `abs` (resident).
    pub fn pixel(&self, abs: usize) -> &[i32] {
        &self.pixels[abs - self.first]
    }

    /// Cheap shared handle on the pixel at absolute index `abs`, for
    /// worker job snapshots.
    pub fn pixel_arc(&self, abs: usize) -> &Arc<[i32]> {
        &self.pixels[abs - self.first]
    }

    /// Elements currently held (published to the stage's peak gauge).
    pub fn held(&self) -> usize {
        self.held
    }

    /// Occupancy of each configured FIFO slice (oldest-to-newest), the
    /// Figs. 7/9 chain view: buffered elements beyond the in-flight
    /// pixel fill the chain from the newest (input) end.  Should the
    /// window transiently hold more than the configured chain capacity,
    /// the excess is not attributed to any slice (the view saturates);
    /// the stage's total-occupancy gauge still accounts every element.
    pub fn slice_occupancy(&self) -> Vec<usize> {
        let mut remaining = self.held.saturating_sub(self.ich);
        let mut occ = vec![0usize; self.slice_sizes.len()];
        for (o, &cap) in occ.iter_mut().zip(&self.slice_sizes).rev() {
            let take = remaining.min(cap);
            *o = take;
            remaining -= take;
        }
        occ
    }

    /// Drop every resident pixel with absolute index `< abs`, returning
    /// them in stream order (for skip-path forwarding).
    pub fn evict_below(&mut self, abs: usize) -> Vec<Arc<[i32]>> {
        let mut out = Vec::new();
        while self.first < abs {
            match self.pixels.pop_front() {
                Some(p) => {
                    self.held -= p.len();
                    self.first += 1;
                    out.push(p);
                }
                None => break,
            }
        }
        out
    }

    /// End-of-frame: drain the remaining pixels in order, reset indices.
    pub fn flush(&mut self) -> Vec<Arc<[i32]>> {
        let out: Vec<_> = self.pixels.drain(..).collect();
        self.held = 0;
        self.first = 0;
        out
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::hls::window::slice_plan;

    fn row(v: i32, n: usize) -> Arc<[i32]> {
        Arc::from(vec![v; n])
    }

    #[test]
    fn sliding_window_evicts_in_order() {
        let mut lb = LineBuffer::new(4);
        for i in 0..3 {
            lb.push_row(row(i, 4));
        }
        assert_eq!(lb.next_row(), 3);
        assert_eq!(lb.held(), 12);
        assert_eq!(lb.row(1)[0], 1);
        let ev = lb.evict_below(2);
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0][0], 0);
        assert_eq!(ev[1][0], 1);
        assert_eq!(lb.row(2)[0], 2);
        assert_eq!(lb.held(), 4);
        let (first, rows) = lb.resident();
        assert_eq!(first, 2);
        assert_eq!(rows.len(), 1);
    }

    #[test]
    fn slice_window_tracks_span_and_evicts_in_stream_order() {
        // 3x3 window, 4-wide rows, 2 channels, ow_par = 1: the span is
        // (2*4 + 2) pixels buffered + 1 in flight.
        let plan = slice_plan(3, 3, 4, 2, 1).unwrap();
        let mut w = SliceWindow::new(2, &plan);
        for i in 0..11 {
            w.push_pixel(row(i, 2));
        }
        assert_eq!(w.next_pixel(), 11);
        assert_eq!(w.held(), 22);
        // Exactly the Eq. 16 span: B_i + the in-flight pixel.
        assert_eq!(w.held(), plan.total() + 2);
        assert_eq!(w.pixel(4)[0], 4);
        // The chain view accounts every buffered element beyond the
        // in-flight pixel, newest slices first.
        let occ = w.slice_occupancy();
        assert_eq!(occ.iter().sum::<usize>(), plan.total());
        assert_eq!(occ.len(), plan.slices());
        let ev = w.evict_below(3);
        assert_eq!(ev.len(), 3);
        assert_eq!(ev[0][0], 0);
        assert_eq!(ev[2][0], 2);
        assert_eq!(w.held(), 16);
        assert_eq!(w.pixel_arc(3)[0], 3);
        let rest = w.flush();
        assert_eq!(rest.len(), 8);
        assert_eq!(w.next_pixel(), 0);
        assert_eq!(w.held(), 0);
    }

    #[test]
    fn flush_resets_for_next_frame() {
        let mut lb = LineBuffer::new(2);
        lb.push_row(row(7, 2));
        let rest = lb.flush();
        assert_eq!(rest.len(), 1);
        assert_eq!(lb.next_row(), 0);
        assert_eq!(lb.held(), 0);
        lb.push_row(row(9, 2));
        assert_eq!(lb.row(0)[0], 9);
        assert_eq!(lb.held(), 2);
    }
}
