//! Software line buffer — the row-granular equivalent of the paper's
//! window buffer (Section III-F, Eqs. 16–17).
//!
//! The hardware window buffer is a chain of FIFO slices holding exactly
//! `B_i = [(fh-1)*iw + fw - 1] * ich` activations (see
//! [`hls::window`](crate::hls::window)).  The streaming executor works at
//! row granularity instead: it retains at most `fh` complete input rows
//! (`fh * iw * ich` elements — the same bound rounded up to whole rows),
//! evicting each row the moment no pending output row's window can still
//! reach it.  Eviction order is stream order, which is what lets the
//! temporal-reuse path (paper Fig. 12a) forward evicted rows as the skip
//! stream with no second buffer.
//!
//! Rows are reference-counted (`Arc<[i32]>`) so a conv stage can hand the
//! resident window to its `och_par` channel-parallel workers without
//! copying pixel data — the workers hold cheap clones while the stage
//! keeps evicting/forwarding at its own pace.  Occupancy reporting is
//! external: the owning stage publishes [`held`](LineBuffer::held) into
//! its pre-registered [`PeakGauge`](super::PeakGauge) after every push,
//! so the pool can read peaks while the pipeline runs.

use std::collections::VecDeque;
use std::sync::Arc;

/// Sliding window of input rows with absolute row indexing.
pub struct LineBuffer {
    rows: VecDeque<Arc<[i32]>>,
    /// Absolute index (within the current frame) of `rows[0]`.
    first: usize,
    row_elems: usize,
    held: usize,
}

impl LineBuffer {
    pub fn new(row_elems: usize) -> LineBuffer {
        LineBuffer { rows: VecDeque::new(), first: 0, row_elems, held: 0 }
    }

    /// Absolute index of the next row to be pushed (== rows consumed from
    /// the input stream this frame).
    pub fn next_row(&self) -> usize {
        self.first + self.rows.len()
    }

    pub fn push_row(&mut self, row: Arc<[i32]>) {
        debug_assert_eq!(row.len(), self.row_elems);
        self.held += row.len();
        self.rows.push_back(row);
    }

    /// Row at absolute index `abs` (must be resident).
    pub fn row(&self, abs: usize) -> &[i32] {
        &self.rows[abs - self.first]
    }

    /// Elements currently held (published to the stage's peak gauge).
    pub fn held(&self) -> usize {
        self.held
    }

    /// Snapshot of the resident rows for channel-parallel workers:
    /// `(absolute index of the first row, cheap Arc clones in order)`.
    pub fn resident(&self) -> (usize, Vec<Arc<[i32]>>) {
        (self.first, self.rows.iter().cloned().collect())
    }

    /// Drop every resident row with absolute index `< abs`, returning them
    /// in stream order (for skip-path forwarding).
    pub fn evict_below(&mut self, abs: usize) -> Vec<Arc<[i32]>> {
        let mut out = Vec::new();
        while self.first < abs {
            match self.rows.pop_front() {
                Some(r) => {
                    self.held -= r.len();
                    self.first += 1;
                    out.push(r);
                }
                None => break,
            }
        }
        out
    }

    /// End-of-frame: drain the remaining rows in order and reset indices.
    pub fn flush(&mut self) -> Vec<Arc<[i32]>> {
        let out: Vec<_> = self.rows.drain(..).collect();
        self.held = 0;
        self.first = 0;
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(v: i32, n: usize) -> Arc<[i32]> {
        Arc::from(vec![v; n])
    }

    #[test]
    fn sliding_window_evicts_in_order() {
        let mut lb = LineBuffer::new(4);
        for i in 0..3 {
            lb.push_row(row(i, 4));
        }
        assert_eq!(lb.next_row(), 3);
        assert_eq!(lb.held(), 12);
        assert_eq!(lb.row(1)[0], 1);
        let ev = lb.evict_below(2);
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0][0], 0);
        assert_eq!(ev[1][0], 1);
        assert_eq!(lb.row(2)[0], 2);
        assert_eq!(lb.held(), 4);
        let (first, rows) = lb.resident();
        assert_eq!(first, 2);
        assert_eq!(rows.len(), 1);
    }

    #[test]
    fn flush_resets_for_next_frame() {
        let mut lb = LineBuffer::new(2);
        lb.push_row(row(7, 2));
        let rest = lb.flush();
        assert_eq!(rest.len(), 1);
        assert_eq!(lb.next_row(), 0);
        assert_eq!(lb.held(), 0);
        lb.push_row(row(9, 2));
        assert_eq!(lb.row(0)[0], 9);
        assert_eq!(lb.held(), 2);
    }
}
