//! Software line buffer — the row-granular equivalent of the paper's
//! window buffer (Section III-F, Eqs. 16–17).
//!
//! The hardware window buffer is a chain of FIFO slices holding exactly
//! `B_i = [(fh-1)*iw + fw - 1] * ich` activations (see
//! [`hls::window`](crate::hls::window)).  The streaming executor works at
//! row granularity instead: it retains at most `fh` complete input rows
//! (`fh * iw * ich` elements — the same bound rounded up to whole rows),
//! evicting each row the moment no pending output row's window can still
//! reach it.  Eviction order is stream order, which is what lets the
//! temporal-reuse path (paper Fig. 12a) forward evicted rows as the skip
//! stream with no second buffer.

use super::fifo::BufferStat;
use crate::hls::streams::StreamKind;
use std::collections::VecDeque;

/// Sliding window of input rows with absolute row indexing.
pub struct LineBuffer {
    name: String,
    rows: VecDeque<Box<[i32]>>,
    /// Absolute index (within the current frame) of `rows[0]`.
    first: usize,
    row_elems: usize,
    /// Row-count bound implied by the caller's access pattern (reporting).
    rows_bound: usize,
    held: usize,
    peak: usize,
}

impl LineBuffer {
    pub fn new(name: String, row_elems: usize, rows_bound: usize) -> LineBuffer {
        LineBuffer {
            name,
            rows: VecDeque::new(),
            first: 0,
            row_elems,
            rows_bound,
            held: 0,
            peak: 0,
        }
    }

    /// Absolute index of the next row to be pushed (== rows consumed from
    /// the input stream this frame).
    pub fn next_row(&self) -> usize {
        self.first + self.rows.len()
    }

    pub fn push_row(&mut self, row: Box<[i32]>) {
        debug_assert_eq!(row.len(), self.row_elems);
        self.held += row.len();
        self.peak = self.peak.max(self.held);
        self.rows.push_back(row);
    }

    /// Row at absolute index `abs` (must be resident).
    pub fn row(&self, abs: usize) -> &[i32] {
        &self.rows[abs - self.first]
    }

    /// Drop every resident row with absolute index `< abs`, returning them
    /// in stream order (for skip-path forwarding).
    pub fn evict_below(&mut self, abs: usize) -> Vec<Box<[i32]>> {
        let mut out = Vec::new();
        while self.first < abs {
            match self.rows.pop_front() {
                Some(r) => {
                    self.held -= r.len();
                    self.first += 1;
                    out.push(r);
                }
                None => break,
            }
        }
        out
    }

    /// End-of-frame: drain the remaining rows in order and reset indices.
    pub fn flush(&mut self) -> Vec<Box<[i32]>> {
        let out: Vec<_> = self.rows.drain(..).collect();
        self.held = 0;
        self.first = 0;
        out
    }

    pub fn stat(&self) -> BufferStat {
        BufferStat {
            name: self.name.clone(),
            kind: StreamKind::WindowSlice,
            capacity: self.rows_bound * self.row_elems,
            peak: self.peak,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(v: i32, n: usize) -> Box<[i32]> {
        vec![v; n].into_boxed_slice()
    }

    #[test]
    fn sliding_window_evicts_in_order() {
        let mut lb = LineBuffer::new("t".into(), 4, 3);
        for i in 0..3 {
            lb.push_row(row(i, 4));
        }
        assert_eq!(lb.next_row(), 3);
        assert_eq!(lb.row(1)[0], 1);
        let ev = lb.evict_below(2);
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0][0], 0);
        assert_eq!(ev[1][0], 1);
        assert_eq!(lb.row(2)[0], 2);
        assert_eq!(lb.stat().peak, 12);
    }

    #[test]
    fn flush_resets_for_next_frame() {
        let mut lb = LineBuffer::new("t".into(), 2, 2);
        lb.push_row(row(7, 2));
        let rest = lb.flush();
        assert_eq!(rest.len(), 1);
        assert_eq!(lb.next_row(), 0);
        lb.push_row(row(9, 2));
        assert_eq!(lb.row(0)[0], 9);
        // Peak persists across frames (it is a whole-run statistic).
        assert_eq!(lb.stat().peak, 2);
    }
}
