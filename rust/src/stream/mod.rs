//! Streaming line-buffer execution backend (paper Sections III-E/F/G).
//!
//! Until this module existed, the paper's central buffering claim — skip
//! connections served from bounded FIFOs sized by Eq. 22 instead of
//! whole-tensor intermediates — lived only as *sizing math* in
//! [`hls::streams`] and [`hls::window`].  This subsystem actually runs
//! that dataflow in software:
//!
//! * [`executor::run_streaming`] spawns one scoped thread per layer stage
//!   of the optimized graph, connected by bounded [`Fifo`]s whose depths
//!   come from `hls::streams` (DMA, output-burst and `skip_stream(B_sc)`
//!   kinds) and whose sliding windows are [`LineBuffer`]s mirroring
//!   `hls::window`'s geometry;
//! * the skip path flows through the Eq. 22-sized FIFO directly into the
//!   fused conv1 accumulator init (paper Fig. 13) — identity skips as
//!   forwarded line-buffer rows (temporal reuse, Fig. 12a), downsample
//!   skips computed inside the host conv task (loop merge, Fig. 12b);
//! * numerics are bit-identical to [`sim::golden`](crate::sim::golden)
//!   (same `quant::requantize` contract in the same evaluation order);
//! * all blocking is bounded: an undersized FIFO produces a
//!   [`StreamError::Stalled`] *error*, never a hang — the executor
//!   analogue of the simulator's deadlock report (Fig. 14);
//! * every run reports per-buffer peak occupancy ([`StreamStats`]) so
//!   tests can assert the measured buffering stays below the
//!   whole-tensor-intermediates total and within the Eq. 22 depths.
//!
//! Serving-side integration lives in
//! [`runtime::backend`](crate::runtime::backend) as `StreamBackend` /
//! `StreamFactory` (the fourth backend next to pjrt/golden/sim).
//!
//! [`hls::streams`]: crate::hls::streams
//! [`hls::window`]: crate::hls::window

mod executor;
mod fifo;
mod line_buffer;

pub use executor::run_streaming;
pub use fifo::{BufferStat, Fifo, StreamError};
pub use line_buffer::LineBuffer;

use std::time::Duration;

use crate::hls::streams::StreamKind;

/// Executor policy knobs.
#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// Bounded wait before a blocked FIFO push/pop reports
    /// [`StreamError::Stalled`] instead of hanging.
    pub progress_timeout: Duration,
    /// Test hook: force every skip FIFO to this capacity (in elements),
    /// overriding the Eq. 22 depth from `hls::streams::skip_stream` —
    /// used by the deadlock-regression tests to prove that undersized
    /// depths fail with an error rather than a hang.
    pub skip_capacity_override: Option<usize>,
}

impl Default for StreamConfig {
    fn default() -> Self {
        // Generous: the longest legitimate wait is the sink's first pop,
        // which spans the whole pipeline fill (a full-frame compute in
        // debug builds on slow CI hosts).  Stall detection stays bounded.
        StreamConfig { progress_timeout: Duration::from_secs(60), skip_capacity_override: None }
    }
}

/// Per-run buffering report: every FIFO and line buffer with its capacity
/// bound and peak occupancy, in activation elements (the unit of
/// `hls::streams` depths; most streams carry int8 activations, the final
/// logits stream carries int32).
#[derive(Debug, Clone)]
pub struct StreamStats {
    pub buffers: Vec<BufferStat>,
    pub frames: usize,
    /// What a non-streaming executor materializes per frame: the summed
    /// size of every intermediate edge tensor in the graph.
    pub whole_tensor_elems: usize,
}

impl StreamStats {
    /// Summed peak occupancy across all buffers — an upper bound on the
    /// executor's concurrent intermediate storage.
    pub fn peak_buffered_elems(&self) -> usize {
        self.buffers.iter().map(|b| b.peak).sum()
    }

    /// Buffers of one stream kind (e.g. [`StreamKind::Skip`]).
    pub fn of_kind(&self, kind: StreamKind) -> impl Iterator<Item = &BufferStat> {
        self.buffers.iter().filter(move |b| b.kind == kind)
    }

    /// Look up a buffer by name (e.g. `"s0b0c1.skip"`).
    pub fn buffer(&self, name: &str) -> Option<&BufferStat> {
        self.buffers.iter().find(|b| b.name == name)
    }

    /// Fraction of the whole-tensor intermediates the pipeline actually
    /// buffered (lower is better; Eq. 22's point is that this is small).
    pub fn buffered_fraction(&self) -> f64 {
        if self.whole_tensor_elems == 0 {
            return 0.0;
        }
        self.peak_buffered_elems() as f64 / self.whole_tensor_elems as f64
    }
}
