//! Streaming line-buffer execution backend (paper Sections III-E/F/G)
//! with a persistent, replicated serving pool.
//!
//! Until this module existed, the paper's central buffering claim — skip
//! connections served from bounded FIFOs sized by Eq. 22 instead of
//! whole-tensor intermediates — lived only as *sizing math* in
//! [`hls::streams`] and [`hls::window`].  This subsystem actually runs
//! that dataflow in software, at the paper's parallelism model:
//!
//! * [`StreamPool`] is the serving engine: stage threads are
//!   spawned **once per pipeline replica** and stay alive across frames,
//!   fed through a shared work queue — frame N+1 enters conv0 while
//!   frame N is still in the classifier (frame-level pipelining,
//!   Section III-B), `replicas` pipeline copies trade buffering for
//!   throughput, and each conv stage splits its output channels across
//!   up to `och_par` worker threads from the layer's ILP allocation
//!   ([`ilp::solver::LayerAlloc`](crate::ilp::LayerAlloc)) — plus, in
//!   the default slice-granular mode, up to `ow_par` *column* workers
//!   per window group (the execution counterpart of the ILP's DSP
//!   packing, `hls::packing::macs_per_cycle`), with conv window storage
//!   held to exactly the Eq. 16/17 span ([`WindowStorage::Slices`]);
//! * the replica count can be **elastic** ([`StreamConfig::elastic`]):
//!   a controller thread samples the work-queue depth (plus the
//!   router's queue-depth hint) and the in-flight frame count, and
//!   grows or drains whole replicas between `min_replicas..=max_replicas`,
//!   stamping new replicas from the pool's single pipeline blueprint
//!   (planned once, instantiated per replica) and draining via the
//!   end-of-stream sentinel — never mid-frame (see [`ElasticConfig`]);
//! * FIFO depths and `ow_par` come from the board/ILP configuration
//!   ([`planned_config`] → `hls::config::configure`) — the
//!   executor validates exactly the depths codegen emits: conv output
//!   bursts at `och_groups x och_par x ow_par`, fused skips at Eq. 22
//!   (`skip_stream(B_sc)`), naive skips at Eq. 21;
//! * identity skips flow as forwarded line-buffer rows (temporal reuse,
//!   Fig. 12a), downsample skips are computed inside the host conv task
//!   (loop merge, Fig. 12b), both into the fused conv1 accumulator init
//!   (Fig. 13); numerics are bit-identical to
//!   [`sim::golden`](crate::sim::golden) — including across replicas and
//!   channel-split workers;
//! * [`StreamConfig::naive_add`] runs the *unoptimized* dataflow instead:
//!   tee'd producers, raw int32 accumulator streams, explicit Add stage
//!   tasks behind Eq. 21-sized skip FIFOs — undersize them and the
//!   executor reproduces the paper's Fig. 14 deadlock as a typed
//!   [`StreamError::Stalled`] error, not only in the discrete-event
//!   simulator;
//! * all blocking is bounded (stall errors, never hangs) and shutdown is
//!   drain-and-join: the pool flows a zero-length end-of-stream sentinel
//!   through every replica, finishes frames mid-pipeline, answers every
//!   accepted frame, and leaks no threads;
//! * every pool reports cumulative per-buffer peak occupancy
//!   ([`StreamStats`], live via [`StreamPool::stats`]) so tests
//!   and serving metrics can assert the measured buffering stays below
//!   whole-tensor intermediates and within the configured depths.
//!
//! [`executor::run_streaming`] remains the one-shot wrapper (build, run
//! one batch, drain) for tools and property tests.  Serving-side
//! integration lives in [`runtime::backend`](crate::runtime::backend) as
//! `StreamBackend` / `StreamFactory`: the backend holds a pool for its
//! lifetime, `infer_batch` enqueues frames and awaits results in order,
//! and the router exports the pool's buffering stats as gauges.
//!
//! [`hls::streams`]: crate::hls::streams
//! [`hls::window`]: crate::hls::window

// Panic-freedom gate: the serving hot path reports typed `StreamError`s
// (poisoning the pool) instead of unwinding worker threads.  `clippy.toml`
// disallows Option/Result unwrap+expect; test modules opt out locally.
#![deny(clippy::disallowed_methods)]

mod budget;
mod elastic;
mod executor;
mod fifo;
mod line_buffer;
mod pool;
mod stage;

pub use budget::{BudgetError, BudgetHandle, WorkerBudget, WorkerLease};
pub use elastic::{ElasticConfig, ElasticPolicy, ScaleAction};
pub use executor::run_streaming;
pub use fifo::{BufferStat, Fifo, PeakGauge, StreamError};
pub use line_buffer::{LineBuffer, SliceWindow};
pub use pool::{planned_config, FrameTicket, StreamPool};

use std::time::Duration;

use crate::hls::streams::StreamKind;
use crate::hls::{Board, KV260};

/// How a conv stage stores its sliding input window.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WindowStorage {
    /// Row-granular (the pre-slice legacy mode): retain up to `fh` whole
    /// input rows (`fh * iw * ich` elements — Eq. 16 rounded up to rows)
    /// and emit a whole output row per step.
    Rows,
    /// Slice-granular (paper Figs. 7/9, the default): consume the
    /// depth-first pixel stream one `ow_par`-wide window group at a time,
    /// holding exactly the Eq. 16/17 span (`hls::window::slice_plan`
    /// total) plus the in-flight pixel, and evicting in stream order
    /// behind the last window that can still reach each pixel.
    #[default]
    Slices,
}

/// Executor/pool policy knobs.
#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// Bounded wait before a blocked FIFO push/pop reports
    /// [`StreamError::Stalled`] instead of hanging.
    pub progress_timeout: Duration,
    /// Test hook: force every skip FIFO to this capacity (in elements),
    /// overriding the Eq. 22 depth from `hls::streams::skip_stream` (or
    /// the Eq. 21 naive depth) — used by the deadlock-regression tests to
    /// prove that undersized depths fail with an error rather than a hang.
    pub skip_capacity_override: Option<usize>,
    /// Pipeline replicas behind the pool's shared work queue.
    pub replicas: usize,
    /// Run the *naive* dataflow: tee'd producers, raw int32 accumulator
    /// streams and explicit Add stage tasks behind Eq. 21-sized FIFOs
    /// (paper Fig. 10/14) instead of rejecting unoptimized graphs.
    pub naive_add: bool,
    /// Cap on channel-parallel worker threads per conv stage; the actual
    /// count is `min(cap, layer's ILP och_par, och)`.  1 = inline.
    pub och_worker_cap: usize,
    /// Board whose DSP budget drives the ILP allocation that sizes FIFO
    /// depths and per-layer `och_par`.
    pub board: &'static Board,
    /// Output-width unroll (2 = the paper's DSP-packing default, matching
    /// codegen).  Drives stream/window sizing *and*, in slice-granular
    /// mode, the executor's window-group width and column-worker fan-out
    /// (stride-1 convs only; strided convs fall back to single-column
    /// groups, whose Eq. 16 span the configured capacity covers).
    pub ow_par: usize,
    /// Window-buffer storage mode for conv stages (see [`WindowStorage`];
    /// defaults to the slice-granular Eq. 16/17 layout).
    pub window_storage: WindowStorage,
    /// Cap on column-parallel worker threads per conv stage in
    /// slice-granular mode; the actual count is `min(cap, ow_par, ow)`
    /// and multiplies the channel-worker count.  1 = no column split.
    pub ow_worker_cap: usize,
    /// Elastic replica scaling: `Some` grows/drains whole pipeline
    /// replicas between `min_replicas..=max_replicas` under the
    /// work-queue depth signal (plus the router's queue-depth hint),
    /// ignoring the fixed `replicas` knob; `None` keeps the pool at
    /// exactly `replicas`.  See [`ElasticConfig`].
    pub elastic: Option<ElasticConfig>,
    /// Run the static analyzer ([`crate::analysis::preflight`]) inside
    /// `plan_pipeline`, refusing provably-deadlocking configurations with
    /// a typed [`crate::analysis::AnalysisError`] before any stage thread
    /// spawns (default).  The deadlock-regression tests set this to
    /// `false` to reach the runtime `Stalled` watchdog on purpose.
    pub static_checks: bool,
    /// Process-wide worker budget for multi-tenant serving: when set,
    /// the pool registers a `min_replicas x stages` reservation at
    /// construction (failing with a typed [`BudgetError`] if the cap
    /// cannot cover every pool's floor) and every replica beyond it is
    /// leased — grown only when the shared budget grants the bid,
    /// released on retire/drain/failed spawn.  `None` keeps the
    /// pre-budget behavior: the pool owns its band outright.
    pub budget: Option<std::sync::Arc<WorkerBudget>>,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            // Generous: the longest legitimate wait is the sink's first
            // pop, which spans the whole pipeline fill (a full-frame
            // compute in debug builds on slow CI hosts).  Stall detection
            // stays bounded.
            progress_timeout: Duration::from_secs(60),
            skip_capacity_override: None,
            replicas: 1,
            naive_add: false,
            och_worker_cap: 4,
            board: &KV260,
            ow_par: 2,
            window_storage: WindowStorage::default(),
            ow_worker_cap: 4,
            elastic: None,
            static_checks: true,
            budget: None,
        }
    }
}

/// Per-pool buffering report: every FIFO and line buffer with its
/// capacity bound and peak occupancy, in activation elements (the unit of
/// `hls::streams` depths; most streams carry int8 activations, the final
/// logits stream carries int32).  For a multi-replica pool, replica
/// `i > 0` buffer names carry an `r{i}/` prefix (replicas the elastic
/// controller drained keep reporting their final stats) and
/// `whole_tensor_elems` is scaled by the pool's *peak* replica count
/// (the concurrent whole-tensor storage a non-streaming executor would
/// need at that concurrency).
#[derive(Debug, Clone)]
pub struct StreamStats {
    pub buffers: Vec<BufferStat>,
    pub frames: usize,
    /// What a non-streaming executor materializes per frame (times the
    /// pool's replica count): the summed size of every intermediate edge
    /// tensor in the graph.
    pub whole_tensor_elems: usize,
}

impl StreamStats {
    /// Summed peak occupancy across all buffers — an upper bound on the
    /// executor's concurrent intermediate storage.
    pub fn peak_buffered_elems(&self) -> usize {
        self.buffers.iter().map(|b| b.peak).sum()
    }

    /// Buffers of one stream kind (e.g. [`StreamKind::Skip`]).
    pub fn of_kind(&self, kind: StreamKind) -> impl Iterator<Item = &BufferStat> {
        self.buffers.iter().filter(move |b| b.kind == kind)
    }

    /// Look up a buffer by name (e.g. `"s0b0c1.skip"`; replica `i > 0`
    /// buffers are `"r{i}/s0b0c1.skip"`).
    pub fn buffer(&self, name: &str) -> Option<&BufferStat> {
        self.buffers.iter().find(|b| b.name == name)
    }

    /// Fraction of the whole-tensor intermediates the pipeline actually
    /// buffered (lower is better; Eq. 22's point is that this is small).
    pub fn buffered_fraction(&self) -> f64 {
        if self.whole_tensor_elems == 0 {
            return 0.0;
        }
        self.peak_buffered_elems() as f64 / self.whole_tensor_elems as f64
    }
}
