//! The pipelined streaming executor: one task per layer stage, bounded
//! FIFOs between them, line-buffered sliding windows inside them.
//!
//! This runs the *optimized* graph (paper Fig. 14) the way the generated
//! accelerator does: every conv is a free-running task consuming a
//! depth-first pixel stream through a line buffer (Section III-F), the
//! residual skip path flows through a `skip_stream(B_sc)` FIFO sized by
//! Eq. 22 straight into the consumer's accumulator initialization
//! (Fig. 13), and the whole chain executes concurrently on scoped
//! threads — cross-layer pipeline parallelism with bounded intermediate
//! storage instead of whole-tensor materialization.
//!
//! Numerics are exactly [`sim::golden`](crate::sim::golden)'s: the same
//! `requantize`/`align_skip` contract applied in the same per-element
//! order, so outputs are bit-identical (asserted by integration and
//! property tests).  What changes is *where tensors live*: the executor
//! reports per-buffer peak occupancy so the Eq. 22 buffering saving can
//! be measured, not just sized.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::graph::{infer_shapes, Edge, Graph, InputRole, Op};
use crate::hls::streams::{dma_stream, output_stream, skip_stream, StreamKind};
use crate::hls::window::buffer_size;
use crate::models::ModelWeights;
use crate::quant::{clip_i8, requantize, round_shift, QTensor, Shape4};

use super::fifo::{BufferStat, Fifo, StreamError};
use super::line_buffer::LineBuffer;
use super::{StreamConfig, StreamStats};

// ------------------------------------------------------------ stage plan

struct SkipIn {
    fifo: Arc<Fifo>,
    /// `skip_exp - acc_exp` (>= 0 by the builders' exponent contract).
    shift: u32,
}

struct DsStage<'w> {
    k: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
    och: usize,
    out_exp: i32,
    acc_exp: i32,
    w: &'w [i32],
    bias: &'w [i32],
    out: Arc<Fifo>,
}

struct ConvStage<'w> {
    name: String,
    k: usize,
    stride: usize,
    pad: usize,
    relu: bool,
    out_exp: i32,
    acc_exp: i32,
    ih: usize,
    iw: usize,
    ich: usize,
    oh: usize,
    ow: usize,
    och: usize,
    w: &'w [i32],
    bias: &'w [i32],
    input: Arc<Fifo>,
    out: Arc<Fifo>,
    skip: Option<SkipIn>,
    /// Temporal reuse (Fig. 12a): evicted line-buffer rows are re-emitted
    /// on port 1 as the skip stream.
    forward: Option<Arc<Fifo>>,
    /// Loop merge (Fig. 12b): the pointwise downsample computed inside
    /// this task, emitting on port 1.
    ds: Option<DsStage<'w>>,
}

struct PoolStage {
    name: String,
    k: usize,
    stride: usize,
    ih: usize,
    iw: usize,
    c: usize,
    oh: usize,
    ow: usize,
    input: Arc<Fifo>,
    out: Arc<Fifo>,
}

struct GapStage {
    h: usize,
    w: usize,
    c: usize,
    in_exp: i32,
    out_exp: i32,
    input: Arc<Fifo>,
    out: Arc<Fifo>,
}

struct LinearStage<'w> {
    cout: usize,
    /// Pixel tokens per frame on the input stream.
    tokens: usize,
    cin: usize,
    w: &'w [i32],
    bias: &'w [i32],
    input: Arc<Fifo>,
    out: Arc<Fifo>,
}

struct ReluStage {
    tokens: usize,
    input: Arc<Fifo>,
    out: Arc<Fifo>,
}

enum Stage<'w> {
    Conv(ConvStage<'w>),
    Pool(PoolStage),
    Gap(GapStage),
    Linear(LinearStage<'w>),
    Relu(ReluStage),
}

// --------------------------------------------------------------- helpers

/// Run `f`, raising the shared abort flag on error *or panic* so every
/// peer blocked on a FIFO unwinds within one poll interval.
fn guarded<T>(
    abort: &AtomicBool,
    f: impl FnOnce() -> Result<T, StreamError>,
) -> Result<T, StreamError> {
    struct Guard<'a>(&'a AtomicBool, bool);
    impl Drop for Guard<'_> {
        fn drop(&mut self) {
            if self.1 {
                self.0.store(true, Ordering::SeqCst);
            }
        }
    }
    let mut g = Guard(abort, true);
    let r = f();
    if r.is_ok() {
        g.1 = false;
    }
    r
}

fn pull_row(input: &Fifo, iw: usize, ich: usize) -> Result<Box<[i32]>, StreamError> {
    let mut row = vec![0i32; iw * ich].into_boxed_slice();
    for x in 0..iw {
        let t = input.pop()?;
        row[x * ich..(x + 1) * ich].copy_from_slice(&t);
    }
    Ok(row)
}

fn forward_rows(fwd: &Fifo, rows: &[Box<[i32]>], ich: usize) -> Result<(), StreamError> {
    for row in rows {
        for px in row.chunks_exact(ich) {
            fwd.push(Box::from(px))?;
        }
    }
    Ok(())
}

// ---------------------------------------------------------- stage bodies

fn run_source(input: &QTensor, out: &Fifo) -> Result<(), StreamError> {
    let (n, h, w, c) = (input.shape.n, input.shape.h, input.shape.w, input.shape.c);
    for f in 0..n {
        for y in 0..h {
            for x in 0..w {
                let base = ((f * h + y) * w + x) * c;
                out.push(Box::from(&input.data[base..base + c]))?;
            }
        }
    }
    Ok(())
}

/// Emit one merged-downsample output row from the resident input rows.
fn emit_ds_row(
    ds: &DsStage<'_>,
    lb: &LineBuffer,
    dy: usize,
    ih: usize,
    iw: usize,
    ich: usize,
) -> Result<(), StreamError> {
    let mut acc = vec![0i32; ds.och];
    for ox in 0..ds.ow {
        acc.copy_from_slice(ds.bias);
        for ky in 0..ds.k {
            let iy = dy * ds.stride + ky;
            if iy < ds.pad || iy - ds.pad >= ih {
                continue;
            }
            let row = lb.row(iy - ds.pad);
            for kx in 0..ds.k {
                let ix = ox * ds.stride + kx;
                if ix < ds.pad || ix - ds.pad >= iw {
                    continue;
                }
                let base = (ix - ds.pad) * ich;
                let wtap = (ky * ds.k + kx) * ich * ds.och;
                for ci in 0..ich {
                    let xv = row[base + ci];
                    if xv == 0 {
                        continue;
                    }
                    let ws = &ds.w[wtap + ci * ds.och..wtap + (ci + 1) * ds.och];
                    for (a, &wv) in acc.iter_mut().zip(ws) {
                        *a += xv * wv;
                    }
                }
            }
        }
        let tok: Box<[i32]> =
            acc.iter().map(|&v| requantize(v, ds.acc_exp, ds.out_exp, false)).collect();
        ds.out.push(tok)?;
    }
    Ok(())
}

/// Emit every downsample row whose input rows are already resident.
fn emit_ready_ds_rows(
    ds_next: &mut usize,
    ds: &DsStage<'_>,
    lb: &LineBuffer,
    ih: usize,
    iw: usize,
    ich: usize,
) -> Result<(), StreamError> {
    while *ds_next < ds.oh {
        let last = (*ds_next * ds.stride + ds.k).saturating_sub(1 + ds.pad).min(ih - 1);
        if lb.next_row() <= last {
            break;
        }
        emit_ds_row(ds, lb, *ds_next, ih, iw, ich)?;
        *ds_next += 1;
    }
    Ok(())
}

fn run_conv(p: ConvStage<'_>, frames: usize) -> Result<Vec<BufferStat>, StreamError> {
    let (k, s, pad) = (p.k, p.stride, p.pad);
    let rows_bound = if p.ds.is_some() { k + 1 } else { k };
    let mut lb = LineBuffer::new(format!("{}.window", p.name), p.iw * p.ich, rows_bound);
    let mut acc = vec![0i32; p.och];
    for _f in 0..frames {
        let mut ds_next = 0usize;
        for oy in 0..p.oh {
            // Pull rows until the window for output row `oy` is resident.
            let last = (oy * s + k).saturating_sub(1 + pad).min(p.ih - 1);
            while lb.next_row() <= last {
                lb.push_row(pull_row(&p.input, p.iw, p.ich)?);
            }
            for ox in 0..p.ow {
                // Accumulator init: bias (Fig. 4), then the aligned skip
                // stream (Fig. 13) — same order as golden's conv2d.
                acc.copy_from_slice(p.bias);
                if let Some(sk) = &p.skip {
                    let t = sk.fifo.pop()?;
                    for (a, &v) in acc.iter_mut().zip(t.iter()) {
                        *a += v << sk.shift;
                    }
                }
                for ky in 0..k {
                    let iy = oy * s + ky;
                    if iy < pad || iy - pad >= p.ih {
                        continue;
                    }
                    let row = lb.row(iy - pad);
                    for kx in 0..k {
                        let ix = ox * s + kx;
                        if ix < pad || ix - pad >= p.iw {
                            continue;
                        }
                        let base = (ix - pad) * p.ich;
                        let wtap = (ky * k + kx) * p.ich * p.och;
                        for ci in 0..p.ich {
                            let xv = row[base + ci];
                            if xv == 0 {
                                continue;
                            }
                            let ws = &p.w[wtap + ci * p.och..wtap + (ci + 1) * p.och];
                            for (a, &wv) in acc.iter_mut().zip(ws) {
                                *a += xv * wv;
                            }
                        }
                    }
                }
                let tok: Box<[i32]> =
                    acc.iter().map(|&v| requantize(v, p.acc_exp, p.out_exp, p.relu)).collect();
                p.out.push(tok)?;
            }
            if let Some(ds) = &p.ds {
                emit_ready_ds_rows(&mut ds_next, ds, &lb, p.ih, p.iw, p.ich)?;
            }
            // Evict rows that neither the host's next output row nor the
            // pending downsample rows can still reach; forwarded rows are
            // the temporal-reuse skip stream.
            let next_host = if oy + 1 < p.oh { ((oy + 1) * s).saturating_sub(pad) } else { p.ih };
            let next_ds = match &p.ds {
                Some(ds) if ds_next < ds.oh => (ds_next * ds.stride).saturating_sub(ds.pad),
                _ => p.ih,
            };
            let evicted = lb.evict_below(next_host.min(next_ds));
            if let Some(fwd) = &p.forward {
                forward_rows(fwd, &evicted, p.ich)?;
            }
        }
        // Frame drain: finish the downsample program, consume any input
        // rows the host windows never reached, and flush the line buffer
        // (the skip consumer expects the complete forwarded tensor).
        if let Some(ds) = &p.ds {
            while ds_next < ds.oh {
                let last = (ds_next * ds.stride + ds.k).saturating_sub(1 + ds.pad).min(p.ih - 1);
                while lb.next_row() <= last {
                    lb.push_row(pull_row(&p.input, p.iw, p.ich)?);
                }
                emit_ds_row(ds, &lb, ds_next, p.ih, p.iw, p.ich)?;
                ds_next += 1;
            }
        }
        while lb.next_row() < p.ih {
            lb.push_row(pull_row(&p.input, p.iw, p.ich)?);
        }
        let rest = lb.flush();
        if let Some(fwd) = &p.forward {
            forward_rows(fwd, &rest, p.ich)?;
        }
    }
    Ok(vec![lb.stat()])
}

fn run_pool(p: PoolStage, frames: usize) -> Result<Vec<BufferStat>, StreamError> {
    let mut lb = LineBuffer::new(format!("{}.window", p.name), p.iw * p.c, p.k);
    for _f in 0..frames {
        for oy in 0..p.oh {
            let last = (oy * p.stride + p.k - 1).min(p.ih - 1);
            while lb.next_row() <= last {
                lb.push_row(pull_row(&p.input, p.iw, p.c)?);
            }
            for ox in 0..p.ow {
                let mut best = vec![i32::MIN; p.c];
                for ky in 0..p.k {
                    let row = lb.row(oy * p.stride + ky);
                    for kx in 0..p.k {
                        let base = (ox * p.stride + kx) * p.c;
                        for (ch, b) in best.iter_mut().enumerate() {
                            *b = (*b).max(row[base + ch]);
                        }
                    }
                }
                p.out.push(best.into_boxed_slice())?;
            }
            let next = if oy + 1 < p.oh { (oy + 1) * p.stride } else { p.ih };
            lb.evict_below(next);
        }
        while lb.next_row() < p.ih {
            lb.push_row(pull_row(&p.input, p.iw, p.c)?);
        }
        lb.flush();
    }
    Ok(vec![lb.stat()])
}

fn run_gap(p: GapStage, frames: usize) -> Result<Vec<BufferStat>, StreamError> {
    let hw = p.h * p.w;
    // Power-of-two validated at plan time.
    let shift = p.out_exp - p.in_exp + hw.trailing_zeros() as i32;
    for _f in 0..frames {
        let mut acc = vec![0i32; p.c];
        for _ in 0..hw {
            let t = p.input.pop()?;
            for (a, &v) in acc.iter_mut().zip(t.iter()) {
                *a += v;
            }
        }
        let tok: Box<[i32]> = acc.iter().map(|&v| clip_i8(round_shift(v, shift))).collect();
        p.out.push(tok)?;
    }
    Ok(Vec::new())
}

fn run_linear(p: LinearStage<'_>, frames: usize) -> Result<Vec<BufferStat>, StreamError> {
    for _f in 0..frames {
        let mut xbuf = Vec::with_capacity(p.cin);
        for _ in 0..p.tokens {
            let t = p.input.pop()?;
            xbuf.extend_from_slice(&t);
        }
        let mut out = vec![0i32; p.cout];
        for (co, o) in out.iter_mut().enumerate() {
            let mut a = p.bias[co];
            for (ci, &xv) in xbuf.iter().enumerate() {
                a += xv * p.w[ci * p.cout + co];
            }
            *o = a;
        }
        p.out.push(out.into_boxed_slice())?;
    }
    Ok(Vec::new())
}

fn run_relu(p: ReluStage, frames: usize) -> Result<Vec<BufferStat>, StreamError> {
    for _f in 0..frames {
        for _ in 0..p.tokens {
            let t = p.input.pop()?;
            let tok: Box<[i32]> = t.iter().map(|&v| v.max(0)).collect();
            p.out.push(tok)?;
        }
    }
    Ok(Vec::new())
}

fn run_stage(stage: Stage<'_>, frames: usize) -> Result<Vec<BufferStat>, StreamError> {
    match stage {
        Stage::Conv(p) => run_conv(p, frames),
        Stage::Pool(p) => run_pool(p, frames),
        Stage::Gap(p) => run_gap(p, frames),
        Stage::Linear(p) => run_linear(p, frames),
        Stage::Relu(p) => run_relu(p, frames),
    }
}

// ------------------------------------------------------------- execution

/// Run `input` through the streaming pipeline for graph `g`.
///
/// Bit-identical to [`golden::run`](crate::sim::golden::run) on the same
/// graph/weights/input, but executed as a concurrent task pipeline with
/// bounded FIFOs; returns the logits plus the per-buffer occupancy stats.
///
/// Requires the *optimized* graph form: explicit `Add`/`BatchNorm` nodes
/// and raw-accumulator streams are rejected with an error (the naive
/// dataflow is the golden model's and the simulator's job).
pub fn run_streaming(
    g: &Graph,
    weights: &ModelWeights,
    input: &QTensor,
    cfg: &StreamConfig,
) -> Result<(QTensor, StreamStats)> {
    let shapes = infer_shapes(g).map_err(|e| anyhow!("{e}"))?;
    let frames = input.shape.n;
    anyhow::ensure!(frames >= 1, "empty input batch");

    let abort = Arc::new(AtomicBool::new(false));
    let timeout = cfg.progress_timeout;
    let mut fifos: Vec<Arc<Fifo>> = Vec::new();
    let mut fifo_of: BTreeMap<Edge, Arc<Fifo>> = BTreeMap::new();

    // Pass 1: one FIFO per consumed edge, sized by hls::streams according
    // to its role (paper Section III-E).
    for n in g.live() {
        for (e, role) in &n.inputs {
            anyhow::ensure!(
                g.consumers(*e).len() == 1,
                "stream backend needs single-consumer edges; output of {} has several",
                g.node(e.node).name
            );
            let es = shapes
                .get(e)
                .copied()
                .ok_or_else(|| anyhow!("{}: unshaped input edge", n.name))?;
            let (name, kind, cap) = match role {
                InputRole::SkipInit => {
                    let a = match &n.op {
                        Op::Conv(a) => a,
                        _ => bail!("{}: skip input on a non-conv node", n.name),
                    };
                    // Eq. 22: the optimized B_sc is the consumer's own
                    // window-buffer size.
                    let data_shape = shapes[&n.inputs[0].0];
                    let spec = skip_stream(buffer_size(a.k, a.k, data_shape.w, a.cin, 1));
                    let cap = cfg.skip_capacity_override.unwrap_or_else(|| spec.capacity());
                    (format!("{}.skip", n.name), StreamKind::Skip, cap)
                }
                InputRole::Data => {
                    if matches!(g.node(e.node).op, Op::Input { .. }) {
                        let spec = dma_stream(es.w * es.c);
                        (format!("{}.in", n.name), StreamKind::Dma, spec.capacity())
                    } else {
                        // One full och burst per window position.
                        let spec = output_stream(es.c, es.c, 1);
                        (format!("{}.in", n.name), StreamKind::Output, spec.capacity())
                    }
                }
            };
            let f = Fifo::new(name, kind, cap, abort.clone(), timeout);
            fifos.push(f.clone());
            fifo_of.insert(*e, f);
        }
    }

    // The network output: the unique sink node must be the classifier.
    let out_node = g
        .output()
        .ok_or_else(|| anyhow!("graph has no unique output node"))?;
    anyhow::ensure!(
        matches!(g.node(out_node).op, Op::Linear { .. }),
        "graph has no linear output node"
    );
    let out_shape = shapes[&Edge::new(out_node, 0)];
    let classes = out_shape.c;
    let sink_fifo = Fifo::new(
        format!("{}.out", g.node(out_node).name),
        StreamKind::Dma,
        dma_stream(classes).capacity(),
        abort.clone(),
        timeout,
    );
    fifos.push(sink_fifo.clone());

    let out_fifo_for = |id: usize| -> Result<Arc<Fifo>> {
        if id == out_node {
            Ok(sink_fifo.clone())
        } else {
            fifo_of
                .get(&Edge::new(id, 0))
                .cloned()
                .ok_or_else(|| anyhow!("output of {} has no consumer", g.node(id).name))
        }
    };

    // Pass 2: build the stage plan.
    let mut stages: Vec<Stage<'_>> = Vec::new();
    let mut source_fifo: Option<Arc<Fifo>> = None;
    for n in g.live() {
        match &n.op {
            Op::Input { h, w, c, exp } => {
                if (input.shape.h, input.shape.w, input.shape.c) != (*h, *w, *c) {
                    bail!("input shape {} vs expected ({h},{w},{c})", input.shape);
                }
                if input.exp != *exp {
                    bail!("input exp {} vs expected {exp}", input.exp);
                }
                anyhow::ensure!(source_fifo.is_none(), "stream backend supports one input node");
                source_fifo = Some(out_fifo_for(n.id)?);
            }
            Op::Conv(a) => {
                anyhow::ensure!(
                    !a.raw_output,
                    "stream backend runs optimized graphs only ({}: raw int32 accumulator \
                     streams feed explicit Add nodes)",
                    n.name
                );
                let in_shape = shapes[&n.inputs[0].0];
                let os = shapes[&Edge::new(n.id, 0)];
                let lw = weights.layer(&n.name)?;
                anyhow::ensure!(
                    lw.w.data.len() == a.k * a.k * a.cin * a.cout && lw.b.data.len() == a.cout,
                    "{}: weight/bias sizes do not match conv geometry",
                    n.name
                );
                let skip = n
                    .inputs
                    .iter()
                    .find(|(_, r)| *r == InputRole::SkipInit)
                    .map(|(e, _)| -> Result<SkipIn> {
                        let se = shapes[e];
                        anyhow::ensure!(
                            (se.h, se.w, se.c) == (os.h, os.w, os.c),
                            "{}: skip stream shape mismatch",
                            n.name
                        );
                        let shift = se.exp - lw.acc_exp();
                        anyhow::ensure!(shift >= 0, "{}: skip exp below acc exp", n.name);
                        Ok(SkipIn { fifo: fifo_of[e].clone(), shift: shift as u32 })
                    })
                    .transpose()?;
                let aux = fifo_of.get(&Edge::new(n.id, 1)).cloned();
                let (forward, ds) = if a.forwards_input {
                    (aux, None)
                } else if let Some(m) = &a.merged_downsample {
                    match aux {
                        Some(out) => {
                            let dss = shapes[&Edge::new(n.id, 1)];
                            let dsw = weights.layer(&m.name)?;
                            anyhow::ensure!(
                                dsw.w.data.len() == m.k * m.k * a.cin * m.cout
                                    && dsw.b.data.len() == m.cout,
                                "{}: merged downsample weight sizes mismatch",
                                m.name
                            );
                            let ds = DsStage {
                                k: m.k,
                                stride: m.stride,
                                pad: m.pad,
                                oh: dss.h,
                                ow: dss.w,
                                och: m.cout,
                                out_exp: m.out_exp,
                                acc_exp: dsw.acc_exp(),
                                w: dsw.w.data.as_slice(),
                                bias: dsw.b.data.as_slice(),
                                out,
                            };
                            (None, Some(ds))
                        }
                        // Port 1 unconsumed: skip the downsample entirely.
                        None => (None, None),
                    }
                } else {
                    (None, None)
                };
                stages.push(Stage::Conv(ConvStage {
                    name: n.name.clone(),
                    k: a.k,
                    stride: a.stride,
                    pad: a.pad,
                    relu: a.relu,
                    out_exp: a.out_exp,
                    acc_exp: lw.acc_exp(),
                    ih: in_shape.h,
                    iw: in_shape.w,
                    ich: a.cin,
                    oh: os.h,
                    ow: os.w,
                    och: a.cout,
                    w: lw.w.data.as_slice(),
                    bias: lw.b.data.as_slice(),
                    input: fifo_of[&n.inputs[0].0].clone(),
                    out: out_fifo_for(n.id)?,
                    skip,
                    forward,
                    ds,
                }));
            }
            Op::MaxPool { k, stride } => {
                // Window/stride bounds already validated by infer_shapes.
                let s = shapes[&n.inputs[0].0];
                let os = shapes[&Edge::new(n.id, 0)];
                stages.push(Stage::Pool(PoolStage {
                    name: n.name.clone(),
                    k: *k,
                    stride: *stride,
                    ih: s.h,
                    iw: s.w,
                    c: s.c,
                    oh: os.h,
                    ow: os.w,
                    input: fifo_of[&n.inputs[0].0].clone(),
                    out: out_fifo_for(n.id)?,
                }));
            }
            Op::GlobalAvgPool { out_exp } => {
                let s = shapes[&n.inputs[0].0];
                anyhow::ensure!(
                    (s.h * s.w).is_power_of_two(),
                    "{}: global pool window {}x{} must be 2^k",
                    n.name,
                    s.h,
                    s.w
                );
                stages.push(Stage::Gap(GapStage {
                    h: s.h,
                    w: s.w,
                    c: s.c,
                    in_exp: s.exp,
                    out_exp: *out_exp,
                    input: fifo_of[&n.inputs[0].0].clone(),
                    out: out_fifo_for(n.id)?,
                }));
            }
            Op::Linear { cin, cout, .. } => {
                let s = shapes[&n.inputs[0].0];
                let lw = weights.layer(&n.name)?;
                anyhow::ensure!(
                    lw.w.data.len() == cin * cout && lw.b.data.len() == *cout,
                    "{}: linear weight sizes mismatch",
                    n.name
                );
                stages.push(Stage::Linear(LinearStage {
                    cout: *cout,
                    tokens: s.h * s.w,
                    cin: *cin,
                    w: lw.w.data.as_slice(),
                    bias: lw.b.data.as_slice(),
                    input: fifo_of[&n.inputs[0].0].clone(),
                    out: out_fifo_for(n.id)?,
                }));
            }
            Op::Relu => {
                let s = shapes[&n.inputs[0].0];
                stages.push(Stage::Relu(ReluStage {
                    tokens: s.h * s.w,
                    input: fifo_of[&n.inputs[0].0].clone(),
                    out: out_fifo_for(n.id)?,
                }));
            }
            Op::Add { .. } | Op::BatchNorm(_) => {
                bail!(
                    "stream backend runs optimized graphs only ({} is a {} node)",
                    n.name,
                    n.op.kind()
                );
            }
        }
    }
    let source_fifo = source_fifo.ok_or_else(|| anyhow!("graph has no input node"))?;

    // Execute: one scoped thread per stage plus source and sink.
    let mut stage_stats: Vec<BufferStat> = Vec::new();
    let mut first_err: Option<StreamError> = None;
    let mut logits: Option<Vec<i32>> = None;
    std::thread::scope(|s| {
        let mut handles = Vec::with_capacity(stages.len() + 1);
        {
            let abort = abort.clone();
            let f = source_fifo.clone();
            handles.push(s.spawn(move || {
                guarded(&abort, || run_source(input, &f).map(|()| Vec::new()))
            }));
        }
        for st in stages.drain(..) {
            let abort = abort.clone();
            handles.push(s.spawn(move || guarded(&abort, || run_stage(st, frames))));
        }
        let sink_handle = {
            let abort = abort.clone();
            let f = sink_fifo.clone();
            s.spawn(move || {
                guarded(&abort, || {
                    let mut out = vec![0i32; frames * classes];
                    for fr in 0..frames {
                        let t = f.pop()?;
                        out[fr * classes..(fr + 1) * classes].copy_from_slice(&t);
                    }
                    Ok(out)
                })
            })
        };
        let mut record = |e: StreamError| {
            if !matches!(e, StreamError::Aborted) && first_err.is_none() {
                first_err = Some(e);
            }
        };
        for h in handles {
            match h.join() {
                Ok(Ok(bufs)) => stage_stats.extend(bufs),
                Ok(Err(e)) => record(e),
                Err(_) => record(StreamError::Panicked),
            }
        }
        match sink_handle.join() {
            Ok(Ok(out)) => logits = Some(out),
            Ok(Err(e)) => record(e),
            Err(_) => record(StreamError::Panicked),
        }
    });
    if let Some(e) = first_err {
        return Err(anyhow::Error::new(e).context("streaming execution failed"));
    }
    let data = logits.ok_or_else(|| anyhow!("streaming execution produced no output"))?;

    // Stats: FIFO + line-buffer peaks vs the whole-tensor intermediates a
    // non-streaming executor materializes per frame.
    let mut buffers: Vec<BufferStat> = fifos.iter().map(|f| f.stat()).collect();
    buffers.extend(stage_stats);
    let whole_tensor_elems: usize = shapes
        .iter()
        .filter(|(e, _)| {
            !matches!(g.node(e.node).op, Op::Input { .. }) && !(e.node == out_node && e.port == 0)
        })
        .map(|(_, s)| s.h * s.w * s.c)
        .sum();
    let stats = StreamStats { buffers, frames, whole_tensor_elems };
    Ok((QTensor::from_vec(Shape4::new(frames, 1, 1, classes), 0, data), stats))
}
