//! One-shot streaming execution: a thin wrapper over the persistent
//! [`StreamPool`].
//!
//! Historically this module *was* the executor — it spawned one scoped
//! thread per layer stage on every call and drained the whole pipeline
//! per batch.  The execution engine now lives in [`super::pool`] /
//! [`super::stage`] (persistent stage threads, frame-level pipelining,
//! channel-parallel workers); `run_streaming` remains as the convenient
//! build-run-drain entry point for tools, tests and property checks that
//! want a single batch plus its buffering report with no pool lifecycle
//! to manage.

use std::sync::Arc;

use anyhow::Result;

use crate::graph::Graph;
use crate::models::ModelWeights;
use crate::quant::QTensor;

use super::pool::StreamPool;
use super::{StreamConfig, StreamStats};

/// Run `input` through a freshly built streaming pipeline for graph `g`,
/// then drain and join it.
///
/// Bit-identical to [`golden::run`](crate::sim::golden::run) on the same
/// graph/weights/input, but executed as a concurrent task pipeline with
/// bounded FIFOs; returns the logits plus the per-buffer occupancy stats.
/// All pool policy knobs apply (`cfg.replicas` pipeline copies,
/// `cfg.naive_add` explicit-Add dataflow, ILP-driven depths and channel
/// workers); a stalled pipeline surfaces as a typed error, never a hang.
///
/// Serving should hold a [`StreamPool`] (or the `StreamBackend`) for its
/// lifetime instead: this wrapper pays plan + thread spawn + pipeline
/// fill on every call, which is exactly the overhead the pool removes.
pub fn run_streaming(
    g: &Graph,
    weights: &ModelWeights,
    input: &QTensor,
    cfg: &StreamConfig,
) -> Result<(QTensor, StreamStats)> {
    anyhow::ensure!(input.shape.n >= 1, "empty input batch");
    let pool = StreamPool::new("stream", g, Arc::new(weights.clone()), cfg.clone())?;
    let result = pool.infer(input);
    let stats = pool.shutdown();
    Ok((result?, stats))
}
