//! Elastic replica scaling for the persistent stream pool.
//!
//! The paper's throughput comes from keeping the dataflow pipeline
//! saturated; a pool fixed at `--replicas B` either wastes stage threads
//! at low load or queues frames at high load.  This module closes that
//! loop, FINN-style (parallelism as a runtime resource knob, not a
//! build-time constant): a controller thread samples the pool's shared
//! work-queue depth (plus the router's queue-depth hint, see
//! `InferenceBackend::load_hint`) and the in-flight frame count on a
//! cadence, and grows or drains **whole pipeline replicas** between
//! `min_replicas..=max_replicas`.
//!
//! Scaling is deliberately conservative and frame-safe:
//! * **up** — only after the load signal stays *strictly above* the
//!   high-water mark for `scale_up_samples` consecutive samples; the new
//!   replica is stamped from the pool's one [`PipelineBlueprint`]
//!   (FIFO specs, gauges and the weights `Arc` are built once per pool,
//!   so growth costs thread spawns, not re-planning);
//! * **down** — only after the pool is *fully idle* (empty queue, zero
//!   frames in flight) for `scale_down_samples` consecutive samples; the
//!   drained replica's feeder stops claiming work between frames, flows
//!   the existing zero-length end-of-stream sentinel through its front
//!   stage, and every thread is joined before the replica is dropped —
//!   never mid-frame;
//! * **no flap** — a load sitting exactly *at* the high-water mark (or
//!   an idle queue with frames still in flight) resets both streaks, so
//!   steady load at the boundary never oscillates the pool
//!   ([`ElasticPolicy`] is pure and unit-tested for exactly this).
//!
//! [`PipelineBlueprint`]: super::stage::PipelineBlueprint

use std::sync::atomic::Ordering;
use std::thread;
use std::time::Duration;

use super::pool::PoolInner;

/// Elastic-scaling policy knobs (see [`crate::stream::StreamConfig`]'s
/// `elastic` field; `None` there keeps the fixed `replicas` pool).
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// The pool never drains below this many replicas (floor 1); also
    /// the replica count the pool starts with.
    pub min_replicas: usize,
    /// The pool never grows beyond this many replicas.  Batcher buckets
    /// are sized to the in-flight capacity at this band maximum.
    pub max_replicas: usize,
    /// Queue-depth high-water mark; `None` sizes it to one replica's
    /// in-flight capacity (its stage count) — scale up only when at
    /// least a whole replica's worth of frames is waiting.
    pub high_water: Option<usize>,
    /// Controller sampling cadence.  Also bounds pool-shutdown latency:
    /// the controller is joined on shutdown and sleeps this long between
    /// samples, so keep it small (milliseconds, not minutes).
    pub sample_interval: Duration,
    /// Consecutive samples strictly above the high-water mark before one
    /// replica is added.
    pub scale_up_samples: usize,
    /// Consecutive fully idle samples (empty queue, nothing in flight)
    /// before one replica is drained.
    pub scale_down_samples: usize,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            min_replicas: 1,
            max_replicas: 4,
            high_water: None,
            sample_interval: Duration::from_millis(5),
            scale_up_samples: 2,
            scale_down_samples: 40,
        }
    }
}

/// One scaling decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleAction {
    /// Add one replica.
    Up,
    /// Drain and join one replica.
    Down,
}

/// The pure scaling policy: streak counting over load samples.  Kept
/// free of pool state so the hysteresis (in particular the no-flap
/// behavior at the high-water mark) is directly unit-testable.
#[derive(Debug)]
pub struct ElasticPolicy {
    min: usize,
    max: usize,
    high_water: usize,
    up_after: usize,
    down_after: usize,
    up_streak: usize,
    idle_streak: usize,
}

impl ElasticPolicy {
    /// `default_high_water` is used when the config leaves `high_water`
    /// unset (the pool passes one replica's stage count).
    pub fn new(cfg: &ElasticConfig, default_high_water: usize) -> ElasticPolicy {
        let min = cfg.min_replicas.max(1);
        ElasticPolicy {
            min,
            max: cfg.max_replicas.max(min),
            high_water: cfg.high_water.unwrap_or(default_high_water).max(1),
            up_after: cfg.scale_up_samples.max(1),
            down_after: cfg.scale_down_samples.max(1),
            up_streak: 0,
            idle_streak: 0,
        }
    }

    /// The effective high-water mark.
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Feed one load sample; returns the action to take now, if any.
    /// `queue_depth` is the waiting-frame signal (pool queue plus router
    /// hint), `in_flight` counts accepted-but-unanswered frames,
    /// `replicas` is the current live replica count.
    pub fn observe(
        &mut self,
        queue_depth: usize,
        in_flight: usize,
        replicas: usize,
    ) -> Option<ScaleAction> {
        if queue_depth > self.high_water {
            self.idle_streak = 0;
            self.up_streak = self.up_streak.saturating_add(1);
            if replicas < self.max && self.up_streak >= self.up_after {
                self.up_streak = 0;
                return Some(ScaleAction::Up);
            }
        } else if queue_depth == 0 && in_flight == 0 {
            self.up_streak = 0;
            self.idle_streak = self.idle_streak.saturating_add(1);
            if replicas > self.min && self.idle_streak >= self.down_after {
                self.idle_streak = 0;
                return Some(ScaleAction::Down);
            }
        } else {
            // Load at/below the high-water mark, or an idle queue with
            // frames still in flight: steady state.  Both streaks reset,
            // so load sitting exactly on the mark never flaps the pool.
            self.up_streak = 0;
            self.idle_streak = 0;
        }
        None
    }
}

/// One load sample the pool hands the controller.
pub(crate) struct LoadSample {
    /// Waiting frames: the pool's queue depth plus the router's hint.
    pub queue_depth: usize,
    /// Frames accepted but not yet answered (includes the queue).
    pub in_flight: usize,
}

/// The controller body: sample on the cadence, apply the policy, scale.
/// Exits when the pool stops, poisons, or raises the stop flag.
pub(crate) fn controller_loop(inner: &PoolInner, cfg: &ElasticConfig, default_high_water: usize) {
    let mut policy = ElasticPolicy::new(cfg, default_high_water);
    loop {
        thread::sleep(cfg.sample_interval);
        if inner.ctl_stop.load(Ordering::SeqCst) {
            return;
        }
        let Some(s) = inner.sample() else { return };
        // Preemption first: if this pool holds borrowed workers while a
        // starved peer's bid waits in the shared budget's queue, give a
        // replica back voluntarily (drained between frames, never a
        // mid-frame kill) before judging our own load.  `retire_one`
        // refuses below `min_replicas`, so the reservation floor holds.
        if inner.should_yield() && inner.retire_one() {
            inner.scale_downs.fetch_add(1, Ordering::Relaxed);
            continue;
        }
        match policy.observe(s.queue_depth, s.in_flight, inner.replica_count()) {
            // Scaling up is a BID, not a self-grant: under a shared
            // budget `add_replica` first asks for a lease, and a denial
            // (like a failed spawn under transient resource exhaustion)
            // is not fatal — the pool keeps serving at its current
            // size, the denial lands in the budget's counters/queue,
            // and the controller retries on a later sample.
            Some(ScaleAction::Up) => {
                if inner.add_replica().is_ok() {
                    inner.scale_ups.fetch_add(1, Ordering::Relaxed);
                }
            }
            Some(ScaleAction::Down) => {
                inner.cancel_bid();
                if inner.retire_one() {
                    inner.scale_downs.fetch_add(1, Ordering::Relaxed);
                }
            }
            // Steady state: once the queue is back at/under the mark,
            // withdraw any stale queued bid — a pool that stopped
            // wanting to grow must not block the other pools' borrows
            // from the FIFO waiter queue.  (While pressure persists the
            // bid stays queued, keeping its anti-starvation position.)
            None => {
                if s.queue_depth <= policy.high_water() {
                    inner.cancel_bid();
                }
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    fn cfg() -> ElasticConfig {
        ElasticConfig {
            min_replicas: 1,
            max_replicas: 4,
            high_water: Some(8),
            scale_up_samples: 2,
            scale_down_samples: 3,
            ..Default::default()
        }
    }

    #[test]
    fn scales_up_only_after_a_sustained_burst() {
        let mut p = ElasticPolicy::new(&cfg(), 99);
        assert_eq!(p.high_water(), 8);
        assert!(p.observe(9, 9, 1).is_none());
        assert_eq!(p.observe(9, 9, 1), Some(ScaleAction::Up));
        // The streak resets after an action: growing further takes
        // another sustained burst.
        assert!(p.observe(9, 9, 2).is_none());
        assert_eq!(p.observe(9, 9, 2), Some(ScaleAction::Up));
        // At the band maximum, pressure never acts.
        for _ in 0..50 {
            assert!(p.observe(1000, 1000, 4).is_none());
        }
    }

    #[test]
    fn scales_down_only_when_fully_idle_for_the_streak() {
        let mut p = ElasticPolicy::new(&cfg(), 99);
        // An empty queue with frames still in flight is not idle.
        for _ in 0..50 {
            assert!(p.observe(0, 3, 2).is_none());
        }
        assert!(p.observe(0, 0, 2).is_none());
        assert!(p.observe(0, 0, 2).is_none());
        assert_eq!(p.observe(0, 0, 2), Some(ScaleAction::Down));
        // At the band minimum, idleness never drains further.
        for _ in 0..50 {
            assert!(p.observe(0, 0, 1).is_none());
        }
    }
}
