//! # resnet-hls — Residual NN accelerators for low-power FPGAs, reproduced
//!
//! Rust implementation of the systems described in *"Design and Optimization
//! of Residual Neural Network Accelerators for Low-Power FPGAs Using
//! High-Level Synthesis"* (Minnella, Urso, Lazarescu, Lavagno, 2023).
//!
//! The paper's testbed is physical FPGA hardware; here the hardware substrate
//! is **simulated** (see `DESIGN.md` §Substitutions) while the numerics run
//! for real through an AOT-compiled JAX/Pallas model executed via PJRT.
//!
//! Layer map (three-layer architecture):
//! * **L3 (this crate)** — the paper's flow and substrates:
//!   - *design flow*: graph IR and the residual-block optimizations
//!     (`graph`, `passes`), ILP throughput balancing (`ilp`), HLS-style
//!     configuration/codegen/resource model (`hls`);
//!   - *execution*: the backend-agnostic inference API
//!     (`runtime::backend` — the `InferenceBackend`/`BackendFactory`
//!     traits) with four substrates: the PJRT engine (`runtime`, real
//!     AOT-compiled numerics), the integer golden model (`sim::golden`,
//!     artifact-free), the cycle-approximate dataflow simulator
//!     (`sim::engine`, realistic accelerator timing), and the pipelined
//!     streaming executor (`stream`, golden numerics executed as the
//!     paper's line-buffer/FIFO dataflow with measured Eq. 22 buffering);
//!   - *serving*: the multi-arch `coordinator::Router` (per-arch worker
//!     pools, dynamic batcher, metrics) — backend-generic, so the whole
//!     request path is testable without Python, PJRT or artifacts —
//!     fronted by the `net` ingress tier (length-prefixed TCP protocol,
//!     bounded admission with load-shedding and deadlines, in-order
//!     per-connection responses).
//! * **L2/L1 (python/, build-time only)** — quantized ResNet8/20 in JAX,
//!   compute hot-spots as Pallas kernels, lowered once to `artifacts/*.hlo.txt`.
//!
//! Nothing in this crate imports Python at runtime; the `artifacts/`
//! directory fully decouples the two worlds, and only the PJRT backend
//! consumes it.

pub mod analysis;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod graph;
pub mod hls;
pub mod ilp;
pub mod models;
pub mod net;
pub mod obs;
pub mod passes;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod stream;
pub mod util;

/// Repository-relative path helpers used by tests, benches and examples.
pub mod paths {
    use std::path::PathBuf;

    /// Root of the repository (directory containing `Cargo.toml`).
    pub fn repo_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
    }

    /// The artifacts directory produced by `make artifacts`.
    pub fn artifacts_dir() -> PathBuf {
        if let Ok(p) = std::env::var("REPRO_ARTIFACTS") {
            return PathBuf::from(p);
        }
        repo_root().join("artifacts")
    }
}
