//! Pipeline observability: per-stage stall attribution, frame spans and
//! bottleneck reports for the streaming executor.
//!
//! The paper's whole optimization story is about *balancing* the
//! dataflow pipeline — FIFO depths from Eq. 21/22 and loop merging exist
//! to keep every stage's initiation interval matched — yet aggregate
//! counters and peak occupancy alone cannot say *which* stage or FIFO
//! edge limits throughput when a configuration plateaus.  This module is
//! the measurement layer that answers that question, cheaply enough to
//! leave on in production:
//!
//! * [`FifoProbe`] — lock-free per-edge counters attached to every
//!   [`Fifo`](crate::stream::Fifo): wall time a producer spent blocked
//!   pushing, wall time a consumer spent blocked popping (both recorded
//!   only on the slow path, so an uncontended transfer costs one relaxed
//!   atomic increment for the occupancy histogram and nothing else), and
//!   an 8-bucket occupancy-fraction histogram on top of the peak gauge;
//! * [`StageClock`] — per stage thread: wall time since the replica
//!   epoch split into busy / blocked-on-push / blocked-on-pop by summing
//!   the stage's own side of its port probes (each FIFO has exactly one
//!   producer and one consumer stage, so the topology *is* the
//!   attribution), plus a frame counter and a bounded ring of per-frame
//!   completion stamps (the "stage boundary" timestamps of a frame
//!   span);
//! * [`SpanRing`] / [`FrameSpan`] — frame-level spans: every ticket is
//!   timestamped entering the pool, when a replica feeder claims it, at
//!   every stage boundary (via the stage completion rings) and at
//!   delivery, retained in a bounded ring per replica;
//! * [`StallReport`] / [`BottleneckReport`] — the replica-aggregated
//!   rollup and the verdict: which stage limits the pipeline (highest
//!   busy fraction) and which FIFO edge the most-stalled stage starves
//!   or backpressures, e.g. `s0b0c1: 71% blocked-on-push -> edge
//!   s0b0c2.skip`.
//!
//! Surfaced three ways: the `--metrics-port` exposition endpoint
//! ([`crate::net::metrics`], Prometheus text + JSON), the `repro stats`
//! subcommand, and rollups recorded into [`coordinator::Metrics`]
//! snapshots through the [`InferenceBackend::stall_report`] hook.
//!
//! Instrumentation can be globally disabled ([`set_enabled`]) — the
//! benches use that to measure its own overhead (`BENCH_stream.json`
//! records the on/off throughput pair; the `hotpath` bench guards the
//! per-operation cost).
//!
//! [`coordinator::Metrics`]: crate::coordinator::Metrics
//! [`InferenceBackend::stall_report`]: crate::runtime::InferenceBackend::stall_report

// Panic-freedom gate: observability must never take a serving thread
// down.  `clippy.toml` disallows Option/Result unwrap+expect; test
// modules opt out locally.
#![deny(clippy::disallowed_methods)]

mod clock;
mod report;

pub use clock::{
    FifoProbe, FrameSpan, PipelineObs, SpanRing, StageClock, StageRole, StageStall, OCC_BUCKETS,
    SPAN_RING,
};
pub use report::{
    base_name, BlockOp, BottleneckReport, BudgetLease, BudgetSnapshot, EdgeStat, StallReport,
};

use std::sync::atomic::{AtomicBool, Ordering};

/// Global instrumentation switch (default on).  The hot-path hooks load
/// it relaxed; flipping it off zeroes the *recording* cost, which is how
/// the benches measure the cost of leaving it on.
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Is instrumentation recording?
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Toggle instrumentation recording process-wide (bench/test hook).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}
