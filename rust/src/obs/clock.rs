//! The instrumentation core: lock-free per-edge probes, per-stage
//! clocks, and bounded frame-span rings.
//!
//! Everything here is written by exactly one pipeline thread (the FIFO's
//! single producer, its single consumer, or the one stage/feeder/sink
//! thread that owns a clock) and read by anyone, so plain relaxed
//! atomics carry the counters and a seqlock-lite stamp guards the rings.
//! Readers are best-effort by design: a span assembled while the pipeline
//! is writing may skip a stage mark, never block a serving thread.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Buckets of the per-FIFO occupancy-fraction histogram: bucket `i`
/// counts pushes that left occupancy in `(i/8, (i+1)/8]` of capacity
/// (bucket 0 includes empty).
pub const OCC_BUCKETS: usize = 8;

/// Frames of history per replica in the span ring and in each stage's
/// boundary-mark ring.
pub const SPAN_RING: usize = 64;

#[allow(clippy::declare_interior_mutable_const)]
const ZERO: AtomicU64 = AtomicU64::new(0);

/// Per-FIFO stall and occupancy counters, attached to every stream FIFO
/// at construction and shared with the stage clocks of its producer and
/// consumer.
///
/// The fast path of a FIFO transfer records exactly one relaxed
/// increment (the occupancy histogram); blocked wall time is measured
/// only once an operation actually waits.
#[derive(Debug, Default)]
pub struct FifoProbe {
    blocked_push_ns: AtomicU64,
    blocked_pop_ns: AtomicU64,
    push_blocks: AtomicU64,
    pop_blocks: AtomicU64,
    occ_hist: [AtomicU64; OCC_BUCKETS],
}

impl FifoProbe {
    pub fn new() -> Arc<FifoProbe> {
        Arc::new(FifoProbe::default())
    }

    /// A push left the FIFO at `occupancy` of `capacity` elements.
    #[inline]
    pub fn observe_occupancy(&self, occupancy: usize, capacity: usize) {
        let cap = capacity.max(1);
        let bucket = (occupancy * OCC_BUCKETS / cap).min(OCC_BUCKETS - 1);
        self.occ_hist[bucket].fetch_add(1, Ordering::Relaxed);
    }

    /// A producer finished a push that had to wait `blocked` first.
    pub fn record_push_block(&self, blocked: Duration) {
        self.blocked_push_ns.fetch_add(blocked.as_nanos() as u64, Ordering::Relaxed);
        self.push_blocks.fetch_add(1, Ordering::Relaxed);
    }

    /// A consumer finished a pop that had to wait `blocked` first.
    pub fn record_pop_block(&self, blocked: Duration) {
        self.blocked_pop_ns.fetch_add(blocked.as_nanos() as u64, Ordering::Relaxed);
        self.pop_blocks.fetch_add(1, Ordering::Relaxed);
    }

    pub fn blocked_push_ns(&self) -> u64 {
        self.blocked_push_ns.load(Ordering::Relaxed)
    }

    pub fn blocked_pop_ns(&self) -> u64 {
        self.blocked_pop_ns.load(Ordering::Relaxed)
    }

    pub fn push_blocks(&self) -> u64 {
        self.push_blocks.load(Ordering::Relaxed)
    }

    pub fn pop_blocks(&self) -> u64 {
        self.pop_blocks.load(Ordering::Relaxed)
    }

    pub fn occ_hist(&self) -> [u64; OCC_BUCKETS] {
        std::array::from_fn(|i| self.occ_hist[i].load(Ordering::Relaxed))
    }
}

/// What kind of pipeline thread a clock instruments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageRole {
    /// The replica feeder (claims work, streams pixels into the sources).
    Feeder,
    /// A layer stage thread (conv/pool/gap/linear/relu/add).
    Stage,
    /// The replica sink (pops classified frames, answers tickets).
    Sink,
}

/// Wall-time accounting for one pipeline thread.
///
/// Each FIFO has exactly one producer and one consumer stage, so a
/// stage's blocked-on-push time is the summed producer-side blocked time
/// of its output probes, its blocked-on-pop time the summed
/// consumer-side blocked time of its input probes, and busy time is
/// whatever remains of the wall clock since the replica epoch.  The
/// clock additionally counts completed frames and stamps each frame's
/// completion time into a bounded ring ([`SPAN_RING`] entries), which is
/// where [`FrameSpan`] stage-boundary timestamps come from.
#[derive(Debug)]
pub struct StageClock {
    name: String,
    role: StageRole,
    epoch: Instant,
    frames: AtomicU64,
    /// Ring slot stamp: frame index + 1 (0 = never written).
    mark_seq: [AtomicU64; SPAN_RING],
    /// Nanoseconds since `epoch` at that frame's completion.
    mark_ns: [AtomicU64; SPAN_RING],
    inputs: Vec<(String, Arc<FifoProbe>)>,
    outputs: Vec<(String, Arc<FifoProbe>)>,
}

impl StageClock {
    pub fn new(
        name: String,
        role: StageRole,
        epoch: Instant,
        inputs: Vec<(String, Arc<FifoProbe>)>,
        outputs: Vec<(String, Arc<FifoProbe>)>,
    ) -> Arc<StageClock> {
        Arc::new(StageClock {
            name,
            role,
            epoch,
            frames: AtomicU64::new(0),
            mark_seq: [ZERO; SPAN_RING],
            mark_ns: [ZERO; SPAN_RING],
            inputs,
            outputs,
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn role(&self) -> StageRole {
        self.role
    }

    /// Frame boundary hook, called by the owning thread once per
    /// completed frame: stamp the completion time and advance the
    /// counter.  Two relaxed loads, three stores — cheap enough for every
    /// frame.
    pub fn frame_done(&self) {
        let n = self.frames.load(Ordering::Relaxed);
        let slot = (n % SPAN_RING as u64) as usize;
        let ns = self.epoch.elapsed().as_nanos() as u64;
        self.mark_ns[slot].store(ns, Ordering::Relaxed);
        self.mark_seq[slot].store(n + 1, Ordering::Release);
        self.frames.store(n + 1, Ordering::Release);
    }

    /// Completed frames.
    pub fn frames(&self) -> u64 {
        self.frames.load(Ordering::Acquire)
    }

    /// Completion time (ns since the replica epoch) of frame `n`, if the
    /// mark is still in the ring and not being overwritten right now.
    pub fn mark(&self, n: u64) -> Option<u64> {
        if self.frames() <= n {
            return None;
        }
        let slot = (n % SPAN_RING as u64) as usize;
        if self.mark_seq[slot].load(Ordering::Acquire) != n + 1 {
            return None;
        }
        let ns = self.mark_ns[slot].load(Ordering::Relaxed);
        // Seqlock-lite re-check: a concurrent overwrite of the slot
        // invalidates the read (best effort; see module docs).
        if self.mark_seq[slot].load(Ordering::Acquire) != n + 1 {
            return None;
        }
        Some(ns)
    }

    /// Snapshot this thread's wall-time split.
    pub fn stall(&self) -> StageStall {
        let elapsed_ns = self.epoch.elapsed().as_nanos() as u64;
        let blocked_push_ns: u64 = self.outputs.iter().map(|(_, p)| p.blocked_push_ns()).sum();
        let blocked_pop_ns: u64 = self.inputs.iter().map(|(_, p)| p.blocked_pop_ns()).sum();
        let worst = |ports: &[(String, Arc<FifoProbe>)], f: fn(&FifoProbe) -> u64| {
            ports
                .iter()
                .map(|(n, p)| (n.clone(), f(p)))
                .filter(|(_, ns)| *ns > 0)
                .max_by_key(|(_, ns)| *ns)
        };
        StageStall {
            stage: self.name.clone(),
            role: self.role,
            elapsed_ns,
            blocked_push_ns,
            blocked_pop_ns,
            frames: self.frames(),
            worst_push_edge: worst(&self.outputs, FifoProbe::blocked_push_ns),
            worst_pop_edge: worst(&self.inputs, FifoProbe::blocked_pop_ns),
        }
    }
}

/// One pipeline thread's wall-time split (possibly aggregated across
/// replicas — fractions are then time-weighted averages).
#[derive(Debug, Clone, PartialEq)]
pub struct StageStall {
    pub stage: String,
    pub role: StageRole,
    /// Wall time since the replica epoch (summed when aggregated).
    pub elapsed_ns: u64,
    pub blocked_push_ns: u64,
    pub blocked_pop_ns: u64,
    pub frames: u64,
    /// Output edge with the most producer-side blocked time, if any.
    pub worst_push_edge: Option<(String, u64)>,
    /// Input edge with the most consumer-side blocked time, if any.
    pub worst_pop_edge: Option<(String, u64)>,
}

impl StageStall {
    /// Wall time neither blocked pushing nor popping.
    pub fn busy_ns(&self) -> u64 {
        self.elapsed_ns.saturating_sub(self.blocked_push_ns + self.blocked_pop_ns)
    }

    pub fn busy_frac(&self) -> f64 {
        frac(self.busy_ns(), self.elapsed_ns)
    }

    pub fn blocked_push_frac(&self) -> f64 {
        frac(self.blocked_push_ns, self.elapsed_ns)
    }

    pub fn blocked_pop_frac(&self) -> f64 {
        frac(self.blocked_pop_ns, self.elapsed_ns)
    }

    /// Fold another replica's clock for the same stage into this one.
    pub fn merge(&mut self, other: &StageStall) {
        self.elapsed_ns += other.elapsed_ns;
        self.blocked_push_ns += other.blocked_push_ns;
        self.blocked_pop_ns += other.blocked_pop_ns;
        self.frames += other.frames;
        merge_edge(&mut self.worst_push_edge, &other.worst_push_edge);
        merge_edge(&mut self.worst_pop_edge, &other.worst_pop_edge);
    }
}

/// Merge a worst-edge candidate: same base edge across replicas sums its
/// blocked time (and normalizes to the untagged name); otherwise the
/// edge with more blocked time wins.
fn merge_edge(into: &mut Option<(String, u64)>, other: &Option<(String, u64)>) {
    let Some((oname, ons)) = other else { return };
    let oname = super::base_name(oname).to_string();
    *into = match into.take() {
        Some((cur, cur_ns)) if super::base_name(&cur) == oname => Some((oname, cur_ns + ons)),
        Some((cur, cur_ns)) if cur_ns >= *ons => Some((cur, cur_ns)),
        _ => Some((oname, *ons)),
    };
}

fn frac(part: u64, whole: u64) -> f64 {
    if whole == 0 {
        return 0.0;
    }
    part as f64 / whole as f64
}

/// Bounded ring of delivered-frame spans, written by the replica sink.
#[derive(Debug)]
pub struct SpanRing {
    /// Slot stamp: replica-local frame index + 1 (0 = never written).
    seq: [AtomicU64; SPAN_RING],
    queued_ns: [AtomicU64; SPAN_RING],
    total_ns: [AtomicU64; SPAN_RING],
}

impl Default for SpanRing {
    fn default() -> Self {
        SpanRing { seq: [ZERO; SPAN_RING], queued_ns: [ZERO; SPAN_RING], total_ns: [ZERO; SPAN_RING] }
    }
}

impl SpanRing {
    pub fn new() -> Arc<SpanRing> {
        Arc::new(SpanRing::default())
    }

    /// Record replica-local frame `n`: time queued before a feeder
    /// claimed it, and total submit-to-delivery latency.
    pub fn record(&self, n: u64, queued: Duration, total: Duration) {
        let slot = (n % SPAN_RING as u64) as usize;
        self.queued_ns[slot].store(queued.as_nanos() as u64, Ordering::Relaxed);
        self.total_ns[slot].store(total.as_nanos() as u64, Ordering::Relaxed);
        self.seq[slot].store(n + 1, Ordering::Release);
    }

    /// `(queued_ns, total_ns)` for frame `n`, if still in the ring.
    pub fn get(&self, n: u64) -> Option<(u64, u64)> {
        let slot = (n % SPAN_RING as u64) as usize;
        if self.seq[slot].load(Ordering::Acquire) != n + 1 {
            return None;
        }
        let out = (
            self.queued_ns[slot].load(Ordering::Relaxed),
            self.total_ns[slot].load(Ordering::Relaxed),
        );
        if self.seq[slot].load(Ordering::Acquire) != n + 1 {
            return None;
        }
        Some(out)
    }
}

/// One delivered frame's span, assembled from the sink ring and the
/// stage completion marks.
#[derive(Debug, Clone)]
pub struct FrameSpan {
    /// Replica-local frame index.
    pub frame: u64,
    /// Microseconds between pool submit and a feeder claiming the frame.
    pub queued_us: u64,
    /// Microseconds between pool submit and ticket delivery.
    pub total_us: u64,
    /// `(thread, us since the replica epoch)` at each boundary the rings
    /// still hold, in pipeline order: feeder claim, each stage's frame
    /// completion, sink delivery.
    pub marks_us: Vec<(String, u64)>,
}

/// Per-replica observability bundle: the feeder/stage/sink clocks on one
/// shared epoch, the feeder's wait-for-work probe, and the span ring.
#[derive(Debug, Clone)]
pub struct PipelineObs {
    pub epoch: Instant,
    pub feeder: Arc<StageClock>,
    pub stages: Vec<Arc<StageClock>>,
    pub sink: Arc<StageClock>,
    /// Synthetic "edge" for the feeder's time waiting on the shared work
    /// queue (not a FIFO, but blocked-on-pop all the same).
    pub queue_probe: Arc<FifoProbe>,
    pub spans: Arc<SpanRing>,
}

impl PipelineObs {
    /// Build the bundle for one replica.  `stages` carries, per stage in
    /// pipeline order: its (tagged) name, its input probes and its
    /// output probes, each probe labeled with its FIFO name.
    #[allow(clippy::type_complexity)]
    pub fn new(
        tag: &str,
        stages: Vec<(String, Vec<(String, Arc<FifoProbe>)>, Vec<(String, Arc<FifoProbe>)>)>,
        sources: Vec<(String, Arc<FifoProbe>)>,
        sink: (String, Arc<FifoProbe>),
    ) -> PipelineObs {
        let epoch = Instant::now();
        let queue_probe = FifoProbe::new();
        let feeder = StageClock::new(
            format!("{tag}feeder"),
            StageRole::Feeder,
            epoch,
            vec![(format!("{tag}queue"), queue_probe.clone())],
            sources,
        );
        let stages = stages
            .into_iter()
            .map(|(name, inputs, outputs)| {
                StageClock::new(name, StageRole::Stage, epoch, inputs, outputs)
            })
            .collect();
        let sink =
            StageClock::new(format!("{tag}sink"), StageRole::Sink, epoch, vec![sink], Vec::new());
        PipelineObs { epoch, feeder, stages, sink, queue_probe, spans: SpanRing::new() }
    }

    /// Stall snapshots for every thread of this replica, pipeline order.
    pub fn stalls(&self) -> Vec<StageStall> {
        let mut out = Vec::with_capacity(self.stages.len() + 2);
        out.push(self.feeder.stall());
        out.extend(self.stages.iter().map(|c| c.stall()));
        out.push(self.sink.stall());
        out
    }

    /// Spans of the most recently delivered frames still in the ring,
    /// oldest first.  Best effort: a stage mark that was overwritten (or
    /// is being written) between the sink stamp and this read is simply
    /// absent from `marks_us`.
    pub fn recent_spans(&self) -> Vec<FrameSpan> {
        let done = self.sink.frames();
        let lo = done.saturating_sub(SPAN_RING as u64);
        let mut out = Vec::new();
        for n in lo..done {
            let Some((queued_ns, total_ns)) = self.spans.get(n) else { continue };
            let mut marks_us = Vec::with_capacity(self.stages.len() + 2);
            let clocks = std::iter::once(&self.feeder)
                .chain(self.stages.iter())
                .chain(std::iter::once(&self.sink));
            for clock in clocks {
                if let Some(ns) = clock.mark(n) {
                    marks_us.push((clock.name().to_string(), ns / 1_000));
                }
            }
            out.push(FrameSpan {
                frame: n,
                queued_us: queued_ns / 1_000,
                total_us: total_ns / 1_000,
                marks_us,
            });
        }
        out
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    #[test]
    fn probe_accumulates_blocked_time_and_occupancy_buckets() {
        let p = FifoProbe::new();
        p.observe_occupancy(0, 16); // empty -> bucket 0
        p.observe_occupancy(8, 16); // half -> bucket 4
        p.observe_occupancy(16, 16); // full -> clamped to bucket 7
        p.observe_occupancy(3, 0); // degenerate capacity is clamped, no panic
        let h = p.occ_hist();
        assert_eq!(h[0], 1);
        assert_eq!(h[4], 1);
        assert_eq!(h[7], 2);
        p.record_push_block(Duration::from_micros(5));
        p.record_push_block(Duration::from_micros(5));
        p.record_pop_block(Duration::from_micros(3));
        assert_eq!(p.blocked_push_ns(), 10_000);
        assert_eq!(p.blocked_pop_ns(), 3_000);
        assert_eq!(p.push_blocks(), 2);
        assert_eq!(p.pop_blocks(), 1);
    }

    #[test]
    fn stage_clock_splits_wall_time_and_names_worst_edges() {
        let epoch = Instant::now();
        let in_a = FifoProbe::new();
        let out_a = FifoProbe::new();
        let out_b = FifoProbe::new();
        let clock = StageClock::new(
            "s0".into(),
            StageRole::Stage,
            epoch,
            vec![("s0.in".into(), in_a.clone())],
            vec![("next.in".into(), out_a.clone()), ("next.skip".into(), out_b.clone())],
        );
        in_a.record_pop_block(Duration::from_millis(2));
        out_a.record_push_block(Duration::from_millis(1));
        out_b.record_push_block(Duration::from_millis(4));
        let s = clock.stall();
        assert_eq!(s.blocked_pop_ns, 2_000_000);
        assert_eq!(s.blocked_push_ns, 5_000_000);
        assert_eq!(s.worst_pop_edge, Some(("s0.in".into(), 2_000_000)));
        assert_eq!(s.worst_push_edge, Some(("next.skip".into(), 4_000_000)));
        assert!(s.elapsed_ns >= s.blocked_push_ns + s.blocked_pop_ns || s.busy_ns() == 0);
        // Fractions are well-defined and sum to <= 1 (busy absorbs the rest).
        assert!(s.busy_frac() >= 0.0 && s.busy_frac() <= 1.0);
    }

    #[test]
    fn frame_marks_survive_in_the_ring_until_overwritten() {
        let clock =
            StageClock::new("s".into(), StageRole::Stage, Instant::now(), vec![], vec![]);
        for _ in 0..(SPAN_RING + 3) {
            clock.frame_done();
        }
        assert_eq!(clock.frames(), SPAN_RING as u64 + 3);
        // The first three frames were overwritten by the wraparound.
        assert!(clock.mark(0).is_none());
        assert!(clock.mark(2).is_none());
        assert!(clock.mark(3).is_some());
        assert!(clock.mark(SPAN_RING as u64 + 2).is_some());
        // Not-yet-completed frames have no mark.
        assert!(clock.mark(SPAN_RING as u64 + 3).is_none());
    }

    #[test]
    fn span_ring_returns_only_live_entries() {
        let ring = SpanRing::new();
        ring.record(0, Duration::from_micros(10), Duration::from_micros(50));
        assert_eq!(ring.get(0), Some((10_000, 50_000)));
        // Overwriting the slot invalidates the old frame.
        ring.record(SPAN_RING as u64, Duration::from_micros(1), Duration::from_micros(2));
        assert!(ring.get(0).is_none());
        assert_eq!(ring.get(SPAN_RING as u64), Some((1_000, 2_000)));
    }

    #[test]
    fn stall_merge_aggregates_replicas_time_weighted() {
        let mut a = StageStall {
            stage: "conv".into(),
            role: StageRole::Stage,
            elapsed_ns: 100,
            blocked_push_ns: 10,
            blocked_pop_ns: 20,
            frames: 4,
            worst_push_edge: Some(("r1/next.in".into(), 10)),
            worst_pop_edge: None,
        };
        let b = StageStall {
            stage: "conv".into(),
            role: StageRole::Stage,
            elapsed_ns: 300,
            blocked_push_ns: 30,
            blocked_pop_ns: 0,
            frames: 6,
            worst_push_edge: Some(("next.in".into(), 30)),
            worst_pop_edge: Some(("conv.in".into(), 7)),
        };
        a.merge(&b);
        assert_eq!(a.elapsed_ns, 400);
        assert_eq!(a.blocked_push_ns, 40);
        assert_eq!(a.frames, 10);
        // Same base edge across replicas: blocked time sums, name untagged.
        assert_eq!(a.worst_push_edge, Some(("next.in".into(), 40)));
        assert_eq!(a.worst_pop_edge, Some(("conv.in".into(), 7)));
        assert!((a.busy_frac() - 340.0 / 400.0).abs() < 1e-9);
    }
}
