//! Replica-aggregated stall rollups and the bottleneck verdict.
//!
//! [`StallReport`] is the exportable form of the clocks and probes in
//! [`super::clock`]: stage rows keyed by untagged stage name (replica
//! tags `r{i}/` stripped, counters summed — fractions become
//! time-weighted averages across replicas), edge rows likewise, plus the
//! pool-level gauges (frames, replicas, elastic scale events).
//! [`BottleneckReport`] is the derived verdict the paper's balancing
//! story needs: the stage that limits the pipeline (highest busy
//! fraction — everything else is waiting on it) and the FIFO edge the
//! most-stalled stage starves (blocked-on-pop) or backpressures
//! (blocked-on-push), which under Eq. 21/22 sizing is exactly the edge
//! whose depth or producer rate to revisit.

use std::fmt;

use crate::hls::streams::StreamKind;
use crate::util::Json;

use super::{StageRole, StageStall, OCC_BUCKETS};

/// Strip the replica tag (`r{i}/`) off a stage/FIFO name.
pub fn base_name(name: &str) -> &str {
    name.rsplit_once('/').map_or(name, |(_, b)| b)
}

/// One FIFO edge's full telemetry: the sizing/occupancy view of
/// [`BufferStat`](crate::stream::BufferStat) plus the probe counters.
#[derive(Debug, Clone, PartialEq)]
pub struct EdgeStat {
    pub name: String,
    pub kind: StreamKind,
    /// Capacity bound in activation elements (Eq. 21/22-derived for skip
    /// edges).
    pub capacity: usize,
    pub peak: usize,
    /// Wall time the producer stage spent blocked pushing into this edge.
    pub blocked_push_ns: u64,
    /// Wall time the consumer stage spent blocked popping from it.
    pub blocked_pop_ns: u64,
    pub push_blocks: u64,
    pub pop_blocks: u64,
    /// Occupancy-fraction histogram: bucket `i` counts pushes that left
    /// occupancy in `(i/8, (i+1)/8]` of capacity.
    pub occ_hist: [u64; OCC_BUCKETS],
}

impl EdgeStat {
    /// Fold another replica's stats for the same base edge into this one.
    pub fn merge(&mut self, other: &EdgeStat) {
        self.peak = self.peak.max(other.peak);
        self.blocked_push_ns += other.blocked_push_ns;
        self.blocked_pop_ns += other.blocked_pop_ns;
        self.push_blocks += other.push_blocks;
        self.pop_blocks += other.pop_blocks;
        for (a, b) in self.occ_hist.iter_mut().zip(other.occ_hist.iter()) {
            *a += b;
        }
    }

    /// Total pushes observed by the occupancy histogram.
    pub fn pushes(&self) -> u64 {
        self.occ_hist.iter().sum()
    }
}

/// Which side of a FIFO transfer a stage was blocked on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockOp {
    Push,
    Pop,
}

impl fmt::Display for BlockOp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            BlockOp::Push => "push",
            BlockOp::Pop => "pop",
        })
    }
}

/// The most-stalled stage and the edge it waits on.
#[derive(Debug, Clone)]
pub struct Victim {
    pub stage: String,
    /// Fraction of its wall time blocked on `op`.
    pub frac: f64,
    pub op: BlockOp,
    /// The edge carrying most of that blocked time, when attributable.
    pub edge: Option<String>,
}

/// The pipeline-limiting verdict derived from a [`StallReport`].
#[derive(Debug, Clone, Default)]
pub struct BottleneckReport {
    /// Stage with the highest busy fraction — the rate limiter every
    /// other stage is ultimately waiting on.
    pub limiting: Option<StageStall>,
    /// Most-stalled stage and the edge it starves or backpressures.
    pub victim: Option<Victim>,
}

impl fmt::Display for BottleneckReport {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let Some(lim) = &self.limiting else {
            return f.write_str("no stall data recorded");
        };
        if let Some(v) = &self.victim {
            write!(f, "{}: {:.0}% blocked-on-{}", v.stage, v.frac * 100.0, v.op)?;
            if let Some(edge) = &v.edge {
                write!(f, " -> edge {edge}")?;
            }
            write!(f, "; ")?;
        }
        write!(f, "limiting stage {} ({:.0}% busy)", lim.stage, lim.busy_frac() * 100.0)
    }
}

/// One registered pool's row in a [`BudgetSnapshot`]: its reservation
/// floor, the workers it currently holds (above the reservation =
/// borrowed headroom), how often its bids were denied, and whether a
/// denied bid is still queued.
#[derive(Debug, Clone, Default)]
pub struct BudgetLease {
    pub arch: String,
    pub reserved: usize,
    pub held: usize,
    pub denied: u64,
    pub waiting: bool,
}

/// Point-in-time view of the process-wide worker budget
/// (`stream::WorkerBudget`): the cap, what is leased out, what the
/// grant rule charges (`sum(max(held, reserved))`), and the per-pool
/// ledger.  Plain data so every observability surface — `StallReport`,
/// `/metrics`, `stats.json`, `RouterSnapshot` — renders the same view.
#[derive(Debug, Clone, Default)]
pub struct BudgetSnapshot {
    /// Hard cap on leased stage workers across every pool.
    pub total: usize,
    /// Workers currently leased out.
    pub held: usize,
    /// `sum(max(held, reserved))` — reservations stay charged even
    /// while unused, so they are always satisfiable.
    pub committed: usize,
    /// Denied grants across all pools since startup.
    pub denied: u64,
    /// One row per registered pool, registration order.
    pub leases: Vec<BudgetLease>,
}

impl BudgetSnapshot {
    /// Leased fraction of the cap, 0 when no budget is configured.
    pub fn utilization(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.held as f64 / self.total as f64
        }
    }

    /// Rows merged by arch label (an arch served by several router
    /// workers registers one client per pool; Prometheus series must
    /// not duplicate a label set).
    pub fn per_arch(&self) -> Vec<BudgetLease> {
        let mut out: Vec<BudgetLease> = Vec::new();
        for l in &self.leases {
            match out.iter_mut().find(|o| o.arch == l.arch) {
                Some(o) => {
                    o.reserved += l.reserved;
                    o.held += l.held;
                    o.denied += l.denied;
                    o.waiting |= l.waiting;
                }
                None => out.push(l.clone()),
            }
        }
        out
    }

    /// The machine-readable form used by `stats.json` and
    /// `repro stats --json`.
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("total_workers".to_string(), Json::Int(self.total as i64));
        o.insert("held_workers".to_string(), Json::Int(self.held as i64));
        o.insert("committed_workers".to_string(), Json::Int(self.committed as i64));
        o.insert("denied_total".to_string(), Json::Int(self.denied as i64));
        o.insert("utilization".to_string(), Json::Float(self.utilization()));
        let leases = self
            .per_arch()
            .into_iter()
            .map(|l| {
                let mut m = std::collections::BTreeMap::new();
                m.insert("arch".to_string(), Json::Str(l.arch));
                m.insert("reserved_workers".to_string(), Json::Int(l.reserved as i64));
                m.insert("held_workers".to_string(), Json::Int(l.held as i64));
                m.insert("denied_total".to_string(), Json::Int(l.denied as i64));
                m.insert("waiting".to_string(), Json::Bool(l.waiting));
                Json::Object(m)
            })
            .collect();
        o.insert("leases".to_string(), Json::Array(leases));
        Json::Object(o)
    }

    /// Append the budget's Prometheus samples (no `# TYPE` headers —
    /// the endpoint emits those once).  Process-level series carry no
    /// labels; per-pool series are labelled by arch.
    pub fn prometheus_samples(&self, out: &mut String) {
        use fmt::Write as _;
        let _ = writeln!(out, "repro_budget_total_workers {}", self.total);
        let _ = writeln!(out, "repro_budget_utilization {:.6}", self.utilization());
        let _ = writeln!(out, "repro_budget_denied_total {}", self.denied);
        for l in self.per_arch() {
            let _ = writeln!(
                out,
                "repro_budget_held_workers{{arch=\"{}\"}} {}",
                l.arch, l.held
            );
            let _ = writeln!(
                out,
                "repro_budget_reserved_workers{{arch=\"{}\"}} {}",
                l.arch, l.reserved
            );
            let _ = writeln!(
                out,
                "repro_budget_denied_grants_total{{arch=\"{}\"}} {}",
                l.arch, l.denied
            );
        }
    }
}

impl fmt::Display for BudgetSnapshot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "budget {}/{} workers leased ({:.0}% util, committed {}, denied {})",
            self.held,
            self.total,
            self.utilization() * 100.0,
            self.committed,
            self.denied
        )?;
        for l in self.per_arch() {
            write!(
                f,
                "\n  {:<12} holds {:>3} (reserved {:>3}, denied {}{})",
                l.arch,
                l.held,
                l.reserved,
                l.denied,
                if l.waiting { ", bid queued" } else { "" }
            )?;
        }
        Ok(())
    }
}

/// Replica-aggregated pool telemetry: per-stage wall-time splits,
/// per-edge stall/occupancy counters, and the pool gauges.
#[derive(Debug, Clone, Default)]
pub struct StallReport {
    /// Feeder, layer stages and sink, pipeline order, untagged names,
    /// counters summed across live replicas.
    pub stages: Vec<StageStall>,
    /// FIFO and window-gauge edges, untagged, merged across replicas.
    pub edges: Vec<EdgeStat>,
    /// Frames delivered by the pool since start.
    pub frames: u64,
    pub replicas: usize,
    pub peak_replicas: usize,
    /// Elastic controller scale events since pool start.
    pub scale_ups: u64,
    pub scale_downs: u64,
    /// Worker-budget view when the pool leases replicas from a shared
    /// `stream::WorkerBudget`; `None` for standalone pools.
    pub budget: Option<BudgetSnapshot>,
}

impl StallReport {
    /// Merge per-replica stall rows by (role, untagged stage name),
    /// preserving first-seen (pipeline) order.
    pub fn aggregate_stages(rows: impl IntoIterator<Item = StageStall>) -> Vec<StageStall> {
        let mut out: Vec<StageStall> = Vec::new();
        for row in rows {
            let key = base_name(&row.stage).to_string();
            match out.iter_mut().find(|s| s.role == row.role && s.stage == key) {
                Some(cur) => cur.merge(&row),
                None => {
                    let mut row = row;
                    row.stage = key;
                    out.push(row);
                }
            }
        }
        out
    }

    /// Merge per-replica edge rows by untagged FIFO name, preserving
    /// first-seen order.
    pub fn aggregate_edges(rows: impl IntoIterator<Item = EdgeStat>) -> Vec<EdgeStat> {
        let mut out: Vec<EdgeStat> = Vec::new();
        for row in rows {
            let key = base_name(&row.name).to_string();
            match out.iter_mut().find(|e| e.name == key) {
                Some(cur) => cur.merge(&row),
                None => {
                    let mut row = row;
                    row.name = key;
                    out.push(row);
                }
            }
        }
        out
    }

    /// Edge row by (untagged) name.
    pub fn edge(&self, name: &str) -> Option<&EdgeStat> {
        self.edges.iter().find(|e| e.name == name)
    }

    /// Stage row by (untagged) name.
    pub fn stage(&self, name: &str) -> Option<&StageStall> {
        self.stages.iter().find(|s| s.stage == name)
    }

    /// Derive the bottleneck verdict.  Only layer stages compete — the
    /// feeder and sink are I/O pseudo-stages whose waiting is the normal
    /// state — and stages that processed no frames yet are skipped.
    pub fn bottleneck(&self) -> BottleneckReport {
        let candidates: Vec<&StageStall> = self
            .stages
            .iter()
            .filter(|s| s.role == StageRole::Stage && s.elapsed_ns > 0 && s.frames > 0)
            .collect();
        let limiting = candidates
            .iter()
            .max_by(|a, b| {
                a.busy_frac().partial_cmp(&b.busy_frac()).unwrap_or(std::cmp::Ordering::Equal)
            })
            .map(|s| (*s).clone());
        let victim = candidates
            .iter()
            .map(|s| {
                let (frac, op, edge) = if s.blocked_push_ns >= s.blocked_pop_ns {
                    (
                        s.blocked_push_frac(),
                        BlockOp::Push,
                        s.worst_push_edge.as_ref().map(|(n, _)| n.clone()),
                    )
                } else {
                    (
                        s.blocked_pop_frac(),
                        BlockOp::Pop,
                        s.worst_pop_edge.as_ref().map(|(n, _)| n.clone()),
                    )
                };
                Victim { stage: s.stage.clone(), frac, op, edge }
            })
            .filter(|v| v.frac > 0.0)
            .max_by(|a, b| a.frac.partial_cmp(&b.frac).unwrap_or(std::cmp::Ordering::Equal));
        BottleneckReport { limiting, victim }
    }

    /// The machine-readable form served by the JSON endpoint and
    /// `repro stats --json`.
    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("frames".to_string(), Json::Int(self.frames as i64));
        o.insert("replicas".to_string(), Json::Int(self.replicas as i64));
        o.insert("peak_replicas".to_string(), Json::Int(self.peak_replicas as i64));
        o.insert("scale_ups".to_string(), Json::Int(self.scale_ups as i64));
        o.insert("scale_downs".to_string(), Json::Int(self.scale_downs as i64));
        let stages = self
            .stages
            .iter()
            .map(|s| {
                let mut m = std::collections::BTreeMap::new();
                m.insert("stage".to_string(), Json::Str(s.stage.clone()));
                m.insert(
                    "role".to_string(),
                    Json::Str(
                        match s.role {
                            StageRole::Feeder => "feeder",
                            StageRole::Stage => "stage",
                            StageRole::Sink => "sink",
                        }
                        .to_string(),
                    ),
                );
                m.insert("frames".to_string(), Json::Int(s.frames as i64));
                m.insert("busy_frac".to_string(), Json::Float(s.busy_frac()));
                m.insert("blocked_push_frac".to_string(), Json::Float(s.blocked_push_frac()));
                m.insert("blocked_pop_frac".to_string(), Json::Float(s.blocked_pop_frac()));
                if let Some((edge, ns)) = &s.worst_push_edge {
                    m.insert("worst_push_edge".to_string(), Json::Str(edge.clone()));
                    m.insert("worst_push_edge_ns".to_string(), Json::Int(*ns as i64));
                }
                if let Some((edge, ns)) = &s.worst_pop_edge {
                    m.insert("worst_pop_edge".to_string(), Json::Str(edge.clone()));
                    m.insert("worst_pop_edge_ns".to_string(), Json::Int(*ns as i64));
                }
                Json::Object(m)
            })
            .collect();
        o.insert("stages".to_string(), Json::Array(stages));
        let edges = self
            .edges
            .iter()
            .map(|e| {
                let mut m = std::collections::BTreeMap::new();
                m.insert("fifo".to_string(), Json::Str(e.name.clone()));
                m.insert("kind".to_string(), Json::Str(kind_label(e.kind).to_string()));
                m.insert("capacity".to_string(), Json::Int(e.capacity as i64));
                m.insert("peak".to_string(), Json::Int(e.peak as i64));
                m.insert("blocked_push_ns".to_string(), Json::Int(e.blocked_push_ns as i64));
                m.insert("blocked_pop_ns".to_string(), Json::Int(e.blocked_pop_ns as i64));
                m.insert("push_blocks".to_string(), Json::Int(e.push_blocks as i64));
                m.insert("pop_blocks".to_string(), Json::Int(e.pop_blocks as i64));
                m.insert(
                    "occupancy_hist".to_string(),
                    Json::Array(e.occ_hist.iter().map(|&c| Json::Int(c as i64)).collect()),
                );
                Json::Object(m)
            })
            .collect();
        o.insert("edges".to_string(), Json::Array(edges));
        o.insert("bottleneck".to_string(), Json::Str(self.bottleneck().to_string()));
        if let Some(b) = &self.budget {
            o.insert("budget".to_string(), b.to_json());
        }
        Json::Object(o)
    }

    /// Append Prometheus sample lines (no `# TYPE` headers — the
    /// endpoint emits those once) with `labels` spliced into every
    /// series (e.g. `arch="resnet8"`).
    pub fn prometheus_samples(&self, labels: &str, out: &mut String) {
        use fmt::Write as _;
        for s in &self.stages {
            if s.role != StageRole::Stage {
                continue;
            }
            let _ = writeln!(
                out,
                "repro_stage_busy_fraction{{{labels},stage=\"{}\"}} {:.6}",
                s.stage,
                s.busy_frac()
            );
            let _ = writeln!(
                out,
                "repro_stage_blocked_fraction{{{labels},stage=\"{}\",op=\"push\"}} {:.6}",
                s.stage,
                s.blocked_push_frac()
            );
            let _ = writeln!(
                out,
                "repro_stage_blocked_fraction{{{labels},stage=\"{}\",op=\"pop\"}} {:.6}",
                s.stage,
                s.blocked_pop_frac()
            );
            let _ = writeln!(
                out,
                "repro_stage_frames_total{{{labels},stage=\"{}\"}} {}",
                s.stage, s.frames
            );
        }
        for e in &self.edges {
            let _ = writeln!(
                out,
                "repro_fifo_capacity_elems{{{labels},fifo=\"{}\",kind=\"{}\"}} {}",
                e.name,
                kind_label(e.kind),
                e.capacity
            );
            let _ = writeln!(
                out,
                "repro_fifo_occupancy_peak_elems{{{labels},fifo=\"{}\",kind=\"{}\"}} {}",
                e.name,
                kind_label(e.kind),
                e.peak
            );
            for (op, ns) in [("push", e.blocked_push_ns), ("pop", e.blocked_pop_ns)] {
                let _ = writeln!(
                    out,
                    "repro_fifo_blocked_seconds_total{{{labels},fifo=\"{}\",op=\"{op}\"}} {:.6}",
                    e.name,
                    ns as f64 / 1e9
                );
            }
            // Cumulative histogram over occupancy fraction, Prometheus
            // `le` convention (the +Inf bucket equals total pushes).
            let mut cum = 0u64;
            for (i, c) in e.occ_hist.iter().enumerate() {
                cum += c;
                let le = (i + 1) as f64 / OCC_BUCKETS as f64;
                let _ = writeln!(
                    out,
                    "repro_fifo_occupancy_bucket{{{labels},fifo=\"{}\",le=\"{le}\"}} {cum}",
                    e.name
                );
            }
            let _ = writeln!(
                out,
                "repro_fifo_occupancy_bucket{{{labels},fifo=\"{}\",le=\"+Inf\"}} {cum}",
                e.name
            );
        }
        // Replica gauges are NOT emitted here: `net::metrics` exports
        // them per arch from the metrics snapshot unconditionally (they
        // must not disappear whenever no stall report is cached, and a
        // budget shift between arches must never be netted out).
        for (dir, n) in [("up", self.scale_ups), ("down", self.scale_downs)] {
            let _ = writeln!(
                out,
                "repro_stream_scale_events_total{{{labels},dir=\"{dir}\"}} {n}"
            );
        }
        let _ = writeln!(out, "repro_stream_frames_total{{{labels}}} {}", self.frames);
    }
}

/// Stable lowercase label for a stream kind.
pub(crate) fn kind_label(kind: StreamKind) -> &'static str {
    match kind {
        StreamKind::Parameter => "parameter",
        StreamKind::WindowSlice => "window",
        StreamKind::Output => "output",
        StreamKind::Skip => "skip",
        StreamKind::Dma => "dma",
    }
}

impl fmt::Display for StallReport {
    /// The human table behind `repro stats`.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "{:<20} {:>8} {:>7} {:>9} {:>8}  worst edge",
            "thread", "frames", "busy%", "blk-push%", "blk-pop%"
        )?;
        for s in &self.stages {
            let edge = if s.blocked_push_ns >= s.blocked_pop_ns {
                s.worst_push_edge.as_ref().map(|(n, _)| format!("{n} (push)"))
            } else {
                s.worst_pop_edge.as_ref().map(|(n, _)| format!("{n} (pop)"))
            };
            writeln!(
                f,
                "{:<20} {:>8} {:>7.1} {:>9.1} {:>8.1}  {}",
                s.stage,
                s.frames,
                s.busy_frac() * 100.0,
                s.blocked_push_frac() * 100.0,
                s.blocked_pop_frac() * 100.0,
                edge.unwrap_or_default()
            )?;
        }
        writeln!(
            f,
            "{:<20} {:>8} {:>8} {:>12} {:>11}  occupancy (8 buckets)",
            "fifo", "cap", "peak", "blk-push ms", "blk-pop ms"
        )?;
        for e in &self.edges {
            let hist =
                e.occ_hist.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(":");
            writeln!(
                f,
                "{:<20} {:>8} {:>8} {:>12.1} {:>11.1}  {hist}",
                e.name,
                e.capacity,
                e.peak,
                e.blocked_push_ns as f64 / 1e6,
                e.blocked_pop_ns as f64 / 1e6
            )?;
        }
        writeln!(
            f,
            "frames {}  replicas {} (peak {})  scale up/down {}/{}",
            self.frames, self.replicas, self.peak_replicas, self.scale_ups, self.scale_downs
        )?;
        if let Some(b) = &self.budget {
            writeln!(f, "{b}")?;
        }
        write!(f, "bottleneck: {}", self.bottleneck())
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;

    fn stall(name: &str, role: StageRole, busy: u64, push: u64, pop: u64) -> StageStall {
        StageStall {
            stage: name.to_string(),
            role,
            elapsed_ns: busy + push + pop,
            blocked_push_ns: push,
            blocked_pop_ns: pop,
            frames: 10,
            worst_push_edge: (push > 0).then(|| (format!("{name}.out"), push)),
            worst_pop_edge: (pop > 0).then(|| (format!("{name}.in"), pop)),
        }
    }

    #[test]
    fn aggregation_strips_replica_tags_and_sums() {
        let rows = vec![
            stall("conv0", StageRole::Stage, 80, 10, 10),
            stall("r1/conv0", StageRole::Stage, 40, 50, 10),
            stall("linear", StageRole::Stage, 10, 0, 90),
        ];
        let agg = StallReport::aggregate_stages(rows);
        assert_eq!(agg.len(), 2);
        assert_eq!(agg[0].stage, "conv0");
        assert_eq!(agg[0].elapsed_ns, 200);
        assert_eq!(agg[0].blocked_push_ns, 60);
        assert_eq!(agg[0].frames, 20);
        assert_eq!(agg[1].stage, "linear");
    }

    #[test]
    fn bottleneck_names_limiting_stage_and_victim_edge() {
        let report = StallReport {
            stages: vec![
                stall("feeder", StageRole::Feeder, 1, 99, 0),
                stall("s0b0c1", StageRole::Stage, 90, 5, 5),
                stall("s0b0c2", StageRole::Stage, 20, 71, 9),
                stall("sink", StageRole::Sink, 1, 0, 99),
            ],
            ..Default::default()
        };
        let b = report.bottleneck();
        let lim = b.limiting.expect("limiting stage");
        assert_eq!(lim.stage, "s0b0c1");
        let v = b.victim.expect("victim stage");
        assert_eq!(v.stage, "s0b0c2");
        assert_eq!(v.op, BlockOp::Push);
        assert_eq!(v.edge.as_deref(), Some("s0b0c2.out"));
        let line = b.to_string();
        assert!(line.contains("s0b0c2: 71% blocked-on-push -> edge s0b0c2.out"), "{line}");
        assert!(line.contains("limiting stage s0b0c1 (90% busy)"), "{line}");
    }

    #[test]
    fn bottleneck_ignores_pseudo_stages_and_empty_reports() {
        let empty = StallReport::default();
        assert!(empty.bottleneck().limiting.is_none());
        assert_eq!(empty.bottleneck().to_string(), "no stall data recorded");
        // Only feeder/sink rows: still no verdict.
        let io_only = StallReport {
            stages: vec![
                stall("feeder", StageRole::Feeder, 1, 99, 0),
                stall("sink", StageRole::Sink, 1, 0, 99),
            ],
            ..Default::default()
        };
        assert!(io_only.bottleneck().limiting.is_none());
    }

    #[test]
    fn edge_aggregation_merges_histograms_and_peaks() {
        let mk = |name: &str, peak: usize| EdgeStat {
            name: name.to_string(),
            kind: StreamKind::Skip,
            capacity: 128,
            peak,
            blocked_push_ns: 5,
            blocked_pop_ns: 7,
            push_blocks: 1,
            pop_blocks: 2,
            occ_hist: [1; OCC_BUCKETS],
        };
        let agg = StallReport::aggregate_edges(vec![mk("a.skip", 10), mk("r1/a.skip", 60)]);
        assert_eq!(agg.len(), 1);
        assert_eq!(agg[0].name, "a.skip");
        assert_eq!(agg[0].peak, 60);
        assert_eq!(agg[0].blocked_push_ns, 10);
        assert_eq!(agg[0].occ_hist, [2; OCC_BUCKETS]);
        assert_eq!(agg[0].pushes(), 16);
    }

    #[test]
    fn json_and_prometheus_expose_the_required_families() {
        let report = StallReport {
            stages: vec![stall("s0b0c1", StageRole::Stage, 90, 5, 5)],
            edges: StallReport::aggregate_edges(vec![EdgeStat {
                name: "s0b0c2.skip".to_string(),
                kind: StreamKind::Skip,
                capacity: 128,
                peak: 64,
                blocked_push_ns: 1_000_000,
                blocked_pop_ns: 0,
                push_blocks: 3,
                pop_blocks: 0,
                occ_hist: [4; OCC_BUCKETS],
            }]),
            frames: 32,
            replicas: 2,
            peak_replicas: 3,
            scale_ups: 2,
            scale_downs: 1,
            budget: Some(BudgetSnapshot {
                total: 24,
                held: 18,
                committed: 20,
                denied: 3,
                leases: vec![
                    BudgetLease {
                        arch: "resnet8".into(),
                        reserved: 8,
                        held: 16,
                        denied: 1,
                        waiting: false,
                    },
                    BudgetLease {
                        arch: "resnet20".into(),
                        reserved: 4,
                        held: 2,
                        denied: 2,
                        waiting: true,
                    },
                ],
            }),
        };
        let j = report.to_json();
        assert_eq!(j.at("frames").and_then(|v| v.as_i64()), Some(32));
        assert_eq!(j.at("budget/total_workers").and_then(|v| v.as_i64()), Some(24));
        let leases = j.at("budget/leases").and_then(|v| v.as_array()).expect("leases");
        assert_eq!(leases.len(), 2);
        assert_eq!(leases[1].get("waiting"), Some(&Json::Bool(true)));
        let stages = j.at("stages").and_then(|v| v.as_array()).expect("stages array");
        assert_eq!(stages[0].get("stage").and_then(|v| v.as_str()), Some("s0b0c1"));
        let edges = j.at("edges").and_then(|v| v.as_array()).expect("edges array");
        assert_eq!(edges[0].get("kind").and_then(|v| v.as_str()), Some("skip"));
        assert!(j.at("bottleneck").is_some());

        let mut prom = String::new();
        report.prometheus_samples("arch=\"resnet8\"", &mut prom);
        for family in [
            "repro_stage_busy_fraction{arch=\"resnet8\",stage=\"s0b0c1\"}",
            "repro_stage_blocked_fraction{arch=\"resnet8\",stage=\"s0b0c1\",op=\"push\"}",
            "repro_fifo_occupancy_peak_elems{arch=\"resnet8\",fifo=\"s0b0c2.skip\"",
            "repro_fifo_blocked_seconds_total{arch=\"resnet8\",fifo=\"s0b0c2.skip\",op=\"push\"}",
            "repro_fifo_occupancy_bucket{arch=\"resnet8\",fifo=\"s0b0c2.skip\",le=\"+Inf\"} 32",
            "repro_stream_scale_events_total{arch=\"resnet8\",dir=\"up\"} 2",
        ] {
            assert!(prom.contains(family), "missing {family} in:\n{prom}");
        }
        // Replica gauges moved to the per-arch serving samples so they
        // survive stall-report gaps; the stall report must no longer
        // emit a competing series.
        assert!(!prom.contains("repro_stream_replicas"), "duplicate replica series:\n{prom}");

        let mut bprom = String::new();
        report.budget.as_ref().expect("budget section").prometheus_samples(&mut bprom);
        for family in [
            "repro_budget_total_workers 24",
            "repro_budget_utilization 0.75",
            "repro_budget_denied_total 3",
            "repro_budget_held_workers{arch=\"resnet8\"} 16",
            "repro_budget_reserved_workers{arch=\"resnet20\"} 4",
            "repro_budget_denied_grants_total{arch=\"resnet20\"} 2",
        ] {
            assert!(bprom.contains(family), "missing {family} in:\n{bprom}");
        }

        let text = report.to_string();
        assert!(text.contains("budget 18/24 workers leased"), "{text}");
        assert!(text.contains("bid queued"), "{text}");
    }
}
