//! QONNX-like network graph IR (paper Fig. 2: the parsed model description
//! the code-generation step works on).
//!
//! The IR deliberately models the paper's *pre-optimization* graphs too —
//! explicit BatchNorm, ReLU and Add nodes — so the `passes` module can
//! perform the published transformations (BN/ReLU merging, loop merge,
//! temporal reuse, add fusion) and tests can verify they arrive at the
//! optimized dataflow that `models::resnet` builds directly.

// Panic-freedom gate: graph construction and QONNX parsing run inside
// serving-backend factories, so failures must be typed errors, never
// unwinds.  `clippy.toml` disallows Option/Result unwrap+expect; test
// modules opt out locally.
#![deny(clippy::disallowed_methods)]

mod ir;
pub mod qonnx;
mod shapes;

pub use ir::*;
pub use shapes::{infer_shapes, output_shape, ShapeError, TensorShape};
