//! Shape inference over the graph IR.
//!
//! Shapes are per-edge (node, port); the optimization passes must preserve
//! every live edge's shape — a property test in `rust/tests/props.rs`
//! asserts exactly that.

use std::collections::BTreeMap;

use super::ir::{Edge, Graph, Op};

/// An activation tensor shape (H, W, C) with its quantization exponent.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TensorShape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub exp: i32,
}

#[derive(Debug, Clone)]
pub struct ShapeError(pub String);

impl std::fmt::Display for ShapeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "shape error: {}", self.0)
    }
}

impl std::error::Error for ShapeError {}

/// Infer the shape of every live output edge.
pub fn infer_shapes(g: &Graph) -> Result<BTreeMap<Edge, TensorShape>, ShapeError> {
    let mut shapes: BTreeMap<Edge, TensorShape> = BTreeMap::new();
    for n in g.live() {
        let input_shape = |i: usize| -> Result<TensorShape, ShapeError> {
            let (e, _) = n
                .inputs
                .get(i)
                .ok_or_else(|| ShapeError(format!("{} missing input {i}", n.name)))?;
            shapes
                .get(e)
                .copied()
                .ok_or_else(|| ShapeError(format!("{} reads unshaped edge {:?}", n.name, e)))
        };
        match &n.op {
            Op::Input { h, w, c, exp } => {
                shapes.insert(Edge::new(n.id, 0), TensorShape { h: *h, w: *w, c: *c, exp: *exp });
            }
            Op::Conv(a) => {
                let s = input_shape(0)?;
                if s.c != a.cin {
                    return Err(ShapeError(format!(
                        "{}: cin {} but input has {} channels", n.name, a.cin, s.c
                    )));
                }
                if a.stride == 0 {
                    return Err(ShapeError(format!("{}: conv stride must be >= 1", n.name)));
                }
                if a.k == 0 || s.h + 2 * a.pad < a.k || s.w + 2 * a.pad < a.k {
                    return Err(ShapeError(format!(
                        "{}: kernel {} exceeds padded input {}x{} (pad {})",
                        n.name, a.k, s.h, s.w, a.pad
                    )));
                }
                let oh = (s.h + 2 * a.pad - a.k) / a.stride + 1;
                let ow = (s.w + 2 * a.pad - a.k) / a.stride + 1;
                // Raw-output convs stream int32 accumulators at the
                // accumulator exponent (input exp + weight exp).
                let exp = if a.raw_output { s.exp + a.w_exp } else { a.out_exp };
                shapes.insert(
                    Edge::new(n.id, 0),
                    TensorShape { h: oh, w: ow, c: a.cout, exp },
                );
                if a.forwards_input {
                    // Port 1 re-emits the input tensor (temporal reuse).
                    shapes.insert(Edge::new(n.id, 1), s);
                } else if let Some(ds) = &a.merged_downsample {
                    // Port 1 carries the merged downsample conv's output.
                    if ds.stride == 0 {
                        return Err(ShapeError(format!(
                            "{}: downsample stride must be >= 1", ds.name
                        )));
                    }
                    if ds.k == 0 || s.h + 2 * ds.pad < ds.k || s.w + 2 * ds.pad < ds.k {
                        return Err(ShapeError(format!(
                            "{}: downsample kernel {} exceeds padded input {}x{}",
                            ds.name, ds.k, s.h, s.w
                        )));
                    }
                    let dh = (s.h + 2 * ds.pad - ds.k) / ds.stride + 1;
                    let dw = (s.w + 2 * ds.pad - ds.k) / ds.stride + 1;
                    shapes.insert(
                        Edge::new(n.id, 1),
                        TensorShape { h: dh, w: dw, c: ds.cout, exp: ds.out_exp },
                    );
                }
            }
            Op::BatchNorm(b) => {
                let s = input_shape(0)?;
                if s.c != b.channels {
                    return Err(ShapeError(format!("{}: bn channels mismatch", n.name)));
                }
                shapes.insert(Edge::new(n.id, 0), s);
            }
            Op::Relu => {
                let s = input_shape(0)?;
                shapes.insert(Edge::new(n.id, 0), s);
            }
            Op::Add { out_exp } => {
                // N-ary residual merge: every operand (the long branch plus
                // one or more skips) must agree on the spatial shape.
                let a = input_shape(0)?;
                for i in 1..n.inputs.len() {
                    let b = input_shape(i)?;
                    if (a.h, a.w, a.c) != (b.h, b.w, b.c) {
                        return Err(ShapeError(format!(
                            "{}: add operand {i} {:?} vs {:?}",
                            n.name,
                            (b.h, b.w, b.c),
                            (a.h, a.w, a.c)
                        )));
                    }
                }
                shapes.insert(Edge::new(n.id, 0), TensorShape { exp: *out_exp, ..a });
            }
            Op::MaxPool { k, stride } => {
                let s = input_shape(0)?;
                if *stride == 0 {
                    return Err(ShapeError(format!("{}: pool stride must be >= 1", n.name)));
                }
                if *k == 0 || *k > s.h || *k > s.w {
                    return Err(ShapeError(format!(
                        "{}: pool window {} exceeds input {}x{}", n.name, k, s.h, s.w
                    )));
                }
                shapes.insert(
                    Edge::new(n.id, 0),
                    TensorShape { h: (s.h - k) / stride + 1, w: (s.w - k) / stride + 1, ..s },
                );
            }
            Op::GlobalAvgPool { out_exp } => {
                let s = input_shape(0)?;
                shapes.insert(Edge::new(n.id, 0), TensorShape { h: 1, w: 1, c: s.c, exp: *out_exp });
            }
            Op::Linear { cin, cout, .. } => {
                let s = input_shape(0)?;
                if s.h * s.w * s.c != *cin {
                    return Err(ShapeError(format!(
                        "{}: linear cin {} vs input {}x{}x{}", n.name, cin, s.h, s.w, s.c
                    )));
                }
                // Logits are int32 at an implementation-defined exponent; use
                // the accumulator exponent (input exp + weight exp).
                shapes.insert(Edge::new(n.id, 0), TensorShape { h: 1, w: 1, c: *cout, exp: 0 });
            }
        }
    }
    Ok(shapes)
}

/// Shape of a node's primary output.
pub fn output_shape(g: &Graph, node: usize) -> Result<TensorShape, ShapeError> {
    let shapes = infer_shapes(g)?;
    shapes
        .get(&Edge::new(node, 0))
        .copied()
        .ok_or_else(|| ShapeError(format!("no shape for node {node}")))
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)]
mod tests {
    use super::*;
    use crate::graph::ir::{ConvAttrs, Graph, Op};

    #[test]
    fn conv_shapes() {
        let mut g = Graph::new();
        let i = g.add_simple("in", Op::Input { h: 32, w: 32, c: 3, exp: -7 }, &[]);
        let c = g.add_simple(
            "c",
            Op::Conv(ConvAttrs {
                cin: 3, cout: 16, k: 3, stride: 2, pad: 1, relu: true,
                w_exp: -8, out_exp: -5, merged_downsample: None, forwards_input: false, raw_output: false,
            }),
            &[Edge::new(i, 0)],
        );
        let shapes = infer_shapes(&g).unwrap();
        let s = shapes[&Edge::new(c, 0)];
        assert_eq!((s.h, s.w, s.c), (16, 16, 16));
    }

    #[test]
    fn oversized_kernel_and_zero_stride_rejected() {
        // Kernel beyond the padded input: shape error, not usize underflow.
        let mut g = Graph::new();
        let i = g.add_simple("in", Op::Input { h: 3, w: 3, c: 1, exp: -7 }, &[]);
        g.add_simple(
            "c",
            Op::Conv(ConvAttrs {
                cin: 1, cout: 1, k: 5, stride: 1, pad: 0, relu: false,
                w_exp: -8, out_exp: -5, merged_downsample: None, forwards_input: false, raw_output: false,
            }),
            &[Edge::new(i, 0)],
        );
        assert!(infer_shapes(&g).is_err());

        let mut g = Graph::new();
        let i = g.add_simple("in", Op::Input { h: 4, w: 4, c: 1, exp: -7 }, &[]);
        g.add_simple("mp", Op::MaxPool { k: 5, stride: 1 }, &[Edge::new(i, 0)]);
        assert!(infer_shapes(&g).is_err());

        let mut g = Graph::new();
        let i = g.add_simple("in", Op::Input { h: 4, w: 4, c: 1, exp: -7 }, &[]);
        g.add_simple("mp", Op::MaxPool { k: 2, stride: 0 }, &[Edge::new(i, 0)]);
        assert!(infer_shapes(&g).is_err());
    }

    #[test]
    fn mismatched_cin_rejected() {
        let mut g = Graph::new();
        let i = g.add_simple("in", Op::Input { h: 8, w: 8, c: 3, exp: -7 }, &[]);
        g.add_simple(
            "c",
            Op::Conv(ConvAttrs {
                cin: 4, cout: 8, k: 3, stride: 1, pad: 1, relu: false,
                w_exp: -8, out_exp: -5, merged_downsample: None, forwards_input: false, raw_output: false,
            }),
            &[Edge::new(i, 0)],
        );
        assert!(infer_shapes(&g).is_err());
    }
}
